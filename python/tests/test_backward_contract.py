"""Backward contract: the Rust gradient semantics vs ``jax.vjp``.

``rust/src/conv/backward.rs`` defines the gradient convention every
planned backward lane is pinned to (bit-identically, by
``rust/tests/backward_grad.rs``): data-grad as the full correlation of
the padded output-gradient with the flipped kernel, weight-grad as the
patch × output-gradient accumulation — both phrased over the same
bed-of-nails upsample + pad-by-``P`` + VALID-correlation forward the
layout contract pins.  This test mirrors those gradients index-by-index
in plain numpy (sharing nothing with jax's autodiff) and asserts they
agree with ``jax.vjp`` of ``ref.conventional_transpose_conv`` on the
golden case grid — so a drift in either side's backward convention
fails without any Rust toolchain in the loop.
"""

import jax
import jax.numpy as jnp
import numpy as np

from compile.aot import GOLDEN_CASES
from compile.kernels import ref


def rust_backward_mirror(x, k, dy, padding):
    """numpy mirror of ``grad_input_conventional`` + ``grad_kernel_conventional``.

    Chain rule through the conventional forward, written as explicit
    scatter/gather loops: the upsampled-map gradient accumulates
    ``dy ⊛ k`` patch by patch, then crops the padding and keeps the
    even (real-pixel) positions; the kernel gradient accumulates
    ``patch ⊗ dy`` over every output position.
    """
    n = x.shape[0]
    nk = k.shape[0]
    up_n = 2 * n - 1
    c = x.shape[2]
    padded = up_n + 2 * padding
    up = np.zeros((up_n, up_n, c), np.float32)
    up[::2, ::2, :] = x
    upp = np.zeros((padded, padded, c), np.float32)
    upp[padding : padding + up_n, padding : padding + up_n, :] = up
    ho = padded - nk + 1
    dupp = np.zeros_like(upp)
    dk = np.zeros_like(k)
    for oy in range(ho):
        for ox in range(ho):
            g = dy[oy, ox, :]
            dupp[oy : oy + nk, ox : ox + nk, :] += np.einsum("uvco,o->uvc", k, g)
            dk += np.einsum("uvc,o->uvco", upp[oy : oy + nk, ox : ox + nk, :], g)
    dup = dupp[padding : padding + up_n, padding : padding + up_n, :]
    dx = dup[::2, ::2, :]
    return dx, dk


def test_rust_backward_semantics_match_jax_vjp():
    rng = np.random.default_rng(2024)  # same seed family as the goldens
    for n_in, n_k, pad, cin, cout in GOLDEN_CASES:
        x = rng.standard_normal((n_in, n_in, cin)).astype(np.float32)
        k = rng.standard_normal((n_k, n_k, cin, cout)).astype(np.float32)
        out_n = 2 * n_in + 2 * pad - n_k
        dy = rng.standard_normal((out_n, out_n, cout)).astype(np.float32)

        def f(xx, kk, pad=pad):
            return ref.conventional_transpose_conv(xx, kk, pad)

        y, vjp = jax.vjp(f, jnp.asarray(x), jnp.asarray(k))
        assert y.shape == (out_n, out_n, cout), (n_in, n_k, pad)
        want_dx, want_dk = (np.asarray(v) for v in vjp(jnp.asarray(dy)))
        got_dx, got_dk = rust_backward_mirror(x, k, dy, pad)
        assert got_dx.shape == want_dx.shape == x.shape
        assert got_dk.shape == want_dk.shape == k.shape
        dx_err = float(np.abs(got_dx - want_dx).max())
        dk_err = float(np.abs(got_dk - want_dk).max())
        dx_tol = 1e-3 * (1.0 + float(np.abs(want_dx).max()))
        dk_tol = 1e-3 * (1.0 + float(np.abs(want_dk).max()))
        assert dx_err < dx_tol, f"N={n_in} n={n_k} P={pad}: dx err {dx_err}"
        assert dk_err < dk_tol, f"N={n_in} n={n_k} P={pad}: dk err {dk_err}"


def test_zero_cotangent_gives_zero_grads():
    # The gradient mirrors are linear in dy: a zero cotangent must
    # produce exactly zero gradients (no stray accumulation).
    n_in, n_k, pad, cin, cout = GOLDEN_CASES[0]
    rng = np.random.default_rng(7)
    x = rng.standard_normal((n_in, n_in, cin)).astype(np.float32)
    k = rng.standard_normal((n_k, n_k, cin, cout)).astype(np.float32)
    out_n = 2 * n_in + 2 * pad - n_k
    dy = np.zeros((out_n, out_n, cout), np.float32)
    dx, dk = rust_backward_mirror(x, k, dy, pad)
    assert not dx.any() and not dk.any()
