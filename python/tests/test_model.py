"""L2 correctness: generator shapes, zoo geometry, AOT manifest sanity."""

import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.kernels import ref

RNG = np.random.default_rng(11)


# -------------------------------------------------------------- zoo geometry


@pytest.mark.parametrize("name", list(M.GAN_ZOO))
def test_zoo_layers_chain(name):
    """Each layer's output size/channels feed the next layer (Table 4)."""
    layers = M.GAN_ZOO[name]
    for a, b in zip(layers, layers[1:]):
        assert a.n_out == b.n_in, (name, a, b)
        assert a.cout == b.cin, (name, a, b)


def test_zoo_matches_table4_shapes():
    assert [l.n_in for l in M.GAN_ZOO["dcgan"]] == [4, 8, 16, 32]
    assert [l.cin for l in M.GAN_ZOO["dcgan"]] == [1024, 512, 256, 128]
    assert M.GAN_ZOO["dcgan"][-1].cout == 3
    assert [l.n_in for l in M.GAN_ZOO["ebgan"]] == [4, 8, 16, 32, 64, 128]
    assert M.GAN_ZOO["ebgan"][0].cin == 2048
    assert M.GAN_ZOO["ebgan"][-1].cout == 64


def test_gan_layer_doubles_spatial():
    """k=4, P=2 (the zoo default) is the standard 2× upsampling block."""
    spec = M.LayerSpec(16, 8, 4)
    assert spec.n_out == 32


# ------------------------------------------------------------- generator fwd


def _tiny_zoo(monkeypatch):
    """Shrink channel counts so the full forward runs in milliseconds."""
    tiny = {
        "tiny": [
            M.LayerSpec(4, 8, 6),
            M.LayerSpec(8, 6, 4),
            M.LayerSpec(16, 4, 3),
        ]
    }
    monkeypatch.setitem(M.GAN_ZOO, "tiny", tiny["tiny"])


def test_generator_fwd_shape(monkeypatch):
    _tiny_zoo(monkeypatch)
    params = M.init_params("tiny", seed=3)
    z = jnp.asarray(RNG.standard_normal((2, M.Z_DIM)), jnp.float32)
    img = M.generator_fwd("tiny", z, *params)
    assert img.shape == (2, 32, 32, 3)
    assert np.all(np.abs(np.asarray(img)) <= 1.0)  # tanh range


def test_generator_deterministic(monkeypatch):
    _tiny_zoo(monkeypatch)
    params = M.init_params("tiny", seed=3)
    z = jnp.asarray(RNG.standard_normal((1, M.Z_DIM)), jnp.float32)
    a = M.generator_fwd("tiny", z, *params)
    b = M.generator_fwd("tiny", z, *params)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_weight_shapes_consistent():
    shapes = M.weight_shapes("dcgan")
    # projection w/b + 4 × (kernel, bias)
    assert len(shapes) == 2 + 2 * 4
    assert shapes[0] == (M.Z_DIM, 4 * 4 * 1024)
    assert shapes[2] == (4, 4, 1024, 512)
    assert shapes[-1] == (3,)


def test_single_layer_fwd_matches_oracle():
    x = jnp.asarray(RNG.standard_normal((1, 8, 8, 8)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((4, 4, 8, 4)), jnp.float32)
    got = M.single_layer_fwd(x, k, padding=2)
    want = ref.conventional_transpose_conv(x, k, 2)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), atol=2e-4, rtol=2e-4
    )


# ----------------------------------------------------------------- manifest

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ARTIFACTS, "manifest.json")),
    reason="run `make artifacts` first",
)
def test_manifest_artifacts_exist():
    with open(os.path.join(ARTIFACTS, "manifest.json")) as f:
        manifest = json.load(f)
    assert manifest["version"] == 1
    names = {a["name"] for a in manifest["artifacts"]}
    assert {"unified_layer_s8", "conv_layer_s8"} <= names
    for art in manifest["artifacts"]:
        path = os.path.join(ARTIFACTS, art["path"])
        assert os.path.exists(path), art["path"]
        head = open(path).read(200)
        assert "HloModule" in head  # HLO text, not proto
        assert art["output_shape"]
        assert all(i["shape"] for i in art["inputs"])


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ARTIFACTS, "golden.json")),
    reason="run `make artifacts` first",
)
def test_golden_vectors_shapes():
    with open(os.path.join(ARTIFACTS, "golden.json")) as f:
        golden = json.load(f)
    assert len(golden["cases"]) >= 8
    for c in golden["cases"]:
        assert len(c["x"]) == c["n_in"] ** 2 * c["cin"]
        assert len(c["k"]) == c["n_k"] ** 2 * c["cin"] * c["cout"]
        ho = 2 * c["n_in"] + 2 * c["padding"] - c["n_k"]
        assert c["out_shape"] == [ho, ho, c["cout"]]
        assert len(c["out"]) == ho * ho * c["cout"]
