"""Layout contract: the Rust kernels' semantics vs the JAX oracle.

The Rust side cannot import jax, so its algorithms are pinned to the
oracle through golden vectors (``make artifacts`` → ``golden.json`` →
``rust/tests/golden.rs``).  That pin only catches layout drift *after*
artifacts are rebuilt — this test closes the loop earlier by mirroring
the exact semantics of ``rust/src/conv/conventional.rs`` (row-major HWC
features, HWIO kernels, bed-of-nails upsample, pad by ``P``, VALID
stride-1 cross-correlation) in plain numpy and asserting it agrees with
``ref.conventional_transpose_conv`` on the same case grid ``aot.py``
exports as goldens.  If either side changes its layout convention, this
fails without any Rust toolchain in the loop.
"""

import jax.numpy as jnp
import numpy as np

from compile.aot import GOLDEN_CASES
from compile.kernels import ref


def rust_conventional_mirror(x: np.ndarray, k: np.ndarray, padding: int) -> np.ndarray:
    """numpy mirror of ``rust/src/conv/conventional.rs::transpose_conv``.

    Deliberately index-by-index (no lax.conv) so it shares nothing with
    the oracle's implementation.
    """
    n = x.shape[0]
    nk = k.shape[0]
    up = np.zeros((2 * n - 1, 2 * n - 1, x.shape[2]), np.float32)
    up[::2, ::2, :] = x  # real pixels at even coordinates
    upp = np.pad(up, ((padding, padding), (padding, padding), (0, 0)))
    ho = upp.shape[0] - nk + 1
    out = np.zeros((ho, ho, k.shape[3]), np.float32)
    for oy in range(ho):
        for ox in range(ho):
            patch = upp[oy : oy + nk, ox : ox + nk, :]
            out[oy, ox, :] = np.einsum("uvc,uvco->o", patch, k)
    return out


def test_rust_semantics_match_oracle_on_golden_grid():
    rng = np.random.default_rng(2024)  # same seed as aot.emit_golden
    for n_in, n_k, pad, cin, cout in GOLDEN_CASES:
        x = rng.standard_normal((n_in, n_in, cin)).astype(np.float32)
        k = rng.standard_normal((n_k, n_k, cin, cout)).astype(np.float32)
        want = np.asarray(
            ref.conventional_transpose_conv(jnp.asarray(x), jnp.asarray(k), pad)
        )
        got = rust_conventional_mirror(x, k, pad)
        assert got.shape == want.shape, (n_in, n_k, pad)
        err = float(np.abs(got - want).max())
        assert err < 2e-4, f"N={n_in} n={n_k} P={pad}: max err {err}"


def test_output_size_formula():
    # Ho = 2N + 2P - n, shared by rust conv::out_size and the oracle.
    for n_in, n_k, pad, cin, _ in GOLDEN_CASES:
        x = jnp.zeros((n_in, n_in, cin), jnp.float32)
        k = jnp.zeros((n_k, n_k, cin, 1), jnp.float32)
        out = ref.conventional_transpose_conv(x, k, pad)
        assert out.shape[0] == 2 * n_in + 2 * pad - n_k
