"""L1 correctness: Pallas unified kernel vs the pure-jnp oracle.

This is the CORE correctness signal of the build path — every shape,
padding and dtype combination exercised here is a configuration the Rust
runtime may ship as an artifact.
"""

import math

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, unified

RNG = np.random.default_rng(7)


def _rand(*shape):
    return jnp.asarray(RNG.standard_normal(shape), jnp.float32)


def _assert_close(a, b, tol=2e-4):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=tol, rtol=tol)


# ---------------------------------------------------------------- fixtures

CASES = [
    # (n_in, n_k, padding) — paper configurations + edge cases
    (4, 5, 2),  # Fig. 5/6 worked example
    (4, 4, 2),  # GAN layer geometry (k=4, s=2, p=1 → P=2)
    (4, 3, 1),
    (5, 3, 0),
    (5, 5, 2),
    (6, 4, 1),
    (7, 5, 3),  # odd P → §3.4 sub-kernel role swap
    (3, 3, 2),
    (8, 4, 2),
    (1, 3, 2),  # degenerate 1×1 input
    (2, 2, 0),  # minimal even kernel
]


@pytest.mark.parametrize("n_in,n_k,pad", CASES)
def test_unified_pallas_matches_oracle(n_in, n_k, pad):
    x = _rand(n_in, n_in, 3)
    k = _rand(n_k, n_k, 3, 2)
    _assert_close(
        unified.unified_transpose_conv(x, k, pad),
        ref.conventional_transpose_conv(x, k, pad),
    )


@pytest.mark.parametrize("n_in,n_k,pad", CASES)
def test_conventional_pallas_matches_oracle(n_in, n_k, pad):
    x = _rand(n_in, n_in, 2)
    k = _rand(n_k, n_k, 2, 2)
    _assert_close(
        unified.conventional_transpose_conv_pallas(x, k, pad),
        ref.conventional_transpose_conv(x, k, pad),
    )


@pytest.mark.parametrize("batch", [1, 2, 5])
def test_batched(batch):
    x = _rand(batch, 4, 4, 3)
    k = _rand(4, 4, 3, 2)
    got = unified.unified_transpose_conv(x, k, 2)
    want = ref.conventional_transpose_conv(x, k, 2)
    assert got.shape == (batch, 8, 8, 2)
    _assert_close(got, want)


def test_unified_ref_matches_conventional_ref():
    x = _rand(6, 6, 4)
    k = _rand(5, 5, 4, 3)
    _assert_close(
        ref.unified_transpose_conv_ref(x, k, 2),
        ref.conventional_transpose_conv(x, k, 2),
    )


# ------------------------------------------------------------ segregation


def test_segregation_sizes_5x5():
    """Fig. 4: a 5×5 kernel segregates into 9/6/6/4-element sub-kernels."""
    k = _rand(5, 5, 1, 1)
    k00, k01, k10, k11 = ref.segregate_kernel(k)
    assert k00.shape[:2] == (3, 3)
    assert k01.shape[:2] == (3, 2)
    assert k10.shape[:2] == (2, 3)
    assert k11.shape[:2] == (2, 2)


@pytest.mark.parametrize("n_k", [2, 3, 4, 5, 6, 7])
def test_segregation_partitions_kernel(n_k):
    """The four sub-kernels partition the original kernel's elements."""
    k = _rand(n_k, n_k, 1, 1)
    subs = ref.segregate_kernel(k)
    total = sum(s.shape[0] * s.shape[1] for s in subs)
    assert total == n_k * n_k
    ceil, floor = math.ceil(n_k / 2), n_k // 2
    assert subs[0].shape[:2] == (ceil, ceil)
    assert subs[1].shape[:2] == (ceil, floor)
    assert subs[2].shape[:2] == (floor, ceil)
    assert subs[3].shape[:2] == (floor, floor)


def test_output_size_formula():
    assert ref.output_size(4, 5, 2) == 7  # Fig. 5 worked example
    assert ref.output_size(4, 4, 2) == 8  # GAN doubling layer
    assert ref.output_size(224, 3, 1) == 447


# ------------------------------------------------------------ flop model


@pytest.mark.parametrize("n_in,n_k,pad", [(4, 4, 2), (8, 5, 2), (16, 3, 1)])
def test_flops_unified_about_quarter(n_in, n_k, pad):
    """Exact optimization skips ~3/4 of multiplications (paper §3.1:
    '25 multiplications ... to produce four output elements')."""
    conv = ref.flops_conventional(n_in, n_k, pad, 1, 1)
    uni = ref.flops_unified(n_in, n_k, pad, 1, 1)
    assert uni * 3 < conv  # strictly better than 3×
    assert conv <= uni * 5  # and not better than the ideal ~4× by much


# ----------------------------------------------------------- hypothesis

shape_strategy = st.tuples(
    st.integers(min_value=1, max_value=7),  # n_in
    st.integers(min_value=2, max_value=6),  # n_k
    st.integers(min_value=0, max_value=3),  # padding
    st.integers(min_value=1, max_value=4),  # cin
    st.integers(min_value=1, max_value=3),  # cout
).filter(lambda t: 2 * t[0] + 2 * t[2] - t[1] > 0)


@settings(max_examples=40, deadline=None)
@given(shape_strategy, st.integers(min_value=0, max_value=2**31 - 1))
def test_unified_matches_oracle_property(cfg, seed):
    """Property sweep: ∀ (N, n, P, Cin, Cout) the Pallas unified kernel
    equals Algorithm 1 up to float tolerance."""
    n_in, n_k, pad, cin, cout = cfg
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((n_in, n_in, cin)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((n_k, n_k, cin, cout)), jnp.float32)
    _assert_close(
        unified.unified_transpose_conv(x, k, pad),
        ref.conventional_transpose_conv(x, k, pad),
    )


@settings(max_examples=20, deadline=None)
@given(
    st.integers(min_value=2, max_value=7),
    st.integers(min_value=0, max_value=3),
)
def test_flop_model_consistency(n_k, pad):
    """FLOP model: unified counts exactly the non-zero taps; it is never
    more than the conventional count and always positive."""
    n_in = 5
    conv = ref.flops_conventional(n_in, n_k, pad, 2, 3)
    uni = ref.flops_unified(n_in, n_k, pad, 2, 3)
    assert 0 < uni <= conv
