"""L2: GAN generator models in JAX, built on the L1 unified kernel.

The paper's ablation (Table 4) times the transpose-convolution layers of
DC-GAN/DiscoGAN, ArtGAN, GP-GAN and EB-GAN.  This module defines those
generators as JAX functions whose every ConvTranspose layer calls
``kernels.unified.unified_transpose_conv`` (the Pallas kernel), so the
whole generator lowers into a single HLO module for the Rust runtime.

Weights are *arguments*, not baked constants — keeps the HLO text small
and lets the Rust side own weight initialization.  Layer geometry is the
standard GAN generator block ``ConvTranspose2d(k=4, s=2, p=1)``, i.e.
paper padding factor ``P = k - 1 - p = 2`` (doubles spatial size).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .kernels import ref
from .kernels import unified as uk


@dataclass(frozen=True)
class LayerSpec:
    """One transpose-conv layer of a generator (a Table 4 row)."""

    n_in: int  # input spatial size (square)
    cin: int
    cout: int
    ksize: int = 4
    padding: int = 2  # paper's P (upsampled-map framing)

    @property
    def n_out(self) -> int:
        return ref.output_size(self.n_in, self.ksize, self.padding)


# Layer tables transcribed from Table 4.  The ArtGAN "4×4×246×128" kernel
# is a typo in the paper for 256→128 input channels; we keep the input
# sizes column as ground truth (16×16×128 → cin=128).
GAN_ZOO: dict[str, list[LayerSpec]] = {
    "dcgan": [
        LayerSpec(4, 1024, 512),
        LayerSpec(8, 512, 256),
        LayerSpec(16, 256, 128),
        LayerSpec(32, 128, 3),
    ],
    "artgan": [
        LayerSpec(4, 512, 256),
        LayerSpec(8, 256, 128),
        LayerSpec(16, 128, 128),
        LayerSpec(32, 128, 3),
    ],
    "gpgan": [
        LayerSpec(4, 512, 256),
        LayerSpec(8, 256, 128),
        LayerSpec(16, 128, 64),
        LayerSpec(32, 64, 3),
    ],
    "ebgan": [
        LayerSpec(4, 2048, 1024),
        LayerSpec(8, 1024, 512),
        LayerSpec(16, 512, 256),
        LayerSpec(32, 256, 128),
        LayerSpec(64, 128, 64),
        LayerSpec(128, 64, 64),
    ],
}

Z_DIM = 100


def weight_shapes(model: str) -> list[tuple[int, ...]]:
    """Argument shapes (after z) for ``generator_fwd``: projection w/b then
    per-layer kernel/bias pairs.  Mirrored into the artifact manifest."""
    layers = GAN_ZOO[model]
    c0 = layers[0].cin
    n0 = layers[0].n_in
    shapes: list[tuple[int, ...]] = [(Z_DIM, n0 * n0 * c0), (n0 * n0 * c0,)]
    for l in layers:
        shapes.append((l.ksize, l.ksize, l.cin, l.cout))
        shapes.append((l.cout,))
    return shapes


def generator_fwd(model: str, z: jnp.ndarray, *params: jnp.ndarray) -> jnp.ndarray:
    """Full generator forward: z [B, Z_DIM] → image [B, H, W, C_last].

    Projection (dense) → reshape 4×4 → N unified transpose-conv blocks
    with ReLU, tanh on the last.  Every conv is the L1 Pallas kernel.
    """
    layers = GAN_ZOO[model]
    c0, n0 = layers[0].cin, layers[0].n_in
    proj_w, proj_b = params[0], params[1]
    b = z.shape[0]
    x = (z @ proj_w + proj_b).reshape(b, n0, n0, c0)
    x = jax.nn.relu(x)
    for i, spec in enumerate(layers):
        kw, kb = params[2 + 2 * i], params[3 + 2 * i]
        x = uk.unified_transpose_conv(x, kw, padding=spec.padding) + kb
        x = jnp.tanh(x) if i == len(layers) - 1 else jax.nn.relu(x)
    return x


def single_layer_fwd(
    x: jnp.ndarray, k: jnp.ndarray, *, padding: int = 2
) -> jnp.ndarray:
    """One unified transpose-conv layer — the runtime smoke-test artifact."""
    return uk.unified_transpose_conv(x, k, padding=padding)


def single_layer_conventional_fwd(
    x: jnp.ndarray, k: jnp.ndarray, *, padding: int = 2
) -> jnp.ndarray:
    """Algorithm-1 baseline layer (artifact for runtime A/B comparisons)."""
    return uk.conventional_transpose_conv_pallas(x, k, padding=padding)


def init_params(model: str, seed: int = 0) -> list[jnp.ndarray]:
    """He-style random init matching ``weight_shapes`` (testing aid)."""
    key = jax.random.PRNGKey(seed)
    params = []
    for shape in weight_shapes(model):
        key, sub = jax.random.split(key)
        fan_in = shape[0] if len(shape) > 1 else 1
        scale = 1.0 / jnp.sqrt(jnp.maximum(1.0, fan_in))
        params.append(scale * jax.random.normal(sub, shape, jnp.float32))
    return params
