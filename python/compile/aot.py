"""AOT pipeline: lower the L2 JAX functions to HLO *text* artifacts.

Interchange format is HLO text, NOT a serialized ``HloModuleProto``:
jax ≥ 0.5 emits protos with 64-bit instruction ids which the Rust side's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly.  See /opt/xla-example/README.md.

Outputs (``--outdir``, default ``../artifacts``):
  * ``<name>.hlo.txt``   — one per compiled variant,
  * ``manifest.json``    — input/output specs per artifact (read by the
    Rust runtime: ``rust/src/runtime/artifact.rs``),
  * ``golden.json``      — oracle test vectors for the Rust kernels.

Python runs ONLY here (``make artifacts``); never on the request path.
"""

from __future__ import annotations

import argparse
import json
import os
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels import ref


def to_hlo_text(lowered) -> str:
    """stablehlo → XlaComputation → HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), jnp.float32)


def lower_single_layer(outdir: str, manifest: list, *, conventional: bool) -> None:
    """Smoke-test layer artifact: x[1,8,8,8] ⊛ᵀ k[4,4,8,4], P=2 → [1,16,16,4]."""
    name = "conv_layer_s8" if conventional else "unified_layer_s8"
    fn = (
        M.single_layer_conventional_fwd if conventional else M.single_layer_fwd
    )
    x_shape, k_shape = (1, 8, 8, 8), (4, 4, 8, 4)
    lowered = jax.jit(lambda x, k: (fn(x, k, padding=2),)).lower(
        _spec(x_shape), _spec(k_shape)
    )
    path = f"{name}.hlo.txt"
    with open(os.path.join(outdir, path), "w") as f:
        f.write(to_hlo_text(lowered))
    manifest.append(
        {
            "name": name,
            "path": path,
            "kind": "layer",
            "padding": 2,
            "inputs": [
                {"name": "x", "shape": list(x_shape)},
                {"name": "k", "shape": list(k_shape)},
            ],
            "output_shape": [1, 16, 16, 4],
        }
    )


def lower_generator(outdir: str, manifest: list, model: str, batch: int) -> None:
    """Full generator artifact ``<model>_b<batch>`` with weight arguments."""
    name = f"{model}_b{batch}"
    shapes = [(batch, M.Z_DIM)] + M.weight_shapes(model)
    fn = partial(M.generator_fwd, model)
    lowered = jax.jit(lambda *a: (fn(*a),)).lower(*[_spec(s) for s in shapes])
    path = f"{name}.hlo.txt"
    with open(os.path.join(outdir, path), "w") as f:
        f.write(to_hlo_text(lowered))
    last = M.GAN_ZOO[model][-1]
    manifest.append(
        {
            "name": name,
            "path": path,
            "kind": "generator",
            "model": model,
            "batch": batch,
            "inputs": [{"name": "z", "shape": list(shapes[0])}]
            + [
                {"name": f"w{i}", "shape": list(s)}
                for i, s in enumerate(shapes[1:])
            ],
            "output_shape": [batch, last.n_out, last.n_out, last.cout],
        }
    )


GOLDEN_CASES = [
    # (n_in, n_k, padding, cin, cout) — covers odd/even kernels, odd/even
    # output sizes, and the §3.4 odd-P sub-kernel role swap.
    (4, 5, 2, 3, 2),
    (4, 4, 1, 2, 3),
    (5, 3, 1, 1, 1),
    (6, 4, 2, 3, 3),
    (4, 5, 0, 1, 2),
    (7, 5, 3, 2, 1),
    (3, 3, 2, 2, 2),
    (8, 4, 2, 3, 4),
    (1, 3, 2, 1, 1),
    (2, 2, 0, 2, 2),
]


def emit_golden(outdir: str) -> None:
    """Oracle vectors consumed by the Rust kernel tests (tests/golden.rs)."""
    rng = np.random.default_rng(2024)
    cases = []
    for n_in, n_k, pad, cin, cout in GOLDEN_CASES:
        x = rng.standard_normal((n_in, n_in, cin)).astype(np.float32)
        k = rng.standard_normal((n_k, n_k, cin, cout)).astype(np.float32)
        out = np.asarray(
            ref.conventional_transpose_conv(jnp.asarray(x), jnp.asarray(k), pad)
        )
        cases.append(
            {
                "n_in": n_in,
                "n_k": n_k,
                "padding": pad,
                "cin": cin,
                "cout": cout,
                "x": [round(float(v), 6) for v in x.ravel()],
                "k": [round(float(v), 6) for v in k.ravel()],
                "out_shape": list(out.shape),
                "out": [float(v) for v in out.ravel()],
            }
        )
    with open(os.path.join(outdir, "golden.json"), "w") as f:
        json.dump({"layout": "HWC/HWIO row-major", "cases": cases}, f)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument(
        "--models",
        default="dcgan:1,dcgan:8",
        help="comma-separated <model>:<batch> generator variants",
    )
    ap.add_argument("--out", default=None, help=argparse.SUPPRESS)  # legacy
    args = ap.parse_args()
    outdir = args.outdir
    if args.out:  # legacy single-file invocation from early Makefile
        outdir = os.path.dirname(args.out) or "."
    os.makedirs(outdir, exist_ok=True)

    manifest: list = []
    lower_single_layer(outdir, manifest, conventional=False)
    lower_single_layer(outdir, manifest, conventional=True)
    for spec in args.models.split(","):
        model, batch = spec.split(":")
        lower_generator(outdir, manifest, model, int(batch))
    emit_golden(outdir)

    with open(os.path.join(outdir, "manifest.json"), "w") as f:
        json.dump({"version": 1, "artifacts": manifest}, f, indent=1)
    print(f"wrote {len(manifest)} artifacts + manifest + golden to {outdir}")


if __name__ == "__main__":
    main()
