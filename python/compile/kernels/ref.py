"""Pure-jnp correctness oracles for the transpose-convolution algorithms.

This module is the ground truth every other implementation (the Pallas
kernel, the JAX model layers, and — via golden vectors exported by
``aot.py`` — the Rust kernels) is validated against.

Conventions
-----------
* Feature maps are ``[H, W, C]`` (or ``[B, H, W, C]``) float32.
* Kernels are ``[n, n, Cin, Cout]`` (HWIO).
* ``conv`` means cross-correlation, as in every DL framework and as the
  paper's ``⊛`` is used in Algorithm 1.
* ``padding`` is the paper's padding factor ``P`` applied to the
  *upsampled* feature map (bed-of-nails framing).  The standard GAN layer
  ``ConvTranspose2d(k=4, s=2, p=1)`` corresponds to ``P = k - 1 - p = 2``.

Output size: ``Ho = 2N - 1 + 2P - n + 1 = 2N + 2P - n`` for input ``N``.
"""

from __future__ import annotations

import math

import jax.numpy as jnp
from jax import lax


def upsample_bed_of_nails(x: jnp.ndarray) -> jnp.ndarray:
    """Insert zeros between rows/cols: ``N×N → (2N-1)×(2N-1)`` (Alg. 1).

    Accepts ``[H, W, C]`` or ``[B, H, W, C]``.
    """
    batched = x.ndim == 4
    if not batched:
        x = x[None]
    b, h, w, c = x.shape
    up = jnp.zeros((b, 2 * h - 1, 2 * w - 1, c), x.dtype)
    up = up.at[:, ::2, ::2, :].set(x)
    return up if batched else up[0]


def correlate2d(x: jnp.ndarray, k: jnp.ndarray) -> jnp.ndarray:
    """VALID stride-1 cross-correlation, NHWC × HWIO → NHWC."""
    batched = x.ndim == 4
    if not batched:
        x = x[None]
    out = lax.conv_general_dilated(
        x,
        k,
        window_strides=(1, 1),
        padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return out if batched else out[0]


def conventional_transpose_conv(
    x: jnp.ndarray, k: jnp.ndarray, padding: int = 0
) -> jnp.ndarray:
    """Algorithm 1: bed-of-nails upsample, zero-pad by ``P``, correlate.

    This is the literal, wasteful reference the paper optimizes away.
    """
    up = upsample_bed_of_nails(x)
    if padding:
        pad = [(padding, padding), (padding, padding), (0, 0)]
        if up.ndim == 4:
            pad = [(0, 0)] + pad
        up = jnp.pad(up, pad)
    return correlate2d(up, k)


def segregate_kernel(k: jnp.ndarray):
    """Fig. 4: split ``k`` into ``(k00, k01, k10, k11)``.

    ``k_rs = k[r::2, s::2]`` — the rows/cols of the original kernel that
    land on non-zero (even) positions of the upsampled map when the
    output index has parity ``(r, s)``.  Sizes: ``⌈n/2⌉``/``⌊n/2⌋`` per
    axis — 9/6/6/4 elements for the paper's 5×5 example.
    """
    return k[0::2, 0::2], k[0::2, 1::2], k[1::2, 0::2], k[1::2, 1::2]


def output_size(n_in: int, n_k: int, padding: int) -> int:
    """Paper output-size formula ``2N + 2P - n``."""
    return 2 * n_in + 2 * padding - n_k


def unified_transpose_conv_ref(
    x: jnp.ndarray, k: jnp.ndarray, padding: int = 0
) -> jnp.ndarray:
    """Algorithm 2 / Eqs. 1–4, written densely in jnp (phase form).

    The output decomposes into four parity phases ``out[rp::2, sp::2]``;
    phase ``(rp, sp)`` uses sub-kernel ``k_{(rp+P)%2, (sp+P)%2}`` (the
    §3.4 odd-``P`` role swap falls out of the ``+P``), correlated against
    an input slab whose first row is ``base(i) = ⌈(i - P)/2⌉``.
    """
    batched = x.ndim == 4
    if not batched:
        x = x[None]
    b, n, _, cin = x.shape
    nk = k.shape[0]
    cout = k.shape[3]
    ho = output_size(n, nk, padding)
    subs = segregate_kernel(k)
    out = jnp.zeros((b, ho, ho, cout), x.dtype)

    for rp in (0, 1):  # output-row parity
        for sp in (0, 1):  # output-col parity
            r, s = (rp + padding) % 2, (sp + padding) % 2
            sub = subs[2 * r + s]
            kr, kc = sub.shape[0], sub.shape[1]
            n_rows = len(range(rp, ho, 2))
            n_cols = len(range(sp, ho, 2))
            if n_rows == 0 or n_cols == 0 or kr == 0 or kc == 0:
                continue
            # base(i) = ceil((i - P)/2) for i = rp + 2t  →  base0 + t
            base0_r = math.ceil((rp - padding) / 2)
            base0_c = math.ceil((sp - padding) / 2)
            # Input slab rows needed: base0 .. base0 + (n_rows-1) + kr - 1
            lo_r, hi_r = base0_r, base0_r + n_rows - 1 + kr - 1
            lo_c, hi_c = base0_c, base0_c + n_cols - 1 + kc - 1
            pad_lo_r, pad_hi_r = max(0, -lo_r), max(0, hi_r - (n - 1))
            pad_lo_c, pad_hi_c = max(0, -lo_c), max(0, hi_c - (n - 1))
            slab = jnp.pad(
                x,
                [(0, 0), (pad_lo_r, pad_hi_r), (pad_lo_c, pad_hi_c), (0, 0)],
            )[:, lo_r + pad_lo_r : hi_r + pad_lo_r + 1,
              lo_c + pad_lo_c : hi_c + pad_lo_c + 1, :]
            phase = correlate2d(slab, sub)
            out = out.at[:, rp::2, sp::2, :].set(phase)
    return out if batched else out[0]


def flops_conventional(n_in: int, n_k: int, padding: int, cin: int, cout: int) -> int:
    """MACs of Algorithm 1 (counting multiplications against zeros)."""
    ho = output_size(n_in, n_k, padding)
    return ho * ho * n_k * n_k * cin * cout


def flops_unified(n_in: int, n_k: int, padding: int, cin: int, cout: int) -> int:
    """MACs of Algorithm 2 — only the effective taps of each phase."""
    ho = output_size(n_in, n_k, padding)
    kc, kf = math.ceil(n_k / 2), math.floor(n_k / 2)
    total = 0
    for rp in (0, 1):
        for sp in (0, 1):
            r, s = (rp + padding) % 2, (sp + padding) % 2
            kr = kc if r == 0 else kf
            ks = kc if s == 0 else kf
            n_rows = len(range(rp, ho, 2))
            n_cols = len(range(sp, ho, 2))
            total += n_rows * n_cols * kr * ks * cin * cout
    return total
