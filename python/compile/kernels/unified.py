"""L1 Pallas kernel: unified kernel-segregated transpose convolution.

Hardware adaptation (see DESIGN.md §Hardware-Adaptation)
--------------------------------------------------------
The paper's CUDA formulation launches one thread per output element and
selects sub-kernel ``k_{i%2, j%2}`` at runtime.  A literal port would be
scalar gather/select soup on a TPU.  The same *exact-optimization*
insight — never multiply against a bed-of-nails zero — restructured for
the MXU:

* the runtime parity selection partitions the output into four phases
  ``out[rp::2, sp::2]``, each a dense stride-1 correlation of the
  **un-upsampled** input slab with one sub-kernel (Eqs. 1–4);
* each phase is computed shift-and-matmul style: per sub-kernel tap
  ``(u, v)`` one ``[B·Ho·Wo, Cin] × [Cin, Cout]`` matmul accumulating in
  VMEM scratch — MXU-shaped work, zero wasted multiplications;
* the four phase outputs are interleaved by the caller with strided
  stores (the TPU analogue of CUDA's scatter-by-thread-id).

``interpret=True`` is mandatory here: real TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute.  Interpret mode traces
the kernel into plain HLO, which is exactly what ``aot.py`` ships to the
Rust runtime.
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _phase_conv_kernel(x_ref, k_ref, o_ref, *, taps_r: int, taps_c: int):
    """One parity phase: dense VALID correlation, shift-and-matmul.

    ``x_ref``: [B, Hs, Ws, Cin] input slab (already padded/cropped),
    ``k_ref``: [taps_r, taps_c, Cin, Cout] sub-kernel,
    ``o_ref``: [B, Ho, Wo, Cout] phase output.

    The tap loops are Python-level (static), so each iteration lowers to
    one reshape + one ``jnp.dot`` — the MXU-friendly shape.
    """
    b, ho, wo, cout = o_ref.shape
    cin = x_ref.shape[3]
    acc = jnp.zeros((b * ho * wo, cout), jnp.float32)
    for u in range(taps_r):
        for v in range(taps_c):
            window = x_ref[:, u : u + ho, v : v + wo, :]
            lhs = window.reshape(b * ho * wo, cin)
            acc = acc + jnp.dot(
                lhs, k_ref[u, v, :, :], preferred_element_type=jnp.float32
            )
    o_ref[...] = acc.reshape(b, ho, wo, cout)


def phase_conv(x_slab: jnp.ndarray, sub: jnp.ndarray) -> jnp.ndarray:
    """Run the Pallas phase kernel: VALID correlation of slab × sub-kernel."""
    b, hs, ws, cin = x_slab.shape
    kr, kc, _, cout = sub.shape
    ho, wo = hs - kr + 1, ws - kc + 1
    return pl.pallas_call(
        partial(_phase_conv_kernel, taps_r=kr, taps_c=kc),
        out_shape=jax.ShapeDtypeStruct((b, ho, wo, cout), jnp.float32),
        interpret=True,
    )(x_slab, sub)


def _phase_geometry(n: int, nk: int, padding: int, ho: int):
    """Static slab/sub-kernel geometry for the four output parities.

    Yields ``(rp, sp, sub_index, pads, crops, n_rows, n_cols)`` where
    ``sub_index`` picks from ``segregate_kernel``'s (k00,k01,k10,k11)
    and the slab is ``pad(x)[lo:hi]`` per axis.
    """
    out = []
    for rp in (0, 1):
        for sp in (0, 1):
            r, s = (rp + padding) % 2, (sp + padding) % 2
            kr = math.ceil(nk / 2) if r == 0 else nk // 2
            kc = math.ceil(nk / 2) if s == 0 else nk // 2
            n_rows = len(range(rp, ho, 2))
            n_cols = len(range(sp, ho, 2))
            if n_rows == 0 or n_cols == 0 or kr == 0 or kc == 0:
                continue
            base0_r = math.ceil((rp - padding) / 2)
            base0_c = math.ceil((sp - padding) / 2)
            lo_r, hi_r = base0_r, base0_r + n_rows - 1 + kr - 1
            lo_c, hi_c = base0_c, base0_c + n_cols - 1 + kc - 1
            pad_lo_r, pad_hi_r = max(0, -lo_r), max(0, hi_r - (n - 1))
            pad_lo_c, pad_hi_c = max(0, -lo_c), max(0, hi_c - (n - 1))
            out.append(
                dict(
                    rp=rp,
                    sp=sp,
                    sub=2 * r + s,
                    pads=((pad_lo_r, pad_hi_r), (pad_lo_c, pad_hi_c)),
                    rows=(lo_r + pad_lo_r, hi_r + pad_lo_r + 1),
                    cols=(lo_c + pad_lo_c, hi_c + pad_lo_c + 1),
                )
            )
    return out


def unified_transpose_conv(
    x: jnp.ndarray, k: jnp.ndarray, padding: int = 0
) -> jnp.ndarray:
    """Unified kernel-segregated transpose convolution (Algorithm 2).

    ``x``: [B, N, N, Cin] (or unbatched [N, N, Cin]),
    ``k``: [n, n, Cin, Cout] original (un-segregated) kernel,
    ``padding``: the conventional padding factor ``P``; the proposed
    path pads the raw input by ``⌊P/2⌋``-derived amounts and, for odd
    ``P``, swaps sub-kernel roles (§3.4) — both fall out of the
    geometry computation.

    Returns [B, 2N+2P-n, 2N+2P-n, Cout].
    """
    batched = x.ndim == 4
    if not batched:
        x = x[None]
    b, n, _, _ = x.shape
    nk = k.shape[0]
    cout = k.shape[3]
    ho = ref.output_size(n, nk, padding)
    subs = ref.segregate_kernel(k)

    out = jnp.zeros((b, ho, ho, cout), jnp.float32)
    for g in _phase_geometry(n, nk, padding, ho):
        (plr, phr), (plc, phc) = g["pads"]
        slab = jnp.pad(x, [(0, 0), (plr, phr), (plc, phc), (0, 0)])
        slab = slab[:, g["rows"][0] : g["rows"][1], g["cols"][0] : g["cols"][1], :]
        phase = phase_conv(slab, subs[g["sub"]])
        out = out.at[:, g["rp"] :: 2, g["sp"] :: 2, :].set(phase)
    return out if batched else out[0]


def conventional_transpose_conv_pallas(
    x: jnp.ndarray, k: jnp.ndarray, padding: int = 0
) -> jnp.ndarray:
    """Algorithm 1 as a Pallas kernel (baseline for kernel-vs-kernel
    comparisons): bed-of-nails upsample then one dense correlation whose
    tap loop runs over the FULL ``n×n`` kernel — i.e. it performs the
    wasted multiply-by-zero work the paper eliminates."""
    batched = x.ndim == 4
    if not batched:
        x = x[None]
    up = ref.upsample_bed_of_nails(x)
    if padding:
        up = jnp.pad(up, [(0, 0), (padding, padding), (padding, padding), (0, 0)])
    out = phase_conv(up, k)  # same shift-and-matmul kernel, full kernel
    return out if batched else out[0]
