# Artifact pipeline: lower the JAX/Pallas side to HLO text + golden
# vectors for the Rust runtime and golden tests (DESIGN.md §3).
# Python runs only here — never on the request path.

ARTIFACTS := rust/artifacts

.PHONY: artifacts
artifacts:
	cd python && python3 -m compile.aot --outdir ../$(ARTIFACTS)

.PHONY: clean-artifacts
clean-artifacts:
	rm -rf $(ARTIFACTS)
