//! `ukstc` — leader binary: serve, bench, and inspect the unified
//! kernel-segregated transpose-convolution stack.
//!
//! ```text
//! ukstc table1                       # print the dataset spec (Table 1)
//! ukstc table2 [--scale F] ...       # regenerate Table 2 (Flowers)
//! ukstc table3 [--scale F] ...       # regenerate Table 3 (COCO/PASCAL)
//! ukstc table4 [--model M] ...       # regenerate Table 4 (GAN ablation)
//! ukstc ablation                     # design-choice ablations
//! ukstc serve [--config F] ...       # run the serving coordinator demo
//! ukstc info                         # model zoo + analytic summaries
//! ```

use std::sync::Arc;

use ukstc::bench::{ablation, serving, table2, table3, table4, BenchConfig};
use ukstc::coordinator::backend::RustBackend;
use ukstc::coordinator::{Coordinator, CoordinatorConfig};
use ukstc::models::GanModel;
use ukstc::runtime::{Engine, PjrtBackend};
use ukstc::util::cli::Command;
use ukstc::util::logging;
use ukstc::util::rng::Rng;
use ukstc::workload::datasets::{table1_rows, FLOWER_GROUPS, IMAGE_SIZE};
use ukstc::workload::generator::poisson_trace;

fn main() {
    logging::init();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let sub = args.first().map(String::as_str).unwrap_or("help");
    let rest = args.get(1..).unwrap_or(&[]).to_vec();
    let code = match dispatch(sub, &rest) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            2
        }
    };
    std::process::exit(code);
}

fn bench_cfg(a: &ukstc::util::cli::Args) -> anyhow::Result<BenchConfig> {
    let d = BenchConfig::default();
    Ok(BenchConfig {
        scale: a.get_f64("scale", d.scale)?,
        warmup: a.get_usize("warmup", d.warmup)?,
        iters: a.get_usize("iters", d.iters)?,
        workers: a.get_usize("workers", d.workers)?,
    })
}

fn bench_command(name: &'static str, about: &'static str) -> Command {
    Command::new(name, about)
        .opt("scale", "fraction of each dataset to time", Some("0.02"))
        .opt("warmup", "warmup iterations", Some("1"))
        .opt("iters", "recorded iterations", Some("2"))
        .opt("workers", "parallel-lane worker threads", None)
        .opt("image-size", "image side length", Some("224"))
}

fn dispatch(sub: &str, rest: &[String]) -> anyhow::Result<()> {
    match sub {
        "table1" => {
            let rows: Vec<Vec<String>> = table1_rows()
                .into_iter()
                .map(|(d, g, n)| vec![d.into(), g.into(), n.to_string()])
                .collect();
            ukstc::bench::report::print_table(
                "Table 1 — dataset characteristics",
                &["Dataset", "Group", "Samples"],
                &rows,
            );
            Ok(())
        }
        "table2" => {
            let cmd = bench_command("table2", "regenerate Table 2 (Flower dataset)");
            let a = cmd.parse(rest)?;
            let cfg = bench_cfg(&a)?;
            let size = a.get_usize("image-size", IMAGE_SIZE)?;
            let rows = table2::run_sweep(&FLOWER_GROUPS, &cfg, size);
            table2::print_rows("Table 2 — Flower dataset (conventional vs proposed)", &rows);
            Ok(())
        }
        "table3" => {
            let cmd = bench_command("table3", "regenerate Table 3 (MSCOCO + PASCAL)");
            let a = cmd.parse(rest)?;
            let cfg = bench_cfg(&a)?;
            let size = a.get_usize("image-size", IMAGE_SIZE)?;
            let rows = table3::run_sweep(&cfg, size);
            table3::print_rows(&rows);
            Ok(())
        }
        "table4" => {
            let cmd = bench_command("table4", "regenerate Table 4 (GAN ablation)")
                .opt("model", "dcgan|artgan|gpgan|ebgan|all", Some("all"));
            let a = cmd.parse(rest)?;
            let cfg = bench_cfg(&a)?;
            let models: Vec<GanModel> = match a.get_or("model", "all") {
                "all" => GanModel::all().to_vec(),
                name => vec![GanModel::from_name(name)
                    .ok_or_else(|| anyhow::anyhow!("unknown model '{name}'"))?],
            };
            for m in models {
                let res = table4::measure_model(m, &cfg);
                table4::print_model(&res);
            }
            Ok(())
        }
        "ablation" => {
            let cmd = bench_command("ablation", "design-choice ablations");
            let a = cmd.parse(rest)?;
            let cfg = bench_cfg(&a)?;
            ablation::run_all(&cfg);
            Ok(())
        }
        "serve" => serve(rest),
        "serve-ab" => {
            let cmd = Command::new(
                "serve-ab",
                "serving matrix: unified planned/unplanned vs conventional",
            )
            .opt("model", "gan model", Some("gpgan"))
            .opt("requests", "burst size", Some("24"))
            .opt("workers", "coordinator workers", Some("2"))
            .opt("batch-workers", "threads per batch (per-worker arenas)", Some("1"))
            .opt("max-batch", "dynamic batch cap", Some("8"));
            let a = cmd.parse(rest)?;
            let cfg = serving::ServingConfig {
                model: GanModel::from_name(a.get_or("model", "gpgan"))
                    .ok_or_else(|| anyhow::anyhow!("unknown model"))?,
                requests: a.get_usize("requests", 24)?,
                workers_per_model: a.get_usize("workers", 2)?,
                batch_workers: a.get_usize("batch-workers", 1)?,
                max_batch: a.get_usize("max-batch", 8)?,
                ..Default::default()
            };
            let results = serving::run_matrix(&cfg)?;
            serving::print_results(&results);
            Ok(())
        }
        "info" => {
            for m in GanModel::all() {
                println!(
                    "{:8} layers={} z_dim={} memory_savings={} B",
                    m.name(),
                    m.layers().len(),
                    m.z_dim(),
                    m.total_memory_savings()
                );
            }
            Ok(())
        }
        "help" | "--help" | "-h" => {
            println!("{HELP}");
            Ok(())
        }
        other => anyhow::bail!("unknown subcommand '{other}'\n{HELP}"),
    }
}

/// `ukstc serve`: run the coordinator on a Poisson trace, native or
/// PJRT backend, from a JSON config or flags.
fn serve(rest: &[String]) -> anyhow::Result<()> {
    let cmd = Command::new("serve", "run the serving coordinator demo")
        .opt("config", "JSON config file", None)
        .opt("model", "gan model", Some("dcgan"))
        .opt("backend", "rust|pjrt", Some("rust"))
        .opt("artifacts", "artifact directory", Some("artifacts"))
        .opt("rate", "Poisson request rate (req/s)", Some("20"))
        .opt("requests", "number of requests", Some("40"))
        .opt("workers", "coordinator workers per model", Some("2"))
        .opt("max-batch", "dynamic batch cap", Some("8"));
    let a = cmd.parse(rest)?;

    let mut cfg = if let Some(path) = a.get("config") {
        CoordinatorConfig::from_file(std::path::Path::new(path))?
    } else {
        CoordinatorConfig::default()
    };
    if a.get("config").is_none() {
        cfg.models[0].name = a.get_or("model", "dcgan").to_string();
        cfg.models[0].backend = a.get_or("backend", "rust").to_string();
    }
    cfg.workers_per_model = a.get_usize("workers", cfg.workers_per_model)?;
    cfg.max_batch = a.get_usize("max-batch", cfg.max_batch)?;

    let mut builder = Coordinator::builder()
        .queue_capacity(cfg.queue_capacity)
        .workers_per_model(cfg.workers_per_model)
        .batch_policy(cfg.batch_policy());

    let model_cfg = cfg.models[0].clone();
    let model_name;
    let z_dim;
    if model_cfg.backend == "pjrt" {
        let mut engine = Engine::new(std::path::Path::new(a.get_or("artifacts", "artifacts")))?;
        let artifact = model_cfg
            .artifact
            .clone()
            .unwrap_or_else(|| format!("{}_b{}", model_cfg.name, cfg.max_batch.min(8)));
        engine.compile(&artifact)?;
        let backend = PjrtBackend::new(Arc::new(engine), &artifact, model_cfg.seed)?;
        model_name = ukstc::coordinator::Backend::model_name(&backend).to_string();
        z_dim = ukstc::coordinator::Backend::z_dim(&backend);
        builder = builder.register(Arc::new(backend));
    } else {
        let model = GanModel::from_name(&model_cfg.name)
            .ok_or_else(|| anyhow::anyhow!("unknown model '{}'", model_cfg.name))?;
        let backend = RustBackend::new(
            model,
            model_cfg.algorithm,
            model_cfg.lane(),
            model_cfg.seed,
            cfg.max_batch,
        );
        model_name = model.name().to_string();
        z_dim = model.z_dim();
        builder = builder.register(Arc::new(backend));
    }

    let coord = builder.start()?;
    let rate = a.get_f64("rate", 20.0)?;
    let n = a.get_usize("requests", 40)?;
    log::info!("serving {n} Poisson requests at {rate} req/s to '{model_name}'");

    let mut rng = Rng::seeded(2026);
    let trace = poisson_trace(&model_name, z_dim, rate, n, &mut rng);
    let t0 = std::time::Instant::now();
    let mut pending = Vec::new();
    for tr in trace {
        // Open-loop replay: honor arrival times.
        let now = t0.elapsed().as_secs_f64();
        if tr.at > now {
            std::thread::sleep(std::time::Duration::from_secs_f64(tr.at - now));
        }
        match coord.submit(tr.request) {
            Ok(rx) => pending.push(rx),
            Err(e) => log::warn!("rejected: {e}"),
        }
    }
    for rx in pending {
        let _ = rx.recv();
    }
    let wall = t0.elapsed().as_secs_f64();
    let snap = coord.metrics(&model_name).unwrap();
    println!("\nserve run complete in {wall:.2}s");
    println!("{}", snap.summary());
    Ok(())
}

const HELP: &str = "\
ukstc — Unified Kernel-Segregated Transpose Convolution

subcommands:
  table1     print the dataset spec (paper Table 1)
  table2     regenerate Table 2 (Flower dataset sweep)
  table3     regenerate Table 3 (MSCOCO + PASCAL sweep)
  table4     regenerate Table 4 (GAN-layer ablation)
  ablation   design-choice ablations (formulation, GEMM, dilated, lanes)
  serve      run the serving coordinator on a Poisson trace
  serve-ab   serving matrix: unified planned/unplanned vs conventional
  info       model zoo + analytic memory summaries
common bench flags: --scale F --warmup N --iters N --workers N --image-size N";
