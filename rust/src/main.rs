//! `ukstc` — leader binary: serve, bench, and inspect the unified
//! kernel-segregated transpose-convolution stack.
//!
//! ```text
//! ukstc table1                       # print the dataset spec (Table 1)
//! ukstc table2 [--scale F] ...       # regenerate Table 2 (Flowers)
//! ukstc table3 [--scale F] ...       # regenerate Table 3 (COCO/PASCAL)
//! ukstc table4 [--model M] ...       # regenerate Table 4 (GAN ablation)
//! ukstc ablation                     # design-choice ablations
//! ukstc tune [--model M] ...         # autotune per-layer strategies
//! ukstc accuracy [--precision P] ... # quantized-lane drift vs the f32 reference
//! ukstc serve [--config F] ...       # run the serving coordinator demo
//! ukstc trace forward|train|serve    # span-trace a workload → chrome://tracing JSON
//! ukstc metrics [--json]             # dump the process-wide perf-counter registry
//! ukstc info                         # model zoo + analytic summaries
//! ```

use std::sync::Arc;

use ukstc::bench::{ablation, report, serving, table2, table3, table4, BenchConfig};
use ukstc::conv::parallel::{Algorithm, Lane};
use ukstc::conv::quant::Precision;
use ukstc::conv::simd::Isa;
use ukstc::coordinator::backend::RustBackend;
use ukstc::coordinator::batcher::BatchPolicy;
use ukstc::coordinator::{Coordinator, CoordinatorConfig, GenRequest};
use ukstc::models::{GanModel, Generator, TrainStep};
use ukstc::obs::{registry, trace as obs_trace};
use ukstc::runtime::{Engine, PjrtBackend};
use ukstc::tensor::{ops, Feature};
use ukstc::tune::space::ExecStrategy;
use ukstc::tune::{cache, MeasureBudget, Tuner, TuningCache, WallClockMeasurer};
use ukstc::util::cli::{Args, Command};
use ukstc::util::json::Json;
use ukstc::util::logging;
use ukstc::util::rng::Rng;
use ukstc::util::threadpool;
use ukstc::util::timing;
use ukstc::workload::datasets::{table1_rows, FLOWER_GROUPS, IMAGE_SIZE};
use ukstc::workload::generator::poisson_trace;

fn main() {
    logging::init();
    obs_trace::init_from_env();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let sub = args.first().map(String::as_str).unwrap_or("help");
    let rest = args.get(1..).unwrap_or(&[]).to_vec();
    let code = match dispatch(sub, &rest) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            2
        }
    };
    std::process::exit(code);
}

fn bench_cfg(a: &ukstc::util::cli::Args) -> anyhow::Result<BenchConfig> {
    let d = BenchConfig::default();
    Ok(BenchConfig {
        scale: a.get_f64("scale", d.scale)?,
        warmup: a.get_usize("warmup", d.warmup)?,
        iters: a.get_usize("iters", d.iters)?,
        workers: a.get_usize("workers", d.workers)?,
    })
}

fn bench_command(name: &'static str, about: &'static str) -> Command {
    Command::new(name, about)
        .opt("scale", "fraction of each dataset to time", Some("0.02"))
        .opt("warmup", "warmup iterations", Some("1"))
        .opt("iters", "recorded iterations", Some("2"))
        .opt("workers", "parallel-lane worker threads", None)
        .opt("image-size", "image side length", Some("224"))
}

fn dispatch(sub: &str, rest: &[String]) -> anyhow::Result<()> {
    match sub {
        "table1" => {
            let rows: Vec<Vec<String>> = table1_rows()
                .into_iter()
                .map(|(d, g, n)| vec![d.into(), g.into(), n.to_string()])
                .collect();
            ukstc::bench::report::print_table(
                "Table 1 — dataset characteristics",
                &["Dataset", "Group", "Samples"],
                &rows,
            );
            Ok(())
        }
        "table2" => {
            let cmd = bench_command("table2", "regenerate Table 2 (Flower dataset)");
            let a = cmd.parse(rest)?;
            let cfg = bench_cfg(&a)?;
            let size = a.get_usize("image-size", IMAGE_SIZE)?;
            let rows = table2::run_sweep(&FLOWER_GROUPS, &cfg, size);
            table2::print_rows("Table 2 — Flower dataset (conventional vs proposed)", &rows);
            Ok(())
        }
        "table3" => {
            let cmd = bench_command("table3", "regenerate Table 3 (MSCOCO + PASCAL)");
            let a = cmd.parse(rest)?;
            let cfg = bench_cfg(&a)?;
            let size = a.get_usize("image-size", IMAGE_SIZE)?;
            let rows = table3::run_sweep(&cfg, size);
            table3::print_rows(&rows);
            Ok(())
        }
        "table4" => {
            let cmd = bench_command("table4", "regenerate Table 4 (GAN ablation)")
                .opt("model", "dcgan|artgan|gpgan|ebgan|all", Some("all"));
            let a = cmd.parse(rest)?;
            let cfg = bench_cfg(&a)?;
            let models: Vec<GanModel> = match a.get_or("model", "all") {
                "all" => GanModel::all().to_vec(),
                name => vec![GanModel::from_name(name)
                    .ok_or_else(|| anyhow::anyhow!("unknown model '{name}'"))?],
            };
            for m in models {
                let res = table4::measure_model(m, &cfg);
                table4::print_model(&res);
            }
            Ok(())
        }
        "ablation" => {
            let cmd = bench_command("ablation", "design-choice ablations").opt(
                "json",
                "write the bench snapshot (ablations 10-13 + training step) to this JSON path",
                None,
            );
            let a = cmd.parse(rest)?;
            let cfg = bench_cfg(&a)?;
            if let Some(path) = a.get("json") {
                // Snapshot mode: measure ablation 10 and the
                // training-step column once, print them, and persist the
                // document (the committed `BENCH_*.json` files).
                let rows = ablation::backward_planning(GanModel::DcGan, &cfg, &[1, 4, 8]);
                ablation::print_backward_planning(&rows);
                let train = ablation::training_step(&cfg);
                ablation::print_entries(
                    "Training step — direct vs phase-GEMM backward (smallest Table-4 model)",
                    &train,
                );
                let mut doc = ablation::backward_snapshot_json(&rows, &train);
                if let Json::Obj(map) = &mut doc {
                    // Observability section: span roll-up + registry +
                    // tracing-overhead A/B (ISSUE 8).
                    map.insert(
                        "observability".to_string(),
                        ablation::observability_json(GanModel::DcGan, &cfg),
                    );
                    // Precision section: ablation 12 — per-layer
                    // latency/drift/footprint of the quantized
                    // phase-GEMM lanes (ISSUE 9).
                    map.insert(
                        "precision".to_string(),
                        ablation::precision_json(GanModel::DcGan, &cfg),
                    );
                    // Fusion section: ablation 13 — fused vs separate
                    // epilogue per Table-4 layer × batch (ISSUE 10).
                    map.insert(
                        "fusion".to_string(),
                        ablation::fusion_json(GanModel::DcGan, &cfg, &[1, 4, 8]),
                    );
                }
                std::fs::write(path, doc.to_string_compact())
                    .map_err(|e| anyhow::anyhow!("writing {path}: {e}"))?;
                println!("\nwrote {path}");
            } else {
                ablation::run_all(&cfg);
            }
            Ok(())
        }
        "tune" => {
            let cmd = Command::new(
                "tune",
                "autotune per-layer execution strategies for a zoo model",
            )
            .opt("model", "dcgan|artgan|gpgan|ebgan|smallest", Some("smallest"))
            .opt("batch", "serving batch size to tune for (adds fused lanes)", Some("1"))
            .opt(
                "isa",
                "pin GEMM lanes to one microkernel: scalar|avx2|avx512|neon|best",
                None,
            )
            .opt(
                "precision",
                "pin GEMM lanes to one storage precision: f32|f16|bf16|int8",
                None,
            )
            .opt("cache", "tuning-cache JSON path", Some("tuning-cache.json"))
            .opt("workers", "max worker count in the search space", None)
            .opt("warmup", "warmup iterations per candidate", Some("1"))
            .opt("max-iters", "recorded iterations per candidate", Some("25"))
            .opt("min-time-ms", "min recorded milliseconds per candidate", Some("20"))
            .flag("no-cache", "tune in memory only (neither load nor persist)")
            .flag("no-prune", "measure every candidate (no probe pruning)")
            .flag("backward", "also tune the backward lanes (cached under 'bwd' keys)");
            let a = cmd.parse(rest)?;
            tune(&a)
        }
        "accuracy" => {
            let cmd = Command::new(
                "accuracy",
                "reduced-precision drift report: quantized GEMM lanes vs the f32 reference",
            )
            .opt("model", "dcgan|artgan|gpgan|ebgan|smallest|all", Some("smallest"))
            .opt("precision", "f16|bf16|int8|all", Some("all"))
            .opt("latents", "random latents compared per model", Some("2"))
            .opt(
                "max-drift",
                "exit nonzero unless every max-abs drift is within this bound",
                None,
            );
            let a = cmd.parse(rest)?;
            accuracy(&a)
        }
        "serve" => serve(rest),
        "trace" => cmd_trace(rest),
        "metrics" => cmd_metrics(rest),
        "serve-ab" => {
            let cmd = Command::new(
                "serve-ab",
                "serving matrix: unified planned/unplanned vs conventional",
            )
            .opt("model", "gan model", Some("gpgan"))
            .opt("requests", "burst size", Some("24"))
            .opt("workers", "coordinator workers", Some("2"))
            .opt("batch-workers", "threads per batch (per-worker arenas)", Some("1"))
            .opt("max-batch", "dynamic batch cap", Some("8"))
            .opt(
                "tune-cache",
                "autotune backends through this cache (batched for max-batch)",
                None,
            );
            let a = cmd.parse(rest)?;
            let cfg = serving::ServingConfig {
                model: GanModel::from_name(a.get_or("model", "gpgan"))
                    .ok_or_else(|| anyhow::anyhow!("unknown model"))?,
                requests: a.get_usize("requests", 24)?,
                workers_per_model: a.get_usize("workers", 2)?,
                batch_workers: a.get_usize("batch-workers", 1)?,
                max_batch: a.get_usize("max-batch", 8)?,
                tune_cache: a.get("tune-cache").map(std::path::PathBuf::from),
                ..Default::default()
            };
            let results = serving::run_matrix(&cfg)?;
            serving::print_results(&results);
            Ok(())
        }
        "info" => {
            for m in GanModel::all() {
                // Per-batch peak scratch (DESIGN.md §Batched-Execution):
                // one shared arena per serving worker is sized by the
                // worst layer; the packed GEMM operands are plan-resident
                // across all layers.  Derived analytically — no plans are
                // built, so EB-GAN stays cheap to inspect.
                let scratches: Vec<ukstc::conv::memory::PlannedScratch> = m
                    .layers()
                    .iter()
                    .map(|l| ukstc::conv::memory::planned_scratch(&l.params()))
                    .collect();
                let f32s = std::mem::size_of::<f32>();
                let arena = |b: usize| {
                    scratches
                        .iter()
                        .map(|s| s.peak_batch_floats(b))
                        .max()
                        .unwrap_or(0)
                        * f32s
                };
                let packed: usize =
                    scratches.iter().map(|s| s.packed_kernel_floats).sum::<usize>() * f32s;
                println!(
                    "{:8} layers={} z_dim={} memory_savings={} B peak_scratch(b=1)={} B \
                     peak_scratch(b=8)={} B packed_operands={} B",
                    m.name(),
                    m.layers().len(),
                    m.z_dim(),
                    m.total_memory_savings(),
                    arena(1),
                    arena(8),
                    packed
                );
                // Reduced-precision rows (DESIGN.md §Reduced-Precision):
                // the packed-operand footprint a deployment shipping
                // only that precision holds, and the worst-layer peak
                // scratch (f32 arena + quantized patch arena + packed
                // operands) — so the f16 2× / int8 4× operand claims
                // are reproducible straight from the CLI.
                for p in Precision::ALL {
                    let packed_p: usize =
                        scratches.iter().map(|s| s.packed_operand_bytes(p)).sum();
                    let peak = |b: usize| {
                        scratches
                            .iter()
                            .map(|s| s.peak_batch_bytes_at(b, p))
                            .max()
                            .unwrap_or(0)
                    };
                    println!(
                        "  {:5} packed_operands={} B peak_scratch(b=1)={} B peak_scratch(b=8)={} B",
                        p.name(),
                        packed_p,
                        peak(1),
                        peak(8)
                    );
                }
            }
            Ok(())
        }
        "help" | "--help" | "-h" => {
            println!("{HELP}");
            Ok(())
        }
        other => anyhow::bail!("unknown subcommand '{other}'\n{HELP}"),
    }
}

/// `ukstc tune`: search the execution-strategy space for every layer
/// of a zoo model, print the per-layer winners, and persist the
/// tuning cache so the next invocation (and
/// `RustBackend::with_autotune`) loads the verdicts without
/// re-measuring.
fn tune(a: &Args) -> anyhow::Result<()> {
    let model = match a.get_or("model", "smallest") {
        "smallest" => GanModel::smallest(),
        name => GanModel::from_name(name)
            .ok_or_else(|| anyhow::anyhow!("unknown model '{name}'"))?,
    };
    let max_workers = a.get_usize("workers", threadpool::default_parallelism())?;
    let batch = a.get_usize("batch", 1)?.max(1);
    let budget = MeasureBudget {
        warmup: a.get_usize("warmup", 1)?,
        min_time_s: a.get_f64("min-time-ms", 20.0)? / 1e3,
        max_iters: a.get_usize("max-iters", 25)?.max(1),
    };
    let mut tuner = Tuner::for_batch(max_workers, batch).with_budget(budget);
    // `--isa` pins the GEMM candidates to one microkernel lane
    // (DESIGN.md §SIMD-Dispatch): `best` is the host's detected lane,
    // `scalar` the portable fallback; direct lanes always survive.
    if let Some(pin) = a.get("isa") {
        let isa = match pin {
            "best" => Isa::active(),
            name => Isa::parse(name).ok_or_else(|| {
                anyhow::anyhow!("unknown --isa '{name}' (scalar|avx2|avx512|neon|best)")
            })?,
        };
        tuner = tuner.pin_isa(isa);
    }
    // `--precision` swaps the GEMM candidates for their
    // reduced-precision twins (DESIGN.md §Reduced-Precision); the
    // verdict caches under the `+{prec}` key namespace.
    if let Some(name) = a.get("precision") {
        let precision = Precision::parse(name)
            .ok_or_else(|| anyhow::anyhow!("unknown --precision '{name}' (f32|f16|bf16|int8)"))?;
        tuner = tuner.pin_precision(precision);
    }
    let mut isa_label = match tuner.isa_pin {
        Some(isa) => format!("isa {} pinned", isa.name()),
        None => format!("isa {}", Isa::active().name()),
    };
    if tuner.precision.is_quantized() {
        isa_label.push_str(&format!(", precision {} pinned", tuner.precision.name()));
    }
    let mut tuning_cache = if a.has_flag("no-cache") {
        TuningCache::in_memory()
    } else {
        TuningCache::load(std::path::Path::new(a.get_or("cache", "tuning-cache.json")))?
    };
    log::info!(
        "tuning {} at batch {} ({} strategies, fingerprint {}, {})",
        model.name(),
        batch,
        tuner.space.len(),
        cache::host_fingerprint(),
        isa_label
    );
    // Weights are irrelevant to timing (the kernels are
    // data-independent); the layer *plans* carry everything the
    // search needs.
    let mut rng = Rng::seeded(0x7E4E);
    let generator = Generator::random(model, &mut rng);
    let mut measurer = WallClockMeasurer::new(budget);
    if a.has_flag("no-prune") {
        measurer = measurer.without_pruning();
    }
    let mut rows = Vec::new();
    for (i, lw) in generator.layers.iter().enumerate() {
        let tuned = tuner.tune_layer_cached(&lw.plan, &mut tuning_cache, &mut measurer);
        rows.push(vec![
            (i + 2).to_string(), // Table 4 numbers layers from 2
            lw.spec.describe(),
            tuned.strategy.name(),
            timing::fmt_duration(tuned.best_seconds),
            tuned
                .serial_seconds()
                .map(|s| report::speedup(s / tuned.best_seconds))
                .unwrap_or_else(|| "-".into()),
            if tuned.cached {
                "hit".into()
            } else {
                format!("miss ({} timed, {} pruned)", tuned.measured(), tuned.pruned())
            },
        ]);
    }
    report::print_table(
        &format!(
            "Autotune — {} per-layer winners (batch {}, {}, {})",
            model.name(),
            batch,
            cache::host_fingerprint(),
            isa_label
        ),
        &["#", "layer", "strategy", "best", "vs serial", "cache"],
        &rows,
    );
    if a.has_flag("backward") {
        // Backward lanes (DESIGN.md §Backward-Execution): a separate,
        // smaller space searched per layer, persisted under the
        // disjoint `bwd` key namespace of the same cache file.
        let mut bwd_rows = Vec::new();
        for (i, lw) in generator.layers.iter().enumerate() {
            let tuned = tuner.tune_layer_backward_cached(&lw.plan, &mut tuning_cache, &mut measurer);
            bwd_rows.push(vec![
                (i + 2).to_string(),
                lw.spec.describe(),
                tuned.strategy.name(),
                timing::fmt_duration(tuned.best_seconds),
                tuned
                    .serial_seconds()
                    .map(|s| report::speedup(s / tuned.best_seconds))
                    .unwrap_or_else(|| "-".into()),
                if tuned.cached {
                    "hit".into()
                } else {
                    format!("miss ({} timed, {} pruned)", tuned.measured(), tuned.pruned())
                },
            ]);
        }
        report::print_table(
            &format!(
                "Autotune — {} per-layer backward winners ({}, {})",
                model.name(),
                cache::host_fingerprint(),
                isa_label
            ),
            &["#", "layer", "strategy", "best", "vs serial", "cache"],
            &bwd_rows,
        );
    }
    tuning_cache.save()?;
    if let Some(p) = tuning_cache.path() {
        println!(
            "\ntuning cache: {} ({} entries)",
            p.display(),
            tuning_cache.len()
        );
    }
    Ok(())
}

/// `ukstc accuracy`: the reduced-precision drift harness (DESIGN.md
/// §Reduced-Precision).  Each selected zoo model runs its forward pass
/// twice per latent — once with every layer pinned to the f32
/// phase-GEMM lane, once pinned to the quantized twin — so the
/// comparison isolates operand storage from formulation.  Reports
/// max-abs and PSNR (peak 1.0: the final activation is tanh) on the
/// output images; `--max-drift` turns the report into a CI gate.
fn accuracy(a: &Args) -> anyhow::Result<()> {
    let models: Vec<GanModel> = match a.get_or("model", "smallest") {
        "all" => GanModel::all().to_vec(),
        "smallest" => vec![GanModel::smallest()],
        name => vec![GanModel::from_name(name)
            .ok_or_else(|| anyhow::anyhow!("unknown model '{name}'"))?],
    };
    let precisions: Vec<Precision> = match a.get_or("precision", "all") {
        "all" => Precision::QUANTIZED.to_vec(),
        name => vec![Precision::parse(name).ok_or_else(|| {
            anyhow::anyhow!("unknown --precision '{name}' (f32|f16|bf16|int8)")
        })?],
    };
    let latents = a.get_usize("latents", 2)?.max(1);
    let gate: Option<f64> = match a.get("max-drift") {
        Some(s) => Some(
            s.parse::<f64>()
                .map_err(|e| anyhow::anyhow!("bad --max-drift '{s}': {e}"))?,
        ),
        None => None,
    };
    let mut rows = Vec::new();
    let mut worst_overall = 0.0f64;
    for model in models {
        let mut rng = Rng::seeded(0xACC0);
        let mut generator = Generator::random(model, &mut rng);
        let layers = generator.layers.len();
        let zs: Vec<Vec<f32>> = (0..latents)
            .map(|_| {
                let mut z = vec![0.0f32; model.z_dim()];
                rng.fill_normal(&mut z);
                z
            })
            .collect();
        generator.set_strategies(&vec![ExecStrategy::serial_gemm(); layers]);
        let refs: Vec<Feature> = zs
            .iter()
            .map(|z| generator.forward(z, Algorithm::Unified, Lane::Serial))
            .collect();
        for &p in &precisions {
            generator
                .set_strategies(&vec![ExecStrategy::serial_gemm().with_precision(p); layers]);
            let mut max_abs = 0.0f64;
            let mut min_psnr = f64::INFINITY;
            for (z, want) in zs.iter().zip(&refs) {
                let got = generator.forward(z, Algorithm::Unified, Lane::Serial);
                max_abs = max_abs.max(f64::from(ops::max_abs_diff(want, &got)));
                min_psnr = min_psnr.min(ops::psnr(want, &got, 1.0));
            }
            worst_overall = worst_overall.max(max_abs);
            rows.push(vec![
                model.name().to_string(),
                p.name().to_string(),
                format!("{max_abs:.3e}"),
                if min_psnr.is_infinite() {
                    "inf".into()
                } else {
                    format!("{min_psnr:.1} dB")
                },
                match gate {
                    Some(t) => if max_abs <= t { "ok" } else { "FAIL" }.to_string(),
                    None => "-".into(),
                },
            ]);
        }
    }
    report::print_table(
        "Accuracy — quantized phase-GEMM lanes vs f32 (final tanh outputs)",
        &["model", "precision", "max-abs", "PSNR", "gate"],
        &rows,
    );
    if let Some(t) = gate {
        anyhow::ensure!(
            worst_overall <= t,
            "max-abs drift {worst_overall:.3e} exceeds --max-drift {t:.3e}"
        );
        println!("\nall drifts within --max-drift {t:.1e}");
    }
    Ok(())
}

fn parse_model(name: &str) -> anyhow::Result<GanModel> {
    match name {
        "smallest" => Ok(GanModel::smallest()),
        _ => GanModel::from_name(name)
            .ok_or_else(|| anyhow::anyhow!("unknown model '{name}'")),
    }
}

/// One in-process serving burst against a fresh coordinator, so the
/// serving counters (`serve.<model>.*`) and the worker's `serve.batch`
/// spans have data.  Returns the still-running coordinator: its
/// metrics are registered with a `Weak`, so callers keep it alive
/// until after any registry dump.
fn serve_burst(model: GanModel, requests: usize) -> anyhow::Result<Coordinator> {
    let backend = RustBackend::new(model, Algorithm::Unified, Lane::Serial, 0x5EED, 8);
    let coord = Coordinator::builder()
        .queue_capacity(requests.max(16))
        .workers_per_model(2)
        .batch_policy(BatchPolicy {
            max_batch: 8,
            max_delay: std::time::Duration::from_millis(2),
        })
        .register(Arc::new(backend))
        .start()?;
    let mut rng = Rng::seeded(0x5EED);
    let mut pending = Vec::new();
    for i in 0..requests {
        let mut latent = vec![0.0f32; model.z_dim()];
        rng.fill_normal(&mut latent);
        let req = GenRequest::new(i as u64, model.name().to_string(), latent);
        pending.push(coord.submit_blocking(req)?);
    }
    for rx in pending {
        let _ = rx.recv();
    }
    Ok(coord)
}

/// `ukstc trace`: record spans around one workload, write the
/// chrome://tracing JSON, and print the flame table plus a coverage
/// check (per-layer spans vs the end-to-end span).
fn cmd_trace(rest: &[String]) -> anyhow::Result<()> {
    let cmd = Command::new(
        "trace",
        "trace one workload (forward|train|serve) → chrome://tracing JSON + flame table",
    )
    .opt("model", "dcgan|artgan|gpgan|ebgan|smallest", Some("dcgan"))
    .opt("iters", "traced iterations (forward/train) or requests (serve)", Some("2"))
    .opt("out", "chrome://tracing JSON output path", Some("trace.json"))
    .opt("capacity", "per-thread span-ring capacity (spans)", None);
    let a = cmd.parse(rest)?;
    let workload = a.positional.first().map(String::as_str).unwrap_or("forward");
    let model = parse_model(a.get_or("model", "dcgan"))?;
    let iters = a.get_usize("iters", 2)?.max(1);
    match a.get_usize("capacity", 0)? {
        0 => obs_trace::enable(),
        cap => obs_trace::enable_with_capacity(cap),
    }
    obs_trace::clear();
    match workload {
        "forward" => {
            let mut rng = Rng::seeded(0xACE5);
            let generator = Generator::random(model, &mut rng);
            let mut z = vec![0.0f32; model.z_dim()];
            rng.fill_normal(&mut z);
            let mut scratch = generator.scratch();
            for _ in 0..iters {
                std::hint::black_box(generator.forward_with(
                    &z,
                    Algorithm::Unified,
                    Lane::Serial,
                    &mut scratch,
                ));
            }
        }
        "train" => {
            let mut rng = Rng::seeded(0xACE5);
            let generator = Generator::random(model, &mut rng);
            let mut step = TrainStep::new(generator, &mut rng, 1e-3);
            for _ in 0..iters {
                std::hint::black_box(step.step());
            }
        }
        "serve" => {
            drop(serve_burst(model, iters.max(8))?);
        }
        other => anyhow::bail!("unknown workload '{other}' (forward|train|serve)"),
    }
    let spans = obs_trace::drain();
    let dropped = obs_trace::dropped();
    obs_trace::disable();
    let out = a.get_or("out", "trace.json");
    std::fs::write(out, obs_trace::chrome_trace(&spans).to_string_compact())
        .map_err(|e| anyhow::anyhow!("writing {out}: {e}"))?;
    let rows: Vec<Vec<String>> = obs_trace::flame_table(&spans)
        .into_iter()
        .map(|r| {
            vec![
                r.name.to_string(),
                r.lane.to_string(),
                r.count.to_string(),
                timing::fmt_duration(r.total_s),
                timing::fmt_duration(r.self_s),
            ]
        })
        .collect();
    report::print_table(
        &format!("Flame table — {} {} ({} spans)", model.name(), workload, spans.len()),
        &["span", "lane", "count", "total", "self"],
        &rows,
    );
    // Coverage: the per-layer spans (plus the dense projection) should
    // account for nearly all of the enclosing end-to-end span — a gap
    // means un-instrumented time (ISSUE 8 acceptance: within 10%).
    let layers = obs_trace::total_seconds(&spans, "layer.forward")
        + obs_trace::total_seconds(&spans, "layer.backward")
        + obs_trace::total_seconds(&spans, "gen.project");
    let e2e = obs_trace::total_seconds(&spans, "gen.forward")
        + obs_trace::total_seconds(&spans, "gen.forward_batch")
        + obs_trace::total_seconds(&spans, "train.step");
    if e2e > 0.0 {
        println!(
            "\ncoverage: layer spans {} / end-to-end {} = {:.1}%",
            timing::fmt_duration(layers),
            timing::fmt_duration(e2e),
            100.0 * layers / e2e
        );
    }
    if dropped > 0 {
        println!("note: {dropped} spans dropped (ring full) — raise --capacity");
    }
    println!("wrote {out} ({} spans)", spans.len());
    Ok(())
}

/// `ukstc metrics`: run a small in-process serving burst so the
/// counters have data, then dump the process-wide registry.
fn cmd_metrics(rest: &[String]) -> anyhow::Result<()> {
    let cmd = Command::new(
        "metrics",
        "populate and dump the process-wide perf-counter registry",
    )
    .opt("model", "dcgan|artgan|gpgan|ebgan|smallest", Some("smallest"))
    .opt("requests", "serving-burst size used to populate the counters", Some("16"))
    .flag("json", "emit the registry as JSON instead of Prometheus text");
    let a = cmd.parse(rest)?;
    let model = parse_model(a.get_or("model", "smallest"))?;
    let requests = a.get_usize("requests", 16)?.max(1);
    let coord = serve_burst(model, requests)?;
    if a.has_flag("json") {
        println!("{}", registry::global().json_snapshot().to_string_compact());
    } else {
        print!("{}", registry::global().prometheus_text());
    }
    // The lane's collector is Weak-registered: keep the coordinator
    // alive until after the dump.
    drop(coord);
    Ok(())
}

/// `ukstc serve`: run the coordinator on a Poisson trace, native or
/// PJRT backend, from a JSON config or flags.
fn serve(rest: &[String]) -> anyhow::Result<()> {
    let cmd = Command::new("serve", "run the serving coordinator demo")
        .opt("config", "JSON config file", None)
        .opt("model", "gan model", Some("dcgan"))
        .opt("backend", "rust|pjrt", Some("rust"))
        .opt("artifacts", "artifact directory", Some("artifacts"))
        .opt("rate", "Poisson request rate (req/s)", Some("20"))
        .opt("requests", "number of requests", Some("40"))
        .opt("workers", "coordinator workers per model", Some("2"))
        .opt("max-batch", "dynamic batch cap", Some("8"))
        .opt(
            "tune-cache",
            "autotune the rust backend through this cache (batched for max-batch)",
            None,
        )
        .opt(
            "quantize-budget",
            "with --tune-cache: also try f16/bf16/int8 lanes, serving the fastest \
             whose whole-model drift stays within this max-abs budget",
            None,
        );
    let a = cmd.parse(rest)?;

    let mut cfg = if let Some(path) = a.get("config") {
        CoordinatorConfig::from_file(std::path::Path::new(path))?
    } else {
        CoordinatorConfig::default()
    };
    if a.get("config").is_none() {
        cfg.models[0].name = a.get_or("model", "dcgan").to_string();
        cfg.models[0].backend = a.get_or("backend", "rust").to_string();
    }
    cfg.workers_per_model = a.get_usize("workers", cfg.workers_per_model)?;
    cfg.max_batch = a.get_usize("max-batch", cfg.max_batch)?;

    let mut builder = Coordinator::builder()
        .queue_capacity(cfg.queue_capacity)
        .workers_per_model(cfg.workers_per_model)
        .batch_policy(cfg.batch_policy());

    let model_cfg = cfg.models[0].clone();
    let model_name;
    let z_dim;
    if model_cfg.backend == "pjrt" {
        let mut engine = Engine::new(std::path::Path::new(a.get_or("artifacts", "artifacts")))?;
        let artifact = model_cfg
            .artifact
            .clone()
            .unwrap_or_else(|| format!("{}_b{}", model_cfg.name, cfg.max_batch.min(8)));
        engine.compile(&artifact)?;
        let backend = PjrtBackend::new(Arc::new(engine), &artifact, model_cfg.seed)?;
        model_name = ukstc::coordinator::Backend::model_name(&backend).to_string();
        z_dim = ukstc::coordinator::Backend::z_dim(&backend);
        builder = builder.register(Arc::new(backend));
    } else {
        let model = GanModel::from_name(&model_cfg.name)
            .ok_or_else(|| anyhow::anyhow!("unknown model '{}'", model_cfg.name))?;
        let mut backend = RustBackend::new(
            model,
            model_cfg.algorithm,
            model_cfg.lane(),
            model_cfg.seed,
            cfg.max_batch,
        );
        if let Some(path) = a.get("tune-cache") {
            backend = if let Some(budget) = a.get("quantize-budget") {
                let budget: f32 = budget
                    .parse()
                    .map_err(|e| anyhow::anyhow!("--quantize-budget '{budget}': {e}"))?;
                backend.with_autotune_tuner_quantized(
                    Some(std::path::Path::new(path)),
                    &Tuner::for_batch(threadpool::default_parallelism(), cfg.max_batch),
                    budget,
                )
            } else {
                backend.with_autotune_batch(Some(std::path::Path::new(path)), cfg.max_batch)
            };
        }
        println!(
            "backend: rust, {} batch lane (max_batch={}, serving precision {})",
            if backend.is_fused_batch() { "fused" } else { "per-latent" },
            cfg.max_batch,
            backend.serving_precision().name()
        );
        model_name = model.name().to_string();
        z_dim = model.z_dim();
        builder = builder.register(Arc::new(backend));
    }

    let coord = builder.start()?;
    let rate = a.get_f64("rate", 20.0)?;
    let n = a.get_usize("requests", 40)?;
    log::info!("serving {n} Poisson requests at {rate} req/s to '{model_name}'");

    let mut rng = Rng::seeded(2026);
    let trace = poisson_trace(&model_name, z_dim, rate, n, &mut rng);
    let t0 = std::time::Instant::now();
    let mut pending = Vec::new();
    for tr in trace {
        // Open-loop replay: honor arrival times.
        let now = t0.elapsed().as_secs_f64();
        if tr.at > now {
            std::thread::sleep(std::time::Duration::from_secs_f64(tr.at - now));
        }
        match coord.submit(tr.request) {
            Ok(rx) => pending.push(rx),
            Err(e) => log::warn!("rejected: {e}"),
        }
    }
    for rx in pending {
        let _ = rx.recv();
    }
    let wall = t0.elapsed().as_secs_f64();
    let snap = coord.metrics(&model_name).unwrap();
    println!("\nserve run complete in {wall:.2}s");
    println!("{}", snap.summary());
    Ok(())
}

const HELP: &str = "\
ukstc — Unified Kernel-Segregated Transpose Convolution

subcommands:
  table1     print the dataset spec (paper Table 1)
  table2     regenerate Table 2 (Flower dataset sweep)
  table3     regenerate Table 3 (MSCOCO + PASCAL sweep)
  table4     regenerate Table 4 (GAN-layer ablation)
  ablation   design-choice ablations (formulation, GEMM, dilated, lanes, tuning)
  tune       autotune per-layer execution strategies (persists a tuning cache)
  accuracy   reduced-precision drift vs f32 (max-abs + PSNR; --max-drift gates)
  serve      run the serving coordinator on a Poisson trace
  serve-ab   serving matrix: unified planned/unplanned vs conventional
  trace      span-trace a workload (forward|train|serve) → chrome://tracing JSON
  metrics    dump the process-wide perf-counter registry (Prometheus text or --json)
  info       model zoo + analytic memory summaries
common bench flags: --scale F --warmup N --iters N --workers N --image-size N";
