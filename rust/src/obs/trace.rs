//! Span recorder: per-thread ring buffers behind one process-wide flag
//! (DESIGN.md §Observability).
//!
//! ## Hot-path contract
//!
//! [`span`] is called from the planned execution lanes — code whose
//! steady state is proven zero-alloc by `tests/plan_alloc.rs`.  The
//! recorder therefore promises:
//!
//! * **Disabled** (the default): one `Relaxed` load of a process-wide
//!   flag, then nothing — no clock read, no allocation, no atomic
//!   read-modify-write, no lock.  The returned guard's `Drop` is a
//!   single branch.
//! * **Enabled**: two monotonic clock reads per span plus one push into
//!   a *thread-local* ring buffer.  The ring (capacity
//!   [`DEFAULT_CAPACITY`] records, configurable) is allocated once per
//!   thread on its first recorded span — the only allocation the
//!   recorder ever performs — after which pushes overwrite the oldest
//!   record in place.  The ring sits behind a per-thread mutex that is
//!   contended only by [`drain`], never by another recording thread.
//!
//! ## Exporters
//!
//! [`chrome_trace`] renders the records as a chrome://tracing /
//! Perfetto-loadable JSON document (`ph: "X"` complete events, one
//! `tid` per recording thread).  [`flame_table`] aggregates per
//! `(name, lane)` with self-time (nested same-thread spans subtracted),
//! and [`rollup_json`] emits that table for the `BENCH_*.json`
//! snapshots.

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use once_cell::sync::Lazy;

use crate::util::json::Json;

/// Sentinel for "no layer / no phase attribution" on a span.
pub const NONE: u32 = u32::MAX;

/// Default per-thread ring capacity (records).  64Ki spans × 56 bytes ≈
/// 3.5 MiB per recording thread — hours of layer-level tracing, minutes
/// of phase-level tracing.
pub const DEFAULT_CAPACITY: usize = 1 << 16;

/// One closed span.  `&'static str` names keep records `Copy` and the
/// recording path allocation-free.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SpanRecord {
    /// What ran, e.g. `layer.forward`, `conv.phase` (see `obs` docs).
    pub name: &'static str,
    /// Executing lane tag: `direct`, `gemm/avx2`, `per-element`, …
    pub lane: &'static str,
    /// Table-4 layer number, or [`NONE`] below the model level.
    pub layer: u32,
    /// Phase index (0–3), or [`NONE`] above the phase level.
    pub phase: u32,
    /// Recording thread (small dense ids, assigned per thread on first
    /// record; 0 never appears).
    pub tid: u64,
    /// Start / end, nanoseconds since the process trace epoch.
    pub t_start_ns: u64,
    pub t_end_ns: u64,
}

impl SpanRecord {
    /// Span duration in seconds.
    pub fn seconds(&self) -> f64 {
        self.t_end_ns.saturating_sub(self.t_start_ns) as f64 / 1e9
    }
}

static ENABLED: AtomicBool = AtomicBool::new(false);
static CAPACITY: AtomicUsize = AtomicUsize::new(DEFAULT_CAPACITY);
static DROPPED: AtomicU64 = AtomicU64::new(0);
static NEXT_TID: AtomicU64 = AtomicU64::new(1);
static EPOCH: Lazy<Instant> = Lazy::new(Instant::now);

/// Fixed-capacity overwrite-oldest span store (one per thread).
struct Ring {
    slots: Vec<SpanRecord>,
    cap: usize,
    /// Next overwrite position once `slots` is full.
    head: usize,
}

impl Ring {
    fn push(&mut self, rec: SpanRecord) {
        if self.slots.len() < self.cap {
            // Within the preallocated capacity: never reallocates.
            self.slots.push(rec);
        } else {
            self.slots[self.head] = rec;
            self.head = (self.head + 1) % self.cap;
            DROPPED.fetch_add(1, Ordering::Relaxed);
        }
    }
}

/// A thread's ring, shared with the global drain list.  The mutex is
/// uncontended on the recording path (only [`drain`]/[`clear`] take it
/// from another thread).
struct ThreadRing {
    tid: u64,
    ring: Mutex<Ring>,
}

static RINGS: Lazy<Mutex<Vec<Arc<ThreadRing>>>> = Lazy::new(|| Mutex::new(Vec::new()));

thread_local! {
    static LOCAL: RefCell<Option<Arc<ThreadRing>>> = RefCell::new(None);
}

/// Is span recording on?  One relaxed load — the entire disabled-path
/// cost of the recorder.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn recording on at the current ring capacity.
pub fn enable() {
    enable_with_capacity(CAPACITY.load(Ordering::Relaxed));
}

/// Turn recording on with a per-thread ring capacity of `cap` records.
/// Threads that already allocated a ring keep their existing capacity.
pub fn enable_with_capacity(cap: usize) {
    CAPACITY.store(cap.max(1), Ordering::Relaxed);
    // Pin the epoch before the first span so timestamps are
    // monotonically meaningful across threads.
    Lazy::force(&EPOCH);
    ENABLED.store(true, Ordering::Relaxed);
}

/// Turn recording off.  Rings keep their contents for [`drain`].
pub fn disable() {
    ENABLED.store(false, Ordering::Relaxed);
}

/// Honor `UKSTC_TRACE`: unset/`0`/`off` leaves tracing off, `1`/`on`
/// enables at the default capacity, an integer enables with that
/// per-thread ring capacity.
pub fn init_from_env() {
    if let Ok(v) = std::env::var("UKSTC_TRACE") {
        match v.trim() {
            "" | "0" | "off" | "false" => {}
            "1" | "on" | "true" => enable(),
            n => match n.parse::<usize>() {
                Ok(cap) => enable_with_capacity(cap),
                Err(_) => enable(),
            },
        }
    }
}

/// Nanoseconds since the process trace epoch.
#[inline]
pub fn now_ns() -> u64 {
    EPOCH.elapsed().as_nanos() as u64
}

/// Spans overwritten because a ring was full (cumulative).
pub fn dropped() -> u64 {
    DROPPED.load(Ordering::Relaxed)
}

/// Process-wide lock for tests that toggle the recorder (`enable`/
/// `disable`/`drain` are global state; concurrent test threads must
/// serialize on this or interfere with each other).  Not a public API.
#[doc(hidden)]
pub fn test_gate() -> &'static Mutex<()> {
    static GATE: Mutex<()> = Mutex::new(());
    &GATE
}

/// RAII span guard: records `[construction, drop)` when tracing was
/// enabled at construction; otherwise completely inert.
#[must_use = "a span measures until Drop; binding to `_` closes it immediately"]
pub struct Span {
    name: &'static str,
    lane: &'static str,
    layer: u32,
    phase: u32,
    t_start_ns: u64,
    armed: bool,
}

/// Open a span.  `layer`/`phase` take [`NONE`] when the span is not
/// attributable to a Table-4 layer / a decomposition phase.
#[inline]
pub fn span(name: &'static str, lane: &'static str, layer: u32, phase: u32) -> Span {
    let armed = enabled();
    Span {
        name,
        lane,
        layer,
        phase,
        t_start_ns: if armed { now_ns() } else { 0 },
        armed,
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        if !self.armed {
            return;
        }
        record(SpanRecord {
            name: self.name,
            lane: self.lane,
            layer: self.layer,
            phase: self.phase,
            tid: 0,
            t_start_ns: self.t_start_ns,
            t_end_ns: now_ns(),
        });
    }
}

fn record(mut rec: SpanRecord) {
    // try_with: a span dropped during thread teardown (TLS already
    // destroyed) is silently discarded rather than panicking in Drop.
    let _ = LOCAL.try_with(|slot| {
        let mut slot = slot.borrow_mut();
        let tr = slot.get_or_insert_with(|| {
            // First recorded span on this thread: the one-time setup
            // allocation (ring + registration) the alloc-proof budgets.
            let cap = CAPACITY.load(Ordering::Relaxed).max(1);
            let tr = Arc::new(ThreadRing {
                tid: NEXT_TID.fetch_add(1, Ordering::Relaxed),
                ring: Mutex::new(Ring {
                    slots: Vec::with_capacity(cap),
                    cap,
                    head: 0,
                }),
            });
            RINGS.lock().unwrap().push(tr.clone());
            tr
        });
        rec.tid = tr.tid;
        tr.ring.lock().unwrap().push(rec);
    });
}

/// Collect every recorded span across all threads, sorted
/// chronologically (ties broken outermost-first), and empty the rings.
/// Ring capacity stays allocated, so draining between steady-state
/// measurements does not perturb the zero-alloc contract of the next
/// run.
pub fn drain() -> Vec<SpanRecord> {
    let mut out = Vec::new();
    for tr in RINGS.lock().unwrap().iter() {
        let mut ring = tr.ring.lock().unwrap();
        out.extend(ring.slots.drain(..));
        ring.head = 0;
    }
    out.sort_by_key(|r| (r.t_start_ns, std::cmp::Reverse(r.t_end_ns)));
    out
}

/// Discard all recorded spans and reset the drop counter (rings keep
/// their capacity).
pub fn clear() {
    let _ = drain();
    DROPPED.store(0, Ordering::Relaxed);
}

/// Render spans as a chrome://tracing / Perfetto JSON document:
/// `{"traceEvents": [{"name", "cat", "ph": "X", "ts", "dur", "pid",
/// "tid", "args"}, …]}` with microsecond timestamps and the lane /
/// layer / phase attribution under `args`.
pub fn chrome_trace(spans: &[SpanRecord]) -> Json {
    let events: Vec<Json> = spans
        .iter()
        .map(|r| {
            let mut args = BTreeMap::new();
            args.insert("lane".to_string(), Json::Str(r.lane.to_string()));
            if r.layer != NONE {
                args.insert("layer".to_string(), Json::Num(r.layer as f64));
            }
            if r.phase != NONE {
                args.insert("phase".to_string(), Json::Num(r.phase as f64));
            }
            let mut e = BTreeMap::new();
            e.insert("name".to_string(), Json::Str(r.name.to_string()));
            e.insert("cat".to_string(), Json::Str(r.lane.to_string()));
            e.insert("ph".to_string(), Json::Str("X".to_string()));
            e.insert("ts".to_string(), Json::Num(r.t_start_ns as f64 / 1e3));
            e.insert(
                "dur".to_string(),
                Json::Num(r.t_end_ns.saturating_sub(r.t_start_ns) as f64 / 1e3),
            );
            e.insert("pid".to_string(), Json::Num(1.0));
            e.insert("tid".to_string(), Json::Num(r.tid as f64));
            e.insert("args".to_string(), Json::Obj(args));
            Json::Obj(e)
        })
        .collect();
    let mut doc = BTreeMap::new();
    doc.insert("traceEvents".to_string(), Json::Arr(events));
    doc.insert("displayTimeUnit".to_string(), Json::Str("ms".to_string()));
    Json::Obj(doc)
}

/// One aggregated flame-table row.
#[derive(Debug, Clone, PartialEq)]
pub struct FlameRow {
    pub name: &'static str,
    pub lane: &'static str,
    /// Spans aggregated into this row.
    pub count: u64,
    /// Total wall seconds inside these spans.
    pub total_s: f64,
    /// Wall seconds not covered by a nested span on the same thread.
    pub self_s: f64,
}

/// Aggregate spans per `(name, lane)` with self-time: for each span,
/// time spent inside spans nested within it *on the same thread* is
/// subtracted from its self figure.  Rows sort by self time descending
/// — the flame table's "where does the time actually go" answer.
pub fn flame_table(spans: &[SpanRecord]) -> Vec<FlameRow> {
    let mut self_ns: Vec<u64> = spans
        .iter()
        .map(|r| r.t_end_ns.saturating_sub(r.t_start_ns))
        .collect();
    let mut order: Vec<usize> = (0..spans.len()).collect();
    order.sort_by_key(|&i| {
        (
            spans[i].tid,
            spans[i].t_start_ns,
            std::cmp::Reverse(spans[i].t_end_ns),
        )
    });
    // Sweep each thread's spans in start order with an enclosing-span
    // stack; a span subtracts its duration from its *direct* parent
    // only, so grandchildren are not double-counted.
    let mut stack: Vec<usize> = Vec::new();
    let mut prev_tid = None;
    for &i in &order {
        let r = &spans[i];
        if prev_tid != Some(r.tid) {
            stack.clear();
            prev_tid = Some(r.tid);
        }
        while let Some(&top) = stack.last() {
            if spans[top].t_end_ns <= r.t_start_ns {
                stack.pop();
            } else {
                break;
            }
        }
        if let Some(&top) = stack.last() {
            if r.t_end_ns <= spans[top].t_end_ns {
                self_ns[top] =
                    self_ns[top].saturating_sub(r.t_end_ns.saturating_sub(r.t_start_ns));
            }
        }
        stack.push(i);
    }
    let mut agg: BTreeMap<(&'static str, &'static str), FlameRow> = BTreeMap::new();
    for (i, r) in spans.iter().enumerate() {
        let row = agg.entry((r.name, r.lane)).or_insert(FlameRow {
            name: r.name,
            lane: r.lane,
            count: 0,
            total_s: 0.0,
            self_s: 0.0,
        });
        row.count += 1;
        row.total_s += r.seconds();
        row.self_s += self_ns[i] as f64 / 1e9;
    }
    let mut rows: Vec<FlameRow> = agg.into_values().collect();
    rows.sort_by(|a, b| b.self_s.total_cmp(&a.self_s));
    rows
}

/// The flame table as JSON (for the `BENCH_*.json` snapshots):
/// `[{"name", "lane", "count", "total_s", "self_s"}, …]`.
pub fn rollup_json(spans: &[SpanRecord]) -> Json {
    Json::Arr(
        flame_table(spans)
            .into_iter()
            .map(|r| {
                let mut m = BTreeMap::new();
                m.insert("name".to_string(), Json::Str(r.name.to_string()));
                m.insert("lane".to_string(), Json::Str(r.lane.to_string()));
                m.insert("count".to_string(), Json::Num(r.count as f64));
                m.insert("total_s".to_string(), Json::Num(r.total_s));
                m.insert("self_s".to_string(), Json::Num(r.self_s));
                Json::Obj(m)
            })
            .collect(),
    )
}

/// Total seconds of every span named `name` (a roll-up helper for
/// coverage reporting).
pub fn total_seconds(spans: &[SpanRecord], name: &str) -> f64 {
    spans
        .iter()
        .filter(|r| r.name == name)
        .map(SpanRecord::seconds)
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn drain_named(names: &[&str]) -> Vec<SpanRecord> {
        drain()
            .into_iter()
            .filter(|r| names.contains(&r.name))
            .collect()
    }

    #[test]
    fn disabled_records_nothing() {
        let _gate = test_gate().lock().unwrap();
        disable();
        clear();
        {
            let _s = span("test.disabled", "direct", NONE, NONE);
        }
        assert!(drain_named(&["test.disabled"]).is_empty());
    }

    #[test]
    fn enabled_records_nested_spans_and_flame_self_time() {
        let _gate = test_gate().lock().unwrap();
        enable_with_capacity(1024);
        clear();
        {
            let _outer = span("test.outer", "direct", 2, NONE);
            for phase in 0..4u32 {
                let _inner = span("test.inner", "gemm/scalar", NONE, phase);
                std::hint::black_box(phase);
            }
        }
        disable();
        let spans = drain_named(&["test.outer", "test.inner"]);
        assert_eq!(spans.len(), 5);
        let outer = spans.iter().find(|r| r.name == "test.outer").unwrap();
        assert_eq!((outer.layer, outer.phase), (2, NONE));
        assert!(outer.t_end_ns >= outer.t_start_ns);
        let inners: Vec<_> = spans.iter().filter(|r| r.name == "test.inner").collect();
        assert_eq!(inners.len(), 4);
        let phases: Vec<u32> = inners.iter().map(|r| r.phase).collect();
        assert_eq!(phases, vec![0, 1, 2, 3]);
        for i in &inners {
            assert!(i.t_start_ns >= outer.t_start_ns && i.t_end_ns <= outer.t_end_ns);
            assert_eq!(i.tid, outer.tid, "same-thread spans share a tid");
        }
        // Flame: outer's self time excludes the nested inners.
        let table = flame_table(&spans);
        let orow = table.iter().find(|r| r.name == "test.outer").unwrap();
        let irow = table.iter().find(|r| r.name == "test.inner").unwrap();
        assert_eq!(irow.count, 4);
        assert!(orow.self_s <= orow.total_s);
        let inner_total: f64 = inners.iter().map(|r| r.seconds()).sum();
        assert!((orow.total_s - orow.self_s - inner_total).abs() < 1e-9);
    }

    #[test]
    fn ring_overwrites_oldest_at_capacity() {
        let _gate = test_gate().lock().unwrap();
        enable_with_capacity(8);
        clear();
        // A fresh thread gets a fresh ring at the new tiny capacity.
        std::thread::spawn(|| {
            for i in 0..20u32 {
                let _s = span("test.wrap", "direct", i, NONE);
            }
        })
        .join()
        .unwrap();
        disable();
        let spans = drain_named(&["test.wrap"]);
        assert_eq!(spans.len(), 8, "ring holds exactly its capacity");
        assert!(dropped() >= 12);
        // The survivors are the newest records.
        assert!(spans.iter().all(|r| r.layer >= 12));
        clear();
        assert_eq!(dropped(), 0);
    }

    #[test]
    fn chrome_trace_is_valid_loadable_json() {
        let spans = [
            SpanRecord {
                name: "layer.forward",
                lane: "direct",
                layer: 2,
                phase: NONE,
                tid: 1,
                t_start_ns: 1_000,
                t_end_ns: 5_000,
            },
            SpanRecord {
                name: "conv.phase",
                lane: "gemm/avx2",
                layer: NONE,
                phase: 3,
                tid: 1,
                t_start_ns: 1_500,
                t_end_ns: 2_500,
            },
        ];
        let doc = chrome_trace(&spans);
        // Roundtrip through the hand-rolled parser: the export is
        // syntactically valid JSON.
        let text = doc.to_string_compact();
        let back = crate::util::json::parse(&text).unwrap();
        let events = match back.get("traceEvents") {
            Some(Json::Arr(a)) => a,
            other => panic!("traceEvents missing: {other:?}"),
        };
        assert_eq!(events.len(), 2);
        let e0 = &events[0];
        assert_eq!(e0.get("ph").unwrap().as_str(), Some("X"));
        assert_eq!(e0.get("name").unwrap().as_str(), Some("layer.forward"));
        assert_eq!(e0.get("ts").unwrap().as_f64(), Some(1.0)); // µs
        assert_eq!(e0.get("dur").unwrap().as_f64(), Some(4.0));
        let args0 = e0.get("args").unwrap();
        assert_eq!(args0.get("layer").unwrap().as_f64(), Some(2.0));
        assert!(args0.get("phase").is_none(), "NONE phase omitted");
        let args1 = events[1].get("args").unwrap();
        assert_eq!(args1.get("phase").unwrap().as_f64(), Some(3.0));
        assert!(args1.get("layer").is_none(), "NONE layer omitted");
        assert_eq!(args1.get("lane").unwrap().as_str(), Some("gemm/avx2"));
    }

    #[test]
    fn rollup_and_total_seconds_aggregate() {
        let mk = |start: u64, end: u64| SpanRecord {
            name: "x.op",
            lane: "direct",
            layer: NONE,
            phase: NONE,
            tid: 7,
            t_start_ns: start,
            t_end_ns: end,
        };
        let spans = [mk(0, 1_000_000_000), mk(2_000_000_000, 2_500_000_000)];
        assert!((total_seconds(&spans, "x.op") - 1.5).abs() < 1e-12);
        assert_eq!(total_seconds(&spans, "y.op"), 0.0);
        let rollup = rollup_json(&spans);
        let text = rollup.to_string_compact();
        let back = crate::util::json::parse(&text).unwrap();
        match back {
            Json::Arr(rows) => {
                assert_eq!(rows.len(), 1);
                assert_eq!(rows[0].get("count").unwrap().as_f64(), Some(2.0));
                assert!((rows[0].get("total_s").unwrap().as_f64().unwrap() - 1.5).abs() < 1e-9);
            }
            other => panic!("rollup not an array: {other:?}"),
        }
    }
}
