//! Process-wide perf-counter registry (DESIGN.md §Observability).
//!
//! Counters, gauges and [`LatencyHistogram`]s registered by name, plus
//! [`Collector`]s — live metric sources (the serving coordinator's
//! per-lane [`Metrics`](crate::coordinator::metrics::Metrics)) that are sampled
//! at exposition time through a `Weak` reference, so a dropped lane
//! disappears from the output instead of pinning its metrics alive.
//!
//! Recording is lock-free (`Relaxed` atomics) for counters and gauges;
//! histograms share the mutex discipline of
//! [`LatencyHistogram`]-in-`Metrics`.  Registration allocates (name
//! lookup), so hot call sites cache their `Arc<Counter>` in a
//! `Lazy` static and pay one `fetch_add` per event thereafter.
//!
//! Two expositions, both hand-rolled (the crate carries no serde):
//! [`Registry::prometheus_text`] (text format: `# TYPE` headers,
//! `ukstc_`-prefixed sanitized names, summary quantiles for
//! histograms) and [`Registry::json_snapshot`] (`util::json`).

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, Weak};

use once_cell::sync::Lazy;

use crate::util::json::Json;
use crate::util::stats::LatencyHistogram;

/// Monotone event counter (relaxed `fetch_add` on record).
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-write-wins f64 gauge (stored as bits in an atomic).
#[derive(Debug, Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

/// A live metric source sampled at exposition time.  Implementors
/// return `(suffix, value)` pairs; the registry prefixes each with the
/// name the collector was registered under.
pub trait Collector: Send + Sync {
    fn collect(&self) -> Vec<(String, f64)>;
}

/// Named metric store.  Use [`global`] (and the module-level shorthands
/// [`counter`]/[`gauge`]/[`histogram`]/[`register_collector`]) for the
/// process-wide instance; constructing a private `Registry` is for
/// tests.
#[derive(Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<String, Arc<Counter>>>,
    gauges: Mutex<BTreeMap<String, Arc<Gauge>>>,
    hists: Mutex<BTreeMap<String, Arc<Mutex<LatencyHistogram>>>>,
    collectors: Mutex<BTreeMap<String, Weak<dyn Collector>>>,
}

impl Registry {
    /// Get-or-register the counter `name`.
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        self.counters
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .clone()
    }

    /// Get-or-register the gauge `name`.
    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        self.gauges
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .clone()
    }

    /// Get-or-register the latency histogram `name`.
    pub fn histogram(&self, name: &str) -> Arc<Mutex<LatencyHistogram>> {
        self.hists
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_insert_with(|| Arc::new(Mutex::new(LatencyHistogram::new())))
            .clone()
    }

    /// Register (or replace) the collector exported under `name`.  The
    /// registry holds only a `Weak`; when the collector's owner drops
    /// it, its samples vanish from the expositions.
    pub fn register_collector(&self, name: &str, c: Weak<dyn Collector>) {
        self.collectors
            .lock()
            .unwrap()
            .insert(name.to_string(), c);
    }

    /// Flat point-in-time view: every counter, gauge, histogram
    /// quantile (`.p50`/`.p95`/`.p99`/`.count`) and live collector
    /// sample (prefixed `<collector>.`), keyed by dotted name.
    pub fn samples(&self) -> BTreeMap<String, f64> {
        let mut out = BTreeMap::new();
        for (name, c) in self.counters.lock().unwrap().iter() {
            out.insert(name.clone(), c.get() as f64);
        }
        for (name, g) in self.gauges.lock().unwrap().iter() {
            out.insert(name.clone(), g.get());
        }
        for (name, h) in self.hists.lock().unwrap().iter() {
            let h = h.lock().unwrap();
            out.insert(format!("{name}.p50"), h.quantile(0.50));
            out.insert(format!("{name}.p95"), h.quantile(0.95));
            out.insert(format!("{name}.p99"), h.quantile(0.99));
            out.insert(format!("{name}.count"), h.count() as f64);
        }
        let mut collectors = self.collectors.lock().unwrap();
        collectors.retain(|_, w| w.strong_count() > 0);
        for (prefix, w) in collectors.iter() {
            if let Some(c) = w.upgrade() {
                for (suffix, v) in c.collect() {
                    out.insert(format!("{prefix}.{suffix}"), v);
                }
            }
        }
        out
    }

    /// Prometheus text exposition: counters as `counter`, gauges and
    /// collector samples as `gauge`, histograms as `summary` quantiles.
    /// Metric names are `ukstc_`-prefixed with non-alphanumerics
    /// folded to `_`.
    pub fn prometheus_text(&self) -> String {
        let mut s = String::new();
        for (name, c) in self.counters.lock().unwrap().iter() {
            let m = metric_name(name);
            let _ = writeln!(s, "# TYPE {m} counter");
            let _ = writeln!(s, "{m} {}", c.get());
        }
        for (name, g) in self.gauges.lock().unwrap().iter() {
            let m = metric_name(name);
            let _ = writeln!(s, "# TYPE {m} gauge");
            let _ = writeln!(s, "{m} {}", g.get());
        }
        for (name, h) in self.hists.lock().unwrap().iter() {
            let m = metric_name(name);
            let h = h.lock().unwrap();
            let _ = writeln!(s, "# TYPE {m} summary");
            for (q, label) in [(0.50, "0.5"), (0.95, "0.95"), (0.99, "0.99")] {
                let _ = writeln!(s, "{m}{{quantile=\"{label}\"}} {}", h.quantile(q));
            }
            let _ = writeln!(s, "{m}_count {}", h.count());
        }
        let mut collectors = self.collectors.lock().unwrap();
        collectors.retain(|_, w| w.strong_count() > 0);
        for (prefix, w) in collectors.iter() {
            if let Some(c) = w.upgrade() {
                for (suffix, v) in c.collect() {
                    let m = metric_name(&format!("{prefix}.{suffix}"));
                    let _ = writeln!(s, "# TYPE {m} gauge");
                    let _ = writeln!(s, "{m} {v}");
                }
            }
        }
        s
    }

    /// JSON snapshot (`util::json`, no serde): `{"counters": {...},
    /// "gauges": {...}, "histograms": {name: {p50, p95, p99, count}},
    /// "collected": {...}}`.
    pub fn json_snapshot(&self) -> Json {
        let mut counters = BTreeMap::new();
        for (name, c) in self.counters.lock().unwrap().iter() {
            counters.insert(name.clone(), Json::Num(c.get() as f64));
        }
        let mut gauges = BTreeMap::new();
        for (name, g) in self.gauges.lock().unwrap().iter() {
            gauges.insert(name.clone(), Json::Num(g.get()));
        }
        let mut hists = BTreeMap::new();
        for (name, h) in self.hists.lock().unwrap().iter() {
            let h = h.lock().unwrap();
            let mut m = BTreeMap::new();
            m.insert("p50".to_string(), Json::Num(h.quantile(0.50)));
            m.insert("p95".to_string(), Json::Num(h.quantile(0.95)));
            m.insert("p99".to_string(), Json::Num(h.quantile(0.99)));
            m.insert("count".to_string(), Json::Num(h.count() as f64));
            hists.insert(name.clone(), Json::Obj(m));
        }
        let mut collected = BTreeMap::new();
        let mut collectors = self.collectors.lock().unwrap();
        collectors.retain(|_, w| w.strong_count() > 0);
        for (prefix, w) in collectors.iter() {
            if let Some(c) = w.upgrade() {
                for (suffix, v) in c.collect() {
                    collected.insert(format!("{prefix}.{suffix}"), Json::Num(v));
                }
            }
        }
        let mut doc = BTreeMap::new();
        doc.insert("counters".to_string(), Json::Obj(counters));
        doc.insert("gauges".to_string(), Json::Obj(gauges));
        doc.insert("histograms".to_string(), Json::Obj(hists));
        doc.insert("collected".to_string(), Json::Obj(collected));
        Json::Obj(doc)
    }
}

/// Prometheus-legal metric name: `ukstc_` prefix, non-alphanumerics
/// folded to `_`.
fn metric_name(name: &str) -> String {
    let mut s = String::with_capacity(name.len() + 6);
    s.push_str("ukstc_");
    for ch in name.chars() {
        s.push(if ch.is_ascii_alphanumeric() { ch } else { '_' });
    }
    s
}

static GLOBAL: Lazy<Registry> = Lazy::new(Registry::default);

/// The process-wide registry every subsystem records into.
pub fn global() -> &'static Registry {
    &GLOBAL
}

/// Get-or-register a counter in the [`global`] registry.
pub fn counter(name: &str) -> Arc<Counter> {
    GLOBAL.counter(name)
}

/// Get-or-register a gauge in the [`global`] registry.
pub fn gauge(name: &str) -> Arc<Gauge> {
    GLOBAL.gauge(name)
}

/// Get-or-register a histogram in the [`global`] registry.
pub fn histogram(name: &str) -> Arc<Mutex<LatencyHistogram>> {
    GLOBAL.histogram(name)
}

/// Register a collector in the [`global`] registry.
pub fn register_collector(name: &str, c: Weak<dyn Collector>) {
    GLOBAL.register_collector(name, c)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_gauges_roundtrip() {
        let r = Registry::default();
        let c = r.counter("test.events");
        c.inc();
        c.add(4);
        // Re-registration returns the same underlying counter.
        assert_eq!(r.counter("test.events").get(), 5);
        let g = r.gauge("test.depth");
        g.set(2.5);
        assert_eq!(r.gauge("test.depth").get(), 2.5);
        let s = r.samples();
        assert_eq!(s["test.events"], 5.0);
        assert_eq!(s["test.depth"], 2.5);
    }

    #[test]
    fn histogram_quantiles_in_samples() {
        let r = Registry::default();
        let h = r.histogram("test.latency");
        for _ in 0..100 {
            h.lock().unwrap().record(0.010);
        }
        let s = r.samples();
        assert_eq!(s["test.latency.count"], 100.0);
        assert!(s["test.latency.p50"] >= 0.010);
        assert!(s["test.latency.p99"] >= s["test.latency.p50"]);
    }

    #[test]
    fn prometheus_text_format() {
        let r = Registry::default();
        r.counter("tune.cache_hits").add(3);
        r.gauge("pool.workers").set(8.0);
        r.histogram("serve.latency").lock().unwrap().record(0.001);
        let text = r.prometheus_text();
        assert!(text.contains("# TYPE ukstc_tune_cache_hits counter"), "{text}");
        assert!(text.contains("ukstc_tune_cache_hits 3"), "{text}");
        assert!(text.contains("# TYPE ukstc_pool_workers gauge"), "{text}");
        assert!(text.contains("ukstc_pool_workers 8"), "{text}");
        assert!(text.contains("# TYPE ukstc_serve_latency summary"), "{text}");
        assert!(text.contains("ukstc_serve_latency{quantile=\"0.95\"}"), "{text}");
        assert!(text.contains("ukstc_serve_latency_count 1"), "{text}");
    }

    #[test]
    fn json_snapshot_parses_and_carries_sections() {
        let r = Registry::default();
        r.counter("a.b").inc();
        r.gauge("c.d").set(1.5);
        r.histogram("e.f").lock().unwrap().record(0.5);
        let text = r.json_snapshot().to_string_compact();
        let back = crate::util::json::parse(&text).unwrap();
        assert_eq!(back.get("counters").unwrap().get("a.b").unwrap().as_f64(), Some(1.0));
        assert_eq!(back.get("gauges").unwrap().get("c.d").unwrap().as_f64(), Some(1.5));
        let hist = back.get("histograms").unwrap().get("e.f").unwrap();
        assert_eq!(hist.get("count").unwrap().as_f64(), Some(1.0));
        assert!(hist.get("p50").unwrap().as_f64().unwrap() >= 0.5);
    }

    #[test]
    fn collector_prefixes_and_weak_lifecycle() {
        struct Fake;
        impl Collector for Fake {
            fn collect(&self) -> Vec<(String, f64)> {
                vec![("completed".to_string(), 7.0), ("rejected".to_string(), 1.0)]
            }
        }
        let r = Registry::default();
        let fake: Arc<Fake> = Arc::new(Fake);
        let weak: Weak<Fake> = Arc::downgrade(&fake);
        r.register_collector("serve.dcgan", weak);
        let s = r.samples();
        assert_eq!(s["serve.dcgan.completed"], 7.0);
        assert_eq!(s["serve.dcgan.rejected"], 1.0);
        assert!(r.prometheus_text().contains("ukstc_serve_dcgan_completed 7"));
        // Dropping the owner removes the samples (weak registration).
        drop(fake);
        assert!(!r.samples().contains_key("serve.dcgan.completed"));
        assert!(!r.prometheus_text().contains("serve_dcgan"));
    }

    #[test]
    fn global_registry_is_shared() {
        counter("test.global.shared").add(2);
        assert_eq!(global().counter("test.global.shared").get(), 2);
        assert!(global().samples().contains_key("test.global.shared"));
    }
}
