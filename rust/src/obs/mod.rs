//! Observability: span tracing and the process-wide metrics registry
//! (DESIGN.md §Observability).
//!
//! Two halves, both zero-dependency and allocation-disciplined:
//!
//! * [`trace`] — a span recorder for the execution hot paths.  Spans
//!   are `{name, lane, layer, phase, t_start, t_end}` records written
//!   into preallocated per-thread ring buffers.  Recording is gated by
//!   one process-wide flag (`UKSTC_TRACE` / [`trace::enable`]); when
//!   the flag is off, opening a span is a single relaxed atomic load —
//!   no clock read, no allocation, no shared-cache-line write — so the
//!   planned execution lanes keep their zero-alloc steady-state
//!   contract (`tests/plan_alloc.rs` Part 6) and pay well under 1% on
//!   the forward path (ablation 11).  Exporters produce
//!   chrome://tracing JSON and a self-time flame table.
//! * [`registry`] — a process-wide counter/gauge/histogram registry
//!   with Prometheus-style text exposition and a hand-rolled JSON
//!   snapshot (`util::json`; the crate carries no serde).  The serving
//!   coordinator's per-lane [`Metrics`](crate::coordinator::metrics::Metrics)
//!   export through it as a [`registry::Collector`], and the tuner /
//!   phase-GEMM engine feed counters into it directly.
//!
//! Naming scheme: dot-separated `subsystem.metric` keys
//! (`tune.candidates_measured`, `gemm.packed_calls`,
//! `serve.<model>.completed`); span names are `subsystem.operation`
//! (`gen.forward`, `layer.forward`, `conv.phase`, `train.step`,
//! `serve.batch`) with the executing lane (`direct`, `gemm/avx2`, …)
//! carried as a tag, never encoded into the name.

pub mod registry;
pub mod trace;
