//! Persisted tuning cache (DESIGN.md §Autotuning): JSON on disk via
//! [`util::json`](crate::util::json), keyed by `(layer shape, host
//! parallelism fingerprint, search-space worker bound)` so tuning pays
//! once per machine class and one file can hold verdicts from
//! differently-sized hosts — or differently-bounded searches — without
//! cross-contamination.
//!
//! Format (version 1, stable key order from `BTreeMap`):
//!
//! ```json
//! {"entries":{"n4k4p2ci512co256@cpu8w8":
//!     {"candidates":[
//!        {"seconds":0.0015,
//!         "strategy":{"axis":"phase-rows","formulation":"phase","workers":1}},
//!        {"seconds":null,
//!         "strategy":{"axis":"phase-rows","formulation":"phase-gemm","workers":1}}],
//!      "seconds":0.0012,
//!      "strategy":{"axis":"rows","formulation":"phase","workers":4}}},
//!  "version":1}
//! ```
//!
//! `candidates` records the full per-strategy measurement trace of the
//! search that produced the verdict (`seconds: null` = pruned by the
//! probe) — the CI smoke run asserts the searched space really
//! contained a measured `phase-gemm` candidate.  The field is optional
//! on load, so version-1 caches written before it exist keep working.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::conv::quant::Precision;
use crate::conv::ConvTransposeParams;
use crate::util::json::{self, Json};

use super::space::ExecStrategy;

/// Schema version of the on-disk format.
pub const CACHE_VERSION: usize = 1;

/// Host fingerprint baked into every key: tuned worker counts only
/// transfer between hosts with the same available parallelism, and —
/// since the microkernel axis (DESIGN.md §SIMD-Dispatch) — the same
/// active SIMD lane.  Scalar hosts keep the historic `cpu{n}` form so
/// their existing cache entries stay valid verbatim; vector hosts
/// fingerprint as `cpu{n}+{isa}` (e.g. `cpu8+avx2`), so verdicts
/// measured scalar-only correctly *miss* there and the layer re-tunes
/// over the wider space.  Keys are opaque strings: legacy `cpu{n}`
/// entries still load and coexist in the same file.
pub fn host_fingerprint() -> String {
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let isa = crate::conv::simd::Isa::active();
    if isa == crate::conv::simd::Isa::Scalar {
        format!("cpu{cores}")
    } else {
        format!("cpu{cores}+{}", isa.name())
    }
}

/// One cached verdict.
#[derive(Debug, Clone, PartialEq)]
pub struct CacheEntry {
    pub strategy: ExecStrategy,
    /// Best measured seconds when the verdict was recorded.
    pub seconds: f64,
    /// The search's per-candidate record (`None` = pruned); empty for
    /// entries written before the field existed.
    pub candidates: Vec<(ExecStrategy, Option<f64>)>,
}

/// The tuning cache: an in-memory map plus an optional backing file.
#[derive(Debug, Clone, Default)]
pub struct TuningCache {
    path: Option<PathBuf>,
    entries: BTreeMap<String, CacheEntry>,
}

impl TuningCache {
    /// A cache with no backing file ([`save`](Self::save) is a no-op).
    pub fn in_memory() -> TuningCache {
        TuningCache::default()
    }

    /// An empty cache backed by `path` (what [`load`](Self::load)
    /// returns for a missing file — the first run of a machine).
    pub fn backed(path: &Path) -> TuningCache {
        TuningCache {
            path: Some(path.to_path_buf()),
            entries: BTreeMap::new(),
        }
    }

    /// Open the cache at `path`.  A missing file is an empty cache; a
    /// malformed or version-mismatched one is an error (callers decide
    /// whether to re-tune or abort).
    pub fn load(path: &Path) -> anyhow::Result<TuningCache> {
        let mut cache = TuningCache::backed(path);
        if !path.exists() {
            return Ok(cache);
        }
        let doc = json::parse_file(path)?;
        let version = doc.get("version").and_then(Json::as_usize).unwrap_or(0);
        anyhow::ensure!(
            version == CACHE_VERSION,
            "tuning cache {}: unsupported version {version} (want {CACHE_VERSION})",
            path.display()
        );
        let Some(Json::Obj(entries)) = doc.get("entries") else {
            anyhow::bail!("tuning cache {}: missing 'entries' object", path.display());
        };
        for (key, v) in entries {
            let strategy = v.get("strategy").and_then(ExecStrategy::from_json);
            let seconds = v.get("seconds").and_then(Json::as_f64);
            let (Some(strategy), Some(seconds)) = (strategy, seconds) else {
                anyhow::bail!("tuning cache {}: malformed entry '{key}'", path.display());
            };
            // Optional measurement trace (absent in caches written
            // before the field existed); a malformed trace is an error,
            // not silently dropped data.
            let mut candidates = Vec::new();
            match v.get("candidates") {
                None => {}
                Some(Json::Arr(items)) => {
                    for c in items {
                        let s = c.get("strategy").and_then(ExecStrategy::from_json);
                        let Some(s) = s else {
                            anyhow::bail!(
                                "tuning cache {}: malformed candidate in '{key}'",
                                path.display()
                            );
                        };
                        let t = match c.get("seconds") {
                            Some(Json::Null) | None => None,
                            Some(other) => Some(other.as_f64().ok_or_else(|| {
                                anyhow::anyhow!(
                                    "tuning cache {}: non-numeric candidate seconds in '{key}'",
                                    path.display()
                                )
                            })?),
                        };
                        candidates.push((s, t));
                    }
                }
                Some(_) => anyhow::bail!(
                    "tuning cache {}: 'candidates' must be an array in '{key}'",
                    path.display()
                ),
            }
            cache.entries.insert(
                key.clone(),
                CacheEntry {
                    strategy,
                    seconds,
                    candidates,
                },
            );
        }
        Ok(cache)
    }

    /// Cache key: full layer geometry, the host fingerprint, and the
    /// search space's worker bound (`space_workers`) — so a verdict
    /// from a narrower space (`--workers 2`) can never shadow a
    /// full-space tune on the same host.  The measurement *budget* is
    /// deliberately not part of the key (it is a fidelity knob, not a
    /// different question); delete the cache file to re-tune at a
    /// higher budget.
    pub fn key(params: &ConvTransposeParams, space_workers: usize) -> String {
        Self::key_batch(params, space_workers, 1)
    }

    /// [`key`](Self::key) for a serving batch size: batch `> 1`
    /// verdicts answer a different question (fused batched lanes are
    /// in the space, the work per step is `N×`), so they get a `bN`
    /// suffix and can never shadow — or be shadowed by — single-image
    /// verdicts.  Batch 1 keeps the historic key, so existing cache
    /// files stay valid.
    pub fn key_batch(params: &ConvTransposeParams, space_workers: usize, batch: usize) -> String {
        Self::key_batch_at(params, space_workers, batch, Precision::F32)
    }

    /// [`key_batch`](Self::key_batch) for a precision-pinned search
    /// (`ukstc tune --precision`): quantized pins answer a different
    /// question (the GEMM candidates are the reduced-precision twins),
    /// so they get a `+{prec}` suffix following the fingerprint's
    /// `+{isa}` pattern.  An f32 pin keeps the historic key
    /// byte-for-byte — pre-precision cache files stay hits — and the
    /// `+` delimiter keeps the namespace disjoint from the
    /// digit-terminated `b{N}` and letter-terminated `bwd` suffixes.
    pub fn key_batch_at(
        params: &ConvTransposeParams,
        space_workers: usize,
        batch: usize,
        precision: Precision,
    ) -> String {
        let mut key = format!(
            "n{}k{}p{}ci{}co{}@{}w{}",
            params.n_in,
            params.n_k,
            params.padding,
            params.cin,
            params.cout,
            host_fingerprint(),
            space_workers
        );
        if batch > 1 {
            key.push_str(&format!("b{batch}"));
        }
        if precision.is_quantized() {
            key.push_str(&format!("+{}", precision.name()));
        }
        key
    }

    /// [`key`](Self::key) for a backward-pass verdict.  Backward
    /// execution searches a different space (no fused lanes, no
    /// per-element axis) over different work (data-grad + weight-grad),
    /// so it gets a `bwd` suffix — disjoint from the batch `b{N}`
    /// suffix, which is always digit-terminated — and can never shadow
    /// a forward verdict.
    pub fn key_backward(params: &ConvTransposeParams, space_workers: usize) -> String {
        format!("{}bwd", Self::key(params, space_workers))
    }

    pub fn get(&self, params: &ConvTransposeParams, space_workers: usize) -> Option<&CacheEntry> {
        self.get_batch(params, space_workers, 1)
    }

    /// Lookup under the backward key (see [`key_backward`](Self::key_backward)).
    pub fn get_backward(
        &self,
        params: &ConvTransposeParams,
        space_workers: usize,
    ) -> Option<&CacheEntry> {
        self.entries.get(&Self::key_backward(params, space_workers))
    }

    /// Lookup for a serving batch size (see [`key_batch`](Self::key_batch)).
    pub fn get_batch(
        &self,
        params: &ConvTransposeParams,
        space_workers: usize,
        batch: usize,
    ) -> Option<&CacheEntry> {
        self.get_batch_at(params, space_workers, batch, Precision::F32)
    }

    /// Lookup under the precision-pinned key (see
    /// [`key_batch_at`](Self::key_batch_at)).
    pub fn get_batch_at(
        &self,
        params: &ConvTransposeParams,
        space_workers: usize,
        batch: usize,
        precision: Precision,
    ) -> Option<&CacheEntry> {
        self.entries
            .get(&Self::key_batch_at(params, space_workers, batch, precision))
    }

    pub fn put(
        &mut self,
        params: &ConvTransposeParams,
        space_workers: usize,
        strategy: ExecStrategy,
        seconds: f64,
    ) {
        self.put_with_candidates(params, space_workers, strategy, seconds, &[]);
    }

    /// [`put`](Self::put) carrying the search's full per-candidate
    /// measurement trace (what `Tuner::tune_layer_cached` records).
    pub fn put_with_candidates(
        &mut self,
        params: &ConvTransposeParams,
        space_workers: usize,
        strategy: ExecStrategy,
        seconds: f64,
        candidates: &[(ExecStrategy, Option<f64>)],
    ) {
        self.put_with_candidates_batch(params, space_workers, 1, strategy, seconds, candidates);
    }

    /// [`put_with_candidates`](Self::put_with_candidates) under the
    /// batch-extended key (what `Tuner::tune_layer_cached` records for
    /// a batched search).
    pub fn put_with_candidates_batch(
        &mut self,
        params: &ConvTransposeParams,
        space_workers: usize,
        batch: usize,
        strategy: ExecStrategy,
        seconds: f64,
        candidates: &[(ExecStrategy, Option<f64>)],
    ) {
        self.put_with_candidates_batch_at(
            params,
            space_workers,
            batch,
            Precision::F32,
            strategy,
            seconds,
            candidates,
        );
    }

    /// [`put_with_candidates_batch`](Self::put_with_candidates_batch)
    /// under the precision-pinned key (what a `--precision` tune
    /// records).
    #[allow(clippy::too_many_arguments)]
    pub fn put_with_candidates_batch_at(
        &mut self,
        params: &ConvTransposeParams,
        space_workers: usize,
        batch: usize,
        precision: Precision,
        strategy: ExecStrategy,
        seconds: f64,
        candidates: &[(ExecStrategy, Option<f64>)],
    ) {
        self.entries.insert(
            Self::key_batch_at(params, space_workers, batch, precision),
            CacheEntry {
                strategy,
                seconds,
                candidates: candidates.to_vec(),
            },
        );
    }

    /// [`put_with_candidates`](Self::put_with_candidates) under the
    /// backward key (what `Tuner::tune_layer_backward_cached` records).
    pub fn put_backward_with_candidates(
        &mut self,
        params: &ConvTransposeParams,
        space_workers: usize,
        strategy: ExecStrategy,
        seconds: f64,
        candidates: &[(ExecStrategy, Option<f64>)],
    ) {
        self.entries.insert(
            Self::key_backward(params, space_workers),
            CacheEntry {
                strategy,
                seconds,
                candidates: candidates.to_vec(),
            },
        );
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Backing file, if any.
    pub fn path(&self) -> Option<&Path> {
        self.path.as_deref()
    }

    /// Serialize to the on-disk JSON document.
    pub fn to_json(&self) -> Json {
        let mut entries = BTreeMap::new();
        for (key, entry) in &self.entries {
            let mut e = BTreeMap::new();
            e.insert("strategy".to_string(), entry.strategy.to_json());
            e.insert("seconds".to_string(), Json::Num(entry.seconds));
            if !entry.candidates.is_empty() {
                let items = entry
                    .candidates
                    .iter()
                    .map(|(s, t)| {
                        let mut c = BTreeMap::new();
                        c.insert("strategy".to_string(), s.to_json());
                        c.insert(
                            "seconds".to_string(),
                            t.map(Json::Num).unwrap_or(Json::Null),
                        );
                        Json::Obj(c)
                    })
                    .collect();
                e.insert("candidates".to_string(), Json::Arr(items));
            }
            entries.insert(key.clone(), Json::Obj(e));
        }
        let mut doc = BTreeMap::new();
        doc.insert("version".to_string(), Json::Num(CACHE_VERSION as f64));
        doc.insert("entries".to_string(), Json::Obj(entries));
        Json::Obj(doc)
    }

    /// Persist to the backing file (no-op for in-memory caches).
    pub fn save(&self) -> anyhow::Result<()> {
        let Some(path) = &self.path else {
            return Ok(());
        };
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)
                    .map_err(|e| anyhow::anyhow!("creating {}: {e}", dir.display()))?;
            }
        }
        std::fs::write(path, self.to_json().to_string_compact())
            .map_err(|e| anyhow::anyhow!("writing {}: {e}", path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tune::space::ParAxis;

    fn params(n_in: usize) -> ConvTransposeParams {
        ConvTransposeParams::new(n_in, 4, 2, 8, 4)
    }

    #[test]
    fn key_carries_shape_fingerprint_and_space_bound() {
        let a = TuningCache::key(&params(4), 8);
        let b = TuningCache::key(&params(8), 8);
        assert_ne!(a, b);
        assert!(a.starts_with("n4k4p2ci8co4@"), "{a}");
        assert!(a.contains(&host_fingerprint()), "{a}");
        // A narrower search space is a different question.
        assert_ne!(TuningCache::key(&params(4), 2), a);
        assert!(a.ends_with("w8"), "{a}");
    }

    #[test]
    fn fingerprint_carries_isa_on_vector_hosts_only() {
        use crate::conv::simd::Isa;
        let fp = host_fingerprint();
        let cores = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        match Isa::active() {
            // Scalar hosts keep the historic form byte-for-byte — their
            // pre-SIMD cache entries must stay hits.
            Isa::Scalar => assert_eq!(fp, format!("cpu{cores}")),
            isa => assert_eq!(fp, format!("cpu{cores}+{}", isa.name())),
        }
        // The `+{isa}` suffix can't collide with the `w{n}` / `b{n}` /
        // `bwd` key suffixes, and stays parseable as an opaque key.
        assert!(!fp.contains('w') && !fp.contains('@'), "{fp}");
    }

    #[test]
    fn batch_keys_disjoint_from_single_image_keys() {
        // Batch 1 is exactly the historic key (old cache files stay
        // valid); batch > 1 is a distinct namespace per batch size.
        let single = TuningCache::key(&params(4), 8);
        assert_eq!(TuningCache::key_batch(&params(4), 8, 1), single);
        let b4 = TuningCache::key_batch(&params(4), 8, 4);
        assert!(b4.ends_with("w8b4"), "{b4}");
        assert_ne!(b4, single);
        assert_ne!(TuningCache::key_batch(&params(4), 8, 8), b4);
        let mut cache = TuningCache::in_memory();
        cache.put_with_candidates_batch(
            &params(4),
            8,
            4,
            ExecStrategy::serial_gemm().fused(),
            1e-4,
            &[],
        );
        assert!(cache.get(&params(4), 8).is_none(), "b4 must not shadow b1");
        let hit = cache.get_batch(&params(4), 8, 4).unwrap();
        assert_eq!(hit.strategy, ExecStrategy::serial_gemm().fused());
        assert!(cache.get_batch(&params(4), 8, 2).is_none());
    }

    #[test]
    fn backward_keys_disjoint_from_forward_and_batch_keys() {
        let fwd = TuningCache::key(&params(4), 8);
        let bwd = TuningCache::key_backward(&params(4), 8);
        assert!(bwd.ends_with("w8bwd"), "{bwd}");
        assert_ne!(bwd, fwd);
        // `bwd` is letter-terminated; batch suffixes are `b{digits}`,
        // so no batch size can collide with the backward namespace.
        for batch in [1, 2, 4, 8, 100] {
            assert_ne!(TuningCache::key_batch(&params(4), 8, batch), bwd);
        }
        let mut cache = TuningCache::in_memory();
        cache.put_backward_with_candidates(
            &params(4),
            8,
            ExecStrategy::serial_gemm(),
            2e-4,
            &[(ExecStrategy::serial(), Some(5e-4))],
        );
        assert!(cache.get(&params(4), 8).is_none(), "bwd must not shadow fwd");
        assert!(cache.get_batch(&params(4), 8, 4).is_none());
        let hit = cache.get_backward(&params(4), 8).unwrap();
        assert_eq!(hit.strategy, ExecStrategy::serial_gemm());
        assert_eq!(hit.candidates.len(), 1);
        // And the narrower-space backward question stays distinct.
        assert!(cache.get_backward(&params(4), 2).is_none());
    }

    #[test]
    fn precision_keys_disjoint_and_f32_legacy_stable() {
        // An f32 pin IS the historic key, byte for byte: caches written
        // before the precision axis existed keep hitting.
        let legacy = TuningCache::key(&params(4), 8);
        assert_eq!(
            TuningCache::key_batch_at(&params(4), 8, 1, Precision::F32),
            legacy
        );
        // Quantized pins suffix `+{prec}` after every other suffix.
        let f16 = TuningCache::key_batch_at(&params(4), 8, 1, Precision::F16);
        assert!(f16.ends_with("w8+f16"), "{f16}");
        let b4i8 = TuningCache::key_batch_at(&params(4), 8, 4, Precision::Int8);
        assert!(b4i8.ends_with("w8b4+int8"), "{b4i8}");
        // All four precisions (x batch) are pairwise disjoint.
        let mut keys: Vec<String> = Vec::new();
        for b in [1, 4] {
            for p in Precision::ALL {
                keys.push(TuningCache::key_batch_at(&params(4), 8, b, p));
            }
        }
        for (i, a) in keys.iter().enumerate() {
            for b in &keys[i + 1..] {
                assert_ne!(a, b);
            }
        }
        // Lookups honor the namespace: a quantized verdict never
        // shadows the f32 one, and vice versa.
        let mut cache = TuningCache::in_memory();
        let quant = ExecStrategy::serial_gemm().with_precision(Precision::F16);
        cache.put_with_candidates_batch_at(&params(4), 8, 1, Precision::F16, quant, 1e-4, &[]);
        assert!(cache.get(&params(4), 8).is_none(), "+f16 must not shadow f32");
        assert!(cache
            .get_batch_at(&params(4), 8, 1, Precision::Bf16)
            .is_none());
        let hit = cache.get_batch_at(&params(4), 8, 1, Precision::F16).unwrap();
        assert_eq!(hit.strategy, quant);
        // And the strategy's own JSON (with its precision field)
        // survives the file roundtrip under the suffixed key.
        let dir = std::env::temp_dir().join(format!("ukstc-cache-prec-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cache.json");
        let mut backed = TuningCache::backed(&path);
        backed.put_with_candidates_batch_at(
            &params(4),
            8,
            1,
            Precision::F16,
            quant,
            1e-4,
            &[(quant, Some(1e-4))],
        );
        backed.save().unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains(r#""precision":"f16""#), "{text}");
        assert!(text.contains("+f16"), "{text}");
        let reloaded = TuningCache::load(&path).unwrap();
        let entry = reloaded
            .get_batch_at(&params(4), 8, 1, Precision::F16)
            .unwrap();
        assert_eq!(entry.strategy, quant);
        assert_eq!(entry.strategy.precision, Precision::F16);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn put_get_roundtrip_in_memory() {
        let mut cache = TuningCache::in_memory();
        assert!(cache.is_empty());
        assert!(cache.get(&params(4), 4).is_none());
        let s = ExecStrategy::parallel(4, ParAxis::Rows);
        cache.put(&params(4), 4, s, 1.5e-3);
        let hit = cache.get(&params(4), 4).unwrap();
        assert_eq!(hit.strategy, s);
        assert_eq!(hit.seconds, 1.5e-3);
        assert_eq!(cache.len(), 1);
        assert!(cache.get(&params(8), 4).is_none());
        // A narrower-space verdict does not shadow the wider space.
        assert!(cache.get(&params(4), 2).is_none());
        // Overwrite is an update, not a duplicate.
        cache.put(&params(4), 4, ExecStrategy::serial(), 1.0e-3);
        assert_eq!(cache.len(), 1);
        assert_eq!(
            cache.get(&params(4), 4).unwrap().strategy,
            ExecStrategy::serial()
        );
        // In-memory save is a no-op that succeeds.
        assert!(cache.path().is_none());
        cache.save().unwrap();
    }

    #[test]
    fn json_document_roundtrips() {
        let mut cache = TuningCache::in_memory();
        cache.put(&params(4), 2, ExecStrategy::parallel(2, ParAxis::PhaseRows), 2e-4);
        cache.put(&params(8), 2, ExecStrategy::serial_per_element(), 7e-4);
        let text = cache.to_json().to_string_compact();
        let doc = crate::util::json::parse(&text).unwrap();
        assert_eq!(doc.get("version").and_then(Json::as_usize), Some(CACHE_VERSION));
        let entries = doc.get("entries").unwrap();
        let hit = entries.get(&TuningCache::key(&params(8), 2)).unwrap();
        assert_eq!(
            hit.get("strategy").and_then(ExecStrategy::from_json),
            Some(ExecStrategy::serial_per_element())
        );
        assert_eq!(hit.get("seconds").and_then(Json::as_f64), Some(7e-4));
        // put() without a trace writes no candidates field at all.
        assert!(hit.get("candidates").is_none());
    }

    #[test]
    fn candidate_trace_roundtrips_through_file() {
        let dir = std::env::temp_dir().join(format!("ukstc-cache-cand-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cache.json");
        let trace = vec![
            (ExecStrategy::serial(), Some(3e-4)),
            (ExecStrategy::serial_gemm(), Some(1e-4)),
            (ExecStrategy::per_element_parallel(2), None), // pruned
        ];
        let mut cache = TuningCache::backed(&path);
        cache.put_with_candidates(&params(4), 2, ExecStrategy::serial_gemm(), 1e-4, &trace);
        cache.save().unwrap();
        // The on-disk text names the phase-gemm formulation — what the
        // CI smoke assertion greps for.
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains(r#""formulation":"phase-gemm""#), "{text}");
        assert!(text.contains("null"), "pruned candidate must persist as null");
        let reloaded = TuningCache::load(&path).unwrap();
        let entry = reloaded.get(&params(4), 2).unwrap();
        assert_eq!(entry.strategy, ExecStrategy::serial_gemm());
        assert_eq!(entry.candidates, trace);
        std::fs::remove_file(&path).ok();
    }
}
