//! Per-layer strategy search (DESIGN.md §Autotuning).
//!
//! One [`Tuner`] owns a search space and a budget; [`Tuner::tune_layer`]
//! walks the space for one layer shape, seeding the incumbent with the
//! conventional serial default (always element zero of the space, never
//! pruned) and letting the [`Measurer`](super::measure::Measurer) prune
//! candidates that can't win.  [`Tuner::tune_layer_cached`] goes
//! through the [`TuningCache`] so a machine pays the search once per
//! layer shape.

use crate::conv::plan::ConvTransposePlan;
use crate::conv::quant::Precision;
use crate::conv::simd::Isa;
use crate::conv::ConvTransposeParams;

use super::cache::TuningCache;
use super::measure::{MeasureBudget, Measurer};
use super::space::{
    backward_search_space, search_space, search_space_batch, ExecStrategy, Formulation,
};

/// The tuning verdict for one layer shape.
#[derive(Debug, Clone)]
pub struct TunedPlan {
    /// The layer geometry the verdict applies to.
    pub params: ConvTransposeParams,
    /// The winning strategy.
    pub strategy: ExecStrategy,
    /// Best measured seconds for the winner (the cached figure on a
    /// cache hit).
    pub best_seconds: f64,
    /// Every candidate with its measurement (`None` = pruned).  Empty
    /// on a cache hit — nothing was measured.
    pub candidates: Vec<(ExecStrategy, Option<f64>)>,
    /// True when the verdict came from the tuning cache.
    pub cached: bool,
}

impl TunedPlan {
    /// Candidates that were actually timed (not pruned).
    pub fn measured(&self) -> usize {
        self.candidates.iter().filter(|(_, t)| t.is_some()).count()
    }

    /// Candidates the probe pruned.
    pub fn pruned(&self) -> usize {
        self.candidates.len() - self.measured()
    }

    /// Seconds of the serial phase-decomposed default, when it was
    /// among the measured candidates — the "hand-picked" baseline the
    /// tables compare against.
    pub fn serial_seconds(&self) -> Option<f64> {
        self.candidates
            .iter()
            .find(|(s, _)| *s == ExecStrategy::serial())
            .and_then(|(_, t)| *t)
    }
}

/// Searches the execution-strategy space, one layer shape at a time.
#[derive(Debug, Clone)]
pub struct Tuner {
    /// Candidate strategies, searched in order (element zero seeds the
    /// incumbent).
    pub space: Vec<ExecStrategy>,
    /// Per-candidate measurement budget.
    pub budget: MeasureBudget,
    /// Serving batch size every candidate is measured at (1 = the
    /// historic single-image search).  Batched tuners search the
    /// extended space — per-latent *and* fused batched variants — and
    /// their verdicts live under the batch-suffixed cache key
    /// (DESIGN.md §Batched-Execution).
    pub batch: usize,
    /// When set (`ukstc tune --isa`), GEMM candidates are restricted to
    /// this microkernel lane — forward, batched, and backward spaces
    /// alike.  Direct lanes always survive the pin, so element zero
    /// (the serial seed) is never filtered out.
    pub isa_pin: Option<Isa>,
    /// Storage precision of the searched GEMM candidates (`ukstc tune
    /// --precision`).  `F32` (the default) is the historic search;
    /// quantized pins swap every PhaseGemm candidate for its
    /// reduced-precision twin and cache the verdict under the
    /// `+{prec}`-suffixed key.  Forward-only: the backward space has no
    /// quantized dispatch, so [`tune_layer_backward`](Self::tune_layer_backward)
    /// always searches f32.
    pub precision: Precision,
}

impl Tuner {
    /// Space bounded by `max_workers`, default budget.
    pub fn new(max_workers: usize) -> Tuner {
        Tuner {
            space: search_space(max_workers),
            budget: MeasureBudget::default(),
            batch: 1,
            isa_pin: None,
            precision: Precision::F32,
        }
    }

    /// A tuner that searches batched strategies for serving batch size
    /// `batch` (`ukstc tune --batch N`): the space gains the fused
    /// batched lanes, every candidate is timed serving a whole batch,
    /// and the verdict is cached under the batch-extended key.
    pub fn for_batch(max_workers: usize, batch: usize) -> Tuner {
        let batch = batch.max(1);
        Tuner {
            space: search_space_batch(max_workers, batch),
            budget: MeasureBudget::default(),
            batch,
            isa_pin: None,
            precision: Precision::F32,
        }
    }

    pub fn with_budget(mut self, budget: MeasureBudget) -> Tuner {
        self.budget = budget;
        self
    }

    /// Pin the GEMM candidates to one microkernel lane (`ukstc tune
    /// --isa scalar|best`): PhaseGemm strategies whose [`Isa`] differs
    /// from `isa` are dropped from the forward space now and from the
    /// backward space at [`tune_layer_backward`](Self::tune_layer_backward)
    /// time.  Non-GEMM strategies are untouched — in particular the
    /// serial direct seed at element zero — so a pin to a lane the
    /// space doesn't carry degrades to a direct-only search rather
    /// than an empty one.
    pub fn pin_isa(mut self, isa: Isa) -> Tuner {
        self.space
            .retain(|s| s.formulation != Formulation::PhaseGemm || s.isa == isa);
        self.isa_pin = Some(isa);
        self
    }

    /// Pin the GEMM candidates' storage precision (`ukstc tune
    /// --precision f16|bf16|int8`): every PhaseGemm candidate in the
    /// forward space is replaced by its `with_precision` twin, so the
    /// search measures the widening kernels against the untouched
    /// direct lanes and the verdict answers "best strategy *at this
    /// precision*".  Direct candidates are normalized to f32 by
    /// `with_precision`, i.e. unchanged — the serial seed at element
    /// zero survives.  An `F32` pin is the identity, keeping the
    /// historic cache key valid.
    pub fn pin_precision(mut self, precision: Precision) -> Tuner {
        for s in &mut self.space {
            *s = s.with_precision(precision);
        }
        self.precision = precision;
        self
    }

    /// The space's worker bound — part of the cache key, so verdicts
    /// from differently-bounded searches never shadow each other.
    pub fn space_workers(&self) -> usize {
        self.space.iter().map(|s| s.workers).max().unwrap_or(1)
    }

    /// Exhaustive search with incumbent pruning over one layer's plan.
    /// Every candidate is timed at the tuner's serving batch size
    /// ([`Self::batch`]; 1 = the single-image measurement).
    pub fn tune_layer<M: Measurer>(&self, plan: &ConvTransposePlan, measurer: &mut M) -> TunedPlan {
        assert!(!self.space.is_empty(), "tuner: empty search space");
        let mut best: Option<(ExecStrategy, f64)> = None;
        let mut candidates = Vec::with_capacity(self.space.len());
        for s in &self.space {
            let incumbent = best.as_ref().map(|b| b.1);
            let t = measurer.time_strategy_batch(plan, s, self.batch, incumbent);
            if let Some(sec) = t {
                let improves = match &best {
                    None => true,
                    Some((_, b)) => sec < *b,
                };
                if improves {
                    best = Some((*s, sec));
                }
            }
            candidates.push((*s, t));
        }
        let (strategy, best_seconds) =
            best.expect("tuner: no candidate measured (first is never pruned)");
        TunedPlan {
            params: *plan.params(),
            strategy,
            best_seconds,
            candidates,
            cached: false,
        }
    }

    /// [`tune_layer`](Self::tune_layer) through the cache: a hit
    /// returns the stored verdict without any measurement; a miss
    /// searches and stores the winner.
    pub fn tune_layer_cached<M: Measurer>(
        &self,
        plan: &ConvTransposePlan,
        cache: &mut TuningCache,
        measurer: &mut M,
    ) -> TunedPlan {
        if let Some(entry) =
            cache.get_batch_at(plan.params(), self.space_workers(), self.batch, self.precision)
        {
            crate::obs::registry::counter("tune.cache_hits").inc();
            return TunedPlan {
                params: *plan.params(),
                strategy: entry.strategy,
                best_seconds: entry.seconds,
                candidates: Vec::new(),
                cached: true,
            };
        }
        crate::obs::registry::counter("tune.cache_misses").inc();
        let tuned = self.tune_layer(plan, measurer);
        cache.put_with_candidates_batch_at(
            plan.params(),
            self.space_workers(),
            self.batch,
            self.precision,
            tuned.strategy,
            tuned.best_seconds,
            &tuned.candidates,
        );
        tuned
    }

    /// Exhaustive search over the *backward* strategy space (DESIGN.md
    /// §Backward-Execution): direct, phase-GEMM, and phase-row-parallel
    /// data-grad lanes, each timed running a full backward step
    /// (data-grad + weight-grad) through
    /// [`Measurer::time_backward`].  The space is
    /// [`backward_search_space`] bounded by the same worker cap as the
    /// forward space, so forward and backward verdicts share one cache
    /// file under disjoint keys.
    pub fn tune_layer_backward<M: Measurer>(
        &self,
        plan: &ConvTransposePlan,
        measurer: &mut M,
    ) -> TunedPlan {
        let mut space = backward_search_space(self.space_workers());
        if let Some(isa) = self.isa_pin {
            space.retain(|s| s.formulation != Formulation::PhaseGemm || s.isa == isa);
        }
        assert!(!space.is_empty(), "tuner: empty backward search space");
        let mut best: Option<(ExecStrategy, f64)> = None;
        let mut candidates = Vec::with_capacity(space.len());
        for s in &space {
            let incumbent = best.as_ref().map(|b| b.1);
            let t = measurer.time_backward(plan, s, incumbent);
            if let Some(sec) = t {
                let improves = match &best {
                    None => true,
                    Some((_, b)) => sec < *b,
                };
                if improves {
                    best = Some((*s, sec));
                }
            }
            candidates.push((*s, t));
        }
        let (strategy, best_seconds) =
            best.expect("tuner: no backward candidate measured (first is never pruned)");
        TunedPlan {
            params: *plan.params(),
            strategy,
            best_seconds,
            candidates,
            cached: false,
        }
    }

    /// [`tune_layer_backward`](Self::tune_layer_backward) through the
    /// cache's `bwd`-suffixed key namespace.
    pub fn tune_layer_backward_cached<M: Measurer>(
        &self,
        plan: &ConvTransposePlan,
        cache: &mut TuningCache,
        measurer: &mut M,
    ) -> TunedPlan {
        if let Some(entry) = cache.get_backward(plan.params(), self.space_workers()) {
            crate::obs::registry::counter("tune.cache_hits").inc();
            return TunedPlan {
                params: *plan.params(),
                strategy: entry.strategy,
                best_seconds: entry.seconds,
                candidates: Vec::new(),
                cached: true,
            };
        }
        crate::obs::registry::counter("tune.cache_misses").inc();
        let tuned = self.tune_layer_backward(plan, measurer);
        cache.put_backward_with_candidates(
            plan.params(),
            self.space_workers(),
            tuned.strategy,
            tuned.best_seconds,
            &tuned.candidates,
        );
        tuned
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Kernel;
    use crate::tune::space::ParAxis;
    use crate::util::rng::Rng;

    fn plan() -> ConvTransposePlan {
        let mut rng = Rng::seeded(0xD00D);
        let k = Kernel::random(4, 2, 2, &mut rng);
        ConvTransposePlan::new(ConvTransposeParams::new(4, 4, 2, 2, 2), &k)
    }

    /// Scripted measurer: fixed per-strategy times, records the
    /// incumbents it was offered, prunes when told.
    struct Scripted {
        incumbents: Vec<Option<f64>>,
        winner: ExecStrategy,
    }

    impl Measurer for Scripted {
        fn time_strategy(
            &mut self,
            _plan: &ConvTransposePlan,
            s: &ExecStrategy,
            incumbent: Option<f64>,
        ) -> Option<f64> {
            self.incumbents.push(incumbent);
            if s.workers > 2 {
                return None; // "pruned"
            }
            Some(if *s == self.winner { 0.5 } else { 1.0 + self.incumbents.len() as f64 * 0.01 })
        }
    }

    #[test]
    fn picks_argmin_and_threads_incumbent() {
        let winner = ExecStrategy::parallel(2, ParAxis::Rows);
        let mut m = Scripted {
            incumbents: Vec::new(),
            winner,
        };
        let tuner = Tuner::new(4);
        assert_eq!(tuner.space_workers(), 4);
        let tuned = tuner.tune_layer(&plan(), &mut m);
        assert_eq!(tuned.strategy, winner);
        assert_eq!(tuned.best_seconds, 0.5);
        assert!(!tuned.cached);
        assert_eq!(tuned.candidates.len(), tuner.space.len());
        // First candidate saw no incumbent; later ones saw the running best.
        assert_eq!(m.incumbents[0], None);
        assert!(m.incumbents[1].is_some());
        assert_eq!(*m.incumbents.last().unwrap(), Some(0.5));
        // Pruned candidates (workers > 2) are recorded as None.
        assert!(tuned.pruned() > 0);
        assert_eq!(tuned.measured() + tuned.pruned(), tuned.candidates.len());
        assert!(tuned.serial_seconds().is_some());
    }

    #[test]
    fn batched_tuner_searches_fused_lanes_and_keys_by_batch() {
        // The batched space includes fused candidates; verdicts cache
        // under the batch-suffixed key, disjoint from single-image ones.
        let winner = ExecStrategy::serial_gemm().fused();
        let mut m = Scripted {
            incumbents: Vec::new(),
            winner,
        };
        let tuner = Tuner::for_batch(2, 4);
        assert_eq!(tuner.batch, 4);
        assert!(tuner.space.contains(&winner));
        assert_eq!(tuner.space[0], ExecStrategy::serial());
        let mut cache = TuningCache::in_memory();
        let tuned = tuner.tune_layer_cached(&plan(), &mut cache, &mut m);
        assert_eq!(tuned.strategy, winner);
        // The single-image tuner must miss on the batched verdict.
        let single = Tuner::new(2);
        assert!(cache.get(plan().params(), single.space_workers()).is_none());
        assert!(cache
            .get_batch(plan().params(), tuner.space_workers(), 4)
            .is_some());
        // And the batched tuner hits on a rerun without measuring.
        let timed = m.incumbents.len();
        let again = tuner.tune_layer_cached(&plan(), &mut cache, &mut m);
        assert!(again.cached);
        assert_eq!(m.incumbents.len(), timed);
    }

    #[test]
    fn backward_tuner_searches_backward_space_and_keys_by_bwd() {
        // The Scripted measurer implements only `time_strategy`; the
        // defaulted `Measurer::time_backward` routes through it, so the
        // backward search exercises the same pruning contract.
        let winner = ExecStrategy::serial_gemm();
        let mut m = Scripted {
            incumbents: Vec::new(),
            winner,
        };
        let tuner = Tuner::new(2);
        let tuned = tuner.tune_layer_backward(&plan(), &mut m);
        assert_eq!(tuned.strategy, winner);
        assert_eq!(tuned.best_seconds, 0.5);
        assert_eq!(
            tuned.candidates.len(),
            backward_search_space(2).len(),
            "every backward candidate must be visited"
        );
        assert_eq!(tuned.candidates[0].0, ExecStrategy::serial());
        assert_eq!(m.incumbents[0], None, "serial seeds the incumbent");
        assert!(tuned.serial_seconds().is_some());
        // Cached roundtrip lives in the bwd namespace: the forward
        // lookup must miss, the backward rerun must hit.
        let mut cache = TuningCache::in_memory();
        let first = tuner.tune_layer_backward_cached(&plan(), &mut cache, &mut m);
        assert!(!first.cached);
        assert!(cache.get(plan().params(), tuner.space_workers()).is_none());
        assert!(cache
            .get_backward(plan().params(), tuner.space_workers())
            .is_some());
        let timed = m.incumbents.len();
        let again = tuner.tune_layer_backward_cached(&plan(), &mut cache, &mut m);
        assert!(again.cached);
        assert_eq!(m.incumbents.len(), timed, "hit must not measure");
        assert_eq!(again.strategy, first.strategy);
    }

    #[test]
    fn isa_pin_keeps_direct_lanes_and_matching_gemm() {
        // Every supported lane can be pinned; the pin filters only
        // GEMM candidates and never touches the serial seed or the
        // space's worker bound.
        for isa in Isa::supported() {
            let tuner = Tuner::new(4).pin_isa(isa);
            assert_eq!(tuner.isa_pin, Some(isa));
            assert_eq!(tuner.space[0], ExecStrategy::serial(), "seed survives the pin");
            assert!(tuner
                .space
                .iter()
                .all(|s| s.formulation != Formulation::PhaseGemm || s.isa == isa));
            assert!(
                tuner
                    .space
                    .iter()
                    .any(|s| s.formulation == Formulation::PhaseGemm && s.isa == isa),
                "pin to {} must keep that lane's GEMM candidates",
                isa.name()
            );
            assert_eq!(tuner.space_workers(), 4, "direct parallel lanes keep the bound");
            // The backward search honors the same pin: every visited
            // GEMM candidate carries the pinned lane.
            let mut m = Scripted {
                incumbents: Vec::new(),
                winner: ExecStrategy::serial(),
            };
            let tuned = tuner.tune_layer_backward(&plan(), &mut m);
            assert_eq!(tuned.candidates[0].0, ExecStrategy::serial());
            assert!(tuned
                .candidates
                .iter()
                .all(|(s, _)| s.formulation != Formulation::PhaseGemm || s.isa == isa));
        }
        // Pinning scalar always leaves at least the serial GEMM lane:
        // the space carries a scalar-pinned twin on vector hosts and
        // the native serial GEMM on scalar hosts.
        let scalar = Tuner::new(2).pin_isa(Isa::Scalar);
        assert!(scalar
            .space
            .contains(&ExecStrategy::serial_gemm().with_isa(Isa::Scalar)));
    }

    #[test]
    fn precision_pin_quantizes_gemm_lanes_and_keys_by_precision() {
        // The pin swaps PhaseGemm candidates for their quantized twins
        // and leaves direct lanes (and the serial seed) untouched.
        let tuner = Tuner::new(4).pin_precision(Precision::F16);
        assert_eq!(tuner.precision, Precision::F16);
        assert_eq!(tuner.space[0], ExecStrategy::serial(), "seed survives the pin");
        assert_eq!(tuner.space.len(), Tuner::new(4).space.len(), "pin is a map, not a filter");
        for s in &tuner.space {
            match s.formulation {
                Formulation::PhaseGemm => assert_eq!(s.precision, Precision::F16),
                _ => assert_eq!(s.precision, Precision::F32),
            }
        }
        assert!(tuner
            .space
            .iter()
            .any(|s| s.formulation == Formulation::PhaseGemm));
        assert_eq!(tuner.space_workers(), 4);
        // An f32 pin is the identity.
        assert_eq!(Tuner::new(4).pin_precision(Precision::F32).space, Tuner::new(4).space);
        // Verdicts live under the +f16 key: the unpinned tuner misses,
        // the pinned one hits without re-measuring.
        let winner = ExecStrategy::serial_gemm().with_precision(Precision::F16);
        let mut m = Scripted {
            incumbents: Vec::new(),
            winner,
        };
        let mut cache = TuningCache::in_memory();
        let tuned = tuner.tune_layer_cached(&plan(), &mut cache, &mut m);
        assert_eq!(tuned.strategy, winner);
        assert!(cache.get(plan().params(), tuner.space_workers()).is_none());
        assert!(cache
            .get_batch_at(plan().params(), tuner.space_workers(), 1, Precision::F16)
            .is_some());
        let timed = m.incumbents.len();
        let again = tuner.tune_layer_cached(&plan(), &mut cache, &mut m);
        assert!(again.cached);
        assert_eq!(m.incumbents.len(), timed, "hit must not measure");
        // The backward search stays f32 even under a quantized pin —
        // the backward lanes have no quantized dispatch to measure.
        let bwd = tuner.tune_layer_backward(&plan(), &mut m);
        assert!(bwd.candidates.iter().all(|(s, _)| s.precision == Precision::F32));
    }

    #[test]
    fn cached_roundtrip_in_memory() {
        let winner = ExecStrategy::serial_per_element();
        let mut m = Scripted {
            incumbents: Vec::new(),
            winner,
        };
        let tuner = Tuner::new(2);
        let mut cache = TuningCache::in_memory();
        let first = tuner.tune_layer_cached(&plan(), &mut cache, &mut m);
        let timed_after_first = m.incumbents.len();
        let second = tuner.tune_layer_cached(&plan(), &mut cache, &mut m);
        assert!(!first.cached && second.cached);
        assert_eq!(m.incumbents.len(), timed_after_first, "hit must not measure");
        assert_eq!(second.strategy, first.strategy);
        assert_eq!(second.best_seconds, first.best_seconds);
        assert!(second.candidates.is_empty());
    }
}
