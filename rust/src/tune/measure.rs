//! Candidate measurement: warmup + trials with early pruning
//! (DESIGN.md §Autotuning).
//!
//! Timing goes through [`crate::util::timing::measure_for`], so a
//! candidate gets an adaptive number of trials (until `min_time_s` of
//! recorded samples or `max_iters`, whichever first); budgets below
//! three iterations fall back to the fixed-count
//! [`crate::util::timing::measure`] so a CI `--max-iters 1` smoke run
//! really is one trial.  Before spending the full budget, one probe
//! run prunes candidates already [`PRUNE_FACTOR`]× slower than the
//! incumbent — on a big search space most losers cost one iteration.
//!
//! [`Measurer`] is a trait so the cache tests can inject a counting
//! fake and prove that a cache hit performs **zero** measurements.

use crate::conv::plan::{ConvTransposePlan, Scratch};
use crate::tensor::{Feature, FeatureBatch};
use crate::util::rng::Rng;
use crate::util::timing;

use super::space::ExecStrategy;

/// Prune a candidate whose probe run exceeds this multiple of the
/// incumbent's best time.
pub const PRUNE_FACTOR: f64 = 2.0;

/// Measurement budget for one candidate strategy.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MeasureBudget {
    /// Unrecorded warmup iterations per candidate.
    pub warmup: usize,
    /// Keep sampling until this much recorded time (seconds) ...
    pub min_time_s: f64,
    /// ... or this many recorded iterations, whichever comes first.
    pub max_iters: usize,
}

impl Default for MeasureBudget {
    fn default() -> Self {
        MeasureBudget {
            warmup: 1,
            min_time_s: 0.02,
            max_iters: 25,
        }
    }
}

impl MeasureBudget {
    /// One-trial budget (`ukstc tune --warmup 0 --max-iters 1
    /// --min-time-ms 0`), used by the CI smoke run.
    pub fn quick() -> Self {
        MeasureBudget {
            warmup: 0,
            min_time_s: 0.0,
            max_iters: 1,
        }
    }
}

/// Times one `(plan, strategy)` candidate.
pub trait Measurer {
    /// Best observed seconds for one execution of `plan` under
    /// `strategy`, or `None` if the candidate was pruned against
    /// `incumbent` (the best seconds of any candidate so far for this
    /// layer).  The first candidate of a search is passed
    /// `incumbent == None` and therefore can never be pruned.
    fn time_strategy(
        &mut self,
        plan: &ConvTransposePlan,
        strategy: &ExecStrategy,
        incumbent: Option<f64>,
    ) -> Option<f64>;

    /// Best observed seconds for serving one whole batch of `batch`
    /// inputs under `strategy` — fused
    /// (`ConvTransposePlan::run_batch_with`) when the strategy says so,
    /// a per-latent loop otherwise — so the batched tuner compares the
    /// two dispatches on the same footing (DESIGN.md
    /// §Batched-Execution).  Defaults to the single-image measurement
    /// so scripted test measurers need not care about batching.
    fn time_strategy_batch(
        &mut self,
        plan: &ConvTransposePlan,
        strategy: &ExecStrategy,
        batch: usize,
        incumbent: Option<f64>,
    ) -> Option<f64> {
        let _ = batch;
        self.time_strategy(plan, strategy, incumbent)
    }

    /// Best observed seconds for one **training-direction** step of
    /// `plan` under `strategy`: data-grad through the strategy's
    /// backward lane plus the weight-grad phase GEMM (DESIGN.md
    /// §Backward-Execution).  Defaults to the forward measurement so
    /// direction-oblivious test measurers keep working unchanged;
    /// [`WallClockMeasurer`] overrides it with a real backward timing.
    fn time_backward(
        &mut self,
        plan: &ConvTransposePlan,
        strategy: &ExecStrategy,
        incumbent: Option<f64>,
    ) -> Option<f64> {
        self.time_strategy(plan, strategy, incumbent)
    }
}

/// Wall-clock [`Measurer`]: deterministic random input per layer
/// shape, warm scratch + output reused across the timed iterations
/// (the steady-state serving shape the strategies will actually run
/// in), probe-based pruning (optional).
#[derive(Debug, Clone, Copy)]
pub struct WallClockMeasurer {
    pub budget: MeasureBudget,
    /// Probe-prune candidates [`PRUNE_FACTOR`]× slower than the
    /// incumbent (the default).  Disabled by
    /// [`without_pruning`](Self::without_pruning) when every candidate
    /// must end up with a real measurement — e.g. the CI smoke run,
    /// which asserts the persisted trace contains a *measured*
    /// phase-gemm candidate and must not depend on one noisy probe
    /// sample.
    pub prune: bool,
}

impl WallClockMeasurer {
    pub fn new(budget: MeasureBudget) -> WallClockMeasurer {
        // Spawn the persistent kernel pool now: with `warmup: 0`
        // budgets the pruning probe must not charge the first parallel
        // candidate for one-time thread startup that steady-state
        // serving never pays.
        crate::util::threadpool::shared_pool();
        WallClockMeasurer { budget, prune: true }
    }

    /// Measure every candidate to completion — no probe pruning.
    pub fn without_pruning(mut self) -> WallClockMeasurer {
        self.prune = false;
        self
    }

    /// Warmup + probe-prune + budgeted trials around one execution
    /// closure — the measurement protocol shared by the single-image
    /// and batched candidates.
    fn run_budgeted(&self, incumbent: Option<f64>, mut step: impl FnMut() -> f32) -> Option<f64> {
        for _ in 0..self.budget.warmup {
            step();
        }
        // One probe run, then prune hopeless candidates before spending
        // the full trial budget on them.
        let (probe, _) = timing::time_once(&mut step);
        if self.prune {
            if let Some(best) = incumbent {
                if probe > PRUNE_FACTOR * best {
                    crate::obs::registry::counter("tune.candidates_pruned").inc();
                    return None;
                }
            }
        }
        crate::obs::registry::counter("tune.candidates_measured").inc();
        let b = self.budget;
        let m = if b.max_iters < 3 {
            // measure_for insists on ≥3 samples; honor 1/2-trial budgets.
            timing::measure(0, b.max_iters.max(1), &mut step)
        } else {
            timing::measure_for(0, b.min_time_s, b.max_iters, &mut step)
        };
        Some(m.best().min(probe))
    }
}

impl Measurer for WallClockMeasurer {
    fn time_strategy(
        &mut self,
        plan: &ConvTransposePlan,
        strategy: &ExecStrategy,
        incumbent: Option<f64>,
    ) -> Option<f64> {
        let p = *plan.params();
        // Deterministic per shape: candidates for one layer all see the
        // same input (the kernels are data-independent, but determinism
        // keeps reruns comparable).
        let mut rng = Rng::seeded(
            0x7EA5 ^ ((p.n_in as u64) << 16) ^ ((p.cin as u64) << 8) ^ (p.cout as u64),
        );
        let x = Feature::random(p.n_in, p.n_in, p.cin, &mut rng);
        let mut scratch = Scratch::for_plan(plan);
        let mut out = plan.new_output();
        self.run_budgeted(incumbent, || {
            plan.run_with(strategy, &x, &mut scratch, &mut out);
            out.data[0]
        })
    }

    /// Batched candidate: one timed step serves the whole `batch` —
    /// fused through `run_batch_with` when the strategy says so, as a
    /// per-latent loop otherwise — so fused and per-latent variants of
    /// the same lane compete on identical work.
    fn time_strategy_batch(
        &mut self,
        plan: &ConvTransposePlan,
        strategy: &ExecStrategy,
        batch: usize,
        incumbent: Option<f64>,
    ) -> Option<f64> {
        if batch <= 1 {
            return self.time_strategy(plan, strategy, incumbent);
        }
        let p = *plan.params();
        let mut rng = Rng::seeded(
            0x7EA5
                ^ ((batch as u64) << 32)
                ^ ((p.n_in as u64) << 16)
                ^ ((p.cin as u64) << 8)
                ^ (p.cout as u64),
        );
        let xb = FeatureBatch::random(batch, p.n_in, p.n_in, p.cin, &mut rng);
        if strategy.fused {
            let mut scratch = Scratch::with_floats(plan.scratch_floats_for_batch(strategy, batch));
            let mut out = plan.new_batch_output(batch);
            self.run_budgeted(incumbent, || {
                plan.run_batch_with(strategy, &xb, &mut scratch, &mut out);
                out.data[0]
            })
        } else {
            let xs: Vec<Feature> = (0..batch).map(|i| xb.feature(i)).collect();
            let mut scratch = Scratch::for_plan(plan);
            let mut out = plan.new_output();
            self.run_budgeted(incumbent, || {
                for x in &xs {
                    plan.run_with(strategy, x, &mut scratch, &mut out);
                }
                out.data[0]
            })
        }
    }

    /// Backward candidate: one timed step is a full training-direction
    /// gradient — both gradients through the **fused** backward lane
    /// ([`ConvTransposePlan::run_backward_with`]), which extracts each
    /// `dy` phase once and shares it between the weight-grad GEMM and
    /// the strategy's data-grad lane — over a deterministic dy, through
    /// a warm arena sized to the backward peak (the steady state a
    /// `TrainStep` runs in).
    fn time_backward(
        &mut self,
        plan: &ConvTransposePlan,
        strategy: &ExecStrategy,
        incumbent: Option<f64>,
    ) -> Option<f64> {
        let p = *plan.params();
        let mut rng = Rng::seeded(
            0x7EA5
                ^ (0xB0D << 40)
                ^ ((p.n_in as u64) << 16)
                ^ ((p.cin as u64) << 8)
                ^ (p.cout as u64),
        );
        let ho = plan.out_size();
        let x = Feature::random(p.n_in, p.n_in, p.cin, &mut rng);
        let dy = Feature::random(ho, ho, p.cout, &mut rng);
        let mut scratch = Scratch::with_floats(plan.peak_scratch_floats_backward());
        let mut dx = plan.new_input_grad();
        let mut dk = plan.new_kernel_grad();
        self.run_budgeted(incumbent, || {
            plan.run_backward_with(strategy, &x, &dy, &mut scratch, &mut dx, &mut dk);
            dx.data[0] + dk.data[0]
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::ConvTransposeParams;
    use crate::tensor::Kernel;

    fn plan() -> ConvTransposePlan {
        let mut rng = Rng::seeded(0xBEEF);
        let k = Kernel::random(4, 8, 8, &mut rng);
        ConvTransposePlan::new(ConvTransposeParams::new(16, 4, 2, 8, 8), &k)
    }

    #[test]
    fn measures_first_candidate_without_incumbent() {
        let plan = plan();
        let mut m = WallClockMeasurer::new(MeasureBudget::quick());
        let t = m.time_strategy(&plan, &ExecStrategy::serial(), None);
        assert!(t.is_some());
        assert!(t.unwrap() >= 0.0);
    }

    #[test]
    fn prunes_against_unbeatable_incumbent() {
        // A 16×16×8→8 conv takes far longer than 2 × 1 femtosecond, so
        // the probe must prune.
        let plan = plan();
        let mut m = WallClockMeasurer::new(MeasureBudget::quick());
        let t = m.time_strategy(&plan, &ExecStrategy::serial_per_element(), Some(1e-15));
        assert_eq!(t, None);
    }

    #[test]
    fn without_pruning_measures_hopeless_candidates() {
        // The CI smoke run relies on this: with pruning off, even a
        // candidate that would lose the probe by far gets a real
        // measurement.
        let plan = plan();
        let mut m = WallClockMeasurer::new(MeasureBudget::quick()).without_pruning();
        let t = m.time_strategy(&plan, &ExecStrategy::serial_per_element(), Some(1e-15));
        assert!(t.is_some());
    }

    #[test]
    fn generous_incumbent_not_pruned() {
        let plan = plan();
        let mut m = WallClockMeasurer::new(MeasureBudget::quick());
        let t = m.time_strategy(&plan, &ExecStrategy::serial(), Some(1e9));
        assert!(t.is_some());
    }

    #[test]
    fn batched_measurement_times_fused_and_per_latent_candidates() {
        let plan = plan();
        let mut m = WallClockMeasurer::new(MeasureBudget::quick());
        for s in [
            ExecStrategy::serial(),                 // per-latent loop
            ExecStrategy::serial_gemm().fused(),    // fused stacked GEMM
            ExecStrategy::gemm_parallel(2).fused(), // fused row-parallel
            ExecStrategy::parallel(2, crate::tune::space::ParAxis::PhaseRows).fused(),
        ] {
            let t = m.time_strategy_batch(&plan, &s, 4, None);
            assert!(t.is_some(), "{} not measured", s.name());
            assert!(t.unwrap() >= 0.0);
        }
        // Batch 1 delegates to the single-image measurement.
        assert!(m
            .time_strategy_batch(&plan, &ExecStrategy::serial(), 1, None)
            .is_some());
    }

    #[test]
    fn backward_measurement_times_every_backward_candidate() {
        let plan = plan();
        let mut m = WallClockMeasurer::new(MeasureBudget::quick());
        for s in crate::tune::space::backward_search_space(2) {
            let t = m.time_backward(&plan, &s, None);
            assert!(t.is_some(), "{} not measured backward", s.name());
            assert!(t.unwrap() >= 0.0);
        }
        // The prune contract holds in the backward direction too.
        let t = m.time_backward(&plan, &ExecStrategy::serial(), Some(1e-15));
        assert_eq!(t, None);
    }

    #[test]
    fn quick_budget_is_single_trial_shaped() {
        assert_eq!(MeasureBudget::quick().max_iters, 1);
        assert_eq!(MeasureBudget::quick().warmup, 0);
        assert!(MeasureBudget::default().max_iters >= 3);
    }
}
