//! Empirical per-layer autotuner (DESIGN.md §Autotuning).
//!
//! The paper's speedups come from picking the right formulation of the
//! unified kernel for the hardware, and §Hardware-Adaptation keeps two
//! formulations of Algorithm 2 precisely because the winner is
//! machine-dependent — yet before this module every caller hardcoded
//! `Algorithm`, `Lane` and worker counts by hand.  Following the
//! per-layer schedule specialization of GANAX and HUGE² (PAPERS.md),
//! this subsystem searches the execution-strategy space *empirically*,
//! one layer shape at a time, and remembers the verdicts:
//!
//! * [`space`] — [`ExecStrategy`]: formulation (phase-decomposed vs
//!   per-element vs planned phase-GEMM) × lane (serial vs parallel
//!   worker count) × parallel axis (phase×row queue vs per-phase
//!   rows) × batched dispatch (fused vs per-latent, DESIGN.md
//!   §Batched-Execution), and the [`search_space`] /
//!   [`search_space_batch`] / [`backward_search_space`] enumerations
//!   (the last covering the planned backward lanes of DESIGN.md
//!   §Backward-Execution, cached under disjoint `bwd` keys)
//! * [`measure`] — warmup + adaptive trials per candidate
//!   (`util::timing::measure_for`) with probe-based early pruning of
//!   candidates already 2× slower than the incumbent
//! * [`tuner`] — the per-layer search returning a [`TunedPlan`]
//! * [`cache`] — [`TuningCache`]: JSON persistence keyed by
//!   `(layer shape, host fingerprint)` so tuning pays once per machine
//!
//! Execution plugs in beneath the existing plan/execute seam:
//! [`ConvTransposePlan::run_with`](crate::conv::plan::ConvTransposePlan::run_with)
//! dispatches a strategy, `models::forward::LayerWeights` pins one per
//! layer, and `RustBackend::with_autotune` tunes a whole generator at
//! construction.  The direct strategies are bit-identical to the
//! planned serial reference; the [`Formulation::PhaseGemm`] strategies
//! run the packed-GEMM engine and match within 1e-4 (both pinned by
//! `tests/conv_properties.rs`), so tuning can change throughput only —
//! never results beyond the f32 reassociation tolerance.

pub mod cache;
pub mod measure;
pub mod space;
pub mod tuner;

pub use cache::{CacheEntry, TuningCache};
pub use measure::{MeasureBudget, Measurer, WallClockMeasurer};
pub use space::{
    backward_search_space, search_space, search_space_batch, ExecStrategy, Formulation, ParAxis,
};
pub use tuner::{TunedPlan, Tuner};
