//! The execution-strategy search space (DESIGN.md §Autotuning).
//!
//! DESIGN.md §Hardware-Adaptation keeps **two** formulations of
//! Algorithm 2 because the winner is machine-dependent; the parallel
//! lane adds a worker count and a split axis on top.  An
//! [`ExecStrategy`] names one point of that space, and
//! [`search_space`] enumerates every point the tuner considers for a
//! machine with a given parallelism bound.  The direct formulations
//! are bit-identical to the planned serial reference
//! ([`ConvTransposePlan::run`](crate::conv::plan::ConvTransposePlan::run))
//! — pinned with `==` by the equivalence property in
//! `tests/conv_properties.rs`; the [`PhaseGemm`](Formulation::PhaseGemm)
//! formulation reorders f32 accumulation through the tiled microkernel
//! and is pinned to the same reference within 1e-4 (DESIGN.md
//! §GEMM-Execution), so the tuner changes *speed*, never results
//! beyond that reassociation tolerance.

use std::collections::BTreeMap;

use crate::conv::quant::Precision;
use crate::conv::simd::Isa;
use crate::util::json::Json;

/// Which formulation of Algorithm 2 executes the layer.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Formulation {
    /// Hoisted parity selection: four dense slab correlations
    /// (`unified::transpose_conv`, the TPU/MXU shape).
    PhaseDecomposed,
    /// Literal Algorithm 2: runtime sub-kernel pick per output element
    /// (the paper's CUDA shape).
    PerElement,
    /// §5 phase GEMMs through the planned packed operands and the
    /// tiled microkernel (`conv::gemm`): per phase, im2col the slab
    /// into the scratch patch matrix and multiply by the
    /// plan-time-packed sub-kernel.  Equivalent to the reference
    /// within 1e-4 (f32 reassociation), not bit-identical.
    PhaseGemm,
}

impl Formulation {
    pub fn name(&self) -> &'static str {
        match self {
            Formulation::PhaseDecomposed => "phase",
            Formulation::PerElement => "per-element",
            Formulation::PhaseGemm => "phase-gemm",
        }
    }

    fn from_name(name: &str) -> Option<Formulation> {
        match name {
            "phase" => Some(Formulation::PhaseDecomposed),
            "per-element" => Some(Formulation::PerElement),
            "phase-gemm" => Some(Formulation::PhaseGemm),
            _ => None,
        }
    }
}

/// Which axis the parallel lane splits across (phase-decomposed
/// formulation only; the per-element formulation always splits by
/// output rows).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ParAxis {
    /// One work queue of (phase × output-row) jobs — best load balance.
    PhaseRows,
    /// Rows within one phase at a time — one slab + sub-kernel resident
    /// per step, best cache locality.
    Rows,
}

impl ParAxis {
    pub fn name(&self) -> &'static str {
        match self {
            ParAxis::PhaseRows => "phase-rows",
            ParAxis::Rows => "rows",
        }
    }

    fn from_name(name: &str) -> Option<ParAxis> {
        match name {
            "phase-rows" => Some(ParAxis::PhaseRows),
            "rows" => Some(ParAxis::Rows),
            _ => None,
        }
    }
}

/// Where the layer epilogue (per-channel bias + activation) executes
/// for the phase-GEMM formulation (DESIGN.md §Fused-Epilogue).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum EpilogueMode {
    /// Phase GEMMs write a contiguous phase slab, `scatter_rows`
    /// recombines it into the strided output, and the caller applies
    /// bias + activation as a separate full pass (the historical
    /// three-pass shape).
    Separate,
    /// GEMM accumulator tiles store directly into the strided output
    /// positions with bias + activation applied in-register
    /// (`ConvTransposePlan::run_gemm_fused*`) — no phase slab, no
    /// scatter pass, no epilogue pass.
    Fused,
}

impl EpilogueMode {
    pub fn name(&self) -> &'static str {
        match self {
            EpilogueMode::Separate => "separate",
            EpilogueMode::Fused => "fused",
        }
    }

    fn from_name(name: &str) -> Option<EpilogueMode> {
        match name {
            "separate" => Some(EpilogueMode::Separate),
            "fused" => Some(EpilogueMode::Fused),
            _ => None,
        }
    }
}

/// One point in the execution-strategy space for a planned layer.
///
/// Constructed through the helpers so the serial lane is canonical
/// (`workers == 1` always carries `ParAxis::PhaseRows`); `Eq`/`Hash`
/// then mean semantic equality.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ExecStrategy {
    pub formulation: Formulation,
    /// Worker threads; 1 = the serial lane.
    pub workers: usize,
    /// Parallel split axis (ignored on the serial lane and by the
    /// per-element formulation).
    pub axis: ParAxis,
    /// Batched dispatch (DESIGN.md §Batched-Execution): `true` executes
    /// a whole micro-batch through the plan's fused lanes
    /// (`ConvTransposePlan::run_batch_with` — stacked phase GEMMs /
    /// the image×row job queue); `false` loops the batch per latent
    /// through the single-image lane.  Irrelevant at batch size 1; the
    /// batched search space ([`search_space_batch`]) carries both so
    /// the tuner measures the fusion win instead of assuming it.
    pub fused: bool,
    /// The microkernel axis (DESIGN.md §SIMD-Dispatch): which SIMD lane
    /// the phase-GEMM lanes execute with.  The GEMM constructors
    /// default to the host's active lane ([`Isa::active`]); the search
    /// spaces additionally carry scalar-pinned GEMM variants on vector
    /// hosts so the tuner *measures* the vector win per layer instead
    /// of assuming it.  Normalized to `Isa::Scalar` for the direct
    /// formulations (their inner loops always run the active lane's
    /// bit-identical saxpy — there is nothing to tune), so `Eq` stays
    /// semantic.
    pub isa: Isa,
    /// The operand-precision axis (DESIGN.md §Reduced-Precision): which
    /// storage format the phase-GEMM lanes execute with.  `F32` is the
    /// exact engine; the quantized formats
    /// ([`Precision::QUANTIZED`]) store both packed operands in
    /// reduced precision and accumulate in f32 through the widening
    /// kernels, trading bounded drift for operand bandwidth.  The
    /// default search spaces stay f32-only — quantized lanes enter
    /// via [`ExecStrategy::with_precision`] (pinned tuning /
    /// `ukstc accuracy`), keeping every existing verdict exact.
    /// Normalized to `F32` for the direct formulations (they have no
    /// quantized lanes), so `Eq` stays semantic.
    pub precision: Precision,
    /// The epilogue axis (DESIGN.md §Fused-Epilogue): whether the
    /// phase-GEMM lanes store accumulator tiles straight into the
    /// strided output with bias + activation folded in
    /// ([`EpilogueMode::Fused`]) or keep the historical slab → scatter
    /// → separate-epilogue shape ([`EpilogueMode::Separate`]).
    /// Normalized to `Separate` for the non-GEMM formulations (their
    /// writes are already direct; only the GEMM lanes have a slab to
    /// skip), so `Eq` stays semantic.
    pub epilogue: EpilogueMode,
}

impl ExecStrategy {
    /// The conventional default every caller hardcoded before the
    /// tuner existed: serial phase decomposition.  Always first in
    /// [`search_space`] so it seeds the incumbent for pruning.
    pub fn serial() -> ExecStrategy {
        ExecStrategy {
            formulation: Formulation::PhaseDecomposed,
            workers: 1,
            axis: ParAxis::PhaseRows,
            fused: false,
            isa: Isa::Scalar,
            precision: Precision::F32,
            epilogue: EpilogueMode::Separate,
        }
    }

    /// Serial literal-Algorithm-2 lane.
    pub fn serial_per_element() -> ExecStrategy {
        ExecStrategy {
            formulation: Formulation::PerElement,
            workers: 1,
            axis: ParAxis::PhaseRows,
            fused: false,
            isa: Isa::Scalar,
            precision: Precision::F32,
            epilogue: EpilogueMode::Separate,
        }
    }

    /// Phase-decomposed parallel lane over `workers` threads.
    pub fn parallel(workers: usize, axis: ParAxis) -> ExecStrategy {
        let workers = workers.max(1);
        ExecStrategy {
            formulation: Formulation::PhaseDecomposed,
            axis: if workers == 1 { ParAxis::PhaseRows } else { axis },
            workers,
            fused: false,
            isa: Isa::Scalar,
            precision: Precision::F32,
            epilogue: EpilogueMode::Separate,
        }
    }

    /// Per-element parallel lane (row split) over `workers` threads.
    pub fn per_element_parallel(workers: usize) -> ExecStrategy {
        ExecStrategy {
            formulation: Formulation::PerElement,
            workers: workers.max(1),
            axis: ParAxis::PhaseRows,
            fused: false,
            isa: Isa::Scalar,
            precision: Precision::F32,
            epilogue: EpilogueMode::Separate,
        }
    }

    /// Serial phase-GEMM lane (planned packed operands + tiled
    /// microkernel), on the host's active SIMD lane.
    pub fn serial_gemm() -> ExecStrategy {
        ExecStrategy {
            formulation: Formulation::PhaseGemm,
            workers: 1,
            axis: ParAxis::PhaseRows,
            fused: false,
            isa: Isa::active(),
            precision: Precision::F32,
            epilogue: EpilogueMode::Separate,
        }
    }

    /// Row-parallel phase-GEMM lane over `workers` threads (the GEMM
    /// formulation always splits by output rows within a phase, so the
    /// axis is normalized like the per-element lane's), on the host's
    /// active SIMD lane.
    pub fn gemm_parallel(workers: usize) -> ExecStrategy {
        ExecStrategy {
            formulation: Formulation::PhaseGemm,
            workers: workers.max(1),
            axis: ParAxis::PhaseRows,
            fused: false,
            isa: Isa::active(),
            precision: Precision::F32,
            epilogue: EpilogueMode::Separate,
        }
    }

    /// Pin the microkernel axis.  Meaningful only for the phase-GEMM
    /// formulation — the direct formulations normalize it away so `Eq`
    /// stays semantic (their inner loops are not strategy-dispatched).
    pub fn with_isa(mut self, isa: Isa) -> ExecStrategy {
        self.isa = if self.formulation == Formulation::PhaseGemm {
            isa
        } else {
            Isa::Scalar
        };
        self
    }

    /// Pin the operand-precision axis.  Meaningful only for the
    /// phase-GEMM formulation — the direct formulations have no
    /// quantized lanes, so the axis is normalized to `F32` and `Eq`
    /// stays semantic (mirrors [`with_isa`](Self::with_isa)).
    pub fn with_precision(mut self, precision: Precision) -> ExecStrategy {
        self.precision = if self.formulation == Formulation::PhaseGemm {
            precision
        } else {
            Precision::F32
        };
        self
    }

    /// Pin the epilogue axis to in-register fusion
    /// (DESIGN.md §Fused-Epilogue).  Meaningful only for the
    /// phase-GEMM formulation — the direct formulations have no phase
    /// slab to skip, so the axis is normalized to `Separate` and `Eq`
    /// stays semantic (mirrors [`with_isa`](Self::with_isa)).
    pub fn fused_epilogue(mut self) -> ExecStrategy {
        self.epilogue = if self.formulation == Formulation::PhaseGemm {
            EpilogueMode::Fused
        } else {
            EpilogueMode::Separate
        };
        self
    }

    /// Mark this strategy for fused batched dispatch
    /// (`ConvTransposePlan::run_batch_with`).  The per-element
    /// formulation has no fused lane — the flag is normalized away so
    /// `Eq` stays semantic.
    pub fn fused(mut self) -> ExecStrategy {
        self.fused = self.formulation != Formulation::PerElement;
        self
    }

    pub fn is_serial(&self) -> bool {
        self.workers == 1
    }

    /// Trace-lane tag (`obs::trace`): which executing lane a span under
    /// this strategy should be attributed to.  `&'static str` so span
    /// recording stays allocation-free.
    pub fn lane_tag(&self) -> &'static str {
        match self.formulation {
            Formulation::PhaseDecomposed => "direct",
            Formulation::PerElement => "per-element",
            Formulation::PhaseGemm => self.isa.gemm_lane_tag(),
        }
    }

    /// Compact display name, e.g. `phase/par4/rows`,
    /// `phase-gemm/serial/avx2` or `phase-gemm/par4/fused`.  The
    /// microkernel axis appears only on non-scalar GEMM lanes (before
    /// the `/fused` suffix), so scalar-host names are unchanged from
    /// pre-SIMD releases; the precision axis likewise appears only on
    /// quantized lanes (after the ISA, before `/fused`), so every f32
    /// name is unchanged from pre-quantization releases; the epilogue
    /// axis appears as `/fuse` only on fused-epilogue GEMM lanes
    /// (after the precision, before the batched `/fused` suffix —
    /// `/fuse` is the epilogue, `/fused` is batched dispatch), so
    /// every separate-epilogue name is unchanged from pre-fusion
    /// releases.
    pub fn name(&self) -> String {
        let mut base = match (self.formulation, self.workers) {
            (f, 1) => format!("{}/serial", f.name()),
            (Formulation::PerElement, w) => format!("per-element/par{w}"),
            (Formulation::PhaseGemm, w) => format!("phase-gemm/par{w}"),
            (Formulation::PhaseDecomposed, w) => {
                format!("phase/par{w}/{}", self.axis.name())
            }
        };
        if self.formulation == Formulation::PhaseGemm && self.isa != Isa::Scalar {
            base = format!("{base}/{}", self.isa.name());
        }
        if self.precision != Precision::F32 {
            base = format!("{base}/{}", self.precision.name());
        }
        if self.epilogue == EpilogueMode::Fused {
            base = format!("{base}/fuse");
        }
        if self.fused {
            format!("{base}/fused")
        } else {
            base
        }
    }

    /// JSON encoding for the tuning cache (`util::json`).  The `fused`,
    /// `isa` and `precision` fields are written only when set /
    /// non-scalar / non-f32, so pre-batching, pre-SIMD and
    /// pre-quantization caches and the documented examples stay
    /// byte-stable.
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert(
            "formulation".to_string(),
            Json::Str(self.formulation.name().to_string()),
        );
        m.insert("workers".to_string(), Json::Num(self.workers as f64));
        m.insert("axis".to_string(), Json::Str(self.axis.name().to_string()));
        if self.fused {
            m.insert("fused".to_string(), Json::Bool(true));
        }
        if self.isa != Isa::Scalar {
            m.insert("isa".to_string(), Json::Str(self.isa.name().to_string()));
        }
        if self.precision != Precision::F32 {
            m.insert(
                "precision".to_string(),
                Json::Str(self.precision.name().to_string()),
            );
        }
        if self.epilogue == EpilogueMode::Fused {
            m.insert(
                "epilogue".to_string(),
                Json::Str(self.epilogue.name().to_string()),
            );
        }
        Json::Obj(m)
    }

    /// Decode from the cache encoding; `None` on any malformed field.
    /// A missing `fused` field decodes as per-latent, a missing `isa`
    /// field decodes as scalar, a missing `precision` field decodes
    /// as f32, and a missing `epilogue` field decodes as the separate
    /// epilogue — the only lanes that existed when such caches were
    /// written, so legacy verdicts keep their historically-correct
    /// meaning.
    pub fn from_json(v: &Json) -> Option<ExecStrategy> {
        let formulation = Formulation::from_name(v.get("formulation")?.as_str()?)?;
        let workers = v.get("workers")?.as_usize()?;
        if workers == 0 {
            return None;
        }
        let axis = ParAxis::from_name(v.get("axis")?.as_str()?)?;
        let s = match formulation {
            Formulation::PhaseDecomposed => ExecStrategy::parallel(workers, axis),
            Formulation::PerElement => ExecStrategy::per_element_parallel(workers),
            Formulation::PhaseGemm => ExecStrategy::gemm_parallel(workers),
        };
        let isa = match v.get("isa") {
            None => Isa::Scalar,
            Some(j) => Isa::parse(j.as_str()?)?,
        };
        let precision = match v.get("precision") {
            None => Precision::F32,
            Some(j) => Precision::parse(j.as_str()?)?,
        };
        let s = s.with_isa(isa).with_precision(precision);
        let s = match v.get("epilogue") {
            None => s,
            Some(j) => match EpilogueMode::from_name(j.as_str()?)? {
                EpilogueMode::Fused => s.fused_epilogue(),
                EpilogueMode::Separate => s,
            },
        };
        match v.get("fused") {
            None => Some(s),
            Some(f) => {
                if f.as_bool()? {
                    Some(s.fused())
                } else {
                    Some(s)
                }
            }
        }
    }
}

/// Candidate worker counts: powers of two up to `max_workers`, plus
/// `max_workers` itself (so a 6-core host still tries 6).
fn worker_counts(max_workers: usize) -> Vec<usize> {
    let mut counts = Vec::new();
    let mut w = 2;
    while w < max_workers {
        counts.push(w);
        w *= 2;
    }
    if max_workers >= 2 {
        counts.push(max_workers);
    }
    counts
}

/// The full search space for a machine with `max_workers` usable
/// threads: all three formulations serial, then every candidate
/// worker count × lane (two phase-decomposed axes, per-element rows,
/// phase-GEMM rows).  On vector hosts every GEMM lane additionally
/// appears scalar-pinned (the microkernel axis, DESIGN.md
/// §SIMD-Dispatch) — [`Isa::supported`] is `{active, scalar}`, so the
/// space enumerates exactly the lanes the host can execute.  Every
/// active-ISA GEMM lane also appears with the fused epilogue (the
/// epilogue axis, DESIGN.md §Fused-Epilogue) so the tuner *measures*
/// the skipped slab+scatter pass per layer instead of assuming it.
/// [`ExecStrategy::serial`] is always element zero.
pub fn search_space(max_workers: usize) -> Vec<ExecStrategy> {
    let vector_host = Isa::active() != Isa::Scalar;
    let mut out = vec![
        ExecStrategy::serial(),
        ExecStrategy::serial_per_element(),
        ExecStrategy::serial_gemm(),
        ExecStrategy::serial_gemm().fused_epilogue(),
    ];
    if vector_host {
        out.push(ExecStrategy::serial_gemm().with_isa(Isa::Scalar));
    }
    for w in worker_counts(max_workers) {
        out.push(ExecStrategy::parallel(w, ParAxis::PhaseRows));
        out.push(ExecStrategy::parallel(w, ParAxis::Rows));
        out.push(ExecStrategy::per_element_parallel(w));
        out.push(ExecStrategy::gemm_parallel(w));
        out.push(ExecStrategy::gemm_parallel(w).fused_epilogue());
        if vector_host {
            out.push(ExecStrategy::gemm_parallel(w).with_isa(Isa::Scalar));
        }
    }
    out
}

/// The search space for serving batch size `batch`
/// (DESIGN.md §Batched-Execution): at `batch ≤ 1` exactly
/// [`search_space`]; above it, every per-latent strategy **plus** the
/// fused batched variants — the serial fused GEMM (one stacked phase
/// GEMM per phase, packed panels streamed once per batch), the fused
/// row-parallel GEMM, and the fused image×row direct queue per worker
/// count.  The per-latent serial default stays element zero, so the
/// incumbent pruning baseline is the pre-batching behavior and a fused
/// verdict can only come from measuring it faster.  The batched GEMM
/// variants additionally appear with the fused epilogue (stacked phase
/// GEMMs storing straight into every image's strided rows).
pub fn search_space_batch(max_workers: usize, batch: usize) -> Vec<ExecStrategy> {
    let mut out = search_space(max_workers);
    if batch <= 1 {
        return out;
    }
    let vector_host = Isa::active() != Isa::Scalar;
    out.push(ExecStrategy::serial_gemm().fused());
    out.push(ExecStrategy::serial_gemm().fused().fused_epilogue());
    if vector_host {
        out.push(ExecStrategy::serial_gemm().with_isa(Isa::Scalar).fused());
    }
    for w in worker_counts(max_workers) {
        out.push(ExecStrategy::parallel(w, ParAxis::PhaseRows).fused());
        out.push(ExecStrategy::gemm_parallel(w).fused());
        out.push(ExecStrategy::gemm_parallel(w).fused().fused_epilogue());
        if vector_host {
            out.push(ExecStrategy::gemm_parallel(w).with_isa(Isa::Scalar).fused());
        }
    }
    out
}

/// The **backward-direction** search space (DESIGN.md
/// §Backward-Execution): the lanes
/// [`ConvTransposePlan::run_backward_data_with`](crate::conv::plan::ConvTransposePlan::run_backward_data_with)
/// dispatches — serial direct (element zero, seeding the incumbent
/// like the forward spaces), serial GEMM (scalar-pinned as well on
/// vector hosts), and the `(phase, slab-row)` parallel direct lane per
/// candidate worker count.  A separate
/// enumeration rather than a [`search_space`] extension: backward has
/// no per-element formulation and no split-axis choice, and keeping it
/// apart leaves the pinned forward space sizes untouched.
pub fn backward_search_space(max_workers: usize) -> Vec<ExecStrategy> {
    let mut out = vec![ExecStrategy::serial(), ExecStrategy::serial_gemm()];
    if Isa::active() != Isa::Scalar {
        out.push(ExecStrategy::serial_gemm().with_isa(Isa::Scalar));
    }
    for w in worker_counts(max_workers) {
        out.push(ExecStrategy::parallel(w, ParAxis::PhaseRows));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serial_default_is_first() {
        for max in [1, 2, 3, 8] {
            assert_eq!(search_space(max)[0], ExecStrategy::serial());
        }
    }

    /// 1 on vector hosts (each GEMM lane gains a scalar-pinned twin),
    /// 0 on scalar hosts — keeps the size pins exact on every CI ISA.
    fn extra() -> usize {
        usize::from(Isa::active() != Isa::Scalar)
    }

    #[test]
    fn space_sizes() {
        // max 1 → only the serial lanes (3 formulations + the
        // fused-epilogue GEMM twin); each worker count adds 5
        // (+ the scalar-pinned GEMM twin on vector hosts).
        let e = extra();
        assert_eq!(search_space(1).len(), 4 + e);
        assert_eq!(search_space(2).len(), 4 + e + (5 + e)); // w ∈ {2}
        assert_eq!(search_space(8).len(), 4 + e + 3 * (5 + e)); // w ∈ {2, 4, 8}
        assert_eq!(worker_counts(6), vec![2, 4, 6]);
    }

    #[test]
    fn vector_hosts_carry_scalar_pinned_gemm_lanes() {
        // The microkernel axis: the space holds exactly the ISA lanes
        // the host supports — every GEMM worker count × Isa::supported().
        let space = search_space(4);
        for isa in Isa::supported() {
            assert!(space.contains(&ExecStrategy::serial_gemm().with_isa(isa)));
            assert!(space.contains(&ExecStrategy::gemm_parallel(4).with_isa(isa)));
        }
        // No GEMM lane carries an ISA the host can't run.
        for s in &space {
            assert!(s.isa.is_available(), "{}", s.name());
        }
        // Direct formulations normalize the axis away.
        assert_eq!(
            ExecStrategy::serial().with_isa(Isa::Avx512),
            ExecStrategy::serial()
        );
    }

    #[test]
    fn space_includes_gemm_lanes() {
        // ISSUE 4 acceptance: the search space carries the PhaseGemm
        // formulation serial AND row-parallel.
        let space = search_space(4);
        assert!(space.contains(&ExecStrategy::serial_gemm()));
        assert!(space.contains(&ExecStrategy::gemm_parallel(2)));
        assert!(space.contains(&ExecStrategy::gemm_parallel(4)));
    }

    #[test]
    fn names_unique() {
        let names: Vec<String> = search_space_batch(8, 4)
            .iter()
            .map(ExecStrategy::name)
            .collect();
        let mut dedup = names.clone();
        dedup.sort();
        dedup.dedup();
        assert_eq!(names.len(), dedup.len(), "{names:?}");
    }

    #[test]
    fn batched_space_extends_per_latent_space() {
        // batch ≤ 1 is exactly the per-latent space; above it, the
        // per-latent space is a prefix (serial default still seeds the
        // incumbent) and the fused variants follow.
        assert_eq!(search_space_batch(4, 1), search_space(4));
        assert_eq!(search_space_batch(4, 0), search_space(4));
        let batched = search_space_batch(4, 8);
        let base = search_space(4);
        assert_eq!(&batched[..base.len()], &base[..]);
        assert_eq!(batched[0], ExecStrategy::serial());
        assert!(batched.contains(&ExecStrategy::serial_gemm().fused()));
        assert!(batched.contains(&ExecStrategy::gemm_parallel(4).fused()));
        assert!(batched.contains(&ExecStrategy::parallel(2, ParAxis::PhaseRows).fused()));
        assert!(batched.contains(&ExecStrategy::serial_gemm().fused().fused_epilogue()));
        assert!(batched.contains(&ExecStrategy::gemm_parallel(4).fused().fused_epilogue()));
        // 2 fused serial gemms (separate + fused epilogue) + 3 fused
        // lanes per worker count {2, 4} (+ scalar-pinned GEMM twins on
        // vector hosts).
        let e = extra();
        assert_eq!(batched.len(), base.len() + (2 + e) + (3 + e) * 2);
        assert_eq!(
            ExecStrategy::serial_gemm().with_isa(Isa::Scalar).fused().name(),
            "phase-gemm/serial/fused"
        );
        // The per-element formulation has no fused lane — normalized away.
        assert_eq!(
            ExecStrategy::serial_per_element().fused(),
            ExecStrategy::serial_per_element()
        );
    }

    #[test]
    fn backward_space_is_small_and_disjointly_defined() {
        // Serial direct seeds the incumbent; the space holds exactly
        // {serial, serial-gemm (× supported ISA lanes)} + one parallel
        // lane per worker count, every member dispatchable by
        // run_backward_data_with.  The forward spaces keep their
        // pinned sizes regardless.
        let e = extra();
        assert_eq!(backward_search_space(1).len(), 2 + e);
        assert_eq!(backward_search_space(2).len(), 2 + e + 1);
        assert_eq!(backward_search_space(8).len(), 2 + e + 3);
        for max in [1, 2, 8] {
            let space = backward_search_space(max);
            assert_eq!(space[0], ExecStrategy::serial());
            assert!(space.contains(&ExecStrategy::serial_gemm()));
            assert!(!space.iter().any(|s| s.formulation == Formulation::PerElement));
            assert!(!space.iter().any(|s| s.fused));
            // Backward lanes have no fused-epilogue variant (the
            // backward GEMMs accumulate into dx, there is no bias /
            // activation epilogue to fold).
            assert!(!space.iter().any(|s| s.epilogue == EpilogueMode::Fused));
            let mut names: Vec<String> = space.iter().map(ExecStrategy::name).collect();
            names.sort();
            names.dedup();
            assert_eq!(names.len(), space.len());
        }
        assert!(backward_search_space(8).contains(&ExecStrategy::parallel(4, ParAxis::PhaseRows)));
    }

    #[test]
    fn serial_lane_is_canonical() {
        // workers == 1 normalizes the axis, so Eq means semantic equality.
        assert_eq!(
            ExecStrategy::parallel(1, ParAxis::Rows),
            ExecStrategy::serial()
        );
        assert_eq!(ExecStrategy::per_element_parallel(0).workers, 1);
        assert_eq!(ExecStrategy::gemm_parallel(1), ExecStrategy::serial_gemm());
        // Scalar GEMM names carry no ISA suffix (pre-SIMD stability);
        // vector lanes append it before any /fused.
        let scalar = ExecStrategy::serial_gemm().with_isa(Isa::Scalar);
        assert_eq!(scalar.name(), "phase-gemm/serial");
        assert_eq!(
            ExecStrategy::gemm_parallel(4).with_isa(Isa::Scalar).name(),
            "phase-gemm/par4"
        );
        assert_eq!(
            ExecStrategy::gemm_parallel(4).with_isa(Isa::Avx2).name(),
            "phase-gemm/par4/avx2"
        );
        assert_eq!(
            ExecStrategy::serial_gemm().with_isa(Isa::Neon).fused().name(),
            "phase-gemm/serial/neon/fused"
        );
    }

    #[test]
    fn json_roundtrip_whole_space() {
        for s in search_space_batch(8, 4) {
            let encoded = s.to_json().to_string_compact();
            let decoded =
                ExecStrategy::from_json(&crate::util::json::parse(&encoded).unwrap()).unwrap();
            assert_eq!(decoded, s, "{encoded}");
        }
    }

    #[test]
    fn precision_axis_is_gemm_only_and_defaults_f32() {
        // Every constructor and every default-space member is f32 —
        // quantized lanes never enter the default spaces, so the size
        // pins above and every existing verdict stay exact.
        for s in search_space_batch(8, 4) {
            assert_eq!(s.precision, Precision::F32, "{}", s.name());
        }
        // with_precision pins GEMM lanes; direct formulations
        // normalize the axis away (mirrors with_isa).
        let q = ExecStrategy::serial_gemm().with_precision(Precision::F16);
        assert_eq!(q.precision, Precision::F16);
        assert_eq!(
            ExecStrategy::serial().with_precision(Precision::Int8),
            ExecStrategy::serial()
        );
        assert_eq!(
            ExecStrategy::serial_per_element().with_precision(Precision::Bf16),
            ExecStrategy::serial_per_element()
        );
        // F32 pin is the identity.
        assert_eq!(
            ExecStrategy::serial_gemm().with_precision(Precision::F32),
            ExecStrategy::serial_gemm()
        );
    }

    #[test]
    fn precision_names_and_json() {
        // Name suffix sits after the ISA, before /fused; f32 names are
        // byte-stable (no suffix).
        let q = ExecStrategy::gemm_parallel(4)
            .with_isa(Isa::Avx2)
            .with_precision(Precision::F16);
        assert_eq!(q.name(), "phase-gemm/par4/avx2/f16");
        assert_eq!(q.fused().name(), "phase-gemm/par4/avx2/f16/fused");
        assert_eq!(
            ExecStrategy::serial_gemm()
                .with_isa(Isa::Scalar)
                .with_precision(Precision::Int8)
                .name(),
            "phase-gemm/serial/int8"
        );
        // JSON: emitted only when quantized; decode applies it after
        // the ISA; legacy encodings (no field) decode as f32.
        for p in Precision::QUANTIZED {
            let s = ExecStrategy::serial_gemm().with_precision(p);
            let encoded = s.to_json().to_string_compact();
            assert!(
                encoded.contains(&format!("\"precision\":\"{}\"", p.name())),
                "{encoded}"
            );
            let decoded =
                ExecStrategy::from_json(&crate::util::json::parse(&encoded).unwrap()).unwrap();
            assert_eq!(decoded, s, "{encoded}");
        }
        let f32_enc = ExecStrategy::serial_gemm().to_json().to_string_compact();
        assert!(!f32_enc.contains("precision"), "{f32_enc}");
        let legacy = r#"{"formulation":"phase-gemm","workers":2,"axis":"phase-rows"}"#;
        let decoded =
            ExecStrategy::from_json(&crate::util::json::parse(legacy).unwrap()).unwrap();
        assert_eq!(decoded.precision, Precision::F32);
        // Malformed precision fields reject like malformed ISAs.
        for bad in [
            r#"{"formulation":"phase-gemm","workers":2,"axis":"phase-rows","precision":"f8"}"#,
            r#"{"formulation":"phase-gemm","workers":2,"axis":"phase-rows","precision":16}"#,
        ] {
            let v = crate::util::json::parse(bad).unwrap();
            assert_eq!(ExecStrategy::from_json(&v), None, "{bad}");
        }
    }

    #[test]
    fn epilogue_axis_is_gemm_only_and_defaults_separate() {
        // Every constructor defaults to the separate epilogue, so
        // pre-fusion behavior is the baseline the tuner prunes against.
        assert_eq!(ExecStrategy::serial_gemm().epilogue, EpilogueMode::Separate);
        // fused_epilogue pins GEMM lanes; direct formulations
        // normalize the axis away (mirrors with_isa/with_precision).
        let f = ExecStrategy::serial_gemm().fused_epilogue();
        assert_eq!(f.epilogue, EpilogueMode::Fused);
        assert_eq!(
            ExecStrategy::serial().fused_epilogue(),
            ExecStrategy::serial()
        );
        assert_eq!(
            ExecStrategy::serial_per_element().fused_epilogue(),
            ExecStrategy::serial_per_element()
        );
        // Both epilogue modes of every GEMM lane are enumerated, so
        // the tuner measures the fusion win instead of assuming it.
        let space = search_space(4);
        assert!(space.contains(&ExecStrategy::serial_gemm().fused_epilogue()));
        assert!(space.contains(&ExecStrategy::gemm_parallel(4).fused_epilogue()));
        // The axis composes with the others and names append /fuse
        // after the precision, before any batched /fused.
        assert_eq!(f.name(), "phase-gemm/serial/fuse");
        assert_eq!(
            ExecStrategy::gemm_parallel(4)
                .with_isa(Isa::Avx2)
                .with_precision(Precision::F16)
                .fused_epilogue()
                .fused()
                .name(),
            "phase-gemm/par4/avx2/f16/fuse/fused"
        );
    }

    #[test]
    fn epilogue_json_omitted_means_separate() {
        // Separate-epilogue encodings carry no field, so every
        // pre-fusion cache line is byte-stable and decodes unchanged.
        let sep = ExecStrategy::serial_gemm().to_json().to_string_compact();
        assert!(!sep.contains("epilogue"), "{sep}");
        let fused = ExecStrategy::serial_gemm().fused_epilogue();
        let encoded = fused.to_json().to_string_compact();
        assert!(encoded.contains("\"epilogue\":\"fused\""), "{encoded}");
        let decoded =
            ExecStrategy::from_json(&crate::util::json::parse(&encoded).unwrap()).unwrap();
        assert_eq!(decoded, fused, "{encoded}");
        // Legacy line (no epilogue field) decodes as separate.
        let legacy = r#"{"formulation":"phase-gemm","workers":2,"axis":"phase-rows"}"#;
        let decoded =
            ExecStrategy::from_json(&crate::util::json::parse(legacy).unwrap()).unwrap();
        assert_eq!(decoded.epilogue, EpilogueMode::Separate);
        // An explicit "separate" also decodes (forward-compat with
        // hand-edited caches); malformed values reject.
        let explicit = r#"{"formulation":"phase-gemm","workers":2,"axis":"phase-rows","epilogue":"separate"}"#;
        let decoded =
            ExecStrategy::from_json(&crate::util::json::parse(explicit).unwrap()).unwrap();
        assert_eq!(decoded.epilogue, EpilogueMode::Separate);
        for bad in [
            r#"{"formulation":"phase-gemm","workers":2,"axis":"phase-rows","epilogue":"inline"}"#,
            r#"{"formulation":"phase-gemm","workers":2,"axis":"phase-rows","epilogue":1}"#,
        ] {
            let v = crate::util::json::parse(bad).unwrap();
            assert_eq!(ExecStrategy::from_json(&v), None, "{bad}");
        }
    }

    #[test]
    fn from_json_rejects_malformed() {
        for bad in [
            r#"{"formulation":"phase","workers":0,"axis":"rows"}"#,
            r#"{"formulation":"gpu","workers":2,"axis":"rows"}"#,
            r#"{"formulation":"phase","workers":2,"axis":"cols"}"#,
            r#"{"workers":2,"axis":"rows"}"#,
            r#"{"formulation":"phase-gemm","workers":2,"axis":"phase-rows","isa":"sse9"}"#,
            r#"{"formulation":"phase-gemm","workers":2,"axis":"phase-rows","isa":7}"#,
            r#"[1,2,3]"#,
        ] {
            let v = crate::util::json::parse(bad).unwrap();
            assert_eq!(ExecStrategy::from_json(&v), None, "{bad}");
        }
    }
}
