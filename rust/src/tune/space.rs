//! The execution-strategy search space (DESIGN.md §Autotuning).
//!
//! DESIGN.md §Hardware-Adaptation keeps **two** formulations of
//! Algorithm 2 because the winner is machine-dependent; the parallel
//! lane adds a worker count and a split axis on top.  An
//! [`ExecStrategy`] names one point of that space, and
//! [`search_space`] enumerates every point the tuner considers for a
//! machine with a given parallelism bound.  The direct formulations
//! are bit-identical to the planned serial reference
//! ([`ConvTransposePlan::run`](crate::conv::plan::ConvTransposePlan::run))
//! — pinned with `==` by the equivalence property in
//! `tests/conv_properties.rs`; the [`PhaseGemm`](Formulation::PhaseGemm)
//! formulation reorders f32 accumulation through the tiled microkernel
//! and is pinned to the same reference within 1e-4 (DESIGN.md
//! §GEMM-Execution), so the tuner changes *speed*, never results
//! beyond that reassociation tolerance.

use std::collections::BTreeMap;

use crate::util::json::Json;

/// Which formulation of Algorithm 2 executes the layer.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Formulation {
    /// Hoisted parity selection: four dense slab correlations
    /// (`unified::transpose_conv`, the TPU/MXU shape).
    PhaseDecomposed,
    /// Literal Algorithm 2: runtime sub-kernel pick per output element
    /// (the paper's CUDA shape).
    PerElement,
    /// §5 phase GEMMs through the planned packed operands and the
    /// tiled microkernel (`conv::gemm`): per phase, im2col the slab
    /// into the scratch patch matrix and multiply by the
    /// plan-time-packed sub-kernel.  Equivalent to the reference
    /// within 1e-4 (f32 reassociation), not bit-identical.
    PhaseGemm,
}

impl Formulation {
    pub fn name(&self) -> &'static str {
        match self {
            Formulation::PhaseDecomposed => "phase",
            Formulation::PerElement => "per-element",
            Formulation::PhaseGemm => "phase-gemm",
        }
    }

    fn from_name(name: &str) -> Option<Formulation> {
        match name {
            "phase" => Some(Formulation::PhaseDecomposed),
            "per-element" => Some(Formulation::PerElement),
            "phase-gemm" => Some(Formulation::PhaseGemm),
            _ => None,
        }
    }
}

/// Which axis the parallel lane splits across (phase-decomposed
/// formulation only; the per-element formulation always splits by
/// output rows).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ParAxis {
    /// One work queue of (phase × output-row) jobs — best load balance.
    PhaseRows,
    /// Rows within one phase at a time — one slab + sub-kernel resident
    /// per step, best cache locality.
    Rows,
}

impl ParAxis {
    pub fn name(&self) -> &'static str {
        match self {
            ParAxis::PhaseRows => "phase-rows",
            ParAxis::Rows => "rows",
        }
    }

    fn from_name(name: &str) -> Option<ParAxis> {
        match name {
            "phase-rows" => Some(ParAxis::PhaseRows),
            "rows" => Some(ParAxis::Rows),
            _ => None,
        }
    }
}

/// One point in the execution-strategy space for a planned layer.
///
/// Constructed through the helpers so the serial lane is canonical
/// (`workers == 1` always carries `ParAxis::PhaseRows`); `Eq`/`Hash`
/// then mean semantic equality.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ExecStrategy {
    pub formulation: Formulation,
    /// Worker threads; 1 = the serial lane.
    pub workers: usize,
    /// Parallel split axis (ignored on the serial lane and by the
    /// per-element formulation).
    pub axis: ParAxis,
    /// Batched dispatch (DESIGN.md §Batched-Execution): `true` executes
    /// a whole micro-batch through the plan's fused lanes
    /// (`ConvTransposePlan::run_batch_with` — stacked phase GEMMs /
    /// the image×row job queue); `false` loops the batch per latent
    /// through the single-image lane.  Irrelevant at batch size 1; the
    /// batched search space ([`search_space_batch`]) carries both so
    /// the tuner measures the fusion win instead of assuming it.
    pub fused: bool,
}

impl ExecStrategy {
    /// The conventional default every caller hardcoded before the
    /// tuner existed: serial phase decomposition.  Always first in
    /// [`search_space`] so it seeds the incumbent for pruning.
    pub fn serial() -> ExecStrategy {
        ExecStrategy {
            formulation: Formulation::PhaseDecomposed,
            workers: 1,
            axis: ParAxis::PhaseRows,
            fused: false,
        }
    }

    /// Serial literal-Algorithm-2 lane.
    pub fn serial_per_element() -> ExecStrategy {
        ExecStrategy {
            formulation: Formulation::PerElement,
            workers: 1,
            axis: ParAxis::PhaseRows,
            fused: false,
        }
    }

    /// Phase-decomposed parallel lane over `workers` threads.
    pub fn parallel(workers: usize, axis: ParAxis) -> ExecStrategy {
        let workers = workers.max(1);
        ExecStrategy {
            formulation: Formulation::PhaseDecomposed,
            axis: if workers == 1 { ParAxis::PhaseRows } else { axis },
            workers,
            fused: false,
        }
    }

    /// Per-element parallel lane (row split) over `workers` threads.
    pub fn per_element_parallel(workers: usize) -> ExecStrategy {
        ExecStrategy {
            formulation: Formulation::PerElement,
            workers: workers.max(1),
            axis: ParAxis::PhaseRows,
            fused: false,
        }
    }

    /// Serial phase-GEMM lane (planned packed operands + tiled
    /// microkernel).
    pub fn serial_gemm() -> ExecStrategy {
        ExecStrategy {
            formulation: Formulation::PhaseGemm,
            workers: 1,
            axis: ParAxis::PhaseRows,
            fused: false,
        }
    }

    /// Row-parallel phase-GEMM lane over `workers` threads (the GEMM
    /// formulation always splits by output rows within a phase, so the
    /// axis is normalized like the per-element lane's).
    pub fn gemm_parallel(workers: usize) -> ExecStrategy {
        ExecStrategy {
            formulation: Formulation::PhaseGemm,
            workers: workers.max(1),
            axis: ParAxis::PhaseRows,
            fused: false,
        }
    }

    /// Mark this strategy for fused batched dispatch
    /// (`ConvTransposePlan::run_batch_with`).  The per-element
    /// formulation has no fused lane — the flag is normalized away so
    /// `Eq` stays semantic.
    pub fn fused(mut self) -> ExecStrategy {
        self.fused = self.formulation != Formulation::PerElement;
        self
    }

    pub fn is_serial(&self) -> bool {
        self.workers == 1
    }

    /// Compact display name, e.g. `phase/par4/rows` or
    /// `phase-gemm/serial/fused`.
    pub fn name(&self) -> String {
        let base = match (self.formulation, self.workers) {
            (f, 1) => format!("{}/serial", f.name()),
            (Formulation::PerElement, w) => format!("per-element/par{w}"),
            (Formulation::PhaseGemm, w) => format!("phase-gemm/par{w}"),
            (Formulation::PhaseDecomposed, w) => {
                format!("phase/par{w}/{}", self.axis.name())
            }
        };
        if self.fused {
            format!("{base}/fused")
        } else {
            base
        }
    }

    /// JSON encoding for the tuning cache (`util::json`).  The `fused`
    /// field is written only when set, so pre-batching caches and the
    /// documented examples stay byte-stable.
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert(
            "formulation".to_string(),
            Json::Str(self.formulation.name().to_string()),
        );
        m.insert("workers".to_string(), Json::Num(self.workers as f64));
        m.insert("axis".to_string(), Json::Str(self.axis.name().to_string()));
        if self.fused {
            m.insert("fused".to_string(), Json::Bool(true));
        }
        Json::Obj(m)
    }

    /// Decode from the cache encoding; `None` on any malformed field.
    /// A missing `fused` field decodes as per-latent (the only lane
    /// that existed when such caches were written).
    pub fn from_json(v: &Json) -> Option<ExecStrategy> {
        let formulation = Formulation::from_name(v.get("formulation")?.as_str()?)?;
        let workers = v.get("workers")?.as_usize()?;
        if workers == 0 {
            return None;
        }
        let axis = ParAxis::from_name(v.get("axis")?.as_str()?)?;
        let s = match formulation {
            Formulation::PhaseDecomposed => ExecStrategy::parallel(workers, axis),
            Formulation::PerElement => ExecStrategy::per_element_parallel(workers),
            Formulation::PhaseGemm => ExecStrategy::gemm_parallel(workers),
        };
        match v.get("fused") {
            None => Some(s),
            Some(f) => {
                if f.as_bool()? {
                    Some(s.fused())
                } else {
                    Some(s)
                }
            }
        }
    }
}

/// Candidate worker counts: powers of two up to `max_workers`, plus
/// `max_workers` itself (so a 6-core host still tries 6).
fn worker_counts(max_workers: usize) -> Vec<usize> {
    let mut counts = Vec::new();
    let mut w = 2;
    while w < max_workers {
        counts.push(w);
        w *= 2;
    }
    if max_workers >= 2 {
        counts.push(max_workers);
    }
    counts
}

/// The full search space for a machine with `max_workers` usable
/// threads: all three formulations serial, then every candidate
/// worker count × lane (two phase-decomposed axes, per-element rows,
/// phase-GEMM rows).  [`ExecStrategy::serial`] is always element zero.
pub fn search_space(max_workers: usize) -> Vec<ExecStrategy> {
    let mut out = vec![
        ExecStrategy::serial(),
        ExecStrategy::serial_per_element(),
        ExecStrategy::serial_gemm(),
    ];
    for w in worker_counts(max_workers) {
        out.push(ExecStrategy::parallel(w, ParAxis::PhaseRows));
        out.push(ExecStrategy::parallel(w, ParAxis::Rows));
        out.push(ExecStrategy::per_element_parallel(w));
        out.push(ExecStrategy::gemm_parallel(w));
    }
    out
}

/// The search space for serving batch size `batch`
/// (DESIGN.md §Batched-Execution): at `batch ≤ 1` exactly
/// [`search_space`]; above it, every per-latent strategy **plus** the
/// fused batched variants — the serial fused GEMM (one stacked phase
/// GEMM per phase, packed panels streamed once per batch), the fused
/// row-parallel GEMM, and the fused image×row direct queue per worker
/// count.  The per-latent serial default stays element zero, so the
/// incumbent pruning baseline is the pre-batching behavior and a fused
/// verdict can only come from measuring it faster.
pub fn search_space_batch(max_workers: usize, batch: usize) -> Vec<ExecStrategy> {
    let mut out = search_space(max_workers);
    if batch <= 1 {
        return out;
    }
    out.push(ExecStrategy::serial_gemm().fused());
    for w in worker_counts(max_workers) {
        out.push(ExecStrategy::parallel(w, ParAxis::PhaseRows).fused());
        out.push(ExecStrategy::gemm_parallel(w).fused());
    }
    out
}

/// The **backward-direction** search space (DESIGN.md
/// §Backward-Execution): the lanes
/// [`ConvTransposePlan::run_backward_data_with`](crate::conv::plan::ConvTransposePlan::run_backward_data_with)
/// dispatches — serial direct (element zero, seeding the incumbent
/// like the forward spaces), serial GEMM, and the `(phase, slab-row)`
/// parallel direct lane per candidate worker count.  A separate
/// enumeration rather than a [`search_space`] extension: backward has
/// no per-element formulation and no split-axis choice, and keeping it
/// apart leaves the pinned forward space sizes untouched.
pub fn backward_search_space(max_workers: usize) -> Vec<ExecStrategy> {
    let mut out = vec![ExecStrategy::serial(), ExecStrategy::serial_gemm()];
    for w in worker_counts(max_workers) {
        out.push(ExecStrategy::parallel(w, ParAxis::PhaseRows));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serial_default_is_first() {
        for max in [1, 2, 3, 8] {
            assert_eq!(search_space(max)[0], ExecStrategy::serial());
        }
    }

    #[test]
    fn space_sizes() {
        // max 1 → only the three serial lanes; each worker count adds 4.
        assert_eq!(search_space(1).len(), 3);
        assert_eq!(search_space(2).len(), 3 + 4); // w ∈ {2}
        assert_eq!(search_space(8).len(), 3 + 3 * 4); // w ∈ {2, 4, 8}
        assert_eq!(worker_counts(6), vec![2, 4, 6]);
    }

    #[test]
    fn space_includes_gemm_lanes() {
        // ISSUE 4 acceptance: the search space carries the PhaseGemm
        // formulation serial AND row-parallel.
        let space = search_space(4);
        assert!(space.contains(&ExecStrategy::serial_gemm()));
        assert!(space.contains(&ExecStrategy::gemm_parallel(2)));
        assert!(space.contains(&ExecStrategy::gemm_parallel(4)));
    }

    #[test]
    fn names_unique() {
        let names: Vec<String> = search_space_batch(8, 4)
            .iter()
            .map(ExecStrategy::name)
            .collect();
        let mut dedup = names.clone();
        dedup.sort();
        dedup.dedup();
        assert_eq!(names.len(), dedup.len(), "{names:?}");
    }

    #[test]
    fn batched_space_extends_per_latent_space() {
        // batch ≤ 1 is exactly the per-latent space; above it, the
        // per-latent space is a prefix (serial default still seeds the
        // incumbent) and the fused variants follow.
        assert_eq!(search_space_batch(4, 1), search_space(4));
        assert_eq!(search_space_batch(4, 0), search_space(4));
        let batched = search_space_batch(4, 8);
        let base = search_space(4);
        assert_eq!(&batched[..base.len()], &base[..]);
        assert_eq!(batched[0], ExecStrategy::serial());
        assert!(batched.contains(&ExecStrategy::serial_gemm().fused()));
        assert!(batched.contains(&ExecStrategy::gemm_parallel(4).fused()));
        assert!(batched.contains(&ExecStrategy::parallel(2, ParAxis::PhaseRows).fused()));
        // 1 fused serial gemm + 2 fused lanes per worker count {2, 4}.
        assert_eq!(batched.len(), base.len() + 1 + 2 * 2);
        assert_eq!(
            ExecStrategy::serial_gemm().fused().name(),
            "phase-gemm/serial/fused"
        );
        // The per-element formulation has no fused lane — normalized away.
        assert_eq!(
            ExecStrategy::serial_per_element().fused(),
            ExecStrategy::serial_per_element()
        );
    }

    #[test]
    fn backward_space_is_small_and_disjointly_defined() {
        // Serial direct seeds the incumbent; the space holds exactly
        // {serial, serial-gemm} + one parallel lane per worker count,
        // every member dispatchable by run_backward_data_with.  The
        // forward spaces keep their pinned sizes regardless.
        assert_eq!(backward_search_space(1).len(), 2);
        assert_eq!(backward_search_space(2).len(), 2 + 1);
        assert_eq!(backward_search_space(8).len(), 2 + 3);
        for max in [1, 2, 8] {
            let space = backward_search_space(max);
            assert_eq!(space[0], ExecStrategy::serial());
            assert!(space.contains(&ExecStrategy::serial_gemm()));
            assert!(!space.iter().any(|s| s.formulation == Formulation::PerElement));
            assert!(!space.iter().any(|s| s.fused));
            let mut names: Vec<String> = space.iter().map(ExecStrategy::name).collect();
            names.sort();
            names.dedup();
            assert_eq!(names.len(), space.len());
        }
        assert!(backward_search_space(8).contains(&ExecStrategy::parallel(4, ParAxis::PhaseRows)));
    }

    #[test]
    fn serial_lane_is_canonical() {
        // workers == 1 normalizes the axis, so Eq means semantic equality.
        assert_eq!(
            ExecStrategy::parallel(1, ParAxis::Rows),
            ExecStrategy::serial()
        );
        assert_eq!(ExecStrategy::per_element_parallel(0).workers, 1);
        assert_eq!(ExecStrategy::gemm_parallel(1), ExecStrategy::serial_gemm());
        assert_eq!(ExecStrategy::serial_gemm().name(), "phase-gemm/serial");
        assert_eq!(ExecStrategy::gemm_parallel(4).name(), "phase-gemm/par4");
    }

    #[test]
    fn json_roundtrip_whole_space() {
        for s in search_space_batch(8, 4) {
            let encoded = s.to_json().to_string_compact();
            let decoded =
                ExecStrategy::from_json(&crate::util::json::parse(&encoded).unwrap()).unwrap();
            assert_eq!(decoded, s, "{encoded}");
        }
    }

    #[test]
    fn from_json_rejects_malformed() {
        for bad in [
            r#"{"formulation":"phase","workers":0,"axis":"rows"}"#,
            r#"{"formulation":"gpu","workers":2,"axis":"rows"}"#,
            r#"{"formulation":"phase","workers":2,"axis":"cols"}"#,
            r#"{"workers":2,"axis":"rows"}"#,
            r#"[1,2,3]"#,
        ] {
            let v = crate::util::json::parse(bad).unwrap();
            assert_eq!(ExecStrategy::from_json(&v), None, "{bad}");
        }
    }
}
