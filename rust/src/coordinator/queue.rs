//! Bounded MPMC queue with blocking and non-blocking push — the
//! coordinator's backpressure primitive.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::Duration;

/// Result of a non-blocking push.
#[derive(Debug, PartialEq, Eq)]
pub enum TryPush<T> {
    Ok,
    /// Queue at capacity; the item is handed back.
    Full(T),
    /// Queue closed; the item is handed back.
    Closed(T),
}

struct Inner<T> {
    items: VecDeque<T>,
    closed: bool,
}

/// Bounded multi-producer multi-consumer queue.
pub struct BoundedQueue<T> {
    inner: Mutex<Inner<T>>,
    not_empty: Condvar,
    not_full: Condvar,
    capacity: usize,
}

impl<T> BoundedQueue<T> {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0, "queue capacity must be positive");
        BoundedQueue {
            inner: Mutex::new(Inner {
                items: VecDeque::with_capacity(capacity),
                closed: false,
            }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            capacity,
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Non-blocking push.
    pub fn try_push(&self, item: T) -> TryPush<T> {
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            return TryPush::Closed(item);
        }
        if g.items.len() >= self.capacity {
            return TryPush::Full(item);
        }
        g.items.push_back(item);
        drop(g);
        self.not_empty.notify_one();
        TryPush::Ok
    }

    /// Blocking push: waits for space (backpressure).  Returns the item
    /// back if the queue closes while waiting.
    pub fn push(&self, item: T) -> Result<(), T> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if g.closed {
                return Err(item);
            }
            if g.items.len() < self.capacity {
                g.items.push_back(item);
                drop(g);
                self.not_empty.notify_one();
                return Ok(());
            }
            g = self.not_full.wait(g).unwrap();
        }
    }

    /// Blocking pop.  `None` once the queue is closed AND drained.
    pub fn pop(&self) -> Option<T> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(item) = g.items.pop_front() {
                drop(g);
                self.not_full.notify_one();
                return Some(item);
            }
            if g.closed {
                return None;
            }
            g = self.not_empty.wait(g).unwrap();
        }
    }

    /// Pop with a deadline.  `Ok(None)` means timed out; `Err(())` means
    /// closed and drained.
    pub fn pop_timeout(&self, timeout: Duration) -> Result<Option<T>, ()> {
        let mut g = self.inner.lock().unwrap();
        let deadline = std::time::Instant::now() + timeout;
        loop {
            if let Some(item) = g.items.pop_front() {
                drop(g);
                self.not_full.notify_one();
                return Ok(Some(item));
            }
            if g.closed {
                return Err(());
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return Ok(None);
            }
            let (guard, res) = self.not_empty.wait_timeout(g, deadline - now).unwrap();
            g = guard;
            if res.timed_out() && g.items.is_empty() {
                if g.closed {
                    return Err(());
                }
                return Ok(None);
            }
        }
    }

    /// Drain up to `max` immediately-available items (non-blocking).
    pub fn drain_up_to(&self, max: usize) -> Vec<T> {
        let mut g = self.inner.lock().unwrap();
        let n = max.min(g.items.len());
        let out: Vec<T> = g.items.drain(..n).collect();
        drop(g);
        if !out.is_empty() {
            self.not_full.notify_all();
        }
        out
    }

    /// Close the queue: producers fail, consumers drain then get `None`.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    pub fn is_closed(&self) -> bool {
        self.inner.lock().unwrap().closed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::thread;

    #[test]
    fn fifo_order() {
        let q = BoundedQueue::new(4);
        for i in 0..4 {
            assert_eq!(q.try_push(i), TryPush::Ok);
        }
        for i in 0..4 {
            assert_eq!(q.pop(), Some(i));
        }
    }

    #[test]
    fn try_push_full() {
        let q = BoundedQueue::new(1);
        assert_eq!(q.try_push(1), TryPush::Ok);
        assert_eq!(q.try_push(2), TryPush::Full(2));
    }

    #[test]
    fn close_rejects_producers_drains_consumers() {
        let q = BoundedQueue::new(4);
        q.try_push(1);
        q.close();
        assert_eq!(q.try_push(2), TryPush::Closed(2));
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn blocking_push_applies_backpressure() {
        let q = Arc::new(BoundedQueue::new(1));
        q.try_push(0);
        let q2 = Arc::clone(&q);
        let producer = thread::spawn(move || q2.push(1).is_ok());
        thread::sleep(Duration::from_millis(20));
        assert_eq!(q.pop(), Some(0)); // frees space
        assert!(producer.join().unwrap());
        assert_eq!(q.pop(), Some(1));
    }

    #[test]
    fn pop_timeout_times_out() {
        let q: BoundedQueue<u32> = BoundedQueue::new(1);
        let r = q.pop_timeout(Duration::from_millis(10));
        assert_eq!(r, Ok(None));
    }

    #[test]
    fn pop_timeout_closed() {
        let q: BoundedQueue<u32> = BoundedQueue::new(1);
        q.close();
        assert_eq!(q.pop_timeout(Duration::from_millis(10)), Err(()));
    }

    #[test]
    fn drain_up_to_bounded() {
        let q = BoundedQueue::new(8);
        for i in 0..6 {
            q.try_push(i);
        }
        let d = q.drain_up_to(4);
        assert_eq!(d, vec![0, 1, 2, 3]);
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn concurrent_producers_consumers() {
        let q = Arc::new(BoundedQueue::new(16));
        let mut handles = Vec::new();
        for p in 0..4 {
            let q = Arc::clone(&q);
            handles.push(thread::spawn(move || {
                for i in 0..100 {
                    q.push(p * 1000 + i).unwrap();
                }
            }));
        }
        let consumed = Arc::new(Mutex::new(Vec::new()));
        let mut consumers = Vec::new();
        for _ in 0..2 {
            let q = Arc::clone(&q);
            let consumed = Arc::clone(&consumed);
            consumers.push(thread::spawn(move || {
                while let Some(v) = q.pop() {
                    consumed.lock().unwrap().push(v);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        q.close();
        for c in consumers {
            c.join().unwrap();
        }
        let mut got = consumed.lock().unwrap().clone();
        got.sort_unstable();
        assert_eq!(got.len(), 400);
        got.dedup();
        assert_eq!(got.len(), 400);
    }
}
