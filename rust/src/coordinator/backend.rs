//! Model-execution backends.
//!
//! [`Backend`] abstracts "turn a batch of latents into images" so the
//! worker loop is agnostic to whether inference runs on the native Rust
//! unified kernels ([`RustBackend`]) or on an AOT-compiled HLO module
//! via PJRT ([`crate::runtime::PjrtBackend`]).

use crate::conv::parallel::{Algorithm, Lane};
use crate::models::{Generator, GanModel};
use crate::tensor::Feature;
use crate::util::rng::Rng;

/// A batched latent→image executor.
pub trait Backend: Send + Sync {
    /// Model name (router key).
    fn model_name(&self) -> &str;

    /// Latent dimensionality this backend expects.
    fn z_dim(&self) -> usize;

    /// Largest batch the backend can serve in one call.
    fn max_batch(&self) -> usize;

    /// Generate one image per latent.  `latents.len() ≤ max_batch()`.
    fn generate(&self, latents: &[Vec<f32>]) -> Vec<Feature>;
}

/// Native backend: the Rust generator running the **unified** kernel
/// (or any other algorithm, for A/B serving experiments).
pub struct RustBackend {
    pub generator: Generator,
    pub alg: Algorithm,
    pub lane: Lane,
    max_batch: usize,
}

impl RustBackend {
    pub fn new(model: GanModel, alg: Algorithm, lane: Lane, seed: u64, max_batch: usize) -> Self {
        let mut rng = Rng::seeded(seed);
        RustBackend {
            generator: Generator::random(model, &mut rng),
            alg,
            lane,
            max_batch: max_batch.max(1),
        }
    }

    /// Wrap an existing generator (e.g. a shrunken test model).
    pub fn from_generator(generator: Generator, alg: Algorithm, lane: Lane, max_batch: usize) -> Self {
        RustBackend {
            generator,
            alg,
            lane,
            max_batch: max_batch.max(1),
        }
    }
}

impl Backend for RustBackend {
    fn model_name(&self) -> &str {
        self.generator.model.name()
    }

    fn z_dim(&self) -> usize {
        self.generator.model.z_dim()
    }

    fn max_batch(&self) -> usize {
        self.max_batch
    }

    fn generate(&self, latents: &[Vec<f32>]) -> Vec<Feature> {
        latents
            .iter()
            .map(|z| self.generator.forward(z, self.alg, self.lane))
            .collect()
    }
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;
    use crate::conv::segregation::segregate;
    use crate::models::{forward::LayerWeights, zoo::LayerSpec};
    use crate::tensor::Kernel;

    /// A millisecond-fast generator for coordinator tests.
    pub fn tiny_backend(alg: Algorithm) -> RustBackend {
        let mut rng = Rng::seeded(99);
        let mut g = Generator::random(GanModel::GpGan, &mut rng);
        let specs = [LayerSpec::gan(4, 6, 4), LayerSpec::gan(8, 4, 3)];
        g.layers = specs
            .iter()
            .map(|&spec| {
                let kernel = Kernel::random(spec.ksize, spec.cin, spec.cout, &mut rng);
                let seg = segregate(&kernel);
                LayerWeights {
                    spec,
                    kernel,
                    seg,
                    bias: vec![0.0; spec.cout],
                }
            })
            .collect();
        let out0 = 4 * 4 * 6;
        g.proj_w = vec![0.01; g.model.z_dim() * out0];
        g.proj_b = vec![0.0; out0];
        RustBackend::from_generator(g, alg, Lane::Serial, 8)
    }
}

#[cfg(test)]
mod tests {
    use super::testutil::tiny_backend;
    use super::*;

    #[test]
    fn generates_batch() {
        let b = tiny_backend(Algorithm::Unified);
        let latents: Vec<Vec<f32>> = (0..3).map(|i| vec![0.1 * i as f32; b.z_dim()]).collect();
        let imgs = b.generate(&latents);
        assert_eq!(imgs.len(), 3);
        for img in &imgs {
            assert_eq!((img.h, img.w, img.c), (16, 16, 3));
        }
    }

    #[test]
    fn backend_algorithms_agree() {
        let a = tiny_backend(Algorithm::Unified);
        let b = tiny_backend(Algorithm::Conventional); // same seed → same weights
        let z = vec![vec![0.3; a.z_dim()]];
        let ia = a.generate(&z);
        let ib = b.generate(&z);
        assert!(crate::tensor::ops::max_abs_diff(&ia[0], &ib[0]) < 1e-3);
    }

    #[test]
    fn reports_metadata() {
        let b = tiny_backend(Algorithm::Unified);
        assert_eq!(b.model_name(), "gpgan");
        assert_eq!(b.z_dim(), 100);
        assert_eq!(b.max_batch(), 8);
    }
}
