//! Model-execution backends.
//!
//! [`Backend`] abstracts "turn a batch of latents into images" so the
//! worker loop is agnostic to whether inference runs on the native Rust
//! unified kernels ([`RustBackend`]) or on an AOT-compiled HLO module
//! via PJRT ([`crate::runtime::PjrtBackend`]).

use std::path::Path;
use std::sync::Mutex;

use crate::conv::parallel::{Algorithm, Lane};
use crate::conv::plan::Scratch;
use crate::conv::quant::Precision;
use crate::models::{Generator, GanModel};
use crate::tensor::Feature;
use crate::tune::{ExecStrategy, Tuner, TuningCache, WallClockMeasurer};
use crate::util::rng::Rng;
use crate::util::threadpool;

/// A batched latent→image executor.
pub trait Backend: Send + Sync {
    /// Model name (router key).
    fn model_name(&self) -> &str;

    /// Latent dimensionality this backend expects.
    fn z_dim(&self) -> usize;

    /// Largest batch the backend can serve in one call.
    fn max_batch(&self) -> usize;

    /// Generate one image per latent.  `latents.len() ≤ max_batch()`.
    fn generate(&self, latents: &[Vec<f32>]) -> Vec<Feature>;
}

/// Native backend: the Rust generator running the **unified** kernel
/// (or any other algorithm, for A/B serving experiments).
///
/// Executes through the per-layer
/// [`ConvTransposePlan`](crate::conv::plan::ConvTransposePlan)s with a
/// pool of scratch arenas that persists across batches (one arena per
/// concurrent worker), so steady-state batches allocate activations
/// only — never planning structures or conv scratch.
///
/// The default unified path is **fused batched** (DESIGN.md
/// §Batched-Execution): one `Generator::forward_batch_with` call
/// executes the whole dynamic batch through every layer, reusing each
/// phase's plan-time-packed GEMM operand across all `N` latents.  Two
/// A/B lanes remain: [`with_per_latent`](Self::with_per_latent) keeps
/// the historic one-forward-per-latent loop, and
/// [`with_batch_workers`](Self::with_batch_workers) fans the latents of
/// one batch out across scoped threads (parallelism across latents ×
/// phases, on top of the row-level [`Lane::Parallel`] lane).
pub struct RustBackend {
    pub generator: Generator,
    pub alg: Algorithm,
    pub lane: Lane,
    max_batch: usize,
    /// Threads that split one batch's latents (1 = in-line).
    batch_workers: usize,
    /// `false` → per-call (unplanned) dispatch, the A/B ablation lane.
    planned: bool,
    /// `false` → loop the batch per latent instead of the fused
    /// batched forward (the fused-vs-per-latent serving A/B lane).
    fused_batch: bool,
    /// Storage precision the quantized autotune settled on (`F32`
    /// when no quantized search ran or none passed its budget).
    serving_precision: Precision,
    /// Warm scratch arenas, reused across batches.  Bounded by the
    /// number of concurrent `generate` workers.
    arenas: Mutex<Vec<Scratch>>,
}

impl RustBackend {
    pub fn new(model: GanModel, alg: Algorithm, lane: Lane, seed: u64, max_batch: usize) -> Self {
        let mut rng = Rng::seeded(seed);
        RustBackend::from_generator(Generator::random(model, &mut rng), alg, lane, max_batch)
    }

    /// Wrap an existing generator (e.g. a shrunken test model).
    pub fn from_generator(
        generator: Generator,
        alg: Algorithm,
        lane: Lane,
        max_batch: usize,
    ) -> Self {
        RustBackend {
            generator,
            alg,
            lane,
            max_batch: max_batch.max(1),
            batch_workers: 1,
            planned: true,
            fused_batch: true,
            serving_precision: Precision::F32,
            arenas: Mutex::new(Vec::new()),
        }
    }

    /// Fan each batch's latents out over `workers` threads, one scratch
    /// arena per worker (a per-latent A/B lane — the fused batched
    /// forward is not used).
    pub fn with_batch_workers(mut self, workers: usize) -> Self {
        self.batch_workers = workers.max(1);
        self
    }

    /// Disable the ahead-of-time planned path (planned-vs-unplanned
    /// serving ablation; see `bench::serving`).
    pub fn with_unplanned(mut self) -> Self {
        self.planned = false;
        self
    }

    /// Serve each batch as a per-latent loop instead of the fused
    /// batched forward (the fused-vs-per-latent serving ablation; see
    /// `bench::serving`).
    pub fn with_per_latent(mut self) -> Self {
        self.fused_batch = false;
        self
    }

    /// Whether this backend serves batches through the fused batched
    /// forward.
    pub fn is_fused_batch(&self) -> bool {
        self.fused_batch
            && self.planned
            && self.batch_workers == 1
            && self.alg == Algorithm::Unified
    }

    /// Autotune every layer of the model at construction (DESIGN.md
    /// §Autotuning): search the execution-strategy space per layer —
    /// through the tuning cache at `cache_path` when given, so a
    /// machine pays the search once — and pin the winners on the
    /// generator.  The pinned strategies drive the unified planned
    /// path for every request (including the batch-worker lane, whose
    /// latent fan-out composes on top).  The direct strategies are
    /// bit-identical to the untuned execution; a
    /// [`PhaseGemm`](crate::tune::Formulation::PhaseGemm) verdict runs
    /// the planned packed-GEMM engine, equivalent within 1e-4 (f32
    /// reassociation — DESIGN.md §GEMM-Execution), so tuning can never
    /// change served results beyond that tolerance.  Cache I/O
    /// problems are downgraded to warnings: serving must come up even
    /// on a read-only filesystem.
    pub fn with_autotune(self, cache_path: Option<&Path>) -> Self {
        self.with_autotune_tuner(cache_path, &Tuner::new(threadpool::default_parallelism()))
    }

    /// [`with_autotune`](Self::with_autotune) searching **batched**
    /// strategies for serving batch size `batch` (DESIGN.md
    /// §Batched-Execution): candidates are timed serving whole
    /// batches — fused batched lanes included — and verdicts persist
    /// under the batch-extended cache key, so `ukstc serve
    /// --tune-cache` plumbs `ukstc tune --batch N` verdicts straight
    /// into the fused serving path.
    pub fn with_autotune_batch(self, cache_path: Option<&Path>, batch: usize) -> Self {
        self.with_autotune_tuner(
            cache_path,
            &Tuner::for_batch(threadpool::default_parallelism(), batch),
        )
    }

    /// [`with_autotune`](Self::with_autotune) with an explicit tuner
    /// (search space + measurement budget) — tests and the CLI use
    /// tighter budgets.
    pub fn with_autotune_tuner(mut self, cache_path: Option<&Path>, tuner: &Tuner) -> Self {
        let mut cache = match cache_path {
            Some(p) => TuningCache::load(p).unwrap_or_else(|e| {
                log::warn!("tuning cache {}: {e}; re-tuning from scratch", p.display());
                TuningCache::backed(p)
            }),
            None => TuningCache::in_memory(),
        };
        let mut measurer = WallClockMeasurer::new(tuner.budget);
        let strategies: Vec<ExecStrategy> = self
            .generator
            .layers
            .iter()
            .map(|lw| {
                let tuned = tuner.tune_layer_cached(&lw.plan, &mut cache, &mut measurer);
                log::info!(
                    "autotune {} {}: {} ({}){}",
                    self.generator.model.name(),
                    lw.spec.describe(),
                    tuned.strategy.name(),
                    crate::util::timing::fmt_duration(tuned.best_seconds),
                    if tuned.cached { " [cache hit]" } else { "" }
                );
                tuned.strategy
            })
            .collect();
        self.generator.set_strategies(&strategies);
        if let Err(e) = cache.save() {
            log::warn!("could not persist tuning cache: {e}");
        }
        self
    }

    /// Tune every layer under `tuner` through `cache`, returning the
    /// per-layer winners plus the summed best seconds (the model-level
    /// figure the precision search compares).
    fn tuned_strategies(
        &self,
        tuner: &Tuner,
        cache: &mut TuningCache,
        measurer: &mut WallClockMeasurer,
    ) -> (Vec<ExecStrategy>, f64) {
        let mut total = 0.0;
        let strategies = self
            .generator
            .layers
            .iter()
            .map(|lw| {
                let tuned = tuner.tune_layer_cached(&lw.plan, cache, measurer);
                log::info!(
                    "autotune {} {}: {} ({}){}",
                    self.generator.model.name(),
                    lw.spec.describe(),
                    tuned.strategy.name(),
                    crate::util::timing::fmt_duration(tuned.best_seconds),
                    if tuned.cached { " [cache hit]" } else { "" }
                );
                total += tuned.best_seconds;
                tuned.strategy
            })
            .collect();
        (strategies, total)
    }

    /// [`with_autotune`](Self::with_autotune) extended with a
    /// **precision search** (ISSUE 10 / DESIGN.md §Reduced-Precision):
    /// after the f32 search, every quantized [`Precision`] lane is
    /// tuned per layer (verdicts cache under the `+{prec}`-suffixed
    /// keys), and a candidate precision is adopted only when its
    /// summed per-layer time beats the incumbent **and** a
    /// whole-model probe forward drifts at most `accuracy_budget`
    /// (max-abs elementwise, in the generator's tanh output range
    /// `[-1, 1]`) from the f32-tuned reference.  A budget of `0.0`
    /// therefore always serves f32.
    pub fn with_autotune_quantized(self, cache_path: Option<&Path>, accuracy_budget: f32) -> Self {
        self.with_autotune_tuner_quantized(
            cache_path,
            &Tuner::new(threadpool::default_parallelism()),
            accuracy_budget,
        )
    }

    /// [`with_autotune_quantized`](Self::with_autotune_quantized) with
    /// an explicit base tuner (search space + measurement budget).
    /// The quantized searches are the base tuner under
    /// [`Tuner::pin_precision`], so batch size and worker bound carry
    /// over and all verdicts share one cache file.
    pub fn with_autotune_tuner_quantized(
        mut self,
        cache_path: Option<&Path>,
        tuner: &Tuner,
        accuracy_budget: f32,
    ) -> Self {
        let mut cache = match cache_path {
            Some(p) => TuningCache::load(p).unwrap_or_else(|e| {
                log::warn!("tuning cache {}: {e}; re-tuning from scratch", p.display());
                TuningCache::backed(p)
            }),
            None => TuningCache::in_memory(),
        };
        let mut measurer = WallClockMeasurer::new(tuner.budget);
        let (mut best, mut best_secs) = self.tuned_strategies(tuner, &mut cache, &mut measurer);
        // Deterministic probe latent; the f32-tuned forward is the
        // accuracy reference (within its own 1e-4 GEMM contract of the
        // untuned model — the budget gates *additional* quantization
        // drift).
        let mut rng = Rng::seeded(0xACC);
        let z: Vec<f32> = (0..self.generator.model.z_dim())
            .map(|_| rng.normal_f32())
            .collect();
        let mut probe_gen = self.generator.clone();
        probe_gen.set_strategies(&best);
        let reference = probe_gen.forward(&z, Algorithm::Unified, Lane::Serial);
        let mut chosen = Precision::F32;
        for prec in Precision::QUANTIZED {
            let qt = tuner.clone().pin_precision(prec);
            let (strats, secs) = self.tuned_strategies(&qt, &mut cache, &mut measurer);
            if secs >= best_secs {
                log::info!(
                    "autotune precision {}: {} ≥ incumbent {} — skipped",
                    prec.name(),
                    crate::util::timing::fmt_duration(secs),
                    crate::util::timing::fmt_duration(best_secs)
                );
                continue;
            }
            probe_gen.set_strategies(&strats);
            let probe = probe_gen.forward(&z, Algorithm::Unified, Lane::Serial);
            let drift = crate::tensor::ops::max_abs_diff(&probe, &reference);
            if drift <= accuracy_budget {
                log::info!(
                    "autotune precision {}: accepted (drift {drift:.2e} ≤ budget {accuracy_budget:.2e})",
                    prec.name()
                );
                best = strats;
                best_secs = secs;
                chosen = prec;
            } else {
                log::info!(
                    "autotune precision {}: rejected (drift {drift:.2e} > budget {accuracy_budget:.2e})",
                    prec.name()
                );
            }
        }
        log::info!("autotune precision verdict: {}", chosen.name());
        self.serving_precision = chosen;
        self.generator.set_strategies(&best);
        if let Err(e) = cache.save() {
            log::warn!("could not persist tuning cache: {e}");
        }
        self
    }

    /// The storage precision the quantized autotune settled on
    /// (`F32` unless [`with_autotune_quantized`](Self::with_autotune_quantized)
    /// accepted a faster quantized lane within its accuracy budget).
    pub fn serving_precision(&self) -> Precision {
        self.serving_precision
    }

    /// Whether this backend runs the planned execution path.
    pub fn is_planned(&self) -> bool {
        self.planned
    }

    fn generate_one(&self, z: &[f32], scratch: &mut Scratch) -> Feature {
        if self.planned {
            self.generator.forward_with(z, self.alg, self.lane, scratch)
        } else {
            self.generator.forward_unplanned(z, self.alg, self.lane)
        }
    }

    /// Pop a warm arena from the pool (pre-sized on first use — to the
    /// max-batch fused requirement on the fused lane, so steady-state
    /// batches of any admissible size never grow it).
    fn take_arena(&self) -> Scratch {
        self.arenas.lock().unwrap().pop().unwrap_or_else(|| {
            if self.is_fused_batch() {
                self.generator.scratch_batch(self.max_batch, self.lane)
            } else {
                self.generator.scratch()
            }
        })
    }

    /// Return an arena to the pool for the next batch.
    fn put_arena(&self, scratch: Scratch) {
        self.arenas.lock().unwrap().push(scratch);
    }
}

impl Backend for RustBackend {
    fn model_name(&self) -> &str {
        self.generator.model.name()
    }

    fn z_dim(&self) -> usize {
        self.generator.model.z_dim()
    }

    fn max_batch(&self) -> usize {
        self.max_batch
    }

    fn generate(&self, latents: &[Vec<f32>]) -> Vec<Feature> {
        let workers = self.batch_workers.min(latents.len()).max(1);
        if workers <= 1 {
            if self.is_fused_batch() && !latents.is_empty() {
                // Fused batched lane (the default): one forward call
                // serves the whole dynamic batch, so every layer's
                // packed GEMM operands are fetched once per batch
                // instead of once per latent.
                let mut scratch = self.take_arena();
                let images = self.generator.forward_batch_with(latents, self.lane, &mut scratch);
                self.put_arena(scratch);
                return images.into_features();
            }
            let mut scratch = self.take_arena();
            let images = latents
                .iter()
                .map(|z| self.generate_one(z, &mut scratch))
                .collect();
            self.put_arena(scratch);
            return images;
        }
        // Batch-parallel lane: a shared work queue of latents, each
        // worker owns one warm arena for its whole share of the batch.
        let mut images: Vec<Feature> = latents.iter().map(|_| Feature::zeros(0, 0, 0)).collect();
        let jobs: Vec<(usize, &mut Feature)> = images.iter_mut().enumerate().collect();
        let jobs = Mutex::new(jobs);
        let jobs = &jobs;
        std::thread::scope(|s| {
            for _ in 0..workers {
                s.spawn(move || {
                    let mut scratch = self.take_arena();
                    loop {
                        let job = jobs.lock().unwrap().pop();
                        let Some((i, slot)) = job else { break };
                        *slot = self.generate_one(&latents[i], &mut scratch);
                    }
                    self.put_arena(scratch);
                });
            }
        });
        images
    }
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;
    use crate::models::{forward::LayerWeights, zoo::LayerSpec};
    use crate::tensor::Kernel;

    /// A millisecond-fast generator for coordinator tests.
    pub fn tiny_backend(alg: Algorithm) -> RustBackend {
        let mut rng = Rng::seeded(99);
        let mut g = Generator::random(GanModel::GpGan, &mut rng);
        let specs = [LayerSpec::gan(4, 6, 4), LayerSpec::gan(8, 4, 3)];
        g.layers = specs
            .iter()
            .map(|&spec| {
                let kernel = Kernel::random(spec.ksize, spec.cin, spec.cout, &mut rng);
                LayerWeights::new(spec, kernel, vec![0.0; spec.cout])
            })
            .collect();
        let out0 = 4 * 4 * 6;
        g.proj_w = vec![0.01; g.model.z_dim() * out0];
        g.proj_b = vec![0.0; out0];
        RustBackend::from_generator(g, alg, Lane::Serial, 8)
    }
}

#[cfg(test)]
mod tests {
    use super::testutil::tiny_backend;
    use super::*;

    #[test]
    fn generates_batch() {
        let b = tiny_backend(Algorithm::Unified);
        let latents: Vec<Vec<f32>> = (0..3).map(|i| vec![0.1 * i as f32; b.z_dim()]).collect();
        let imgs = b.generate(&latents);
        assert_eq!(imgs.len(), 3);
        for img in &imgs {
            assert_eq!((img.h, img.w, img.c), (16, 16, 3));
        }
    }

    #[test]
    fn backend_algorithms_agree() {
        let a = tiny_backend(Algorithm::Unified);
        let b = tiny_backend(Algorithm::Conventional); // same seed → same weights
        let z = vec![vec![0.3; a.z_dim()]];
        let ia = a.generate(&z);
        let ib = b.generate(&z);
        assert!(crate::tensor::ops::max_abs_diff(&ia[0], &ib[0]) < 1e-3);
    }

    #[test]
    fn batch_parallel_lane_matches_serial() {
        let serial = tiny_backend(Algorithm::Unified);
        let latents: Vec<Vec<f32>> = (0..7)
            .map(|i| vec![0.05 * (i + 1) as f32; serial.z_dim()])
            .collect();
        let want = serial.generate(&latents);
        for workers in [2, 3, 16] {
            let par = tiny_backend(Algorithm::Unified).with_batch_workers(workers);
            let got = par.generate(&latents);
            assert_eq!(got.len(), want.len());
            for (g, w) in got.iter().zip(&want) {
                assert_eq!(g, w, "batch-parallel ({workers}) diverged");
            }
        }
    }

    #[test]
    fn fused_batch_lane_matches_per_latent_bit_identically() {
        // The default generate is now the fused batched forward; with
        // no pinned strategies it runs the batched direct lanes, which
        // must reproduce the per-latent loop exactly — ragged batch
        // sizes (1 and 3 under max_batch 8) included.
        let fused = tiny_backend(Algorithm::Unified);
        let per_latent = tiny_backend(Algorithm::Unified).with_per_latent();
        assert!(fused.is_fused_batch() && !per_latent.is_fused_batch());
        for n in [1usize, 3, 8] {
            let latents: Vec<Vec<f32>> = (0..n)
                .map(|i| vec![0.03 * (i + 1) as f32; fused.z_dim()])
                .collect();
            let got = fused.generate(&latents);
            let want = per_latent.generate(&latents);
            assert_eq!(got.len(), n);
            assert_eq!(got, want, "fused batch diverged at n={n}");
        }
    }

    #[test]
    fn non_unified_backends_skip_the_fused_lane() {
        let conv = tiny_backend(Algorithm::Conventional);
        assert!(!conv.is_fused_batch());
        let imgs = conv.generate(&vec![vec![0.2; conv.z_dim()]; 2]);
        assert_eq!(imgs.len(), 2);
    }

    #[test]
    fn unplanned_lane_matches_planned() {
        let planned = tiny_backend(Algorithm::Unified);
        let unplanned = tiny_backend(Algorithm::Unified).with_unplanned();
        assert!(planned.is_planned() && !unplanned.is_planned());
        let z = vec![vec![0.2; planned.z_dim()]; 2];
        assert_eq!(planned.generate(&z), unplanned.generate(&z));
    }

    #[test]
    fn autotuned_backend_serves_equivalent_results() {
        use crate::tune::{Formulation, MeasureBudget};
        let baseline = tiny_backend(Algorithm::Unified);
        let latents: Vec<Vec<f32>> = (0..3)
            .map(|i| vec![0.07 * (i + 1) as f32; baseline.z_dim()])
            .collect();
        let want = baseline.generate(&latents);
        let tuner = Tuner::new(2).with_budget(MeasureBudget::quick());
        let tuned = tiny_backend(Algorithm::Unified)
            .with_autotune_tuner(None, &tuner)
            .with_batch_workers(2);
        let pinned = tuned.generator.strategies();
        assert!(pinned.iter().all(Option::is_some));
        let got = tuned.generate(&latents);
        // Direct verdicts are bit-identical; a PhaseGemm verdict is
        // allowed the 1e-4 reassociation tolerance (ISSUE 4).
        if pinned
            .iter()
            .all(|s| s.unwrap().formulation != Formulation::PhaseGemm)
        {
            assert_eq!(got, want, "direct autotune verdicts changed output bits");
        } else {
            for (g, w) in got.iter().zip(&want) {
                assert!(
                    crate::tensor::ops::max_abs_diff(g, w) < 1e-4,
                    "autotune changed output beyond the GEMM tolerance"
                );
            }
        }
    }

    #[test]
    fn quantized_autotune_zero_budget_serves_f32() {
        // ISSUE 10 satellite: a 0.0 accuracy budget can never accept a
        // quantized lane (quantization always drifts), so the verdict
        // is f32 and every pinned strategy stays full precision.
        use crate::tune::MeasureBudget;
        let tuner = Tuner::new(2).with_budget(MeasureBudget::quick());
        let b = tiny_backend(Algorithm::Unified).with_autotune_tuner_quantized(None, &tuner, 0.0);
        assert_eq!(b.serving_precision(), Precision::F32);
        assert!(b
            .generator
            .strategies()
            .iter()
            .all(|s| s.unwrap().precision == Precision::F32));
        let imgs = b.generate(&[vec![0.2; b.z_dim()]]);
        assert_eq!(imgs.len(), 1);
    }

    #[test]
    fn quantized_autotune_respects_accuracy_budget() {
        // With a generous budget the search may or may not adopt a
        // quantized lane (speed is machine-dependent) — but whatever it
        // picks must (a) pin one consistent precision across the GEMM
        // layers matching `serving_precision`, and (b) serve outputs
        // within the budget of the f32-tuned reference.
        use crate::tune::{Formulation, MeasureBudget};
        let budget = 0.05f32;
        let tuner = Tuner::new(2).with_budget(MeasureBudget::quick());
        let f32_tuned = tiny_backend(Algorithm::Unified).with_autotune_tuner(None, &tuner);
        let quant =
            tiny_backend(Algorithm::Unified).with_autotune_tuner_quantized(None, &tuner, budget);
        let chosen = quant.serving_precision();
        for s in quant.generator.strategies() {
            let s = s.unwrap();
            match s.formulation {
                Formulation::PhaseGemm => assert_eq!(s.precision, chosen),
                _ => assert_eq!(s.precision, Precision::F32),
            }
        }
        let latents: Vec<Vec<f32>> = (0..2)
            .map(|i| vec![0.06 * (i + 1) as f32; quant.z_dim()])
            .collect();
        let got = quant.generate(&latents);
        let want = f32_tuned.generate(&latents);
        for (g, w) in got.iter().zip(&want) {
            let drift = crate::tensor::ops::max_abs_diff(g, w);
            // Budget on top of the GEMM lanes' own reassociation
            // contract (both backends' f32 searches may pick different
            // strategies, each ≤1e-4 from the direct reference).
            assert!(
                drift <= budget + 1e-3,
                "served drift {drift} exceeds accuracy budget"
            );
        }
    }

    #[test]
    fn autotune_persists_cache_file() {
        use crate::tune::MeasureBudget;
        let dir = std::env::temp_dir().join(format!("ukstc-backend-tune-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cache.json");
        let _ = std::fs::remove_file(&path);
        let tuner = Tuner::new(2).with_budget(MeasureBudget::quick());
        let _b = tiny_backend(Algorithm::Unified).with_autotune_tuner(Some(&path), &tuner);
        let cache = TuningCache::load(&path).unwrap();
        assert_eq!(cache.len(), 2, "one verdict per tiny-backend layer");
        // Second construction resolves every layer from the cache.
        let again = tiny_backend(Algorithm::Unified).with_autotune_tuner(Some(&path), &tuner);
        assert!(again.generator.strategies().iter().all(Option::is_some));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn reports_metadata() {
        let b = tiny_backend(Algorithm::Unified);
        assert_eq!(b.model_name(), "gpgan");
        assert_eq!(b.z_dim(), 100);
        assert_eq!(b.max_batch(), 8);
    }
}
