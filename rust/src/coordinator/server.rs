//! The coordinator: router + per-model worker pools + lifecycle.

use std::collections::BTreeMap;
use std::sync::mpsc::{self, Receiver};
use std::sync::Arc;
use std::thread::JoinHandle;

use super::backend::Backend;
use super::batcher::BatchPolicy;
use super::metrics::{Metrics, Snapshot};
use super::queue::{BoundedQueue, TryPush};
use super::request::{GenRequest, GenResponse, SubmitError};
use super::worker::{worker_loop, Envelope};

struct ModelLane {
    queue: Arc<BoundedQueue<Envelope>>,
    metrics: Arc<Metrics>,
    z_dim: usize,
    workers: Vec<JoinHandle<()>>,
}

/// The serving coordinator.  Construct with [`Coordinator::builder`],
/// submit with [`Coordinator::submit`], stop with
/// [`Coordinator::shutdown`] (also runs on drop).
pub struct Coordinator {
    lanes: BTreeMap<String, ModelLane>,
}

/// Builder: register one backend per model, then `start()`.
pub struct Builder {
    queue_capacity: usize,
    workers_per_model: usize,
    policy: BatchPolicy,
    backends: Vec<Arc<dyn Backend>>,
}

impl Coordinator {
    pub fn builder() -> Builder {
        Builder {
            queue_capacity: 256,
            workers_per_model: 1,
            policy: BatchPolicy::default(),
            backends: Vec::new(),
        }
    }

    /// Route a request to its model lane.  Non-blocking: a full queue is
    /// surfaced as [`SubmitError::QueueFull`] (backpressure to clients).
    ///
    /// `created` is re-stamped at admission so latency metrics measure
    /// admission→completion (a pre-built trace would otherwise charge
    /// its generation time to the queue).
    pub fn submit(&self, mut request: GenRequest) -> Result<Receiver<GenResponse>, SubmitError> {
        request.created = std::time::Instant::now();
        let lane = self
            .lanes
            .get(&request.model)
            .ok_or_else(|| SubmitError::UnknownModel(request.model.clone()))?;
        if request.latent.len() != lane.z_dim {
            return Err(SubmitError::BadLatent {
                got: request.latent.len(),
                want: lane.z_dim,
            });
        }
        let (tx, rx) = mpsc::channel();
        let model = request.model.clone();
        lane.metrics.record_submit();
        match lane.queue.try_push(Envelope {
            request,
            respond: tx,
        }) {
            TryPush::Ok => Ok(rx),
            TryPush::Full(_) => {
                lane.metrics.record_reject();
                Err(SubmitError::QueueFull(model))
            }
            TryPush::Closed(_) => Err(SubmitError::ShuttingDown),
        }
    }

    /// Blocking submit: waits for queue space instead of rejecting.
    pub fn submit_blocking(
        &self,
        mut request: GenRequest,
    ) -> Result<Receiver<GenResponse>, SubmitError> {
        request.created = std::time::Instant::now();
        let lane = self
            .lanes
            .get(&request.model)
            .ok_or_else(|| SubmitError::UnknownModel(request.model.clone()))?;
        if request.latent.len() != lane.z_dim {
            return Err(SubmitError::BadLatent {
                got: request.latent.len(),
                want: lane.z_dim,
            });
        }
        let (tx, rx) = mpsc::channel();
        lane.metrics.record_submit();
        lane.queue
            .push(Envelope {
                request,
                respond: tx,
            })
            .map_err(|_| SubmitError::ShuttingDown)?;
        Ok(rx)
    }

    /// Metrics snapshot for one model.
    pub fn metrics(&self, model: &str) -> Option<Snapshot> {
        self.lanes.get(model).map(|l| l.metrics.snapshot())
    }

    /// Registered model names.
    pub fn models(&self) -> Vec<&str> {
        self.lanes.keys().map(String::as_str).collect()
    }

    /// Drain queues and join all workers.
    pub fn shutdown(&mut self) {
        for lane in self.lanes.values() {
            lane.queue.close();
        }
        for lane in self.lanes.values_mut() {
            for handle in lane.workers.drain(..) {
                let _ = handle.join();
            }
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shutdown();
    }
}

impl Builder {
    pub fn queue_capacity(mut self, n: usize) -> Self {
        self.queue_capacity = n.max(1);
        self
    }

    pub fn workers_per_model(mut self, n: usize) -> Self {
        self.workers_per_model = n.max(1);
        self
    }

    pub fn batch_policy(mut self, policy: BatchPolicy) -> Self {
        self.policy = policy;
        self
    }

    pub fn register(mut self, backend: Arc<dyn Backend>) -> Self {
        self.backends.push(backend);
        self
    }

    /// Spawn the worker pools and return the running coordinator.
    pub fn start(self) -> anyhow::Result<Coordinator> {
        if self.backends.is_empty() {
            anyhow::bail!("coordinator needs at least one backend");
        }
        let mut lanes = BTreeMap::new();
        for backend in self.backends {
            let name = backend.model_name().to_string();
            if lanes.contains_key(&name) {
                anyhow::bail!("duplicate backend for model '{name}'");
            }
            let queue = Arc::new(BoundedQueue::new(self.queue_capacity));
            let metrics = Arc::new(Metrics::for_max_batch(self.policy.max_batch));
            // Weak registration: the lane's metrics show up in the
            // process-wide registry (`serve.<model>.*`) for as long as
            // the coordinator lives, and vanish with it.
            let weak: std::sync::Weak<dyn crate::obs::registry::Collector> =
                Arc::downgrade(&metrics);
            crate::obs::registry::register_collector(&format!("serve.{name}"), weak);
            let mut workers = Vec::with_capacity(self.workers_per_model);
            for w in 0..self.workers_per_model {
                let (q, b, m, p) = (
                    Arc::clone(&queue),
                    Arc::clone(&backend),
                    Arc::clone(&metrics),
                    self.policy,
                );
                workers.push(
                    std::thread::Builder::new()
                        .name(format!("ukstc-worker-{name}-{w}"))
                        .spawn(move || worker_loop(q, b, p, m))?,
                );
            }
            lanes.insert(
                name,
                ModelLane {
                    queue,
                    metrics,
                    z_dim: backend.z_dim(),
                    workers,
                },
            );
        }
        Ok(Coordinator { lanes })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::parallel::Algorithm;
    use crate::coordinator::backend::testutil::tiny_backend;
    use std::time::Duration;

    fn start_tiny() -> Coordinator {
        Coordinator::builder()
            .queue_capacity(32)
            .workers_per_model(2)
            .batch_policy(BatchPolicy {
                max_batch: 4,
                max_delay: Duration::from_millis(2),
            })
            .register(Arc::new(tiny_backend(Algorithm::Unified)))
            .start()
            .unwrap()
    }

    #[test]
    fn end_to_end_submit_receive() {
        let coord = start_tiny();
        let mut rxs = Vec::new();
        for i in 0..10 {
            let req = GenRequest::new(i, "gpgan".into(), vec![0.05; 100]);
            rxs.push((i, coord.submit(req).unwrap()));
        }
        for (i, rx) in rxs {
            let resp = rx.recv_timeout(Duration::from_secs(10)).unwrap();
            assert_eq!(resp.id, i);
            assert_eq!((resp.image.h, resp.image.w, resp.image.c), (16, 16, 3));
        }
        let snap = coord.metrics("gpgan").unwrap();
        assert_eq!(snap.completed, 10);
        assert!(snap.mean_batch_size >= 1.0);
    }

    #[test]
    fn unknown_model_rejected() {
        let coord = start_tiny();
        let req = GenRequest::new(0, "stylegan".into(), vec![0.0; 100]);
        assert!(matches!(
            coord.submit(req),
            Err(SubmitError::UnknownModel(_))
        ));
    }

    #[test]
    fn bad_latent_rejected() {
        let coord = start_tiny();
        let req = GenRequest::new(0, "gpgan".into(), vec![0.0; 3]);
        assert!(matches!(
            coord.submit(req),
            Err(SubmitError::BadLatent { got: 3, want: 100 })
        ));
    }

    #[test]
    fn shutdown_joins_workers() {
        let mut coord = start_tiny();
        let req = GenRequest::new(0, "gpgan".into(), vec![0.1; 100]);
        let rx = coord.submit(req).unwrap();
        let _ = rx.recv_timeout(Duration::from_secs(10)).unwrap();
        coord.shutdown();
        // Submitting after shutdown fails.
        let req = GenRequest::new(1, "gpgan".into(), vec![0.1; 100]);
        assert!(coord.submit(req).is_err());
    }

    #[test]
    fn duplicate_model_rejected_at_build() {
        let r = Coordinator::builder()
            .register(Arc::new(tiny_backend(Algorithm::Unified)))
            .register(Arc::new(tiny_backend(Algorithm::Conventional)))
            .start();
        assert!(r.is_err());
    }
}
