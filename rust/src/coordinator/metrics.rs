//! Serving metrics: counters + latency histograms with cheap recording
//! on the hot path and consistent snapshots for reporting.
//!
//! Every recording call is lock-free on the counter stores: the
//! batch-size distribution is a fixed array of `AtomicU64` sized by the
//! lane's `max_batch` at construction, so [`Metrics::record_batch`] is
//! one relaxed `fetch_add` (it used to take a `Mutex<Vec<u64>>` and
//! possibly resize it mid-serve).  Only the latency histograms keep a
//! mutex, and those are uncontended per lane.
//!
//! `Metrics` also implements [`Collector`], so a serving lane registered
//! with `obs::registry` exports its snapshot through the process-wide
//! registry (`serve.<model>.*` samples in `ukstc metrics`).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::obs::registry::Collector;
use crate::util::stats::LatencyHistogram;

/// Default batch-size distribution width when no policy is given —
/// comfortably above every `BatchPolicy::max_batch` in the repo.
pub const DEFAULT_MAX_BATCH: usize = 64;

/// Aggregated service metrics (one per model lane).
#[derive(Debug)]
pub struct Metrics {
    started: Instant,
    pub submitted: AtomicU64,
    pub rejected: AtomicU64,
    pub completed: AtomicU64,
    /// Exact batch-size distribution: `batch_size_counts[s]` = number
    /// of executed batches of size `s` (sizes are small integers
    /// bounded by `max_batch`, so an exact count vector beats the
    /// log-spaced latency buckets).  Batch count, mean and quantiles
    /// are all derived from this one store — operators see whether
    /// `BatchPolicy` actually forms batches for the fused lane.
    /// Fixed-size and atomic: recording is one relaxed `fetch_add`,
    /// never a lock; sizes beyond the construction-time cap clamp into
    /// the top slot.
    batch_size_counts: Box<[AtomicU64]>,
    queue_hist: Mutex<LatencyHistogram>,
    total_hist: Mutex<LatencyHistogram>,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    /// Metrics with the [`DEFAULT_MAX_BATCH`] distribution width.
    pub fn new() -> Metrics {
        Self::for_max_batch(DEFAULT_MAX_BATCH)
    }

    /// Metrics whose batch-size distribution covers sizes
    /// `0..=max_batch` exactly (the coordinator passes its
    /// `BatchPolicy::max_batch`).
    pub fn for_max_batch(max_batch: usize) -> Metrics {
        let slots = max_batch.max(1) + 1;
        Metrics {
            started: Instant::now(),
            submitted: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            batch_size_counts: (0..slots).map(|_| AtomicU64::new(0)).collect(),
            queue_hist: Mutex::new(LatencyHistogram::new()),
            total_hist: Mutex::new(LatencyHistogram::new()),
        }
    }

    pub fn record_submit(&self) {
        self.submitted.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_reject(&self) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_batch(&self, size: usize) {
        let idx = size.min(self.batch_size_counts.len() - 1);
        self.batch_size_counts[idx].fetch_add(1, Ordering::Relaxed);
    }

    /// Exact quantile of the recorded batch sizes (0 when none yet).
    fn batch_size_quantile(counts: &[u64], q: f64) -> f64 {
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return 0.0;
        }
        let target = ((q * total as f64).ceil() as u64).max(1);
        let mut cum = 0;
        for (size, &c) in counts.iter().enumerate() {
            cum += c;
            if cum >= target {
                return size as f64;
            }
        }
        (counts.len() - 1) as f64
    }

    pub fn record_completion(&self, queued_s: f64, total_s: f64) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        self.queue_hist.lock().unwrap().record(queued_s);
        self.total_hist.lock().unwrap().record(total_s);
    }

    pub fn snapshot(&self) -> Snapshot {
        let completed = self.completed.load(Ordering::Relaxed);
        let qh = self.queue_hist.lock().unwrap();
        let th = self.total_hist.lock().unwrap();
        let sizes: Vec<u64> = self
            .batch_size_counts
            .iter()
            .map(|c| c.load(Ordering::Relaxed))
            .collect();
        let elapsed = self.started.elapsed().as_secs_f64();
        let batches: u64 = sizes.iter().sum();
        let size_sum: u64 = sizes
            .iter()
            .enumerate()
            .map(|(size, &c)| size as u64 * c)
            .sum();
        Snapshot {
            submitted: self.submitted.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            completed,
            batches,
            mean_batch_size: if batches == 0 {
                0.0
            } else {
                size_sum as f64 / batches as f64
            },
            batch_p50: Self::batch_size_quantile(&sizes, 0.50),
            batch_p95: Self::batch_size_quantile(&sizes, 0.95),
            throughput_rps: if elapsed > 0.0 {
                completed as f64 / elapsed
            } else {
                0.0
            },
            queue_p50_s: qh.quantile(0.50),
            queue_p95_s: qh.quantile(0.95),
            total_p50_s: th.quantile(0.50),
            total_p95_s: th.quantile(0.95),
            total_p99_s: th.quantile(0.99),
        }
    }
}

impl Collector for Metrics {
    fn collect(&self) -> Vec<(String, f64)> {
        let s = self.snapshot();
        vec![
            ("submitted".to_string(), s.submitted as f64),
            ("rejected".to_string(), s.rejected as f64),
            ("completed".to_string(), s.completed as f64),
            ("batches".to_string(), s.batches as f64),
            ("mean_batch_size".to_string(), s.mean_batch_size),
            ("batch_p50".to_string(), s.batch_p50),
            ("batch_p95".to_string(), s.batch_p95),
            ("throughput_rps".to_string(), s.throughput_rps),
            ("queue_p50_s".to_string(), s.queue_p50_s),
            ("queue_p95_s".to_string(), s.queue_p95_s),
            ("total_p50_s".to_string(), s.total_p50_s),
            ("total_p95_s".to_string(), s.total_p95_s),
            ("total_p99_s".to_string(), s.total_p99_s),
        ]
    }
}

/// Point-in-time metrics view.
#[derive(Debug, Clone, PartialEq)]
pub struct Snapshot {
    pub submitted: u64,
    pub rejected: u64,
    pub completed: u64,
    pub batches: u64,
    pub mean_batch_size: f64,
    /// Exact p50/p95 of the observed batch-size distribution — whether
    /// the dynamic batcher actually forms batches for the fused lane.
    pub batch_p50: f64,
    pub batch_p95: f64,
    pub throughput_rps: f64,
    pub queue_p50_s: f64,
    pub queue_p95_s: f64,
    pub total_p50_s: f64,
    pub total_p95_s: f64,
    pub total_p99_s: f64,
}

impl Snapshot {
    /// One-line human-readable summary.
    pub fn summary(&self) -> String {
        format!(
            "completed={}/{} rejected={} batches={} (size mean {:.2} \
             p50 {:.0} p95 {:.0}) thpt={:.1} req/s p50={:.1}ms \
             p95={:.1}ms p99={:.1}ms",
            self.completed,
            self.submitted,
            self.rejected,
            self.batches,
            self.mean_batch_size,
            self.batch_p50,
            self.batch_p95,
            self.throughput_rps,
            self.total_p50_s * 1e3,
            self.total_p95_s * 1e3,
            self.total_p99_s * 1e3,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.record_submit();
        m.record_submit();
        m.record_reject();
        m.record_batch(4);
        m.record_batch(2);
        m.record_completion(0.001, 0.005);
        let s = m.snapshot();
        assert_eq!(s.submitted, 2);
        assert_eq!(s.rejected, 1);
        assert_eq!(s.completed, 1);
        assert_eq!(s.batches, 2);
        assert!((s.mean_batch_size - 3.0).abs() < 1e-12);
        assert!(s.total_p50_s > 0.0);
        // Sizes 4 and 2: p50 is the lower, p95 the upper.
        assert_eq!(s.batch_p50, 2.0);
        assert_eq!(s.batch_p95, 4.0);
    }

    #[test]
    fn batch_size_distribution_quantiles_exact() {
        let m = Metrics::new();
        // 8 singleton batches, one 8-wide batch: p50 = 1, p95 = 8.
        for _ in 0..8 {
            m.record_batch(1);
        }
        m.record_batch(8);
        let s = m.snapshot();
        assert_eq!(s.batch_p50, 1.0);
        assert_eq!(s.batch_p95, 8.0);
        assert!((s.mean_batch_size - 16.0 / 9.0).abs() < 1e-12);
        let printed = s.summary();
        assert!(printed.contains("p50 1"), "{printed}");
    }

    #[test]
    fn empty_snapshot_sane() {
        let s = Metrics::new().snapshot();
        assert_eq!(s.completed, 0);
        assert_eq!(s.mean_batch_size, 0.0);
        assert_eq!(s.batch_p50, 0.0);
        assert_eq!(s.batch_p95, 0.0);
        assert_eq!(s.total_p99_s, 0.0);
    }

    #[test]
    fn summary_mentions_throughput() {
        let m = Metrics::new();
        m.record_submit();
        m.record_completion(0.0, 0.001);
        assert!(m.snapshot().summary().contains("req/s"));
    }

    #[test]
    fn batch_sizes_beyond_cap_clamp_into_top_slot() {
        let m = Metrics::for_max_batch(4);
        m.record_batch(3);
        m.record_batch(100); // clamps to slot 4
        let s = m.snapshot();
        assert_eq!(s.batches, 2);
        assert!((s.mean_batch_size - 3.5).abs() < 1e-12);
        assert_eq!(s.batch_p95, 4.0);
    }

    #[test]
    fn collector_exports_snapshot_figures() {
        let m = Metrics::new();
        m.record_submit();
        m.record_batch(2);
        m.record_completion(0.001, 0.002);
        let samples = m.collect();
        let get = |k: &str| {
            samples
                .iter()
                .find(|(name, _)| name == k)
                .map(|(_, v)| *v)
                .unwrap()
        };
        assert_eq!(get("submitted"), 1.0);
        assert_eq!(get("completed"), 1.0);
        assert_eq!(get("batches"), 1.0);
        assert_eq!(get("mean_batch_size"), 2.0);
        assert!(get("total_p50_s") > 0.0);
    }
}
