//! Worker loop: batch formation → backend execution → response fanout.

use std::sync::mpsc::Sender;
use std::sync::Arc;
use std::time::Instant;

use super::backend::Backend;
use super::batcher::{next_batch, BatchPolicy};
use super::metrics::Metrics;
use super::queue::BoundedQueue;
use super::request::{GenRequest, GenResponse};

/// A queued request with its response channel.
pub struct Envelope {
    pub request: GenRequest,
    pub respond: Sender<GenResponse>,
}

/// Run one worker until the queue closes.  Several workers may share
/// the same queue (pool).
pub fn worker_loop(
    queue: Arc<BoundedQueue<Envelope>>,
    backend: Arc<dyn Backend>,
    policy: BatchPolicy,
    metrics: Arc<Metrics>,
) {
    let policy = BatchPolicy {
        max_batch: policy.max_batch.min(backend.max_batch()),
        ..policy
    };
    while let Some(batch) = next_batch(&queue, policy) {
        let formed_at = Instant::now();
        let size = batch.len();
        metrics.record_batch(size);
        let latents: Vec<Vec<f32>> = batch.iter().map(|e| e.request.latent.clone()).collect();
        let images = {
            let _span = crate::obs::trace::span(
                "serve.batch",
                "backend",
                crate::obs::trace::NONE,
                crate::obs::trace::NONE,
            );
            backend.generate(&latents)
        };
        debug_assert_eq!(images.len(), size);
        let service_s = formed_at.elapsed().as_secs_f64();
        for (env, image) in batch.into_iter().zip(images) {
            let queued_s = formed_at
                .saturating_duration_since(env.request.created)
                .as_secs_f64();
            let resp = GenResponse {
                id: env.request.id,
                image,
                queued_s,
                service_s,
                batch_size: size,
            };
            metrics.record_completion(queued_s, resp.total_s());
            // A dropped receiver (client gave up) is not an error.
            let _ = env.respond.send(resp);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::parallel::Algorithm;
    use crate::coordinator::backend::testutil::tiny_backend;
    use std::sync::mpsc;
    use std::thread;
    use std::time::Duration;

    #[test]
    fn worker_serves_and_exits_on_close() {
        let queue = Arc::new(BoundedQueue::new(16));
        let backend: Arc<dyn Backend> = Arc::new(tiny_backend(Algorithm::Unified));
        let metrics = Arc::new(Metrics::new());
        let policy = BatchPolicy {
            max_batch: 4,
            max_delay: Duration::from_millis(2),
        };
        let handle = {
            let (q, b, m) = (Arc::clone(&queue), Arc::clone(&backend), Arc::clone(&metrics));
            thread::spawn(move || worker_loop(q, b, policy, m))
        };
        let mut receivers = Vec::new();
        for i in 0..6 {
            let (tx, rx) = mpsc::channel();
            let req = GenRequest::new(i, "gpgan".into(), vec![0.1; 100]);
            queue
                .push(Envelope {
                    request: req,
                    respond: tx,
                })
                .ok()
                .unwrap();
            receivers.push((i, rx));
        }
        for (i, rx) in receivers {
            let resp = rx.recv_timeout(Duration::from_secs(10)).unwrap();
            assert_eq!(resp.id, i);
            assert_eq!((resp.image.h, resp.image.w), (16, 16));
            assert!(resp.batch_size >= 1);
        }
        queue.close();
        handle.join().unwrap();
        let snap = metrics.snapshot();
        assert_eq!(snap.completed, 6);
        assert!(snap.batches >= 2); // 6 requests, max_batch 4
    }
}
