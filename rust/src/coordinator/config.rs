//! Coordinator configuration (JSON file or programmatic).
//!
//! ```json
//! {
//!   "queue_capacity": 256,
//!   "workers_per_model": 2,
//!   "max_batch": 8,
//!   "max_delay_ms": 5.0,
//!   "models": [
//!     {"name": "dcgan", "backend": "rust", "algorithm": "unified",
//!      "lane_workers": 4, "seed": 7}
//!   ]
//! }
//! ```

use std::path::Path;
use std::time::Duration;

use crate::conv::parallel::{Algorithm, Lane};
use crate::util::json::{self, Json};

use super::batcher::BatchPolicy;

/// Per-model backend configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    /// `"rust"` (native kernels) or `"pjrt"` (AOT HLO artifact).
    pub backend: String,
    /// Conv algorithm for the rust backend.
    pub algorithm: Algorithm,
    /// 0 → serial lane, else parallel with this many workers.
    pub lane_workers: usize,
    pub seed: u64,
    /// Artifact name for the pjrt backend (defaults to `<name>_b<max_batch>`).
    pub artifact: Option<String>,
}

impl ModelConfig {
    pub fn lane(&self) -> Lane {
        if self.lane_workers == 0 {
            Lane::Serial
        } else {
            Lane::Parallel(self.lane_workers)
        }
    }
}

/// Whole-coordinator configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct CoordinatorConfig {
    pub queue_capacity: usize,
    pub workers_per_model: usize,
    pub max_batch: usize,
    pub max_delay: Duration,
    pub models: Vec<ModelConfig>,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            queue_capacity: 256,
            workers_per_model: 1,
            max_batch: 8,
            max_delay: Duration::from_millis(5),
            models: vec![ModelConfig {
                name: "dcgan".into(),
                backend: "rust".into(),
                algorithm: Algorithm::Unified,
                lane_workers: 0,
                seed: 7,
                artifact: None,
            }],
        }
    }
}

impl CoordinatorConfig {
    pub fn batch_policy(&self) -> BatchPolicy {
        BatchPolicy {
            max_batch: self.max_batch,
            max_delay: self.max_delay,
        }
    }

    /// Parse from a JSON value (see module docs for the schema).
    pub fn from_json(v: &Json) -> anyhow::Result<CoordinatorConfig> {
        let mut cfg = CoordinatorConfig::default();
        if let Some(n) = v.get("queue_capacity").and_then(Json::as_usize) {
            cfg.queue_capacity = n;
        }
        if let Some(n) = v.get("workers_per_model").and_then(Json::as_usize) {
            cfg.workers_per_model = n;
        }
        if let Some(n) = v.get("max_batch").and_then(Json::as_usize) {
            cfg.max_batch = n;
        }
        if let Some(ms) = v.get("max_delay_ms").and_then(Json::as_f64) {
            cfg.max_delay = Duration::from_secs_f64(ms / 1e3);
        }
        if let Some(models) = v.get("models").and_then(Json::as_arr) {
            cfg.models = models
                .iter()
                .map(parse_model)
                .collect::<anyhow::Result<Vec<_>>>()?;
        }
        if cfg.queue_capacity == 0 || cfg.max_batch == 0 {
            anyhow::bail!("queue_capacity and max_batch must be positive");
        }
        Ok(cfg)
    }

    pub fn from_file(path: &Path) -> anyhow::Result<CoordinatorConfig> {
        Self::from_json(&json::parse_file(path)?)
    }
}

fn parse_model(v: &Json) -> anyhow::Result<ModelConfig> {
    let name = v
        .get("name")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow::anyhow!("model entry missing 'name'"))?
        .to_string();
    let backend = v
        .get("backend")
        .and_then(Json::as_str)
        .unwrap_or("rust")
        .to_string();
    if backend != "rust" && backend != "pjrt" {
        anyhow::bail!("model '{name}': unknown backend '{backend}'");
    }
    let algorithm = match v.get("algorithm").and_then(Json::as_str).unwrap_or("unified") {
        "conventional" => Algorithm::Conventional,
        "grouped" => Algorithm::Grouped,
        "unified" => Algorithm::Unified,
        "unified-per-element" => Algorithm::UnifiedPerElement,
        "im2col" => Algorithm::Im2col,
        other => anyhow::bail!("model '{name}': unknown algorithm '{other}'"),
    };
    Ok(ModelConfig {
        name,
        backend,
        algorithm,
        lane_workers: v.get("lane_workers").and_then(Json::as_usize).unwrap_or(0),
        seed: v.get("seed").and_then(Json::as_usize).unwrap_or(7) as u64,
        artifact: v
            .get("artifact")
            .and_then(Json::as_str)
            .map(str::to_string),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::parse;

    #[test]
    fn parses_full_config() {
        let j = parse(
            r#"{
            "queue_capacity": 64, "workers_per_model": 2,
            "max_batch": 16, "max_delay_ms": 2.5,
            "models": [
                {"name": "dcgan", "backend": "rust", "algorithm": "unified",
                 "lane_workers": 4, "seed": 3},
                {"name": "ebgan", "backend": "pjrt", "artifact": "ebgan_b8"}
            ]}"#,
        )
        .unwrap();
        let cfg = CoordinatorConfig::from_json(&j).unwrap();
        assert_eq!(cfg.queue_capacity, 64);
        assert_eq!(cfg.max_batch, 16);
        assert_eq!(cfg.max_delay, Duration::from_micros(2500));
        assert_eq!(cfg.models.len(), 2);
        assert_eq!(cfg.models[0].lane(), Lane::Parallel(4));
        assert_eq!(cfg.models[1].backend, "pjrt");
        assert_eq!(cfg.models[1].artifact.as_deref(), Some("ebgan_b8"));
    }

    #[test]
    fn defaults_fill_missing() {
        let cfg = CoordinatorConfig::from_json(&parse("{}").unwrap()).unwrap();
        assert_eq!(cfg, CoordinatorConfig::default());
    }

    #[test]
    fn rejects_bad_backend_and_algorithm() {
        let j = parse(r#"{"models": [{"name": "x", "backend": "cuda"}]}"#).unwrap();
        assert!(CoordinatorConfig::from_json(&j).is_err());
        let j = parse(r#"{"models": [{"name": "x", "algorithm": "winograd"}]}"#).unwrap();
        assert!(CoordinatorConfig::from_json(&j).is_err());
    }

    #[test]
    fn rejects_zero_capacity() {
        let j = parse(r#"{"queue_capacity": 0}"#).unwrap();
        assert!(CoordinatorConfig::from_json(&j).is_err());
    }
}
