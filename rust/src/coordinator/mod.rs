//! L3 serving coordinator: a GAN image-generation service with the
//! unified kernel as its first-class compute feature.
//!
//! Architecture (vLLM-router-shaped, scaled to this paper's workload):
//!
//! ```text
//!  clients ──submit──▶ Router ──▶ per-model BoundedQueue (backpressure)
//!                                   │
//!                             DynamicBatcher (max_batch / max_delay)
//!                                   │
//!                              Worker pool ──▶ Backend
//!                                   │            ├─ RustBackend   (native unified kernels)
//!                                   │            └─ PjrtBackend   (AOT HLO via runtime/)
//!                                responses (per-request channels) + Metrics
//! ```
//!
//! * [`request`] — request/response types
//! * [`queue`] — bounded MPMC queue with blocking push (backpressure)
//! * [`batcher`] — dynamic batching (size + delay window)
//! * [`backend`] — the model-execution trait + native Rust backend
//! * [`worker`] — batch-execution loop
//! * [`server`] — [`server::Coordinator`]: router + lifecycle + submit API
//! * [`metrics`] — counters and latency histograms
//! * [`config`] — JSON-file configuration

pub mod backend;
pub mod batcher;
pub mod config;
pub mod metrics;
pub mod queue;
pub mod request;
pub mod server;
pub mod worker;

pub use backend::Backend;
pub use config::CoordinatorConfig;
pub use request::{GenRequest, GenResponse};
pub use server::Coordinator;
