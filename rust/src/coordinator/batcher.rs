//! Dynamic batching policy.
//!
//! The classic size-or-deadline window: block for the first request,
//! then keep admitting until the batch is full or `max_delay` has
//! elapsed since the first admission.  Larger batches amortize backend
//! dispatch; the delay bound caps the queueing penalty for sparse
//! traffic.  `bench/ablation.rs` sweeps both knobs.

use std::time::{Duration, Instant};

use super::queue::BoundedQueue;

/// Batching knobs.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_delay: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 8,
            max_delay: Duration::from_millis(5),
        }
    }
}

/// Collect the next batch from `queue` under `policy`.
///
/// Returns `None` when the queue is closed and fully drained (worker
/// shutdown signal).  Otherwise returns ≥1 items.
pub fn next_batch<T>(queue: &BoundedQueue<T>, policy: BatchPolicy) -> Option<Vec<T>> {
    // Block for the batch leader.
    let first = queue.pop()?;
    let mut batch = vec![first];
    if policy.max_batch <= 1 {
        return Some(batch);
    }
    let deadline = Instant::now() + policy.max_delay;
    loop {
        // Fast path: grab whatever is already waiting.
        let room = policy.max_batch - batch.len();
        if room == 0 {
            return Some(batch);
        }
        let drained = queue.drain_up_to(room);
        if !drained.is_empty() {
            batch.extend(drained);
            continue;
        }
        let now = Instant::now();
        if now >= deadline {
            return Some(batch);
        }
        match queue.pop_timeout(deadline - now) {
            Ok(Some(item)) => batch.push(item),
            Ok(None) => return Some(batch), // window expired
            Err(()) => return Some(batch),  // closed; serve what we have
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::thread;

    #[test]
    fn batches_ready_items_up_to_max() {
        let q = BoundedQueue::new(16);
        for i in 0..10 {
            q.try_push(i);
        }
        let policy = BatchPolicy {
            max_batch: 4,
            max_delay: Duration::from_millis(1),
        };
        let b = next_batch(&q, policy).unwrap();
        assert_eq!(b, vec![0, 1, 2, 3]);
        assert_eq!(q.len(), 6);
    }

    #[test]
    fn respects_delay_window() {
        let q: BoundedQueue<u32> = BoundedQueue::new(16);
        q.try_push(1);
        let policy = BatchPolicy {
            max_batch: 8,
            max_delay: Duration::from_millis(15),
        };
        let t0 = Instant::now();
        let b = next_batch(&q, policy).unwrap();
        assert_eq!(b, vec![1]);
        let waited = t0.elapsed();
        assert!(waited >= Duration::from_millis(10), "waited {waited:?}");
    }

    #[test]
    fn late_arrivals_join_within_window() {
        let q = Arc::new(BoundedQueue::new(16));
        q.try_push(1u32);
        let q2 = Arc::clone(&q);
        let feeder = thread::spawn(move || {
            thread::sleep(Duration::from_millis(5));
            q2.try_push(2);
        });
        let policy = BatchPolicy {
            max_batch: 4,
            max_delay: Duration::from_millis(100),
        };
        let b = next_batch(&q, policy).unwrap();
        feeder.join().unwrap();
        // Either joined (common) or the window logic returned early with
        // at least the leader — it must never lose item 2.
        if b.len() == 1 {
            assert_eq!(q.pop(), Some(2));
        } else {
            assert_eq!(b, vec![1, 2]);
        }
    }

    #[test]
    fn closed_empty_queue_yields_none() {
        let q: BoundedQueue<u32> = BoundedQueue::new(4);
        q.close();
        assert!(next_batch(&q, BatchPolicy::default()).is_none());
    }

    #[test]
    fn max_batch_one_returns_immediately() {
        let q = BoundedQueue::new(4);
        q.try_push(7);
        let policy = BatchPolicy {
            max_batch: 1,
            max_delay: Duration::from_secs(10),
        };
        let t0 = Instant::now();
        let b = next_batch(&q, policy).unwrap();
        assert_eq!(b, vec![7]);
        assert!(t0.elapsed() < Duration::from_millis(50));
    }
}
