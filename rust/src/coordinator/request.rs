//! Request/response types of the image-generation service.

use std::time::Instant;

use crate::tensor::Feature;

/// A latent→image generation request.
#[derive(Debug, Clone)]
pub struct GenRequest {
    pub id: u64,
    /// Target model name (router key), e.g. `"dcgan"`.
    pub model: String,
    /// Latent vector (length = the model's z_dim).
    pub latent: Vec<f32>,
    /// Creation time (for end-to-end latency accounting).
    pub created: Instant,
}

impl GenRequest {
    pub fn new(id: u64, model: String, latent: Vec<f32>) -> GenRequest {
        GenRequest {
            id,
            model,
            latent,
            created: Instant::now(),
        }
    }
}

/// The service's answer.
#[derive(Debug, Clone)]
pub struct GenResponse {
    pub id: u64,
    pub image: Feature,
    /// Seconds spent queued (submit → batch formation).
    pub queued_s: f64,
    /// Seconds of backend execution (shared by the whole batch).
    pub service_s: f64,
    /// Size of the batch this request was served in.
    pub batch_size: usize,
}

impl GenResponse {
    /// End-to-end latency.
    pub fn total_s(&self) -> f64 {
        self.queued_s + self.service_s
    }
}

/// Submission failure modes surfaced to clients.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SubmitError {
    UnknownModel(String),
    /// Queue for the model is at capacity (backpressure).
    QueueFull(String),
    ShuttingDown,
    BadLatent {
        got: usize,
        want: usize,
    },
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::UnknownModel(m) => write!(f, "unknown model '{m}'"),
            SubmitError::QueueFull(m) => {
                write!(f, "queue for model '{m}' is full (backpressure)")
            }
            SubmitError::ShuttingDown => write!(f, "coordinator is shutting down"),
            SubmitError::BadLatent { got, want } => {
                write!(f, "latent length {got} != expected {want}")
            }
        }
    }
}

impl std::error::Error for SubmitError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn response_total_is_sum() {
        let r = GenResponse {
            id: 1,
            image: Feature::zeros(1, 1, 1),
            queued_s: 0.25,
            service_s: 0.5,
            batch_size: 4,
        };
        assert!((r.total_s() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn errors_display() {
        assert!(SubmitError::UnknownModel("x".into())
            .to_string()
            .contains("unknown model"));
        assert!(SubmitError::BadLatent { got: 3, want: 100 }
            .to_string()
            .contains("3"));
    }
}
