//! Batched feature maps (DESIGN.md §Batched-Execution).
//!
//! [`FeatureBatch`] is the serving-side substrate for fused micro-batch
//! execution: `N` equally-shaped `[H, W, C]` maps laid out contiguously
//! as `[N, H, W, C]` row-major f32.  Image `i` occupies the slice
//! `data[i·H·W·C .. (i+1)·H·W·C]` and is bit-compatible with a
//! standalone [`Feature`] of the same shape, so the batched execution
//! lanes (`conv::plan::ConvTransposePlan::run_batch*`) and the
//! per-image reference path see *exactly* the same bytes — which is
//! what lets the batched direct lanes promise bit-identity with `N`
//! sequential single-image runs.
//!
//! The layout contract is deliberately the simplest one that makes the
//! batched phase-GEMM fusion work: stacking each image's im2col patch
//! rows back to back yields one `[N·rows, K]` operand whose row order
//! matches the `[N·rows, Cout]` result rows scattered back per image —
//! no permutation, no per-image GEMM dispatch, one packed B panel
//! streamed once for the whole batch.

use super::Feature;
use crate::util::rng::Rng;

/// `[N, H, W, C]` row-major f32 batch of equally-shaped feature maps.
#[derive(Debug, Clone, PartialEq)]
pub struct FeatureBatch {
    /// Batch size `N` (may be 0 for an empty batch).
    pub n: usize,
    pub h: usize,
    pub w: usize,
    pub c: usize,
    pub data: Vec<f32>,
}

impl FeatureBatch {
    /// Zero-filled batch.
    pub fn zeros(n: usize, h: usize, w: usize, c: usize) -> FeatureBatch {
        FeatureBatch {
            n,
            h,
            w,
            c,
            data: vec![0.0; n * h * w * c],
        }
    }

    /// Standard-normal random batch.
    pub fn random(n: usize, h: usize, w: usize, c: usize, rng: &mut Rng) -> FeatureBatch {
        let mut b = FeatureBatch::zeros(n, h, w, c);
        rng.fill_normal(&mut b.data);
        b
    }

    /// Wrap an existing buffer (length must be `n*h*w*c`).
    pub fn from_vec(n: usize, h: usize, w: usize, c: usize, data: Vec<f32>) -> FeatureBatch {
        assert_eq!(
            data.len(),
            n * h * w * c,
            "FeatureBatch::from_vec length mismatch"
        );
        FeatureBatch { n, h, w, c, data }
    }

    /// Stack equally-shaped features into one contiguous batch.
    pub fn from_features(features: &[Feature]) -> FeatureBatch {
        assert!(!features.is_empty(), "FeatureBatch::from_features: empty");
        let (h, w, c) = (features[0].h, features[0].w, features[0].c);
        let mut out = FeatureBatch::zeros(features.len(), h, w, c);
        for (i, f) in features.iter().enumerate() {
            assert_eq!(
                (f.h, f.w, f.c),
                (h, w, c),
                "FeatureBatch::from_features: shape mismatch at image {i}"
            );
            out.image_mut(i).copy_from_slice(&f.data);
        }
        out
    }

    /// Floats per image (`H·W·C`).
    #[inline]
    pub fn image_floats(&self) -> usize {
        self.h * self.w * self.c
    }

    /// Borrow image `i` as its raw `[H, W, C]` row-major slice.
    #[inline]
    pub fn image(&self, i: usize) -> &[f32] {
        let len = self.image_floats();
        &self.data[i * len..(i + 1) * len]
    }

    /// Mutably borrow image `i`.
    #[inline]
    pub fn image_mut(&mut self, i: usize) -> &mut [f32] {
        let len = self.image_floats();
        &mut self.data[i * len..(i + 1) * len]
    }

    /// Copy image `i` out into an owned [`Feature`].
    pub fn feature(&self, i: usize) -> Feature {
        Feature::from_vec(self.h, self.w, self.c, self.image(i).to_vec())
    }

    /// Split the batch into owned per-image [`Feature`]s.
    pub fn into_features(self) -> Vec<Feature> {
        let (h, w, c) = (self.h, self.w, self.c);
        let len = h * w * c;
        self.data
            .chunks(len.max(1))
            .take(self.n)
            .map(|img| Feature::from_vec(h, w, c, img.to_vec()))
            .collect()
    }

    /// Total element count across the batch.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Bytes occupied by the raw data (fp32).
    pub fn bytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<f32>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layout_is_image_major_contiguous() {
        let mut b = FeatureBatch::zeros(2, 2, 3, 4);
        b.image_mut(1)[(1 * 3 + 2) * 4 + 3] = 9.0;
        // Image 1 starts at offset H·W·C = 24.
        assert_eq!(b.data[24 + (1 * 3 + 2) * 4 + 3], 9.0);
        assert_eq!(b.image(0).len(), 24);
        assert_eq!(b.image_floats(), 24);
    }

    #[test]
    fn image_slices_bit_compatible_with_feature() {
        let mut rng = Rng::seeded(7);
        let fs: Vec<Feature> = (0..3).map(|_| Feature::random(4, 5, 2, &mut rng)).collect();
        let b = FeatureBatch::from_features(&fs);
        assert_eq!((b.n, b.h, b.w, b.c), (3, 4, 5, 2));
        for (i, f) in fs.iter().enumerate() {
            assert_eq!(b.image(i), &f.data[..], "image {i} bytes diverged");
            assert_eq!(&b.feature(i), f);
        }
        let back = b.into_features();
        assert_eq!(back, fs);
    }

    #[test]
    fn bytes_and_len() {
        let b = FeatureBatch::zeros(3, 2, 2, 2);
        assert_eq!(b.len(), 24);
        assert_eq!(b.bytes(), 24 * 4);
        assert!(!b.is_empty());
        assert!(FeatureBatch::zeros(0, 2, 2, 2).is_empty());
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn from_vec_checks_len() {
        FeatureBatch::from_vec(2, 2, 2, 2, vec![0.0; 15]);
    }

    #[test]
    #[should_panic(expected = "shape mismatch")]
    fn from_features_checks_shapes() {
        FeatureBatch::from_features(&[Feature::zeros(2, 2, 1), Feature::zeros(2, 3, 1)]);
    }

    #[test]
    fn random_fills_all() {
        let mut rng = Rng::seeded(8);
        let b = FeatureBatch::random(2, 3, 3, 2, &mut rng);
        assert!(b.data.iter().any(|&v| v != 0.0));
    }
}
