//! Feature-map and kernel containers (substrate).
//!
//! Concrete, layout-explicit types rather than a generic ndarray:
//! * [`Feature`] — `[H, W, C]` row-major f32 feature map,
//! * [`FeatureBatch`] — `[N, H, W, C]` contiguous micro-batch
//!   (DESIGN.md §Batched-Execution),
//! * [`Kernel`] — `[n, n, Cin, Cout]` (HWIO) f32 convolution kernel,
//! * [`SubKernel`] — a segregated `[R, C, Cin, Cout]` fragment.
//!
//! Row-major HWC matches the Python oracle's layout, so golden vectors
//! flow between the two sides without permutation.

pub mod batch;
pub mod io;
pub mod ops;

pub use batch::FeatureBatch;

use crate::util::rng::Rng;

/// `[H, W, C]` row-major f32 feature map.
#[derive(Debug, Clone, PartialEq)]
pub struct Feature {
    pub h: usize,
    pub w: usize,
    pub c: usize,
    pub data: Vec<f32>,
}

impl Feature {
    /// Zero-filled map.
    pub fn zeros(h: usize, w: usize, c: usize) -> Feature {
        Feature {
            h,
            w,
            c,
            data: vec![0.0; h * w * c],
        }
    }

    /// Standard-normal random map.
    pub fn random(h: usize, w: usize, c: usize, rng: &mut Rng) -> Feature {
        let mut f = Feature::zeros(h, w, c);
        rng.fill_normal(&mut f.data);
        f
    }

    /// Wrap an existing buffer (length must be `h*w*c`).
    pub fn from_vec(h: usize, w: usize, c: usize, data: Vec<f32>) -> Feature {
        assert_eq!(data.len(), h * w * c, "Feature::from_vec length mismatch");
        Feature { h, w, c, data }
    }

    #[inline]
    pub fn idx(&self, y: usize, x: usize, ch: usize) -> usize {
        (y * self.w + x) * self.c + ch
    }

    #[inline]
    pub fn get(&self, y: usize, x: usize, ch: usize) -> f32 {
        self.data[self.idx(y, x, ch)]
    }

    #[inline]
    pub fn set(&mut self, y: usize, x: usize, ch: usize, v: f32) {
        let i = self.idx(y, x, ch);
        self.data[i] = v;
    }

    /// Borrow the `C`-length pixel vector at `(y, x)`.
    #[inline]
    pub fn pixel(&self, y: usize, x: usize) -> &[f32] {
        let base = (y * self.w + x) * self.c;
        &self.data[base..base + self.c]
    }

    /// Borrow one row (all x, all channels) — `w*c` contiguous floats.
    #[inline]
    pub fn row(&self, y: usize) -> &[f32] {
        let base = y * self.w * self.c;
        &self.data[base..base + self.w * self.c]
    }

    /// Total element count.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Bytes occupied by the raw data (fp32).
    pub fn bytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<f32>()
    }
}

/// `[n, n, Cin, Cout]` (HWIO) f32 kernel.
#[derive(Debug, Clone, PartialEq)]
pub struct Kernel {
    pub n: usize,
    pub cin: usize,
    pub cout: usize,
    pub data: Vec<f32>,
}

impl Kernel {
    pub fn zeros(n: usize, cin: usize, cout: usize) -> Kernel {
        Kernel {
            n,
            cin,
            cout,
            data: vec![0.0; n * n * cin * cout],
        }
    }

    pub fn random(n: usize, cin: usize, cout: usize, rng: &mut Rng) -> Kernel {
        let mut k = Kernel::zeros(n, cin, cout);
        rng.fill_normal(&mut k.data);
        k
    }

    pub fn from_vec(n: usize, cin: usize, cout: usize, data: Vec<f32>) -> Kernel {
        assert_eq!(
            data.len(),
            n * n * cin * cout,
            "Kernel::from_vec length mismatch"
        );
        Kernel { n, cin, cout, data }
    }

    #[inline]
    pub fn idx(&self, u: usize, v: usize, ci: usize, co: usize) -> usize {
        (((u * self.n) + v) * self.cin + ci) * self.cout + co
    }

    #[inline]
    pub fn get(&self, u: usize, v: usize, ci: usize, co: usize) -> f32 {
        self.data[self.idx(u, v, ci, co)]
    }

    /// Borrow the `[Cin, Cout]` matrix at tap `(u, v)` — contiguous.
    #[inline]
    pub fn tap(&self, u: usize, v: usize) -> &[f32] {
        let base = ((u * self.n) + v) * self.cin * self.cout;
        &self.data[base..base + self.cin * self.cout]
    }

    pub fn bytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<f32>()
    }
}

/// A segregated sub-kernel: `[rows, cols, Cin, Cout]` (HWIO), possibly
/// non-square (Fig. 4: 3×3 / 3×2 / 2×3 / 2×2 for a 5×5 original).
#[derive(Debug, Clone, PartialEq)]
pub struct SubKernel {
    pub rows: usize,
    pub cols: usize,
    pub cin: usize,
    pub cout: usize,
    pub data: Vec<f32>,
}

impl SubKernel {
    pub fn zeros(rows: usize, cols: usize, cin: usize, cout: usize) -> SubKernel {
        SubKernel {
            rows,
            cols,
            cin,
            cout,
            data: vec![0.0; rows * cols * cin * cout],
        }
    }

    #[inline]
    pub fn idx(&self, u: usize, v: usize, ci: usize, co: usize) -> usize {
        (((u * self.cols) + v) * self.cin + ci) * self.cout + co
    }

    #[inline]
    pub fn get(&self, u: usize, v: usize, ci: usize, co: usize) -> f32 {
        self.data[self.idx(u, v, ci, co)]
    }

    #[inline]
    pub fn set(&mut self, u: usize, v: usize, ci: usize, co: usize, val: f32) {
        let i = self.idx(u, v, ci, co);
        self.data[i] = val;
    }

    /// Borrow the `[Cin, Cout]` matrix at tap `(u, v)`.
    #[inline]
    pub fn tap(&self, u: usize, v: usize) -> &[f32] {
        let base = ((u * self.cols) + v) * self.cin * self.cout;
        &self.data[base..base + self.cin * self.cout]
    }

    /// Element count (spatial only), e.g. 9/6/6/4 for the 5×5 example.
    pub fn taps(&self) -> usize {
        self.rows * self.cols
    }

    pub fn bytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<f32>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn feature_indexing_row_major_hwc() {
        let mut f = Feature::zeros(2, 3, 4);
        f.set(1, 2, 3, 9.0);
        assert_eq!(f.data[(1 * 3 + 2) * 4 + 3], 9.0);
        assert_eq!(f.get(1, 2, 3), 9.0);
        assert_eq!(f.pixel(1, 2)[3], 9.0);
    }

    #[test]
    fn feature_row_slice() {
        let mut f = Feature::zeros(2, 2, 2);
        f.set(1, 0, 0, 5.0);
        assert_eq!(f.row(1)[0], 5.0);
        assert_eq!(f.row(1).len(), 4);
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn from_vec_checks_len() {
        Feature::from_vec(2, 2, 2, vec![0.0; 7]);
    }

    #[test]
    fn kernel_tap_is_cin_cout_matrix() {
        let mut k = Kernel::zeros(3, 2, 4);
        let i = k.idx(1, 2, 1, 3);
        k.data[i] = 7.0;
        let tap = k.tap(1, 2);
        assert_eq!(tap.len(), 8);
        assert_eq!(tap[1 * 4 + 3], 7.0);
    }

    #[test]
    fn subkernel_taps_counts() {
        assert_eq!(SubKernel::zeros(3, 3, 1, 1).taps(), 9);
        assert_eq!(SubKernel::zeros(3, 2, 1, 1).taps(), 6);
        assert_eq!(SubKernel::zeros(2, 2, 1, 1).taps(), 4);
    }

    #[test]
    fn byte_accounting_fp32() {
        assert_eq!(Feature::zeros(4, 4, 3).bytes(), 4 * 4 * 3 * 4);
        assert_eq!(Kernel::zeros(4, 8, 16).bytes(), 4 * 4 * 8 * 16 * 4);
    }

    #[test]
    fn random_fills_all() {
        let mut rng = Rng::seeded(1);
        let f = Feature::random(5, 5, 2, &mut rng);
        assert!(f.data.iter().any(|&v| v != 0.0));
    }
}
