//! Feature-map transforms used by the conv algorithms.

use super::{Feature, FeatureBatch};

/// Bed-of-nails upsampling (Algorithm 1): `N×M → (2N-1)×(2M-1)` with the
/// original pixels at even coordinates and zeros elsewhere.
pub fn upsample_bed_of_nails(x: &Feature) -> Feature {
    if x.h == 0 || x.w == 0 {
        return Feature::zeros(0, 0, x.c);
    }
    let mut up = Feature::zeros(2 * x.h - 1, 2 * x.w - 1, x.c);
    for y in 0..x.h {
        for xx in 0..x.w {
            let src = x.idx(y, xx, 0);
            let dst = up.idx(2 * y, 2 * xx, 0);
            up.data[dst..dst + x.c].copy_from_slice(&x.data[src..src + x.c]);
        }
    }
    up
}

/// Zero-pad by `p` on every spatial side.
pub fn pad(x: &Feature, p: usize) -> Feature {
    if p == 0 {
        return x.clone();
    }
    pad_asym(x, p, p, p, p)
}

/// Zero-pad with independent top/bottom/left/right margins.
pub fn pad_asym(x: &Feature, top: usize, bottom: usize, left: usize, right: usize) -> Feature {
    let mut out = Feature::zeros(x.h + top + bottom, x.w + left + right, x.c);
    for y in 0..x.h {
        let src = x.idx(y, 0, 0);
        let dst = out.idx(y + top, left, 0);
        out.data[dst..dst + x.w * x.c].copy_from_slice(&x.data[src..src + x.w * x.c]);
    }
    out
}

/// Crop the window `[y0, y0+h) × [x0, x0+w)`.
pub fn crop(x: &Feature, y0: usize, x0: usize, h: usize, w: usize) -> Feature {
    assert!(y0 + h <= x.h && x0 + w <= x.w, "crop out of bounds");
    let mut out = Feature::zeros(h, w, x.c);
    for y in 0..h {
        let src = x.idx(y0 + y, x0, 0);
        let dst = out.idx(y, 0, 0);
        out.data[dst..dst + w * x.c].copy_from_slice(&x.data[src..src + w * x.c]);
    }
    out
}

/// Interleave four parity phases into one map: phase `(r, s)` supplies
/// `out[r::2, s::2]`.  Inverse of phase extraction; the Rust analogue of
/// the CUDA scatter-by-thread-id (DESIGN.md §Hardware-Adaptation).
pub fn interleave_phases(
    phases: [&Feature; 4], // order: (0,0), (0,1), (1,0), (1,1)
    h: usize,
    w: usize,
) -> Feature {
    let c = phases[0].c;
    let mut out = Feature::zeros(h, w, c);
    for (pi, ph) in phases.iter().enumerate() {
        let (r, s) = (pi / 2, pi % 2);
        assert_eq!(ph.c, c, "phase channel mismatch");
        for (py, y) in (r..h).step_by(2).enumerate() {
            for (px, x) in (s..w).step_by(2).enumerate() {
                let src = ph.idx(py, px, 0);
                let dst = out.idx(y, x, 0);
                out.data[dst..dst + c].copy_from_slice(&ph.data[src..src + c]);
            }
        }
    }
    out
}

/// Extract parity phase `(r, s)`: `x[r::2, s::2]`.
pub fn extract_phase(x: &Feature, r: usize, s: usize) -> Feature {
    let h = x.h.saturating_sub(r).div_ceil(2);
    let w = x.w.saturating_sub(s).div_ceil(2);
    let mut out = Feature::zeros(h, w, x.c);
    for (py, y) in (r..x.h).step_by(2).enumerate() {
        for (px, xx) in (s..x.w).step_by(2).enumerate() {
            let src = x.idx(y, xx, 0);
            let dst = out.idx(py, px, 0);
            out.data[dst..dst + x.c].copy_from_slice(&x.data[src..src + x.c]);
        }
    }
    out
}

/// Max |a-b| over two equally-shaped maps.
pub fn max_abs_diff(a: &Feature, b: &Feature) -> f32 {
    assert_eq!(
        (a.h, a.w, a.c),
        (b.h, b.w, b.c),
        "max_abs_diff shape mismatch"
    );
    a.data
        .iter()
        .zip(&b.data)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f32::max)
}

/// Peak signal-to-noise ratio of `got` against the reference `want`,
/// in dB, at an explicit signal `peak` (1.0 for tanh outputs):
/// `10·log10(peak² / MSE)` with the MSE accumulated in f64.
/// Bit-identical inputs (and the degenerate empty pair) return
/// `f64::INFINITY` — the `ukstc accuracy` harness prints that as
/// `inf dB`, meaning "no drift at all".
pub fn psnr_slice(want: &[f32], got: &[f32], peak: f64) -> f64 {
    assert_eq!(want.len(), got.len(), "psnr length mismatch");
    assert!(peak > 0.0, "psnr peak must be positive");
    if want.is_empty() {
        return f64::INFINITY;
    }
    let mse = want
        .iter()
        .zip(got)
        .map(|(a, b)| {
            let d = f64::from(*a) - f64::from(*b);
            d * d
        })
        .sum::<f64>()
        / want.len() as f64;
    if mse == 0.0 {
        f64::INFINITY
    } else {
        10.0 * (peak * peak / mse).log10()
    }
}

/// [`psnr_slice`] over two equally-shaped maps.
pub fn psnr(want: &Feature, got: &Feature, peak: f64) -> f64 {
    assert_eq!(
        (want.h, want.w, want.c),
        (got.h, got.w, got.c),
        "psnr shape mismatch"
    );
    psnr_slice(&want.data, &got.data, peak)
}

/// Elementwise ReLU over a raw f32 slice — shared by the single-image
/// and batched epilogues (identical arithmetic, so the batched forward
/// stays bit-identical to per-image execution).
pub fn relu_slice_inplace(xs: &mut [f32]) {
    for v in xs {
        *v = v.max(0.0);
    }
}

/// Elementwise tanh over a raw f32 slice (see [`relu_slice_inplace`]).
pub fn tanh_slice_inplace(xs: &mut [f32]) {
    for v in xs {
        *v = v.tanh();
    }
}

/// Per-channel bias over a raw `[.., C]` row-major slice.
pub fn add_bias_slice_inplace(xs: &mut [f32], bias: &[f32]) {
    assert!(!bias.is_empty(), "bias length mismatch");
    assert_eq!(xs.len() % bias.len(), 0, "bias length mismatch");
    for px in xs.chunks_exact_mut(bias.len()) {
        for (v, b) in px.iter_mut().zip(bias) {
            *v += b;
        }
    }
}

/// Elementwise ReLU in place.
pub fn relu_inplace(x: &mut Feature) {
    relu_slice_inplace(&mut x.data);
}

/// Elementwise tanh in place.
pub fn tanh_inplace(x: &mut Feature) {
    tanh_slice_inplace(&mut x.data);
}

/// Add per-channel bias in place (`bias.len() == x.c`).
pub fn add_bias_inplace(x: &mut Feature, bias: &[f32]) {
    assert_eq!(bias.len(), x.c, "bias length mismatch");
    add_bias_slice_inplace(&mut x.data, bias);
}

/// Batched epilogues (DESIGN.md §Batched-Execution): the `[N, H, W, C]`
/// layout is channel-minor like a single map, so one pass over the
/// whole batch applies the per-channel bias / activation to every
/// image with the same per-element arithmetic as N single-image calls.
pub fn relu_batch_inplace(x: &mut FeatureBatch) {
    relu_slice_inplace(&mut x.data);
}

/// Batched tanh (see [`relu_batch_inplace`]).
pub fn tanh_batch_inplace(x: &mut FeatureBatch) {
    tanh_slice_inplace(&mut x.data);
}

/// Batched per-channel bias (`bias.len() == x.c`).
pub fn add_bias_batch_inplace(x: &mut FeatureBatch, bias: &[f32]) {
    assert_eq!(bias.len(), x.c, "bias length mismatch");
    if x.n == 0 {
        return;
    }
    add_bias_slice_inplace(&mut x.data, bias);
}

/// Max |a-b| over two equally-shaped batches.
pub fn max_abs_diff_batch(a: &FeatureBatch, b: &FeatureBatch) -> f32 {
    assert_eq!(
        (a.n, a.h, a.w, a.c),
        (b.n, b.h, b.w, b.c),
        "max_abs_diff_batch shape mismatch"
    );
    a.data
        .iter()
        .zip(&b.data)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f32::max)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn upsample_places_pixels_at_even_coords() {
        let mut x = Feature::zeros(2, 2, 1);
        x.set(0, 0, 0, 1.0);
        x.set(0, 1, 0, 2.0);
        x.set(1, 0, 0, 3.0);
        x.set(1, 1, 0, 4.0);
        let up = upsample_bed_of_nails(&x);
        assert_eq!((up.h, up.w), (3, 3));
        assert_eq!(up.get(0, 0, 0), 1.0);
        assert_eq!(up.get(0, 2, 0), 2.0);
        assert_eq!(up.get(2, 0, 0), 3.0);
        assert_eq!(up.get(2, 2, 0), 4.0);
        assert_eq!(up.get(1, 1, 0), 0.0);
        assert_eq!(up.get(0, 1, 0), 0.0);
    }

    #[test]
    fn pad_adds_zero_border() {
        let mut x = Feature::zeros(1, 1, 2);
        x.set(0, 0, 1, 5.0);
        let p = pad(&x, 2);
        assert_eq!((p.h, p.w), (5, 5));
        assert_eq!(p.get(2, 2, 1), 5.0);
        assert_eq!(p.get(0, 0, 1), 0.0);
    }

    #[test]
    fn crop_inverse_of_pad() {
        let mut rng = Rng::seeded(2);
        let x = Feature::random(4, 5, 3, &mut rng);
        let roundtrip = crop(&pad(&x, 3), 3, 3, 4, 5);
        assert_eq!(roundtrip, x);
    }

    #[test]
    fn phase_extract_interleave_roundtrip() {
        let mut rng = Rng::seeded(3);
        for (h, w) in [(4, 4), (5, 5), (5, 4), (7, 3)] {
            let x = Feature::random(h, w, 2, &mut rng);
            let p00 = extract_phase(&x, 0, 0);
            let p01 = extract_phase(&x, 0, 1);
            let p10 = extract_phase(&x, 1, 0);
            let p11 = extract_phase(&x, 1, 1);
            let back = interleave_phases([&p00, &p01, &p10, &p11], h, w);
            assert_eq!(back, x, "roundtrip failed for {h}x{w}");
        }
    }

    #[test]
    fn bias_and_activations() {
        let mut x = Feature::from_vec(1, 2, 2, vec![-1.0, 2.0, 3.0, -4.0]);
        add_bias_inplace(&mut x, &[1.0, -1.0]);
        assert_eq!(x.data, vec![0.0, 1.0, 4.0, -5.0]);
        relu_inplace(&mut x);
        assert_eq!(x.data, vec![0.0, 1.0, 4.0, 0.0]);
        tanh_inplace(&mut x);
        assert!((x.data[2] - 4f32.tanh()).abs() < 1e-6);
    }

    #[test]
    fn batched_epilogues_match_per_image() {
        // One batched pass must be bit-identical to N per-image passes.
        let mut rng = Rng::seeded(5);
        let fs: Vec<Feature> = (0..3).map(|_| Feature::random(2, 3, 2, &mut rng)).collect();
        let bias = [0.5f32, -1.25];
        let mut batch = FeatureBatch::from_features(&fs);
        add_bias_batch_inplace(&mut batch, &bias);
        relu_batch_inplace(&mut batch);
        tanh_batch_inplace(&mut batch);
        for (i, f) in fs.iter().enumerate() {
            let mut one = f.clone();
            add_bias_inplace(&mut one, &bias);
            relu_inplace(&mut one);
            tanh_inplace(&mut one);
            assert_eq!(batch.image(i), &one.data[..], "image {i}");
        }
        assert_eq!(max_abs_diff_batch(&batch, &batch), 0.0);
        // Empty batches are fine (the coordinator never forms them, but
        // the ops must not panic on the degenerate shape).
        add_bias_batch_inplace(&mut FeatureBatch::zeros(0, 2, 2, 2), &[0.0, 0.0]);
    }

    #[test]
    fn psnr_known_values_and_identity() {
        let mut rng = Rng::seeded(6);
        let x = Feature::random(3, 4, 2, &mut rng);
        assert_eq!(psnr(&x, &x, 1.0), f64::INFINITY);
        // Uniform error of 0.1 against peak 1.0: MSE = 0.01 → 20 dB.
        let want = vec![0.0f32; 16];
        let got = vec![0.1f32; 16];
        assert!((psnr_slice(&want, &got, 1.0) - 20.0).abs() < 1e-6);
        // Doubling the peak adds 10·log10(4) ≈ 6.02 dB.
        let d = psnr_slice(&want, &got, 2.0) - psnr_slice(&want, &got, 1.0);
        assert!((d - 20.0 * 2f64.log10()).abs() < 1e-9);
        assert_eq!(psnr_slice(&[], &[], 1.0), f64::INFINITY);
    }

    #[test]
    fn max_abs_diff_zero_for_identical() {
        let mut rng = Rng::seeded(4);
        let x = Feature::random(3, 3, 3, &mut rng);
        assert_eq!(max_abs_diff(&x, &x), 0.0);
    }

    #[test]
    #[should_panic(expected = "crop out of bounds")]
    fn crop_bounds_checked() {
        let x = Feature::zeros(2, 2, 1);
        crop(&x, 1, 1, 2, 2);
    }
}
