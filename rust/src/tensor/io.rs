//! Image output: dump feature maps as PPM/PGM so the serving examples
//! produce inspectable artifacts (binary formats, no codec deps).

use std::io::Write as _;
use std::path::Path;

use super::Feature;

/// Map `[-1, 1]`-ish float data to `u8` with clamping.
fn to_u8(v: f32) -> u8 {
    (((v.clamp(-1.0, 1.0) + 1.0) / 2.0) * 255.0).round() as u8
}

/// Write a 3-channel feature map as binary PPM (P6).
pub fn write_ppm(img: &Feature, path: &Path) -> anyhow::Result<()> {
    anyhow::ensure!(img.c == 3, "PPM needs exactly 3 channels, got {}", img.c);
    let mut out = std::io::BufWriter::new(std::fs::File::create(path)?);
    write!(out, "P6\n{} {}\n255\n", img.w, img.h)?;
    let bytes: Vec<u8> = img.data.iter().map(|&v| to_u8(v)).collect();
    out.write_all(&bytes)?;
    Ok(())
}

/// Write channel `ch` of a feature map as binary PGM (P5).
pub fn write_pgm(img: &Feature, ch: usize, path: &Path) -> anyhow::Result<()> {
    anyhow::ensure!(ch < img.c, "channel {ch} out of range ({})", img.c);
    let mut out = std::io::BufWriter::new(std::fs::File::create(path)?);
    write!(out, "P5\n{} {}\n255\n", img.w, img.h)?;
    let bytes: Vec<u8> = (0..img.h)
        .flat_map(|y| (0..img.w).map(move |x| (y, x)))
        .map(|(y, x)| to_u8(img.get(y, x, ch)))
        .collect();
    out.write_all(&bytes)?;
    Ok(())
}

/// Read back a P6 PPM into a Feature (for roundtrip tests / tooling).
pub fn read_ppm(path: &Path) -> anyhow::Result<Feature> {
    let data = std::fs::read(path)?;
    let header_end = find_header_end(&data, 3)?;
    let header = std::str::from_utf8(&data[..header_end])?;
    let mut fields = header.split_ascii_whitespace();
    anyhow::ensure!(fields.next() == Some("P6"), "not a P6 PPM");
    let w: usize = fields.next().unwrap_or("0").parse()?;
    let h: usize = fields.next().unwrap_or("0").parse()?;
    let maxv: usize = fields.next().unwrap_or("0").parse()?;
    anyhow::ensure!(maxv == 255, "only 8-bit PPM supported");
    let pixels = &data[header_end + 1..];
    anyhow::ensure!(pixels.len() >= w * h * 3, "truncated PPM");
    let floats: Vec<f32> = pixels[..w * h * 3]
        .iter()
        .map(|&b| b as f32 / 255.0 * 2.0 - 1.0)
        .collect();
    Ok(Feature::from_vec(h, w, 3, floats))
}

/// Find the byte offset of the end of the ASCII header (after the
/// `maxval` token), before the single whitespace preceding pixel data.
fn find_header_end(data: &[u8], n_fields: usize) -> anyhow::Result<usize> {
    let mut fields = 0;
    let mut in_token = false;
    for (i, &b) in data.iter().enumerate() {
        let ws = b.is_ascii_whitespace();
        if in_token && ws {
            fields += 1;
            if fields == n_fields + 1 {
                return Ok(i);
            }
            in_token = false;
        } else if !ws {
            in_token = true;
        }
    }
    anyhow::bail!("PPM header truncated")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn ppm_roundtrip() {
        let mut rng = Rng::seeded(100);
        let mut img = Feature::random(5, 7, 3, &mut rng);
        for v in &mut img.data {
            *v = v.tanh(); // clamp-free range
        }
        let path = std::env::temp_dir().join("ukstc_test.ppm");
        write_ppm(&img, &path).unwrap();
        let back = read_ppm(&path).unwrap();
        assert_eq!((back.h, back.w, back.c), (5, 7, 3));
        // Quantization error ≤ 1/255 of the 2-unit range.
        let err = crate::tensor::ops::max_abs_diff(&img, &back);
        assert!(err <= 2.0 / 255.0 + 1e-6, "err {err}");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn pgm_writes_single_channel() {
        let img = Feature::from_vec(2, 2, 2, vec![0.0; 8]);
        let path = std::env::temp_dir().join("ukstc_test.pgm");
        write_pgm(&img, 1, &path).unwrap();
        let data = std::fs::read(&path).unwrap();
        assert!(data.starts_with(b"P5\n2 2\n255\n"));
        assert_eq!(data.len(), 11 + 4);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn ppm_rejects_wrong_channels() {
        let img = Feature::zeros(2, 2, 1);
        let path = std::env::temp_dir().join("ukstc_bad.ppm");
        assert!(write_ppm(&img, &path).is_err());
    }
}
