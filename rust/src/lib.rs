//! # UKSTC — Unified Kernel-Segregated Transpose Convolution
//!
//! A production-grade reproduction of *"Unified Kernel-Segregated
//! Transpose Convolution Operation"* (Tida et al., 2025): the unified
//! kernel-segregation algorithm (Algorithm 2) for stride-2 transpose
//! convolution, its baselines (conventional bed-of-nails Algorithm 1 and
//! the HICSS'23 grouped segregation), the paper's GAN-generator
//! workloads, an AOT (JAX/Pallas → HLO → PJRT) execution runtime, and a
//! serving coordinator that makes the optimized kernel a first-class
//! feature of a GAN image-generation service.
//!
//! ## Layout
//!
//! * [`tensor`] — feature-map / kernel containers (substrate)
//! * [`conv`] — the paper's algorithms: conventional, grouped
//!   (prior work), **unified** (the contribution), plus im2col and
//!   dilated-convolution extensions, FLOP and memory models
//! * [`models`] — GAN generator zoo (Table 4) and forward pass
//! * [`workload`] — dataset specs (Table 1) and request generators
//! * [`runtime`] — PJRT client: load + execute AOT HLO artifacts
//! * [`coordinator`] — serving layer: router, batcher, workers, metrics
//! * [`tune`] — per-layer execution-strategy autotuner with a
//!   persisted tuning cache
//! * [`obs`] — observability: span tracing (chrome://tracing export,
//!   flame tables) and the process-wide perf-counter registry
//! * [`bench`] — benchmark harness regenerating every paper table
//! * [`util`] — offline-image substrates: JSON, RNG, CLI, stats,
//!   thread pool, property-testing
//!
//! ## Quickstart
//!
//! Plan once, execute many (DESIGN.md §Plan-Execute): the plan owns the
//! pre-segregated kernel and every shape-derived quantity; steady-state
//! `run` calls through a warm [`Scratch`](conv::plan::Scratch) arena
//! perform zero heap allocations.
//!
//! ```
//! use ukstc::conv::plan::{ConvTransposePlan, Scratch};
//! use ukstc::conv::ConvTransposeParams;
//! use ukstc::tensor::{Feature, Kernel};
//! use ukstc::util::rng::Rng;
//!
//! let mut rng = Rng::seeded(42);
//! let x = Feature::random(8, 8, 16, &mut rng);
//! let k = Kernel::random(4, 16, 32, &mut rng);
//! let p = ConvTransposeParams::gan_layer().with_io(8, 16, 32); // k=4, s=2, P=2
//! let plan = ConvTransposePlan::new(p, &k);   // build once: segregate + freeze geometry
//! let mut scratch = Scratch::for_plan(&plan); // exact scratch sizing, reusable
//! let mut y = plan.new_output();
//! plan.run(&x, &mut scratch, &mut y);         // steady state: zero allocations
//! assert_eq!((y.h, y.w, y.c), (p.out_size(), p.out_size(), p.cout));
//! assert_eq!(p.out_size(), 16);
//! ```
//!
//! The one-shot entry points ([`conv::unified::transpose_conv`]) remain
//! for single calls and as the bit-identical reference for the plan.

// The SIMD microkernels (`conv::simd`) are the crate's only real
// unsafe surface; every unsafe operation there must sit in an explicit
// block with its own safety argument (DESIGN.md §SIMD-Dispatch).
#![deny(unsafe_op_in_unsafe_fn)]

pub mod bench;
pub mod conv;
pub mod coordinator;
pub mod models;
pub mod obs;
pub mod runtime;
pub mod tensor;
pub mod tune;
pub mod util;
pub mod workload;
