//! HLO-text analysis: op census and memory estimates for the lowered
//! modules — the L2 profiling tool used in the §Perf pass
//! (EXPERIMENTS.md) to verify the lowered graph contains no redundant
//! recomputation and that fusion happened where expected.
//!
//! The parser is deliberately shallow: HLO text is line-oriented
//! (`  %name = type opcode(args), ...`), so an opcode census plus
//! shape-byte accounting covers what the perf pass needs without a
//! full grammar.

use std::collections::BTreeMap;
use std::path::Path;

/// Census of one HLO module.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct HloStats {
    /// Instructions per opcode.
    pub op_counts: BTreeMap<String, usize>,
    /// Total instruction count.
    pub total_ops: usize,
    /// Number of fused computations (`fusion` opcodes).
    pub fusions: usize,
    /// Total bytes of all f32 instruction outputs (upper bound on live
    /// memory; XLA reuses buffers so the true peak is lower).
    pub f32_output_bytes: usize,
    /// Dot/convolution ops (the MXU-shaped work).
    pub dot_like: usize,
}

/// Parse HLO text into an op census.
pub fn analyze(text: &str) -> HloStats {
    let mut stats = HloStats::default();
    for line in text.lines() {
        let trimmed = line.trim_start();
        // Instruction lines look like `x.1 = f32[2,3]{1,0} add(...)` —
        // jax's dumper omits the `%` sigil; older dumps include it, and
        // ROOT instructions carry a `ROOT ` prefix.  Either way: an
        // identifier, `=`, a shape, an opcode.
        let rest = trimmed.strip_prefix("ROOT ").unwrap_or(trimmed);
        let rest = rest.strip_prefix('%').unwrap_or(rest);
        let ident_len = rest
            .chars()
            .take_while(|c| c.is_ascii_alphanumeric() || matches!(c, '.' | '_' | '-'))
            .count();
        if ident_len == 0 || !rest[ident_len..].trim_start().starts_with('=') {
            continue;
        }
        let eq = rest[ident_len..].trim_start();
        let after = eq[1..].trim_start();
        // after = "f32[2,3]{1,0} add(%a, %b), metadata=..."
        let Some(space) = after.find(' ') else { continue };
        let shape = &after[..space];
        let op_part = after[space + 1..].trim_start();
        let opcode: String = op_part
            .chars()
            .take_while(|c| c.is_ascii_alphanumeric() || *c == '-' || *c == '_')
            .collect();
        if opcode.is_empty() {
            continue;
        }
        *stats.op_counts.entry(opcode.clone()).or_insert(0) += 1;
        stats.total_ops += 1;
        if opcode == "fusion" {
            stats.fusions += 1;
        }
        if opcode == "dot" || opcode == "convolution" {
            stats.dot_like += 1;
        }
        stats.f32_output_bytes += shape_bytes(shape);
    }
    stats
}

/// Parse `f32[4,8,8]{...}`-style shapes into byte counts (f32 only; other
/// dtypes contribute zero — fine for this crate's all-f32 artifacts).
fn shape_bytes(shape: &str) -> usize {
    let Some(rest) = shape.strip_prefix("f32[") else {
        return 0;
    };
    let Some(close) = rest.find(']') else { return 0 };
    let dims = &rest[..close];
    if dims.is_empty() {
        return 4; // scalar
    }
    dims.split(',')
        .map(|d| d.trim().parse::<usize>().unwrap_or(0))
        .product::<usize>()
        * 4
}

/// Analyze an HLO text file.
pub fn analyze_file(path: &Path) -> anyhow::Result<HloStats> {
    Ok(analyze(&std::fs::read_to_string(path)?))
}

impl HloStats {
    /// Human-readable summary (top-k opcodes).
    pub fn summary(&self, top: usize) -> String {
        let mut by_count: Vec<(&String, &usize)> = self.op_counts.iter().collect();
        by_count.sort_by(|a, b| b.1.cmp(a.1));
        let tops: Vec<String> = by_count
            .iter()
            .take(top)
            .map(|(op, n)| format!("{op}:{n}"))
            .collect();
        format!(
            "{} ops ({} dot-like, {} fusions), ~{:.1} MB f32 outputs; top: {}",
            self.total_ops,
            self.dot_like,
            self.fusions,
            self.f32_output_bytes as f64 / 1e6,
            tops.join(" ")
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
HloModule jit_fn

ENTRY %main.10 (Arg_0.1: f32[2,2], Arg_1.2: f32[2,2]) -> (f32[2,2]) {
  %Arg_0.1 = f32[2,2]{1,0} parameter(0)
  %Arg_1.2 = f32[2,2]{1,0} parameter(1)
  %dot.3 = f32[2,2]{1,0} dot(%Arg_0.1, %Arg_1.2)
  %constant.4 = f32[] constant(2)
  %broadcast.5 = f32[2,2]{1,0} broadcast(%constant.4), dimensions={}
  %add.6 = f32[2,2]{1,0} add(%dot.3, %broadcast.5)
  ROOT %tuple.7 = (f32[2,2]{1,0}) tuple(%add.6)
}
"#;

    #[test]
    fn censuses_sample() {
        let s = analyze(SAMPLE);
        assert_eq!(s.op_counts["dot"], 1);
        assert_eq!(s.op_counts["parameter"], 2);
        assert_eq!(s.op_counts["add"], 1);
        assert_eq!(s.dot_like, 1);
        assert!(s.total_ops >= 6);
        // 4 f32[2,2] outputs + scalar + tuple(unparsed=0).
        assert_eq!(s.f32_output_bytes, 5 * 16 + 4);
    }

    #[test]
    fn shape_bytes_parses() {
        assert_eq!(shape_bytes("f32[2,3]{1,0}"), 24);
        assert_eq!(shape_bytes("f32[]"), 4);
        assert_eq!(shape_bytes("(f32[2])"), 0); // tuples skipped
        assert_eq!(shape_bytes("s32[4]"), 0); // non-f32 skipped
    }

    #[test]
    fn analyzes_real_artifact_if_present() {
        let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts/unified_layer_s8.hlo.txt");
        if !dir.exists() {
            return;
        }
        let s = analyze_file(&dir).unwrap();
        assert!(s.dot_like >= 1, "Pallas phase matmuls must lower to dots");
        assert!(s.total_ops > 10);
        assert!(s.summary(3).contains("dot-like"));
    }
}
