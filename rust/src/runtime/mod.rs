//! PJRT execution runtime: load AOT HLO-text artifacts and run them on
//! the request path.
//!
//! Flow (see /opt/xla-example/load_hlo and DESIGN.md §3): python lowers
//! jax+Pallas to **HLO text** once at build time (`make artifacts`);
//! here `HloModuleProto::from_text_file` → `XlaComputation` →
//! `PjRtClient::compile` happens once at startup, and the compiled
//! executable serves every request with no Python anywhere.
//!
//! * [`artifact`] — manifest parsing
//! * [`Engine`] — artifact registry + compile cache + execute API
//! * [`PjrtBackend`] — [`crate::coordinator::Backend`] adapter
//!
//! On the offline build image the PJRT bindings are replaced by
//! [`xla_stub`]: [`Engine::new`] then fails with a clear message and
//! callers fall back to the native Rust backend (the integration tests
//! skip when no artifacts are present, so this module stays fully
//! compiled and type-checked either way).

pub mod artifact;
pub mod hlo_stats;
pub mod xla_stub;

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Mutex;

use anyhow::Context;

use self::xla_stub as xla;

pub use artifact::{ArtifactSpec, InputSpec, Manifest};

/// A compiled artifact.
///
/// SAFETY rationale for the `Send + Sync` below: `PjRtLoadedExecutable`
/// wraps a PJRT C-API executable handle.  The PJRT CPU client is
/// thread-safe for concurrent `Execute` calls; the bindings merely
/// never declared it.  We still serialize calls through a `Mutex` to
/// stay conservative (one execute at a time per executable).  With the
/// offline [`xla_stub`] these impls are trivially sound (plain unit
/// structs), but they are kept so a real-bindings swap needs no edits.
struct Compiled {
    spec: ArtifactSpec,
    exe: Mutex<xla::PjRtLoadedExecutable>,
}

unsafe impl Send for Compiled {}
unsafe impl Sync for Compiled {}

/// The runtime engine: a PJRT CPU client plus compiled artifacts.
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    compiled: BTreeMap<String, Compiled>,
}

unsafe impl Send for Engine {}
unsafe impl Sync for Engine {}

impl Engine {
    /// Create a CPU engine over an artifact directory (reads the
    /// manifest; compiles lazily via [`Engine::compile`]).
    pub fn new(artifact_dir: &Path) -> anyhow::Result<Engine> {
        let manifest = Manifest::load(artifact_dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("creating PJRT CPU client: {e:?}"))?;
        log::info!(
            "PJRT engine up: platform={} devices={} artifacts={}",
            client.platform_name(),
            client.device_count(),
            manifest.artifacts.len()
        );
        Ok(Engine {
            client,
            manifest,
            compiled: BTreeMap::new(),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Compile one artifact by name (idempotent).
    pub fn compile(&mut self, name: &str) -> anyhow::Result<()> {
        if self.compiled.contains_key(name) {
            return Ok(());
        }
        let spec = self
            .manifest
            .get(name)
            .with_context(|| format!("artifact '{name}' not in manifest"))?
            .clone();
        let path = self.manifest.hlo_path(&spec);
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow::anyhow!("parsing {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling '{name}': {e:?}"))?;
        log::info!(
            "compiled artifact '{name}' in {:.2}s",
            t0.elapsed().as_secs_f64()
        );
        self.compiled.insert(
            name.to_string(),
            Compiled {
                spec,
                exe: Mutex::new(exe),
            },
        );
        Ok(())
    }

    /// Compile every artifact in the manifest.
    pub fn compile_all(&mut self) -> anyhow::Result<()> {
        let names: Vec<String> = self
            .manifest
            .artifacts
            .iter()
            .map(|a| a.name.clone())
            .collect();
        for n in names {
            self.compile(&n)?;
        }
        Ok(())
    }

    /// Execute a compiled artifact.  `inputs` must match the manifest's
    /// input specs in order; returns the flat f32 output plus its shape.
    pub fn execute(&self, name: &str, inputs: &[Vec<f32>]) -> anyhow::Result<(Vec<f32>, Vec<usize>)> {
        let compiled = self
            .compiled
            .get(name)
            .with_context(|| format!("artifact '{name}' not compiled"))?;
        let spec = &compiled.spec;
        if inputs.len() != spec.inputs.len() {
            anyhow::bail!(
                "artifact '{name}' expects {} inputs, got {}",
                spec.inputs.len(),
                inputs.len()
            );
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (data, ispec) in inputs.iter().zip(&spec.inputs) {
            if data.len() != ispec.elements() {
                anyhow::bail!(
                    "artifact '{name}' input '{}' expects {} elements, got {}",
                    ispec.name,
                    ispec.elements(),
                    data.len()
                );
            }
            let dims: Vec<i64> = ispec.shape.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(data)
                .reshape(&dims)
                .map_err(|e| anyhow::anyhow!("reshaping input '{}': {e:?}", ispec.name))?;
            literals.push(lit);
        }
        let exe = compiled.exe.lock().unwrap();
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow::anyhow!("executing '{name}': {e:?}"))?;
        drop(exe);
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetching output of '{name}': {e:?}"))?;
        // aot.py lowers with return_tuple=True → unwrap the 1-tuple.
        let out = lit
            .to_tuple1()
            .map_err(|e| anyhow::anyhow!("untupling output of '{name}': {e:?}"))?;
        let data = out
            .to_vec::<f32>()
            .map_err(|e| anyhow::anyhow!("reading output of '{name}': {e:?}"))?;
        if data.len() != spec.output_elements() {
            anyhow::bail!(
                "artifact '{name}' produced {} elements, manifest says {}",
                data.len(),
                spec.output_elements()
            );
        }
        Ok((data, spec.output_shape.clone()))
    }
}

// ---------------------------------------------------------------- backend

use crate::coordinator::backend::Backend;
use crate::tensor::Feature;
use crate::util::rng::Rng;
use std::sync::Arc;

/// Serving backend over an AOT-compiled generator artifact.
///
/// Weights are generated once (seeded) to match the artifact's weight
/// argument shapes and reused for every request; latent batches are
/// padded up to the compiled batch size.
pub struct PjrtBackend {
    engine: Arc<Engine>,
    artifact: String,
    model_name: String,
    z_dim: usize,
    batch: usize,
    weights: Vec<Vec<f32>>,
    out_shape: Vec<usize>,
}

impl PjrtBackend {
    /// Build from an engine that has already compiled `artifact`.
    pub fn new(engine: Arc<Engine>, artifact: &str, seed: u64) -> anyhow::Result<PjrtBackend> {
        let spec = engine
            .manifest()
            .get(artifact)
            .with_context(|| format!("artifact '{artifact}' not in manifest"))?
            .clone();
        if spec.kind != "generator" {
            anyhow::bail!("artifact '{artifact}' is not a generator");
        }
        let batch = spec.batch.context("generator artifact missing batch")?;
        let z_dim = spec.inputs[0].shape[1];
        let mut rng = Rng::seeded(seed);
        // He-style init mirroring model.init_params: scale 1/sqrt(fan_in).
        let weights = spec.inputs[1..]
            .iter()
            .map(|ispec| {
                let mut w = vec![0.0f32; ispec.elements()];
                rng.fill_normal(&mut w);
                let fan_in = if ispec.shape.len() > 1 {
                    ispec.shape[0] as f32
                } else {
                    1.0
                };
                let scale = 1.0 / fan_in.max(1.0).sqrt();
                for v in &mut w {
                    *v *= scale;
                }
                w
            })
            .collect();
        Ok(PjrtBackend {
            model_name: spec.model.clone().unwrap_or_else(|| artifact.to_string()),
            out_shape: spec.output_shape.clone(),
            engine,
            artifact: artifact.to_string(),
            z_dim,
            batch,
            weights,
        })
    }

    /// Run one batch (padded to the compiled size) and split per-image.
    fn run_batch(&self, latents: &[Vec<f32>]) -> anyhow::Result<Vec<Feature>> {
        let mut z = vec![0.0f32; self.batch * self.z_dim];
        for (i, lat) in latents.iter().enumerate() {
            z[i * self.z_dim..(i + 1) * self.z_dim].copy_from_slice(lat);
        }
        let mut inputs = Vec::with_capacity(1 + self.weights.len());
        inputs.push(z);
        inputs.extend(self.weights.iter().cloned());
        let (data, shape) = self.engine.execute(&self.artifact, &inputs)?;
        let (h, w, c) = (shape[1], shape[2], shape[3]);
        let per = h * w * c;
        Ok(latents
            .iter()
            .enumerate()
            .map(|(i, _)| Feature::from_vec(h, w, c, data[i * per..(i + 1) * per].to_vec()))
            .collect())
    }
}

impl Backend for PjrtBackend {
    fn model_name(&self) -> &str {
        &self.model_name
    }

    fn z_dim(&self) -> usize {
        self.z_dim
    }

    fn max_batch(&self) -> usize {
        self.batch
    }

    fn generate(&self, latents: &[Vec<f32>]) -> Vec<Feature> {
        match self.run_batch(latents) {
            Ok(images) => images,
            Err(e) => {
                // Serving must not bring the worker down; surface a
                // zero image and log (clients see all-zeros).
                log::error!("pjrt backend '{}' failed: {e:#}", self.artifact);
                let (h, w, c) = (self.out_shape[1], self.out_shape[2], self.out_shape[3]);
                latents.iter().map(|_| Feature::zeros(h, w, c)).collect()
            }
        }
    }
}
