//! Artifact manifest parsing (`artifacts/manifest.json`, produced by
//! `python/compile/aot.py`).

use std::path::{Path, PathBuf};

use crate::util::json::{self, Json};

/// One input tensor spec of an artifact.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct InputSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

impl InputSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One compiled-artifact entry.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactSpec {
    pub name: String,
    /// HLO text file, relative to the artifact directory.
    pub path: String,
    /// `"layer"` or `"generator"`.
    pub kind: String,
    /// Source GAN model (generators only).
    pub model: Option<String>,
    /// Compiled batch size (generators only).
    pub batch: Option<usize>,
    pub inputs: Vec<InputSpec>,
    pub output_shape: Vec<usize>,
}

impl ArtifactSpec {
    pub fn output_elements(&self) -> usize {
        self.output_shape.iter().product()
    }
}

/// The parsed manifest.
#[derive(Debug, Clone, PartialEq)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: Vec<ArtifactSpec>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> anyhow::Result<Manifest> {
        let v = json::parse_file(&dir.join("manifest.json"))?;
        let version = v
            .get("version")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow::anyhow!("manifest missing version"))?;
        if version != 1 {
            anyhow::bail!("unsupported manifest version {version}");
        }
        let artifacts = v
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("manifest missing artifacts"))?
            .iter()
            .map(parse_artifact)
            .collect::<anyhow::Result<Vec<_>>>()?;
        Ok(Manifest {
            dir: dir.to_path_buf(),
            artifacts,
        })
    }

    pub fn get(&self, name: &str) -> Option<&ArtifactSpec> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    /// Absolute path of an artifact's HLO file.
    pub fn hlo_path(&self, spec: &ArtifactSpec) -> PathBuf {
        self.dir.join(&spec.path)
    }
}

fn parse_artifact(v: &Json) -> anyhow::Result<ArtifactSpec> {
    let get_str = |k: &str| -> anyhow::Result<String> {
        v.get(k)
            .and_then(Json::as_str)
            .map(str::to_string)
            .ok_or_else(|| anyhow::anyhow!("artifact entry missing '{k}'"))
    };
    let inputs = v
        .get("inputs")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow::anyhow!("artifact missing inputs"))?
        .iter()
        .map(|i| {
            Ok(InputSpec {
                name: i
                    .get("name")
                    .and_then(Json::as_str)
                    .unwrap_or("arg")
                    .to_string(),
                shape: i
                    .get("shape")
                    .and_then(Json::as_usize_vec)
                    .ok_or_else(|| anyhow::anyhow!("input missing shape"))?,
            })
        })
        .collect::<anyhow::Result<Vec<_>>>()?;
    Ok(ArtifactSpec {
        name: get_str("name")?,
        path: get_str("path")?,
        kind: get_str("kind")?,
        model: v.get("model").and_then(Json::as_str).map(str::to_string),
        batch: v.get("batch").and_then(Json::as_usize),
        inputs,
        output_shape: v
            .get("output_shape")
            .and_then(Json::as_usize_vec)
            .ok_or_else(|| anyhow::anyhow!("artifact missing output_shape"))?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn loads_real_manifest() {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        let layer = m.get("unified_layer_s8").expect("layer artifact");
        assert_eq!(layer.kind, "layer");
        assert_eq!(layer.inputs.len(), 2);
        assert_eq!(layer.inputs[0].shape, vec![1, 8, 8, 8]);
        assert_eq!(layer.output_shape, vec![1, 16, 16, 4]);
        assert!(m.hlo_path(layer).exists());
        let g = m.get("dcgan_b1").expect("generator artifact");
        assert_eq!(g.kind, "generator");
        assert_eq!(g.batch, Some(1));
        assert_eq!(g.model.as_deref(), Some("dcgan"));
        // z + proj w/b + 4 layers × (kernel, bias)
        assert_eq!(g.inputs.len(), 1 + 2 + 8);
    }

    #[test]
    fn missing_manifest_errors() {
        assert!(Manifest::load(Path::new("/nonexistent")).is_err());
    }

    #[test]
    fn input_spec_elements() {
        let s = InputSpec {
            name: "x".into(),
            shape: vec![2, 3, 4],
        };
        assert_eq!(s.elements(), 24);
    }
}
