//! Offline stand-in for the `xla` PJRT bindings.
//!
//! The build image does not ship the XLA/PJRT native bridge, so this
//! module mirrors the exact API surface [`super`] consumes — client
//! construction, HLO-text parsing, compilation and execution — and
//! fails fast at [`PjRtClient::cpu`] with an actionable message.  All
//! downstream methods are type-correct but unreachable in practice:
//! [`super::Engine::new`] is the only entry point and it propagates the
//! construction error before anything can be compiled or executed.
//!
//! Swapping in a real PJRT runtime means replacing the
//! `use self::xla_stub as xla;` alias in `runtime/mod.rs` with the
//! actual bindings crate; no other code changes, because the signatures
//! below are kept in lockstep with what `runtime/mod.rs` calls.

use std::fmt;
use std::path::Path;

const UNAVAILABLE: &str =
    "PJRT/XLA runtime is not available in this build (offline stub); \
     use the native Rust backend (`--backend rust`) instead";

/// Error type mirroring the bindings' debug-printable errors.
pub struct Error(String);

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

fn unavailable() -> Error {
    Error(UNAVAILABLE.to_string())
}

/// Stand-in for the PJRT CPU client.  Construction always fails.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(unavailable())
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(unavailable())
    }
}

/// Stand-in for a parsed HLO module proto.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &Path) -> Result<HloModuleProto, Error> {
        Err(unavailable())
    }
}

/// Stand-in for an XLA computation.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Stand-in for a compiled, loaded executable.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(unavailable())
    }
}

/// Stand-in for a device buffer returned by execution.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(unavailable())
    }
}

/// Stand-in for a host literal.
pub struct Literal;

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        Ok(Literal)
    }

    pub fn to_tuple1(self) -> Result<Literal, Error> {
        Err(unavailable())
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        Err(unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_construction_reports_offline() {
        let err = PjRtClient::cpu().err().unwrap();
        assert!(format!("{err:?}").contains("offline stub"));
    }

    #[test]
    fn literal_builders_are_inert() {
        let lit = Literal::vec1(&[1.0, 2.0]).reshape(&[2]).unwrap();
        assert!(lit.to_vec::<f32>().is_err());
    }
}
