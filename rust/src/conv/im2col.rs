//! GEMM-based transpose convolution (paper §5 discussion).
//!
//! The matrix-multiplication route: lower the (upsampled, padded) input
//! to an im2col patch matrix `[Ho·Wo, n·n·Cin]` and multiply by the
//! kernel reshaped to `[n·n·Cin, Cout]`.  The §5 discussion also
//! sketches a *segregated* GEMM — four phase GEMMs whose outputs land in
//! four sub-arrays that must then be re-interleaved, costing an extra
//! output-sized buffer and a rearrangement pass; both are implemented
//! so the ablation bench can quantify the §5 claim.

use crate::tensor::{ops, Feature};
use crate::tensor::Kernel;

use super::segregation::segregate;
use super::unified::scatter_rows;
use super::{gemm as tiled, out_size, TapSet};

/// Zero-skipping GEMM: `c[m×n] += a[m×k] · b[k×n]`, row-major,
/// branching past `a` elements that are exactly zero (the im2col of an
/// upsampled map is ~75% zeros).  Deliberately kept as the scalar
/// i-k-j loop — a thin sparse lane whose §5 ablation numbers stay
/// comparable across PRs; the dense route ([`gemm_dense`]) runs the
/// tiled microkernel ([`tiled::gemm_tiled`](crate::conv::gemm)), which
/// cannot branch per element.
pub fn gemm(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    for i in 0..m {
        let a_row = &a[i * k..(i + 1) * k];
        let c_row = &mut c[i * n..(i + 1) * n];
        for (kk, &av) in a_row.iter().enumerate() {
            if av == 0.0 {
                continue; // im2col of an upsampled map is ~75% zeros
            }
            let b_row = &b[kk * n..(kk + 1) * n];
            for (cv, &bv) in c_row.iter_mut().zip(b_row) {
                *cv += av * bv;
            }
        }
    }
}

/// Dense GEMM — same signature as before, internals replaced by the
/// register-blocked, cache-tiled microkernel (`conv::gemm`, DESIGN.md
/// §GEMM-Execution).
pub fn gemm_dense(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    tiled::gemm_tiled(a, b, c, m, k, n);
}

/// im2col patch matrix of `x` for a `kr×kc` VALID window sweep:
/// row `oy*wo + ox` holds the flattened `[kr, kc, C]` patch.
pub fn im2col(x: &Feature, kr: usize, kc: usize) -> (Vec<f32>, usize, usize) {
    let ho = x.h - kr + 1;
    let wo = x.w - kc + 1;
    let patch = kr * kc * x.c;
    let mut m = vec![0.0f32; ho * wo * patch];
    for oy in 0..ho {
        for ox in 0..wo {
            let row = &mut m[(oy * wo + ox) * patch..(oy * wo + ox + 1) * patch];
            for u in 0..kr {
                let src = x.idx(oy + u, ox, 0);
                let dst = u * kc * x.c;
                row[dst..dst + kc * x.c]
                    .copy_from_slice(&x.data[src..src + kc * x.c]);
            }
        }
    }
    (m, ho * wo, patch)
}

/// Kernel reshaped to the GEMM operand `[n·n·Cin, Cout]` (tap-major,
/// matching [`im2col`]'s patch layout).
pub fn kernel_matrix<T: TapSet>(k: &T) -> Vec<f32> {
    let (kr, kc, cin, cout) = (k.rows(), k.cols(), k.cin(), k.cout());
    let mut m = vec![0.0f32; kr * kc * cin * cout];
    for u in 0..kr {
        for v in 0..kc {
            let tap = k.tap(u, v);
            let base = (u * kc + v) * cin * cout;
            m[base..base + cin * cout].copy_from_slice(tap);
        }
    }
    m
}

/// Conventional GEMM transpose conv: upsample → pad → im2col → GEMM.
pub fn transpose_conv(x: &Feature, k: &Kernel, padding: usize) -> Feature {
    let up = ops::upsample_bed_of_nails(x);
    let padded = ops::pad(&up, padding);
    let (patches, rows, patch) = im2col(&padded, k.n, k.n);
    let km = kernel_matrix(k);
    let ho = padded.h - k.n + 1;
    let wo = padded.w - k.n + 1;
    let mut out = vec![0.0f32; rows * k.cout];
    gemm(&patches, &km, &mut out, rows, patch, k.cout);
    Feature::from_vec(ho, wo, k.cout, out)
}

/// §5 segregated GEMM: four phase GEMMs over the raw input, followed by
/// the re-interleaving pass the paper warns costs "more memory, which
/// might be equivalent to double the size of the output feature map".
/// Returns `(result, extra_bytes)` where `extra_bytes` is the transient
/// phase-buffer footprint beyond the final output.
pub fn transpose_conv_segregated_gemm(
    x: &Feature,
    k: &Kernel,
    padding: usize,
) -> (Feature, usize) {
    let seg = segregate(k);
    let ho = out_size(x.h, k.n, padding);
    let mut result = Feature::zeros(ho, ho, k.cout);
    let mut extra = 0usize;
    // `phase_geometries` omits empty phases (a 1×1 output has only the
    // (0,0) phase), so interleave whatever phases exist by scattering
    // each into its strided parity positions — the existing extents
    // always partition the output exactly.
    for g in super::unified::phase_geometries(x.h, k.n, padding) {
        let (pt, pb, pl, pr) = g.pads;
        let padded = ops::pad_asym(x, pt, pb, pl, pr);
        let slab = ops::crop(
            &padded,
            g.rows.0,
            g.cols.0,
            g.rows.1 - g.rows.0,
            g.cols.1 - g.cols.0,
        );
        let sub = &seg.subs[g.sub];
        let (patches, rows, patch) = im2col(&slab, sub.rows, sub.cols);
        let km = kernel_matrix(sub);
        let mut out = vec![0.0f32; rows * sub.cout];
        gemm_dense(&patches, &km, &mut out, rows, patch, sub.cout);
        let phase = Feature::from_vec(g.n_rows, g.n_cols, sub.cout, out);
        extra += phase.bytes();
        scatter_rows(&mut result, &phase.data, g.rp, g.sp, g.n_rows, g.n_cols);
    }
    (result, extra)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::conventional;
    use crate::util::prop::{close, forall_res, Config};
    use crate::util::rng::Rng;

    #[test]
    fn gemm_small_known() {
        // [1 2; 3 4] * [5 6; 7 8] = [19 22; 43 50]
        let a = [1.0, 2.0, 3.0, 4.0];
        let b = [5.0, 6.0, 7.0, 8.0];
        let mut c = [0.0; 4];
        gemm(&a, &b, &mut c, 2, 2, 2);
        assert_eq!(c, [19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn gemm_zero_skip_matches_dense() {
        let mut rng = Rng::seeded(40);
        let mut a = vec![0.0f32; 6 * 5];
        rng.fill_normal(&mut a);
        for v in a.iter_mut().step_by(3) {
            *v = 0.0;
        }
        let mut b = vec![0.0f32; 5 * 4];
        rng.fill_normal(&mut b);
        let mut c1 = vec![0.0f32; 6 * 4];
        let mut c2 = vec![0.0f32; 6 * 4];
        gemm(&a, &b, &mut c1, 6, 5, 4);
        gemm_dense(&a, &b, &mut c2, 6, 5, 4);
        assert!(close(&c1, &c2, 1e-5).is_ok());
    }

    #[test]
    fn gemm_route_matches_direct() {
        let mut rng = Rng::seeded(41);
        let x = Feature::random(5, 5, 3, &mut rng);
        let k = Kernel::random(4, 3, 2, &mut rng);
        let want = conventional::transpose_conv(&x, &k, 2);
        let got = transpose_conv(&x, &k, 2);
        assert!(ops::max_abs_diff(&want, &got) < 1e-4);
    }

    #[test]
    fn segregated_gemm_matches_and_reports_extra() {
        let mut rng = Rng::seeded(42);
        let x = Feature::random(4, 4, 2, &mut rng);
        let k = Kernel::random(5, 2, 3, &mut rng);
        let want = conventional::transpose_conv(&x, &k, 2);
        let (got, extra) = transpose_conv_segregated_gemm(&x, &k, 2);
        assert!(ops::max_abs_diff(&want, &got) < 1e-4);
        // §5: phase buffers ≈ one extra output copy.
        assert_eq!(extra, want.bytes());
    }

    #[test]
    fn segregated_gemm_handles_missing_phases() {
        // ho = 1: only the (0,0) phase exists — `phase_geometries`
        // omits the empty ones, and the old `phases.len() == 4` assert
        // panicked on exactly these shapes.
        let mut rng = Rng::seeded(43);
        for (n, nk, p) in [(1usize, 3usize, 1usize), (2, 5, 1)] {
            let x = Feature::random(n, n, 2, &mut rng);
            let k = Kernel::random(nk, 2, 3, &mut rng);
            let want = conventional::transpose_conv(&x, &k, p);
            assert_eq!(want.h, 1, "shape picked for a degenerate 1×1 output");
            let (got, extra) = transpose_conv_segregated_gemm(&x, &k, p);
            assert!(
                ops::max_abs_diff(&want, &got) < 1e-4,
                "n={n} nk={nk} p={p}"
            );
            assert_eq!(extra, want.bytes(), "phase buffers still ≈ one output");
        }
        // Odd output with all four phases present still interleaves
        // correctly through the scatter.
        let x = Feature::random(2, 2, 2, &mut rng);
        let k = Kernel::random(3, 2, 2, &mut rng);
        let want = conventional::transpose_conv(&x, &k, 1);
        assert_eq!(want.h, 3);
        let (got, _) = transpose_conv_segregated_gemm(&x, &k, 1);
        assert!(ops::max_abs_diff(&want, &got) < 1e-4);
    }

    #[test]
    fn prop_gemm_route_equals_conventional() {
        forall_res(Config::default().cases(30), "im2col == conventional", |rng| {
            let n_in = rng.range(2, 6);
            let nk = rng.range(2, 5);
            let p = rng.range(0, 2);
            if 2 * n_in + 2 * p <= nk {
                return ((n_in, nk, p), Ok(()));
            }
            let mut r2 = rng.split();
            let x = Feature::random(n_in, n_in, 2, &mut r2);
            let k = Kernel::random(nk, 2, 2, &mut r2);
            let want = conventional::transpose_conv(&x, &k, p);
            let got = transpose_conv(&x, &k, p);
            ((n_in, nk, p), close(&want.data, &got.data, 1e-3))
        });
    }
}
