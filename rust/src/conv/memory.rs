//! Analytic memory accounting — reproduces the paper's savings columns
//! **exactly** (they are analytic, not measured; see DESIGN.md §6).
//!
//! Two definitions appear in the paper:
//!
//! * **Table 4 (GAN ablation)**: savings = the eliminated
//!   upsampled+padded buffer, `(2N−1+2P)² · C · 4` bytes.
//!   Verified: DC-GAN layer 2 → `11²·1024·4 = 495,616` ✓,
//!   EB-GAN layer 7 → `259²·64·4 = 17,172,736` ✓.
//! * **Table 2/3 (datasets)**: savings = upsampled+padded buffer minus
//!   the proposed path's padded input,
//!   `[(2N−1+2P)² − (N+2⌊P/2⌋)²] · C · 4` bytes.
//!   Verified: N=224, P=2, C=3 → `1,827,900 B = 1.8279 MB` (decimal) ✓.

use super::ConvTransposeParams;

const F32: usize = std::mem::size_of::<f32>(); // 4

/// Size in bytes of the conventional path's upsampled+padded buffer
/// `(2N−1+2P)² · Cin · 4`.
pub fn upsampled_buffer_bytes(p: &ConvTransposeParams) -> usize {
    let side = 2 * p.n_in - 1 + 2 * p.padding;
    side * side * p.cin * F32
}

/// Size in bytes of the proposed path's padded raw input
/// `(N + 2⌊P/2⌋)² · Cin · 4`.
pub fn proposed_input_bytes(p: &ConvTransposeParams) -> usize {
    let side = p.n_in + 2 * (p.padding / 2);
    side * side * p.cin * F32
}

/// Table 4 definition: the whole upsampled buffer is saved.
pub fn savings_table4(p: &ConvTransposeParams) -> usize {
    upsampled_buffer_bytes(p)
}

/// Table 2/3 definition: upsampled buffer minus the padded raw input.
pub fn savings_table2(p: &ConvTransposeParams) -> usize {
    upsampled_buffer_bytes(p) - proposed_input_bytes(p)
}

/// Decimal megabytes (the paper's Table 2 unit: 1 MB = 10⁶ B).
pub fn to_decimal_mb(bytes: usize) -> f64 {
    bytes as f64 / 1e6
}

/// Full memory footprint of one layer under each algorithm (input,
/// intermediate, kernel, output) — used by the serving coordinator's
/// admission control and the ablation report.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LayerFootprint {
    pub input_bytes: usize,
    pub intermediate_bytes: usize,
    pub kernel_bytes: usize,
    pub output_bytes: usize,
}

impl LayerFootprint {
    pub fn total(&self) -> usize {
        self.input_bytes + self.intermediate_bytes + self.kernel_bytes + self.output_bytes
    }
}

/// Footprint of the conventional algorithm (materializes the upsampled
/// padded map as its intermediate).
pub fn footprint_conventional(p: &ConvTransposeParams) -> LayerFootprint {
    let ho = p.out_size();
    LayerFootprint {
        input_bytes: p.n_in * p.n_in * p.cin * F32,
        intermediate_bytes: upsampled_buffer_bytes(p),
        kernel_bytes: p.n_k * p.n_k * p.cin * p.cout * F32,
        output_bytes: ho * ho * p.cout * F32,
    }
}

/// Footprint of the unified algorithm (no upsampled buffer; transient
/// phase slabs are bounded by the padded input and reused per phase).
pub fn footprint_unified(p: &ConvTransposeParams) -> LayerFootprint {
    let ho = p.out_size();
    LayerFootprint {
        input_bytes: p.n_in * p.n_in * p.cin * F32,
        intermediate_bytes: proposed_input_bytes(p),
        kernel_bytes: p.n_k * p.n_k * p.cin * p.cout * F32,
        output_bytes: ho * ho * p.cout * F32,
    }
}

/// Footprint of the grouped (HICSS'23) algorithm: like unified but with
/// the even-rounded output allocation on odd output sizes.
pub fn footprint_grouped(p: &ConvTransposeParams) -> LayerFootprint {
    let mut f = footprint_unified(p);
    let ho = p.out_size();
    let ho_pad = ho.div_ceil(2) * 2;
    f.output_bytes = ho_pad * ho_pad * p.cout * F32;
    f
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dcgan_layer2_matches_paper_exactly() {
        // Table 4, DC-GAN row 2: 4×4×1024 input, k=4, P=2 → 495,616 B.
        let p = ConvTransposeParams::new(4, 4, 2, 1024, 512);
        assert_eq!(savings_table4(&p), 495_616);
    }

    #[test]
    fn dcgan_all_layers_match_paper() {
        let rows = [
            (4, 1024, 495_616),
            (8, 512, 739_328),
            (16, 256, 1_254_400),
            (32, 128, 2_298_368),
        ];
        let mut total = 0;
        for (n, c, want) in rows {
            let p = ConvTransposeParams::new(n, 4, 2, c, 1);
            assert_eq!(savings_table4(&p), want, "N={n} C={c}");
            total += savings_table4(&p);
        }
        assert_eq!(total, 4_787_712); // paper's DC-GAN total
    }

    #[test]
    fn ebgan_layers_match_paper() {
        let rows = [
            (4, 2048, 991_232),
            (8, 1024, 1_478_656),
            (16, 512, 2_508_800),
            (32, 256, 4_596_736),
            (64, 128, 8_786_432),
            (128, 64, 17_172_736),
        ];
        let mut total = 0;
        for (n, c, want) in rows {
            let p = ConvTransposeParams::new(n, 4, 2, c, 1);
            assert_eq!(savings_table4(&p), want, "N={n} C={c}");
            total += savings_table4(&p);
        }
        assert_eq!(total, 35_534_592); // the paper's "35 MB" headline
    }

    #[test]
    fn flower_dataset_matches_table2() {
        // Table 2: 224×224×3, 5×5 kernel (P=2) → 1.8279 MB (decimal).
        let p = ConvTransposeParams::new(224, 5, 2, 3, 1);
        assert_eq!(savings_table2(&p), 1_827_900);
        assert!((to_decimal_mb(savings_table2(&p)) - 1.8279).abs() < 1e-9);
    }

    #[test]
    fn table2_per_kernel_actuals() {
        // The paper reports the 5×5 figure for all kernels; actual
        // per-kernel savings differ slightly (flagged in EXPERIMENTS.md).
        let k3 = ConvTransposeParams::new(224, 3, 1, 3, 1);
        let k4 = ConvTransposeParams::new(224, 4, 2, 3, 1);
        assert_eq!(savings_table2(&k3), 1_817_100);
        assert_eq!(savings_table2(&k4), 1_827_900);
    }

    #[test]
    fn footprints_ordered() {
        let p = ConvTransposeParams::new(16, 4, 2, 64, 32);
        let conv = footprint_conventional(&p);
        let uni = footprint_unified(&p);
        assert!(conv.intermediate_bytes > uni.intermediate_bytes);
        assert_eq!(conv.output_bytes, uni.output_bytes);
        // Table 2's savings definition is exactly the intermediate delta.
        assert_eq!(
            conv.intermediate_bytes - uni.intermediate_bytes,
            savings_table2(&p)
        );
    }

    #[test]
    fn grouped_output_padding_on_odd() {
        let p = ConvTransposeParams::new(4, 5, 2, 8, 4); // ho = 7
        let g = footprint_grouped(&p);
        let u = footprint_unified(&p);
        assert_eq!(g.output_bytes, 8 * 8 * 4 * F32);
        assert_eq!(u.output_bytes, 7 * 7 * 4 * F32);
        assert!(g.total() > u.total());
    }
}
