//! Analytic memory accounting — reproduces the paper's savings columns
//! **exactly** (they are analytic, not measured; see DESIGN.md §6).
//!
//! Two definitions appear in the paper:
//!
//! * **Table 4 (GAN ablation)**: savings = the eliminated
//!   upsampled+padded buffer, `(2N−1+2P)² · C · 4` bytes.
//!   Verified: DC-GAN layer 2 → `11²·1024·4 = 495,616` ✓,
//!   EB-GAN layer 7 → `259²·64·4 = 17,172,736` ✓.
//! * **Table 2/3 (datasets)**: savings = upsampled+padded buffer minus
//!   the proposed path's padded input,
//!   `[(2N−1+2P)² − (N+2⌊P/2⌋)²] · C · 4` bytes.
//!   Verified: N=224, P=2, C=3 → `1,827,900 B = 1.8279 MB` (decimal) ✓.

use super::gemm::packed_b_floats;
use super::quant::{packed_qb_elems, Precision};
use super::unified::phase_geometries;
use super::ConvTransposeParams;

const F32: usize = std::mem::size_of::<f32>(); // 4

/// Size in bytes of the conventional path's upsampled+padded buffer
/// `(2N−1+2P)² · Cin · 4`.
pub fn upsampled_buffer_bytes(p: &ConvTransposeParams) -> usize {
    let side = 2 * p.n_in - 1 + 2 * p.padding;
    side * side * p.cin * F32
}

/// Size in bytes of the proposed path's padded raw input
/// `(N + 2⌊P/2⌋)² · Cin · 4`.
pub fn proposed_input_bytes(p: &ConvTransposeParams) -> usize {
    let side = p.n_in + 2 * (p.padding / 2);
    side * side * p.cin * F32
}

/// Table 4 definition: the whole upsampled buffer is saved.
pub fn savings_table4(p: &ConvTransposeParams) -> usize {
    upsampled_buffer_bytes(p)
}

/// Table 2/3 definition: upsampled buffer minus the padded raw input.
pub fn savings_table2(p: &ConvTransposeParams) -> usize {
    upsampled_buffer_bytes(p) - proposed_input_bytes(p)
}

/// Decimal megabytes (the paper's Table 2 unit: 1 MB = 10⁶ B).
pub fn to_decimal_mb(bytes: usize) -> f64 {
    bytes as f64 / 1e6
}

/// Full memory footprint of one layer under each algorithm (input,
/// intermediate, kernel, output) — used by the serving coordinator's
/// admission control and the ablation report.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LayerFootprint {
    pub input_bytes: usize,
    pub intermediate_bytes: usize,
    pub kernel_bytes: usize,
    pub output_bytes: usize,
}

impl LayerFootprint {
    pub fn total(&self) -> usize {
        self.input_bytes + self.intermediate_bytes + self.kernel_bytes + self.output_bytes
    }
}

/// Footprint of the conventional algorithm (materializes the upsampled
/// padded map as its intermediate).
pub fn footprint_conventional(p: &ConvTransposeParams) -> LayerFootprint {
    let ho = p.out_size();
    LayerFootprint {
        input_bytes: p.n_in * p.n_in * p.cin * F32,
        intermediate_bytes: upsampled_buffer_bytes(p),
        kernel_bytes: p.n_k * p.n_k * p.cin * p.cout * F32,
        output_bytes: ho * ho * p.cout * F32,
    }
}

/// Footprint of the unified algorithm (no upsampled buffer; transient
/// phase slabs are bounded by the padded input and reused per phase).
pub fn footprint_unified(p: &ConvTransposeParams) -> LayerFootprint {
    let ho = p.out_size();
    LayerFootprint {
        input_bytes: p.n_in * p.n_in * p.cin * F32,
        intermediate_bytes: proposed_input_bytes(p),
        kernel_bytes: p.n_k * p.n_k * p.cin * p.cout * F32,
        output_bytes: ho * ho * p.cout * F32,
    }
}

/// Footprint of the grouped (HICSS'23) algorithm: like unified but with
/// the even-rounded output allocation on odd output sizes.
pub fn footprint_grouped(p: &ConvTransposeParams) -> LayerFootprint {
    let mut f = footprint_unified(p);
    let ho = p.out_size();
    let ho_pad = ho.div_ceil(2) * 2;
    f.output_bytes = ho_pad * ho_pad * p.cout * F32;
    f
}

/// Exact working-set accounting of the **planned** execution engines
/// (DESIGN.md §Plan-Execute / §GEMM-Execution / §Batched-Execution).
///
/// [`footprint_unified`] above reproduces the *paper's* analytic claim
/// and deliberately stays verbatim — but as implemented since PR 4 the
/// planned engines hold more than the padded input: the direct arena
/// (slabs + phase outputs), the GEMM formulation's im2col patch
/// region, and the plan-resident packed B operands.  This struct
/// derives all of them from geometry alone (no plan construction, so
/// `ukstc info` can report EB-GAN-sized layers without allocating
/// hundreds of MB), and `conv::plan` unit tests pin it float-for-float
/// to the real plan's sizing.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PlannedScratch {
    /// Slab area floats (sum over phases) — one image.
    pub slab_floats: usize,
    /// Phase-output area floats (sum over phases) — one image.
    pub phase_floats: usize,
    /// Largest single phase output (the batched GEMM lanes stack `N ×`
    /// this instead of `N ×` the sum).
    pub max_phase_floats: usize,
    /// Shared im2col patch region (max over phases) — one image.
    pub patch_floats: usize,
    /// Plan-resident packed GEMM operands (not arena scratch, but very
    /// much resident memory the old accounting ignored).
    pub packed_kernel_floats: usize,
    /// Plan-resident quantized B-panel **elements** (sum over phases,
    /// `Cout` padded to the fixed `QNR = 8` quant panel width).  One
    /// element is 2 bytes for f16/bf16 storage and 1 byte for int8;
    /// int8 per-column scales are plan metadata and excluded, matching
    /// `ConvTransposePlan::packed_operand_bytes`.
    pub packed_qpanel_elems: usize,
}

impl PlannedScratch {
    /// Direct-path arena floats (`ConvTransposePlan::scratch_floats_direct`).
    pub fn direct_floats(&self) -> usize {
        self.slab_floats + self.phase_floats
    }

    /// Single-image GEMM arena floats (`ConvTransposePlan::scratch_floats`).
    pub fn gemm_floats(&self) -> usize {
        self.direct_floats() + self.patch_floats
    }

    /// Fused batched GEMM arena floats at batch `n`
    /// (`ConvTransposePlan::scratch_floats_gemm_batch`).
    pub fn gemm_batch_floats(&self, n: usize) -> usize {
        self.slab_floats + n * (self.max_phase_floats + self.patch_floats)
    }

    /// Image-parallel batched direct arena floats at batch `n`
    /// (`ConvTransposePlan::scratch_floats_batch_par`).
    pub fn batch_par_floats(&self, n: usize) -> usize {
        n.max(1) * self.direct_floats()
    }

    /// Per-batch peak arena floats: the worst any fused batched lane
    /// demands at batch `n`.
    pub fn peak_batch_floats(&self, n: usize) -> usize {
        self.gemm_batch_floats(n).max(self.batch_par_floats(n))
    }

    /// Per-batch peak scratch **bytes**, packed operands included —
    /// the honest Table-5-style resident figure for one planned layer
    /// serving batches of `n`.
    pub fn peak_batch_bytes(&self, n: usize) -> usize {
        self.peak_batch_bytes_at(n, Precision::F32)
    }

    /// Packed-B operand bytes at `precision`: the resident weight-panel
    /// footprint a deployment shipping only that precision holds.
    /// Geometry-only twin of `ConvTransposePlan::packed_operand_bytes`
    /// (pinned element-for-element by the `conv::memory` tests), so
    /// `ukstc info` can print the f16 2× / int8 4× rows for
    /// EB-GAN-sized layers without building the plan.
    pub fn packed_operand_bytes(&self, precision: Precision) -> usize {
        if precision.is_quantized() {
            self.packed_qpanel_elems * precision.operand_bytes()
        } else {
            self.packed_kernel_floats * F32
        }
    }

    /// Quantized-A arena bytes the reduced-precision lanes add on top
    /// of the f32 arena at batch `n`: the im2col patch re-encoded at
    /// the operand width (`Scratch::ensure_quant` sizing; zero for
    /// f32, which quantizes nothing).
    pub fn quant_arena_bytes(&self, n: usize, precision: Precision) -> usize {
        if precision.is_quantized() {
            n.max(1) * self.patch_floats * precision.operand_bytes()
        } else {
            0
        }
    }

    /// [`peak_batch_bytes`](Self::peak_batch_bytes) at an explicit
    /// execution precision: f32 peak arena + the quantized patch arena
    /// + the packed operands at that precision.  (The f32 arena does
    /// not shrink under quantized execution — im2col and accumulation
    /// stay f32 — only the operand copies change width.)
    pub fn peak_batch_bytes_at(&self, n: usize, precision: Precision) -> usize {
        self.peak_batch_floats(n) * F32
            + self.quant_arena_bytes(n, precision)
            + self.packed_operand_bytes(precision)
    }
}

/// Derive the planned engines' working set from layer geometry alone.
pub fn planned_scratch(p: &ConvTransposeParams) -> PlannedScratch {
    let mut s = PlannedScratch {
        slab_floats: 0,
        phase_floats: 0,
        max_phase_floats: 0,
        patch_floats: 0,
        packed_kernel_floats: 0,
        packed_qpanel_elems: 0,
    };
    for g in phase_geometries(p.n_in, p.n_k, p.padding) {
        let slab_h = g.rows.1 - g.rows.0;
        let slab_w = g.cols.1 - g.cols.0;
        // The slab is the phase output extent dilated by the sub-kernel
        // (VALID correlation), so the sub-kernel dims fall out of it.
        let kr = slab_h + 1 - g.n_rows;
        let kc = slab_w + 1 - g.n_cols;
        let phase = g.n_rows * g.n_cols * p.cout;
        let k = kr * kc * p.cin;
        s.slab_floats += slab_h * slab_w * p.cin;
        s.phase_floats += phase;
        s.max_phase_floats = s.max_phase_floats.max(phase);
        s.patch_floats = s.patch_floats.max(g.n_rows * g.n_cols * k);
        s.packed_kernel_floats += packed_b_floats(k, p.cout);
        s.packed_qpanel_elems += packed_qb_elems(k, p.cout);
    }
    s
}

/// Measured-engine footprint of one planned layer at serving batch
/// `batch`: inputs/outputs are batched, the intermediate is the
/// per-batch peak arena, and the kernel figure includes the packed
/// GEMM operands the plan keeps resident — everything the PR-4-era
/// accounting under-counted.
pub fn footprint_planned(p: &ConvTransposeParams, batch: usize) -> LayerFootprint {
    let batch = batch.max(1);
    let s = planned_scratch(p);
    let ho = p.out_size();
    LayerFootprint {
        input_bytes: batch * p.n_in * p.n_in * p.cin * F32,
        intermediate_bytes: s.peak_batch_floats(batch) * F32,
        kernel_bytes: (p.n_k * p.n_k * p.cin * p.cout + s.packed_kernel_floats) * F32,
        output_bytes: batch * ho * ho * p.cout * F32,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dcgan_layer2_matches_paper_exactly() {
        // Table 4, DC-GAN row 2: 4×4×1024 input, k=4, P=2 → 495,616 B.
        let p = ConvTransposeParams::new(4, 4, 2, 1024, 512);
        assert_eq!(savings_table4(&p), 495_616);
    }

    #[test]
    fn dcgan_all_layers_match_paper() {
        let rows = [
            (4, 1024, 495_616),
            (8, 512, 739_328),
            (16, 256, 1_254_400),
            (32, 128, 2_298_368),
        ];
        let mut total = 0;
        for (n, c, want) in rows {
            let p = ConvTransposeParams::new(n, 4, 2, c, 1);
            assert_eq!(savings_table4(&p), want, "N={n} C={c}");
            total += savings_table4(&p);
        }
        assert_eq!(total, 4_787_712); // paper's DC-GAN total
    }

    #[test]
    fn ebgan_layers_match_paper() {
        let rows = [
            (4, 2048, 991_232),
            (8, 1024, 1_478_656),
            (16, 512, 2_508_800),
            (32, 256, 4_596_736),
            (64, 128, 8_786_432),
            (128, 64, 17_172_736),
        ];
        let mut total = 0;
        for (n, c, want) in rows {
            let p = ConvTransposeParams::new(n, 4, 2, c, 1);
            assert_eq!(savings_table4(&p), want, "N={n} C={c}");
            total += savings_table4(&p);
        }
        assert_eq!(total, 35_534_592); // the paper's "35 MB" headline
    }

    #[test]
    fn flower_dataset_matches_table2() {
        // Table 2: 224×224×3, 5×5 kernel (P=2) → 1.8279 MB (decimal).
        let p = ConvTransposeParams::new(224, 5, 2, 3, 1);
        assert_eq!(savings_table2(&p), 1_827_900);
        assert!((to_decimal_mb(savings_table2(&p)) - 1.8279).abs() < 1e-9);
    }

    #[test]
    fn table2_per_kernel_actuals() {
        // The paper reports the 5×5 figure for all kernels; actual
        // per-kernel savings differ slightly (flagged in EXPERIMENTS.md).
        let k3 = ConvTransposeParams::new(224, 3, 1, 3, 1);
        let k4 = ConvTransposeParams::new(224, 4, 2, 3, 1);
        assert_eq!(savings_table2(&k3), 1_817_100);
        assert_eq!(savings_table2(&k4), 1_827_900);
    }

    #[test]
    fn footprints_ordered() {
        let p = ConvTransposeParams::new(16, 4, 2, 64, 32);
        let conv = footprint_conventional(&p);
        let uni = footprint_unified(&p);
        assert!(conv.intermediate_bytes > uni.intermediate_bytes);
        assert_eq!(conv.output_bytes, uni.output_bytes);
        // Table 2's savings definition is exactly the intermediate delta.
        assert_eq!(
            conv.intermediate_bytes - uni.intermediate_bytes,
            savings_table2(&p)
        );
    }

    #[test]
    fn planned_scratch_matches_real_plan_sizing() {
        // The geometry-only derivation must agree float-for-float with
        // a constructed plan — on even, odd and degenerate shapes.
        use crate::conv::plan::ConvTransposePlan;
        use crate::tensor::Kernel;
        use crate::util::rng::Rng;
        let mut rng = Rng::seeded(0x3E3);
        let shapes = [(4, 5, 2, 3, 2), (8, 4, 2, 6, 4), (5, 3, 1, 2, 2), (1, 3, 2, 1, 1)];
        for (n, nk, pd, cin, cout) in shapes {
            let p = ConvTransposeParams::new(n, nk, pd, cin, cout);
            let k = Kernel::random(nk, cin, cout, &mut rng);
            let plan = ConvTransposePlan::new(p, &k);
            let s = planned_scratch(&p);
            assert_eq!(s.direct_floats(), plan.scratch_floats_direct(), "direct n={n}");
            assert_eq!(s.gemm_floats(), plan.scratch_floats(), "gemm n={n}");
            assert_eq!(s.patch_floats, plan.patch_region_floats(), "patch n={n}");
            assert_eq!(
                s.packed_kernel_floats,
                plan.packed_operand_floats(),
                "packed n={n}"
            );
            for prec in Precision::ALL {
                assert_eq!(
                    s.packed_operand_bytes(prec),
                    plan.packed_operand_bytes(prec),
                    "packed {} n={n}",
                    prec.name()
                );
            }
            for b in [1usize, 4, 8] {
                assert_eq!(s.gemm_batch_floats(b), plan.scratch_floats_gemm_batch(b));
                assert_eq!(s.batch_par_floats(b), plan.scratch_floats_batch_par(b));
                assert_eq!(s.peak_batch_floats(b), plan.peak_scratch_floats_batch(b));
            }
        }
    }

    #[test]
    fn planned_footprint_counts_what_the_paper_figure_missed() {
        // The under-count fix: once the GEMM patch/pack regions exist,
        // the honest working set strictly exceeds the paper's
        // padded-input intermediate and bare-kernel figures.
        let p = ConvTransposeParams::new(16, 4, 2, 64, 32);
        let paper = footprint_unified(&p);
        let real = footprint_planned(&p, 1);
        assert!(real.intermediate_bytes > paper.intermediate_bytes);
        assert!(real.kernel_bytes > paper.kernel_bytes);
        // Batched serving scales inputs/outputs/peak-arena, not weights.
        let b8 = footprint_planned(&p, 8);
        assert_eq!(b8.input_bytes, 8 * real.input_bytes);
        assert_eq!(b8.output_bytes, 8 * real.output_bytes);
        assert!(b8.intermediate_bytes > real.intermediate_bytes);
        assert_eq!(b8.kernel_bytes, real.kernel_bytes);
        let s = planned_scratch(&p);
        assert!(s.peak_batch_bytes(8) > s.peak_batch_bytes(1));
        assert_eq!(footprint_planned(&p, 0), footprint_planned(&p, 1));
    }

    #[test]
    fn per_precision_packed_operand_reduction_on_table4() {
        // The ISSUE acceptance bar: on every Table-4 layer (with the
        // models' real channel trajectories, not the C_out = 1 savings
        // rows), f16/bf16 packed operands are at least 2x smaller than
        // f32 and int8 at least 4x.  Structurally guaranteed because
        // the f32 panels pad C_out to the active ISA width (>= 8) at
        // 4 B/elem while qpanels pad to QNR = 8 at 2 B / 1 B — but the
        // claim ships as a test, not an argument.  Geometry-only, so
        // the EB-GAN stack costs nothing to check.
        let dcgan = [(4, 1024, 512), (8, 512, 256), (16, 256, 128), (32, 128, 3)];
        let ebgan = [
            (4, 2048, 1024),
            (8, 1024, 512),
            (16, 512, 256),
            (32, 256, 128),
            (64, 128, 64),
            (128, 64, 3),
        ];
        for (n, cin, cout) in dcgan.iter().chain(&ebgan) {
            let p = ConvTransposeParams::gan_layer().with_io(*n, *cin, *cout);
            let s = planned_scratch(&p);
            let f32b = s.packed_operand_bytes(Precision::F32);
            let f16b = s.packed_operand_bytes(Precision::F16);
            let i8b = s.packed_operand_bytes(Precision::Int8);
            assert_eq!(f16b, s.packed_operand_bytes(Precision::Bf16));
            assert!(f32b >= 2 * f16b, "f16 2x on N={n} Cout={cout}");
            assert!(f32b >= 4 * i8b, "int8 4x on N={n} Cout={cout}");
            // Peak-scratch rows: f32 row is the legacy figure; the
            // quantized rows add exactly the re-encoded patch arena on
            // top of the (unchanged) f32 arena + smaller operands.
            for b in [1usize, 8] {
                assert_eq!(s.peak_batch_bytes_at(b, Precision::F32), s.peak_batch_bytes(b));
                for prec in Precision::QUANTIZED {
                    assert_eq!(
                        s.peak_batch_bytes_at(b, prec),
                        s.peak_batch_floats(b) * F32
                            + b * s.patch_floats * prec.operand_bytes()
                            + s.packed_operand_bytes(prec)
                    );
                }
            }
            assert_eq!(s.quant_arena_bytes(4, Precision::F32), 0);
            assert_eq!(s.quant_arena_bytes(0, Precision::Int8), s.patch_floats);
        }
    }

    #[test]
    fn grouped_output_padding_on_odd() {
        let p = ConvTransposeParams::new(4, 5, 2, 8, 4); // ho = 7
        let g = footprint_grouped(&p);
        let u = footprint_unified(&p);
        assert_eq!(g.output_bytes, 8 * 8 * 4 * F32);
        assert_eq!(u.output_bytes, 7 * 7 * 4 * F32);
        assert!(g.total() > u.total());
    }
}
