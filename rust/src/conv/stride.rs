//! Generalized stride-`s` kernel segregation (extension beyond the
//! paper, which fixes `s = 2`).
//!
//! For stride `s`, bed-of-nails upsampling maps `N×N → (sN - s + 1)²`
//! with real pixels at multiples of `s`; the kernel segregates into
//! `s × s` sub-kernels `k_rs = K[r::s, s'::s]` and output element
//! `(i, j)` (padding `P`) uses `k_{(i+P)%s, (j+P)%s}` starting at input
//! offset `⌈(i − P)/s⌉`.  Setting `s = 2` recovers Algorithm 2 exactly
//! (checked by a regression test against `unified`).

use crate::tensor::{Feature, SubKernel};
use crate::tensor::Kernel;

/// Output size for stride `s`: `(sN - s + 1) + 2P - n + 1`.
pub fn out_size_s(n_in: usize, n_k: usize, padding: usize, stride: usize) -> usize {
    (stride * n_in - stride + 1 + 2 * padding)
        .checked_sub(n_k)
        .expect("kernel larger than padded upsampled input")
        + 1
}

/// `s × s` segregation: `subs[r * s + c] = K[r::s, c::s]`.
pub fn segregate_s(k: &Kernel, stride: usize) -> Vec<SubKernel> {
    assert!(stride >= 1);
    let n = k.n;
    let mut subs = Vec::with_capacity(stride * stride);
    for r in 0..stride {
        for c in 0..stride {
            let rows = if n > r { (n - r).div_ceil(stride) } else { 0 };
            let cols = if n > c { (n - c).div_ceil(stride) } else { 0 };
            let mut sub = SubKernel::zeros(rows, cols, k.cin, k.cout);
            for (su, u) in (r..n).step_by(stride).enumerate() {
                for (sv, v) in (c..n).step_by(stride).enumerate() {
                    let src = k.tap(u, v);
                    let base = sub.idx(su, sv, 0, 0);
                    sub.data[base..base + src.len()].copy_from_slice(src);
                }
            }
            subs.push(sub);
        }
    }
    subs
}

/// Reference: bed-of-nails upsample with stride `s` then dense VALID
/// correlation (the generalization of Algorithm 1).
pub fn transpose_conv_naive_s(
    x: &Feature,
    k: &Kernel,
    padding: usize,
    stride: usize,
) -> Feature {
    use crate::tensor::ops;
    let side = stride * x.h - stride + 1;
    let mut up = Feature::zeros(side, side, x.c);
    for y in 0..x.h {
        for xx in 0..x.w {
            let src = x.idx(y, xx, 0);
            let dst = up.idx(stride * y, stride * xx, 0);
            up.data[dst..dst + x.c].copy_from_slice(&x.data[src..src + x.c]);
        }
    }
    let padded = ops::pad(&up, padding);
    super::conventional::correlate_valid(&padded, k)
}

/// Unified stride-`s` segregated transpose conv (per-element form with
/// runtime sub-kernel selection — the natural generalization of the
/// paper's Algorithm 2).
pub fn transpose_conv_unified_s(
    x: &Feature,
    k: &Kernel,
    padding: usize,
    stride: usize,
) -> Feature {
    assert_eq!(x.h, x.w, "square inputs only");
    let subs = segregate_s(k, stride);
    let n = x.h as isize;
    let s = stride as isize;
    let p = padding as isize;
    let ho = out_size_s(x.h, k.n, padding, stride);
    let cout = k.cout;
    let mut out = Feature::zeros(ho, ho, cout);
    for i in 0..ho {
        let ii = i as isize;
        // Selection: u ≡ (P − i) mod s (for s=2 this equals the paper's
        // (i+P) mod 2); base(i) = ceil((i − P)/s).
        let r = ((p - ii).rem_euclid(s)) as usize;
        let base_i = (ii - p).div_euclid(s)
            + ((ii - p).rem_euclid(s) != 0) as isize;
        for j in 0..ho {
            let jj = j as isize;
            let c = ((p - jj).rem_euclid(s)) as usize;
            let base_j = (jj - p).div_euclid(s)
                + ((jj - p).rem_euclid(s) != 0) as isize;
            let sub = &subs[r * stride + c];
            if sub.rows == 0 || sub.cols == 0 {
                continue;
            }
            let dst = out.idx(i, j, 0);
            // Split the mutable borrow: take the accumulator row out.
            for u in 0..sub.rows {
                let iy = base_i + u as isize;
                if iy < 0 || iy >= n {
                    continue;
                }
                for v in 0..sub.cols {
                    let ix = base_j + v as isize;
                    if ix < 0 || ix >= n {
                        continue;
                    }
                    let px_base = x.idx(iy as usize, ix as usize, 0);
                    let tap = sub.tap(u, v);
                    for ci in 0..x.c {
                        let xv = x.data[px_base + ci];
                        let trow = &tap[ci * cout..(ci + 1) * cout];
                        for (co, &t) in trow.iter().enumerate() {
                            out.data[dst + co] += xv * t;
                        }
                    }
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::unified;
    use crate::tensor::ops;
    use crate::util::prop::{close, forall_res, Config};
    use crate::util::rng::Rng;

    #[test]
    fn stride2_recovers_algorithm2() {
        let mut rng = Rng::seeded(90);
        for (n_in, nk, p) in [(4, 4, 2), (5, 3, 1), (4, 5, 2)] {
            let x = Feature::random(n_in, n_in, 2, &mut rng);
            let k = Kernel::random(nk, 2, 2, &mut rng);
            let a = unified::transpose_conv(&x, &k, p);
            let b = transpose_conv_unified_s(&x, &k, p, 2);
            assert_eq!((a.h, a.w), (b.h, b.w));
            assert!(ops::max_abs_diff(&a, &b) < 1e-4, "n={n_in} k={nk} p={p}");
        }
    }

    #[test]
    fn stride3_matches_naive() {
        let mut rng = Rng::seeded(91);
        for (n_in, nk, p) in [(3, 3, 0), (4, 4, 2), (3, 5, 2)] {
            let x = Feature::random(n_in, n_in, 2, &mut rng);
            let k = Kernel::random(nk, 2, 2, &mut rng);
            let a = transpose_conv_naive_s(&x, &k, p, 3);
            let b = transpose_conv_unified_s(&x, &k, p, 3);
            assert_eq!((a.h, a.w), (b.h, b.w));
            assert!(ops::max_abs_diff(&a, &b) < 1e-4, "n={n_in} k={nk} p={p}");
        }
    }

    #[test]
    fn stride1_is_plain_convolution() {
        // s=1: no upsampling at all; unified == plain padded correlation.
        let mut rng = Rng::seeded(92);
        let x = Feature::random(5, 5, 2, &mut rng);
        let k = Kernel::random(3, 2, 2, &mut rng);
        let a = transpose_conv_naive_s(&x, &k, 1, 1);
        let b = transpose_conv_unified_s(&x, &k, 1, 1);
        assert!(ops::max_abs_diff(&a, &b) < 1e-4);
    }

    #[test]
    fn segregation_partitions_for_any_stride() {
        let mut rng = Rng::seeded(93);
        for stride in 1..=4 {
            for nk in 2..=6 {
                let k = Kernel::random(nk, 1, 1, &mut rng);
                let subs = segregate_s(&k, stride);
                assert_eq!(subs.len(), stride * stride);
                let total: usize = subs.iter().map(|s| s.taps()).sum();
                assert_eq!(total, nk * nk, "stride={stride} nk={nk}");
            }
        }
    }

    #[test]
    fn prop_general_stride_equivalence() {
        forall_res(
            Config::default().cases(40),
            "stride-s unified == naive",
            |rng| {
                let stride = rng.range(1, 4);
                let n_in = rng.range(2, 5);
                let nk = rng.range(2, 5);
                let p = rng.range(0, 2);
                let up_side = stride * n_in - stride + 1 + 2 * p;
                if up_side < nk {
                    return ((stride, n_in, nk, p), Ok(()));
                }
                let mut r2 = rng.split();
                let x = Feature::random(n_in, n_in, 2, &mut r2);
                let k = Kernel::random(nk, 2, 2, &mut r2);
                let a = transpose_conv_naive_s(&x, &k, p, stride);
                let b = transpose_conv_unified_s(&x, &k, p, stride);
                ((stride, n_in, nk, p), close(&a.data, &b.data, 1e-3))
            },
        );
    }
}
