//! Plan/execute: ahead-of-time transpose-conv plans and a zero-alloc
//! scratch arena (DESIGN.md §Plan-Execute).
//!
//! The one-shot entry points in [`unified`](super::unified) recompute
//! the phase geometry, build four slabs, and heap-allocate four phase
//! buffers plus the output on *every* call — per-call overhead the
//! paper's resident CUDA kernel never pays.  Following the
//! plan-once/execute-many discipline of HUGE2 and the static operation
//! schedules of GANAX (PAPERS.md), this module hoists all
//! shape-dependent work to construction time:
//!
//! * [`ConvTransposePlan`] — built once per `(ConvTransposeParams,
//!   kernel)`: segregates the kernel, freezes the four
//!   [`PhaseGeometry`]s, derives every slab window and per-phase output
//!   extent, and lays the whole working set out as offsets into one
//!   contiguous arena with an **exact** float requirement
//!   ([`scratch_floats`](ConvTransposePlan::scratch_floats)).
//! * [`Scratch`] — the reusable arena.  It grows to the high-water mark
//!   of whatever plans run through it and never shrinks, so steady-state
//!   [`run`](ConvTransposePlan::run) performs **zero heap allocations**
//!   (pinned by the counting-allocator test in `tests/plan_alloc.rs`).
//!   One arena may be shared across differently-shaped layers: every
//!   byte a run reads is written first (`build_slab` covers slabs, the
//!   phase regions are zero-filled), so stale data never aliases in.
//!
//! Direct (correlation) execution is bit-identical to the one-shot
//! path — same slab values, same correlation loops, same f32
//! accumulation order — which the property suite asserts with `==`,
//! not a tolerance.  The planned **phase-GEMM** formulation
//! ([`run_gemm`](ConvTransposePlan::run_gemm), DESIGN.md
//! §GEMM-Execution) executes the same phases as packed GEMMs through
//! the tiled microkernel (`conv::gemm`): each segregated sub-kernel is
//! packed into its GEMM operand layout **once at construction**, and
//! the im2col patch matrix lives in the scratch arena — so the GEMM
//! steady state is also zero-alloc, equivalent to the direct reference
//! within 1e-4 (f32 reassociation through the register tile).

use crate::tensor::{Feature, Kernel};
use crate::tune::space::{ExecStrategy, Formulation, ParAxis};
use crate::util::threadpool;

use super::conventional::correlate_rows;
use super::gemm;
use super::im2col::kernel_matrix;
use super::segregation::{segregate, Segregated};
use super::unified::{build_slab, phase_geometries, scatter_rows, PhaseGeometry};
use super::ConvTransposeParams;

/// One phase of the plan: its frozen geometry plus the arena layout
/// and the plan-time-packed GEMM operand.
#[derive(Debug, Clone)]
struct PhasePlan {
    geom: PhaseGeometry,
    /// Slab (padded input window) width in pixels.
    slab_w: usize,
    /// Float offset/length of the slab within the arena's slab area.
    slab_off: usize,
    slab_len: usize,
    /// Float offset/length of the phase output within the phase area.
    phase_off: usize,
    phase_len: usize,
    /// GEMM reduction depth `kr·kc·Cin` of this phase's sub-kernel.
    gemm_k: usize,
    /// im2col patch-matrix floats (`n_rows·n_cols·gemm_k`) — the
    /// phase's claim on the arena's shared patch area.
    patch_len: usize,
    /// The sub-kernel as a packed GEMM B operand
    /// (`gemm::pack_b` over the tap-major `[gemm_k, Cout]` matrix),
    /// laid out once here so steady-state GEMM execution never packs.
    packed_kernel: Vec<f32>,
}

/// An ahead-of-time plan for one transpose-convolution layer.
///
/// Owns the pre-segregated kernel and every shape-derived quantity, so
/// the steady-state call does arithmetic and memory traffic only.
#[derive(Debug, Clone)]
pub struct ConvTransposePlan {
    params: ConvTransposeParams,
    seg: Segregated,
    phases: Vec<PhasePlan>,
    /// Output spatial size.
    out: usize,
    /// Total floats of the slab area (phase area follows it).
    slab_floats: usize,
    phase_floats: usize,
    /// Floats of the shared im2col patch area (max over phases —
    /// phases run one at a time, so one region serves all four).
    patch_floats: usize,
}

impl ConvTransposePlan {
    /// Build a plan from a full kernel (segregates once, here).
    pub fn new(params: ConvTransposeParams, kernel: &Kernel) -> ConvTransposePlan {
        assert_eq!(kernel.n, params.n_k, "plan: kernel size mismatch");
        assert_eq!(
            (kernel.cin, kernel.cout),
            (params.cin, params.cout),
            "plan: kernel channel mismatch"
        );
        ConvTransposePlan::from_seg(params, segregate(kernel))
    }

    /// Build a plan from an already-segregated kernel (takes ownership —
    /// weights are prepared once at load time and live in the plan).
    pub fn from_seg(params: ConvTransposeParams, seg: Segregated) -> ConvTransposePlan {
        assert!(
            params.n_in > 0 && params.cin > 0 && params.cout > 0,
            "plan requires fully-specified I/O geometry (chain with_io on templates)"
        );
        assert_eq!(seg.n, params.n_k, "plan: segregated kernel size mismatch");
        assert_eq!(
            (seg.subs[0].cin, seg.subs[0].cout),
            (params.cin, params.cout),
            "plan: segregated kernel channel mismatch"
        );
        let out = params.out_size();
        let mut slab_off = 0usize;
        let mut phase_off = 0usize;
        let mut patch_floats = 0usize;
        let phases = phase_geometries(params.n_in, params.n_k, params.padding)
            .into_iter()
            .map(|geom| {
                let slab_h = geom.rows.1 - geom.rows.0;
                let slab_w = geom.cols.1 - geom.cols.0;
                let slab_len = slab_h * slab_w * params.cin;
                let phase_len = geom.n_rows * geom.n_cols * params.cout;
                // Plan-time GEMM lowering: pack this phase's sub-kernel
                // into its panel operand once, here.
                let sub = &seg.subs[geom.sub];
                let gemm_k = sub.rows * sub.cols * params.cin;
                let patch_len = geom.n_rows * geom.n_cols * gemm_k;
                patch_floats = patch_floats.max(patch_len);
                let mut packed_kernel = vec![0.0f32; gemm::packed_b_floats(gemm_k, params.cout)];
                gemm::pack_b(&kernel_matrix(sub), gemm_k, params.cout, &mut packed_kernel);
                let pp = PhasePlan {
                    geom,
                    slab_w,
                    slab_off,
                    slab_len,
                    phase_off,
                    phase_len,
                    gemm_k,
                    patch_len,
                    packed_kernel,
                };
                slab_off += slab_len;
                phase_off += phase_len;
                pp
            })
            .collect();
        ConvTransposePlan {
            params,
            seg,
            phases,
            out,
            slab_floats: slab_off,
            phase_floats: phase_off,
            patch_floats,
        }
    }

    /// The layer geometry this plan was built for.
    pub fn params(&self) -> &ConvTransposeParams {
        &self.params
    }

    /// The pre-segregated kernel the plan executes with.
    pub fn seg(&self) -> &Segregated {
        &self.seg
    }

    /// Output spatial size (square).
    pub fn out_size(&self) -> usize {
        self.out
    }

    /// Exact scratch requirement in floats covering **every**
    /// execution strategy: slabs + phase outputs + the shared im2col
    /// patch region the GEMM formulation fills (max over phases).  An
    /// arena pre-sized to this runs any tuned [`ExecStrategy`] —
    /// including [`Formulation::PhaseGemm`] — without ever growing.
    pub fn scratch_floats(&self) -> usize {
        self.scratch_floats_direct() + self.patch_floats
    }

    /// Exact scratch requirement of the direct (correlation) paths
    /// alone ([`run`](Self::run)/[`run_par`](Self::run_par)/
    /// [`run_par_rows`](Self::run_par_rows)): slabs + phase outputs.
    /// Direct execution only ever grows an arena to this, so
    /// GEMM-free deployments don't pay for the patch region.
    pub fn scratch_floats_direct(&self) -> usize {
        self.slab_floats + self.phase_floats
    }

    /// Exact scratch requirement of one strategy: the GEMM-inclusive
    /// figure for [`Formulation::PhaseGemm`], the direct figure for
    /// everything else (the per-element lanes allocate their own
    /// output and use no scratch at all, but sizing them like the
    /// direct paths keeps one arena safely shared across pins).
    pub fn scratch_floats_for(&self, strategy: &ExecStrategy) -> usize {
        match strategy.formulation {
            Formulation::PhaseGemm => self.scratch_floats(),
            _ => self.scratch_floats_direct(),
        }
    }

    /// Exact scratch requirement in bytes (fp32, every strategy).
    pub fn scratch_bytes(&self) -> usize {
        self.scratch_floats() * std::mem::size_of::<f32>()
    }

    /// A correctly-shaped output buffer for this plan.
    pub fn new_output(&self) -> Feature {
        Feature::zeros(self.out, self.out, self.params.cout)
    }

    fn check_shapes(&self, x: &Feature, out: &Feature) {
        assert_eq!(
            (x.h, x.w, x.c),
            (self.params.n_in, self.params.n_in, self.params.cin),
            "plan: input shape mismatch"
        );
        assert_eq!(
            (out.h, out.w, out.c),
            (self.out, self.out, self.params.cout),
            "plan: output shape mismatch"
        );
    }

    /// Execute serially: `x → out` through `scratch`.
    ///
    /// Steady state (arena at its high-water mark) performs **zero**
    /// heap allocations: slabs are cropped into the arena, phases are
    /// correlated into the arena, and the scatter writes every output
    /// element (the phase extents partition the output, so `out` needs
    /// no pre-clearing).
    pub fn run(&self, x: &Feature, scratch: &mut Scratch, out: &mut Feature) {
        self.check_shapes(x, out);
        let buf = scratch.ensure(self.scratch_floats_direct());
        let (slab_area, phase_area) = buf.split_at_mut(self.slab_floats);
        for pp in &self.phases {
            build_slab(x, &pp.geom, &mut slab_area[pp.slab_off..pp.slab_off + pp.slab_len]);
            let phase = &mut phase_area[pp.phase_off..pp.phase_off + pp.phase_len];
            phase.fill(0.0);
            correlate_rows(
                &slab_area[pp.slab_off..pp.slab_off + pp.slab_len],
                pp.slab_w,
                &self.seg.subs[pp.geom.sub],
                phase,
                pp.geom.n_cols,
                0,
                pp.geom.n_rows,
            );
            scatter_rows(
                out,
                &phase_area[pp.phase_off..pp.phase_off + pp.phase_len],
                pp.geom.rp,
                pp.geom.sp,
                pp.geom.n_rows,
                pp.geom.n_cols,
            );
        }
    }

    /// Execute with the output allocated here (convenience for callers
    /// that consume the result immediately).
    pub fn run_alloc(&self, x: &Feature, scratch: &mut Scratch) -> Feature {
        let mut out = self.new_output();
        self.run(x, scratch, &mut out);
        out
    }

    /// Parallel execution, phase×row axis: one work queue of
    /// `(phase, output-row)` jobs drained by `workers` threads of the
    /// persistent kernel pool ([`threadpool::parallel_drain`] — no
    /// per-call thread spawning, so small-layer timings measure the
    /// kernel and tuned worker counts mean what they measure).  Tensor
    /// buffers all come from the arena; only the per-call job list is
    /// allocated.  Bit-identical to [`run`] (each output row is
    /// computed by the same serial loops).
    pub fn run_par(&self, x: &Feature, scratch: &mut Scratch, out: &mut Feature, workers: usize) {
        let workers = workers.max(1);
        if workers == 1 {
            return self.run(x, scratch, out);
        }
        self.check_shapes(x, out);
        let cout = self.params.cout;
        let buf = scratch.ensure(self.scratch_floats_direct());
        {
            let (slab_area, phase_area) = buf.split_at_mut(self.slab_floats);
            for pp in &self.phases {
                let slab = &mut slab_area[pp.slab_off..pp.slab_off + pp.slab_len];
                build_slab(x, &pp.geom, slab);
            }
            let slab_area: &[f32] = slab_area;
            let mut jobs: Vec<(usize, usize, &mut [f32])> = Vec::new();
            let mut rest: &mut [f32] = phase_area;
            for (pi, pp) in self.phases.iter().enumerate() {
                let (mine, tail) = rest.split_at_mut(pp.phase_len);
                rest = tail;
                let row_len = pp.geom.n_cols * cout;
                for (ri, row) in mine.chunks_mut(row_len).enumerate() {
                    jobs.push((pi, ri, row));
                }
            }
            threadpool::parallel_drain(jobs, workers, |(pi, ri, row)| {
                let pp = &self.phases[pi];
                row.fill(0.0);
                correlate_rows(
                    &slab_area[pp.slab_off..pp.slab_off + pp.slab_len],
                    pp.slab_w,
                    &self.seg.subs[pp.geom.sub],
                    row,
                    pp.geom.n_cols,
                    ri,
                    ri + 1,
                );
            });
        }
        let phase_area = &buf[self.slab_floats..];
        for pp in &self.phases {
            scatter_rows(
                out,
                &phase_area[pp.phase_off..pp.phase_off + pp.phase_len],
                pp.geom.rp,
                pp.geom.sp,
                pp.geom.n_rows,
                pp.geom.n_cols,
            );
        }
    }

    /// Parallel execution, row axis: phases processed one at a time,
    /// each phase's output rows drained across `workers` pool threads —
    /// trades the phase×row queue's load balance for locality (one
    /// slab + sub-kernel resident per step).  Bit-identical to [`run`].
    pub fn run_par_rows(
        &self,
        x: &Feature,
        scratch: &mut Scratch,
        out: &mut Feature,
        workers: usize,
    ) {
        let workers = workers.max(1);
        if workers == 1 {
            return self.run(x, scratch, out);
        }
        self.check_shapes(x, out);
        let cout = self.params.cout;
        let buf = scratch.ensure(self.scratch_floats_direct());
        {
            let (slab_area, phase_area) = buf.split_at_mut(self.slab_floats);
            for pp in &self.phases {
                let slab = &mut slab_area[pp.slab_off..pp.slab_off + pp.slab_len];
                build_slab(x, &pp.geom, slab);
            }
            let slab_area: &[f32] = slab_area;
            let mut rest: &mut [f32] = phase_area;
            for pp in &self.phases {
                let (mine, tail) = rest.split_at_mut(pp.phase_len);
                rest = tail;
                let row_len = pp.geom.n_cols * cout;
                let jobs: Vec<(usize, &mut [f32])> = mine.chunks_mut(row_len).enumerate().collect();
                threadpool::parallel_drain(jobs, workers, |(ri, row)| {
                    row.fill(0.0);
                    correlate_rows(
                        &slab_area[pp.slab_off..pp.slab_off + pp.slab_len],
                        pp.slab_w,
                        &self.seg.subs[pp.geom.sub],
                        row,
                        pp.geom.n_cols,
                        ri,
                        ri + 1,
                    );
                });
            }
        }
        let phase_area = &buf[self.slab_floats..];
        for pp in &self.phases {
            scatter_rows(
                out,
                &phase_area[pp.phase_off..pp.phase_off + pp.phase_len],
                pp.geom.rp,
                pp.geom.sp,
                pp.geom.n_rows,
                pp.geom.n_cols,
            );
        }
    }

    /// Execute through the planned phase-GEMM engine, serially
    /// (DESIGN.md §GEMM-Execution): per phase, crop the slab into the
    /// arena, im2col it into the arena's patch region, and multiply by
    /// the sub-kernel packed at construction
    /// ([`gemm::gemm_packed`], register-blocked + cache-tiled).
    /// Steady state performs **zero** heap allocations (the patch
    /// region is part of [`scratch_floats`](Self::scratch_floats)).
    /// Equivalent to [`run`](Self::run) within 1e-4 — the register
    /// tile reassociates f32 sums, so bit-identity is not promised.
    pub fn run_gemm(&self, x: &Feature, scratch: &mut Scratch, out: &mut Feature) {
        self.check_shapes(x, out);
        let cout = self.params.cout;
        let buf = scratch.ensure(self.scratch_floats());
        let (slab_area, rest) = buf.split_at_mut(self.slab_floats);
        let (phase_area, patch_area) = rest.split_at_mut(self.phase_floats);
        for pp in &self.phases {
            let slab = &mut slab_area[pp.slab_off..pp.slab_off + pp.slab_len];
            build_slab(x, &pp.geom, slab);
            let sub = &self.seg.subs[pp.geom.sub];
            let patch = &mut patch_area[..pp.patch_len];
            gemm::im2col_rows(
                slab,
                pp.slab_w,
                self.params.cin,
                sub.rows,
                sub.cols,
                pp.geom.n_cols,
                0,
                pp.geom.n_rows,
                patch,
            );
            let phase = &mut phase_area[pp.phase_off..pp.phase_off + pp.phase_len];
            phase.fill(0.0);
            gemm::gemm_packed(
                patch,
                &pp.packed_kernel,
                phase,
                pp.geom.n_rows * pp.geom.n_cols,
                pp.gemm_k,
                cout,
            );
            scatter_rows(
                out,
                phase,
                pp.geom.rp,
                pp.geom.sp,
                pp.geom.n_rows,
                pp.geom.n_cols,
            );
        }
    }

    /// Row-parallel phase-GEMM lane: phases processed one at a time,
    /// each phase's output rows drained across `workers` pool threads —
    /// every job im2cols its own patch rows and runs its own
    /// `n_cols × Cout` GEMM against the shared packed sub-kernel.
    /// Same 1e-4 equivalence contract as [`run_gemm`](Self::run_gemm)
    /// (each output element's sum is computed by the same microkernel
    /// whatever the worker count, so this lane matches `run_gemm`
    /// bit-for-bit; only the direct reference is tolerance-matched).
    pub fn run_gemm_par_rows(
        &self,
        x: &Feature,
        scratch: &mut Scratch,
        out: &mut Feature,
        workers: usize,
    ) {
        let workers = workers.max(1);
        if workers == 1 {
            return self.run_gemm(x, scratch, out);
        }
        self.check_shapes(x, out);
        let cin = self.params.cin;
        let cout = self.params.cout;
        let buf = scratch.ensure(self.scratch_floats());
        {
            let (slab_area, rest) = buf.split_at_mut(self.slab_floats);
            let (phase_area, patch_area) = rest.split_at_mut(self.phase_floats);
            for pp in &self.phases {
                let slab = &mut slab_area[pp.slab_off..pp.slab_off + pp.slab_len];
                build_slab(x, &pp.geom, slab);
            }
            let slab_area: &[f32] = slab_area;
            let mut rest: &mut [f32] = phase_area;
            for pp in &self.phases {
                let (mine, tail) = rest.split_at_mut(pp.phase_len);
                rest = tail;
                let sub = &self.seg.subs[pp.geom.sub];
                let row_len = pp.geom.n_cols * cout;
                let patch_row_len = pp.geom.n_cols * pp.gemm_k;
                let jobs: Vec<(usize, &mut [f32], &mut [f32])> = mine
                    .chunks_mut(row_len)
                    .zip(patch_area[..pp.patch_len].chunks_mut(patch_row_len))
                    .enumerate()
                    .map(|(ri, (row, patch))| (ri, row, patch))
                    .collect();
                threadpool::parallel_drain(jobs, workers, |(ri, row, patch)| {
                    let slab = &slab_area[pp.slab_off..pp.slab_off + pp.slab_len];
                    gemm::im2col_rows(
                        slab,
                        pp.slab_w,
                        cin,
                        sub.rows,
                        sub.cols,
                        pp.geom.n_cols,
                        ri,
                        ri + 1,
                        patch,
                    );
                    row.fill(0.0);
                    gemm::gemm_packed(
                        patch,
                        &pp.packed_kernel,
                        row,
                        pp.geom.n_cols,
                        pp.gemm_k,
                        cout,
                    );
                });
            }
        }
        let phase_area = &buf[self.slab_floats..];
        for pp in &self.phases {
            scatter_rows(
                out,
                &phase_area[pp.phase_off..pp.phase_off + pp.phase_len],
                pp.geom.rp,
                pp.geom.sp,
                pp.geom.n_rows,
                pp.geom.n_cols,
            );
        }
    }

    /// Execute under an autotuned [`ExecStrategy`]
    /// (`tune::space`, DESIGN.md §Autotuning): dispatches to [`run`],
    /// [`run_par`] (phase×row axis), [`run_par_rows`], the
    /// per-element formulation of Algorithm 2, or the planned
    /// phase-GEMM engine ([`run_gemm`]/[`run_gemm_par_rows`]).  The
    /// direct strategies are bit-identical to [`run`] — same in-range
    /// contributions accumulated in the same (tap-row, tap-col,
    /// channel) order — which the equivalence property in
    /// `tests/conv_properties.rs` pins with `==`; the
    /// [`Formulation::PhaseGemm`] strategies reassociate f32 sums
    /// through the register tile and are pinned within 1e-4 instead.
    pub fn run_with(
        &self,
        strategy: &ExecStrategy,
        x: &Feature,
        scratch: &mut Scratch,
        out: &mut Feature,
    ) {
        match strategy.formulation {
            Formulation::PhaseDecomposed => {
                if strategy.workers <= 1 {
                    self.run(x, scratch, out);
                } else {
                    match strategy.axis {
                        ParAxis::PhaseRows => self.run_par(x, scratch, out, strategy.workers),
                        ParAxis::Rows => self.run_par_rows(x, scratch, out, strategy.workers),
                    }
                }
            }
            Formulation::PhaseGemm => {
                if strategy.workers <= 1 {
                    self.run_gemm(x, scratch, out);
                } else {
                    self.run_gemm_par_rows(x, scratch, out, strategy.workers);
                }
            }
            Formulation::PerElement => {
                self.check_shapes(x, out);
                let got = if strategy.workers <= 1 {
                    super::unified::transpose_conv_per_element_seg(
                        x,
                        &self.seg,
                        self.params.padding,
                    )
                } else {
                    super::parallel::unified_per_element_par(
                        x,
                        &self.seg,
                        self.params.padding,
                        strategy.workers,
                    )
                };
                out.data.copy_from_slice(&got.data);
            }
        }
    }
}

/// Reusable scratch arena for planned execution.
///
/// One flat `Vec<f32>` that grows to the high-water mark of the plans
/// run through it and never shrinks.  Safe to thread through
/// differently-shaped layers back to back: plans write every scratch
/// byte they read, so no run observes another run's data.
#[derive(Debug, Clone, Default)]
pub struct Scratch {
    buf: Vec<f32>,
}

impl Scratch {
    /// An empty arena (grows on first use).
    pub fn new() -> Scratch {
        Scratch { buf: Vec::new() }
    }

    /// An arena pre-sized to exactly `n` floats.
    pub fn with_floats(n: usize) -> Scratch {
        Scratch { buf: vec![0.0; n] }
    }

    /// An arena pre-sized for one plan (its steady state from call one).
    pub fn for_plan(plan: &ConvTransposePlan) -> Scratch {
        Scratch::with_floats(plan.scratch_floats())
    }

    /// An arena pre-sized for the largest of several plans — e.g. every
    /// layer of a generator sharing one arena.
    pub fn for_plans<'a>(plans: impl IntoIterator<Item = &'a ConvTransposePlan>) -> Scratch {
        Scratch::with_floats(
            plans
                .into_iter()
                .map(ConvTransposePlan::scratch_floats)
                .max()
                .unwrap_or(0),
        )
    }

    /// Current arena size in floats (the high-water mark).
    pub fn capacity_floats(&self) -> usize {
        self.buf.len()
    }

    /// Borrow the first `n` floats, growing only if the arena is
    /// smaller than `n` (never in steady state).
    fn ensure(&mut self, n: usize) -> &mut [f32] {
        if self.buf.len() < n {
            self.buf.resize(n, 0.0);
        }
        &mut self.buf[..n]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::unified;
    use crate::tensor::ops;
    use crate::util::rng::Rng;

    fn case(n_in: usize, nk: usize, p: usize, cin: usize, cout: usize, seed: u64) {
        let mut rng = Rng::seeded(seed);
        let x = Feature::random(n_in, n_in, cin, &mut rng);
        let k = Kernel::random(nk, cin, cout, &mut rng);
        let want = unified::transpose_conv(&x, &k, p);
        let plan = ConvTransposePlan::new(ConvTransposeParams::new(n_in, nk, p, cin, cout), &k);
        let mut scratch = Scratch::for_plan(&plan);
        let mut out = plan.new_output();
        plan.run(&x, &mut scratch, &mut out);
        assert_eq!(out, want, "planned != one-shot (n={n_in} k={nk} p={p})");
        for workers in [2, 3, 8] {
            let mut out_par = plan.new_output();
            plan.run_par(&x, &mut scratch, &mut out_par, workers);
            assert_eq!(out_par, want, "run_par({workers}) != one-shot");
        }
    }

    #[test]
    fn planned_bit_identical_fig6() {
        case(4, 5, 2, 3, 2, 40); // Fig. 5/6 worked example (odd output)
    }

    #[test]
    fn planned_bit_identical_gan_layer() {
        case(4, 4, 2, 8, 4, 41);
        case(8, 4, 2, 4, 2, 42);
    }

    #[test]
    fn planned_bit_identical_odd_padding_and_degenerate() {
        case(5, 3, 1, 2, 2, 43); // role swap
        case(1, 3, 2, 1, 1, 44); // single pixel
        case(3, 2, 0, 2, 2, 45); // no padding
    }

    #[test]
    fn scratch_sizing_is_exact() {
        let mut rng = Rng::seeded(46);
        let k = Kernel::random(5, 3, 2, &mut rng);
        let plan = ConvTransposePlan::new(ConvTransposeParams::new(4, 5, 2, 3, 2), &k);
        // Fig. 5 geometry: slabs + phase outputs for the direct paths,
        // plus the largest phase's im2col patch matrix for the GEMM
        // formulation — nothing else.
        let seg = segregate(&k);
        let geoms = unified::phase_geometries(4, 5, 2);
        let by_hand_direct: usize = geoms
            .iter()
            .map(|g| (g.rows.1 - g.rows.0) * (g.cols.1 - g.cols.0) * 3 + g.n_rows * g.n_cols * 2)
            .sum();
        let by_hand_patch: usize = geoms
            .iter()
            .map(|g| {
                let s = &seg.subs[g.sub];
                g.n_rows * g.n_cols * s.rows * s.cols * 3
            })
            .max()
            .unwrap();
        assert_eq!(plan.scratch_floats_direct(), by_hand_direct);
        assert_eq!(plan.scratch_floats(), by_hand_direct + by_hand_patch);
        assert_eq!(plan.scratch_bytes(), 4 * (by_hand_direct + by_hand_patch));
        // A cold arena grows to exactly the direct requirement on the
        // direct path — GEMM-free users never pay for the patch area —
        let x = Feature::random(4, 4, 3, &mut rng);
        let mut scratch = Scratch::new();
        let mut out = plan.new_output();
        plan.run(&x, &mut scratch, &mut out);
        assert_eq!(scratch.capacity_floats(), plan.scratch_floats_direct());
        // — and to exactly the full requirement once the GEMM lane runs.
        plan.run_gemm(&x, &mut scratch, &mut out);
        assert_eq!(scratch.capacity_floats(), plan.scratch_floats());
        // A for_plan arena covers every strategy from call one.
        let mut full = Scratch::for_plan(&plan);
        plan.run_gemm(&x, &mut full, &mut out);
        assert_eq!(full.capacity_floats(), plan.scratch_floats());
    }

    #[test]
    fn arena_shared_across_shapes_never_aliases() {
        // Big layer, then small, then big again through ONE arena —
        // every result must stay bit-identical to a fresh computation.
        let mut rng = Rng::seeded(47);
        let shapes = [(9, 4, 2, 3, 2), (3, 3, 1, 2, 4), (6, 5, 2, 1, 1)];
        let cases: Vec<(Feature, ConvTransposePlan, Feature)> = shapes
            .iter()
            .map(|&(n, nk, p, cin, cout)| {
                let x = Feature::random(n, n, cin, &mut rng);
                let k = Kernel::random(nk, cin, cout, &mut rng);
                let want = unified::transpose_conv(&x, &k, p);
                let plan =
                    ConvTransposePlan::new(ConvTransposeParams::new(n, nk, p, cin, cout), &k);
                (x, plan, want)
            })
            .collect();
        let mut scratch = Scratch::new();
        for _round in 0..3 {
            for (x, plan, want) in &cases {
                let mut out = plan.new_output();
                plan.run(x, &mut scratch, &mut out);
                assert_eq!(&out, want);
            }
            for (x, plan, want) in cases.iter().rev() {
                let mut out = plan.new_output();
                plan.run_par(x, &mut scratch, &mut out, 3);
                assert_eq!(&out, want);
            }
        }
    }

    #[test]
    fn run_does_not_depend_on_stale_output() {
        // The scatter covers the whole output, so a dirty `out` buffer
        // must not leak through.
        let mut rng = Rng::seeded(48);
        let x = Feature::random(5, 5, 2, &mut rng);
        let k = Kernel::random(4, 2, 3, &mut rng);
        let plan = ConvTransposePlan::new(ConvTransposeParams::new(5, 4, 2, 2, 3), &k);
        let mut scratch = Scratch::for_plan(&plan);
        let mut out = plan.new_output();
        plan.run(&x, &mut scratch, &mut out);
        let want = out.clone();
        out.data.fill(f32::NAN);
        plan.run(&x, &mut scratch, &mut out);
        assert!(out
            .data
            .iter()
            .zip(&want.data)
            .all(|(a, b)| a.to_bits() == b.to_bits()));
    }

    #[test]
    #[should_panic(expected = "fully-specified")]
    fn plan_rejects_placeholder_template() {
        let seg = segregate(&Kernel::zeros(4, 2, 2));
        // gan_layer() has zero n_in/cin/cout — the with_io footgun.
        ConvTransposePlan::from_seg(ConvTransposeParams::gan_layer(), seg);
    }

    #[test]
    #[should_panic(expected = "input shape mismatch")]
    fn run_checks_input_shape() {
        let mut rng = Rng::seeded(49);
        let k = Kernel::random(4, 2, 2, &mut rng);
        let plan = ConvTransposePlan::new(ConvTransposeParams::new(4, 4, 2, 2, 2), &k);
        let x = Feature::zeros(5, 5, 2);
        let mut out = plan.new_output();
        plan.run(&x, &mut Scratch::new(), &mut out);
    }

    #[test]
    fn run_with_every_strategy_matches_reference() {
        // The whole autotuner search space, on an odd-output (Fig. 5/6)
        // and an even-output (GAN block) shape, against dirty output
        // buffers — every direct strategy must reproduce the planned
        // serial reference exactly; the GEMM formulation within 1e-4
        // (f32 reassociation through the register tile) — and all must
        // overwrite every output element.
        let mut rng = Rng::seeded(51);
        for (n_in, nk, p, cin, cout) in [(4, 5, 2, 3, 2), (4, 4, 2, 3, 2)] {
            let x = Feature::random(n_in, n_in, cin, &mut rng);
            let k = Kernel::random(nk, cin, cout, &mut rng);
            let plan =
                ConvTransposePlan::new(ConvTransposeParams::new(n_in, nk, p, cin, cout), &k);
            let mut scratch = Scratch::for_plan(&plan);
            let mut want = plan.new_output();
            plan.run(&x, &mut scratch, &mut want);
            for s in crate::tune::space::search_space(4) {
                let mut got = plan.new_output();
                got.data.fill(f32::NAN);
                plan.run_with(&s, &x, &mut scratch, &mut got);
                if s.formulation == Formulation::PhaseGemm {
                    assert!(got.data.iter().all(|v| !v.is_nan()), "{} left NaNs", s.name());
                    assert!(
                        ops::max_abs_diff(&got, &want) < 1e-4,
                        "{} diverged (n={n_in} k={nk} p={p})",
                        s.name()
                    );
                } else {
                    assert_eq!(got, want, "{} diverged (n={n_in} k={nk} p={p})", s.name());
                }
            }
        }
    }

    #[test]
    fn gemm_lanes_match_direct_across_couts() {
        // The register tile is MR×NR — Cout values off the NR multiple
        // (1, 3, 17) exercise the ragged-edge path; 8 hits it exactly.
        let mut rng = Rng::seeded(53);
        for cout in [1usize, 3, 8, 17] {
            for (n_in, nk, p) in [(4, 5, 2), (6, 4, 2), (5, 3, 1), (3, 4, 3)] {
                let x = Feature::random(n_in, n_in, 3, &mut rng);
                let k = Kernel::random(nk, 3, cout, &mut rng);
                let plan =
                    ConvTransposePlan::new(ConvTransposeParams::new(n_in, nk, p, 3, cout), &k);
                let mut scratch = Scratch::for_plan(&plan);
                let mut want = plan.new_output();
                plan.run(&x, &mut scratch, &mut want);
                let mut got = plan.new_output();
                got.data.fill(f32::NAN);
                plan.run_gemm(&x, &mut scratch, &mut got);
                assert!(
                    ops::max_abs_diff(&got, &want) < 1e-4,
                    "run_gemm (cout={cout} n={n_in} k={nk} p={p})"
                );
                for workers in [2, 3, 8] {
                    let mut par = plan.new_output();
                    par.data.fill(f32::NAN);
                    plan.run_gemm_par_rows(&x, &mut scratch, &mut par, workers);
                    assert_eq!(
                        par, got,
                        "row-parallel GEMM ({workers}) != serial GEMM (cout={cout})"
                    );
                }
            }
        }
    }

    #[test]
    fn run_par_rows_matches_run_par() {
        let mut rng = Rng::seeded(52);
        let x = Feature::random(6, 6, 3, &mut rng);
        let k = Kernel::random(5, 3, 2, &mut rng);
        let plan = ConvTransposePlan::new(ConvTransposeParams::new(6, 5, 2, 3, 2), &k);
        let mut scratch = Scratch::for_plan(&plan);
        let mut want = plan.new_output();
        plan.run(&x, &mut scratch, &mut want);
        for workers in [1, 2, 5] {
            let mut got = plan.new_output();
            plan.run_par_rows(&x, &mut scratch, &mut got, workers);
            assert_eq!(got, want, "run_par_rows({workers})");
        }
    }

    #[test]
    fn planned_matches_conventional_reference() {
        // End-to-end sanity against Algorithm 1 (tolerance, not bits —
        // different accumulation order).
        let mut rng = Rng::seeded(50);
        let x = Feature::random(6, 6, 3, &mut rng);
        let k = Kernel::random(4, 3, 2, &mut rng);
        let want = crate::conv::conventional::transpose_conv(&x, &k, 2);
        let plan = ConvTransposePlan::new(ConvTransposeParams::new(6, 4, 2, 3, 2), &k);
        let got = plan.run_alloc(&x, &mut Scratch::for_plan(&plan));
        assert!(ops::max_abs_diff(&want, &got) < 1e-4);
    }
}
