//! Plan/execute: ahead-of-time transpose-conv plans and a zero-alloc
//! scratch arena (DESIGN.md §Plan-Execute).
//!
//! The one-shot entry points in [`unified`](super::unified) recompute
//! the phase geometry, build four slabs, and heap-allocate four phase
//! buffers plus the output on *every* call — per-call overhead the
//! paper's resident CUDA kernel never pays.  Following the
//! plan-once/execute-many discipline of HUGE2 and the static operation
//! schedules of GANAX (PAPERS.md), this module hoists all
//! shape-dependent work to construction time:
//!
//! * [`ConvTransposePlan`] — built once per `(ConvTransposeParams,
//!   kernel)`: segregates the kernel, freezes the four
//!   [`PhaseGeometry`]s, derives every slab window and per-phase output
//!   extent, and lays the whole working set out as offsets into one
//!   contiguous arena with an **exact** float requirement
//!   ([`scratch_floats`](ConvTransposePlan::scratch_floats)).
//! * [`Scratch`] — the reusable arena.  It grows to the high-water mark
//!   of whatever plans run through it and never shrinks, so steady-state
//!   [`run`](ConvTransposePlan::run) performs **zero heap allocations**
//!   (pinned by the counting-allocator test in `tests/plan_alloc.rs`).
//!   One arena may be shared across differently-shaped layers: every
//!   byte a run reads is written first (`build_slab` covers slabs, the
//!   phase regions are zero-filled), so stale data never aliases in.
//!
//! Direct (correlation) execution is bit-identical to the one-shot
//! path — same slab values, same correlation loops, same f32
//! accumulation order — which the property suite asserts with `==`,
//! not a tolerance.  The planned **phase-GEMM** formulation
//! ([`run_gemm`](ConvTransposePlan::run_gemm), DESIGN.md
//! §GEMM-Execution) executes the same phases as packed GEMMs through
//! the tiled microkernel (`conv::gemm`): each segregated sub-kernel is
//! packed into its GEMM operand layout **once at construction**, and
//! the im2col patch matrix lives in the scratch arena — so the GEMM
//! steady state is also zero-alloc, equivalent to the direct reference
//! within 1e-4 (f32 reassociation through the register tile).
//!
//! **Batched execution** (DESIGN.md §Batched-Execution): a plan also
//! executes whole [`FeatureBatch`] micro-batches in one call.  The
//! fused batched GEMM lanes ([`run_gemm_batch`](ConvTransposePlan::run_gemm_batch))
//! stack every image's im2col patch rows into a single `[N·rows, K]`
//! operand per phase, so each plan-time-packed B panel is streamed
//! once for the whole batch — the packing finally amortizes `N×` — and
//! the batched direct lanes stay bit-identical to `N` sequential
//! single-image runs.  Batch-aware scratch sizing
//! ([`scratch_floats_gemm_batch`](ConvTransposePlan::scratch_floats_gemm_batch),
//! [`scratch_floats_for_batch`](ConvTransposePlan::scratch_floats_for_batch))
//! extends the zero-alloc steady-state guarantee to batched serving.
//!
//! **Backward execution** (DESIGN.md §Backward-Execution): the same
//! plan also runs the training-direction gradients through the same
//! arena.  Data-grad
//! ([`run_backward_data`](ConvTransposePlan::run_backward_data)) is a
//! per-phase full correlation of the `dy` phase against the flipped
//! sub-kernel — frozen (and GEMM-packed) at construction, no
//! upsampled-gradient buffer ever materializes — accumulated into `dx`
//! through the adjoint of the slab crop.  Weight-grad
//! ([`run_backward_weights`](ConvTransposePlan::run_backward_weights))
//! is the phase GEMM with operands swapped: the im2col patch matrix
//! (transposed) as A, the `dy` phase packed at runtime as B, so the
//! batched variant accumulates `dK` across the whole batch for free
//! (`C +=`).  Direct backward lanes are bit-identical to the one-shot
//! [`backward`](super::backward) routes; GEMM lanes match within 1e-4
//! (same reassociation contract as forward).  The **fused** backward
//! ([`run_backward`](ConvTransposePlan::run_backward)) produces both
//! gradients in one pass, extracting each `dy` phase from the output
//! map **once** and sharing it between the weight GEMM and the padded
//! data-grad frame — the unfused pair strides `dy` twice per phase.
//!
//! Every GEMM lane executes through the runtime-dispatched SIMD
//! microkernel (`conv::simd`, DESIGN.md §SIMD-Dispatch); strategy
//! dispatch ([`run_with`](ConvTransposePlan::run_with) and friends)
//! pins the lane to [`ExecStrategy::isa`] so tuned verdicts mean what
//! they measured.

use crate::obs::trace;
use crate::tensor::ops;
use crate::tensor::{Feature, FeatureBatch, Kernel, SubKernel};
use crate::tune::space::{EpilogueMode, ExecStrategy, Formulation, ParAxis};
use crate::util::threadpool;

use super::backward::flip_sub;
use super::conventional::correlate_rows;
use super::gemm;
use super::im2col::kernel_matrix;
use super::quant::{self, Precision};
use super::simd::Isa;
use super::segregation::{segregate, Segregated};
use super::unified::{
    build_slab, build_slab_view, phase_geometries, scatter_rows, scatter_rows_view, PhaseGeometry,
};
use super::ConvTransposeParams;

/// One phase of the plan: its frozen geometry plus the arena layout
/// and the plan-time-packed GEMM operand.
#[derive(Debug, Clone)]
struct PhasePlan {
    geom: PhaseGeometry,
    /// Slab (padded input window) width in pixels.
    slab_w: usize,
    /// Float offset/length of the slab within the arena's slab area.
    slab_off: usize,
    slab_len: usize,
    /// Float offset/length of the phase output within the phase area.
    phase_off: usize,
    phase_len: usize,
    /// GEMM reduction depth `kr·kc·Cin` of this phase's sub-kernel.
    gemm_k: usize,
    /// im2col patch-matrix floats (`n_rows·n_cols·gemm_k`) — the
    /// phase's claim on the arena's shared patch area.
    patch_len: usize,
    /// The sub-kernel as a packed GEMM B operand
    /// (`gemm::pack_b` over the tap-major `[gemm_k, Cout]` matrix),
    /// laid out once here so steady-state GEMM execution never packs.
    packed_kernel: Vec<f32>,
    /// Reduced-precision twins of `packed_kernel` (DESIGN.md
    /// §Reduced-Precision): the same `[gemm_k, Cout]` matrix packed
    /// into width-`quant::QNR` panels as f16 / bf16 bit patterns and
    /// symmetric-absmax int8, frozen here so quantized steady state
    /// never converts or re-quantizes weights.  ~1.25× plan-resident
    /// weight memory; execution reads exactly one of the four panels.
    qpanel_f16: Vec<u16>,
    qpanel_bf16: Vec<u16>,
    qpanel_i8: Vec<i8>,
    /// Per-output-channel scales of `qpanel_i8` (len `Cout`):
    /// `q[k][j] · qscale_i8[j]` recovers the f32 weight.
    qscale_i8: Vec<f32>,
    /// Slab height in pixels (`rows.1 - rows.0 = n_rows + sub.rows - 1`).
    slab_h: usize,
    /// Flipped sub-kernel (spatial flip + Cin/Cout transpose) — the
    /// backward-data correlation taps, frozen at construction so the
    /// steady-state backward never flips.
    flipped: SubKernel,
    /// Padded dy-phase width in pixels (`n_cols + 2(sub.cols-1) =
    /// slab_w + sub.cols - 1`): the full correlation producing the slab
    /// gradient runs VALID over this frame.
    pad_w: usize,
    /// Float offset/length of the padded dy phase within the arena's
    /// backward pad area.
    pad_off: usize,
    pad_len: usize,
    /// Backward-data GEMM reduction depth `kr·kc·Cout` (the flipped
    /// sub-kernel maps Cout→Cin).
    gemm_k_bwd: usize,
    /// Backward-data im2col patch floats (`slab_h·slab_w·gemm_k_bwd`) —
    /// the phase's claim on the shared backward patch area.
    patch_bwd_len: usize,
    /// The flipped sub-kernel as a packed GEMM B operand
    /// (`[gemm_k_bwd, Cin]`), packed once here.
    packed_flip: Vec<f32>,
    /// Float offset/length of this phase's dSub accumulator within the
    /// weight-grad area (`sub.rows·sub.cols·Cin·Cout` floats, tap-major
    /// like `kernel_matrix`).
    dsub_off: usize,
    dsub_len: usize,
}

/// An ahead-of-time plan for one transpose-convolution layer.
///
/// Owns the pre-segregated kernel and every shape-derived quantity, so
/// the steady-state call does arithmetic and memory traffic only.
#[derive(Debug, Clone)]
pub struct ConvTransposePlan {
    params: ConvTransposeParams,
    seg: Segregated,
    phases: Vec<PhasePlan>,
    /// Output spatial size.
    out: usize,
    /// Total floats of the slab area (phase area follows it).
    slab_floats: usize,
    phase_floats: usize,
    /// Floats of the shared im2col patch area (max over phases —
    /// phases run one at a time, so one region serves all four).
    patch_floats: usize,
    /// Total floats of the backward padded-dy area (sum over phases).
    pad_floats: usize,
    /// Floats of the shared backward-data im2col patch area (max).
    patch_bwd_floats: usize,
    /// Floats of the runtime-packed dy panel region of the weight grad
    /// (max over phases of `packed_b_floats(n_rows·n_cols, Cout)`).
    packed_dy_floats: usize,
    /// Total floats of the per-phase dSub accumulators (sum).
    dsub_floats: usize,
}

impl ConvTransposePlan {
    /// Build a plan from a full kernel (segregates once, here).
    pub fn new(params: ConvTransposeParams, kernel: &Kernel) -> ConvTransposePlan {
        assert_eq!(kernel.n, params.n_k, "plan: kernel size mismatch");
        assert_eq!(
            (kernel.cin, kernel.cout),
            (params.cin, params.cout),
            "plan: kernel channel mismatch"
        );
        ConvTransposePlan::from_seg(params, segregate(kernel))
    }

    /// Build a plan from an already-segregated kernel (takes ownership —
    /// weights are prepared once at load time and live in the plan).
    pub fn from_seg(params: ConvTransposeParams, seg: Segregated) -> ConvTransposePlan {
        assert!(
            params.n_in > 0 && params.cin > 0 && params.cout > 0,
            "plan requires fully-specified I/O geometry (chain with_io on templates)"
        );
        assert_eq!(seg.n, params.n_k, "plan: segregated kernel size mismatch");
        assert_eq!(
            (seg.subs[0].cin, seg.subs[0].cout),
            (params.cin, params.cout),
            "plan: segregated kernel channel mismatch"
        );
        let out = params.out_size();
        let mut slab_off = 0usize;
        let mut phase_off = 0usize;
        let mut patch_floats = 0usize;
        let mut pad_off = 0usize;
        let mut dsub_off = 0usize;
        let mut patch_bwd_floats = 0usize;
        let mut packed_dy_floats = 0usize;
        let phases = phase_geometries(params.n_in, params.n_k, params.padding)
            .into_iter()
            .map(|geom| {
                let slab_h = geom.rows.1 - geom.rows.0;
                let slab_w = geom.cols.1 - geom.cols.0;
                let slab_len = slab_h * slab_w * params.cin;
                let phase_len = geom.n_rows * geom.n_cols * params.cout;
                // Plan-time GEMM lowering: pack this phase's sub-kernel
                // into its panel operand once, here.
                let sub = &seg.subs[geom.sub];
                let gemm_k = sub.rows * sub.cols * params.cin;
                let patch_len = geom.n_rows * geom.n_cols * gemm_k;
                patch_floats = patch_floats.max(patch_len);
                let bmat = kernel_matrix(sub);
                let mut packed_kernel = vec![0.0f32; gemm::packed_b_floats(gemm_k, params.cout)];
                gemm::pack_b(&bmat, gemm_k, params.cout, &mut packed_kernel);
                // Reduced-precision weight panels, quantized once here
                // (per-output-channel absmax scales for int8).
                let qelems = quant::packed_qb_elems(gemm_k, params.cout);
                let mut qpanel_f16 = vec![0u16; qelems];
                quant::pack_b_q16(
                    &bmat,
                    gemm_k,
                    params.cout,
                    quant::f32_to_f16_bits,
                    &mut qpanel_f16,
                );
                let mut qpanel_bf16 = vec![0u16; qelems];
                quant::pack_b_q16(
                    &bmat,
                    gemm_k,
                    params.cout,
                    quant::f32_to_bf16_bits,
                    &mut qpanel_bf16,
                );
                let qscale_i8 = quant::col_absmax_scales(&bmat, gemm_k, params.cout);
                let mut qpanel_i8 = vec![0i8; qelems];
                quant::pack_b_q8(&bmat, gemm_k, params.cout, &qscale_i8, &mut qpanel_i8);
                // Backward lowering, frozen here too: the flipped
                // sub-kernel (data-grad taps, packed as `[gemm_k_bwd,
                // Cin]`), the padded-dy frame the full correlation runs
                // over, and the dSub accumulator layout.
                let flipped = flip_sub(sub);
                let pad_w = slab_w + sub.cols - 1;
                let pad_h = slab_h + sub.rows - 1;
                let pad_len = pad_h * pad_w * params.cout;
                let gemm_k_bwd = sub.rows * sub.cols * params.cout;
                let patch_bwd_len = slab_h * slab_w * gemm_k_bwd;
                patch_bwd_floats = patch_bwd_floats.max(patch_bwd_len);
                packed_dy_floats = packed_dy_floats
                    .max(gemm::packed_b_floats(geom.n_rows * geom.n_cols, params.cout));
                let dsub_len = gemm_k * params.cout;
                let mut packed_flip = vec![0.0f32; gemm::packed_b_floats(gemm_k_bwd, params.cin)];
                gemm::pack_b(
                    &kernel_matrix(&flipped),
                    gemm_k_bwd,
                    params.cin,
                    &mut packed_flip,
                );
                let pp = PhasePlan {
                    geom,
                    slab_w,
                    slab_off,
                    slab_len,
                    phase_off,
                    phase_len,
                    gemm_k,
                    patch_len,
                    packed_kernel,
                    qpanel_f16,
                    qpanel_bf16,
                    qpanel_i8,
                    qscale_i8,
                    slab_h,
                    flipped,
                    pad_w,
                    pad_off,
                    pad_len,
                    gemm_k_bwd,
                    patch_bwd_len,
                    packed_flip,
                    dsub_off,
                    dsub_len,
                };
                slab_off += slab_len;
                phase_off += phase_len;
                pad_off += pad_len;
                dsub_off += dsub_len;
                pp
            })
            .collect();
        ConvTransposePlan {
            params,
            seg,
            phases,
            out,
            slab_floats: slab_off,
            phase_floats: phase_off,
            patch_floats,
            pad_floats: pad_off,
            patch_bwd_floats,
            packed_dy_floats,
            dsub_floats: dsub_off,
        }
    }

    /// The layer geometry this plan was built for.
    pub fn params(&self) -> &ConvTransposeParams {
        &self.params
    }

    /// The pre-segregated kernel the plan executes with.
    pub fn seg(&self) -> &Segregated {
        &self.seg
    }

    /// Output spatial size (square).
    pub fn out_size(&self) -> usize {
        self.out
    }

    /// Exact scratch requirement in floats covering **every**
    /// execution strategy: slabs + phase outputs + the shared im2col
    /// patch region the GEMM formulation fills (max over phases).  An
    /// arena pre-sized to this runs any tuned [`ExecStrategy`] —
    /// including [`Formulation::PhaseGemm`] — without ever growing.
    pub fn scratch_floats(&self) -> usize {
        self.scratch_floats_direct() + self.patch_floats
    }

    /// Exact scratch requirement of the direct (correlation) paths
    /// alone ([`run`](Self::run)/[`run_par`](Self::run_par)/
    /// [`run_par_rows`](Self::run_par_rows)): slabs + phase outputs.
    /// Direct execution only ever grows an arena to this, so
    /// GEMM-free deployments don't pay for the patch region.
    pub fn scratch_floats_direct(&self) -> usize {
        self.slab_floats + self.phase_floats
    }

    /// Exact scratch requirement of one strategy: the GEMM-inclusive
    /// figure for [`Formulation::PhaseGemm`], the direct figure for
    /// everything else (the per-element lanes allocate their own
    /// output and use no scratch at all, but sizing them like the
    /// direct paths keeps one arena safely shared across pins).
    pub fn scratch_floats_for(&self, strategy: &ExecStrategy) -> usize {
        match (strategy.formulation, strategy.epilogue) {
            (Formulation::PhaseGemm, EpilogueMode::Fused) => self.scratch_floats_gemm_fused(),
            (Formulation::PhaseGemm, EpilogueMode::Separate) => self.scratch_floats(),
            _ => self.scratch_floats_direct(),
        }
    }

    /// Exact scratch requirement in bytes (fp32, every strategy).
    pub fn scratch_bytes(&self) -> usize {
        self.scratch_floats() * std::mem::size_of::<f32>()
    }

    /// Largest single-phase output in floats — the batched GEMM lanes
    /// process one phase at a time across the whole batch, so their
    /// phase region is `N ×` this rather than `N ×` the sum.
    fn max_phase_floats(&self) -> usize {
        self.phases.iter().map(|p| p.phase_len).max().unwrap_or(0)
    }

    /// Exact scratch floats of the fused batched GEMM lanes
    /// ([`run_gemm_batch`](Self::run_gemm_batch) /
    /// [`run_gemm_batch_par`](Self::run_gemm_batch_par)) for batch
    /// size `n`: one reusable slab area plus `n` stacked phase-output
    /// and im2col-patch regions (DESIGN.md §Batched-Execution).
    pub fn scratch_floats_gemm_batch(&self, n: usize) -> usize {
        self.slab_floats + n * (self.max_phase_floats() + self.patch_floats)
    }

    /// Exact scratch floats of the fused-epilogue GEMM lanes
    /// ([`run_gemm_fused`](Self::run_gemm_fused) /
    /// [`run_gemm_fused_par_rows`](Self::run_gemm_fused_par_rows),
    /// DESIGN.md §Fused-Epilogue): slabs + the im2col patch region
    /// only.  Strictly smaller than [`scratch_floats`](Self::scratch_floats)
    /// whenever the layer has any output — the accumulator tiles store
    /// straight into the strided output, so the phase-slab region is
    /// never claimed.
    pub fn scratch_floats_gemm_fused(&self) -> usize {
        self.slab_floats + self.patch_floats
    }

    /// Exact scratch floats of the fused-epilogue batched GEMM lanes
    /// ([`run_gemm_fused_batch`](Self::run_gemm_fused_batch) /
    /// [`run_gemm_fused_batch_par`](Self::run_gemm_fused_batch_par))
    /// for batch size `n`: one reusable slab area plus `n` stacked
    /// im2col patch regions — the `n ×` phase-output region of
    /// [`scratch_floats_gemm_batch`](Self::scratch_floats_gemm_batch)
    /// is never claimed.
    pub fn scratch_floats_gemm_batch_fused(&self, n: usize) -> usize {
        self.slab_floats + n * self.patch_floats
    }

    /// Exact scratch floats of the image-parallel batched direct lane
    /// ([`run_batch_par`](Self::run_batch_par)): one full direct region
    /// per image, so every `(image, phase, row)` job owns disjoint
    /// arena slices.
    pub fn scratch_floats_batch_par(&self, n: usize) -> usize {
        n.max(1) * self.scratch_floats_direct()
    }

    /// Exact scratch floats one *fused batched* execution of `strategy`
    /// needs for batch size `n` (the batched analogue of
    /// [`scratch_floats_for`](Self::scratch_floats_for); the serial
    /// direct lane loops images through one direct region, and the
    /// per-element lanes allocate their own buffers).
    pub fn scratch_floats_for_batch(&self, strategy: &ExecStrategy, n: usize) -> usize {
        match (strategy.formulation, strategy.epilogue) {
            (Formulation::PhaseGemm, EpilogueMode::Fused) => {
                self.scratch_floats_gemm_batch_fused(n)
            }
            (Formulation::PhaseGemm, EpilogueMode::Separate) => self.scratch_floats_gemm_batch(n),
            (Formulation::PhaseDecomposed, _) if strategy.workers > 1 => {
                self.scratch_floats_batch_par(n)
            }
            _ => self.scratch_floats_direct(),
        }
    }

    /// Worst-case scratch floats any fused batched lane of this plan
    /// can demand at batch size `n` — what serving arenas are sized to
    /// (`conv::memory` reports it as the per-batch peak).
    pub fn peak_scratch_floats_batch(&self, n: usize) -> usize {
        self.scratch_floats_gemm_batch(n)
            .max(self.scratch_floats_batch_par(n))
    }

    /// Total floats of the plan-time-packed GEMM operands — resident in
    /// the plan (not the arena); `conv::memory`'s working-set
    /// accounting includes them alongside the scratch regions.
    pub fn packed_operand_floats(&self) -> usize {
        self.phases.iter().map(|p| p.packed_kernel.len()).sum()
    }

    /// Floats of the shared im2col patch region (the GEMM formulation's
    /// claim on the arena beyond the direct paths).
    pub fn patch_region_floats(&self) -> usize {
        self.patch_floats
    }

    /// Bytes of the plan-resident packed B operands at `precision`
    /// (DESIGN.md §Reduced-Precision).  The quantized figures count
    /// panel elements only — the int8 per-channel scales are plan
    /// metadata (`4·Cout` bytes per phase, amortized over `gemm_k`
    /// rows) and are excluded so the ratios reflect the streamed
    /// operand traffic.  Quantized panels pad `Cout` to
    /// [`quant::QNR`] where the f32 panels pad to the active ISA's
    /// (wider) tile, so f16 is **at least** 2× smaller and int8 at
    /// least 4× — more on ragged `Cout` (e.g. the RGB head).
    pub fn packed_operand_bytes(&self, precision: Precision) -> usize {
        match precision {
            Precision::F32 => self.packed_operand_floats() * std::mem::size_of::<f32>(),
            Precision::F16 => self.phases.iter().map(|p| p.qpanel_f16.len() * 2).sum(),
            Precision::Bf16 => self.phases.iter().map(|p| p.qpanel_bf16.len() * 2).sum(),
            Precision::Int8 => self.phases.iter().map(|p| p.qpanel_i8.len()).sum(),
        }
    }

    /// Exact quantized-patch arena requirement in **elements** (u16 for
    /// f16/bf16, i8 for int8) of the single-image quantized lanes: the
    /// quantized copy of the shared im2col patch region, one element
    /// per patch float.  The arena element count is precision-
    /// independent; only the byte width differs.
    pub fn quant_patch_elems(&self) -> usize {
        self.patch_floats
    }

    /// Exact quantized-patch arena elements of the fused batched
    /// quantized lanes at batch size `n` (the quantized copy of the
    /// stacked `[N·rows, K]` patch operand, largest phase).
    pub fn quant_patch_elems_batch(&self, n: usize) -> usize {
        n * self.patch_floats
    }

    /// A correctly-shaped output buffer for this plan.
    pub fn new_output(&self) -> Feature {
        Feature::zeros(self.out, self.out, self.params.cout)
    }

    /// A correctly-shaped batched output for this plan.
    pub fn new_batch_output(&self, n: usize) -> FeatureBatch {
        FeatureBatch::zeros(n, self.out, self.out, self.params.cout)
    }

    fn check_shapes(&self, x: &Feature, out: &Feature) {
        assert_eq!(
            (x.h, x.w, x.c),
            (self.params.n_in, self.params.n_in, self.params.cin),
            "plan: input shape mismatch"
        );
        assert_eq!(
            (out.h, out.w, out.c),
            (self.out, self.out, self.params.cout),
            "plan: output shape mismatch"
        );
    }

    fn check_batch_shapes(&self, x: &FeatureBatch, out: &FeatureBatch) {
        assert_eq!(x.n, out.n, "plan: batch size mismatch");
        assert_eq!(
            (x.h, x.w, x.c),
            (self.params.n_in, self.params.n_in, self.params.cin),
            "plan: batch input shape mismatch"
        );
        assert_eq!(
            (out.h, out.w, out.c),
            (self.out, self.out, self.params.cout),
            "plan: batch output shape mismatch"
        );
    }

    /// Execute serially: `x → out` through `scratch`.
    ///
    /// Steady state (arena at its high-water mark) performs **zero**
    /// heap allocations: slabs are cropped into the arena, phases are
    /// correlated into the arena, and the scatter writes every output
    /// element (the phase extents partition the output, so `out` needs
    /// no pre-clearing).
    pub fn run(&self, x: &Feature, scratch: &mut Scratch, out: &mut Feature) {
        self.check_shapes(x, out);
        let buf = scratch.ensure(self.scratch_floats_direct());
        self.run_image(&x.data, buf, &mut out.data);
    }

    /// Direct serial core over raw `[H, W, C]` image views (shapes are
    /// the plan's own; public entry points validate).  This is the body
    /// [`run`](Self::run) always had — same slab crops, same
    /// correlation loops, same scatters — factored onto slices so the
    /// batched lanes ([`run_batch`](Self::run_batch)) can execute each
    /// [`FeatureBatch`] image in place, bit-identically.
    fn run_image(&self, x: &[f32], buf: &mut [f32], out: &mut [f32]) {
        let n_in = self.params.n_in;
        let cin = self.params.cin;
        let cout = self.params.cout;
        let (slab_area, phase_area) = buf.split_at_mut(self.slab_floats);
        for (pi, pp) in self.phases.iter().enumerate() {
            let _phase_span = trace::span("conv.phase", "direct", trace::NONE, pi as u32);
            build_slab_view(
                x,
                n_in,
                n_in,
                cin,
                &pp.geom,
                &mut slab_area[pp.slab_off..pp.slab_off + pp.slab_len],
            );
            let phase = &mut phase_area[pp.phase_off..pp.phase_off + pp.phase_len];
            phase.fill(0.0);
            correlate_rows(
                &slab_area[pp.slab_off..pp.slab_off + pp.slab_len],
                pp.slab_w,
                &self.seg.subs[pp.geom.sub],
                phase,
                pp.geom.n_cols,
                0,
                pp.geom.n_rows,
            );
            scatter_rows_view(
                out,
                self.out,
                cout,
                phase,
                pp.geom.rp,
                pp.geom.sp,
                pp.geom.n_rows,
                pp.geom.n_cols,
            );
        }
    }

    /// Execute with the output allocated here (convenience for callers
    /// that consume the result immediately).
    pub fn run_alloc(&self, x: &Feature, scratch: &mut Scratch) -> Feature {
        let mut out = self.new_output();
        self.run(x, scratch, &mut out);
        out
    }

    /// Parallel execution, phase×row axis: one work queue of
    /// `(phase, output-row)` jobs drained by `workers` threads of the
    /// persistent kernel pool ([`threadpool::parallel_drain`] — no
    /// per-call thread spawning, so small-layer timings measure the
    /// kernel and tuned worker counts mean what they measure).  Tensor
    /// buffers all come from the arena; only the per-call job list is
    /// allocated.  Bit-identical to [`run`] (each output row is
    /// computed by the same serial loops).
    pub fn run_par(&self, x: &Feature, scratch: &mut Scratch, out: &mut Feature, workers: usize) {
        let workers = workers.max(1);
        if workers == 1 {
            return self.run(x, scratch, out);
        }
        self.check_shapes(x, out);
        let cout = self.params.cout;
        let buf = scratch.ensure(self.scratch_floats_direct());
        {
            let (slab_area, phase_area) = buf.split_at_mut(self.slab_floats);
            for pp in &self.phases {
                let slab = &mut slab_area[pp.slab_off..pp.slab_off + pp.slab_len];
                build_slab(x, &pp.geom, slab);
            }
            let slab_area: &[f32] = slab_area;
            let mut jobs: Vec<(usize, usize, &mut [f32])> = Vec::new();
            let mut rest: &mut [f32] = phase_area;
            for (pi, pp) in self.phases.iter().enumerate() {
                let (mine, tail) = rest.split_at_mut(pp.phase_len);
                rest = tail;
                let row_len = pp.geom.n_cols * cout;
                for (ri, row) in mine.chunks_mut(row_len).enumerate() {
                    jobs.push((pi, ri, row));
                }
            }
            threadpool::parallel_drain(jobs, workers, |(pi, ri, row)| {
                let pp = &self.phases[pi];
                row.fill(0.0);
                correlate_rows(
                    &slab_area[pp.slab_off..pp.slab_off + pp.slab_len],
                    pp.slab_w,
                    &self.seg.subs[pp.geom.sub],
                    row,
                    pp.geom.n_cols,
                    ri,
                    ri + 1,
                );
            });
        }
        let phase_area = &buf[self.slab_floats..];
        for pp in &self.phases {
            scatter_rows(
                out,
                &phase_area[pp.phase_off..pp.phase_off + pp.phase_len],
                pp.geom.rp,
                pp.geom.sp,
                pp.geom.n_rows,
                pp.geom.n_cols,
            );
        }
    }

    /// Parallel execution, row axis: phases processed one at a time,
    /// each phase's output rows drained across `workers` pool threads —
    /// trades the phase×row queue's load balance for locality (one
    /// slab + sub-kernel resident per step).  Bit-identical to [`run`].
    pub fn run_par_rows(
        &self,
        x: &Feature,
        scratch: &mut Scratch,
        out: &mut Feature,
        workers: usize,
    ) {
        let workers = workers.max(1);
        if workers == 1 {
            return self.run(x, scratch, out);
        }
        self.check_shapes(x, out);
        let cout = self.params.cout;
        let buf = scratch.ensure(self.scratch_floats_direct());
        {
            let (slab_area, phase_area) = buf.split_at_mut(self.slab_floats);
            for pp in &self.phases {
                let slab = &mut slab_area[pp.slab_off..pp.slab_off + pp.slab_len];
                build_slab(x, &pp.geom, slab);
            }
            let slab_area: &[f32] = slab_area;
            let mut rest: &mut [f32] = phase_area;
            for pp in &self.phases {
                let (mine, tail) = rest.split_at_mut(pp.phase_len);
                rest = tail;
                let row_len = pp.geom.n_cols * cout;
                let jobs: Vec<(usize, &mut [f32])> = mine.chunks_mut(row_len).enumerate().collect();
                threadpool::parallel_drain(jobs, workers, |(ri, row)| {
                    row.fill(0.0);
                    correlate_rows(
                        &slab_area[pp.slab_off..pp.slab_off + pp.slab_len],
                        pp.slab_w,
                        &self.seg.subs[pp.geom.sub],
                        row,
                        pp.geom.n_cols,
                        ri,
                        ri + 1,
                    );
                });
            }
        }
        let phase_area = &buf[self.slab_floats..];
        for pp in &self.phases {
            scatter_rows(
                out,
                &phase_area[pp.phase_off..pp.phase_off + pp.phase_len],
                pp.geom.rp,
                pp.geom.sp,
                pp.geom.n_rows,
                pp.geom.n_cols,
            );
        }
    }

    /// Execute through the planned phase-GEMM engine, serially
    /// (DESIGN.md §GEMM-Execution): per phase, crop the slab into the
    /// arena, im2col it into the arena's patch region, and multiply by
    /// the sub-kernel packed at construction
    /// ([`gemm::gemm_packed`], register-blocked + cache-tiled).
    /// Steady state performs **zero** heap allocations (the patch
    /// region is part of [`scratch_floats`](Self::scratch_floats)).
    /// Equivalent to [`run`](Self::run) within 1e-4 — the register
    /// tile reassociates f32 sums, so bit-identity is not promised.
    pub fn run_gemm(&self, x: &Feature, scratch: &mut Scratch, out: &mut Feature) {
        self.run_gemm_isa(Isa::active(), x, scratch, out);
    }

    /// [`run_gemm`](Self::run_gemm) with the microkernel lane pinned —
    /// what [`run_with`](Self::run_with) dispatches so a tuned
    /// [`ExecStrategy::isa`] means what it measured (DESIGN.md
    /// §SIMD-Dispatch).  Unavailable lanes degrade to scalar.
    fn run_gemm_isa(&self, isa: Isa, x: &Feature, scratch: &mut Scratch, out: &mut Feature) {
        self.check_shapes(x, out);
        let buf = scratch.ensure(self.scratch_floats());
        self.run_gemm_image(isa, &x.data, buf, &mut out.data);
    }

    /// Serial phase-GEMM core over raw image views (`buf` laid out as
    /// [`scratch_floats`](Self::scratch_floats): slabs | phases |
    /// patch).  Factored from [`run_gemm`](Self::run_gemm) unchanged.
    fn run_gemm_image(&self, isa: Isa, x: &[f32], buf: &mut [f32], out: &mut [f32]) {
        let n_in = self.params.n_in;
        let cin = self.params.cin;
        let cout = self.params.cout;
        let (slab_area, rest) = buf.split_at_mut(self.slab_floats);
        let (phase_area, patch_area) = rest.split_at_mut(self.phase_floats);
        for (pi, pp) in self.phases.iter().enumerate() {
            let _phase_span = trace::span("conv.phase", isa.gemm_lane_tag(), trace::NONE, pi as u32);
            let slab = &mut slab_area[pp.slab_off..pp.slab_off + pp.slab_len];
            build_slab_view(x, n_in, n_in, cin, &pp.geom, slab);
            let sub = &self.seg.subs[pp.geom.sub];
            let patch = &mut patch_area[..pp.patch_len];
            gemm::im2col_rows(
                slab,
                pp.slab_w,
                cin,
                sub.rows,
                sub.cols,
                pp.geom.n_cols,
                0,
                pp.geom.n_rows,
                patch,
            );
            let phase = &mut phase_area[pp.phase_off..pp.phase_off + pp.phase_len];
            phase.fill(0.0);
            gemm::gemm_packed_isa(
                isa,
                patch,
                &pp.packed_kernel,
                phase,
                pp.geom.n_rows * pp.geom.n_cols,
                pp.gemm_k,
                cout,
            );
            scatter_rows_view(
                out,
                self.out,
                cout,
                phase,
                pp.geom.rp,
                pp.geom.sp,
                pp.geom.n_rows,
                pp.geom.n_cols,
            );
        }
    }

    /// Row-parallel phase-GEMM lane: phases processed one at a time,
    /// each phase's output rows drained across `workers` pool threads —
    /// every job im2cols its own patch rows and runs its own
    /// `n_cols × Cout` GEMM against the shared packed sub-kernel.
    /// Same 1e-4 equivalence contract as [`run_gemm`](Self::run_gemm)
    /// (each output element's sum is computed by the same microkernel
    /// whatever the worker count, so this lane matches `run_gemm`
    /// bit-for-bit; only the direct reference is tolerance-matched).
    pub fn run_gemm_par_rows(
        &self,
        x: &Feature,
        scratch: &mut Scratch,
        out: &mut Feature,
        workers: usize,
    ) {
        self.run_gemm_par_rows_isa(Isa::active(), x, scratch, out, workers)
    }

    /// [`run_gemm_par_rows`](Self::run_gemm_par_rows) with the
    /// microkernel lane pinned (see [`run_gemm_isa`](Self::run_gemm_isa)).
    fn run_gemm_par_rows_isa(
        &self,
        isa: Isa,
        x: &Feature,
        scratch: &mut Scratch,
        out: &mut Feature,
        workers: usize,
    ) {
        let workers = workers.max(1);
        if workers == 1 {
            return self.run_gemm_isa(isa, x, scratch, out);
        }
        self.check_shapes(x, out);
        let cin = self.params.cin;
        let cout = self.params.cout;
        let buf = scratch.ensure(self.scratch_floats());
        {
            let (slab_area, rest) = buf.split_at_mut(self.slab_floats);
            let (phase_area, patch_area) = rest.split_at_mut(self.phase_floats);
            for pp in &self.phases {
                let slab = &mut slab_area[pp.slab_off..pp.slab_off + pp.slab_len];
                build_slab(x, &pp.geom, slab);
            }
            let slab_area: &[f32] = slab_area;
            let mut rest: &mut [f32] = phase_area;
            for pp in &self.phases {
                let (mine, tail) = rest.split_at_mut(pp.phase_len);
                rest = tail;
                let sub = &self.seg.subs[pp.geom.sub];
                let row_len = pp.geom.n_cols * cout;
                let patch_row_len = pp.geom.n_cols * pp.gemm_k;
                let jobs: Vec<(usize, &mut [f32], &mut [f32])> = mine
                    .chunks_mut(row_len)
                    .zip(patch_area[..pp.patch_len].chunks_mut(patch_row_len))
                    .enumerate()
                    .map(|(ri, (row, patch))| (ri, row, patch))
                    .collect();
                threadpool::parallel_drain(jobs, workers, |(ri, row, patch)| {
                    let slab = &slab_area[pp.slab_off..pp.slab_off + pp.slab_len];
                    gemm::im2col_rows(
                        slab,
                        pp.slab_w,
                        cin,
                        sub.rows,
                        sub.cols,
                        pp.geom.n_cols,
                        ri,
                        ri + 1,
                        patch,
                    );
                    row.fill(0.0);
                    gemm::gemm_packed_isa(
                        isa,
                        patch,
                        &pp.packed_kernel,
                        row,
                        pp.geom.n_cols,
                        pp.gemm_k,
                        cout,
                    );
                });
            }
        }
        let phase_area = &buf[self.slab_floats..];
        for pp in &self.phases {
            scatter_rows(
                out,
                &phase_area[pp.phase_off..pp.phase_off + pp.phase_len],
                pp.geom.rp,
                pp.geom.sp,
                pp.geom.n_rows,
                pp.geom.n_cols,
            );
        }
    }

    // ------------------------------------------- fused-epilogue lanes

    /// The [`gemm::StridedDst`] mapping one phase's GEMM rows onto the
    /// interleaved output (DESIGN.md §Fused-Epilogue): row-major phase
    /// row `py`, col `px` lands at output pixel
    /// `(rp + 2·py, sp + 2·px)`.  `img_rows`/`img_stride` thread the
    /// batched variant (phase rows repeat per image, `img_rows = 0`
    /// means single image).
    fn phase_dst<'a>(
        &self,
        pp: &PhasePlan,
        out: &'a mut [f32],
        img_rows: usize,
        img_stride: usize,
    ) -> gemm::StridedDst<'a> {
        let cout = self.params.cout;
        gemm::StridedDst {
            out,
            base: (pp.geom.rp * self.out + pp.geom.sp) * cout,
            col_stride: 2 * cout,
            row_stride: 2 * self.out * cout,
            n_cols: pp.geom.n_cols,
            img_rows,
            img_stride,
        }
    }

    /// [`phase_dst`](Self::phase_dst) restricted to one output row (the
    /// row-parallel fused lanes hand each job a disjoint
    /// `out_w·Cout` row slice): every GEMM row `r < n_cols` maps into
    /// the same output row, so the row stride is never taken.
    fn phase_row_dst<'a>(&self, pp: &PhasePlan, row: &'a mut [f32]) -> gemm::StridedDst<'a> {
        let cout = self.params.cout;
        gemm::StridedDst {
            out: row,
            base: pp.geom.sp * cout,
            col_stride: 2 * cout,
            row_stride: 0,
            n_cols: pp.geom.n_cols,
            img_rows: 0,
            img_stride: 0,
        }
    }

    /// Epilogue-only drain of one phase's strided output rows — the
    /// `k = 0` degenerate of the row-parallel fused lanes (zero-tap
    /// sub-kernel): the GEMM contributes nothing, but the phase still
    /// owns its rows, so bias + activation must be stored over zero
    /// accumulators exactly like the separate path's scatter of a
    /// zero slab.
    fn fused_epilogue_only_rows(
        &self,
        pp: &PhasePlan,
        out: &mut [f32],
        workers: usize,
        epi: &gemm::Epilogue<'_>,
    ) {
        let cout = self.params.cout;
        let row_floats = self.out * cout;
        let jobs: Vec<&mut [f32]> = out
            .chunks_mut(row_floats)
            .skip(pp.geom.rp)
            .step_by(2)
            .take(pp.geom.n_rows)
            .collect();
        threadpool::parallel_drain(jobs, workers, |row| {
            let mut dst = self.phase_row_dst(pp, row);
            gemm::gemm_packed_fused(Isa::Scalar, &[], &[], pp.geom.n_cols, 0, cout, &mut dst, epi);
        });
    }

    /// Serial fused-epilogue phase-GEMM lane (DESIGN.md
    /// §Fused-Epilogue): identical phase walk to
    /// [`run_gemm`](Self::run_gemm), but each accumulator tile stores
    /// **directly** into the strided output positions with `epi`'s
    /// bias + activation applied in-register — no phase slab, no
    /// scatter pass, no separate epilogue pass.  Scalar microkernels
    /// are bit-identical to slab + scatter + apply (the slab
    /// store/reload is an exact f32 round-trip); vector lanes hold the
    /// usual 1e-4 reassociation contract.  Zero-alloc in steady state
    /// with the strictly smaller
    /// [`scratch_floats_gemm_fused`](Self::scratch_floats_gemm_fused)
    /// arena claim.
    pub fn run_gemm_fused(
        &self,
        x: &Feature,
        scratch: &mut Scratch,
        out: &mut Feature,
        epi: &gemm::Epilogue<'_>,
    ) {
        self.run_gemm_fused_isa(Isa::active(), x, scratch, out, epi);
    }

    /// [`run_gemm_fused`](Self::run_gemm_fused) with the microkernel
    /// lane pinned (see [`run_gemm_isa`](Self::run_gemm_isa)).
    fn run_gemm_fused_isa(
        &self,
        isa: Isa,
        x: &Feature,
        scratch: &mut Scratch,
        out: &mut Feature,
        epi: &gemm::Epilogue<'_>,
    ) {
        self.check_shapes(x, out);
        let buf = scratch.ensure(self.scratch_floats_gemm_fused());
        self.run_gemm_fused_image(isa, &x.data, buf, &mut out.data, epi);
    }

    /// Serial fused core over raw image views (`buf` laid out as
    /// [`scratch_floats_gemm_fused`](Self::scratch_floats_gemm_fused):
    /// slabs | patch — no phase area).
    fn run_gemm_fused_image(
        &self,
        isa: Isa,
        x: &[f32],
        buf: &mut [f32],
        out: &mut [f32],
        epi: &gemm::Epilogue<'_>,
    ) {
        let n_in = self.params.n_in;
        let cin = self.params.cin;
        let cout = self.params.cout;
        let (slab_area, patch_area) = buf.split_at_mut(self.slab_floats);
        for (pi, pp) in self.phases.iter().enumerate() {
            let _phase_span = trace::span("conv.phase", isa.gemm_lane_tag(), trace::NONE, pi as u32);
            let slab = &mut slab_area[pp.slab_off..pp.slab_off + pp.slab_len];
            build_slab_view(x, n_in, n_in, cin, &pp.geom, slab);
            let sub = &self.seg.subs[pp.geom.sub];
            let patch = &mut patch_area[..pp.patch_len];
            gemm::im2col_rows(
                slab,
                pp.slab_w,
                cin,
                sub.rows,
                sub.cols,
                pp.geom.n_cols,
                0,
                pp.geom.n_rows,
                patch,
            );
            let mut dst = self.phase_dst(pp, out, 0, 0);
            gemm::gemm_packed_fused(
                isa,
                patch,
                &pp.packed_kernel,
                pp.geom.n_rows * pp.geom.n_cols,
                pp.gemm_k,
                cout,
                &mut dst,
                epi,
            );
        }
    }

    /// Row-parallel fused-epilogue phase-GEMM lane: like
    /// [`run_gemm_par_rows`](Self::run_gemm_par_rows), but every job
    /// owns the **output row itself** (a disjoint `out_w·Cout` slice
    /// reached by striding the output's rows by 2 from `rp`) instead
    /// of a phase-slab row, and its GEMM stores tiles straight into
    /// the strided columns with the epilogue folded in — the scatter
    /// loop disappears entirely.
    pub fn run_gemm_fused_par_rows(
        &self,
        x: &Feature,
        scratch: &mut Scratch,
        out: &mut Feature,
        workers: usize,
        epi: &gemm::Epilogue<'_>,
    ) {
        self.run_gemm_fused_par_rows_isa(Isa::active(), x, scratch, out, workers, epi);
    }

    /// [`run_gemm_fused_par_rows`](Self::run_gemm_fused_par_rows) with
    /// the microkernel lane pinned.
    fn run_gemm_fused_par_rows_isa(
        &self,
        isa: Isa,
        x: &Feature,
        scratch: &mut Scratch,
        out: &mut Feature,
        workers: usize,
        epi: &gemm::Epilogue<'_>,
    ) {
        let workers = workers.max(1);
        if workers == 1 {
            return self.run_gemm_fused_isa(isa, x, scratch, out, epi);
        }
        self.check_shapes(x, out);
        let cin = self.params.cin;
        let cout = self.params.cout;
        let row_floats = self.out * cout;
        let buf = scratch.ensure(self.scratch_floats_gemm_fused());
        let (slab_area, patch_area) = buf.split_at_mut(self.slab_floats);
        for pp in &self.phases {
            let slab = &mut slab_area[pp.slab_off..pp.slab_off + pp.slab_len];
            build_slab(x, &pp.geom, slab);
        }
        let slab_area: &[f32] = slab_area;
        for pp in &self.phases {
            let sub = &self.seg.subs[pp.geom.sub];
            let patch_row_len = pp.geom.n_cols * pp.gemm_k;
            if patch_row_len == 0 {
                self.fused_epilogue_only_rows(pp, &mut out.data, workers, epi);
                continue;
            }
            let jobs: Vec<(usize, &mut [f32], &mut [f32])> = out
                .data
                .chunks_mut(row_floats)
                .skip(pp.geom.rp)
                .step_by(2)
                .take(pp.geom.n_rows)
                .zip(patch_area[..pp.patch_len].chunks_mut(patch_row_len))
                .enumerate()
                .map(|(ri, (row, patch))| (ri, row, patch))
                .collect();
            threadpool::parallel_drain(jobs, workers, |(ri, row, patch)| {
                let slab = &slab_area[pp.slab_off..pp.slab_off + pp.slab_len];
                gemm::im2col_rows(
                    slab,
                    pp.slab_w,
                    cin,
                    sub.rows,
                    sub.cols,
                    pp.geom.n_cols,
                    ri,
                    ri + 1,
                    patch,
                );
                let mut dst = self.phase_row_dst(pp, row);
                gemm::gemm_packed_fused(
                    isa,
                    patch,
                    &pp.packed_kernel,
                    pp.geom.n_cols,
                    pp.gemm_k,
                    cout,
                    &mut dst,
                    epi,
                );
            });
        }
    }

    /// Serial quantized fused-epilogue lane (DESIGN.md
    /// §Fused-Epilogue / §Reduced-Precision): the quantized phase walk
    /// of [`run_gemm_quant_isa`](Self::run_gemm_quant_isa) with the
    /// widening GEMM storing straight into the strided output — the
    /// int8 dequantization scale folds into the same epilogue step as
    /// the bias + activation.  The quantized fused drivers are the
    /// scalar panel loops, so this lane is **bit-identical** to the
    /// separate quantized lane followed by the epilogue pass.
    fn run_gemm_fused_quant(
        &self,
        precision: Precision,
        x: &Feature,
        scratch: &mut Scratch,
        out: &mut Feature,
        epi: &gemm::Epilogue<'_>,
    ) {
        self.check_shapes(x, out);
        let (q16_n, q8_n) = quant_elem_split(precision, self.quant_patch_elems());
        let (buf, q16, q8) = scratch.ensure_quant(self.scratch_floats_gemm_fused(), q16_n, q8_n);
        let n_in = self.params.n_in;
        let cin = self.params.cin;
        let cout = self.params.cout;
        let (slab_area, patch_area) = buf.split_at_mut(self.slab_floats);
        for (pi, pp) in self.phases.iter().enumerate() {
            let _phase_span = trace::span("conv.phase", precision.name(), trace::NONE, pi as u32);
            let slab = &mut slab_area[pp.slab_off..pp.slab_off + pp.slab_len];
            build_slab_view(&x.data, n_in, n_in, cin, &pp.geom, slab);
            let sub = &self.seg.subs[pp.geom.sub];
            let patch = &mut patch_area[..pp.patch_len];
            gemm::im2col_rows(
                slab,
                pp.slab_w,
                cin,
                sub.rows,
                sub.cols,
                pp.geom.n_cols,
                0,
                pp.geom.n_rows,
                patch,
            );
            let m = pp.geom.n_rows * pp.geom.n_cols;
            let mut dst = self.phase_dst(pp, &mut out.data, 0, 0);
            match precision {
                Precision::F16 => {
                    let qa = &mut q16[..pp.patch_len];
                    quant::quantize_f16(patch, qa);
                    gemm::gemm_packed_q16_fused(
                        precision,
                        qa,
                        &pp.qpanel_f16,
                        m,
                        pp.gemm_k,
                        cout,
                        &mut dst,
                        epi,
                    );
                }
                Precision::Bf16 => {
                    let qa = &mut q16[..pp.patch_len];
                    quant::quantize_bf16(patch, qa);
                    gemm::gemm_packed_q16_fused(
                        precision,
                        qa,
                        &pp.qpanel_bf16,
                        m,
                        pp.gemm_k,
                        cout,
                        &mut dst,
                        epi,
                    );
                }
                Precision::Int8 => {
                    let qa = &mut q8[..pp.patch_len];
                    let a_scale = quant::int8_scale(quant::absmax(patch));
                    quant::quantize_i8(patch, a_scale, qa);
                    gemm::gemm_packed_q8_fused(
                        qa,
                        a_scale,
                        &pp.qpanel_i8,
                        &pp.qscale_i8,
                        m,
                        pp.gemm_k,
                        cout,
                        &mut dst,
                        epi,
                    );
                }
                Precision::F32 => unreachable!("f32 dispatches the exact fused GEMM lane"),
            }
        }
    }

    /// Row-parallel quantized fused-epilogue lane: every job im2cols
    /// its row, quantizes it into its disjoint slice of the arena's
    /// reduced-precision lane (per-row int8 activation scales, like
    /// [`run_gemm_quant_par_rows_isa`](Self::run_gemm_quant_par_rows_isa)),
    /// and stores the widening GEMM straight into its strided output
    /// row with the epilogue folded in.
    #[allow(clippy::too_many_arguments)]
    fn run_gemm_fused_quant_par_rows(
        &self,
        precision: Precision,
        x: &Feature,
        scratch: &mut Scratch,
        out: &mut Feature,
        workers: usize,
        epi: &gemm::Epilogue<'_>,
    ) {
        let workers = workers.max(1);
        if workers == 1 {
            return self.run_gemm_fused_quant(precision, x, scratch, out, epi);
        }
        self.check_shapes(x, out);
        let cin = self.params.cin;
        let cout = self.params.cout;
        let row_floats = self.out * cout;
        let (q16_n, q8_n) = quant_elem_split(precision, self.quant_patch_elems());
        let (buf, q16, q8) = scratch.ensure_quant(self.scratch_floats_gemm_fused(), q16_n, q8_n);
        let (slab_area, patch_area) = buf.split_at_mut(self.slab_floats);
        for pp in &self.phases {
            let slab = &mut slab_area[pp.slab_off..pp.slab_off + pp.slab_len];
            build_slab(x, &pp.geom, slab);
        }
        let slab_area: &[f32] = slab_area;
        for pp in &self.phases {
            let sub = &self.seg.subs[pp.geom.sub];
            let patch_row_len = pp.geom.n_cols * pp.gemm_k;
            if patch_row_len == 0 {
                self.fused_epilogue_only_rows(pp, &mut out.data, workers, epi);
                continue;
            }
            let im2col_row = |ri: usize, patch: &mut [f32]| {
                let slab = &slab_area[pp.slab_off..pp.slab_off + pp.slab_len];
                gemm::im2col_rows(
                    slab,
                    pp.slab_w,
                    cin,
                    sub.rows,
                    sub.cols,
                    pp.geom.n_cols,
                    ri,
                    ri + 1,
                    patch,
                );
            };
            match precision {
                Precision::F16 | Precision::Bf16 => {
                    let panel: &[u16] = if precision == Precision::F16 {
                        &pp.qpanel_f16
                    } else {
                        &pp.qpanel_bf16
                    };
                    let jobs: Vec<(usize, &mut [f32], &mut [f32], &mut [u16])> = out
                        .data
                        .chunks_mut(row_floats)
                        .skip(pp.geom.rp)
                        .step_by(2)
                        .take(pp.geom.n_rows)
                        .zip(patch_area[..pp.patch_len].chunks_mut(patch_row_len))
                        .zip(q16[..pp.patch_len].chunks_mut(patch_row_len))
                        .enumerate()
                        .map(|(ri, ((row, patch), qrow))| (ri, row, patch, qrow))
                        .collect();
                    threadpool::parallel_drain(jobs, workers, |(ri, row, patch, qrow)| {
                        im2col_row(ri, patch);
                        if precision == Precision::F16 {
                            quant::quantize_f16(patch, qrow);
                        } else {
                            quant::quantize_bf16(patch, qrow);
                        }
                        let mut dst = self.phase_row_dst(pp, row);
                        gemm::gemm_packed_q16_fused(
                            precision,
                            qrow,
                            panel,
                            pp.geom.n_cols,
                            pp.gemm_k,
                            cout,
                            &mut dst,
                            epi,
                        );
                    });
                }
                Precision::Int8 => {
                    let jobs: Vec<(usize, &mut [f32], &mut [f32], &mut [i8])> = out
                        .data
                        .chunks_mut(row_floats)
                        .skip(pp.geom.rp)
                        .step_by(2)
                        .take(pp.geom.n_rows)
                        .zip(patch_area[..pp.patch_len].chunks_mut(patch_row_len))
                        .zip(q8[..pp.patch_len].chunks_mut(patch_row_len))
                        .enumerate()
                        .map(|(ri, ((row, patch), qrow))| (ri, row, patch, qrow))
                        .collect();
                    threadpool::parallel_drain(jobs, workers, |(ri, row, patch, qrow)| {
                        im2col_row(ri, patch);
                        let a_scale = quant::int8_scale(quant::absmax(patch));
                        quant::quantize_i8(patch, a_scale, qrow);
                        let mut dst = self.phase_row_dst(pp, row);
                        gemm::gemm_packed_q8_fused(
                            qrow,
                            a_scale,
                            &pp.qpanel_i8,
                            &pp.qscale_i8,
                            pp.geom.n_cols,
                            pp.gemm_k,
                            cout,
                            &mut dst,
                            epi,
                        );
                    });
                }
                Precision::F32 => unreachable!("f32 dispatches the exact fused GEMM lane"),
            }
        }
    }

    /// Serial quantized phase-GEMM lane (DESIGN.md §Reduced-Precision):
    /// the same phases as [`run_gemm`](Self::run_gemm), but the im2col
    /// patch is quantized into the arena's reduced-precision lane and
    /// multiplied by the matching weight panel frozen at construction
    /// through the widening kernels ([`gemm::gemm_packed_q16`] /
    /// [`gemm::gemm_packed_q8`] — f32 accumulation throughout).  int8
    /// activations take one symmetric absmax scale per phase, computed
    /// from the f32 patch just filled.  Zero-alloc in steady state
    /// (the quantized lanes of the arena grow once, to
    /// [`quant_patch_elems`](Self::quant_patch_elems)); within the
    /// documented per-precision drift bound of the f32 reference.
    fn run_gemm_quant_isa(
        &self,
        isa: Isa,
        precision: Precision,
        x: &Feature,
        scratch: &mut Scratch,
        out: &mut Feature,
    ) {
        self.check_shapes(x, out);
        let (q16_n, q8_n) = quant_elem_split(precision, self.quant_patch_elems());
        let (buf, q16, q8) = scratch.ensure_quant(self.scratch_floats(), q16_n, q8_n);
        self.run_gemm_quant_image(isa, precision, &x.data, buf, q16, q8, &mut out.data);
    }

    /// Serial quantized core over raw image views (`buf` laid out as
    /// [`scratch_floats`](Self::scratch_floats); exactly one of
    /// `q16`/`q8` is non-empty, per the precision).
    #[allow(clippy::too_many_arguments)]
    fn run_gemm_quant_image(
        &self,
        isa: Isa,
        precision: Precision,
        x: &[f32],
        buf: &mut [f32],
        q16: &mut [u16],
        q8: &mut [i8],
        out: &mut [f32],
    ) {
        let n_in = self.params.n_in;
        let cin = self.params.cin;
        let cout = self.params.cout;
        let (slab_area, rest) = buf.split_at_mut(self.slab_floats);
        let (phase_area, patch_area) = rest.split_at_mut(self.phase_floats);
        for (pi, pp) in self.phases.iter().enumerate() {
            let _phase_span = trace::span("conv.phase", precision.name(), trace::NONE, pi as u32);
            let slab = &mut slab_area[pp.slab_off..pp.slab_off + pp.slab_len];
            build_slab_view(x, n_in, n_in, cin, &pp.geom, slab);
            let sub = &self.seg.subs[pp.geom.sub];
            let patch = &mut patch_area[..pp.patch_len];
            gemm::im2col_rows(
                slab,
                pp.slab_w,
                cin,
                sub.rows,
                sub.cols,
                pp.geom.n_cols,
                0,
                pp.geom.n_rows,
                patch,
            );
            let m = pp.geom.n_rows * pp.geom.n_cols;
            let phase = &mut phase_area[pp.phase_off..pp.phase_off + pp.phase_len];
            phase.fill(0.0);
            match precision {
                Precision::F16 => {
                    let qa = &mut q16[..pp.patch_len];
                    quant::quantize_f16(patch, qa);
                    gemm::gemm_packed_q16(
                        isa,
                        precision,
                        qa,
                        &pp.qpanel_f16,
                        phase,
                        m,
                        pp.gemm_k,
                        cout,
                    );
                }
                Precision::Bf16 => {
                    let qa = &mut q16[..pp.patch_len];
                    quant::quantize_bf16(patch, qa);
                    gemm::gemm_packed_q16(
                        isa,
                        precision,
                        qa,
                        &pp.qpanel_bf16,
                        phase,
                        m,
                        pp.gemm_k,
                        cout,
                    );
                }
                Precision::Int8 => {
                    let qa = &mut q8[..pp.patch_len];
                    let a_scale = quant::int8_scale(quant::absmax(patch));
                    quant::quantize_i8(patch, a_scale, qa);
                    gemm::gemm_packed_q8(
                        isa,
                        qa,
                        a_scale,
                        &pp.qpanel_i8,
                        &pp.qscale_i8,
                        phase,
                        m,
                        pp.gemm_k,
                        cout,
                    );
                }
                Precision::F32 => unreachable!("f32 dispatches the exact GEMM lane"),
            }
            scatter_rows_view(
                out,
                self.out,
                cout,
                phase,
                pp.geom.rp,
                pp.geom.sp,
                pp.geom.n_rows,
                pp.geom.n_cols,
            );
        }
    }

    /// Row-parallel quantized phase-GEMM lane: like
    /// [`run_gemm_par_rows`](Self::run_gemm_par_rows), every job
    /// im2cols its own patch rows, quantizes them into its disjoint
    /// slice of the arena's reduced-precision lane, and runs the
    /// widening GEMM against the shared frozen panel.  f16/bf16 are
    /// bit-identical to the serial quantized lane (elementwise
    /// conversion, same per-element accumulation order); int8 takes a
    /// **per-row** activation scale (each job's GEMM applies its own),
    /// which can only tighten the phase-wide serial scale — the same
    /// drift bound holds for both.
    #[allow(clippy::too_many_arguments)]
    fn run_gemm_quant_par_rows_isa(
        &self,
        isa: Isa,
        precision: Precision,
        x: &Feature,
        scratch: &mut Scratch,
        out: &mut Feature,
        workers: usize,
    ) {
        let workers = workers.max(1);
        if workers == 1 {
            return self.run_gemm_quant_isa(isa, precision, x, scratch, out);
        }
        self.check_shapes(x, out);
        let cin = self.params.cin;
        let cout = self.params.cout;
        let (q16_n, q8_n) = quant_elem_split(precision, self.quant_patch_elems());
        let (buf, q16, q8) = scratch.ensure_quant(self.scratch_floats(), q16_n, q8_n);
        {
            let (slab_area, rest) = buf.split_at_mut(self.slab_floats);
            let (phase_area, patch_area) = rest.split_at_mut(self.phase_floats);
            for pp in &self.phases {
                let slab = &mut slab_area[pp.slab_off..pp.slab_off + pp.slab_len];
                build_slab(x, &pp.geom, slab);
            }
            let slab_area: &[f32] = slab_area;
            let mut rest: &mut [f32] = phase_area;
            for pp in &self.phases {
                let (mine, tail) = rest.split_at_mut(pp.phase_len);
                rest = tail;
                let sub = &self.seg.subs[pp.geom.sub];
                let row_len = pp.geom.n_cols * cout;
                let patch_row_len = pp.geom.n_cols * pp.gemm_k;
                let im2col_row = |ri: usize, patch: &mut [f32]| {
                    let slab = &slab_area[pp.slab_off..pp.slab_off + pp.slab_len];
                    gemm::im2col_rows(
                        slab,
                        pp.slab_w,
                        cin,
                        sub.rows,
                        sub.cols,
                        pp.geom.n_cols,
                        ri,
                        ri + 1,
                        patch,
                    );
                };
                if precision == Precision::Int8 {
                    let jobs: Vec<(usize, &mut [f32], &mut [f32], &mut [i8])> = mine
                        .chunks_mut(row_len)
                        .zip(patch_area[..pp.patch_len].chunks_mut(patch_row_len))
                        .zip(q8[..pp.patch_len].chunks_mut(patch_row_len))
                        .enumerate()
                        .map(|(ri, ((row, patch), qrow))| (ri, row, patch, qrow))
                        .collect();
                    threadpool::parallel_drain(jobs, workers, |(ri, row, patch, qrow)| {
                        im2col_row(ri, patch);
                        let a_scale = quant::int8_scale(quant::absmax(patch));
                        quant::quantize_i8(patch, a_scale, qrow);
                        row.fill(0.0);
                        gemm::gemm_packed_q8(
                            isa,
                            qrow,
                            a_scale,
                            &pp.qpanel_i8,
                            &pp.qscale_i8,
                            row,
                            pp.geom.n_cols,
                            pp.gemm_k,
                            cout,
                        );
                    });
                } else {
                    let (panel, convert): (&[u16], fn(&[f32], &mut [u16])) =
                        if precision == Precision::F16 {
                            (&pp.qpanel_f16, quant::quantize_f16)
                        } else {
                            (&pp.qpanel_bf16, quant::quantize_bf16)
                        };
                    let jobs: Vec<(usize, &mut [f32], &mut [f32], &mut [u16])> = mine
                        .chunks_mut(row_len)
                        .zip(patch_area[..pp.patch_len].chunks_mut(patch_row_len))
                        .zip(q16[..pp.patch_len].chunks_mut(patch_row_len))
                        .enumerate()
                        .map(|(ri, ((row, patch), qrow))| (ri, row, patch, qrow))
                        .collect();
                    threadpool::parallel_drain(jobs, workers, |(ri, row, patch, qrow)| {
                        im2col_row(ri, patch);
                        convert(patch, qrow);
                        row.fill(0.0);
                        gemm::gemm_packed_q16(
                            isa,
                            precision,
                            qrow,
                            panel,
                            row,
                            pp.geom.n_cols,
                            pp.gemm_k,
                            cout,
                        );
                    });
                }
            }
        }
        let phase_area = &buf[self.slab_floats..];
        for pp in &self.phases {
            scatter_rows(
                out,
                &phase_area[pp.phase_off..pp.phase_off + pp.phase_len],
                pp.geom.rp,
                pp.geom.sp,
                pp.geom.n_rows,
                pp.geom.n_cols,
            );
        }
    }

    /// Fused batched quantized phase-GEMM lane: the stacked
    /// `[N·rows, K]` patch operand of
    /// [`run_gemm_batch`](Self::run_gemm_batch) is quantized whole and
    /// multiplied by the frozen panel in one widening GEMM per phase.
    /// f16/bf16 are bit-identical to `N` sequential quantized runs
    /// (elementwise conversion; the stacked M extent does not change
    /// per-element accumulation order); int8 takes one **batch-wide**
    /// activation scale per phase, so it matches the per-image lane
    /// within the drift bound rather than bit-for-bit.
    fn run_gemm_quant_batch_isa(
        &self,
        isa: Isa,
        precision: Precision,
        x: &FeatureBatch,
        scratch: &mut Scratch,
        out: &mut FeatureBatch,
    ) {
        self.check_batch_shapes(x, out);
        let n = x.n;
        let cout = self.params.cout;
        let (q16_n, q8_n) = quant_elem_split(precision, self.quant_patch_elems_batch(n));
        let (buf, q16, q8) = scratch.ensure_quant(self.scratch_floats_gemm_batch(n), q16_n, q8_n);
        let (slab_area, rest) = buf.split_at_mut(self.slab_floats);
        let (phase_area, patch_area) = rest.split_at_mut(n * self.max_phase_floats());
        for pp in &self.phases {
            self.stack_phase_patches(pp, x, slab_area, patch_area);
            let patch = &patch_area[..n * pp.patch_len];
            let m = n * pp.geom.n_rows * pp.geom.n_cols;
            let phase = &mut phase_area[..n * pp.phase_len];
            phase.fill(0.0);
            match precision {
                Precision::F16 => {
                    let qa = &mut q16[..n * pp.patch_len];
                    quant::quantize_f16(patch, qa);
                    gemm::gemm_packed_q16(
                        isa,
                        precision,
                        qa,
                        &pp.qpanel_f16,
                        phase,
                        m,
                        pp.gemm_k,
                        cout,
                    );
                }
                Precision::Bf16 => {
                    let qa = &mut q16[..n * pp.patch_len];
                    quant::quantize_bf16(patch, qa);
                    gemm::gemm_packed_q16(
                        isa,
                        precision,
                        qa,
                        &pp.qpanel_bf16,
                        phase,
                        m,
                        pp.gemm_k,
                        cout,
                    );
                }
                Precision::Int8 => {
                    let qa = &mut q8[..n * pp.patch_len];
                    let a_scale = quant::int8_scale(quant::absmax(patch));
                    quant::quantize_i8(patch, a_scale, qa);
                    gemm::gemm_packed_q8(
                        isa,
                        qa,
                        a_scale,
                        &pp.qpanel_i8,
                        &pp.qscale_i8,
                        phase,
                        m,
                        pp.gemm_k,
                        cout,
                    );
                }
                Precision::F32 => unreachable!("f32 dispatches the exact GEMM lane"),
            }
            for i in 0..n {
                scatter_rows_view(
                    out.image_mut(i),
                    self.out,
                    cout,
                    &phase[i * pp.phase_len..(i + 1) * pp.phase_len],
                    pp.geom.rp,
                    pp.geom.sp,
                    pp.geom.n_rows,
                    pp.geom.n_cols,
                );
            }
        }
    }

    /// Row-parallel fused batched quantized lane: the stacked patch is
    /// built image-serially like
    /// [`run_gemm_batch_par`](Self::run_gemm_batch_par), then each
    /// per-output-row job quantizes its patch rows into its disjoint
    /// quantized-lane slice and runs the widening GEMM (per-row int8
    /// activation scales, like the single-image parallel lane).
    #[allow(clippy::too_many_arguments)]
    fn run_gemm_quant_batch_par_isa(
        &self,
        isa: Isa,
        precision: Precision,
        x: &FeatureBatch,
        scratch: &mut Scratch,
        out: &mut FeatureBatch,
        workers: usize,
    ) {
        let workers = workers.max(1);
        if workers == 1 {
            return self.run_gemm_quant_batch_isa(isa, precision, x, scratch, out);
        }
        self.check_batch_shapes(x, out);
        let n = x.n;
        let cout = self.params.cout;
        let (q16_n, q8_n) = quant_elem_split(precision, self.quant_patch_elems_batch(n));
        let (buf, q16, q8) = scratch.ensure_quant(self.scratch_floats_gemm_batch(n), q16_n, q8_n);
        let (slab_area, rest) = buf.split_at_mut(self.slab_floats);
        let (phase_area, patch_area) = rest.split_at_mut(n * self.max_phase_floats());
        for pp in &self.phases {
            self.stack_phase_patches(pp, x, slab_area, patch_area);
            {
                let row_len = pp.geom.n_cols * cout;
                let patch_row_len = pp.geom.n_cols * pp.gemm_k;
                let patch: &[f32] = &patch_area[..n * pp.patch_len];
                if precision == Precision::Int8 {
                    let jobs: Vec<(&[f32], &mut [f32], &mut [i8])> = phase_area
                        [..n * pp.phase_len]
                        .chunks_mut(row_len)
                        .zip(patch.chunks(patch_row_len))
                        .zip(q8[..n * pp.patch_len].chunks_mut(patch_row_len))
                        .map(|((row, prow), qrow)| (prow, row, qrow))
                        .collect();
                    threadpool::parallel_drain(jobs, workers, |(prow, row, qrow)| {
                        let a_scale = quant::int8_scale(quant::absmax(prow));
                        quant::quantize_i8(prow, a_scale, qrow);
                        row.fill(0.0);
                        gemm::gemm_packed_q8(
                            isa,
                            qrow,
                            a_scale,
                            &pp.qpanel_i8,
                            &pp.qscale_i8,
                            row,
                            pp.geom.n_cols,
                            pp.gemm_k,
                            cout,
                        );
                    });
                } else {
                    let (panel, convert): (&[u16], fn(&[f32], &mut [u16])) =
                        if precision == Precision::F16 {
                            (&pp.qpanel_f16, quant::quantize_f16)
                        } else {
                            (&pp.qpanel_bf16, quant::quantize_bf16)
                        };
                    let jobs: Vec<(&[f32], &mut [f32], &mut [u16])> = phase_area
                        [..n * pp.phase_len]
                        .chunks_mut(row_len)
                        .zip(patch.chunks(patch_row_len))
                        .zip(q16[..n * pp.patch_len].chunks_mut(patch_row_len))
                        .map(|((row, prow), qrow)| (prow, row, qrow))
                        .collect();
                    threadpool::parallel_drain(jobs, workers, |(prow, row, qrow)| {
                        convert(prow, qrow);
                        row.fill(0.0);
                        gemm::gemm_packed_q16(
                            isa,
                            precision,
                            qrow,
                            panel,
                            row,
                            pp.geom.n_cols,
                            pp.gemm_k,
                            cout,
                        );
                    });
                }
            }
            for i in 0..n {
                scatter_rows_view(
                    out.image_mut(i),
                    self.out,
                    cout,
                    &phase_area[i * pp.phase_len..(i + 1) * pp.phase_len],
                    pp.geom.rp,
                    pp.geom.sp,
                    pp.geom.n_rows,
                    pp.geom.n_cols,
                );
            }
        }
    }

    /// Batched direct serial lane (DESIGN.md §Batched-Execution): the
    /// whole [`FeatureBatch`] through **one** direct scratch region,
    /// image by image.  Bit-identical to `N` sequential
    /// [`run`](Self::run) calls — it *is* `N` calls of the same core —
    /// and zero-alloc in steady state like them.
    pub fn run_batch(&self, x: &FeatureBatch, scratch: &mut Scratch, out: &mut FeatureBatch) {
        self.check_batch_shapes(x, out);
        let buf = scratch.ensure(self.scratch_floats_direct());
        let in_len = x.image_floats();
        let out_len = out.image_floats();
        for i in 0..x.n {
            self.run_image(
                &x.data[i * in_len..(i + 1) * in_len],
                buf,
                &mut out.data[i * out_len..(i + 1) * out_len],
            );
        }
    }

    /// Batched direct parallel lane: every image's slabs are built into
    /// its own direct arena region, then **one** work queue of
    /// `(image, phase, output-row)` jobs drains across `workers`
    /// threads of the persistent pool — the batch dimension simply
    /// multiplies the job count, so small layers that could not feed
    /// `workers` threads alone now can.  A singleton batch keeps its
    /// row parallelism (the queue degenerates to exactly
    /// [`run_par`](Self::run_par)'s job set — no serial fallback).
    /// Bit-identical to [`run_batch`](Self::run_batch) (each row is
    /// computed by the same serial correlation core).
    pub fn run_batch_par(
        &self,
        x: &FeatureBatch,
        scratch: &mut Scratch,
        out: &mut FeatureBatch,
        workers: usize,
    ) {
        let workers = workers.max(1);
        if workers == 1 || x.n == 0 {
            return self.run_batch(x, scratch, out);
        }
        self.check_batch_shapes(x, out);
        let n_in = self.params.n_in;
        let cin = self.params.cin;
        let cout = self.params.cout;
        let per = self.scratch_floats_direct();
        let buf = scratch.ensure(self.scratch_floats_batch_par(x.n));
        {
            let mut jobs: Vec<(&[f32], usize, usize, &mut [f32])> = Vec::new();
            let mut regions: &mut [f32] = &mut buf[..];
            for i in 0..x.n {
                let (region, tail) = regions.split_at_mut(per);
                regions = tail;
                let (slab_area, phase_area) = region.split_at_mut(self.slab_floats);
                for pp in &self.phases {
                    build_slab_view(
                        x.image(i),
                        n_in,
                        n_in,
                        cin,
                        &pp.geom,
                        &mut slab_area[pp.slab_off..pp.slab_off + pp.slab_len],
                    );
                }
                let slab_area: &[f32] = slab_area;
                let mut rest: &mut [f32] = phase_area;
                for (pi, pp) in self.phases.iter().enumerate() {
                    let (mine, tail) = rest.split_at_mut(pp.phase_len);
                    rest = tail;
                    let row_len = pp.geom.n_cols * cout;
                    for (ri, row) in mine.chunks_mut(row_len).enumerate() {
                        jobs.push((slab_area, pi, ri, row));
                    }
                }
            }
            threadpool::parallel_drain(jobs, workers, |(slab_area, pi, ri, row)| {
                let pp = &self.phases[pi];
                row.fill(0.0);
                correlate_rows(
                    &slab_area[pp.slab_off..pp.slab_off + pp.slab_len],
                    pp.slab_w,
                    &self.seg.subs[pp.geom.sub],
                    row,
                    pp.geom.n_cols,
                    ri,
                    ri + 1,
                );
            });
        }
        for i in 0..x.n {
            let phase_area = &buf[i * per + self.slab_floats..(i + 1) * per];
            for pp in &self.phases {
                scatter_rows_view(
                    out.image_mut(i),
                    self.out,
                    cout,
                    &phase_area[pp.phase_off..pp.phase_off + pp.phase_len],
                    pp.geom.rp,
                    pp.geom.sp,
                    pp.geom.n_rows,
                    pp.geom.n_cols,
                );
            }
        }
    }

    /// Build one phase's stacked `[N·rows, K]` patch operand: each
    /// image's slab is cropped into the phase's (reused) slab region
    /// and im2col'ed into its `patch_len` slice of `patch_area` — the
    /// shared stacking step of both fused GEMM lanes, so their
    /// patch-offset contract can never desynchronize.
    fn stack_phase_patches(
        &self,
        pp: &PhasePlan,
        x: &FeatureBatch,
        slab_area: &mut [f32],
        patch_area: &mut [f32],
    ) {
        let n_in = self.params.n_in;
        let cin = self.params.cin;
        let sub = &self.seg.subs[pp.geom.sub];
        for i in 0..x.n {
            let slab = &mut slab_area[pp.slab_off..pp.slab_off + pp.slab_len];
            build_slab_view(x.image(i), n_in, n_in, cin, &pp.geom, slab);
            gemm::im2col_rows(
                slab,
                pp.slab_w,
                cin,
                sub.rows,
                sub.cols,
                pp.geom.n_cols,
                0,
                pp.geom.n_rows,
                &mut patch_area[i * pp.patch_len..(i + 1) * pp.patch_len],
            );
        }
    }

    /// Fused batched phase-GEMM lane — where the plan-time packing pays
    /// `N×` (DESIGN.md §Batched-Execution): per phase, every image's
    /// im2col patch rows are stacked back to back into one
    /// `[N·rows, K]` operand and multiplied by the sub-kernel packed at
    /// construction in a **single** GEMM, so the packed B panels are
    /// streamed once per phase for the whole batch instead of once per
    /// image.  Zero-alloc in steady state (the stacked patch/phase
    /// regions are part of
    /// [`scratch_floats_gemm_batch`](Self::scratch_floats_gemm_batch));
    /// bit-identical to `N` sequential [`run_gemm`](Self::run_gemm)
    /// calls (per-element f32 accumulation order does not depend on the
    /// GEMM's M extent), hence within the same 1e-4 of the direct
    /// reference.
    pub fn run_gemm_batch(&self, x: &FeatureBatch, scratch: &mut Scratch, out: &mut FeatureBatch) {
        self.run_gemm_batch_isa(Isa::active(), x, scratch, out);
    }

    /// [`run_gemm_batch`](Self::run_gemm_batch) with the microkernel
    /// lane pinned (see [`run_gemm_isa`](Self::run_gemm_isa)).
    fn run_gemm_batch_isa(
        &self,
        isa: Isa,
        x: &FeatureBatch,
        scratch: &mut Scratch,
        out: &mut FeatureBatch,
    ) {
        self.check_batch_shapes(x, out);
        let n = x.n;
        let cout = self.params.cout;
        let buf = scratch.ensure(self.scratch_floats_gemm_batch(n));
        let (slab_area, rest) = buf.split_at_mut(self.slab_floats);
        let (phase_area, patch_area) = rest.split_at_mut(n * self.max_phase_floats());
        for pp in &self.phases {
            self.stack_phase_patches(pp, x, slab_area, patch_area);
            let phase = &mut phase_area[..n * pp.phase_len];
            phase.fill(0.0);
            gemm::gemm_packed_isa(
                isa,
                &patch_area[..n * pp.patch_len],
                &pp.packed_kernel,
                phase,
                n * pp.geom.n_rows * pp.geom.n_cols,
                pp.gemm_k,
                cout,
            );
            for i in 0..n {
                scatter_rows_view(
                    out.image_mut(i),
                    self.out,
                    cout,
                    &phase[i * pp.phase_len..(i + 1) * pp.phase_len],
                    pp.geom.rp,
                    pp.geom.sp,
                    pp.geom.n_rows,
                    pp.geom.n_cols,
                );
            }
        }
    }

    /// Row-parallel fused batched GEMM lane: the stacked `[N·rows, K]`
    /// patch operand is built image-serially (im2col is a memcpy-bound
    /// fraction of the work), then the batch-wide GEMM drains as
    /// per-output-row jobs across `workers` pool threads, every job
    /// multiplying its contiguous patch rows by the one shared packed
    /// sub-kernel.  Bit-identical to [`run_gemm_batch`](Self::run_gemm_batch)
    /// (same microkernel per element, whatever the worker count).
    pub fn run_gemm_batch_par(
        &self,
        x: &FeatureBatch,
        scratch: &mut Scratch,
        out: &mut FeatureBatch,
        workers: usize,
    ) {
        self.run_gemm_batch_par_isa(Isa::active(), x, scratch, out, workers)
    }

    /// [`run_gemm_batch_par`](Self::run_gemm_batch_par) with the
    /// microkernel lane pinned (see [`run_gemm_isa`](Self::run_gemm_isa)).
    fn run_gemm_batch_par_isa(
        &self,
        isa: Isa,
        x: &FeatureBatch,
        scratch: &mut Scratch,
        out: &mut FeatureBatch,
        workers: usize,
    ) {
        let workers = workers.max(1);
        if workers == 1 {
            return self.run_gemm_batch_isa(isa, x, scratch, out);
        }
        self.check_batch_shapes(x, out);
        let n = x.n;
        let cout = self.params.cout;
        let buf = scratch.ensure(self.scratch_floats_gemm_batch(n));
        let (slab_area, rest) = buf.split_at_mut(self.slab_floats);
        let (phase_area, patch_area) = rest.split_at_mut(n * self.max_phase_floats());
        for pp in &self.phases {
            self.stack_phase_patches(pp, x, slab_area, patch_area);
            {
                let row_len = pp.geom.n_cols * cout;
                let patch_row_len = pp.geom.n_cols * pp.gemm_k;
                let patch: &[f32] = &patch_area[..n * pp.patch_len];
                let jobs: Vec<(&[f32], &mut [f32])> = phase_area[..n * pp.phase_len]
                    .chunks_mut(row_len)
                    .zip(patch.chunks(patch_row_len))
                    .map(|(row, prow)| (prow, row))
                    .collect();
                threadpool::parallel_drain(jobs, workers, |(prow, row)| {
                    row.fill(0.0);
                    gemm::gemm_packed_isa(
                        isa,
                        prow,
                        &pp.packed_kernel,
                        row,
                        pp.geom.n_cols,
                        pp.gemm_k,
                        cout,
                    );
                });
            }
            for i in 0..n {
                scatter_rows_view(
                    out.image_mut(i),
                    self.out,
                    cout,
                    &phase_area[i * pp.phase_len..(i + 1) * pp.phase_len],
                    pp.geom.rp,
                    pp.geom.sp,
                    pp.geom.n_rows,
                    pp.geom.n_cols,
                );
            }
        }
    }

    /// Batched fused-epilogue phase-GEMM lane (DESIGN.md
    /// §Fused-Epilogue): the stacked `[N·rows, K]` patch operand of
    /// [`run_gemm_batch`](Self::run_gemm_batch) multiplied in a single
    /// GEMM per phase, with every accumulator tile storing straight
    /// into the owning image's strided output rows
    /// (`img_rows`/`img_stride` on the [`gemm::StridedDst`]) and the
    /// epilogue folded in — the `n ×` phase region and the per-image
    /// scatter loop both disappear.
    pub fn run_gemm_fused_batch(
        &self,
        x: &FeatureBatch,
        scratch: &mut Scratch,
        out: &mut FeatureBatch,
        epi: &gemm::Epilogue<'_>,
    ) {
        self.run_gemm_fused_batch_isa(Isa::active(), x, scratch, out, epi);
    }

    /// [`run_gemm_fused_batch`](Self::run_gemm_fused_batch) with the
    /// microkernel lane pinned.
    fn run_gemm_fused_batch_isa(
        &self,
        isa: Isa,
        x: &FeatureBatch,
        scratch: &mut Scratch,
        out: &mut FeatureBatch,
        epi: &gemm::Epilogue<'_>,
    ) {
        self.check_batch_shapes(x, out);
        let n = x.n;
        let cout = self.params.cout;
        let img_stride = out.image_floats();
        let buf = scratch.ensure(self.scratch_floats_gemm_batch_fused(n));
        let (slab_area, patch_area) = buf.split_at_mut(self.slab_floats);
        for (pi, pp) in self.phases.iter().enumerate() {
            let _phase_span = trace::span("conv.phase", isa.gemm_lane_tag(), trace::NONE, pi as u32);
            self.stack_phase_patches(pp, x, slab_area, patch_area);
            let img_rows = pp.geom.n_rows * pp.geom.n_cols;
            let mut dst = self.phase_dst(pp, &mut out.data, img_rows, img_stride);
            gemm::gemm_packed_fused(
                isa,
                &patch_area[..n * pp.patch_len],
                &pp.packed_kernel,
                n * img_rows,
                pp.gemm_k,
                cout,
                &mut dst,
                epi,
            );
        }
    }

    /// Row-parallel batched fused-epilogue lane: the stacked patch is
    /// built image-serially (like
    /// [`run_gemm_batch_par`](Self::run_gemm_batch_par)), then every
    /// `(image, phase-row)` output row drains as its own fused GEMM
    /// job across the pool — each job owns a disjoint output row and
    /// its contiguous patch rows, so no post-GEMM scatter or epilogue
    /// pass exists.
    pub fn run_gemm_fused_batch_par(
        &self,
        x: &FeatureBatch,
        scratch: &mut Scratch,
        out: &mut FeatureBatch,
        workers: usize,
        epi: &gemm::Epilogue<'_>,
    ) {
        self.run_gemm_fused_batch_par_isa(Isa::active(), x, scratch, out, workers, epi);
    }

    /// [`run_gemm_fused_batch_par`](Self::run_gemm_fused_batch_par)
    /// with the microkernel lane pinned.
    fn run_gemm_fused_batch_par_isa(
        &self,
        isa: Isa,
        x: &FeatureBatch,
        scratch: &mut Scratch,
        out: &mut FeatureBatch,
        workers: usize,
        epi: &gemm::Epilogue<'_>,
    ) {
        let workers = workers.max(1);
        if workers == 1 {
            return self.run_gemm_fused_batch_isa(isa, x, scratch, out, epi);
        }
        self.check_batch_shapes(x, out);
        let n = x.n;
        let cout = self.params.cout;
        let out_h = self.out;
        let row_floats = out_h * cout;
        let buf = scratch.ensure(self.scratch_floats_gemm_batch_fused(n));
        let (slab_area, patch_area) = buf.split_at_mut(self.slab_floats);
        for pp in &self.phases {
            self.stack_phase_patches(pp, x, slab_area, patch_area);
            let patch_row_len = pp.geom.n_cols * pp.gemm_k;
            if patch_row_len == 0 {
                for i in 0..n {
                    self.fused_epilogue_only_rows(pp, out.image_mut(i), workers, epi);
                }
                continue;
            }
            let patch: &[f32] = &patch_area[..n * pp.patch_len];
            // Global output row `g` belongs to image `g / out_h` at
            // height `y = g % out_h`; the phase owns it iff `y` sits on
            // its parity grid within the phase's row count.
            let jobs: Vec<(&[f32], &mut [f32])> = out
                .data
                .chunks_mut(row_floats)
                .enumerate()
                .filter_map(|(g, row)| {
                    let (i, y) = (g / out_h, g % out_h);
                    if y < pp.geom.rp || (y - pp.geom.rp) % 2 != 0 {
                        return None;
                    }
                    let ri = (y - pp.geom.rp) / 2;
                    if ri >= pp.geom.n_rows {
                        return None;
                    }
                    let pr = i * pp.geom.n_rows + ri;
                    Some((&patch[pr * patch_row_len..(pr + 1) * patch_row_len], row))
                })
                .collect();
            threadpool::parallel_drain(jobs, workers, |(prow, row)| {
                let mut dst = self.phase_row_dst(pp, row);
                gemm::gemm_packed_fused(
                    isa,
                    prow,
                    &pp.packed_kernel,
                    pp.geom.n_cols,
                    pp.gemm_k,
                    cout,
                    &mut dst,
                    epi,
                );
            });
        }
    }

    /// Serial batched quantized fused-epilogue lane: the stacked
    /// quantized GEMM of
    /// [`run_gemm_quant_batch_isa`](Self::run_gemm_quant_batch_isa)
    /// (batch-wide int8 activation scale) storing straight into every
    /// image's strided rows with the dequant scale folded into the
    /// epilogue.
    fn run_gemm_fused_quant_batch(
        &self,
        precision: Precision,
        x: &FeatureBatch,
        scratch: &mut Scratch,
        out: &mut FeatureBatch,
        epi: &gemm::Epilogue<'_>,
    ) {
        self.check_batch_shapes(x, out);
        let n = x.n;
        let cout = self.params.cout;
        let img_stride = out.image_floats();
        let (q16_n, q8_n) = quant_elem_split(precision, self.quant_patch_elems_batch(n));
        let (buf, q16, q8) =
            scratch.ensure_quant(self.scratch_floats_gemm_batch_fused(n), q16_n, q8_n);
        let (slab_area, patch_area) = buf.split_at_mut(self.slab_floats);
        for (pi, pp) in self.phases.iter().enumerate() {
            let _phase_span = trace::span("conv.phase", precision.name(), trace::NONE, pi as u32);
            self.stack_phase_patches(pp, x, slab_area, patch_area);
            let patch = &patch_area[..n * pp.patch_len];
            let img_rows = pp.geom.n_rows * pp.geom.n_cols;
            let m = n * img_rows;
            let mut dst = self.phase_dst(pp, &mut out.data, img_rows, img_stride);
            match precision {
                Precision::F16 => {
                    let qa = &mut q16[..n * pp.patch_len];
                    quant::quantize_f16(patch, qa);
                    gemm::gemm_packed_q16_fused(
                        precision,
                        qa,
                        &pp.qpanel_f16,
                        m,
                        pp.gemm_k,
                        cout,
                        &mut dst,
                        epi,
                    );
                }
                Precision::Bf16 => {
                    let qa = &mut q16[..n * pp.patch_len];
                    quant::quantize_bf16(patch, qa);
                    gemm::gemm_packed_q16_fused(
                        precision,
                        qa,
                        &pp.qpanel_bf16,
                        m,
                        pp.gemm_k,
                        cout,
                        &mut dst,
                        epi,
                    );
                }
                Precision::Int8 => {
                    let qa = &mut q8[..n * pp.patch_len];
                    let a_scale = quant::int8_scale(quant::absmax(patch));
                    quant::quantize_i8(patch, a_scale, qa);
                    gemm::gemm_packed_q8_fused(
                        qa,
                        a_scale,
                        &pp.qpanel_i8,
                        &pp.qscale_i8,
                        m,
                        pp.gemm_k,
                        cout,
                        &mut dst,
                        epi,
                    );
                }
                Precision::F32 => unreachable!("f32 dispatches the exact fused GEMM lane"),
            }
        }
    }

    /// Row-parallel batched quantized fused-epilogue lane: stacked
    /// patch built image-serially, then every `(image, phase-row)`
    /// output row quantizes its own patch rows (per-row int8 scales,
    /// like
    /// [`run_gemm_quant_batch_par_isa`](Self::run_gemm_quant_batch_par_isa))
    /// and stores its widening GEMM straight into the strided output.
    #[allow(clippy::too_many_arguments)]
    fn run_gemm_fused_quant_batch_par(
        &self,
        precision: Precision,
        x: &FeatureBatch,
        scratch: &mut Scratch,
        out: &mut FeatureBatch,
        workers: usize,
        epi: &gemm::Epilogue<'_>,
    ) {
        let workers = workers.max(1);
        if workers == 1 {
            return self.run_gemm_fused_quant_batch(precision, x, scratch, out, epi);
        }
        self.check_batch_shapes(x, out);
        let n = x.n;
        let cout = self.params.cout;
        let out_h = self.out;
        let row_floats = out_h * cout;
        let (q16_n, q8_n) = quant_elem_split(precision, self.quant_patch_elems_batch(n));
        let (buf, q16, q8) =
            scratch.ensure_quant(self.scratch_floats_gemm_batch_fused(n), q16_n, q8_n);
        let (slab_area, patch_area) = buf.split_at_mut(self.slab_floats);
        for pp in &self.phases {
            self.stack_phase_patches(pp, x, slab_area, patch_area);
            let patch_row_len = pp.geom.n_cols * pp.gemm_k;
            if patch_row_len == 0 {
                for i in 0..n {
                    self.fused_epilogue_only_rows(pp, out.image_mut(i), workers, epi);
                }
                continue;
            }
            let patch: &[f32] = &patch_area[..n * pp.patch_len];
            // The filtered row walk visits `pr = i·n_rows + ri` in
            // strictly increasing order (images ascend, rows within an
            // image ascend), so zipping with the in-order quantized row
            // chunks keeps every job's arena slice aligned to its rows.
            match precision {
                Precision::F16 | Precision::Bf16 => {
                    let panel: &[u16] = if precision == Precision::F16 {
                        &pp.qpanel_f16
                    } else {
                        &pp.qpanel_bf16
                    };
                    let jobs: Vec<(&[f32], &mut [u16], &mut [f32])> = out
                        .data
                        .chunks_mut(row_floats)
                        .enumerate()
                        .filter_map(|(g, row)| {
                            let (i, y) = (g / out_h, g % out_h);
                            if y < pp.geom.rp || (y - pp.geom.rp) % 2 != 0 {
                                return None;
                            }
                            let ri = (y - pp.geom.rp) / 2;
                            if ri >= pp.geom.n_rows {
                                return None;
                            }
                            let pr = i * pp.geom.n_rows + ri;
                            Some((pr, row))
                        })
                        .zip(q16[..n * pp.patch_len].chunks_mut(patch_row_len))
                        .map(|((pr, row), qrow)| {
                            (
                                &patch[pr * patch_row_len..(pr + 1) * patch_row_len],
                                qrow,
                                row,
                            )
                        })
                        .collect();
                    threadpool::parallel_drain(jobs, workers, |(prow, qrow, row)| {
                        if precision == Precision::F16 {
                            quant::quantize_f16(prow, qrow);
                        } else {
                            quant::quantize_bf16(prow, qrow);
                        }
                        let mut dst = self.phase_row_dst(pp, row);
                        gemm::gemm_packed_q16_fused(
                            precision,
                            qrow,
                            panel,
                            pp.geom.n_cols,
                            pp.gemm_k,
                            cout,
                            &mut dst,
                            epi,
                        );
                    });
                }
                Precision::Int8 => {
                    let jobs: Vec<(&[f32], &mut [i8], &mut [f32])> = out
                        .data
                        .chunks_mut(row_floats)
                        .enumerate()
                        .filter_map(|(g, row)| {
                            let (i, y) = (g / out_h, g % out_h);
                            if y < pp.geom.rp || (y - pp.geom.rp) % 2 != 0 {
                                return None;
                            }
                            let ri = (y - pp.geom.rp) / 2;
                            if ri >= pp.geom.n_rows {
                                return None;
                            }
                            let pr = i * pp.geom.n_rows + ri;
                            Some((pr, row))
                        })
                        .zip(q8[..n * pp.patch_len].chunks_mut(patch_row_len))
                        .map(|((pr, row), qrow)| {
                            (
                                &patch[pr * patch_row_len..(pr + 1) * patch_row_len],
                                qrow,
                                row,
                            )
                        })
                        .collect();
                    threadpool::parallel_drain(jobs, workers, |(prow, qrow, row)| {
                        let a_scale = quant::int8_scale(quant::absmax(prow));
                        quant::quantize_i8(prow, a_scale, qrow);
                        let mut dst = self.phase_row_dst(pp, row);
                        gemm::gemm_packed_q8_fused(
                            qrow,
                            a_scale,
                            &pp.qpanel_i8,
                            &pp.qscale_i8,
                            pp.geom.n_cols,
                            pp.gemm_k,
                            cout,
                            &mut dst,
                            epi,
                        );
                    });
                }
                Precision::F32 => unreachable!("f32 dispatches the exact fused GEMM lane"),
            }
        }
    }

    /// Execute a whole batch under an [`ExecStrategy`], **fused**: the
    /// batched analogue of [`run_with`](Self::run_with), dispatching to
    /// [`run_batch`]/[`run_batch_par`] (direct — bit-identical to `N`
    /// per-image runs), [`run_gemm_batch`]/[`run_gemm_batch_par`]
    /// (stacked phase GEMMs — bit-identical to `N` per-image
    /// [`run_gemm`]s, 1e-4 vs the direct reference), or a per-image
    /// loop of the per-element formulation (no batch structure to
    /// exploit there).  The per-latent execution of a strategy is the
    /// caller's loop over [`run_with`] — that is the serving A/B lane.
    /// Quantized GEMM strategies dispatch the fused quantized lanes
    /// (stacked widening GEMMs; batch-wide int8 activation scales).
    ///
    /// [`run_batch`]: Self::run_batch
    /// [`run_batch_par`]: Self::run_batch_par
    /// [`run_gemm_batch`]: Self::run_gemm_batch
    /// [`run_gemm_batch_par`]: Self::run_gemm_batch_par
    /// [`run_gemm`]: Self::run_gemm
    /// [`run_with`]: Self::run_with
    pub fn run_batch_with(
        &self,
        strategy: &ExecStrategy,
        x: &FeatureBatch,
        scratch: &mut Scratch,
        out: &mut FeatureBatch,
    ) {
        let _span = trace::span("conv.forward_batch", strategy.lane_tag(), trace::NONE, trace::NONE);
        match strategy.formulation {
            Formulation::PhaseDecomposed => {
                if strategy.workers <= 1 {
                    self.run_batch(x, scratch, out);
                } else {
                    self.run_batch_par(x, scratch, out, strategy.workers);
                }
            }
            Formulation::PhaseGemm => {
                if strategy.epilogue == EpilogueMode::Fused {
                    self.dispatch_gemm_fused_batch(strategy, x, scratch, out, &gemm::Epilogue::none());
                } else if strategy.precision.is_quantized() {
                    if strategy.workers <= 1 {
                        self.run_gemm_quant_batch_isa(strategy.isa, strategy.precision, x, scratch, out);
                    } else {
                        self.run_gemm_quant_batch_par_isa(
                            strategy.isa,
                            strategy.precision,
                            x,
                            scratch,
                            out,
                            strategy.workers,
                        );
                    }
                } else if strategy.workers <= 1 {
                    self.run_gemm_batch_isa(strategy.isa, x, scratch, out);
                } else {
                    self.run_gemm_batch_par_isa(strategy.isa, x, scratch, out, strategy.workers);
                }
            }
            Formulation::PerElement => {
                self.check_batch_shapes(x, out);
                for i in 0..x.n {
                    let xi = x.feature(i);
                    let got = if strategy.workers <= 1 {
                        super::unified::transpose_conv_per_element_seg(
                            &xi,
                            &self.seg,
                            self.params.padding,
                        )
                    } else {
                        super::parallel::unified_per_element_par(
                            &xi,
                            &self.seg,
                            self.params.padding,
                            strategy.workers,
                        )
                    };
                    out.image_mut(i).copy_from_slice(&got.data);
                }
            }
        }
    }

    /// Execute under an autotuned [`ExecStrategy`]
    /// (`tune::space`, DESIGN.md §Autotuning): dispatches to [`run`],
    /// [`run_par`] (phase×row axis), [`run_par_rows`], the
    /// per-element formulation of Algorithm 2, or the planned
    /// phase-GEMM engine ([`run_gemm`]/[`run_gemm_par_rows`]).  The
    /// direct strategies are bit-identical to [`run`] — same in-range
    /// contributions accumulated in the same (tap-row, tap-col,
    /// channel) order — which the equivalence property in
    /// `tests/conv_properties.rs` pins with `==`; the
    /// [`Formulation::PhaseGemm`] strategies reassociate f32 sums
    /// through the register tile and are pinned within 1e-4 instead.
    /// Quantized GEMM strategies ([`ExecStrategy::precision`], DESIGN.md
    /// §Reduced-Precision) dispatch the widening lanes and are pinned
    /// to the per-precision drift bounds.
    pub fn run_with(
        &self,
        strategy: &ExecStrategy,
        x: &Feature,
        scratch: &mut Scratch,
        out: &mut Feature,
    ) {
        let _span = trace::span("conv.forward", strategy.lane_tag(), trace::NONE, trace::NONE);
        match strategy.formulation {
            Formulation::PhaseDecomposed => {
                if strategy.workers <= 1 {
                    self.run(x, scratch, out);
                } else {
                    match strategy.axis {
                        ParAxis::PhaseRows => self.run_par(x, scratch, out, strategy.workers),
                        ParAxis::Rows => self.run_par_rows(x, scratch, out, strategy.workers),
                    }
                }
            }
            Formulation::PhaseGemm => {
                if strategy.epilogue == EpilogueMode::Fused {
                    self.dispatch_gemm_fused(strategy, x, scratch, out, &gemm::Epilogue::none());
                } else if strategy.precision.is_quantized() {
                    if strategy.workers <= 1 {
                        self.run_gemm_quant_isa(strategy.isa, strategy.precision, x, scratch, out);
                    } else {
                        self.run_gemm_quant_par_rows_isa(
                            strategy.isa,
                            strategy.precision,
                            x,
                            scratch,
                            out,
                            strategy.workers,
                        );
                    }
                } else if strategy.workers <= 1 {
                    self.run_gemm_isa(strategy.isa, x, scratch, out);
                } else {
                    self.run_gemm_par_rows_isa(strategy.isa, x, scratch, out, strategy.workers);
                }
            }
            Formulation::PerElement => {
                self.check_shapes(x, out);
                let got = if strategy.workers <= 1 {
                    super::unified::transpose_conv_per_element_seg(
                        x,
                        &self.seg,
                        self.params.padding,
                    )
                } else {
                    super::parallel::unified_per_element_par(
                        x,
                        &self.seg,
                        self.params.padding,
                        strategy.workers,
                    )
                };
                out.data.copy_from_slice(&got.data);
            }
        }
    }

    /// Dispatch the fused-epilogue GEMM lane family for `strategy`
    /// (single image): precision picks the exact or widening fused
    /// drivers, workers pick serial vs row-parallel.  The epilogue is
    /// the caller's — strategy measurement and [`run_with`](Self::run_with)
    /// pass the neutral epilogue, serving passes the layer's bias +
    /// activation.
    fn dispatch_gemm_fused(
        &self,
        strategy: &ExecStrategy,
        x: &Feature,
        scratch: &mut Scratch,
        out: &mut Feature,
        epi: &gemm::Epilogue<'_>,
    ) {
        if strategy.precision.is_quantized() {
            if strategy.workers <= 1 {
                self.run_gemm_fused_quant(strategy.precision, x, scratch, out, epi);
            } else {
                self.run_gemm_fused_quant_par_rows(
                    strategy.precision,
                    x,
                    scratch,
                    out,
                    strategy.workers,
                    epi,
                );
            }
        } else if strategy.workers <= 1 {
            self.run_gemm_fused_isa(strategy.isa, x, scratch, out, epi);
        } else {
            self.run_gemm_fused_par_rows_isa(strategy.isa, x, scratch, out, strategy.workers, epi);
        }
    }

    /// Batched analogue of
    /// [`dispatch_gemm_fused`](Self::dispatch_gemm_fused).
    fn dispatch_gemm_fused_batch(
        &self,
        strategy: &ExecStrategy,
        x: &FeatureBatch,
        scratch: &mut Scratch,
        out: &mut FeatureBatch,
        epi: &gemm::Epilogue<'_>,
    ) {
        if strategy.precision.is_quantized() {
            if strategy.workers <= 1 {
                self.run_gemm_fused_quant_batch(strategy.precision, x, scratch, out, epi);
            } else {
                self.run_gemm_fused_quant_batch_par(
                    strategy.precision,
                    x,
                    scratch,
                    out,
                    strategy.workers,
                    epi,
                );
            }
        } else if strategy.workers <= 1 {
            self.run_gemm_fused_batch_isa(strategy.isa, x, scratch, out, epi);
        } else {
            self.run_gemm_fused_batch_par_isa(strategy.isa, x, scratch, out, strategy.workers, epi);
        }
    }

    /// Execute under `strategy` with the layer epilogue (per-channel
    /// bias + activation) owned by the plan (DESIGN.md
    /// §Fused-Epilogue).  Fused-epilogue GEMM strategies store `epi`
    /// in-register on the way to the strided output; every other
    /// strategy runs exactly as [`run_with`](Self::run_with) followed
    /// by a separate epilogue pass over the output (a no-op when `epi`
    /// is neutral).  `run_with` itself executes fused-epilogue
    /// strategies with the **neutral** epilogue, so the two entry
    /// points agree on what a strategy computes — callers that apply
    /// their own epilogue keep calling `run_with` unchanged.
    pub fn run_with_epilogue(
        &self,
        strategy: &ExecStrategy,
        x: &Feature,
        scratch: &mut Scratch,
        out: &mut Feature,
        epi: &gemm::Epilogue<'_>,
    ) {
        if strategy.formulation == Formulation::PhaseGemm
            && strategy.epilogue == EpilogueMode::Fused
        {
            let _span = trace::span("conv.forward", strategy.lane_tag(), trace::NONE, trace::NONE);
            self.dispatch_gemm_fused(strategy, x, scratch, out, epi);
        } else {
            self.run_with(strategy, x, scratch, out);
            apply_epilogue_slice(&mut out.data, epi);
        }
    }

    /// Batched analogue of
    /// [`run_with_epilogue`](Self::run_with_epilogue): the fused
    /// batched dispatch of [`run_batch_with`](Self::run_batch_with)
    /// with the epilogue owned by the plan.
    pub fn run_batch_with_epilogue(
        &self,
        strategy: &ExecStrategy,
        x: &FeatureBatch,
        scratch: &mut Scratch,
        out: &mut FeatureBatch,
        epi: &gemm::Epilogue<'_>,
    ) {
        if strategy.formulation == Formulation::PhaseGemm
            && strategy.epilogue == EpilogueMode::Fused
        {
            let _span =
                trace::span("conv.forward_batch", strategy.lane_tag(), trace::NONE, trace::NONE);
            self.dispatch_gemm_fused_batch(strategy, x, scratch, out, epi);
        } else {
            self.run_batch_with(strategy, x, scratch, out);
            apply_epilogue_slice(&mut out.data, epi);
        }
    }

    // ------------------------------------------------- backward lanes

    /// Exact scratch floats of the direct backward-data lanes
    /// ([`run_backward_data`](Self::run_backward_data) /
    /// [`run_backward_data_par`](Self::run_backward_data_par)): the
    /// slab-gradient area (reusing the forward slab layout) plus the
    /// padded dy-phase area.
    pub fn scratch_floats_backward_data(&self) -> usize {
        self.slab_floats + self.pad_floats
    }

    /// Exact scratch floats of the GEMM backward-data lane
    /// ([`run_backward_data_gemm`](Self::run_backward_data_gemm)): the
    /// direct figure plus the shared backward im2col patch region
    /// (max over phases).
    pub fn scratch_floats_backward_data_gemm(&self) -> usize {
        self.scratch_floats_backward_data() + self.patch_bwd_floats
    }

    /// Exact scratch floats one backward-data execution of `strategy`
    /// needs (the backward analogue of
    /// [`scratch_floats_for`](Self::scratch_floats_for)).
    pub fn scratch_floats_backward_for(&self, strategy: &ExecStrategy) -> usize {
        match strategy.formulation {
            Formulation::PhaseGemm => self.scratch_floats_backward_data_gemm(),
            _ => self.scratch_floats_backward_data(),
        }
    }

    /// Exact scratch floats of the weight-grad phase GEMM
    /// ([`run_backward_weights`](Self::run_backward_weights), single or
    /// batched — the batch accumulates through the same regions):
    /// slabs | dy phases | patchᵀ | runtime-packed dy panel | per-phase
    /// dSub accumulators.
    pub fn scratch_floats_backward_weights(&self) -> usize {
        self.slab_floats
            + self.phase_floats
            + self.patch_floats
            + self.packed_dy_floats
            + self.dsub_floats
    }

    /// Exact scratch floats of the **fused** backward lanes
    /// ([`run_backward`](Self::run_backward) /
    /// [`run_backward_with`](Self::run_backward_with) /
    /// [`run_backward_batch`](Self::run_backward_batch)), which produce
    /// both gradients in one pass, extracting each `dy` phase **once**:
    /// slabs (x-slab, then reused as the dslab area) | dense dy phases
    /// | padded dy frames | one shared im2col patch region (max of the
    /// forward-patch and backward-patch claims — the weight GEMM has
    /// consumed the patch before the data GEMM refills it) |
    /// runtime-packed dy panel | per-phase dSub accumulators.
    pub fn scratch_floats_backward_fused(&self) -> usize {
        self.slab_floats
            + self.phase_floats
            + self.pad_floats
            + self.patch_floats.max(self.patch_bwd_floats)
            + self.packed_dy_floats
            + self.dsub_floats
    }

    /// Worst-case scratch floats any backward lane of this plan can
    /// demand — what training arenas are sized to.
    pub fn peak_scratch_floats_backward(&self) -> usize {
        self.scratch_floats_backward_data_gemm()
            .max(self.scratch_floats_backward_weights())
            .max(self.scratch_floats_backward_fused())
    }

    fn check_backward_shapes(&self, dy: &Feature, dx: &Feature) {
        assert_eq!(
            (dy.h, dy.w, dy.c),
            (self.out, self.out, self.params.cout),
            "plan: dy shape mismatch"
        );
        assert_eq!(
            (dx.h, dx.w, dx.c),
            (self.params.n_in, self.params.n_in, self.params.cin),
            "plan: dx shape mismatch"
        );
    }

    fn check_backward_batch_shapes(&self, dy: &FeatureBatch, dx: &FeatureBatch) {
        assert_eq!(dy.n, dx.n, "plan: batch size mismatch");
        assert_eq!(
            (dy.h, dy.w, dy.c),
            (self.out, self.out, self.params.cout),
            "plan: dy shape mismatch"
        );
        assert_eq!(
            (dx.h, dx.w, dx.c),
            (self.params.n_in, self.params.n_in, self.params.cin),
            "plan: dx shape mismatch"
        );
    }

    /// Write phase `(rp, sp)` of `dy` into its zero-filled padded frame
    /// at interior offset `(sub.rows-1, sub.cols-1)` — the frame the
    /// full correlation runs VALID over.  Produces exactly the values
    /// of the one-shot route's `extract_output_phase` + `pad_asym`,
    /// without the intermediate buffer.
    fn fill_pad_phase(&self, pp: &PhasePlan, dy: &[f32], pad: &mut [f32]) {
        let cout = self.params.cout;
        let (sr, sc) = (pp.flipped.rows, pp.flipped.cols);
        pad.fill(0.0);
        for (py, y) in (pp.geom.rp..self.out)
            .step_by(2)
            .enumerate()
            .take(pp.geom.n_rows)
        {
            for (px, x) in (pp.geom.sp..self.out)
                .step_by(2)
                .enumerate()
                .take(pp.geom.n_cols)
            {
                let src = (y * self.out + x) * cout;
                let dst = ((py + sr - 1) * pp.pad_w + (px + sc - 1)) * cout;
                pad[dst..dst + cout].copy_from_slice(&dy[src..src + cout]);
            }
        }
    }

    /// [`fill_pad_phase`](Self::fill_pad_phase) from an
    /// already-extracted dense phase
    /// ([`fill_phase_dense`](Self::fill_phase_dense)): one contiguous
    /// `n_cols·Cout` row copy per phase row instead of re-striding `dy`
    /// pixel by pixel — the sharing step of the fused backward.
    /// Byte-identical output to `fill_pad_phase` (same values into the
    /// same frame positions), so the fused direct data-grad stays
    /// bit-identical to [`run_backward_data`](Self::run_backward_data).
    fn fill_pad_from_dense(&self, pp: &PhasePlan, dyp: &[f32], pad: &mut [f32]) {
        let cout = self.params.cout;
        let (sr, sc) = (pp.flipped.rows, pp.flipped.cols);
        let row = pp.geom.n_cols * cout;
        pad.fill(0.0);
        for py in 0..pp.geom.n_rows {
            let dst = ((py + sr - 1) * pp.pad_w + (sc - 1)) * cout;
            pad[dst..dst + row].copy_from_slice(&dyp[py * row..(py + 1) * row]);
        }
    }

    /// Write phase `(rp, sp)` of `dy` densely (`[n_rows·n_cols, Cout]`
    /// row-major) — the weight-grad GEMM's B operand before packing.
    fn fill_phase_dense(&self, pp: &PhasePlan, dy: &[f32], dst: &mut [f32]) {
        let cout = self.params.cout;
        for (py, y) in (pp.geom.rp..self.out)
            .step_by(2)
            .enumerate()
            .take(pp.geom.n_rows)
        {
            for (px, x) in (pp.geom.sp..self.out)
                .step_by(2)
                .enumerate()
                .take(pp.geom.n_cols)
            {
                let src = (y * self.out + x) * cout;
                let d = (py * pp.geom.n_cols + px) * cout;
                dst[d..d + cout].copy_from_slice(&dy[src..src + cout]);
            }
        }
    }

    /// Adjoint of the forward slab crop: accumulate one phase's slab
    /// gradient into `dx`, dropping positions that fell in zero
    /// padding.  Phases **overlap** in `dx` (unlike the forward scatter,
    /// which partitions the output), so callers zero `dx` once and
    /// every phase adds.
    fn accumulate_dslab(&self, pp: &PhasePlan, dslab: &[f32], dx: &mut [f32]) {
        let n = self.params.n_in as isize;
        let cin = self.params.cin;
        let (pt, _, pl, _) = pp.geom.pads;
        for sy in 0..pp.slab_h {
            let iy = (pp.geom.rows.0 + sy) as isize - pt as isize;
            if iy < 0 || iy >= n {
                continue;
            }
            for sx in 0..pp.slab_w {
                let ix = (pp.geom.cols.0 + sx) as isize - pl as isize;
                if ix < 0 || ix >= n {
                    continue;
                }
                let src = (sy * pp.slab_w + sx) * cin;
                let dst = ((iy as usize) * self.params.n_in + ix as usize) * cin;
                for ci in 0..cin {
                    dx[dst + ci] += dslab[src + ci];
                }
            }
        }
    }

    /// Serial direct backward-data core over raw views (`buf` laid out
    /// as [`scratch_floats_backward_data`](Self::scratch_floats_backward_data):
    /// dslabs | pads).
    fn backward_data_image(&self, dy: &[f32], buf: &mut [f32], dx: &mut [f32]) {
        dx.fill(0.0);
        let (dslab_area, pad_area) = buf.split_at_mut(self.slab_floats);
        for pp in &self.phases {
            let pad = &mut pad_area[pp.pad_off..pp.pad_off + pp.pad_len];
            self.fill_pad_phase(pp, dy, pad);
            let dslab = &mut dslab_area[pp.slab_off..pp.slab_off + pp.slab_len];
            dslab.fill(0.0);
            correlate_rows(pad, pp.pad_w, &pp.flipped, dslab, pp.slab_w, 0, pp.slab_h);
            self.accumulate_dslab(pp, dslab, dx);
        }
    }

    /// GEMM backward-data core: the padded dy phase is im2col'ed and
    /// multiplied by the flipped sub-kernel packed at construction.
    fn backward_data_gemm_image(&self, isa: Isa, dy: &[f32], buf: &mut [f32], dx: &mut [f32]) {
        dx.fill(0.0);
        let cin = self.params.cin;
        let cout = self.params.cout;
        let (dslab_area, rest) = buf.split_at_mut(self.slab_floats);
        let (pad_area, patch_area) = rest.split_at_mut(self.pad_floats);
        for pp in &self.phases {
            let pad = &mut pad_area[pp.pad_off..pp.pad_off + pp.pad_len];
            self.fill_pad_phase(pp, dy, pad);
            let patch = &mut patch_area[..pp.patch_bwd_len];
            gemm::im2col_rows(
                pad,
                pp.pad_w,
                cout,
                pp.flipped.rows,
                pp.flipped.cols,
                pp.slab_w,
                0,
                pp.slab_h,
                patch,
            );
            let dslab = &mut dslab_area[pp.slab_off..pp.slab_off + pp.slab_len];
            dslab.fill(0.0);
            gemm::gemm_packed_isa(
                isa,
                patch,
                &pp.packed_flip,
                dslab,
                pp.slab_h * pp.slab_w,
                pp.gemm_k_bwd,
                cin,
            );
            self.accumulate_dslab(pp, dslab, dx);
        }
    }

    /// Parallel direct backward-data core: pads built serially, then
    /// one `(phase, slab-row)` job queue drained across `workers` pool
    /// threads (each job correlates into its disjoint dslab row), then
    /// a serial accumulate into `dx` (phases overlap there).
    fn backward_data_par_image(&self, dy: &[f32], buf: &mut [f32], dx: &mut [f32], workers: usize) {
        let cin = self.params.cin;
        {
            let (dslab_area, pad_area) = buf.split_at_mut(self.slab_floats);
            for pp in &self.phases {
                self.fill_pad_phase(pp, dy, &mut pad_area[pp.pad_off..pp.pad_off + pp.pad_len]);
            }
            let pad_area: &[f32] = pad_area;
            let mut jobs: Vec<(usize, usize, &mut [f32])> = Vec::new();
            let mut rest: &mut [f32] = dslab_area;
            for (pi, pp) in self.phases.iter().enumerate() {
                let (mine, tail) = rest.split_at_mut(pp.slab_len);
                rest = tail;
                let row_len = pp.slab_w * cin;
                for (ri, row) in mine.chunks_mut(row_len).enumerate() {
                    jobs.push((pi, ri, row));
                }
            }
            threadpool::parallel_drain(jobs, workers, |(pi, ri, row)| {
                let pp = &self.phases[pi];
                row.fill(0.0);
                correlate_rows(
                    &pad_area[pp.pad_off..pp.pad_off + pp.pad_len],
                    pp.pad_w,
                    &pp.flipped,
                    row,
                    pp.slab_w,
                    ri,
                    ri + 1,
                );
            });
        }
        dx.fill(0.0);
        let dslab_area = &buf[..self.slab_floats];
        for pp in &self.phases {
            self.accumulate_dslab(pp, &dslab_area[pp.slab_off..pp.slab_off + pp.slab_len], dx);
        }
    }

    /// Gradient w.r.t. the layer input, planned direct route: per
    /// phase, full-correlate the dy phase against the flipped
    /// sub-kernel frozen at construction (no upsampled-gradient buffer)
    /// and accumulate the slab gradient into `dx` through the adjoint
    /// of the slab crop.  Bit-identical to
    /// [`backward::grad_input_unified`](super::backward::grad_input_unified)
    /// — same values, same f32 accumulation order — and zero-alloc in
    /// steady state like the forward lanes.
    pub fn run_backward_data(&self, dy: &Feature, scratch: &mut Scratch, dx: &mut Feature) {
        self.check_backward_shapes(dy, dx);
        let buf = scratch.ensure(self.scratch_floats_backward_data());
        self.backward_data_image(&dy.data, buf, &mut dx.data);
    }

    /// Gradient w.r.t. the layer input through the phase-GEMM engine:
    /// the padded dy phase is im2col'ed into the arena and multiplied
    /// by the flipped sub-kernel packed at construction.  Within 1e-4
    /// of [`run_backward_data`](Self::run_backward_data) (the same f32
    /// reassociation contract as the forward GEMM lanes).
    pub fn run_backward_data_gemm(&self, dy: &Feature, scratch: &mut Scratch, dx: &mut Feature) {
        self.run_backward_data_gemm_isa(Isa::active(), dy, scratch, dx);
    }

    /// [`run_backward_data_gemm`](Self::run_backward_data_gemm) with
    /// the microkernel lane pinned (see [`run_gemm_isa`](Self::run_gemm_isa)).
    fn run_backward_data_gemm_isa(
        &self,
        isa: Isa,
        dy: &Feature,
        scratch: &mut Scratch,
        dx: &mut Feature,
    ) {
        self.check_backward_shapes(dy, dx);
        let buf = scratch.ensure(self.scratch_floats_backward_data_gemm());
        self.backward_data_gemm_image(isa, &dy.data, buf, &mut dx.data);
    }

    /// Parallel direct backward-data lane: `(phase, slab-row)` jobs
    /// across `workers` threads of the persistent pool; the overlap-ful
    /// accumulate into `dx` stays serial.  Bit-identical to
    /// [`run_backward_data`](Self::run_backward_data).
    pub fn run_backward_data_par(
        &self,
        dy: &Feature,
        scratch: &mut Scratch,
        dx: &mut Feature,
        workers: usize,
    ) {
        let workers = workers.max(1);
        if workers == 1 {
            return self.run_backward_data(dy, scratch, dx);
        }
        self.check_backward_shapes(dy, dx);
        let buf = scratch.ensure(self.scratch_floats_backward_data());
        self.backward_data_par_image(&dy.data, buf, &mut dx.data, workers);
    }

    /// Backward-data under an autotuned [`ExecStrategy`] (the backward
    /// search space — `tune::space::backward_search_space` — emits
    /// serial direct, row-parallel direct, and serial GEMM candidates;
    /// any other formulation falls back to the serial direct lane).
    pub fn run_backward_data_with(
        &self,
        strategy: &ExecStrategy,
        dy: &Feature,
        scratch: &mut Scratch,
        dx: &mut Feature,
    ) {
        match strategy.formulation {
            Formulation::PhaseGemm => self.run_backward_data_gemm_isa(strategy.isa, dy, scratch, dx),
            _ => {
                if strategy.workers <= 1 {
                    self.run_backward_data(dy, scratch, dx);
                } else {
                    self.run_backward_data_par(dy, scratch, dx, strategy.workers);
                }
            }
        }
    }

    /// Batched direct backward-data: the whole dy batch through **one**
    /// backward region, image by image — bit-identical to `N`
    /// sequential [`run_backward_data`](Self::run_backward_data) calls,
    /// zero-alloc in steady state.
    pub fn run_backward_data_batch(
        &self,
        dy: &FeatureBatch,
        scratch: &mut Scratch,
        dx: &mut FeatureBatch,
    ) {
        self.check_backward_batch_shapes(dy, dx);
        let buf = scratch.ensure(self.scratch_floats_backward_data());
        for i in 0..dy.n {
            self.backward_data_image(dy.image(i), buf, dx.image_mut(i));
        }
    }

    /// Batched backward-data under a strategy: each image runs the
    /// chosen single-image lane through one shared region, so the
    /// result is bit-identical to `N` sequential
    /// [`run_backward_data_with`](Self::run_backward_data_with) calls.
    pub fn run_backward_data_batch_with(
        &self,
        strategy: &ExecStrategy,
        dy: &FeatureBatch,
        scratch: &mut Scratch,
        dx: &mut FeatureBatch,
    ) {
        self.check_backward_batch_shapes(dy, dx);
        match strategy.formulation {
            Formulation::PhaseGemm => {
                let buf = scratch.ensure(self.scratch_floats_backward_data_gemm());
                for i in 0..dy.n {
                    self.backward_data_gemm_image(strategy.isa, dy.image(i), buf, dx.image_mut(i));
                }
            }
            _ if strategy.workers > 1 => {
                let buf = scratch.ensure(self.scratch_floats_backward_data());
                for i in 0..dy.n {
                    self.backward_data_par_image(dy.image(i), buf, dx.image_mut(i), strategy.workers);
                }
            }
            _ => self.run_backward_data_batch(dy, scratch, dx),
        }
    }

    /// One image's weight-grad contribution: per phase, the forward
    /// slab is im2col'ed **transposed** (`gemm::im2col_cols` — A is
    /// `[gemm_k, n_rows·n_cols]`), the dy phase is extracted densely
    /// and packed at runtime as B, and the phase GEMM accumulates
    /// (`C +=`) into the phase's dSub region — which is what makes the
    /// batched variant free: images simply keep accumulating.
    fn backward_weights_accumulate(
        &self,
        isa: Isa,
        x: &[f32],
        dy: &[f32],
        work: &mut [f32],
        dsub_area: &mut [f32],
    ) {
        let n_in = self.params.n_in;
        let cin = self.params.cin;
        let cout = self.params.cout;
        let (slab_area, rest) = work.split_at_mut(self.slab_floats);
        let (phase_area, rest) = rest.split_at_mut(self.phase_floats);
        let (patch_area, packed_area) = rest.split_at_mut(self.patch_floats);
        for pp in &self.phases {
            let slab = &mut slab_area[pp.slab_off..pp.slab_off + pp.slab_len];
            build_slab_view(x, n_in, n_in, cin, &pp.geom, slab);
            let sub = &self.seg.subs[pp.geom.sub];
            let patch = &mut patch_area[..pp.patch_len];
            gemm::im2col_cols(
                slab,
                pp.slab_w,
                cin,
                sub.rows,
                sub.cols,
                pp.geom.n_cols,
                pp.geom.n_rows,
                patch,
            );
            let dyp = &mut phase_area[pp.phase_off..pp.phase_off + pp.phase_len];
            self.fill_phase_dense(pp, dy, dyp);
            let r_total = pp.geom.n_rows * pp.geom.n_cols;
            let packed = &mut packed_area[..gemm::packed_b_floats(r_total, cout)];
            gemm::pack_b(dyp, r_total, cout, packed);
            gemm::gemm_packed_isa(
                isa,
                patch,
                packed,
                &mut dsub_area[pp.dsub_off..pp.dsub_off + pp.dsub_len],
                pp.gemm_k,
                r_total,
                cout,
            );
        }
    }

    /// Scatter the per-phase dSub accumulators into the full `dK`: each
    /// sub-kernel's taps live at `(r + 2u, s + 2v)` of the full kernel
    /// (`(r, s) = (sub/2, sub%2)`), and the phase→sub map is a parity
    /// bijection, so each tap is written exactly once.  Sub-kernels
    /// whose phase is empty (degenerate geometries) never touched any
    /// output, so their taps correctly stay zero.
    fn scatter_dsubs(&self, dsub_area: &[f32], dk: &mut Kernel) {
        dk.data.fill(0.0);
        let cin = self.params.cin;
        let cout = self.params.cout;
        for pp in &self.phases {
            let (r, s) = (pp.geom.sub / 2, pp.geom.sub % 2);
            let sub = &self.seg.subs[pp.geom.sub];
            let d = &dsub_area[pp.dsub_off..pp.dsub_off + pp.dsub_len];
            for u in 0..sub.rows {
                for v in 0..sub.cols {
                    let src = (u * sub.cols + v) * cin * cout;
                    let dst = dk.idx(r + 2 * u, s + 2 * v, 0, 0);
                    dk.data[dst..dst + cin * cout].copy_from_slice(&d[src..src + cin * cout]);
                }
            }
        }
    }

    fn check_backward_weight_shapes(&self, x_shape: (usize, usize, usize), dy_shape: (usize, usize, usize), dk: &Kernel) {
        assert_eq!(
            x_shape,
            (self.params.n_in, self.params.n_in, self.params.cin),
            "plan: input shape mismatch"
        );
        assert_eq!(
            dy_shape,
            (self.out, self.out, self.params.cout),
            "plan: dy shape mismatch"
        );
        assert_eq!(
            (dk.n, dk.cin, dk.cout),
            (self.params.n_k, self.params.cin, self.params.cout),
            "plan: dk shape mismatch"
        );
    }

    /// Gradient w.r.t. the kernel, planned route: per phase, a single
    /// GEMM `dSub = patchᵀ · dy_phase` (the forward phase GEMM with
    /// swapped operands), then one scatter into `dK`.  Within 1e-4 of
    /// [`backward::grad_kernel_unified`](super::backward::grad_kernel_unified)
    /// (the GEMM reassociates the `Σ_{oy,ox}` reduction through its
    /// register tile); zero-alloc in steady state.
    pub fn run_backward_weights(
        &self,
        x: &Feature,
        dy: &Feature,
        scratch: &mut Scratch,
        dk: &mut Kernel,
    ) {
        self.check_backward_weight_shapes((x.h, x.w, x.c), (dy.h, dy.w, dy.c), dk);
        let buf = scratch.ensure(self.scratch_floats_backward_weights());
        let work_floats =
            self.slab_floats + self.phase_floats + self.patch_floats + self.packed_dy_floats;
        let (work, dsub_area) = buf.split_at_mut(work_floats);
        dsub_area.fill(0.0);
        self.backward_weights_accumulate(Isa::active(), &x.data, &dy.data, work, dsub_area);
        self.scatter_dsubs(dsub_area, dk);
    }

    /// Batched gradient w.r.t. the kernel: every image's phase GEMM
    /// accumulates (`C +=`) into the same dSub regions, so the batch
    /// sum costs no extra memory and one final scatter produces the
    /// accumulated `dK` — equal within 1e-4 to summing `N` per-image
    /// [`run_backward_weights`](Self::run_backward_weights) results.
    pub fn run_backward_weights_batch(
        &self,
        x: &FeatureBatch,
        dy: &FeatureBatch,
        scratch: &mut Scratch,
        dk: &mut Kernel,
    ) {
        assert_eq!(x.n, dy.n, "plan: batch size mismatch");
        self.check_backward_weight_shapes((x.h, x.w, x.c), (dy.h, dy.w, dy.c), dk);
        let buf = scratch.ensure(self.scratch_floats_backward_weights());
        let work_floats =
            self.slab_floats + self.phase_floats + self.patch_floats + self.packed_dy_floats;
        let (work, dsub_area) = buf.split_at_mut(work_floats);
        dsub_area.fill(0.0);
        for i in 0..x.n {
            self.backward_weights_accumulate(Isa::active(), x.image(i), dy.image(i), work, dsub_area);
        }
        self.scatter_dsubs(dsub_area, dk);
    }

    /// Fused backward core: both gradients of one image in a single
    /// pass over the phases, extracting each `dy` phase **once** (the
    /// unfused route — [`run_backward_data`](Self::run_backward_data)
    /// then [`run_backward_weights`](Self::run_backward_weights) —
    /// re-extracts every phase from `dy` twice, striding the full
    /// output map both times).
    ///
    /// Pass A (per phase): build the x-slab, im2col it transposed for
    /// the weight GEMM, extract the dense dy phase once, pack it, run
    /// the weight GEMM into the phase's dSub accumulator (`C +=`, so
    /// batches accumulate for free), and build the padded dy frame from
    /// the *dense* phase by contiguous row copies
    /// ([`fill_pad_from_dense`](Self::fill_pad_from_dense)).
    ///
    /// Pass B: the data gradient from the shared pads — by then every
    /// x-slab has been consumed into its patch, so the slab area is
    /// reused as the dslab area (`slab_len` is the same quantity in
    /// both roles).  The lane is the strategy's: serial direct
    /// (bit-identical to `run_backward_data`), `(phase, slab-row)`
    /// parallel direct, or the phase GEMM on `strategy.isa`.
    ///
    /// `buf` is laid out per
    /// [`scratch_floats_backward_fused`](Self::scratch_floats_backward_fused)
    /// minus the trailing dSub area, which persists across batch images
    /// and is passed separately.
    fn backward_fused_image(
        &self,
        strategy: &ExecStrategy,
        x: &[f32],
        dy: &[f32],
        buf: &mut [f32],
        dx: &mut [f32],
        dsub_area: &mut [f32],
    ) {
        let n_in = self.params.n_in;
        let cin = self.params.cin;
        let cout = self.params.cout;
        // The weight grad is always a GEMM; pin its lane only when the
        // strategy actually carries a microkernel axis (PhaseGemm), so
        // a scalar-pinned candidate measures a fully scalar step.
        let wisa = if strategy.formulation == Formulation::PhaseGemm {
            strategy.isa
        } else {
            Isa::active()
        };
        let (slab_area, rest) = buf.split_at_mut(self.slab_floats);
        let (phase_area, rest) = rest.split_at_mut(self.phase_floats);
        let (pad_area, rest) = rest.split_at_mut(self.pad_floats);
        let (patch_area, packed_area) =
            rest.split_at_mut(self.patch_floats.max(self.patch_bwd_floats));
        for pp in &self.phases {
            let slab = &mut slab_area[pp.slab_off..pp.slab_off + pp.slab_len];
            build_slab_view(x, n_in, n_in, cin, &pp.geom, slab);
            let sub = &self.seg.subs[pp.geom.sub];
            let patch = &mut patch_area[..pp.patch_len];
            gemm::im2col_cols(
                slab,
                pp.slab_w,
                cin,
                sub.rows,
                sub.cols,
                pp.geom.n_cols,
                pp.geom.n_rows,
                patch,
            );
            let dyp = &mut phase_area[pp.phase_off..pp.phase_off + pp.phase_len];
            self.fill_phase_dense(pp, dy, dyp);
            let r_total = pp.geom.n_rows * pp.geom.n_cols;
            let packed = &mut packed_area[..gemm::packed_b_floats(r_total, cout)];
            gemm::pack_b(dyp, r_total, cout, packed);
            gemm::gemm_packed_isa(
                wisa,
                patch,
                packed,
                &mut dsub_area[pp.dsub_off..pp.dsub_off + pp.dsub_len],
                pp.gemm_k,
                r_total,
                cout,
            );
            self.fill_pad_from_dense(pp, dyp, &mut pad_area[pp.pad_off..pp.pad_off + pp.pad_len]);
        }
        dx.fill(0.0);
        match strategy.formulation {
            Formulation::PhaseGemm => {
                for pp in &self.phases {
                    let patch = &mut patch_area[..pp.patch_bwd_len];
                    gemm::im2col_rows(
                        &pad_area[pp.pad_off..pp.pad_off + pp.pad_len],
                        pp.pad_w,
                        cout,
                        pp.flipped.rows,
                        pp.flipped.cols,
                        pp.slab_w,
                        0,
                        pp.slab_h,
                        patch,
                    );
                    let dslab = &mut slab_area[pp.slab_off..pp.slab_off + pp.slab_len];
                    dslab.fill(0.0);
                    gemm::gemm_packed_isa(
                        strategy.isa,
                        patch,
                        &pp.packed_flip,
                        dslab,
                        pp.slab_h * pp.slab_w,
                        pp.gemm_k_bwd,
                        cin,
                    );
                    self.accumulate_dslab(pp, dslab, dx);
                }
            }
            _ if strategy.workers > 1 => {
                {
                    let pads: &[f32] = pad_area;
                    let mut jobs: Vec<(usize, usize, &mut [f32])> = Vec::new();
                    let mut rest: &mut [f32] = &mut slab_area[..];
                    for (pi, pp) in self.phases.iter().enumerate() {
                        let (mine, tail) = rest.split_at_mut(pp.slab_len);
                        rest = tail;
                        let row_len = pp.slab_w * cin;
                        for (ri, row) in mine.chunks_mut(row_len).enumerate() {
                            jobs.push((pi, ri, row));
                        }
                    }
                    threadpool::parallel_drain(jobs, strategy.workers, |(pi, ri, row)| {
                        let pp = &self.phases[pi];
                        row.fill(0.0);
                        correlate_rows(
                            &pads[pp.pad_off..pp.pad_off + pp.pad_len],
                            pp.pad_w,
                            &pp.flipped,
                            row,
                            pp.slab_w,
                            ri,
                            ri + 1,
                        );
                    });
                }
                for pp in &self.phases {
                    self.accumulate_dslab(
                        pp,
                        &slab_area[pp.slab_off..pp.slab_off + pp.slab_len],
                        dx,
                    );
                }
            }
            _ => {
                for pp in &self.phases {
                    let dslab = &mut slab_area[pp.slab_off..pp.slab_off + pp.slab_len];
                    dslab.fill(0.0);
                    correlate_rows(
                        &pad_area[pp.pad_off..pp.pad_off + pp.pad_len],
                        pp.pad_w,
                        &pp.flipped,
                        dslab,
                        pp.slab_w,
                        0,
                        pp.slab_h,
                    );
                    self.accumulate_dslab(pp, dslab, dx);
                }
            }
        }
    }

    /// Fused backward, serial: both gradients in one pass with each
    /// `dy` phase extracted once (see
    /// [`backward_fused_image`](Self::backward_fused_image)).  `dx` is
    /// bit-identical to [`run_backward_data`](Self::run_backward_data)
    /// and `dk` to [`run_backward_weights`](Self::run_backward_weights)
    /// — same lanes over the same extracted values; zero-alloc in
    /// steady state like every planned lane.
    pub fn run_backward(
        &self,
        x: &Feature,
        dy: &Feature,
        scratch: &mut Scratch,
        dx: &mut Feature,
        dk: &mut Kernel,
    ) {
        self.run_backward_with(&ExecStrategy::serial(), x, dy, scratch, dx, dk);
    }

    /// Fused backward under an autotuned [`ExecStrategy`]: the data
    /// gradient runs the strategy's lane (serial/parallel direct or
    /// phase GEMM on `strategy.isa` — the same dispatch as
    /// [`run_backward_data_with`](Self::run_backward_data_with)); the
    /// weight gradient is always the phase GEMM, on `strategy.isa` for
    /// GEMM strategies and the active lane otherwise.
    pub fn run_backward_with(
        &self,
        strategy: &ExecStrategy,
        x: &Feature,
        dy: &Feature,
        scratch: &mut Scratch,
        dx: &mut Feature,
        dk: &mut Kernel,
    ) {
        let _span = trace::span("conv.backward", strategy.lane_tag(), trace::NONE, trace::NONE);
        self.check_backward_shapes(dy, dx);
        self.check_backward_weight_shapes((x.h, x.w, x.c), (dy.h, dy.w, dy.c), dk);
        let total = self.scratch_floats_backward_fused();
        let buf = scratch.ensure(total);
        let (work, dsub_area) = buf.split_at_mut(total - self.dsub_floats);
        dsub_area.fill(0.0);
        self.backward_fused_image(strategy, &x.data, &dy.data, work, &mut dx.data, dsub_area);
        self.scatter_dsubs(dsub_area, dk);
    }

    /// Fused batched backward: per-image data gradients plus the
    /// batch-accumulated kernel gradient through **one** fused region —
    /// each image's dy phases extracted once, dSubs accumulating across
    /// the batch (`C +=`), one final scatter.  `dx` images are
    /// bit-identical to per-image [`run_backward_with`](Self::run_backward_with)
    /// calls and `dk` matches
    /// [`run_backward_weights_batch`](Self::run_backward_weights_batch).
    pub fn run_backward_batch(
        &self,
        x: &FeatureBatch,
        dy: &FeatureBatch,
        scratch: &mut Scratch,
        dx: &mut FeatureBatch,
        dk: &mut Kernel,
    ) {
        self.run_backward_batch_with(&ExecStrategy::serial(), x, dy, scratch, dx, dk);
    }

    /// [`run_backward_batch`](Self::run_backward_batch) under a
    /// strategy (see [`run_backward_with`](Self::run_backward_with)).
    pub fn run_backward_batch_with(
        &self,
        strategy: &ExecStrategy,
        x: &FeatureBatch,
        dy: &FeatureBatch,
        scratch: &mut Scratch,
        dx: &mut FeatureBatch,
        dk: &mut Kernel,
    ) {
        let _span = trace::span("conv.backward_batch", strategy.lane_tag(), trace::NONE, trace::NONE);
        assert_eq!(x.n, dy.n, "plan: batch size mismatch");
        self.check_backward_batch_shapes(dy, dx);
        self.check_backward_weight_shapes((x.h, x.w, x.c), (dy.h, dy.w, dy.c), dk);
        let total = self.scratch_floats_backward_fused();
        let buf = scratch.ensure(total);
        let (work, dsub_area) = buf.split_at_mut(total - self.dsub_floats);
        dsub_area.fill(0.0);
        for i in 0..x.n {
            self.backward_fused_image(strategy, x.image(i), dy.image(i), work, dx.image_mut(i), dsub_area);
        }
        self.scatter_dsubs(dsub_area, dk);
    }

    /// A correctly-shaped input-gradient buffer for this plan.
    pub fn new_input_grad(&self) -> Feature {
        Feature::zeros(self.params.n_in, self.params.n_in, self.params.cin)
    }

    /// A correctly-shaped kernel-gradient buffer for this plan.
    pub fn new_kernel_grad(&self) -> Kernel {
        Kernel::zeros(self.params.n_k, self.params.cin, self.params.cout)
    }
}

/// Quantized-lane arena split for one precision: `(u16 elems, i8
/// elems)` — exactly one is non-zero for a quantized precision, both
/// zero for f32 (the exact lane touches no quantized arena).
fn quant_elem_split(precision: Precision, elems: usize) -> (usize, usize) {
    match precision {
        Precision::F16 | Precision::Bf16 => (elems, 0),
        Precision::Int8 => (0, elems),
        Precision::F32 => (0, 0),
    }
}

/// The separate-epilogue pass over a raw output slice — what the
/// non-fused half of the [`ConvTransposePlan::run_with_epilogue`]
/// contract executes after the strategy runs (bias then activation,
/// matching [`gemm::Epilogue`]'s in-register order).  A no-op for the
/// neutral epilogue.
fn apply_epilogue_slice(out: &mut [f32], epi: &gemm::Epilogue<'_>) {
    if let Some(bias) = epi.bias {
        ops::add_bias_slice_inplace(out, bias);
    }
    match epi.act {
        gemm::Activation::None => {}
        gemm::Activation::Relu => ops::relu_slice_inplace(out),
        gemm::Activation::Tanh => ops::tanh_slice_inplace(out),
    }
}

/// Reusable scratch arena for planned execution.
///
/// One flat `Vec<f32>` that grows to the high-water mark of the plans
/// run through it and never shrinks.  Safe to thread through
/// differently-shaped layers back to back: plans write every scratch
/// byte they read, so no run observes another run's data.
///
/// The quantized lanes (DESIGN.md §Reduced-Precision) carry two more
/// grow-only arenas — a `u16` lane for f16/bf16 patch bits and an `i8`
/// lane for int8 — sized by the same exact-requirement discipline
/// ([`ConvTransposePlan::quant_patch_elems`] and its batch variant),
/// so quantized steady state is zero-alloc like every other lane.
/// f32-only deployments never grow them past zero.
#[derive(Debug, Clone, Default)]
pub struct Scratch {
    buf: Vec<f32>,
    qbuf16: Vec<u16>,
    qbuf8: Vec<i8>,
}

impl Scratch {
    /// An empty arena (grows on first use).
    pub fn new() -> Scratch {
        Scratch::default()
    }

    /// An arena pre-sized to exactly `n` floats (quantized lanes grow
    /// on first quantized use).
    pub fn with_floats(n: usize) -> Scratch {
        Scratch {
            buf: vec![0.0; n],
            ..Scratch::default()
        }
    }

    /// An arena pre-sized for one plan (its steady state from call one).
    pub fn for_plan(plan: &ConvTransposePlan) -> Scratch {
        Scratch::with_floats(plan.scratch_floats())
    }

    /// An arena pre-sized for the largest of several plans — e.g. every
    /// layer of a generator sharing one arena.
    pub fn for_plans<'a>(plans: impl IntoIterator<Item = &'a ConvTransposePlan>) -> Scratch {
        Scratch::with_floats(
            plans
                .into_iter()
                .map(ConvTransposePlan::scratch_floats)
                .max()
                .unwrap_or(0),
        )
    }

    /// Current arena size in floats (the high-water mark).
    pub fn capacity_floats(&self) -> usize {
        self.buf.len()
    }

    /// Current u16 quantized-lane size in elements (f16/bf16 patch
    /// bits; zero until a 16-bit quantized lane runs).
    pub fn q16_capacity_elems(&self) -> usize {
        self.qbuf16.len()
    }

    /// Current i8 quantized-lane size in elements (int8 patch values;
    /// zero until an int8 lane runs).
    pub fn q8_capacity_elems(&self) -> usize {
        self.qbuf8.len()
    }

    /// Borrow the first `n` floats, growing only if the arena is
    /// smaller than `n` (never in steady state).
    fn ensure(&mut self, n: usize) -> &mut [f32] {
        if self.buf.len() < n {
            self.buf.resize(n, 0.0);
        }
        &mut self.buf[..n]
    }

    /// [`ensure`](Self::ensure) plus the quantized lanes: borrow the
    /// first `n` floats, `q16` u16 elements, and `q8` i8 elements, each
    /// lane growing only if smaller (never in steady state).  Distinct
    /// fields, so the three mutable borrows coexist.
    fn ensure_quant(
        &mut self,
        n: usize,
        q16: usize,
        q8: usize,
    ) -> (&mut [f32], &mut [u16], &mut [i8]) {
        if self.buf.len() < n {
            self.buf.resize(n, 0.0);
        }
        if self.qbuf16.len() < q16 {
            self.qbuf16.resize(q16, 0);
        }
        if self.qbuf8.len() < q8 {
            self.qbuf8.resize(q8, 0);
        }
        (
            &mut self.buf[..n],
            &mut self.qbuf16[..q16],
            &mut self.qbuf8[..q8],
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::unified;
    use crate::tensor::ops;
    use crate::util::rng::Rng;

    fn case(n_in: usize, nk: usize, p: usize, cin: usize, cout: usize, seed: u64) {
        let mut rng = Rng::seeded(seed);
        let x = Feature::random(n_in, n_in, cin, &mut rng);
        let k = Kernel::random(nk, cin, cout, &mut rng);
        let want = unified::transpose_conv(&x, &k, p);
        let plan = ConvTransposePlan::new(ConvTransposeParams::new(n_in, nk, p, cin, cout), &k);
        let mut scratch = Scratch::for_plan(&plan);
        let mut out = plan.new_output();
        plan.run(&x, &mut scratch, &mut out);
        assert_eq!(out, want, "planned != one-shot (n={n_in} k={nk} p={p})");
        for workers in [2, 3, 8] {
            let mut out_par = plan.new_output();
            plan.run_par(&x, &mut scratch, &mut out_par, workers);
            assert_eq!(out_par, want, "run_par({workers}) != one-shot");
        }
    }

    #[test]
    fn planned_bit_identical_fig6() {
        case(4, 5, 2, 3, 2, 40); // Fig. 5/6 worked example (odd output)
    }

    #[test]
    fn planned_bit_identical_gan_layer() {
        case(4, 4, 2, 8, 4, 41);
        case(8, 4, 2, 4, 2, 42);
    }

    #[test]
    fn planned_bit_identical_odd_padding_and_degenerate() {
        case(5, 3, 1, 2, 2, 43); // role swap
        case(1, 3, 2, 1, 1, 44); // single pixel
        case(3, 2, 0, 2, 2, 45); // no padding
    }

    #[test]
    fn scratch_sizing_is_exact() {
        let mut rng = Rng::seeded(46);
        let k = Kernel::random(5, 3, 2, &mut rng);
        let plan = ConvTransposePlan::new(ConvTransposeParams::new(4, 5, 2, 3, 2), &k);
        // Fig. 5 geometry: slabs + phase outputs for the direct paths,
        // plus the largest phase's im2col patch matrix for the GEMM
        // formulation — nothing else.
        let seg = segregate(&k);
        let geoms = unified::phase_geometries(4, 5, 2);
        let by_hand_direct: usize = geoms
            .iter()
            .map(|g| (g.rows.1 - g.rows.0) * (g.cols.1 - g.cols.0) * 3 + g.n_rows * g.n_cols * 2)
            .sum();
        let by_hand_patch: usize = geoms
            .iter()
            .map(|g| {
                let s = &seg.subs[g.sub];
                g.n_rows * g.n_cols * s.rows * s.cols * 3
            })
            .max()
            .unwrap();
        assert_eq!(plan.scratch_floats_direct(), by_hand_direct);
        assert_eq!(plan.scratch_floats(), by_hand_direct + by_hand_patch);
        assert_eq!(plan.scratch_bytes(), 4 * (by_hand_direct + by_hand_patch));
        // A cold arena grows to exactly the direct requirement on the
        // direct path — GEMM-free users never pay for the patch area —
        let x = Feature::random(4, 4, 3, &mut rng);
        let mut scratch = Scratch::new();
        let mut out = plan.new_output();
        plan.run(&x, &mut scratch, &mut out);
        assert_eq!(scratch.capacity_floats(), plan.scratch_floats_direct());
        // — and to exactly the full requirement once the GEMM lane runs.
        plan.run_gemm(&x, &mut scratch, &mut out);
        assert_eq!(scratch.capacity_floats(), plan.scratch_floats());
        // A for_plan arena covers every strategy from call one.
        let mut full = Scratch::for_plan(&plan);
        plan.run_gemm(&x, &mut full, &mut out);
        assert_eq!(full.capacity_floats(), plan.scratch_floats());
    }

    #[test]
    fn arena_shared_across_shapes_never_aliases() {
        // Big layer, then small, then big again through ONE arena —
        // every result must stay bit-identical to a fresh computation.
        let mut rng = Rng::seeded(47);
        let shapes = [(9, 4, 2, 3, 2), (3, 3, 1, 2, 4), (6, 5, 2, 1, 1)];
        let cases: Vec<(Feature, ConvTransposePlan, Feature)> = shapes
            .iter()
            .map(|&(n, nk, p, cin, cout)| {
                let x = Feature::random(n, n, cin, &mut rng);
                let k = Kernel::random(nk, cin, cout, &mut rng);
                let want = unified::transpose_conv(&x, &k, p);
                let plan =
                    ConvTransposePlan::new(ConvTransposeParams::new(n, nk, p, cin, cout), &k);
                (x, plan, want)
            })
            .collect();
        let mut scratch = Scratch::new();
        for _round in 0..3 {
            for (x, plan, want) in &cases {
                let mut out = plan.new_output();
                plan.run(x, &mut scratch, &mut out);
                assert_eq!(&out, want);
            }
            for (x, plan, want) in cases.iter().rev() {
                let mut out = plan.new_output();
                plan.run_par(x, &mut scratch, &mut out, 3);
                assert_eq!(&out, want);
            }
        }
    }

    #[test]
    fn run_does_not_depend_on_stale_output() {
        // The scatter covers the whole output, so a dirty `out` buffer
        // must not leak through.
        let mut rng = Rng::seeded(48);
        let x = Feature::random(5, 5, 2, &mut rng);
        let k = Kernel::random(4, 2, 3, &mut rng);
        let plan = ConvTransposePlan::new(ConvTransposeParams::new(5, 4, 2, 2, 3), &k);
        let mut scratch = Scratch::for_plan(&plan);
        let mut out = plan.new_output();
        plan.run(&x, &mut scratch, &mut out);
        let want = out.clone();
        out.data.fill(f32::NAN);
        plan.run(&x, &mut scratch, &mut out);
        assert!(out
            .data
            .iter()
            .zip(&want.data)
            .all(|(a, b)| a.to_bits() == b.to_bits()));
    }

    #[test]
    #[should_panic(expected = "fully-specified")]
    fn plan_rejects_placeholder_template() {
        let seg = segregate(&Kernel::zeros(4, 2, 2));
        // gan_layer() has zero n_in/cin/cout — the with_io footgun.
        ConvTransposePlan::from_seg(ConvTransposeParams::gan_layer(), seg);
    }

    #[test]
    #[should_panic(expected = "input shape mismatch")]
    fn run_checks_input_shape() {
        let mut rng = Rng::seeded(49);
        let k = Kernel::random(4, 2, 2, &mut rng);
        let plan = ConvTransposePlan::new(ConvTransposeParams::new(4, 4, 2, 2, 2), &k);
        let x = Feature::zeros(5, 5, 2);
        let mut out = plan.new_output();
        plan.run(&x, &mut Scratch::new(), &mut out);
    }

    #[test]
    fn run_with_every_strategy_matches_reference() {
        // The whole autotuner search space, on an odd-output (Fig. 5/6)
        // and an even-output (GAN block) shape, against dirty output
        // buffers — every direct strategy must reproduce the planned
        // serial reference exactly; the GEMM formulation within 1e-4
        // (f32 reassociation through the register tile) — and all must
        // overwrite every output element.
        let mut rng = Rng::seeded(51);
        for (n_in, nk, p, cin, cout) in [(4, 5, 2, 3, 2), (4, 4, 2, 3, 2)] {
            let x = Feature::random(n_in, n_in, cin, &mut rng);
            let k = Kernel::random(nk, cin, cout, &mut rng);
            let plan =
                ConvTransposePlan::new(ConvTransposeParams::new(n_in, nk, p, cin, cout), &k);
            let mut scratch = Scratch::for_plan(&plan);
            let mut want = plan.new_output();
            plan.run(&x, &mut scratch, &mut want);
            for s in crate::tune::space::search_space(4) {
                let mut got = plan.new_output();
                got.data.fill(f32::NAN);
                plan.run_with(&s, &x, &mut scratch, &mut got);
                if s.formulation == Formulation::PhaseGemm {
                    assert!(got.data.iter().all(|v| !v.is_nan()), "{} left NaNs", s.name());
                    assert!(
                        ops::max_abs_diff(&got, &want) < 1e-4,
                        "{} diverged (n={n_in} k={nk} p={p})",
                        s.name()
                    );
                } else {
                    assert_eq!(got, want, "{} diverged (n={n_in} k={nk} p={p})", s.name());
                }
            }
        }
    }

    #[test]
    fn gemm_lanes_match_direct_across_couts() {
        // The register tile is MR×NR — Cout values off the NR multiple
        // (1, 3, 17) exercise the ragged-edge path; 8 hits it exactly.
        let mut rng = Rng::seeded(53);
        for cout in [1usize, 3, 8, 17] {
            for (n_in, nk, p) in [(4, 5, 2), (6, 4, 2), (5, 3, 1), (3, 4, 3)] {
                let x = Feature::random(n_in, n_in, 3, &mut rng);
                let k = Kernel::random(nk, 3, cout, &mut rng);
                let plan =
                    ConvTransposePlan::new(ConvTransposeParams::new(n_in, nk, p, 3, cout), &k);
                let mut scratch = Scratch::for_plan(&plan);
                let mut want = plan.new_output();
                plan.run(&x, &mut scratch, &mut want);
                let mut got = plan.new_output();
                got.data.fill(f32::NAN);
                plan.run_gemm(&x, &mut scratch, &mut got);
                assert!(
                    ops::max_abs_diff(&got, &want) < 1e-4,
                    "run_gemm (cout={cout} n={n_in} k={nk} p={p})"
                );
                for workers in [2, 3, 8] {
                    let mut par = plan.new_output();
                    par.data.fill(f32::NAN);
                    plan.run_gemm_par_rows(&x, &mut scratch, &mut par, workers);
                    assert_eq!(
                        par, got,
                        "row-parallel GEMM ({workers}) != serial GEMM (cout={cout})"
                    );
                }
            }
        }
    }

    #[test]
    fn fused_lanes_match_separate_plus_epilogue() {
        // Tentpole acceptance (ISSUE 10): the fused-epilogue lanes
        // equal slab + scatter + separate-epilogue — bit-identical
        // with the scalar microkernel (the phase-slab store/reload is
        // an exact f32 round-trip and both sides accumulate
        // k-ascending), ≤ 1e-4 on the active vector lane (the fused
        // driver's single full-K call reassociates the split-K
        // blocking).  Grid: paddings 0–3 × odd/even outputs ×
        // activations {none, relu, tanh} × bias {absent, present}.
        let mut rng = Rng::seeded(60);
        let acts = [
            gemm::Activation::None,
            gemm::Activation::Relu,
            gemm::Activation::Tanh,
        ];
        for (n_in, nk, p, cin, cout) in [
            (4, 5, 2, 3, 2),  // odd output
            (4, 4, 2, 3, 5),  // even output, ragged cout
            (5, 3, 1, 2, 3),  // odd padding
            (3, 4, 3, 2, 2),  // padding 3
            (3, 5, 0, 1, 4),  // no padding
        ] {
            let x = Feature::random(n_in, n_in, cin, &mut rng);
            let k = Kernel::random(nk, cin, cout, &mut rng);
            let plan =
                ConvTransposePlan::new(ConvTransposeParams::new(n_in, nk, p, cin, cout), &k);
            let mut scratch = Scratch::for_plan(&plan);
            let bias = Feature::random(1, 1, cout, &mut rng).data;
            for act in acts {
                for with_bias in [false, true] {
                    let epi = gemm::Epilogue {
                        bias: with_bias.then_some(&bias[..]),
                        act,
                    };
                    let mut want = plan.new_output();
                    plan.run_gemm_isa(Isa::Scalar, &x, &mut scratch, &mut want);
                    apply_epilogue_slice(&mut want.data, &epi);
                    let mut got = plan.new_output();
                    got.data.fill(f32::NAN);
                    plan.run_gemm_fused_isa(Isa::Scalar, &x, &mut scratch, &mut got, &epi);
                    assert_eq!(
                        got, want,
                        "scalar fused != separate (n={n_in} k={nk} p={p} act={act:?} bias={with_bias})"
                    );
                    // Scalar row-parallel fused: same bit-exact
                    // contract (the scalar tile accumulates every
                    // element k-ascending whatever the row tiling).
                    for workers in [2, 3] {
                        let mut par = plan.new_output();
                        par.data.fill(f32::NAN);
                        plan.run_gemm_fused_par_rows_isa(
                            Isa::Scalar,
                            &x,
                            &mut scratch,
                            &mut par,
                            workers,
                            &epi,
                        );
                        assert_eq!(par, want, "scalar fused par({workers}) != separate");
                    }
                    // Active ISA: the 1e-4 reassociation contract, and
                    // every output element overwritten.
                    let mut vec_got = plan.new_output();
                    vec_got.data.fill(f32::NAN);
                    plan.run_gemm_fused(&x, &mut scratch, &mut vec_got, &epi);
                    assert!(vec_got.data.iter().all(|v| !v.is_nan()));
                    assert!(
                        ops::max_abs_diff(&vec_got, &want) < 1e-4,
                        "active fused diverged (n={n_in} k={nk} p={p} act={act:?})"
                    );
                    for workers in [2, 3] {
                        let mut par = plan.new_output();
                        par.data.fill(f32::NAN);
                        plan.run_gemm_fused_par_rows(&x, &mut scratch, &mut par, workers, &epi);
                        assert!(par.data.iter().all(|v| !v.is_nan()));
                        assert!(
                            ops::max_abs_diff(&par, &want) < 1e-4,
                            "active fused par({workers}) diverged"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn fused_batch_lanes_match_separate_plus_epilogue() {
        let mut rng = Rng::seeded(61);
        for (n_in, nk, p, cin, cout, n) in [(4, 5, 2, 3, 2, 3), (4, 4, 2, 2, 3, 2)] {
            let k = Kernel::random(nk, cin, cout, &mut rng);
            let plan =
                ConvTransposePlan::new(ConvTransposeParams::new(n_in, nk, p, cin, cout), &k);
            let xb = FeatureBatch::random(n, n_in, n_in, cin, &mut rng);
            let mut scratch = Scratch::new();
            let bias = Feature::random(1, 1, cout, &mut rng).data;
            let epi = gemm::Epilogue {
                bias: Some(&bias[..]),
                act: gemm::Activation::Relu,
            };
            let mut want = plan.new_batch_output(n);
            plan.run_gemm_batch(&xb, &mut scratch, &mut want);
            apply_epilogue_slice(&mut want.data, &epi);
            let mut got = plan.new_batch_output(n);
            got.data.fill(f32::NAN);
            plan.run_gemm_fused_batch(&xb, &mut scratch, &mut got, &epi);
            assert!(got.data.iter().all(|v| !v.is_nan()));
            assert!(ops::max_abs_diff_batch(&got, &want) < 1e-4, "fused batch diverged");
            for workers in [2, 3] {
                let mut par = plan.new_batch_output(n);
                par.data.fill(f32::NAN);
                plan.run_gemm_fused_batch_par(&xb, &mut scratch, &mut par, workers, &epi);
                assert!(par.data.iter().all(|v| !v.is_nan()));
                assert!(
                    ops::max_abs_diff_batch(&par, &want) < 1e-4,
                    "fused batch par({workers}) diverged"
                );
            }
            // Per-image fused agrees with the stacked batched fused
            // GEMM within the same contract.
            let mut seq = plan.new_batch_output(n);
            for i in 0..n {
                let xi = xb.feature(i);
                let mut oi = plan.new_output();
                plan.run_gemm_fused(&xi, &mut scratch, &mut oi, &epi);
                seq.image_mut(i).copy_from_slice(&oi.data);
            }
            assert!(ops::max_abs_diff_batch(&seq, &got) < 1e-4);
        }
    }

    #[test]
    fn fused_quant_lanes_bit_identical_to_separate_plus_epilogue() {
        // The quantized fused drivers are the scalar panel loops with
        // the dequant scale folded into the epilogue store — the same
        // arithmetic sequence as the separate quantized lane followed
        // by the epilogue pass, so equality is exact for every
        // precision and every worker count (per-row int8 scales match
        // per-row, batch-wide match batch-wide).
        let mut rng = Rng::seeded(62);
        let (n_in, nk, p, cin, cout) = (4, 5, 2, 3, 3);
        let k = Kernel::random(nk, cin, cout, &mut rng);
        let plan = ConvTransposePlan::new(ConvTransposeParams::new(n_in, nk, p, cin, cout), &k);
        let x = Feature::random(n_in, n_in, cin, &mut rng);
        let xb = FeatureBatch::random(2, n_in, n_in, cin, &mut rng);
        let mut scratch = Scratch::new();
        let bias = Feature::random(1, 1, cout, &mut rng).data;
        let epi = gemm::Epilogue {
            bias: Some(&bias[..]),
            act: gemm::Activation::Tanh,
        };
        for prec in Precision::QUANTIZED {
            let mut want = plan.new_output();
            plan.run_gemm_quant_isa(Isa::Scalar, prec, &x, &mut scratch, &mut want);
            apply_epilogue_slice(&mut want.data, &epi);
            let mut got = plan.new_output();
            got.data.fill(f32::NAN);
            plan.run_gemm_fused_quant(prec, &x, &mut scratch, &mut got, &epi);
            assert_eq!(got, want, "{} fused != separate", prec.name());
            for workers in [2, 3] {
                let mut wpar = plan.new_output();
                plan.run_gemm_quant_par_rows_isa(
                    Isa::Scalar,
                    prec,
                    &x,
                    &mut scratch,
                    &mut wpar,
                    workers,
                );
                apply_epilogue_slice(&mut wpar.data, &epi);
                let mut gpar = plan.new_output();
                gpar.data.fill(f32::NAN);
                plan.run_gemm_fused_quant_par_rows(
                    prec,
                    &x,
                    &mut scratch,
                    &mut gpar,
                    workers,
                    &epi,
                );
                assert_eq!(gpar, wpar, "{} fused par({workers})", prec.name());
            }
            let mut wb = plan.new_batch_output(2);
            plan.run_gemm_quant_batch_isa(Isa::Scalar, prec, &xb, &mut scratch, &mut wb);
            apply_epilogue_slice(&mut wb.data, &epi);
            let mut gb = plan.new_batch_output(2);
            gb.data.fill(f32::NAN);
            plan.run_gemm_fused_quant_batch(prec, &xb, &mut scratch, &mut gb, &epi);
            assert_eq!(gb.data, wb.data, "{} fused batch", prec.name());
            for workers in [2, 3] {
                let mut wbp = plan.new_batch_output(2);
                plan.run_gemm_quant_batch_par_isa(
                    Isa::Scalar,
                    prec,
                    &xb,
                    &mut scratch,
                    &mut wbp,
                    workers,
                );
                apply_epilogue_slice(&mut wbp.data, &epi);
                let mut gbp = plan.new_batch_output(2);
                gbp.data.fill(f32::NAN);
                plan.run_gemm_fused_quant_batch_par(
                    prec,
                    &xb,
                    &mut scratch,
                    &mut gbp,
                    workers,
                    &epi,
                );
                assert_eq!(gbp.data, wbp.data, "{} fused batch par({workers})", prec.name());
            }
        }
    }

    #[test]
    fn fused_scratch_sizing_is_exact_and_smaller() {
        // ISSUE 10 acceptance: the fused lanes claim a strictly
        // smaller exact arena than their separate counterparts (the
        // phase region disappears), and cold arenas grow to exactly
        // the fused figure.
        let mut rng = Rng::seeded(63);
        let k = Kernel::random(5, 3, 2, &mut rng);
        let plan = ConvTransposePlan::new(ConvTransposeParams::new(4, 5, 2, 3, 2), &k);
        assert!(plan.scratch_floats_gemm_fused() < plan.scratch_floats());
        assert_eq!(
            plan.scratch_floats_gemm_fused(),
            plan.scratch_floats() - plan.phase_floats
        );
        assert!(plan.scratch_floats_gemm_batch_fused(3) < plan.scratch_floats_gemm_batch(3));
        assert_eq!(
            plan.scratch_floats_gemm_batch_fused(3),
            plan.scratch_floats_gemm_batch(3) - 3 * plan.max_phase_floats()
        );
        // Cold arenas grow to exactly the fused requirement.
        let x = Feature::random(4, 4, 3, &mut rng);
        let mut scratch = Scratch::new();
        let mut out = plan.new_output();
        plan.run_gemm_fused(&x, &mut scratch, &mut out, &gemm::Epilogue::none());
        assert_eq!(scratch.capacity_floats(), plan.scratch_floats_gemm_fused());
        let xb = FeatureBatch::random(3, 4, 4, 3, &mut rng);
        let mut bscratch = Scratch::new();
        let mut bout = plan.new_batch_output(3);
        plan.run_gemm_fused_batch(&xb, &mut bscratch, &mut bout, &gemm::Epilogue::none());
        assert_eq!(
            bscratch.capacity_floats(),
            plan.scratch_floats_gemm_batch_fused(3)
        );
        // Strategy-keyed sizing picks the fused figures.
        let f = ExecStrategy::serial_gemm().fused_epilogue();
        assert_eq!(plan.scratch_floats_for(&f), plan.scratch_floats_gemm_fused());
        assert_eq!(
            plan.scratch_floats_for_batch(&f, 3),
            plan.scratch_floats_gemm_batch_fused(3)
        );
        assert_eq!(
            plan.scratch_floats_for(&ExecStrategy::serial_gemm()),
            plan.scratch_floats()
        );
    }

    #[test]
    fn run_with_epilogue_agrees_across_search_space() {
        // Every strategy — fused or separate epilogue, any
        // formulation — produces the reference "forward + bias +
        // activation" through run_with_epilogue: exact for the direct
        // formulations, ≤ 1e-4 for the GEMM formulation.
        let mut rng = Rng::seeded(64);
        let (n_in, nk, p, cin, cout) = (4, 4, 2, 3, 2);
        let x = Feature::random(n_in, n_in, cin, &mut rng);
        let k = Kernel::random(nk, cin, cout, &mut rng);
        let plan = ConvTransposePlan::new(ConvTransposeParams::new(n_in, nk, p, cin, cout), &k);
        let mut scratch = Scratch::for_plan(&plan);
        let bias = Feature::random(1, 1, cout, &mut rng).data;
        let epi = gemm::Epilogue {
            bias: Some(&bias[..]),
            act: gemm::Activation::Relu,
        };
        let mut want = plan.new_output();
        plan.run(&x, &mut scratch, &mut want);
        apply_epilogue_slice(&mut want.data, &epi);
        for s in crate::tune::space::search_space(4) {
            let mut got = plan.new_output();
            got.data.fill(f32::NAN);
            plan.run_with_epilogue(&s, &x, &mut scratch, &mut got, &epi);
            assert!(got.data.iter().all(|v| !v.is_nan()), "{} left NaNs", s.name());
            if s.formulation == Formulation::PhaseGemm {
                assert!(ops::max_abs_diff(&got, &want) < 1e-4, "{}", s.name());
            } else {
                assert_eq!(got, want, "{}", s.name());
            }
        }
        // Batched entry point over the batched space.
        let xb = FeatureBatch::random(3, n_in, n_in, cin, &mut rng);
        let mut wantb = plan.new_batch_output(3);
        for i in 0..3 {
            let xi = xb.feature(i);
            let mut oi = plan.new_output();
            plan.run(&xi, &mut scratch, &mut oi);
            wantb.image_mut(i).copy_from_slice(&oi.data);
        }
        apply_epilogue_slice(&mut wantb.data, &epi);
        let mut bscratch = Scratch::with_floats(
            plan.peak_scratch_floats_batch(3).max(plan.scratch_floats()),
        );
        for s in crate::tune::space::search_space_batch(4, 3) {
            let mut got = plan.new_batch_output(3);
            got.data.fill(f32::NAN);
            plan.run_batch_with_epilogue(&s, &xb, &mut bscratch, &mut got, &epi);
            assert!(got.data.iter().all(|v| !v.is_nan()), "{} left NaNs", s.name());
            if s.formulation == Formulation::PhaseGemm {
                assert!(ops::max_abs_diff_batch(&got, &wantb) < 1e-4, "{}", s.name());
            } else {
                assert_eq!(got.data, wantb.data, "{}", s.name());
            }
        }
    }

    /// `N` sequential single-image runs of `lane` — the batched lanes'
    /// reference semantics.
    fn sequential_reference(
        plan: &ConvTransposePlan,
        xb: &FeatureBatch,
        gemm: bool,
    ) -> FeatureBatch {
        let mut scratch = Scratch::for_plan(plan);
        let mut want = plan.new_batch_output(xb.n);
        for i in 0..xb.n {
            let xi = xb.feature(i);
            let mut oi = plan.new_output();
            if gemm {
                plan.run_gemm(&xi, &mut scratch, &mut oi);
            } else {
                plan.run(&xi, &mut scratch, &mut oi);
            }
            want.image_mut(i).copy_from_slice(&oi.data);
        }
        want
    }

    #[test]
    fn batched_direct_lanes_bit_identical_to_sequential() {
        let mut rng = Rng::seeded(54);
        for (n_in, nk, p, cin, cout) in [(4, 5, 2, 3, 2), (4, 4, 2, 3, 2), (5, 3, 1, 2, 2)] {
            let k = Kernel::random(nk, cin, cout, &mut rng);
            let plan =
                ConvTransposePlan::new(ConvTransposeParams::new(n_in, nk, p, cin, cout), &k);
            let mut scratch = Scratch::new();
            for n in [1usize, 3, 5] {
                let xb = FeatureBatch::random(n, n_in, n_in, cin, &mut rng);
                let want = sequential_reference(&plan, &xb, false);
                let mut got = plan.new_batch_output(n);
                got.data.fill(f32::NAN);
                plan.run_batch(&xb, &mut scratch, &mut got);
                assert_eq!(got, want, "run_batch (n={n} shape n_in={n_in})");
                for workers in [2, 3, 8] {
                    let mut par = plan.new_batch_output(n);
                    par.data.fill(f32::NAN);
                    plan.run_batch_par(&xb, &mut scratch, &mut par, workers);
                    assert_eq!(par, want, "run_batch_par({workers}) (n={n})");
                }
            }
        }
    }

    #[test]
    fn batched_gemm_lanes_bit_identical_to_sequential_gemm() {
        // The stacked [N·rows, K] GEMM accumulates every output element
        // in the same kk order as the per-image GEMM, so the fused lane
        // is bit-identical to N sequential run_gemm calls — and hence
        // within the same 1e-4 of the direct reference.
        let mut rng = Rng::seeded(55);
        for cout in [1usize, 3, 8, 17] {
            let (n_in, nk, p) = (4, 5, 2);
            let k = Kernel::random(nk, 3, cout, &mut rng);
            let plan = ConvTransposePlan::new(ConvTransposeParams::new(n_in, nk, p, 3, cout), &k);
            let mut scratch = Scratch::new();
            for n in [1usize, 3, 8] {
                let xb = FeatureBatch::random(n, n_in, n_in, 3, &mut rng);
                let want_gemm = sequential_reference(&plan, &xb, true);
                let want_direct = sequential_reference(&plan, &xb, false);
                let mut got = plan.new_batch_output(n);
                got.data.fill(f32::NAN);
                plan.run_gemm_batch(&xb, &mut scratch, &mut got);
                assert_eq!(got, want_gemm, "run_gemm_batch (n={n} cout={cout})");
                assert!(
                    crate::tensor::ops::max_abs_diff_batch(&got, &want_direct) < 1e-4,
                    "fused batched GEMM diverged from the direct reference (n={n} cout={cout})"
                );
                for workers in [2, 3, 8] {
                    let mut par = plan.new_batch_output(n);
                    par.data.fill(f32::NAN);
                    plan.run_gemm_batch_par(&xb, &mut scratch, &mut par, workers);
                    assert_eq!(par, got, "run_gemm_batch_par({workers}) != serial (n={n})");
                }
            }
        }
    }

    #[test]
    fn run_batch_with_covers_search_space() {
        // Every strategy, dispatched fused over a ragged batch, against
        // dirty outputs: direct and per-element formulations must equal
        // the per-image reference exactly; the GEMM formulation within
        // 1e-4 and NaN-free (every element written).
        let mut rng = Rng::seeded(56);
        let (n_in, nk, p, cin, cout) = (4, 5, 2, 3, 2);
        let k = Kernel::random(nk, cin, cout, &mut rng);
        let plan = ConvTransposePlan::new(ConvTransposeParams::new(n_in, nk, p, cin, cout), &k);
        for n in [1usize, 3] {
            let xb = FeatureBatch::random(n, n_in, n_in, cin, &mut rng);
            let want = sequential_reference(&plan, &xb, false);
            let mut scratch = Scratch::new();
            for s in crate::tune::space::search_space(4) {
                let mut got = plan.new_batch_output(n);
                got.data.fill(f32::NAN);
                plan.run_batch_with(&s, &xb, &mut scratch, &mut got);
                if s.formulation == Formulation::PhaseGemm {
                    assert!(got.data.iter().all(|v| !v.is_nan()), "{} left NaNs", s.name());
                    assert!(
                        crate::tensor::ops::max_abs_diff_batch(&got, &want) < 1e-4,
                        "{} diverged on batch n={n}",
                        s.name()
                    );
                } else {
                    assert_eq!(got, want, "{} diverged on batch n={n}", s.name());
                }
            }
            // The arena never outgrew the documented per-strategy peak.
            assert!(scratch.capacity_floats() <= plan.peak_scratch_floats_batch(n));
        }
    }

    #[test]
    fn batched_scratch_sizing_is_exact() {
        let mut rng = Rng::seeded(57);
        let k = Kernel::random(5, 3, 2, &mut rng);
        let plan = ConvTransposePlan::new(ConvTransposeParams::new(4, 5, 2, 3, 2), &k);
        let seg = segregate(&k);
        let geoms = unified::phase_geometries(4, 5, 2);
        let max_phase: usize = geoms.iter().map(|g| g.n_rows * g.n_cols * 2).max().unwrap();
        let max_patch: usize = geoms
            .iter()
            .map(|g| {
                let s = &seg.subs[g.sub];
                g.n_rows * g.n_cols * s.rows * s.cols * 3
            })
            .max()
            .unwrap();
        let slab: usize = geoms
            .iter()
            .map(|g| (g.rows.1 - g.rows.0) * (g.cols.1 - g.cols.0) * 3)
            .sum();
        for n in [1usize, 4, 8] {
            assert_eq!(
                plan.scratch_floats_gemm_batch(n),
                slab + n * (max_phase + max_patch)
            );
            assert_eq!(
                plan.scratch_floats_batch_par(n),
                n * plan.scratch_floats_direct()
            );
        }
        // A cold arena grows to exactly the fused-GEMM batch figure on
        // that lane, and to exactly the image-parallel figure on that
        // one — the sizing functions are tight bounds, not estimates.
        let n = 3;
        let xb = FeatureBatch::random(n, 4, 4, 3, &mut rng);
        let mut out = plan.new_batch_output(n);
        let mut scratch = Scratch::new();
        plan.run_gemm_batch(&xb, &mut scratch, &mut out);
        assert_eq!(scratch.capacity_floats(), plan.scratch_floats_gemm_batch(n));
        let mut scratch = Scratch::new();
        plan.run_batch_par(&xb, &mut scratch, &mut out, 3);
        assert_eq!(scratch.capacity_floats(), plan.scratch_floats_batch_par(n));
        // The serial batched direct lane needs only one direct region.
        let mut scratch = Scratch::new();
        plan.run_batch(&xb, &mut scratch, &mut out);
        assert_eq!(scratch.capacity_floats(), plan.scratch_floats_direct());
        // packed operands + patch region accessors agree with the plan.
        let packed: usize = geoms
            .iter()
            .map(|g| {
                let s = &seg.subs[g.sub];
                gemm::packed_b_floats(s.rows * s.cols * 3, 2)
            })
            .sum();
        assert_eq!(plan.packed_operand_floats(), packed);
        assert_eq!(plan.patch_region_floats(), max_patch);
    }

    /// Analytic worst-case drift of a quantized phase GEMM vs the f32
    /// reference: `K` products, each off by at most the operands'
    /// representation error (relative 2⁻¹¹ for f16, 2⁻⁸ for bf16;
    /// absolute `absmax/254` per side for symmetric int8), with slack
    /// for the f32 accumulation itself.
    fn drift_bound(p: Precision, k_depth: usize, amax: f32, bmax: f32) -> f32 {
        let k = k_depth as f32;
        match p {
            Precision::F16 => 4.0 * k * amax * bmax / 2048.0,
            Precision::Bf16 => 4.0 * k * amax * bmax / 256.0,
            Precision::Int8 => 2.0 * k * amax * bmax * (2.0 / 254.0),
            Precision::F32 => 1e-4,
        }
    }

    #[test]
    fn quantized_lanes_within_drift_bounds() {
        // Every quantized precision, serial and row-parallel, on an
        // odd-output and an even-output shape with ragged and exact
        // Cout: within the analytic drift bound of the f32 GEMM lane,
        // NaN-free on dirty buffers; the 16-bit parallel lanes
        // bit-identical to their serial quantized reference
        // (elementwise conversion, same per-element order).
        let mut rng = Rng::seeded(64);
        for (n_in, nk, p, cin, cout) in [(4, 5, 2, 3, 2), (4, 4, 2, 3, 8), (5, 3, 1, 2, 17)] {
            let x = Feature::random(n_in, n_in, cin, &mut rng);
            let k = Kernel::random(nk, cin, cout, &mut rng);
            let plan =
                ConvTransposePlan::new(ConvTransposeParams::new(n_in, nk, p, cin, cout), &k);
            let mut scratch = Scratch::new();
            let mut want = plan.new_output();
            plan.run_gemm(&x, &mut scratch, &mut want);
            let k_depth = nk * nk * cin; // ≥ any phase's gemm_k
            let amax = quant::absmax(&x.data).max(1.0);
            let bmax = quant::absmax(&k.data);
            for prec in Precision::QUANTIZED {
                let bound = drift_bound(prec, k_depth, amax, bmax);
                let s = ExecStrategy::serial_gemm().with_precision(prec);
                let mut got = plan.new_output();
                got.data.fill(f32::NAN);
                plan.run_with(&s, &x, &mut scratch, &mut got);
                assert!(got.data.iter().all(|v| !v.is_nan()), "{} left NaNs", s.name());
                let drift = max_abs(&got.data, &want.data);
                assert!(
                    drift < bound,
                    "{} drift {drift} ≥ bound {bound} (n={n_in} k={nk} p={p} cout={cout})",
                    s.name()
                );
                for workers in [2, 3, 8] {
                    let sp = ExecStrategy::gemm_parallel(workers).with_precision(prec);
                    let mut par = plan.new_output();
                    par.data.fill(f32::NAN);
                    plan.run_with(&sp, &x, &mut scratch, &mut par);
                    if prec == Precision::Int8 {
                        // Per-row activation scales: bound, not bits.
                        assert!(
                            max_abs(&par.data, &want.data) < bound,
                            "{} diverged",
                            sp.name()
                        );
                    } else {
                        assert_eq!(par, got, "{} != serial quantized lane", sp.name());
                    }
                }
            }
        }
    }

    #[test]
    fn quantized_batched_lanes_match_per_image() {
        // Fused batched quantized lanes vs N per-image quantized runs:
        // bit-identical for f16/bf16 (stacked M never changes
        // per-element order), drift-bounded for int8 (batch-wide vs
        // per-phase activation scales), and within the analytic bound
        // of the f32 reference throughout.
        let mut rng = Rng::seeded(65);
        let (n_in, nk, p, cin, cout) = (4, 5, 2, 3, 2);
        let k = Kernel::random(nk, cin, cout, &mut rng);
        let plan = ConvTransposePlan::new(ConvTransposeParams::new(n_in, nk, p, cin, cout), &k);
        for n in [1usize, 3] {
            let xb = FeatureBatch::random(n, n_in, n_in, cin, &mut rng);
            let want_f32 = sequential_reference(&plan, &xb, true);
            let k_depth = nk * nk * cin;
            let amax = quant::absmax(&xb.data).max(1.0);
            let bmax = quant::absmax(&k.data);
            for prec in Precision::QUANTIZED {
                let bound = drift_bound(prec, k_depth, amax, bmax);
                let s = ExecStrategy::serial_gemm().with_precision(prec);
                // Per-image quantized reference through run_with.
                let mut scratch = Scratch::new();
                let mut want_q = plan.new_batch_output(n);
                for i in 0..n {
                    let xi = xb.feature(i);
                    let mut oi = plan.new_output();
                    plan.run_with(&s, &xi, &mut scratch, &mut oi);
                    want_q.image_mut(i).copy_from_slice(&oi.data);
                }
                let fused = s.fused();
                let mut got = plan.new_batch_output(n);
                got.data.fill(f32::NAN);
                plan.run_batch_with(&fused, &xb, &mut scratch, &mut got);
                assert!(got.data.iter().all(|v| !v.is_nan()), "{} left NaNs", fused.name());
                if prec == Precision::Int8 {
                    assert!(
                        crate::tensor::ops::max_abs_diff_batch(&got, &want_q) < bound,
                        "{} vs per-image (n={n})",
                        fused.name()
                    );
                } else {
                    assert_eq!(got, want_q, "{} != per-image quantized (n={n})", fused.name());
                }
                assert!(
                    crate::tensor::ops::max_abs_diff_batch(&got, &want_f32) < bound,
                    "{} vs f32 reference (n={n})",
                    fused.name()
                );
                for workers in [2, 3] {
                    let sp = ExecStrategy::gemm_parallel(workers)
                        .with_precision(prec)
                        .fused();
                    let mut par = plan.new_batch_output(n);
                    par.data.fill(f32::NAN);
                    plan.run_batch_with(&sp, &xb, &mut scratch, &mut par);
                    if prec == Precision::Int8 {
                        assert!(
                            crate::tensor::ops::max_abs_diff_batch(&par, &want_f32) < bound,
                            "{} diverged (n={n})",
                            sp.name()
                        );
                    } else {
                        assert_eq!(par, got, "{} != serial fused (n={n})", sp.name());
                    }
                }
            }
        }
    }

    #[test]
    fn quantized_scratch_sizing_is_exact() {
        // The quantized arenas grow to exactly the documented element
        // counts — and only the lane the precision uses; the f32 arena
        // figure is unchanged from the exact GEMM lane.
        let mut rng = Rng::seeded(66);
        let k = Kernel::random(5, 3, 2, &mut rng);
        let plan = ConvTransposePlan::new(ConvTransposeParams::new(4, 5, 2, 3, 2), &k);
        assert_eq!(plan.quant_patch_elems(), plan.patch_region_floats());
        assert_eq!(plan.quant_patch_elems_batch(3), 3 * plan.patch_region_floats());
        let x = Feature::random(4, 4, 3, &mut rng);
        let mut out = plan.new_output();
        let mut scratch = Scratch::new();
        let f16 = ExecStrategy::serial_gemm().with_precision(Precision::F16);
        plan.run_with(&f16, &x, &mut scratch, &mut out);
        assert_eq!(scratch.capacity_floats(), plan.scratch_floats());
        assert_eq!(scratch.q16_capacity_elems(), plan.quant_patch_elems());
        assert_eq!(scratch.q8_capacity_elems(), 0);
        let mut scratch = Scratch::new();
        let i8s = ExecStrategy::serial_gemm().with_precision(Precision::Int8);
        plan.run_with(&i8s, &x, &mut scratch, &mut out);
        assert_eq!(scratch.q16_capacity_elems(), 0);
        assert_eq!(scratch.q8_capacity_elems(), plan.quant_patch_elems());
        // Batched: the quantized lane grows to the stacked figure.
        let n = 3;
        let xb = FeatureBatch::random(n, 4, 4, 3, &mut rng);
        let mut outb = plan.new_batch_output(n);
        let mut scratch = Scratch::new();
        plan.run_batch_with(&f16.fused(), &xb, &mut scratch, &mut outb);
        assert_eq!(scratch.capacity_floats(), plan.scratch_floats_gemm_batch(n));
        assert_eq!(scratch.q16_capacity_elems(), plan.quant_patch_elems_batch(n));
        // The f32 lane never touches the quantized arenas.
        let mut scratch = Scratch::new();
        plan.run_gemm(&x, &mut scratch, &mut out);
        assert_eq!(scratch.q16_capacity_elems(), 0);
        assert_eq!(scratch.q8_capacity_elems(), 0);
    }

    #[test]
    fn packed_operand_bytes_shrink_per_precision() {
        // ≥2× for the 16-bit formats and ≥4× for int8 vs the f32
        // panels (exact when the panel widths coincide, better when
        // the f32 panels pad Cout to a wider vector tile).
        let mut rng = Rng::seeded(67);
        for (nk, cin, cout) in [(4, 8, 4), (4, 3, 17), (5, 3, 2)] {
            let k = Kernel::random(nk, cin, cout, &mut rng);
            let plan =
                ConvTransposePlan::new(ConvTransposeParams::new(4, nk, 2, cin, cout), &k);
            let f32b = plan.packed_operand_bytes(Precision::F32);
            assert_eq!(f32b, plan.packed_operand_floats() * 4);
            assert_eq!(
                plan.packed_operand_bytes(Precision::F16),
                plan.packed_operand_bytes(Precision::Bf16)
            );
            assert!(f32b >= 2 * plan.packed_operand_bytes(Precision::F16));
            assert!(f32b >= 4 * plan.packed_operand_bytes(Precision::Int8));
        }
    }

    #[test]
    fn run_par_rows_matches_run_par() {
        let mut rng = Rng::seeded(52);
        let x = Feature::random(6, 6, 3, &mut rng);
        let k = Kernel::random(5, 3, 2, &mut rng);
        let plan = ConvTransposePlan::new(ConvTransposeParams::new(6, 5, 2, 3, 2), &k);
        let mut scratch = Scratch::for_plan(&plan);
        let mut want = plan.new_output();
        plan.run(&x, &mut scratch, &mut want);
        for workers in [1, 2, 5] {
            let mut got = plan.new_output();
            plan.run_par_rows(&x, &mut scratch, &mut got, workers);
            assert_eq!(got, want, "run_par_rows({workers})");
        }
    }

    #[test]
    fn planned_matches_conventional_reference() {
        // End-to-end sanity against Algorithm 1 (tolerance, not bits —
        // different accumulation order).
        let mut rng = Rng::seeded(50);
        let x = Feature::random(6, 6, 3, &mut rng);
        let k = Kernel::random(4, 3, 2, &mut rng);
        let want = crate::conv::conventional::transpose_conv(&x, &k, 2);
        let plan = ConvTransposePlan::new(ConvTransposeParams::new(6, 4, 2, 3, 2), &k);
        let got = plan.run_alloc(&x, &mut Scratch::for_plan(&plan));
        assert!(ops::max_abs_diff(&want, &got) < 1e-4);
    }

    fn max_abs(a: &[f32], b: &[f32]) -> f32 {
        a.iter()
            .zip(b)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f32, f32::max)
    }

    #[test]
    fn backward_data_lanes_match_one_shot_unified() {
        // Direct lane bit-identical to the one-shot unified route (same
        // values, same accumulation order); GEMM lane within 1e-4; the
        // parallel lane bit-identical to the serial direct one.  Dirty
        // dx buffers must not leak (the lanes zero dx — phases overlap).
        let mut rng = Rng::seeded(58);
        for (n_in, nk, p, cin, cout) in [
            (4, 5, 2, 3, 2),
            (4, 4, 2, 3, 2),
            (5, 3, 1, 2, 2),
            (3, 4, 3, 2, 1),
            (6, 4, 2, 2, 8),
        ] {
            let k = Kernel::random(nk, cin, cout, &mut rng);
            let plan =
                ConvTransposePlan::new(ConvTransposeParams::new(n_in, nk, p, cin, cout), &k);
            let ho = plan.out_size();
            let dy = Feature::random(ho, ho, cout, &mut rng);
            let want = crate::conv::backward::grad_input_unified(&dy, &k, n_in, p);
            let mut scratch = Scratch::new();
            let mut dx = plan.new_input_grad();
            dx.data.fill(f32::NAN);
            plan.run_backward_data(&dy, &mut scratch, &mut dx);
            assert_eq!(dx, want, "run_backward_data (n={n_in} k={nk} p={p})");
            let mut dxg = plan.new_input_grad();
            dxg.data.fill(f32::NAN);
            plan.run_backward_data_gemm(&dy, &mut scratch, &mut dxg);
            assert!(
                max_abs(&dxg.data, &want.data) < 1e-4,
                "run_backward_data_gemm (n={n_in} k={nk} p={p} cout={cout})"
            );
            for workers in [2, 3, 8] {
                let mut dxp = plan.new_input_grad();
                dxp.data.fill(f32::NAN);
                plan.run_backward_data_par(&dy, &mut scratch, &mut dxp, workers);
                assert_eq!(dxp, want, "run_backward_data_par({workers})");
            }
        }
    }

    #[test]
    fn backward_weights_matches_one_shot_unified() {
        let mut rng = Rng::seeded(59);
        for (n_in, nk, p, cin, cout) in [
            (4, 5, 2, 3, 2),
            (4, 4, 2, 3, 2),
            (5, 3, 1, 2, 2),
            (3, 4, 3, 2, 1),
            (6, 4, 2, 2, 8),
        ] {
            let k = Kernel::random(nk, cin, cout, &mut rng);
            let plan =
                ConvTransposePlan::new(ConvTransposeParams::new(n_in, nk, p, cin, cout), &k);
            let ho = plan.out_size();
            let x = Feature::random(n_in, n_in, cin, &mut rng);
            let dy = Feature::random(ho, ho, cout, &mut rng);
            let want = crate::conv::backward::grad_kernel_unified(&x, &dy, nk, p);
            let mut scratch = Scratch::new();
            let mut dk = plan.new_kernel_grad();
            dk.data.fill(f32::NAN);
            plan.run_backward_weights(&x, &dy, &mut scratch, &mut dk);
            assert!(
                max_abs(&dk.data, &want.data) < 1e-4,
                "run_backward_weights (n={n_in} k={nk} p={p} cout={cout})"
            );
        }
    }

    #[test]
    fn batched_backward_matches_sequential() {
        // Batched data-grad is bit-identical to N sequential planned
        // runs (it is N runs of the same core); batched weight-grad
        // accumulates across the batch and matches the sum of per-image
        // one-shot gradients within the GEMM tolerance.
        let mut rng = Rng::seeded(60);
        let (n_in, nk, p, cin, cout) = (4, 5, 2, 3, 2);
        let k = Kernel::random(nk, cin, cout, &mut rng);
        let plan = ConvTransposePlan::new(ConvTransposeParams::new(n_in, nk, p, cin, cout), &k);
        let ho = plan.out_size();
        for n in [1usize, 3, 5] {
            let dyb = FeatureBatch::random(n, ho, ho, cout, &mut rng);
            let xb = FeatureBatch::random(n, n_in, n_in, cin, &mut rng);
            // Data grad.
            let mut scratch = Scratch::new();
            let mut dxb = FeatureBatch::zeros(n, n_in, n_in, cin);
            dxb.data.fill(f32::NAN);
            plan.run_backward_data_batch(&dyb, &mut scratch, &mut dxb);
            for i in 0..n {
                let want =
                    crate::conv::backward::grad_input_unified(&dyb.feature(i), &k, n_in, p);
                assert_eq!(dxb.image(i), &want.data[..], "batched dx image {i} (n={n})");
            }
            // Batched dispatch covers the backward search space.
            for s in crate::tune::space::backward_search_space(4) {
                let mut got = FeatureBatch::zeros(n, n_in, n_in, cin);
                got.data.fill(f32::NAN);
                plan.run_backward_data_batch_with(&s, &dyb, &mut scratch, &mut got);
                for i in 0..n {
                    let want =
                        crate::conv::backward::grad_input_unified(&dyb.feature(i), &k, n_in, p);
                    if s.formulation == Formulation::PhaseGemm {
                        assert!(got.image(i).iter().all(|v| !v.is_nan()));
                        assert!(
                            max_abs(got.image(i), &want.data) < 1e-4,
                            "{} diverged (image {i})",
                            s.name()
                        );
                    } else {
                        assert_eq!(got.image(i), &want.data[..], "{} (image {i})", s.name());
                    }
                }
            }
            // Weight grad: batch-accumulated == Σ per-image.
            let mut want_sum = plan.new_kernel_grad();
            for i in 0..n {
                let di = crate::conv::backward::grad_kernel_unified(
                    &xb.feature(i),
                    &dyb.feature(i),
                    nk,
                    p,
                );
                for (w, d) in want_sum.data.iter_mut().zip(&di.data) {
                    *w += d;
                }
            }
            let mut dk_b = plan.new_kernel_grad();
            dk_b.data.fill(f32::NAN);
            plan.run_backward_weights_batch(&xb, &dyb, &mut scratch, &mut dk_b);
            assert!(
                max_abs(&dk_b.data, &want_sum.data) < 1e-3,
                "run_backward_weights_batch (n={n})"
            );
        }
    }

    #[test]
    fn fused_backward_matches_unfused_lanes() {
        // The fused lane extracts each dy phase once and must reproduce
        // the unfused pair exactly: dx bit-identical to
        // run_backward_data for direct strategies (the shared pad is
        // byte-identical, the correlation is the same), dk within the
        // GEMM tolerance for every strategy (bit-identical when the
        // weight GEMM runs the same lane).
        let mut rng = Rng::seeded(62);
        for (n_in, nk, p, cin, cout) in [
            (4, 5, 2, 3, 2),
            (4, 4, 2, 3, 2),
            (5, 3, 1, 2, 2),
            (3, 4, 3, 2, 1),
            (6, 4, 2, 2, 8),
        ] {
            let k = Kernel::random(nk, cin, cout, &mut rng);
            let plan =
                ConvTransposePlan::new(ConvTransposeParams::new(n_in, nk, p, cin, cout), &k);
            let ho = plan.out_size();
            let x = Feature::random(n_in, n_in, cin, &mut rng);
            let dy = Feature::random(ho, ho, cout, &mut rng);
            let mut scratch = Scratch::new();
            let mut want_dx = plan.new_input_grad();
            plan.run_backward_data(&dy, &mut scratch, &mut want_dx);
            let mut want_dk = plan.new_kernel_grad();
            plan.run_backward_weights(&x, &dy, &mut scratch, &mut want_dk);
            for s in crate::tune::space::backward_search_space(4) {
                let mut dx = plan.new_input_grad();
                let mut dk = plan.new_kernel_grad();
                dx.data.fill(f32::NAN);
                dk.data.fill(f32::NAN);
                plan.run_backward_with(&s, &x, &dy, &mut scratch, &mut dx, &mut dk);
                if s.formulation == Formulation::PhaseGemm {
                    assert!(dx.data.iter().all(|v| !v.is_nan()), "{} left NaNs", s.name());
                    assert!(max_abs(&dx.data, &want_dx.data) < 1e-4, "{} dx", s.name());
                } else {
                    assert_eq!(dx, want_dx, "{} dx (n={n_in} k={nk} p={p})", s.name());
                }
                assert!(max_abs(&dk.data, &want_dk.data) < 1e-4, "{} dk", s.name());
            }
            // The default entry point is the serial direct strategy and
            // runs the weight GEMM on the same (active) lane as the
            // unfused route — both gradients land bit-identical.
            let mut dx = plan.new_input_grad();
            let mut dk = plan.new_kernel_grad();
            plan.run_backward(&x, &dy, &mut scratch, &mut dx, &mut dk);
            assert_eq!(dx, want_dx, "run_backward dx (n={n_in})");
            assert!(
                dk.data
                    .iter()
                    .zip(&want_dk.data)
                    .all(|(a, b)| a.to_bits() == b.to_bits()),
                "run_backward dk (n={n_in})"
            );
        }
    }

    #[test]
    fn fused_batched_backward_matches_per_image() {
        let mut rng = Rng::seeded(63);
        let (n_in, nk, p, cin, cout) = (4, 5, 2, 3, 2);
        let k = Kernel::random(nk, cin, cout, &mut rng);
        let plan = ConvTransposePlan::new(ConvTransposeParams::new(n_in, nk, p, cin, cout), &k);
        let ho = plan.out_size();
        for n in [1usize, 3, 5] {
            let xb = FeatureBatch::random(n, n_in, n_in, cin, &mut rng);
            let dyb = FeatureBatch::random(n, ho, ho, cout, &mut rng);
            let mut scratch = Scratch::new();
            let mut dxb = FeatureBatch::zeros(n, n_in, n_in, cin);
            let mut dkb = plan.new_kernel_grad();
            dxb.data.fill(f32::NAN);
            dkb.data.fill(f32::NAN);
            plan.run_backward_batch(&xb, &dyb, &mut scratch, &mut dxb, &mut dkb);
            // Each dx image bit-identical to the single-image direct
            // lane; the accumulated dk bit-identical to the unfused
            // batched weight grad (same GEMMs in the same order).
            for i in 0..n {
                let mut want_dx = plan.new_input_grad();
                plan.run_backward_data(&dyb.feature(i), &mut scratch, &mut want_dx);
                assert_eq!(dxb.image(i), &want_dx.data[..], "fused batch dx image {i}");
            }
            let mut want_dk = plan.new_kernel_grad();
            plan.run_backward_weights_batch(&xb, &dyb, &mut scratch, &mut want_dk);
            assert!(
                dkb.data
                    .iter()
                    .zip(&want_dk.data)
                    .all(|(a, b)| a.to_bits() == b.to_bits()),
                "fused batch dk (n={n})"
            );
        }
    }

    #[test]
    fn backward_scratch_sizing_is_exact() {
        let mut rng = Rng::seeded(61);
        let k = Kernel::random(5, 3, 2, &mut rng);
        let plan = ConvTransposePlan::new(ConvTransposeParams::new(4, 5, 2, 3, 2), &k);
        let seg = segregate(&k);
        let geoms = unified::phase_geometries(4, 5, 2);
        let (cin, cout) = (3usize, 2usize);
        let slab: usize = geoms
            .iter()
            .map(|g| (g.rows.1 - g.rows.0) * (g.cols.1 - g.cols.0) * cin)
            .sum();
        let phase: usize = geoms.iter().map(|g| g.n_rows * g.n_cols * cout).sum();
        let pad: usize = geoms
            .iter()
            .map(|g| {
                let s = &seg.subs[g.sub];
                let sh = g.rows.1 - g.rows.0;
                let sw = g.cols.1 - g.cols.0;
                (sh + s.rows - 1) * (sw + s.cols - 1) * cout
            })
            .sum();
        let patch_bwd: usize = geoms
            .iter()
            .map(|g| {
                let s = &seg.subs[g.sub];
                (g.rows.1 - g.rows.0) * (g.cols.1 - g.cols.0) * s.rows * s.cols * cout
            })
            .max()
            .unwrap();
        let patch_fwd: usize = geoms
            .iter()
            .map(|g| {
                let s = &seg.subs[g.sub];
                g.n_rows * g.n_cols * s.rows * s.cols * cin
            })
            .max()
            .unwrap();
        let packed_dy: usize = geoms
            .iter()
            .map(|g| gemm::packed_b_floats(g.n_rows * g.n_cols, cout))
            .max()
            .unwrap();
        let dsub: usize = geoms
            .iter()
            .map(|g| {
                let s = &seg.subs[g.sub];
                s.rows * s.cols * cin * cout
            })
            .sum();
        assert_eq!(plan.scratch_floats_backward_data(), slab + pad);
        assert_eq!(
            plan.scratch_floats_backward_data_gemm(),
            slab + pad + patch_bwd
        );
        assert_eq!(
            plan.scratch_floats_backward_weights(),
            slab + phase + patch_fwd + packed_dy + dsub
        );
        assert_eq!(
            plan.scratch_floats_backward_fused(),
            slab + phase + pad + patch_fwd.max(patch_bwd) + packed_dy + dsub
        );
        assert_eq!(
            plan.peak_scratch_floats_backward(),
            plan.scratch_floats_backward_data_gemm()
                .max(plan.scratch_floats_backward_weights())
                .max(plan.scratch_floats_backward_fused())
        );
        // Cold arenas grow to exactly each lane's figure — the sizing
        // functions are tight bounds, not estimates.
        let ho = plan.out_size();
        let dy = Feature::random(ho, ho, cout, &mut rng);
        let x = Feature::random(4, 4, cin, &mut rng);
        let mut dx = plan.new_input_grad();
        let mut dk = plan.new_kernel_grad();
        let mut scratch = Scratch::new();
        plan.run_backward_data(&dy, &mut scratch, &mut dx);
        assert_eq!(
            scratch.capacity_floats(),
            plan.scratch_floats_backward_data()
        );
        let mut scratch = Scratch::new();
        plan.run_backward_data_gemm(&dy, &mut scratch, &mut dx);
        assert_eq!(
            scratch.capacity_floats(),
            plan.scratch_floats_backward_data_gemm()
        );
        let mut scratch = Scratch::new();
        plan.run_backward_weights(&x, &dy, &mut scratch, &mut dk);
        assert_eq!(
            scratch.capacity_floats(),
            plan.scratch_floats_backward_weights()
        );
        let mut scratch = Scratch::new();
        plan.run_backward(&x, &dy, &mut scratch, &mut dx, &mut dk);
        assert_eq!(
            scratch.capacity_floats(),
            plan.scratch_floats_backward_fused()
        );
    }
}
