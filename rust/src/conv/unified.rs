//! **The paper's contribution**: unified kernel-segregated transpose
//! convolution (Algorithm 2, Eqs. 1–4).
//!
//! No upsampled buffer is ever materialized.  Each output element
//! `(i, j)` is produced by correlating the *raw* input with the
//! sub-kernel selected at runtime from the output parity
//! (`k_{(i+P)%2, (j+P)%2}`, §3.4 role swap folded in), starting at input
//! offset `base(i) = ⌈(i − P)/2⌉`.
//!
//! Two formulations, numerically identical:
//!
//! * [`transpose_conv`] — **phase decomposition** (the optimized hot
//!   path): the parity selection is hoisted out of the inner loop, so
//!   each of the four phases becomes one dense VALID correlation over a
//!   contiguous input slab, written back with strided stores.  This is
//!   the TPU/MXU-shaped formulation (DESIGN.md §Hardware-Adaptation)
//!   and also what the Pallas kernel does.
//! * [`transpose_conv_per_element`] — the literal Algorithm 2 loop (one
//!   logical work-item per output element, runtime sub-kernel pick).
//!   Kept as the faithful-to-pseudocode lane and for the formulation
//!   ablation bench.

use crate::tensor::Feature;
use crate::util::threadpool;

use super::conventional::correlate_valid_into;
use super::segregation::{segregate, Segregated};
use super::out_size;
use crate::tensor::Kernel;

/// Static geometry of one parity phase (mirrors the Python
/// `_phase_geometry`; see `python/compile/kernels/unified.py`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PhaseGeometry {
    /// Output parity (row, col).
    pub rp: usize,
    pub sp: usize,
    /// Index into `Segregated::subs`.
    pub sub: usize,
    /// Zero-padding of the raw input: (top, bottom, left, right).
    pub pads: (usize, usize, usize, usize),
    /// Slab window in the padded input: rows `[row0, row1)`, cols
    /// `[col0, col1)`.
    pub rows: (usize, usize),
    pub cols: (usize, usize),
    /// Phase output extent.
    pub n_rows: usize,
    pub n_cols: usize,
}

/// Compute the four phase geometries for input `n`, kernel `nk`,
/// padding `p`.  Phases with an empty output are omitted.
pub fn phase_geometries(n: usize, nk: usize, p: usize) -> Vec<PhaseGeometry> {
    let ho = out_size(n, nk, p) as isize;
    let pi = p as isize;
    let ni = n as isize;
    let mut out = Vec::with_capacity(4);
    for rp in 0..2isize {
        for sp in 0..2isize {
            let r = ((rp + pi) % 2) as usize;
            let s = ((sp + pi) % 2) as usize;
            let kr = ((nk - r) as isize + 1) / 2; // ceil((nk - r)/2)
            let kc = ((nk - s) as isize + 1) / 2;
            let n_rows = if ho > rp { (ho - rp + 1) / 2 } else { 0 };
            let n_cols = if ho > sp { (ho - sp + 1) / 2 } else { 0 };
            if n_rows == 0 || n_cols == 0 || kr == 0 || kc == 0 {
                continue;
            }
            // base(i) = ceil((i - P)/2) at i = rp  (then +1 per phase row)
            let base0_r = (rp - pi).div_euclid(2) + ((rp - pi).rem_euclid(2) != 0) as isize;
            let base0_c = (sp - pi).div_euclid(2) + ((sp - pi).rem_euclid(2) != 0) as isize;
            let (lo_r, hi_r) = (base0_r, base0_r + n_rows - 1 + kr - 1);
            let (lo_c, hi_c) = (base0_c, base0_c + n_cols - 1 + kc - 1);
            let pad_lo_r = (-lo_r).max(0) as usize;
            let pad_hi_r = (hi_r - (ni - 1)).max(0) as usize;
            let pad_lo_c = (-lo_c).max(0) as usize;
            let pad_hi_c = (hi_c - (ni - 1)).max(0) as usize;
            out.push(PhaseGeometry {
                rp: rp as usize,
                sp: sp as usize,
                sub: r * 2 + s,
                pads: (pad_lo_r, pad_hi_r, pad_lo_c, pad_hi_c),
                rows: (
                    (lo_r + pad_lo_r as isize) as usize,
                    (hi_r + pad_lo_r as isize + 1) as usize,
                ),
                cols: (
                    (lo_c + pad_lo_c as isize) as usize,
                    (hi_c + pad_lo_c as isize + 1) as usize,
                ),
                n_rows: n_rows as usize,
                n_cols: n_cols as usize,
            });
        }
    }
    out
}

/// Build the contiguous input slab for one phase.
///
/// Single-copy: rows are cropped straight out of the raw input into a
/// fresh buffer, zero-filling only the pad margins — no full-input
/// clone and no padded intermediate (both existed here once; the
/// allocation-count test in `tests/plan_alloc.rs` pins their absence).
fn phase_slab(x: &Feature, g: &PhaseGeometry) -> Feature {
    let mut slab = Feature::zeros(g.rows.1 - g.rows.0, g.cols.1 - g.cols.0, x.c);
    build_slab(x, g, &mut slab.data);
    slab
}

/// Fill `dst` (a `slab_h × slab_w × C` row-major buffer) with the phase
/// slab: the window `g.rows × g.cols` of the virtually-padded input,
/// cropped directly from the raw input with pad margins zero-filled.
/// Every element of `dst` is written, so a dirty scratch region is safe
/// to reuse — the zero-alloc plan path (`conv::plan`) relies on this.
pub(crate) fn build_slab(x: &Feature, g: &PhaseGeometry, dst: &mut [f32]) {
    build_slab_view(&x.data, x.h, x.w, x.c, g, dst)
}

/// [`build_slab`] over a raw `[H, W, C]` row-major slice — the batched
/// execution lanes (`conv::plan`) crop slabs straight out of a
/// [`FeatureBatch`](crate::tensor::FeatureBatch) image view without
/// wrapping it in an owned `Feature`.  Same copies, same zero-fills, so
/// the two entry points are bit-identical.
pub(crate) fn build_slab_view(
    x: &[f32],
    h: usize,
    w: usize,
    c: usize,
    g: &PhaseGeometry,
    dst: &mut [f32],
) {
    debug_assert_eq!(x.len(), h * w * c, "build_slab_view: input size mismatch");
    let (pt, _pb, pl, _pr) = g.pads;
    let slab_h = g.rows.1 - g.rows.0;
    let slab_w = g.cols.1 - g.cols.0;
    debug_assert_eq!(dst.len(), slab_h * slab_w * c, "build_slab: dst size mismatch");
    // Raw-input column of slab column 0 (negative inside the left pad).
    let c0 = g.cols.0 as isize - pl as isize;
    let v0 = c0.max(0);
    let v1 = (c0 + slab_w as isize).min(w as isize);
    let left = (v0 - c0) as usize;
    let valid = (v1 - v0).max(0) as usize;
    for sy in 0..slab_h {
        let row = &mut dst[sy * slab_w * c..(sy + 1) * slab_w * c];
        let ry = (g.rows.0 + sy) as isize - pt as isize;
        if ry < 0 || ry >= h as isize || valid == 0 {
            row.fill(0.0);
            continue;
        }
        row[..left * c].fill(0.0);
        let src = (ry as usize * w + v0 as usize) * c;
        row[left * c..(left + valid) * c].copy_from_slice(&x[src..src + valid * c]);
        row[(left + valid) * c..].fill(0.0);
    }
}

/// Scatter a phase result into the strided positions of the output.
fn scatter_phase(out: &mut Feature, phase: &Feature, rp: usize, sp: usize) {
    scatter_rows(out, &phase.data, rp, sp, phase.h, phase.w);
}

/// Scatter an `n_rows × n_cols × C` phase buffer into the output
/// positions of parity `(rp, sp)` — the raw-slice form used by the
/// one-shot path above, the plan/execute path (`conv::plan`, direct
/// and phase-GEMM engines alike), and the §5 segregated-GEMM ablation
/// (`conv::im2col`), which interleaves whatever phases exist through
/// it (degenerate 1×1 outputs have fewer than four).
pub(crate) fn scatter_rows(
    out: &mut Feature,
    phase: &[f32],
    rp: usize,
    sp: usize,
    n_rows: usize,
    n_cols: usize,
) {
    let (w, c) = (out.w, out.c);
    scatter_rows_view(&mut out.data, w, c, phase, rp, sp, n_rows, n_cols)
}

/// [`scatter_rows`] over a raw `[H, W, C]` output slice — used by the
/// batched lanes to scatter each image's phase rows into its slice of
/// a [`FeatureBatch`](crate::tensor::FeatureBatch).  Same strided
/// copies, bit-identical to the `Feature` entry point.
#[allow(clippy::too_many_arguments)]
pub(crate) fn scatter_rows_view(
    out: &mut [f32],
    out_w: usize,
    c: usize,
    phase: &[f32],
    rp: usize,
    sp: usize,
    n_rows: usize,
    n_cols: usize,
) {
    for py in 0..n_rows {
        let y = rp + 2 * py;
        let mut dst = (y * out_w + sp) * c;
        let mut src = py * n_cols * c;
        for _ in 0..n_cols {
            out[dst..dst + c].copy_from_slice(&phase[src..src + c]);
            dst += 2 * c;
            src += c;
        }
    }
}

/// Unified transpose convolution from a pre-segregated kernel —
/// phase-decomposed hot path.
pub fn transpose_conv_seg(x: &Feature, seg: &Segregated, padding: usize) -> Feature {
    assert_eq!(x.h, x.w, "square inputs only (paper setting)");
    let ho = out_size(x.h, seg.n, padding);
    let cout = seg.subs[0].cout;
    let mut out = Feature::zeros(ho, ho, cout);
    for g in phase_geometries(x.h, seg.n, padding) {
        let slab = phase_slab(x, &g);
        let sub = &seg.subs[g.sub];
        let mut phase = Feature::zeros(g.n_rows, g.n_cols, cout);
        correlate_valid_into(&slab, sub, &mut phase.data, g.n_cols, 0, g.n_rows);
        scatter_phase(&mut out, &phase, g.rp, g.sp);
    }
    out
}

/// Unified transpose convolution (segregates internally).
pub fn transpose_conv(x: &Feature, k: &Kernel, padding: usize) -> Feature {
    transpose_conv_seg(x, &segregate(k), padding)
}

/// Literal Algorithm 2: one logical work-item per output element with a
/// runtime sub-kernel selection.  Faithful to the paper's pseudocode;
/// slower than the phase form on CPUs (the formulation ablation
/// quantifies by how much).
pub fn transpose_conv_per_element(x: &Feature, k: &Kernel, padding: usize) -> Feature {
    let seg = segregate(k);
    transpose_conv_per_element_seg(x, &seg, padding)
}

/// Per-element formulation from a pre-segregated kernel.
pub fn transpose_conv_per_element_seg(
    x: &Feature,
    seg: &Segregated,
    padding: usize,
) -> Feature {
    assert_eq!(x.h, x.w, "square inputs only (paper setting)");
    let n = x.h as isize;
    let ho = out_size(x.h, seg.n, padding);
    let cin = x.c;
    let cout = seg.subs[0].cout;
    let p = padding as isize;
    let mut out = Feature::zeros(ho, ho, cout);
    for i in 0..ho {
        let ii = i as isize;
        let base_i = (ii - p).div_euclid(2) + ((ii - p).rem_euclid(2) != 0) as isize;
        for j in 0..ho {
            let jj = j as isize;
            let base_j = (jj - p).div_euclid(2) + ((jj - p).rem_euclid(2) != 0) as isize;
            // Runtime sub-kernel selection: r ← (i+P)%2, s ← (j+P)%2.
            let sub = seg.for_output_parity(i % 2, j % 2, padding);
            let dst = out.idx(i, j, 0);
            let acc = &mut out.data[dst..dst + cout];
            for u in 0..sub.rows {
                let iy = base_i + u as isize;
                if iy < 0 || iy >= n {
                    continue; // zero padding
                }
                for v in 0..sub.cols {
                    let ix = base_j + v as isize;
                    if ix < 0 || ix >= n {
                        continue;
                    }
                    let px = x.pixel(iy as usize, ix as usize);
                    let tap = sub.tap(u, v);
                    for (ci, &xv) in px.iter().enumerate().take(cin) {
                        let trow = &tap[ci * cout..(ci + 1) * cout];
                        for (a, &t) in acc.iter_mut().zip(trow) {
                            *a += xv * t;
                        }
                    }
                }
            }
        }
    }
    out
}

/// Phase-decomposed parallel lane: phases × row-chunks over `workers`
/// threads.  The "GPU" emulation of the paper's unified CUDA kernel.
pub fn transpose_conv_par(x: &Feature, k: &Kernel, padding: usize, workers: usize) -> Feature {
    let seg = segregate(k);
    transpose_conv_par_seg(x, &seg, padding, workers)
}

/// Parallel phase-decomposed lane from a pre-segregated kernel.
pub fn transpose_conv_par_seg(
    x: &Feature,
    seg: &Segregated,
    padding: usize,
    workers: usize,
) -> Feature {
    assert_eq!(x.h, x.w, "square inputs only (paper setting)");
    let ho = out_size(x.h, seg.n, padding);
    let cout = seg.subs[0].cout;
    let mut out = Feature::zeros(ho, ho, cout);
    let geoms = phase_geometries(x.h, seg.n, padding);
    // Compute each phase into its own buffer in parallel (row-chunked),
    // then scatter serially (pure memcpy, memory-bound).
    let mut phases: Vec<Feature> = geoms
        .iter()
        .map(|g| Feature::zeros(g.n_rows, g.n_cols, cout))
        .collect();
    let slabs: Vec<Feature> = geoms.iter().map(|g| phase_slab(x, g)).collect();
    for ((g, slab), phase) in geoms.iter().zip(&slabs).zip(&mut phases) {
        let sub = &seg.subs[g.sub];
        let n_cols = g.n_cols;
        threadpool::parallel_chunks_mut(
            &mut phase.data,
            g.n_rows.max(1),
            workers,
            |row, chunk| {
                correlate_valid_into(slab, sub, chunk, n_cols, row, row + 1);
            },
        );
    }
    for (g, phase) in geoms.iter().zip(&phases) {
        scatter_phase(&mut out, phase, g.rp, g.sp);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::conventional;
    use crate::tensor::ops;
    use crate::util::prop::{close, forall_res, Config};
    use crate::util::rng::Rng;

    fn check_case(n_in: usize, nk: usize, p: usize, cin: usize, cout: usize, seed: u64) {
        let mut rng = Rng::seeded(seed);
        let x = Feature::random(n_in, n_in, cin, &mut rng);
        let k = Kernel::random(nk, cin, cout, &mut rng);
        let want = conventional::transpose_conv(&x, &k, p);
        let got = transpose_conv(&x, &k, p);
        assert_eq!((got.h, got.w, got.c), (want.h, want.w, want.c));
        assert!(
            ops::max_abs_diff(&want, &got) < 1e-4,
            "phase form mismatch n={n_in} k={nk} p={p}"
        );
        let got2 = transpose_conv_per_element(&x, &k, p);
        assert!(
            ops::max_abs_diff(&want, &got2) < 1e-4,
            "per-element mismatch n={n_in} k={nk} p={p}"
        );
    }

    #[test]
    fn fig6_worked_example_geometry() {
        // Fig. 5/6: input 4×4, kernel 5×5, conventional P=2 → output 7×7
        // (odd!), proposed effective input padding ⌊P/2⌋ = 1.
        let geoms = phase_geometries(4, 5, 2);
        assert_eq!(geoms.len(), 4);
        let g00 = geoms.iter().find(|g| (g.rp, g.sp) == (0, 0)).unwrap();
        // Even P → parity (0,0) uses k00 and pads the raw input by 1.
        assert_eq!(g00.sub, 0);
        assert_eq!(g00.pads, (1, 1, 1, 1));
        assert_eq!((g00.n_rows, g00.n_cols), (4, 4));
        // Output 7×7 is odd: phase (1,1) covers only 3×3.
        let g11 = geoms.iter().find(|g| (g.rp, g.sp) == (1, 1)).unwrap();
        assert_eq!((g11.n_rows, g11.n_cols), (3, 3));
    }

    #[test]
    fn fig6_numeric_equivalence() {
        check_case(4, 5, 2, 3, 2, 10);
    }

    #[test]
    fn gan_layer_equivalence() {
        check_case(4, 4, 2, 8, 4, 11);
        check_case(8, 4, 2, 4, 2, 12);
    }

    #[test]
    fn odd_padding_role_swap() {
        check_case(5, 3, 1, 2, 2, 13);
        check_case(7, 5, 3, 2, 1, 14);
    }

    #[test]
    fn no_padding() {
        check_case(4, 5, 0, 1, 2, 15);
        check_case(3, 2, 0, 2, 2, 16);
    }

    #[test]
    fn degenerate_single_pixel() {
        check_case(1, 3, 2, 1, 1, 17);
    }

    #[test]
    fn parallel_matches_serial() {
        let mut rng = Rng::seeded(18);
        let x = Feature::random(9, 9, 3, &mut rng);
        let k = Kernel::random(5, 3, 4, &mut rng);
        let want = transpose_conv(&x, &k, 2);
        for workers in [1, 2, 3, 8] {
            let got = transpose_conv_par(&x, &k, 2, workers);
            assert!(ops::max_abs_diff(&want, &got) < 1e-5);
        }
    }

    #[test]
    fn prop_unified_equals_conventional() {
        forall_res(
            Config::default().cases(60),
            "unified == conventional (Alg.2 == Alg.1)",
            |rng| {
                let n_in = rng.range(1, 8);
                let nk = rng.range(2, 6);
                let p = rng.range(0, 3);
                if 2 * n_in + 2 * p <= nk {
                    return ((n_in, nk, p, 0, 0), Ok(())); // invalid geometry
                }
                let cin = rng.range(1, 4);
                let cout = rng.range(1, 3);
                let mut r2 = rng.split();
                let x = Feature::random(n_in, n_in, cin, &mut r2);
                let k = Kernel::random(nk, cin, cout, &mut r2);
                let want = conventional::transpose_conv(&x, &k, p);
                let got = transpose_conv(&x, &k, p);
                let res = close(&want.data, &got.data, 1e-3);
                ((n_in, nk, p, cin, cout), res)
            },
        );
    }

    #[test]
    fn prop_per_element_equals_phase_form() {
        forall_res(
            Config::default().cases(40),
            "per-element == phase decomposition",
            |rng| {
                let n_in = rng.range(1, 7);
                let nk = rng.range(2, 5);
                let p = rng.range(0, 3);
                if 2 * n_in + 2 * p <= nk {
                    return ((n_in, nk, p), Ok(()));
                }
                let mut r2 = rng.split();
                let x = Feature::random(n_in, n_in, 2, &mut r2);
                let k = Kernel::random(nk, 2, 2, &mut r2);
                let a = transpose_conv(&x, &k, p);
                let b = transpose_conv_per_element(&x, &k, p);
                ((n_in, nk, p), close(&a.data, &b.data, 1e-4))
            },
        );
    }

    #[test]
    fn build_slab_matches_pad_then_crop() {
        let mut rng = Rng::seeded(19);
        for (n, nk, p) in [(4, 5, 2), (4, 4, 2), (5, 3, 1), (1, 3, 2), (6, 4, 0)] {
            let x = Feature::random(n, n, 3, &mut rng);
            for g in phase_geometries(n, nk, p) {
                let (pt, pb, pl, pr) = g.pads;
                let padded = ops::pad_asym(&x, pt, pb, pl, pr);
                let want = ops::crop(
                    &padded,
                    g.rows.0,
                    g.cols.0,
                    g.rows.1 - g.rows.0,
                    g.cols.1 - g.cols.0,
                );
                let got = phase_slab(&x, &g);
                assert_eq!(got, want, "n={n} nk={nk} p={p} phase ({},{})", g.rp, g.sp);
            }
        }
    }

    #[test]
    fn build_slab_overwrites_dirty_scratch() {
        // The plan path reuses scratch regions without clearing them
        // first — every slab element must be written.
        let mut rng = Rng::seeded(20);
        let x = Feature::random(4, 4, 2, &mut rng);
        for g in phase_geometries(4, 5, 2) {
            let want = phase_slab(&x, &g);
            let mut dirty = vec![f32::NAN; want.data.len()];
            build_slab(&x, &g, &mut dirty);
            assert!(
                dirty
                    .iter()
                    .zip(&want.data)
                    .all(|(a, b)| a.to_bits() == b.to_bits()),
                "stale data survived in phase ({},{})",
                g.rp,
                g.sp
            );
        }
    }

    #[test]
    fn phase_geometry_covers_output_exactly() {
        // Union of phase extents == output size, no overlap (partition).
        for (n, nk, p) in [(4, 5, 2), (4, 4, 2), (5, 3, 1), (7, 5, 3), (6, 4, 0)] {
            let ho = out_size(n, nk, p);
            let total: usize = phase_geometries(n, nk, p)
                .iter()
                .map(|g| g.n_rows * g.n_cols)
                .sum();
            assert_eq!(total, ho * ho, "n={n} nk={nk} p={p}");
        }
    }
}
