//! Runtime ISA dispatch for the phase-GEMM microkernel and the direct
//! per-phase inner loops (DESIGN.md §GEMM-Execution §SIMD-Dispatch).
//!
//! The phase-segregated formulation keeps every inner loop dense and
//! branch-free — exactly the shape SIMD wants (GANAX's argument for
//! phase-segregated deconvolution, PAPERS.md).  This module turns that
//! shape into explicit `std::arch` lanes:
//!
//! * **[`Isa`]** — the lane taxonomy: `scalar` (portable reference),
//!   `avx2` (AVX2+FMA, 8-wide f32), `avx512` (AVX-512F, 16-wide) and
//!   `neon` (AArch64, 4-wide).  Detection runs **once per process**
//!   ([`Isa::active`], `std::arch` runtime feature macros behind a
//!   `OnceLock`) so steady-state execution never re-detects and never
//!   allocates.
//! * **[`Microkernel`]** — the dispatch table entry: register-tile
//!   geometry (`mr × nr`) plus the `#[target_feature]` tile kernel for
//!   the lane.  The B-panel width of the packed GEMM operands
//!   (`gemm::pack_b`) equals the *active* lane's `nr`, so plan-time
//!   packing always produces the panel width the production kernel
//!   streams; the scalar lane reads panels of any width and is
//!   therefore always available as fallback and correctness reference.
//! * **[`saxpy_kernel`]** — the direct formulation's rank-1 update
//!   (`acc[co] += x · tap[co]`), vectorized with **mul+add, never FMA**:
//!   each output lane accumulates in exactly the scalar order and
//!   rounding, keeping the direct lanes' bit-identity contract with the
//!   one-shot reference (`tests/conv_properties.rs`) intact.
//!
//! ## Tile geometry per ISA
//!
//! The x86 f32 tiles run **split-K**: two K-interleaved accumulator
//! sets (even/odd taps) summed at the epilogue, halving the FMA-chain
//! depth per output element (reassociation covered by the callers'
//! 1e-4 GEMM tolerance).
//!
//! | lane   | tile (mr×nr) | accumulators                      |
//! |--------|--------------|-----------------------------------|
//! | scalar | 4×8          | LLVM-allocated from `[[f32;8];4]` |
//! | avx2   | 6×16         | 2×12 ymm chains (split-K; partial spill) |
//! | avx512 | 8×32         | 2×16 zmm chains (split-K; exactly fills 32 regs) |
//! | neon   | 8×8          | 16 acc + 2 B + 1 dup of 32 q-regs |
//!
//! ## Reduced-precision widening lanes
//!
//! The quantized phase-GEMM kernels (`conv::quant`) get AVX2 lanes
//! here: `gemm_q16_f16_avx2` converts f16 (F16C `vcvtph2ps`) panels to
//! f32 on load, `gemm_q16_bf16_avx2` widens bf16 with an integer
//! shift, and `gemm_q8_avx2` runs **`vpmaddwd` i16→i32 k-pairs**
//! (sign-extended, so the pair sum is bounded at `2·127²` and can
//! never saturate) with an exact-widening odd-k tail.  The float
//! lanes use plain mul+add in the scalar kernels' k-ascending order
//! and the int8 lane accumulates exactly in i32 (associative), so all
//! are **bit-identical** to the `conv::quant` scalar references on
//! finite data — the quantized lanes keep one numeric contract across
//! ISAs.
//!
//! ## Safety
//!
//! Every intrinsic block lives inside a `#[target_feature]` function
//! that is only ever *selected* after the matching `std::arch` runtime
//! detection macro returned true ([`Isa::detect`]), and only ever
//! *called* through [`Microkernel::for_isa`], which falls back to the
//! scalar lane for any ISA the host did not report.  The tile kernels'
//! pointer contract (documented on [`TileKernel`]) is discharged by the
//! single call site in `gemm::gemm_packed_with`, which only takes the
//! vector path for full `mr × nr` tiles inside bounds-checked slices.
//! The crate denies `unsafe_op_in_unsafe_fn`, so every unsafe operation
//! below sits in an explicit `unsafe` block with this argument.

use std::sync::OnceLock;

/// One SIMD instruction-set lane of the phase-GEMM microkernel.
///
/// `scalar` is always available; a vector lane is *available* only when
/// it is the host's detected best lane ([`Isa::active`]) — panel
/// geometry follows the active lane, so a narrower vector kernel could
/// not read the packed operands anyway.  Unavailable lanes silently
/// degrade to scalar ([`Microkernel::for_isa`]), which keeps decoded
/// tuning-cache strategies from foreign hosts runnable.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Isa {
    /// Portable scalar tile — fallback and correctness reference.
    Scalar,
    /// AVX2 + FMA, 256-bit lanes (x86-64).
    Avx2,
    /// AVX-512F, 512-bit lanes (x86-64).
    Avx512,
    /// NEON, 128-bit lanes (AArch64).
    Neon,
}

impl Isa {
    /// Stable lane name — used in strategy names, cache fingerprints
    /// (`cpu{n}+{isa}`) and the CLI `--isa` flag.
    pub fn name(self) -> &'static str {
        match self {
            Isa::Scalar => "scalar",
            Isa::Avx2 => "avx2",
            Isa::Avx512 => "avx512",
            Isa::Neon => "neon",
        }
    }

    /// Inverse of [`name`](Self::name); `None` for unknown names.
    pub fn parse(name: &str) -> Option<Isa> {
        match name {
            "scalar" => Some(Isa::Scalar),
            "avx2" => Some(Isa::Avx2),
            "avx512" => Some(Isa::Avx512),
            "neon" => Some(Isa::Neon),
            _ => None,
        }
    }

    /// Trace-lane tag for phase-GEMM spans (`obs::trace`): the GEMM
    /// family plus the dispatched microkernel, e.g. `gemm/avx2`.
    pub fn gemm_lane_tag(self) -> &'static str {
        match self {
            Isa::Scalar => "gemm/scalar",
            Isa::Avx2 => "gemm/avx2",
            Isa::Avx512 => "gemm/avx512",
            Isa::Neon => "gemm/neon",
        }
    }

    /// Native register-tile geometry `(mr, nr)` of the lane's kernel.
    pub fn tile(self) -> (usize, usize) {
        match self {
            Isa::Scalar => (4, 8),
            Isa::Avx2 => (6, 16),
            Isa::Avx512 => (8, 32),
            Isa::Neon => (8, 8),
        }
    }

    /// Raw runtime feature detection: the best lane this host supports.
    /// Callers want [`active`](Self::active), which runs this once.
    pub fn detect() -> Isa {
        #[cfg(target_arch = "x86_64")]
        {
            if std::arch::is_x86_feature_detected!("avx512f") {
                return Isa::Avx512;
            }
            if std::arch::is_x86_feature_detected!("avx2")
                && std::arch::is_x86_feature_detected!("fma")
            {
                return Isa::Avx2;
            }
        }
        #[cfg(target_arch = "aarch64")]
        {
            if std::arch::is_aarch64_feature_detected!("neon") {
                return Isa::Neon;
            }
        }
        Isa::Scalar
    }

    /// The process-wide selected lane: detected once, cached forever.
    /// Everything downstream — panel width of the packed operands,
    /// default GEMM dispatch, the tuning-cache host fingerprint — keys
    /// off this single selection, so it can never change mid-process.
    pub fn active() -> Isa {
        static ACTIVE: OnceLock<Isa> = OnceLock::new();
        *ACTIVE.get_or_init(Isa::detect)
    }

    /// The lanes usable on this host, production lane first: the
    /// detected vector lane (if any) then `scalar`.  This is what the
    /// tuner's microkernel axis enumerates.
    pub fn supported() -> Vec<Isa> {
        match Isa::active() {
            Isa::Scalar => vec![Isa::Scalar],
            vector => vec![vector, Isa::Scalar],
        }
    }

    /// True when [`Microkernel::for_isa`] would run this lane natively
    /// (rather than degrade to scalar).
    pub fn is_available(self) -> bool {
        self == Isa::Scalar || self == Isa::active()
    }
}

impl std::fmt::Display for Isa {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Full-tile kernel contract: computes
/// `C[0..mr, 0..nr] += A[0..mr, 0..kc] · panel` where
///
/// * `a` points at the tile's first A element, row stride `lda`, and
///   `mr` rows × `kc` elements are readable;
/// * `panel` is the `kc × nr` packed B block (contiguous,
///   [`pack_b`](super::gemm::pack_b) layout);
/// * `c` points at the tile's first C element, row stride `ldc`, and
///   `mr` rows × `nr` elements are readable and writable;
/// * the required target features were runtime-detected.
///
/// Unaligned access is allowed (the kernels use unaligned loads).
pub(crate) type TileKernel =
    unsafe fn(a: *const f32, lda: usize, panel: *const f32, c: *mut f32, ldc: usize, kc: usize);

/// One row of the microkernel dispatch table: the lane, its register
/// tile, and (for vector lanes) the `#[target_feature]` tile kernel.
/// `kernel == None` means the generic scalar tile path runs.
#[derive(Clone, Copy)]
pub struct Microkernel {
    pub isa: Isa,
    /// Register-tile rows the kernel computes at once.
    pub mr: usize,
    /// Register-tile columns == the B-panel width the kernel streams.
    pub nr: usize,
    pub(crate) kernel: Option<TileKernel>,
}

impl std::fmt::Debug for Microkernel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Microkernel")
            .field("isa", &self.isa)
            .field("mr", &self.mr)
            .field("nr", &self.nr)
            .field("vector", &self.kernel.is_some())
            .finish()
    }
}

impl Microkernel {
    /// The scalar row of the table.  Its `nr` follows the **active**
    /// panel width so the scalar lane can always consume whatever the
    /// plan packed — the forced-fallback guarantee.
    pub fn scalar() -> Microkernel {
        Microkernel {
            isa: Isa::Scalar,
            mr: Isa::Scalar.tile().0,
            nr: panel_width(),
            kernel: None,
        }
    }

    /// The table row for `isa`, degrading to [`scalar`](Self::scalar)
    /// when the lane is not available on this host (wrong arch,
    /// feature not detected, or not the active lane — panel widths
    /// would mismatch).  Never panics: any `Isa` decoded from a tuning
    /// cache is safe to execute.
    pub fn for_isa(isa: Isa) -> Microkernel {
        if isa == Isa::Scalar || !isa.is_available() {
            return Microkernel::scalar();
        }
        Microkernel::vector(isa).unwrap_or_else(Microkernel::scalar)
    }

    /// The dispatch table's row for the process-wide active lane.
    pub fn active() -> Microkernel {
        Microkernel::for_isa(Isa::active())
    }

    #[cfg(target_arch = "x86_64")]
    fn vector(isa: Isa) -> Option<Microkernel> {
        let (mr, nr) = isa.tile();
        let kernel: TileKernel = match isa {
            Isa::Avx2 => x86::tile_avx2,
            Isa::Avx512 => x86::tile_avx512,
            _ => return None,
        };
        Some(Microkernel {
            isa,
            mr,
            nr,
            kernel: Some(kernel),
        })
    }

    #[cfg(target_arch = "aarch64")]
    fn vector(isa: Isa) -> Option<Microkernel> {
        let (mr, nr) = isa.tile();
        let kernel: TileKernel = match isa {
            Isa::Neon => arm::tile_neon,
            _ => return None,
        };
        Some(Microkernel {
            isa,
            mr,
            nr,
            kernel: Some(kernel),
        })
    }

    #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
    fn vector(_isa: Isa) -> Option<Microkernel> {
        None
    }
}

/// The B-panel width every packed GEMM operand in this process uses:
/// the active lane's `nr`.  `gemm::pack_b` / `gemm::packed_b_floats`
/// derive from this, so plan-time packing, runtime `dy` packing and the
/// analytic memory accounting (`conv::memory`) all agree by
/// construction.
pub fn panel_width() -> usize {
    Isa::active().tile().1
}

/// The direct formulation's inner rank-1 update as a plain function
/// pointer: `acc[j] += x * t[j]` for every `j`.  Mul+add only (no FMA,
/// no horizontal reduction), so every lane is **bit-identical** to the
/// scalar loop — the direct lanes' `==` contract with the one-shot
/// reference survives vectorization.
pub(crate) type SaxpyFn = fn(&mut [f32], f32, &[f32]);

/// The active lane's saxpy, selected once per process.  Hot callers
/// (`conventional::correlate_rows`) hoist the returned pointer out of
/// their pixel loops.
pub(crate) fn saxpy_kernel() -> SaxpyFn {
    static SAXPY: OnceLock<SaxpyFn> = OnceLock::new();
    *SAXPY.get_or_init(|| saxpy_for(Isa::active()))
}

/// The saxpy lane for `isa`, degrading to scalar when unavailable —
/// the test seam for per-lane bit-identity.
pub(crate) fn saxpy_for(isa: Isa) -> SaxpyFn {
    if !isa.is_available() {
        return saxpy_scalar;
    }
    match isa {
        Isa::Scalar => saxpy_scalar,
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => saxpy_avx2,
        #[cfg(target_arch = "x86_64")]
        Isa::Avx512 => saxpy_avx512,
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => saxpy_neon,
        #[allow(unreachable_patterns)]
        _ => saxpy_scalar,
    }
}

fn saxpy_scalar(acc: &mut [f32], x: f32, t: &[f32]) {
    debug_assert_eq!(acc.len(), t.len());
    for (a, &tv) in acc.iter_mut().zip(t) {
        *a += x * tv;
    }
}

#[cfg(target_arch = "x86_64")]
fn saxpy_avx2(acc: &mut [f32], x: f32, t: &[f32]) {
    // SAFETY: only reachable through `saxpy_for` after `is_available`
    // confirmed the runtime detection saw AVX2 (§Safety above).
    unsafe { x86::saxpy_avx2(acc, x, t) }
}

#[cfg(target_arch = "x86_64")]
fn saxpy_avx512(acc: &mut [f32], x: f32, t: &[f32]) {
    // SAFETY: as `saxpy_avx2`, for AVX-512F.
    unsafe { x86::saxpy_avx512(acc, x, t) }
}

#[cfg(target_arch = "aarch64")]
fn saxpy_neon(acc: &mut [f32], x: f32, t: &[f32]) {
    // SAFETY: as the x86 wrappers, for NEON.
    unsafe { arm::saxpy_neon(acc, x, t) }
}

/// True when the AVX2 widening lanes for the bf16/int8 quantized GEMMs
/// can run on this host.  Detected independently of the active f32
/// lane: quantized panels have a fixed ISA-independent width
/// ([`quant::QNR`](super::quant::QNR)), so the widening kernels are
/// usable even when the f32 engine runs AVX-512 (or scalar on an
/// FMA-less AVX2 host).
pub(crate) fn quant_avx2_available() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        static OK: OnceLock<bool> = OnceLock::new();
        *OK.get_or_init(|| std::arch::is_x86_feature_detected!("avx2"))
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// True when the AVX2 + F16C f16 widening lane can run (the f16 kernel
/// converts packed halves with `vcvtph2ps`).
pub(crate) fn quant_f16c_available() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        static OK: OnceLock<bool> = OnceLock::new();
        *OK.get_or_init(|| {
            quant_avx2_available() && std::arch::is_x86_feature_detected!("f16c")
        })
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// AVX2+F16C f16 widening GEMM (bit-identical to
/// `quant::gemm_q16_scalar` with the f16 decoder).
#[cfg(target_arch = "x86_64")]
pub(crate) fn gemm_q16_f16_avx2(
    a: &[u16],
    packed_b: &[u16],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
) {
    // SAFETY: callers gate on `quant_f16c_available()`; the operand
    // bounds are asserted by the `gemm` driver before dispatch and
    // re-checked by the kernel's debug asserts.
    unsafe { x86::gemm_q16_f16(a, packed_b, c, m, k, n) }
}

/// AVX2 bf16 widening GEMM (bit-identical to `quant::gemm_q16_scalar`
/// with the bf16 decoder).
#[cfg(target_arch = "x86_64")]
pub(crate) fn gemm_q16_bf16_avx2(
    a: &[u16],
    packed_b: &[u16],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
) {
    // SAFETY: callers gate on `quant_avx2_available()`; bounds as above.
    unsafe { x86::gemm_q16_bf16(a, packed_b, c, m, k, n) }
}

/// AVX2 int8 GEMM via sign-extended `madd` i16→i32 k-pairs with exact
/// i32 accumulation (bit-identical to `quant::gemm_q8_scalar`).
#[cfg(target_arch = "x86_64")]
#[allow(clippy::too_many_arguments)]
pub(crate) fn gemm_q8_avx2(
    a: &[i8],
    a_scale: f32,
    packed_b: &[i8],
    b_scales: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
) {
    // SAFETY: callers gate on `quant_avx2_available()`; bounds as above.
    unsafe { x86::gemm_q8(a, a_scale, packed_b, b_scales, c, m, k, n) }
}

#[cfg(target_arch = "x86_64")]
mod x86 {
    use core::arch::x86_64::*;

    use crate::conv::quant::{self, QNR};

    /// AVX2+FMA 6×16 tile, split-K: **two K-interleaved accumulator
    /// sets** (even taps in one, odd taps in the other) summed at the
    /// epilogue, so each output element is fed by two independent FMA
    /// chains of half the depth — halving the latency-bound
    /// serialization of the K loop.  The doubled set (24 virtual ymm
    /// accumulators) exceeds the 16 architectural registers, so LLVM
    /// spills part of one chain; the hot B/broadcast operands stay
    /// registered and the chain split still shortens the critical
    /// path.  Splitting reassociates the per-element sum — covered by
    /// the callers' 1e-4 GEMM tolerance (see `conv::gemm`), verified
    /// by `tests/simd_equiv.rs`.  Contract:
    /// [`TileKernel`](super::TileKernel) with `mr = 6`, `nr = 16`.
    #[target_feature(enable = "avx2,fma")]
    pub(super) unsafe fn tile_avx2(
        a: *const f32,
        lda: usize,
        panel: *const f32,
        c: *mut f32,
        ldc: usize,
        kc: usize,
    ) {
        // SAFETY: the caller (gemm_packed_with) discharges the
        // TileKernel pointer contract — every load/store below stays
        // inside the mr×kc A strip, the kc×16 panel block and the
        // mr×16 C tile it sliced bounds-checked before taking raw
        // pointers; unaligned intrinsics are used throughout.
        unsafe {
            // Even chain starts from C, odd chain from zero; the
            // epilogue adds the two partial sums.
            let mut acc = [[_mm256_setzero_ps(); 2]; 6];
            let mut odd = [[_mm256_setzero_ps(); 2]; 6];
            for (i, row) in acc.iter_mut().enumerate() {
                row[0] = _mm256_loadu_ps(c.add(i * ldc));
                row[1] = _mm256_loadu_ps(c.add(i * ldc + 8));
            }
            let mut kk = 0;
            while kk + 2 <= kc {
                let b0 = _mm256_loadu_ps(panel.add(kk * 16));
                let b1 = _mm256_loadu_ps(panel.add(kk * 16 + 8));
                let d0 = _mm256_loadu_ps(panel.add((kk + 1) * 16));
                let d1 = _mm256_loadu_ps(panel.add((kk + 1) * 16 + 8));
                for i in 0..6 {
                    let av = _mm256_set1_ps(*a.add(i * lda + kk));
                    acc[i][0] = _mm256_fmadd_ps(av, b0, acc[i][0]);
                    acc[i][1] = _mm256_fmadd_ps(av, b1, acc[i][1]);
                    let aw = _mm256_set1_ps(*a.add(i * lda + kk + 1));
                    odd[i][0] = _mm256_fmadd_ps(aw, d0, odd[i][0]);
                    odd[i][1] = _mm256_fmadd_ps(aw, d1, odd[i][1]);
                }
                kk += 2;
            }
            if kk < kc {
                let b0 = _mm256_loadu_ps(panel.add(kk * 16));
                let b1 = _mm256_loadu_ps(panel.add(kk * 16 + 8));
                for (i, row) in acc.iter_mut().enumerate() {
                    let av = _mm256_set1_ps(*a.add(i * lda + kk));
                    row[0] = _mm256_fmadd_ps(av, b0, row[0]);
                    row[1] = _mm256_fmadd_ps(av, b1, row[1]);
                }
            }
            for (i, row) in acc.iter().enumerate() {
                _mm256_storeu_ps(c.add(i * ldc), _mm256_add_ps(row[0], odd[i][0]));
                _mm256_storeu_ps(c.add(i * ldc + 8), _mm256_add_ps(row[1], odd[i][1]));
            }
        }
    }

    /// AVX-512F 8×32 tile, split-K: two K-interleaved accumulator sets
    /// summed at the epilogue (see `tile_avx2`).  The doubled set — 32
    /// zmm accumulators — exactly fills the 32 architectural AVX-512
    /// registers, so both chains stay registered (B vectors and the
    /// broadcast re-materialize from memory).  Reassociation covered by
    /// the callers' 1e-4 tolerance.  Contract:
    /// [`TileKernel`](super::TileKernel) with `mr = 8`, `nr = 32`.
    #[target_feature(enable = "avx512f")]
    pub(super) unsafe fn tile_avx512(
        a: *const f32,
        lda: usize,
        panel: *const f32,
        c: *mut f32,
        ldc: usize,
        kc: usize,
    ) {
        // SAFETY: same pointer contract as `tile_avx2`, at nr = 32.
        unsafe {
            let mut acc = [[_mm512_setzero_ps(); 2]; 8];
            let mut odd = [[_mm512_setzero_ps(); 2]; 8];
            for (i, row) in acc.iter_mut().enumerate() {
                row[0] = _mm512_loadu_ps(c.add(i * ldc));
                row[1] = _mm512_loadu_ps(c.add(i * ldc + 16));
            }
            let mut kk = 0;
            while kk + 2 <= kc {
                let b0 = _mm512_loadu_ps(panel.add(kk * 32));
                let b1 = _mm512_loadu_ps(panel.add(kk * 32 + 16));
                let d0 = _mm512_loadu_ps(panel.add((kk + 1) * 32));
                let d1 = _mm512_loadu_ps(panel.add((kk + 1) * 32 + 16));
                for i in 0..8 {
                    let av = _mm512_set1_ps(*a.add(i * lda + kk));
                    acc[i][0] = _mm512_fmadd_ps(av, b0, acc[i][0]);
                    acc[i][1] = _mm512_fmadd_ps(av, b1, acc[i][1]);
                    let aw = _mm512_set1_ps(*a.add(i * lda + kk + 1));
                    odd[i][0] = _mm512_fmadd_ps(aw, d0, odd[i][0]);
                    odd[i][1] = _mm512_fmadd_ps(aw, d1, odd[i][1]);
                }
                kk += 2;
            }
            if kk < kc {
                let b0 = _mm512_loadu_ps(panel.add(kk * 32));
                let b1 = _mm512_loadu_ps(panel.add(kk * 32 + 16));
                for (i, row) in acc.iter_mut().enumerate() {
                    let av = _mm512_set1_ps(*a.add(i * lda + kk));
                    row[0] = _mm512_fmadd_ps(av, b0, row[0]);
                    row[1] = _mm512_fmadd_ps(av, b1, row[1]);
                }
            }
            for (i, row) in acc.iter().enumerate() {
                _mm512_storeu_ps(c.add(i * ldc), _mm512_add_ps(row[0], odd[i][0]));
                _mm512_storeu_ps(c.add(i * ldc + 16), _mm512_add_ps(row[1], odd[i][1]));
            }
        }
    }

    /// f16 widening GEMM over [`QNR`]-column panels: each panel row of
    /// 8 halves converts with one `vcvtph2ps`, the A element decodes in
    /// software (both conversions are exact, so scalar and vector see
    /// identical f32 operands), and the accumulator uses mul+add in
    /// k-ascending order — bit-identical to `quant::gemm_q16_scalar`
    /// on finite data.
    #[target_feature(enable = "avx2,f16c")]
    pub(super) unsafe fn gemm_q16_f16(
        a: &[u16],
        packed_b: &[u16],
        c: &mut [f32],
        m: usize,
        k: usize,
        n: usize,
    ) {
        debug_assert_eq!(a.len(), m * k);
        debug_assert_eq!(packed_b.len(), quant::packed_qb_elems(k, n));
        debug_assert_eq!(c.len(), m * n);
        let panels = n.div_ceil(QNR);
        // SAFETY: every panel pointer below reads 8 u16 at offset
        // kk·QNR of a k·QNR-element panel slice (kk < k), and the
        // epilogue stores into a local [f32; QNR] — all in bounds.
        unsafe {
            for jp in 0..panels {
                let j0 = jp * QNR;
                let jn = QNR.min(n - j0);
                let panel = &packed_b[jp * k * QNR..(jp + 1) * k * QNR];
                for i in 0..m {
                    let arow = &a[i * k..(i + 1) * k];
                    let mut acc = _mm256_setzero_ps();
                    for (kk, &ab) in arow.iter().enumerate() {
                        let av = _mm256_set1_ps(quant::f16_bits_to_f32(ab));
                        let bh = _mm_loadu_si128(panel.as_ptr().add(kk * QNR) as *const __m128i);
                        let bv = _mm256_cvtph_ps(bh);
                        acc = _mm256_add_ps(_mm256_mul_ps(av, bv), acc);
                    }
                    let mut buf = [0.0f32; QNR];
                    _mm256_storeu_ps(buf.as_mut_ptr(), acc);
                    for (jj, &s) in buf[..jn].iter().enumerate() {
                        c[i * n + j0 + jj] += s;
                    }
                }
            }
        }
    }

    /// bf16 widening GEMM: panel rows widen with an integer
    /// `u16 → u32 << 16` (exact by construction).  Same mul+add
    /// contract as `gemm_q16_f16`.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn gemm_q16_bf16(
        a: &[u16],
        packed_b: &[u16],
        c: &mut [f32],
        m: usize,
        k: usize,
        n: usize,
    ) {
        debug_assert_eq!(a.len(), m * k);
        debug_assert_eq!(packed_b.len(), quant::packed_qb_elems(k, n));
        debug_assert_eq!(c.len(), m * n);
        let panels = n.div_ceil(QNR);
        // SAFETY: bounds as `gemm_q16_f16`.
        unsafe {
            for jp in 0..panels {
                let j0 = jp * QNR;
                let jn = QNR.min(n - j0);
                let panel = &packed_b[jp * k * QNR..(jp + 1) * k * QNR];
                for i in 0..m {
                    let arow = &a[i * k..(i + 1) * k];
                    let mut acc = _mm256_setzero_ps();
                    for (kk, &ab) in arow.iter().enumerate() {
                        let av = _mm256_set1_ps(quant::bf16_bits_to_f32(ab));
                        let bh = _mm_loadu_si128(panel.as_ptr().add(kk * QNR) as *const __m128i);
                        let bv = _mm256_castsi256_ps(_mm256_slli_epi32(
                            _mm256_cvtepu16_epi32(bh),
                            16,
                        ));
                        acc = _mm256_add_ps(_mm256_mul_ps(av, bv), acc);
                    }
                    let mut buf = [0.0f32; QNR];
                    _mm256_storeu_ps(buf.as_mut_ptr(), acc);
                    for (jj, &s) in buf[..jn].iter().enumerate() {
                        c[i * n + j0 + jj] += s;
                    }
                }
            }
        }
    }

    /// int8 GEMM via **`vpmaddwd` k-pairs**: taps `kk` and `kk+1`
    /// sign-extend to i16 (`vpmovsxbw`) and interleave so each 32-bit
    /// lane of `_mm256_madd_epi16` computes
    /// `a[kk]·b[kk][j] + a[kk+1]·b[kk+1][j]` — two MACs per lane per
    /// instruction, versus one for the old `vpmulld`+`vpaddd` widening
    /// loop.  The pair product is exact: `|a|,|b| ≤ 127` bounds each
    /// term at `127² = 16129` and the pair sum at `32258`, far inside
    /// i32, so `madd` can never saturate (unlike `maddubs`, whose
    /// u8×i8 i16 pair-sum saturates — that is why the sign-extended
    /// `madd` form is used).  i32 accumulation is associative, so the
    /// lane stays **bit-identical** to `quant::gemm_q8_scalar` always,
    /// with the same single scaled f32 epilogue.  The odd-k remainder
    /// runs one exact widened tap.
    #[target_feature(enable = "avx2")]
    #[allow(clippy::too_many_arguments)]
    pub(super) unsafe fn gemm_q8(
        a: &[i8],
        a_scale: f32,
        packed_b: &[i8],
        b_scales: &[f32],
        c: &mut [f32],
        m: usize,
        k: usize,
        n: usize,
    ) {
        debug_assert_eq!(a.len(), m * k);
        debug_assert_eq!(packed_b.len(), quant::packed_qb_elems(k, n));
        debug_assert_eq!(b_scales.len(), n);
        debug_assert_eq!(c.len(), m * n);
        let panels = n.div_ceil(QNR);
        // SAFETY: each `_mm_loadl_epi64` reads 8 bytes at offset
        // kk·QNR of a k·QNR-byte panel slice (kk < k, and kk+1 < k on
        // the paired path); stores hit a local [i32; QNR].
        unsafe {
            for jp in 0..panels {
                let j0 = jp * QNR;
                let jn = QNR.min(n - j0);
                let panel = &packed_b[jp * k * QNR..(jp + 1) * k * QNR];
                for i in 0..m {
                    let arow = &a[i * k..(i + 1) * k];
                    let mut acc = _mm256_setzero_si256();
                    let mut kk = 0;
                    while kk + 2 <= k {
                        // Broadcast the A pair as alternating i16
                        // lanes [a0, a1, a0, a1, ...].
                        let pair = ((arow[kk + 1] as i16 as u16 as u32) << 16)
                            | (arow[kk] as i16 as u16 as u32);
                        let av = _mm256_set1_epi32(pair as i32);
                        let b0 = _mm_cvtepi8_epi16(_mm_loadl_epi64(
                            panel.as_ptr().add(kk * QNR) as *const __m128i,
                        ));
                        let b1 = _mm_cvtepi8_epi16(_mm_loadl_epi64(
                            panel.as_ptr().add((kk + 1) * QNR) as *const __m128i,
                        ));
                        // Interleave to [b0[j], b1[j]] i16 pairs so
                        // madd's j-th i32 lane sums exactly the two
                        // taps of output column j.
                        let lo = _mm_unpacklo_epi16(b0, b1);
                        let hi = _mm_unpackhi_epi16(b0, b1);
                        let bv = _mm256_set_m128i(hi, lo);
                        acc = _mm256_add_epi32(_mm256_madd_epi16(bv, av), acc);
                        kk += 2;
                    }
                    if kk < k {
                        let av = _mm256_set1_epi32(arow[kk] as i32);
                        let bh = _mm_loadl_epi64(panel.as_ptr().add(kk * QNR) as *const __m128i);
                        let bv = _mm256_cvtepi8_epi32(bh);
                        acc = _mm256_add_epi32(_mm256_mullo_epi32(av, bv), acc);
                    }
                    let mut buf = [0i32; QNR];
                    _mm256_storeu_si256(buf.as_mut_ptr() as *mut __m256i, acc);
                    for (jj, &s) in buf[..jn].iter().enumerate() {
                        c[i * n + j0 + jj] += (s as f32) * (a_scale * b_scales[j0 + jj]);
                    }
                }
            }
        }
    }

    /// `acc += x · t` lanewise, mul+add (bit-identical to scalar).
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn saxpy_avx2(acc: &mut [f32], x: f32, t: &[f32]) {
        debug_assert_eq!(acc.len(), t.len());
        let n = acc.len();
        // SAFETY: j + 8 <= n is checked before every 8-wide block; the
        // pointers derive from the equal-length slices above.
        unsafe {
            let xv = _mm256_set1_ps(x);
            let mut j = 0;
            while j + 8 <= n {
                let av = _mm256_loadu_ps(acc.as_ptr().add(j));
                let tv = _mm256_loadu_ps(t.as_ptr().add(j));
                _mm256_storeu_ps(
                    acc.as_mut_ptr().add(j),
                    _mm256_add_ps(av, _mm256_mul_ps(xv, tv)),
                );
                j += 8;
            }
            while j < n {
                *acc.get_unchecked_mut(j) += x * t.get_unchecked(j);
                j += 1;
            }
        }
    }

    /// `acc += x · t` lanewise, mul+add (bit-identical to scalar).
    #[target_feature(enable = "avx512f")]
    pub(super) unsafe fn saxpy_avx512(acc: &mut [f32], x: f32, t: &[f32]) {
        debug_assert_eq!(acc.len(), t.len());
        let n = acc.len();
        // SAFETY: as `saxpy_avx2`, 16-wide.
        unsafe {
            let xv = _mm512_set1_ps(x);
            let mut j = 0;
            while j + 16 <= n {
                let av = _mm512_loadu_ps(acc.as_ptr().add(j));
                let tv = _mm512_loadu_ps(t.as_ptr().add(j));
                _mm512_storeu_ps(
                    acc.as_mut_ptr().add(j),
                    _mm512_add_ps(av, _mm512_mul_ps(xv, tv)),
                );
                j += 16;
            }
            while j < n {
                *acc.get_unchecked_mut(j) += x * t.get_unchecked(j);
                j += 1;
            }
        }
    }
}

#[cfg(target_arch = "aarch64")]
mod arm {
    use core::arch::aarch64::*;

    /// NEON 8×8 tile (16 q-register accumulators, 2 B vectors, 1 dup).
    /// Contract: [`TileKernel`](super::TileKernel) with `mr = 8`,
    /// `nr = 8`.
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn tile_neon(
        a: *const f32,
        lda: usize,
        panel: *const f32,
        c: *mut f32,
        ldc: usize,
        kc: usize,
    ) {
        // SAFETY: the caller (gemm_packed_with) discharges the
        // TileKernel pointer contract (see the x86 tiles).
        unsafe {
            let mut acc = [[vdupq_n_f32(0.0); 2]; 8];
            for (i, row) in acc.iter_mut().enumerate() {
                row[0] = vld1q_f32(c.add(i * ldc));
                row[1] = vld1q_f32(c.add(i * ldc + 4));
            }
            for kk in 0..kc {
                let b0 = vld1q_f32(panel.add(kk * 8));
                let b1 = vld1q_f32(panel.add(kk * 8 + 4));
                for (i, row) in acc.iter_mut().enumerate() {
                    let av = vdupq_n_f32(*a.add(i * lda + kk));
                    row[0] = vfmaq_f32(row[0], av, b0);
                    row[1] = vfmaq_f32(row[1], av, b1);
                }
            }
            for (i, row) in acc.iter().enumerate() {
                vst1q_f32(c.add(i * ldc), row[0]);
                vst1q_f32(c.add(i * ldc + 4), row[1]);
            }
        }
    }

    /// `acc += x · t` lanewise, mul+add (bit-identical to scalar).
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn saxpy_neon(acc: &mut [f32], x: f32, t: &[f32]) {
        debug_assert_eq!(acc.len(), t.len());
        let n = acc.len();
        // SAFETY: j + 4 <= n is checked before every 4-wide block; the
        // pointers derive from the equal-length slices above.
        unsafe {
            let xv = vdupq_n_f32(x);
            let mut j = 0;
            while j + 4 <= n {
                let av = vld1q_f32(acc.as_ptr().add(j));
                let tv = vld1q_f32(t.as_ptr().add(j));
                vst1q_f32(acc.as_mut_ptr().add(j), vaddq_f32(av, vmulq_f32(xv, tv)));
                j += 4;
            }
            while j < n {
                *acc.get_unchecked_mut(j) += x * t.get_unchecked(j);
                j += 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn scalar_lane_is_always_available() {
        // The forced-fallback guarantee: whatever the host, the scalar
        // row of the dispatch table exists, carries the active panel
        // width, and has no vector kernel to mis-dispatch to.
        assert!(Isa::Scalar.is_available());
        let uk = Microkernel::for_isa(Isa::Scalar);
        assert_eq!(uk.isa, Isa::Scalar);
        assert!(uk.kernel.is_none());
        assert_eq!(uk.nr, panel_width());
        assert!(Isa::supported().contains(&Isa::Scalar));
        assert_eq!(*Isa::supported().last().unwrap(), Isa::Scalar);
    }

    #[test]
    fn unavailable_lanes_degrade_to_scalar_not_panic() {
        // Decoded cache strategies from foreign hosts must stay
        // runnable: every Isa value yields a usable kernel.
        for isa in [Isa::Scalar, Isa::Avx2, Isa::Avx512, Isa::Neon] {
            let uk = Microkernel::for_isa(isa);
            if isa.is_available() {
                assert_eq!(uk.isa, isa, "{isa} detected but not dispatched");
                if isa != Isa::Scalar {
                    assert!(uk.kernel.is_some(), "{isa} lane missing its kernel");
                    assert_eq!((uk.mr, uk.nr), isa.tile());
                }
            } else {
                assert_eq!(uk.isa, Isa::Scalar, "{isa} must degrade to scalar");
                assert!(uk.kernel.is_none());
            }
            let _ = saxpy_for(isa); // must not panic either
        }
    }

    #[test]
    fn active_selection_is_stable_and_supported() {
        let a = Isa::active();
        assert_eq!(a, Isa::active(), "active lane must never change");
        assert!(a.is_available());
        assert_eq!(Isa::supported()[0], a);
        assert_eq!(panel_width(), a.tile().1);
        let uk = Microkernel::active();
        assert_eq!(uk.isa, a);
        assert_eq!(uk.nr, panel_width());
    }

    #[test]
    fn isa_names_roundtrip() {
        for isa in [Isa::Scalar, Isa::Avx2, Isa::Avx512, Isa::Neon] {
            assert_eq!(Isa::parse(isa.name()), Some(isa));
            assert_eq!(format!("{isa}"), isa.name());
        }
        assert_eq!(Isa::parse("sse9"), None);
        // Tile geometry sanity: nr is a multiple of the scalar tile's 8
        // so ragged-edge handling can share panel strides.
        for isa in [Isa::Scalar, Isa::Avx2, Isa::Avx512, Isa::Neon] {
            let (mr, nr) = isa.tile();
            assert!(mr >= 1 && nr % 8 == 0, "{isa}: tile {mr}x{nr}");
        }
    }

    #[test]
    #[cfg_attr(not(target_arch = "x86_64"), allow(unused))]
    fn quantized_widening_lanes_bit_identical_to_scalar() {
        // The quantized lanes keep one numeric contract across ISAs:
        // on any host where the AVX2 widening kernels run, they must
        // produce the exact bits of the conv::quant scalar references
        // (mul+add, k-ascending; int8 accumulates exactly in i32).
        #[cfg(target_arch = "x86_64")]
        {
            use crate::conv::quant::{self, packed_qb_elems};
            if !quant_avx2_available() {
                return;
            }
            let mut rng = Rng::seeded(0x0A16);
            for (m, k, n) in [(1usize, 1usize, 1usize), (3, 7, 5), (6, 37, 17), (4, 16, 8)] {
                let mut af = vec![0.0f32; m * k];
                let mut bf = vec![0.0f32; k * n];
                rng.fill_normal(&mut af);
                rng.fill_normal(&mut bf);
                let mut base = vec![0.0f32; m * n];
                rng.fill_normal(&mut base);
                // bf16 (AVX2 only).
                let mut aq = vec![0u16; m * k];
                quant::quantize_bf16(&af, &mut aq);
                let mut bq = vec![0u16; packed_qb_elems(k, n)];
                quant::pack_b_q16(&bf, k, n, quant::f32_to_bf16_bits, &mut bq);
                let mut want = base.clone();
                quant::gemm_q16_scalar(&aq, &bq, quant::bf16_bits_to_f32, &mut want, m, k, n);
                let mut got = base.clone();
                gemm_q16_bf16_avx2(&aq, &bq, &mut got, m, k, n);
                assert_eq!(want, got, "bf16 lane m={m} k={k} n={n}");
                // f16 (needs F16C on top of AVX2).
                if quant_f16c_available() {
                    let mut aq = vec![0u16; m * k];
                    quant::quantize_f16(&af, &mut aq);
                    let mut bq = vec![0u16; packed_qb_elems(k, n)];
                    quant::pack_b_q16(&bf, k, n, quant::f32_to_f16_bits, &mut bq);
                    let mut want = base.clone();
                    quant::gemm_q16_scalar(&aq, &bq, quant::f16_bits_to_f32, &mut want, m, k, n);
                    let mut got = base.clone();
                    gemm_q16_f16_avx2(&aq, &bq, &mut got, m, k, n);
                    assert_eq!(want, got, "f16 lane m={m} k={k} n={n}");
                }
                // int8: exact integer accumulation, identical epilogue.
                let a_scale = quant::int8_scale(quant::absmax(&af));
                let mut a8 = vec![0i8; m * k];
                quant::quantize_i8(&af, a_scale, &mut a8);
                let b_scales = quant::col_absmax_scales(&bf, k, n);
                let mut b8 = vec![0i8; packed_qb_elems(k, n)];
                quant::pack_b_q8(&bf, k, n, &b_scales, &mut b8);
                let mut want = base.clone();
                quant::gemm_q8_scalar(&a8, a_scale, &b8, &b_scales, &mut want, m, k, n);
                let mut got = base.clone();
                gemm_q8_avx2(&a8, a_scale, &b8, &b_scales, &mut got, m, k, n);
                assert_eq!(want, got, "int8 lane m={m} k={k} n={n}");
            }
        }
    }

    #[test]
    fn saxpy_lanes_bit_identical_to_scalar() {
        // The direct formulation's bit-identity contract: every
        // available lane must produce the exact scalar bits, on every
        // length that straddles the vector width (incl. the remainder
        // loop and length-0/1 edges).
        let mut rng = Rng::seeded(0x51D);
        for isa in Isa::supported() {
            let f = saxpy_for(isa);
            for n in [0usize, 1, 3, 4, 7, 8, 9, 15, 16, 17, 31, 32, 33, 40] {
                let mut t = vec![0.0f32; n];
                rng.fill_normal(&mut t);
                let mut base = vec![0.0f32; n];
                rng.fill_normal(&mut base);
                let x = 0.37f32;
                let mut want = base.clone();
                saxpy_scalar(&mut want, x, &t);
                let mut got = base.clone();
                f(&mut got, x, &t);
                assert_eq!(want, got, "isa={isa} n={n} must be bit-identical");
            }
        }
    }
}
