//! Tiled f32 GEMM microkernel for the phase-GEMM execution engine
//! (DESIGN.md §GEMM-Execution).
//!
//! The paper's §5 discussion frames the segregated transpose
//! convolution as four dense phase GEMMs; GANAX and HUGE² (PAPERS.md)
//! show that deconvolution throughput on real hardware comes from
//! dense MACC engines.  This module is the CPU stand-in for such an
//! engine: a register-blocked, cache-tiled `C += A·B` kernel that the
//! planned [`PhaseGemm`](crate::tune::space::Formulation::PhaseGemm)
//! formulation (`conv::plan`) and the §5 im2col ablation lanes
//! (`conv::im2col`) both execute through.
//!
//! Blocking scheme (all sizes runtime-checked, any `m`/`n`/`k` works):
//!
//! * **Register tile** — [`MR`]`×`[`NR`] output elements accumulate in
//!   a local `[[f32; NR]; MR]` that LLVM keeps in vector registers;
//!   each loaded `a` element and each packed `b` row is reused across
//!   the whole tile, so the inner loop does `MR·NR` MACs per
//!   `MR + NR` loads instead of the rank-1 update's 1-per-load.
//! * **K unroll** — the microkernel's K loop advances [`KU`] taps per
//!   iteration (plus a remainder loop), keeping the accumulator chain
//!   fed without reassociating any single output element's sum.
//! * **B-panel packing** — [`pack_b`] lays `B[k×n]` out as
//!   column-panels of width [`panel_width`](simd::panel_width)
//!   ([`packed_b_floats`] floats, zero-padded at the ragged right
//!   edge), so the microkernel streams one contiguous, aligned panel
//!   instead of striding across `B` rows.  The panel width equals the
//!   **active SIMD lane's** register-tile columns
//!   (`simd::Microkernel`), so plan-time packing always produces the
//!   width whichever kernel will run expects.  The conv plan packs
//!   each segregated sub-kernel **once at construction**; steady-state
//!   execution never re-packs.
//! * **SIMD dispatch** — [`gemm_packed`] runs the process-wide active
//!   [`Isa`] lane (AVX2+FMA / AVX-512 / NEON tile kernels from
//!   [`conv::simd`](super::simd), scalar fallback); [`gemm_packed_isa`]
//!   pins a lane explicitly — the tuner's microkernel axis and the
//!   equivalence tests go through it.  Ragged edges always take the
//!   scalar tile, whatever the lane (DESIGN.md §SIMD-Dispatch).
//! * **Cache blocking** — the K dimension is processed in [`KC`]-sized
//!   blocks, panel-inner, so one `KC×nr` panel block (8–32 KB) stays
//!   L1/L2-resident while every row tile sweeps over it.
//!
//! Accumulation order per output element is `kk` ascending — identical
//! to the naive triple loop — but the *tiling* is still free to change
//! which element a partial sum lands in when shapes are ragged, the
//! vector lanes' FMA contracts the mul+add rounding, and the x86 tiles
//! run **split-K** (two K-interleaved accumulator chains summed at the
//! epilogue, `conv::simd`), which reassociates; callers therefore
//! compare GEMM results with a 1e-4 tolerance, never bit-identity
//! (DESIGN.md §GEMM-Execution).
//!
//! **Reduced-precision panels** (DESIGN.md §Reduced-Precision): the
//! quantized B panels pack through `conv::quant` at the fixed
//! ISA-independent width [`quant::QNR`]; [`gemm_packed_q16`] /
//! [`gemm_packed_q8`] are the quantized analogues of
//! [`gemm_packed_isa`], dispatching to the AVX2 widening kernels when
//! the host has them and to the bit-identical scalar references
//! otherwise.
//!
//! **Fused strided-output epilogue** (DESIGN.md §Fused-Epilogue): the
//! `*_fused` drivers restore the paper's key property — each phase
//! sub-kernel writes **directly into the strided positions of the
//! final output** — at the GEMM layer.  Instead of `C += A·B` into a
//! contiguous phase slab (later scattered and then re-walked for
//! bias+activation), they accumulate each register tile on the stack
//! over the **full K extent** and store it once through a
//! [`StridedDst`] descriptor, applying the [`Epilogue`] (per-channel
//! bias, then the layer activation) in-register before the store.
//! Scalar accumulation order per output element is unchanged
//! (k-ascending mul+add; the KC-block store/reload of the separate
//! path is an exact f32 round-trip), so the scalar fused lane is
//! **bit-identical** to separate slab+scatter+apply; vector lanes call
//! the same tile kernels with `kc = k` (one call instead of one per
//! KC block), which reassociates the split-K chains differently —
//! covered by the callers' 1e-4 phase-GEMM tolerance.

use super::quant::{self, Precision};
use super::simd::{self, Isa, Microkernel};

/// Scalar register-tile rows (output rows accumulated at once).
pub const MR: usize = 4;
/// Scalar register-tile columns — one `[f32; NR]` accumulator row maps
/// onto a 256-bit vector register.  Vector lanes widen this
/// ([`Isa::tile`]); the *panel* width of packed operands follows the
/// active lane, not this constant.
pub const NR: usize = 8;
/// K-dimension cache block: `KC × NR` packed-panel floats ≈ 8 KB,
/// comfortably L1-resident.
pub const KC: usize = 256;
/// K-loop unroll factor of the microkernel.
pub const KU: usize = 4;

/// Floats required by [`pack_b`] for a `k×n` operand: `n` rounded up
/// to whole panels of the active lane's width.
pub fn packed_b_floats(k: usize, n: usize) -> usize {
    packed_b_floats_for(simd::panel_width(), k, n)
}

/// [`packed_b_floats`] at an explicit panel width `pnr` — the
/// tile-parameterized form the equivalence tests pin layouts with.
pub fn packed_b_floats_for(pnr: usize, k: usize, n: usize) -> usize {
    n.div_ceil(pnr) * pnr * k
}

/// Pack row-major `b[k×n]` into the panel layout the microkernel
/// streams, at the active lane's panel width (see [`pack_b_for`]).
pub fn pack_b(b: &[f32], k: usize, n: usize, packed: &mut [f32]) {
    pack_b_for(simd::panel_width(), b, k, n, packed)
}

/// [`pack_b`] at an explicit panel width `pnr`: panel `jp` (columns
/// `jp*pnr..`) occupies `packed[jp*k*pnr..(jp+1)*k*pnr]`, row-of-panel
/// `kk` holding the `pnr` consecutive columns (zero-padded past the
/// edge).  Every element of `packed` is written, so a dirty buffer is
/// safe to reuse.
pub fn pack_b_for(pnr: usize, b: &[f32], k: usize, n: usize, packed: &mut [f32]) {
    assert_eq!(b.len(), k * n, "pack_b: operand size mismatch");
    assert_eq!(
        packed.len(),
        packed_b_floats_for(pnr, k, n),
        "pack_b: packed size mismatch"
    );
    let panels = n.div_ceil(pnr);
    for jp in 0..panels {
        let j0 = jp * pnr;
        let nr = pnr.min(n - j0);
        let panel = &mut packed[jp * k * pnr..(jp + 1) * k * pnr];
        for kk in 0..k {
            let dst = &mut panel[kk * pnr..(kk + 1) * pnr];
            let src = &b[kk * n + j0..kk * n + j0 + nr];
            dst[..nr].copy_from_slice(src);
            dst[nr..].fill(0.0);
        }
    }
}

/// One register tile: `c[i0.., j0..] += a[i0.., k0..] · panel`, where
/// `panel` is the `kc × NR` packed block of B columns `j0..j0+nr`.
/// The full-tile fast path keeps the `MR×NR` accumulator in registers
/// with a [`KU`]-unrolled K loop; ragged edges (`mr < MR` or
/// `nr < NR`) take the bounds-checked slow path over the same
/// zero-padded accumulator.
#[inline]
#[allow(clippy::too_many_arguments)]
fn tile(
    a: &[f32],
    lda: usize,
    i0: usize,
    mr: usize,
    k0: usize,
    kc: usize,
    panel: &[f32],
    c: &mut [f32],
    ldc: usize,
    j0: usize,
    nr: usize,
) {
    debug_assert!(mr <= MR && nr <= NR && panel.len() >= kc * NR);
    let mut acc = [[0f32; NR]; MR];
    if mr == MR && nr == NR {
        for (i, row) in acc.iter_mut().enumerate() {
            row.copy_from_slice(&c[(i0 + i) * ldc + j0..][..NR]);
        }
        let mut kk = 0;
        while kk + KU <= kc {
            for u in 0..KU {
                let b = &panel[(kk + u) * NR..(kk + u + 1) * NR];
                for (i, row) in acc.iter_mut().enumerate() {
                    let av = a[(i0 + i) * lda + k0 + kk + u];
                    for (cv, &bv) in row.iter_mut().zip(b) {
                        *cv += av * bv;
                    }
                }
            }
            kk += KU;
        }
        while kk < kc {
            let b = &panel[kk * NR..(kk + 1) * NR];
            for (i, row) in acc.iter_mut().enumerate() {
                let av = a[(i0 + i) * lda + k0 + kk];
                for (cv, &bv) in row.iter_mut().zip(b) {
                    *cv += av * bv;
                }
            }
            kk += 1;
        }
        for (i, row) in acc.iter().enumerate() {
            c[(i0 + i) * ldc + j0..][..NR].copy_from_slice(row);
        }
        return;
    }
    for (i, row) in acc.iter_mut().enumerate().take(mr) {
        row[..nr].copy_from_slice(&c[(i0 + i) * ldc + j0..][..nr]);
    }
    for kk in 0..kc {
        let b = &panel[kk * NR..(kk + 1) * NR];
        for (i, row) in acc.iter_mut().enumerate().take(mr) {
            let av = a[(i0 + i) * lda + k0 + kk];
            for (cv, &bv) in row.iter_mut().zip(b) {
                *cv += av * bv;
            }
        }
    }
    for (i, row) in acc.iter().enumerate().take(mr) {
        c[(i0 + i) * ldc + j0..][..nr].copy_from_slice(&row[..nr]);
    }
}

/// Widest vector tile supported ([`Isa::Avx512`]'s 8×32) — bounds the
/// generic tile's stack accumulator.
const MR_MAX: usize = 8;
const NR_MAX: usize = 32;

/// Scalar tile over a panel of arbitrary width `pnr` — the fallback
/// path for ragged edges of the vector lanes and for the forced-scalar
/// microkernel on hosts whose packed panels are wider than [`NR`].
/// Same per-element accumulation order (`kk` ascending) as every other
/// tile.
#[inline]
#[allow(clippy::too_many_arguments)]
fn tile_any(
    a: &[f32],
    lda: usize,
    i0: usize,
    mr: usize,
    k0: usize,
    kc: usize,
    panel: &[f32],
    c: &mut [f32],
    ldc: usize,
    j0: usize,
    nr: usize,
    pnr: usize,
) {
    debug_assert!(mr <= MR_MAX && nr <= NR_MAX && nr <= pnr && panel.len() >= kc * pnr);
    let mut acc = [[0f32; NR_MAX]; MR_MAX];
    for (i, row) in acc.iter_mut().enumerate().take(mr) {
        row[..nr].copy_from_slice(&c[(i0 + i) * ldc + j0..][..nr]);
    }
    for kk in 0..kc {
        let b = &panel[kk * pnr..kk * pnr + nr];
        for (i, row) in acc.iter_mut().enumerate().take(mr) {
            let av = a[(i0 + i) * lda + k0 + kk];
            for (cv, &bv) in row.iter_mut().zip(b) {
                *cv += av * bv;
            }
        }
    }
    for (i, row) in acc.iter().enumerate().take(mr) {
        c[(i0 + i) * ldc + j0..][..nr].copy_from_slice(&row[..nr]);
    }
}

/// `c[m×n] += a[m×k] · B` with `B` pre-packed by [`pack_b`] — the
/// steady-state entry point of the phase-GEMM plan (operands packed
/// once at plan construction, zero allocations here).  Runs the
/// process-wide active SIMD lane ([`Isa::active`]).
pub fn gemm_packed(a: &[f32], packed_b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    gemm_packed_with(&Microkernel::active(), a, packed_b, c, m, k, n)
}

/// [`gemm_packed`] with the microkernel lane pinned — the tuner's
/// microkernel axis (`ExecStrategy::isa`) dispatches through this.  An
/// unavailable lane degrades to scalar ([`Microkernel::for_isa`]), so
/// strategies decoded from foreign-host caches stay runnable.
pub fn gemm_packed_isa(
    isa: Isa,
    a: &[f32],
    packed_b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
) {
    gemm_packed_with(&Microkernel::for_isa(isa), a, packed_b, c, m, k, n)
}

/// The dispatch core: K-blocked, panel-inner sweep handing full
/// `uk.mr × pnr` tiles to the lane's vector kernel and every ragged
/// edge to the scalar tile.  `packed_b` must be packed at the active
/// panel width — the only width [`Microkernel::for_isa`] hands out.
fn gemm_packed_with(
    uk: &Microkernel,
    a: &[f32],
    packed_b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
) {
    // Registry handle cached in a Lazy so the steady-state cost is one
    // relaxed fetch_add (the name lookup allocates; warmup pays it).
    static PACKED_CALLS: once_cell::sync::Lazy<std::sync::Arc<crate::obs::registry::Counter>> =
        once_cell::sync::Lazy::new(|| crate::obs::registry::counter("gemm.packed_calls"));
    PACKED_CALLS.inc();
    let pnr = simd::panel_width();
    debug_assert!(uk.kernel.is_none() || uk.nr == pnr, "panel width mismatch");
    assert_eq!(a.len(), m * k, "gemm_packed: A size mismatch");
    assert_eq!(
        packed_b.len(),
        packed_b_floats_for(pnr, k, n),
        "gemm_packed: packed B size mismatch"
    );
    assert_eq!(c.len(), m * n, "gemm_packed: C size mismatch");
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    let panels = n.div_ceil(pnr);
    let mut k0 = 0;
    while k0 < k {
        let kc = KC.min(k - k0);
        for jp in 0..panels {
            let j0 = jp * pnr;
            let nr = pnr.min(n - j0);
            let panel = &packed_b[jp * k * pnr + k0 * pnr..][..kc * pnr];
            let mut i0 = 0;
            while i0 < m {
                let mr = uk.mr.min(m - i0);
                match uk.kernel {
                    Some(f) if mr == uk.mr && nr == pnr => {
                        // SAFETY: the TileKernel contract (conv::simd):
                        // full tile, so `i0 + uk.mr <= m`, `j0 + pnr <=
                        // n`, `k0 + kc <= k` — every pointer below
                        // spans in-bounds rows of the slices sliced
                        // above, and `for_isa` only returns a vector
                        // kernel whose target features were
                        // runtime-detected.
                        unsafe {
                            f(
                                a.as_ptr().add(i0 * k + k0),
                                k,
                                panel.as_ptr(),
                                c.as_mut_ptr().add(i0 * n + j0),
                                n,
                                kc,
                            )
                        }
                    }
                    // Scalar lane on native-width panels: the classic
                    // 4×8 tile (its fast path needs mr ≤ MR, which
                    // only the scalar lane's row step guarantees).
                    None if pnr == NR => tile(a, k, i0, mr, k0, kc, panel, c, n, j0, nr),
                    // Ragged edges of any vector lane, and the scalar
                    // lane on wider-than-native panels.
                    _ => tile_any(a, k, i0, mr, k0, kc, panel, c, n, j0, nr, pnr),
                }
                i0 += uk.mr;
            }
        }
        k0 += KC;
    }
}

/// Quantized analogue of [`gemm_packed_isa`] for 16-bit-float
/// operands: `C += A·B` with A the quantized im2col patch and B a
/// panel packed by [`quant::pack_b_q16`] (width [`quant::QNR`],
/// ISA-independent).  `precision` picks the decoder (`F16` or `Bf16` —
/// anything else panics); any non-scalar `isa` requests the AVX2
/// widening lane, which runs when the host has AVX2 (+F16C for f16)
/// and degrades to the **bit-identical** scalar reference otherwise,
/// so quantized strategies decoded from foreign-host caches stay
/// runnable with unchanged results.
pub fn gemm_packed_q16(
    isa: Isa,
    precision: Precision,
    a: &[u16],
    packed_b: &[u16],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
) {
    assert_eq!(a.len(), m * k, "gemm_packed_q16: A size mismatch");
    assert_eq!(
        packed_b.len(),
        quant::packed_qb_elems(k, n),
        "gemm_packed_q16: packed B size mismatch"
    );
    assert_eq!(c.len(), m * n, "gemm_packed_q16: C size mismatch");
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    #[cfg(not(target_arch = "x86_64"))]
    let _ = isa; // no widening lanes off x86 yet — scalar reference runs
    #[cfg(target_arch = "x86_64")]
    {
        if isa != Isa::Scalar {
            match precision {
                Precision::F16 if simd::quant_f16c_available() => {
                    simd::gemm_q16_f16_avx2(a, packed_b, c, m, k, n);
                    return;
                }
                Precision::Bf16 if simd::quant_avx2_available() => {
                    simd::gemm_q16_bf16_avx2(a, packed_b, c, m, k, n);
                    return;
                }
                _ => {}
            }
        }
    }
    let from_bits = match precision {
        Precision::F16 => quant::f16_bits_to_f32 as fn(u16) -> f32,
        Precision::Bf16 => quant::bf16_bits_to_f32,
        p => panic!("gemm_packed_q16: {} is not a 16-bit precision", p.name()),
    };
    quant::gemm_q16_scalar(a, packed_b, from_bits, c, m, k, n)
}

/// Quantized analogue of [`gemm_packed_isa`] for int8 operands:
/// `C += (a_scale·A) · (B ⊙ b_scales)` with B packed by
/// [`quant::pack_b_q8`].  i32 accumulation is exact, so the AVX2 lane
/// and the scalar reference are bit-identical unconditionally.
#[allow(clippy::too_many_arguments)]
pub fn gemm_packed_q8(
    isa: Isa,
    a: &[i8],
    a_scale: f32,
    packed_b: &[i8],
    b_scales: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
) {
    assert_eq!(a.len(), m * k, "gemm_packed_q8: A size mismatch");
    assert_eq!(
        packed_b.len(),
        quant::packed_qb_elems(k, n),
        "gemm_packed_q8: packed B size mismatch"
    );
    assert_eq!(b_scales.len(), n, "gemm_packed_q8: one scale per column");
    assert_eq!(c.len(), m * n, "gemm_packed_q8: C size mismatch");
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    #[cfg(not(target_arch = "x86_64"))]
    let _ = isa; // no widening lanes off x86 yet — scalar reference runs
    #[cfg(target_arch = "x86_64")]
    {
        if isa != Isa::Scalar && simd::quant_avx2_available() {
            simd::gemm_q8_avx2(a, a_scale, packed_b, b_scales, c, m, k, n);
            return;
        }
    }
    quant::gemm_q8_scalar(a, a_scale, packed_b, b_scales, c, m, k, n)
}

/// The layer activation a fused GEMM lane applies in-register before
/// the strided store.  Semantics match the `tensor::ops` slice
/// routines exactly (`relu_slice_inplace` = `v.max(0.0)`,
/// `tanh_slice_inplace` = `v.tanh()`), so fusing the activation into
/// the epilogue cannot change a single bit relative to the separate
/// post-pass.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Activation {
    /// Identity — the epilogue stores the (optionally biased) sum.
    None,
    /// `v.max(0.0)`, as `ops::relu_slice_inplace`.
    Relu,
    /// `v.tanh()`, as `ops::tanh_slice_inplace`.
    Tanh,
}

impl Activation {
    /// Apply to one element (the fused epilogues' per-lane tail).
    #[inline]
    pub fn apply(self, v: f32) -> f32 {
        match self {
            Activation::None => v,
            Activation::Relu => v.max(0.0),
            Activation::Tanh => v.tanh(),
        }
    }

    /// Stable name for test labels and ablation rows.
    pub fn name(self) -> &'static str {
        match self {
            Activation::None => "none",
            Activation::Relu => "relu",
            Activation::Tanh => "tanh",
        }
    }
}

/// What a fused lane applies to every output element between the
/// accumulator and the store: optional per-output-channel bias
/// (`bias.len() == n`, the GEMM's column count == the layer's `cout`),
/// then the activation.  A quantized fused driver folds its dequant
/// scale in *before* the bias, exactly as the separate scalar kernels'
/// epilogue does.
#[derive(Debug, Clone, Copy)]
pub struct Epilogue<'a> {
    /// Per-output-channel bias, added before the activation.
    pub bias: Option<&'a [f32]>,
    /// Activation applied last, just before the store.
    pub act: Activation,
}

impl Epilogue<'_> {
    /// The neutral epilogue: no bias, no activation — a fused lane run
    /// with it stores the raw GEMM sums (what the tuner measures).
    pub fn none() -> Epilogue<'static> {
        Epilogue {
            bias: None,
            act: Activation::None,
        }
    }

    /// True when the epilogue changes nothing — callers on separate
    /// (unfused) lanes skip their post-pass entirely in this case.
    pub fn is_neutral(&self) -> bool {
        self.bias.is_none() && self.act == Activation::None
    }
}

/// Where a fused GEMM lane stores logical row `r` of its `m×n` C
/// matrix: the strided positions of the interleaved transpose-conv
/// output that `scatter_rows_view` (`conv::unified`) would otherwise
/// copy the phase slab to.  Each C row is one contiguous `n`-float
/// (`cout`) pixel; its offset is
///
/// ```text
/// i  = r / img_rows          (0 when img_rows == 0: single image)
/// rr = r % img_rows
/// off = i·img_stride + base + (rr / n_cols)·row_stride
///                           + (rr % n_cols)·col_stride
/// ```
///
/// For a stride-2 phase `(rp, sp)` of a `[H,W,C]` output this is
/// `base = (rp·W + sp)·C`, `col_stride = 2·C`, `row_stride = 2·W·C` —
/// exactly the scatter loop's arithmetic, hoisted into a descriptor
/// the GEMM epilogue can evaluate per tile row.
#[derive(Debug)]
pub struct StridedDst<'a> {
    /// The output buffer (one image, one row-slice of it, or a whole
    /// batch — the offsets below must stay in bounds).
    pub out: &'a mut [f32],
    /// Float offset of C row 0 within each image.
    pub base: usize,
    /// Float stride between consecutive C rows within a phase row.
    pub col_stride: usize,
    /// Float stride between phase rows (every `n_cols` C rows).
    pub row_stride: usize,
    /// C rows per phase row (the phase's output-column count).
    pub n_cols: usize,
    /// C rows per image for batched GEMMs; 0 means single-image
    /// (`img_stride` unused).
    pub img_rows: usize,
    /// Float stride between images (batched GEMMs only).
    pub img_stride: usize,
}

impl StridedDst<'_> {
    /// Float offset of logical C row `r`'s first channel.
    #[inline]
    fn row_offset(&self, r: usize) -> usize {
        let (i, rr) = if self.img_rows == 0 {
            (0, r)
        } else {
            (r / self.img_rows, r % self.img_rows)
        };
        i * self.img_stride
            + self.base
            + (rr / self.n_cols) * self.row_stride
            + (rr % self.n_cols) * self.col_stride
    }
}

/// Bias + activation + store of one epilogue row: `out[j] =
/// act(vals[j] + bias[j])`.  `bias` is pre-sliced to the panel's
/// columns.  Overwrites (never accumulates): the fused lanes own
/// every strided position they touch, so no zero-fill pass is needed.
#[inline]
fn epilogue_store(out: &mut [f32], vals: &[f32], bias: Option<&[f32]>, act: Activation) {
    match bias {
        Some(b) => {
            for ((o, &v), &bv) in out.iter_mut().zip(vals).zip(b) {
                *o = act.apply(v + bv);
            }
        }
        None => {
            for (o, &v) in out.iter_mut().zip(vals) {
                *o = act.apply(v);
            }
        }
    }
}

/// Fused analogue of [`gemm_packed_isa`]: `out[strided] =
/// act(A·B + bias)` with no intermediate slab — each register tile
/// accumulates over the full K extent on the stack and stores straight
/// into the interleaved output through `dst`.  The scalar lane is
/// bit-identical to separate GEMM + scatter + bias + activation (same
/// per-element k-ascending order); vector lanes carry the usual 1e-4
/// phase-GEMM tolerance (single `kc = k` kernel call reassociates the
/// split-K chains relative to the KC-blocked separate path).
#[allow(clippy::too_many_arguments)]
pub fn gemm_packed_fused(
    isa: Isa,
    a: &[f32],
    packed_b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    dst: &mut StridedDst<'_>,
    epi: &Epilogue<'_>,
) {
    gemm_packed_fused_with(&Microkernel::for_isa(isa), a, packed_b, m, k, n, dst, epi)
}

fn gemm_packed_fused_with(
    uk: &Microkernel,
    a: &[f32],
    packed_b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    dst: &mut StridedDst<'_>,
    epi: &Epilogue<'_>,
) {
    static FUSED_CALLS: once_cell::sync::Lazy<std::sync::Arc<crate::obs::registry::Counter>> =
        once_cell::sync::Lazy::new(|| crate::obs::registry::counter("gemm.fused_calls"));
    FUSED_CALLS.inc();
    let pnr = simd::panel_width();
    debug_assert!(uk.kernel.is_none() || uk.nr == pnr, "panel width mismatch");
    assert_eq!(a.len(), m * k, "gemm_packed_fused: A size mismatch");
    assert_eq!(
        packed_b.len(),
        packed_b_floats_for(pnr, k, n),
        "gemm_packed_fused: packed B size mismatch"
    );
    if let Some(b) = epi.bias {
        assert_eq!(b.len(), n, "gemm_packed_fused: one bias per output channel");
    }
    if m == 0 || n == 0 {
        return;
    }
    let panels = n.div_ceil(pnr);
    for jp in 0..panels {
        let j0 = jp * pnr;
        let jn = pnr.min(n - j0);
        let panel = &packed_b[jp * k * pnr..(jp + 1) * k * pnr];
        let mut i0 = 0;
        while i0 < m {
            let mr = uk.mr.min(m - i0);
            // Full-K accumulation into a zeroed stack tile (1 KB at
            // the widest 8×32 geometry) — the only place a fused tile
            // ever lives before its single strided store.
            let mut tile_c = [0.0f32; MR_MAX * NR_MAX];
            let arows = &a[i0 * k..(i0 + mr) * k];
            match uk.kernel {
                Some(f) if mr == uk.mr && jn == pnr => {
                    // SAFETY: the TileKernel contract (conv::simd) on
                    // the stack tile: `arows` spans the full mr×k A
                    // strip, `panel` the full k×pnr block, and
                    // `tile_c` (MR_MAX·NR_MAX floats, ldc = pnr ≤
                    // NR_MAX, mr = uk.mr ≤ MR_MAX) holds the whole
                    // mr×pnr tile; `for_isa` only returns a vector
                    // kernel whose target features were
                    // runtime-detected.
                    unsafe { f(arows.as_ptr(), k, panel.as_ptr(), tile_c.as_mut_ptr(), pnr, k) }
                }
                None if pnr == NR => tile(arows, k, 0, mr, 0, k, panel, &mut tile_c, pnr, 0, jn),
                _ => tile_any(arows, k, 0, mr, 0, k, panel, &mut tile_c, pnr, 0, jn, pnr),
            }
            for r in 0..mr {
                let off = dst.row_offset(i0 + r) + j0;
                epilogue_store(
                    &mut dst.out[off..off + jn],
                    &tile_c[r * pnr..r * pnr + jn],
                    epi.bias.map(|b| &b[j0..j0 + jn]),
                    epi.act,
                );
            }
            i0 += uk.mr;
        }
    }
}

/// Fused analogue of [`gemm_packed_q16`]: the 16-bit-float phase GEMM
/// with the dequantized sums stored straight to the strided output
/// through the same [`Epilogue`].  Epilogue-level fusion runs the
/// scalar widening loop only (the AVX2 widening kernels target a
/// contiguous C operand — exactly the slab fusion removes); it is
/// **bit-identical** to `quant::gemm_q16_scalar` + scatter + apply,
/// and since the AVX2 widening lanes are themselves bit-identical to
/// that scalar reference, fused-vs-separate stays exact for every
/// quantized strategy.
#[allow(clippy::too_many_arguments)]
pub fn gemm_packed_q16_fused(
    precision: Precision,
    a: &[u16],
    packed_b: &[u16],
    m: usize,
    k: usize,
    n: usize,
    dst: &mut StridedDst<'_>,
    epi: &Epilogue<'_>,
) {
    use super::quant::QNR;
    assert_eq!(a.len(), m * k, "gemm_packed_q16_fused: A size mismatch");
    assert_eq!(
        packed_b.len(),
        quant::packed_qb_elems(k, n),
        "gemm_packed_q16_fused: packed B size mismatch"
    );
    if let Some(b) = epi.bias {
        assert_eq!(b.len(), n, "gemm_packed_q16_fused: one bias per output channel");
    }
    let from_bits = match precision {
        Precision::F16 => quant::f16_bits_to_f32 as fn(u16) -> f32,
        Precision::Bf16 => quant::bf16_bits_to_f32,
        p => panic!("gemm_packed_q16_fused: {} is not a 16-bit precision", p.name()),
    };
    if m == 0 || n == 0 {
        return;
    }
    let panels = n.div_ceil(QNR);
    for jp in 0..panels {
        let j0 = jp * QNR;
        let jn = QNR.min(n - j0);
        let panel = &packed_b[jp * k * QNR..(jp + 1) * k * QNR];
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            let mut acc = [0f32; QNR];
            for (kk, &ab) in arow.iter().enumerate() {
                let av = from_bits(ab);
                let brow = &panel[kk * QNR..(kk + 1) * QNR];
                for (s, &bb) in acc.iter_mut().zip(brow) {
                    *s += av * from_bits(bb);
                }
            }
            let off = dst.row_offset(i) + j0;
            epilogue_store(
                &mut dst.out[off..off + jn],
                &acc[..jn],
                epi.bias.map(|b| &b[j0..j0 + jn]),
                epi.act,
            );
        }
    }
}

/// Fused analogue of [`gemm_packed_q8`]: exact i32 accumulation, then
/// the dequant scale `a_scale · b_scales[j]` folds into the epilogue
/// *before* bias and activation — the same single scaled f32 epilogue
/// as the separate scalar kernel, so fused-vs-separate is
/// bit-identical unconditionally (see [`gemm_packed_q16_fused`] on why
/// epilogue-level quantized fusion is scalar-only).
#[allow(clippy::too_many_arguments)]
pub fn gemm_packed_q8_fused(
    a: &[i8],
    a_scale: f32,
    packed_b: &[i8],
    b_scales: &[f32],
    m: usize,
    k: usize,
    n: usize,
    dst: &mut StridedDst<'_>,
    epi: &Epilogue<'_>,
) {
    use super::quant::QNR;
    assert_eq!(a.len(), m * k, "gemm_packed_q8_fused: A size mismatch");
    assert_eq!(
        packed_b.len(),
        quant::packed_qb_elems(k, n),
        "gemm_packed_q8_fused: packed B size mismatch"
    );
    assert_eq!(b_scales.len(), n, "gemm_packed_q8_fused: one scale per column");
    if let Some(b) = epi.bias {
        assert_eq!(b.len(), n, "gemm_packed_q8_fused: one bias per output channel");
    }
    if m == 0 || n == 0 {
        return;
    }
    let panels = n.div_ceil(QNR);
    for jp in 0..panels {
        let j0 = jp * QNR;
        let jn = QNR.min(n - j0);
        let panel = &packed_b[jp * k * QNR..(jp + 1) * k * QNR];
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            let mut acc = [0i32; QNR];
            for (kk, &ab) in arow.iter().enumerate() {
                let av = ab as i32;
                let brow = &panel[kk * QNR..(kk + 1) * QNR];
                for (s, &bb) in acc.iter_mut().zip(brow) {
                    *s += av * (bb as i32);
                }
            }
            let mut vals = [0f32; QNR];
            for (jj, (v, &s)) in vals.iter_mut().zip(&acc).enumerate().take(jn) {
                *v = (s as f32) * (a_scale * b_scales[j0 + jj]);
            }
            let off = dst.row_offset(i) + j0;
            epilogue_store(
                &mut dst.out[off..off + jn],
                &vals[..jn],
                epi.bias.map(|b| &b[j0..j0 + jn]),
                epi.act,
            );
        }
    }
}

/// `c[m×n] += a[m×k] · b[k×n]`, row-major — packs `b` into a transient
/// panel buffer and runs the tiled kernel.  Convenience for one-shot
/// callers (the im2col ablation lanes); planned execution packs once
/// via [`pack_b`] and calls [`gemm_packed`] directly.
pub fn gemm_tiled(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    assert_eq!(b.len(), k * n, "gemm_tiled: B size mismatch");
    let mut packed = vec![0.0f32; packed_b_floats(k, n)];
    pack_b(b, k, n, &mut packed);
    gemm_packed(a, &packed, c, m, k, n);
}

/// im2col over a contiguous HWC slab, output rows `[row_lo, row_hi)`:
/// patch row `(py - row_lo)·n_cols + px` of `dst` holds the flattened
/// `[kr, kc, c]` window of the slab at `(py, px)`.  The slab is
/// exactly the phase slab the direct path correlates
/// (`slab_w = n_cols + kc - 1`), so the patch matrix times the
/// tap-major kernel matrix reproduces the phase output.  Every `dst`
/// element is written — dirty scratch regions are safe to reuse.
#[allow(clippy::too_many_arguments)]
pub(crate) fn im2col_rows(
    slab: &[f32],
    slab_w: usize,
    c: usize,
    kr: usize,
    kc: usize,
    n_cols: usize,
    row_lo: usize,
    row_hi: usize,
    dst: &mut [f32],
) {
    let patch = kr * kc * c;
    debug_assert_eq!(dst.len(), (row_hi - row_lo) * n_cols * patch);
    debug_assert!(slab_w >= n_cols + kc - 1);
    for py in row_lo..row_hi {
        for px in 0..n_cols {
            let row = &mut dst[((py - row_lo) * n_cols + px) * patch..][..patch];
            for u in 0..kr {
                let src = ((py + u) * slab_w + px) * c;
                row[u * kc * c..(u + 1) * kc * c].copy_from_slice(&slab[src..src + kc * c]);
            }
        }
    }
}

/// Transposed im2col over a contiguous HWC slab: column `r = py·n_cols
/// + px` of the `[kr·kc·c, n_rows·n_cols]` output holds the flattened
/// `[kr, kc, c]` window of the slab at `(py, px)` — i.e. exactly
/// [`im2col_rows`]' patch matrix transposed.  This is the A operand of
/// the backward-weights phase GEMM (`dSub = patchᵀ · dy_phase`, see
/// `conv::plan::run_backward_weights`): laying the taps out row-major
/// here lets the microkernel reduce over the `n_rows·n_cols` output
/// positions with unit stride.  Every `dst` element is written — dirty
/// scratch regions are safe to reuse.
pub(crate) fn im2col_cols(
    slab: &[f32],
    slab_w: usize,
    c: usize,
    kr: usize,
    kc: usize,
    n_cols: usize,
    n_rows: usize,
    dst: &mut [f32],
) {
    let rows_total = n_rows * n_cols;
    debug_assert_eq!(dst.len(), kr * kc * c * rows_total);
    debug_assert!(slab_w >= n_cols + kc - 1);
    for u in 0..kr {
        for v in 0..kc {
            for ch in 0..c {
                let t = (u * kc + v) * c + ch;
                let row = &mut dst[t * rows_total..(t + 1) * rows_total];
                for py in 0..n_rows {
                    let base = ((py + u) * slab_w + v) * c + ch;
                    for (px, d) in row[py * n_cols..(py + 1) * n_cols].iter_mut().enumerate() {
                        *d = slab[base + px * c];
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::close;
    use crate::util::rng::Rng;

    /// Reference: naive i-k-j triple loop (same per-element order).
    fn gemm_naive(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
        for i in 0..m {
            for kk in 0..k {
                let av = a[i * k + kk];
                for j in 0..n {
                    c[i * n + j] += av * b[kk * n + j];
                }
            }
        }
    }

    fn random_mat(m: usize, n: usize, rng: &mut Rng) -> Vec<f32> {
        let mut v = vec![0.0f32; m * n];
        rng.fill_normal(&mut v);
        v
    }

    #[test]
    fn tiled_small_known() {
        // [1 2; 3 4] * [5 6; 7 8] = [19 22; 43 50]
        let a = [1.0, 2.0, 3.0, 4.0];
        let b = [5.0, 6.0, 7.0, 8.0];
        let mut c = [0.0; 4];
        gemm_tiled(&a, &b, &mut c, 2, 2, 2);
        assert_eq!(c, [19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn tiled_matches_naive_on_ragged_edges() {
        // Every combination of m/n/k straddling the MR/NR/KU tile
        // boundaries, including exact multiples and off-by-ones.
        let mut rng = Rng::seeded(0x6E33);
        for &m in &[1, 3, MR, MR + 1, 2 * MR + 3] {
            for &n in &[1, 3, NR - 1, NR, NR + 1, 2 * NR + 5] {
                for &k in &[1, 2, KU, KU + 1, 3 * KU + 1, 37] {
                    let a = random_mat(m, k, &mut rng);
                    let b = random_mat(k, n, &mut rng);
                    let mut want = random_mat(m, n, &mut rng);
                    let mut got = want.clone(); // C += : dirty C must survive
                    gemm_naive(&a, &b, &mut want, m, k, n);
                    gemm_tiled(&a, &b, &mut got, m, k, n);
                    close(&want, &got, 1e-4)
                        .unwrap_or_else(|e| panic!("m={m} n={n} k={k}: {e}"));
                }
            }
        }
    }

    #[test]
    fn cache_blocking_crosses_kc() {
        // K > KC exercises the k0 block loop (partial sums re-loaded
        // from C between blocks).
        let (m, n, k) = (5, 9, KC + KC / 2 + 3);
        let mut rng = Rng::seeded(0x6E34);
        let a = random_mat(m, k, &mut rng);
        let b = random_mat(k, n, &mut rng);
        let mut want = vec![0.0f32; m * n];
        let mut got = vec![0.0f32; m * n];
        gemm_naive(&a, &b, &mut want, m, k, n);
        gemm_tiled(&a, &b, &mut got, m, k, n);
        assert!(close(&want, &got, 1e-3).is_ok());
    }

    #[test]
    fn packed_layout_and_reuse() {
        // Panel layout pinned at every tile width the dispatch table
        // hands out (8 = scalar/neon, 16 = avx2, 32 = avx512) — the
        // layout is tile-parameterized, not hardcoded to NR.
        let mut rng = Rng::seeded(0x6E35);
        for pnr in [8usize, 16, 32] {
            let (k, n) = (3, pnr + 2); // two panels, second ragged
            let b = random_mat(k, n, &mut rng);
            let mut packed = vec![f32::NAN; packed_b_floats_for(pnr, k, n)];
            pack_b_for(pnr, &b, k, n, &mut packed);
            assert_eq!(packed.len(), 2 * pnr * k);
            // Panel 0, row kk = b[kk][0..pnr]; panel 1 zero-padded.
            for kk in 0..k {
                assert_eq!(&packed[kk * pnr..(kk + 1) * pnr], &b[kk * n..kk * n + pnr]);
                let p1 = &packed[k * pnr + kk * pnr..k * pnr + (kk + 1) * pnr];
                assert_eq!(&p1[..2], &b[kk * n + pnr..kk * n + pnr + 2]);
                assert!(p1[2..].iter().all(|&v| v == 0.0), "edge padding not zeroed");
            }
        }
        // gemm_packed on an active-width pre-packed operand is
        // bit-identical to the one-shot (same lane, same packing).
        let (m, k, n) = (6, 3, simd::panel_width() + 2);
        let b = random_mat(k, n, &mut rng);
        let mut packed = vec![f32::NAN; packed_b_floats(k, n)];
        pack_b(&b, k, n, &mut packed);
        let a = random_mat(m, k, &mut rng);
        let mut want = vec![0.0f32; m * n];
        gemm_tiled(&a, &b, &mut want, m, k, n);
        let mut got = vec![0.0f32; m * n];
        gemm_packed(&a, &packed, &mut got, m, k, n);
        assert_eq!(got, want);
    }

    #[test]
    fn every_supported_lane_matches_scalar_on_ragged_edges() {
        // The microkernel axis must not change results: each lane the
        // host supports (vector + forced-scalar fallback on the same
        // packed operands) matches the scalar microkernel within the
        // phase-GEMM tolerance on m/n/k straddling every tile bound.
        let mut rng = Rng::seeded(0x6E38);
        let lanes = Isa::supported();
        for &m in &[1, MR, 5, 6, 7, 8, 9, 2 * MR_MAX + 3] {
            for &n in &[1, 7, 8, 15, 16, 17, 31, 32, 33, 2 * NR_MAX + 5] {
                for &k in &[1, KU + 1, 37] {
                    let a = random_mat(m, k, &mut rng);
                    let b = random_mat(k, n, &mut rng);
                    let mut packed = vec![f32::NAN; packed_b_floats(k, n)];
                    pack_b(&b, k, n, &mut packed);
                    let base = random_mat(m, n, &mut rng);
                    let mut want = base.clone();
                    gemm_packed_isa(Isa::Scalar, &a, &packed, &mut want, m, k, n);
                    for &isa in &lanes {
                        let mut got = base.clone();
                        gemm_packed_isa(isa, &a, &packed, &mut got, m, k, n);
                        close(&want, &got, 1e-4).unwrap_or_else(|e| {
                            panic!("isa={isa} m={m} n={n} k={k}: {e}")
                        });
                    }
                }
            }
        }
    }

    #[test]
    fn forced_scalar_lane_runs_on_active_panels() {
        // The forced-fallback guarantee end-to-end: scalar-pinned GEMM
        // consumes the active-lane packing (whatever its width) and
        // matches the naive reference.
        let (m, k, n) = (7, KC + 5, 2 * simd::panel_width() + 3);
        let mut rng = Rng::seeded(0x6E39);
        let a = random_mat(m, k, &mut rng);
        let b = random_mat(k, n, &mut rng);
        let mut packed = vec![f32::NAN; packed_b_floats(k, n)];
        pack_b(&b, k, n, &mut packed);
        let mut want = vec![0.0f32; m * n];
        gemm_naive(&a, &b, &mut want, m, k, n);
        let mut got = vec![0.0f32; m * n];
        gemm_packed_isa(Isa::Scalar, &a, &packed, &mut got, m, k, n);
        assert!(close(&want, &got, 1e-3).is_ok());
        // Unavailable vector lanes degrade to scalar, bit-identically.
        for isa in [Isa::Avx2, Isa::Avx512, Isa::Neon] {
            if !isa.is_available() {
                let mut degraded = vec![0.0f32; m * n];
                gemm_packed_isa(isa, &a, &packed, &mut degraded, m, k, n);
                assert_eq!(degraded, got, "{isa} fallback must be the scalar lane");
            }
        }
    }

    #[test]
    fn degenerate_sizes_are_noops() {
        gemm_tiled(&[], &[], &mut [], 0, 3, 0);
        gemm_tiled(&[], &[1.0, 2.0], &mut [], 0, 1, 2);
        let mut c = [7.0f32; 2];
        gemm_tiled(&[], &[], &mut c, 2, 0, 1);
        assert_eq!(c, [7.0, 7.0], "k=0 must leave C untouched");
    }

    #[test]
    fn accumulates_into_existing_c() {
        let a = [1.0f32, 1.0];
        let b = [2.0f32, 3.0];
        let mut c = [10.0f32];
        gemm_tiled(&a, &b, &mut c, 1, 2, 1);
        assert_eq!(c, [15.0]);
    }

    #[test]
    fn im2col_rows_matches_whole_matrix() {
        // Row-sliced im2col must tile the full patch matrix exactly —
        // the contract the row-parallel GEMM lane relies on.
        let (kr, kc, c, n_rows, n_cols) = (2, 3, 2, 4, 5);
        let slab_h = n_rows + kr - 1;
        let slab_w = n_cols + kc - 1;
        let mut rng = Rng::seeded(0x6E36);
        let slab = random_mat(slab_h, slab_w * c, &mut rng);
        let patch = kr * kc * c;
        let mut whole = vec![f32::NAN; n_rows * n_cols * patch];
        im2col_rows(&slab, slab_w, c, kr, kc, n_cols, 0, n_rows, &mut whole);
        for lo in 0..n_rows {
            let mut piece = vec![f32::NAN; n_cols * patch];
            im2col_rows(&slab, slab_w, c, kr, kc, n_cols, lo, lo + 1, &mut piece);
            assert_eq!(&whole[lo * n_cols * patch..(lo + 1) * n_cols * patch], &piece[..]);
        }
        // Spot-check one patch against direct slab indexing.
        let (py, px) = (1, 2);
        let row = &whole[(py * n_cols + px) * patch..][..patch];
        for u in 0..kr {
            for v in 0..kc {
                for ch in 0..c {
                    assert_eq!(
                        row[(u * kc + v) * c + ch],
                        slab[((py + u) * slab_w + px + v) * c + ch]
                    );
                }
            }
        }
    }

    #[test]
    fn im2col_cols_is_transpose_of_im2col_rows() {
        // The backward-weights A operand is exactly the forward patch
        // matrix transposed — dirty destination buffers must be fully
        // overwritten.
        let (kr, kc, c, n_rows, n_cols) = (2, 3, 2, 4, 5);
        let slab_h = n_rows + kr - 1;
        let slab_w = n_cols + kc - 1;
        let mut rng = Rng::seeded(0x6E37);
        let slab = random_mat(slab_h, slab_w * c, &mut rng);
        let patch = kr * kc * c;
        let rows_total = n_rows * n_cols;
        let mut by_rows = vec![f32::NAN; rows_total * patch];
        im2col_rows(&slab, slab_w, c, kr, kc, n_cols, 0, n_rows, &mut by_rows);
        let mut by_cols = vec![f32::NAN; patch * rows_total];
        im2col_cols(&slab, slab_w, c, kr, kc, n_cols, n_rows, &mut by_cols);
        for r in 0..rows_total {
            for t in 0..patch {
                assert_eq!(
                    by_cols[t * rows_total + r],
                    by_rows[r * patch + t],
                    "transpose mismatch at (r={r}, t={t})"
                );
            }
        }
    }

    /// The separate-path reference for the fused drivers: scatter the
    /// contiguous C matrix to the strided offsets, then bias + act —
    /// exactly what slab + `scatter_rows_view` + `LayerWeights` do.
    #[allow(clippy::too_many_arguments)]
    fn scatter_apply(
        c: &[f32],
        m: usize,
        n: usize,
        out: &mut [f32],
        base: usize,
        col_stride: usize,
        row_stride: usize,
        n_cols: usize,
        bias: Option<&[f32]>,
        act: Activation,
    ) {
        for r in 0..m {
            let off = base + (r / n_cols) * row_stride + (r % n_cols) * col_stride;
            for j in 0..n {
                let mut v = c[r * n + j];
                if let Some(b) = bias {
                    v += b[j];
                }
                out[off + j] = act.apply(v);
            }
        }
    }

    /// A synthetic stride-2 phase geometry for `m = n_rows·n_cols` C
    /// rows of `n` channels: phase (1,1) of a (2·n_rows+1)×(2·n_cols+1)
    /// output.  Returns (out_len, base, col_stride, row_stride).
    fn phase_geom(n_rows: usize, n_cols: usize, n: usize) -> (usize, usize, usize, usize) {
        let (out_h, out_w) = (2 * n_rows + 1, 2 * n_cols + 1);
        let (rp, sp) = (1, 1);
        (
            out_h * out_w * n,
            (rp * out_w + sp) * n,
            2 * n,
            2 * out_w * n,
        )
    }

    #[test]
    fn fused_matches_separate_scatter_apply() {
        // The fused-epilogue contract: scalar lane bit-identical to
        // GEMM + scatter + bias + activation; vector lanes within the
        // phase-GEMM 1e-4 tolerance.  n straddles every panel width,
        // m straddles every lane's row tile, K crosses KC.
        let (n_rows, n_cols) = (3, 4);
        let m = n_rows * n_cols;
        let mut rng = Rng::seeded(0x6E40);
        for &n in &[1usize, 7, 8, 17, 33] {
            for &k in &[1usize, 37, KC + 3] {
                let a = random_mat(m, k, &mut rng);
                let b = random_mat(k, n, &mut rng);
                let mut packed = vec![f32::NAN; packed_b_floats(k, n)];
                pack_b(&b, k, n, &mut packed);
                let mut bias = vec![0.0f32; n];
                rng.fill_normal(&mut bias);
                let (out_len, base, cstr, rstr) = phase_geom(n_rows, n_cols, n);
                for act in [Activation::None, Activation::Relu, Activation::Tanh] {
                    for bias_opt in [None, Some(&bias[..])] {
                        let epi = Epilogue { bias: bias_opt, act };
                        // Separate reference: scalar GEMM into a slab,
                        // then scatter + epilogue.
                        let mut slab = vec![0.0f32; m * n];
                        gemm_packed_isa(Isa::Scalar, &a, &packed, &mut slab, m, k, n);
                        let mut want = vec![777.0f32; out_len];
                        scatter_apply(
                            &slab, m, n, &mut want, base, cstr, rstr, n_cols, bias_opt, act,
                        );
                        for &isa in &Isa::supported() {
                            let mut got = vec![777.0f32; out_len];
                            let mut dst = StridedDst {
                                out: &mut got,
                                base,
                                col_stride: cstr,
                                row_stride: rstr,
                                n_cols,
                                img_rows: 0,
                                img_stride: 0,
                            };
                            gemm_packed_fused(isa, &a, &packed, m, k, n, &mut dst, &epi);
                            if isa == Isa::Scalar {
                                assert_eq!(
                                    got,
                                    want,
                                    "scalar fused must be bit-identical \
                                     (n={n} k={k} act={} bias={})",
                                    act.name(),
                                    bias_opt.is_some()
                                );
                            } else {
                                close(&want, &got, 1e-4).unwrap_or_else(|e| {
                                    panic!("isa={isa} n={n} k={k} act={}: {e}", act.name())
                                });
                            }
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn fused_batched_row_mapping_matches_per_image() {
        // img_rows/img_stride: one fused GEMM over two stacked images
        // must equal two per-image fused GEMMs, bit-for-bit (same lane,
        // same tiling of each image's row range... the batched m only
        // changes which rows share a ragged tile, so pick m divisible
        // by every lane's mr to keep tiling identical).
        let (n_rows, n_cols, imgs) = (2, 4, 2usize);
        let m1 = n_rows * n_cols; // 8: divisible by mr ∈ {4, 6? no}
        let (n, k) = (5usize, 9usize);
        // 8 is not divisible by the AVX2 lane's mr=6, so tiling of the
        // stacked GEMM differs from per-image — compare within 1e-4
        // for vector lanes and exactly for scalar, like the main test.
        let mut rng = Rng::seeded(0x6E41);
        let a = random_mat(imgs * m1, k, &mut rng);
        let b = random_mat(k, n, &mut rng);
        let mut packed = vec![f32::NAN; packed_b_floats(k, n)];
        pack_b(&b, k, n, &mut packed);
        let mut bias = vec![0.0f32; n];
        rng.fill_normal(&mut bias);
        let (img_len, base, cstr, rstr) = phase_geom(n_rows, n_cols, n);
        let epi = Epilogue {
            bias: Some(&bias),
            act: Activation::Relu,
        };
        for &isa in &Isa::supported() {
            let mut want = vec![777.0f32; imgs * img_len];
            for i in 0..imgs {
                let mut dst = StridedDst {
                    out: &mut want[i * img_len..(i + 1) * img_len],
                    base,
                    col_stride: cstr,
                    row_stride: rstr,
                    n_cols,
                    img_rows: 0,
                    img_stride: 0,
                };
                gemm_packed_fused(
                    isa,
                    &a[i * m1 * k..(i + 1) * m1 * k],
                    &packed,
                    m1,
                    k,
                    n,
                    &mut dst,
                    &epi,
                );
            }
            let mut got = vec![777.0f32; imgs * img_len];
            let mut dst = StridedDst {
                out: &mut got,
                base,
                col_stride: cstr,
                row_stride: rstr,
                n_cols,
                img_rows: m1,
                img_stride: img_len,
            };
            gemm_packed_fused(isa, &a, &packed, imgs * m1, k, n, &mut dst, &epi);
            if isa == Isa::Scalar {
                assert_eq!(got, want, "batched scalar fused must match per-image");
            } else {
                close(&want, &got, 1e-4)
                    .unwrap_or_else(|e| panic!("isa={isa} batched fused: {e}"));
            }
        }
    }

    #[test]
    fn fused_quantized_bit_identical_to_separate() {
        // Quantized fusion is epilogue-level scalar: it must produce
        // the exact bits of scalar quantized GEMM + scatter + epilogue
        // for f16, bf16 and int8.
        let (n_rows, n_cols) = (2, 3);
        let m = n_rows * n_cols;
        let mut rng = Rng::seeded(0x6E42);
        for &(k, n) in &[(7usize, 5usize), (16, 8), (37, 17)] {
            let af = random_mat(m, k, &mut rng);
            let bf = random_mat(k, n, &mut rng);
            let mut bias = vec![0.0f32; n];
            rng.fill_normal(&mut bias);
            let (out_len, base, cstr, rstr) = phase_geom(n_rows, n_cols, n);
            let epi = Epilogue {
                bias: Some(&bias),
                act: Activation::Tanh,
            };
            for prec in [Precision::F16, Precision::Bf16] {
                let to_bits = match prec {
                    Precision::F16 => quant::f32_to_f16_bits as fn(f32) -> u16,
                    _ => quant::f32_to_bf16_bits,
                };
                let from_bits = match prec {
                    Precision::F16 => quant::f16_bits_to_f32 as fn(u16) -> f32,
                    _ => quant::bf16_bits_to_f32,
                };
                let aq: Vec<u16> = af.iter().map(|&v| to_bits(v)).collect();
                let mut bq = vec![0u16; quant::packed_qb_elems(k, n)];
                quant::pack_b_q16(&bf, k, n, to_bits, &mut bq);
                let mut slab = vec![0.0f32; m * n];
                quant::gemm_q16_scalar(&aq, &bq, from_bits, &mut slab, m, k, n);
                let mut want = vec![777.0f32; out_len];
                scatter_apply(
                    &slab, m, n, &mut want, base, cstr, rstr, n_cols, epi.bias, epi.act,
                );
                let mut got = vec![777.0f32; out_len];
                let mut d = StridedDst {
                    out: &mut got,
                    base,
                    col_stride: cstr,
                    row_stride: rstr,
                    n_cols,
                    img_rows: 0,
                    img_stride: 0,
                };
                gemm_packed_q16_fused(prec, &aq, &bq, m, k, n, &mut d, &epi);
                assert_eq!(got, want, "{} fused k={k} n={n}", prec.name());
            }
            // int8: exact i32 accumulation, dequant scale folded first.
            let a_scale = quant::int8_scale(quant::absmax(&af));
            let mut a8 = vec![0i8; m * k];
            quant::quantize_i8(&af, a_scale, &mut a8);
            let b_scales = quant::col_absmax_scales(&bf, k, n);
            let mut b8 = vec![0i8; quant::packed_qb_elems(k, n)];
            quant::pack_b_q8(&bf, k, n, &b_scales, &mut b8);
            let mut slab = vec![0.0f32; m * n];
            quant::gemm_q8_scalar(&a8, a_scale, &b8, &b_scales, &mut slab, m, k, n);
            let mut want = vec![777.0f32; out_len];
            scatter_apply(&slab, m, n, &mut want, base, cstr, rstr, n_cols, epi.bias, epi.act);
            let mut got = vec![777.0f32; out_len];
            let mut d = StridedDst {
                out: &mut got,
                base,
                col_stride: cstr,
                row_stride: rstr,
                n_cols,
                img_rows: 0,
                img_stride: 0,
            };
            gemm_packed_q8_fused(&a8, a_scale, &b8, &b_scales, m, k, n, &mut d, &epi);
            assert_eq!(got, want, "int8 fused k={k} n={n}");
        }
    }

    #[test]
    fn neutral_epilogue_is_pure_strided_store() {
        // Epilogue::none() must store raw GEMM sums — the tuner
        // measures fused candidates through exactly this path.
        assert!(Epilogue::none().is_neutral());
        assert!(!Epilogue {
            bias: None,
            act: Activation::Relu
        }
        .is_neutral());
        let (m, k, n) = (4usize, 6usize, 5usize);
        let mut rng = Rng::seeded(0x6E43);
        let a = random_mat(m, k, &mut rng);
        let b = random_mat(k, n, &mut rng);
        let mut packed = vec![f32::NAN; packed_b_floats(k, n)];
        pack_b(&b, k, n, &mut packed);
        let mut slab = vec![0.0f32; m * n];
        gemm_packed_isa(Isa::Scalar, &a, &packed, &mut slab, m, k, n);
        // Dense geometry (col_stride = n): fused output is the slab.
        let mut got = vec![777.0f32; m * n];
        let mut dst = StridedDst {
            out: &mut got,
            base: 0,
            col_stride: n,
            row_stride: m * n, // unused: one phase row
            n_cols: m,
            img_rows: 0,
            img_stride: 0,
        };
        gemm_packed_fused(Isa::Scalar, &a, &packed, m, k, n, &mut dst, &Epilogue::none());
        assert_eq!(got, slab);
    }
}
