//! Backward pass of the transpose convolution — the training-stage
//! benefit the paper claims ("reduces computational load and memory
//! requirements in the **training** and inference stages", §2; the
//! §2.1 criticism that bed-of-nails methods add "extra load ... during
//! the backward propagation phase").
//!
//! Gradients of `y = T_K(x)` (transpose conv, padding factor `P`):
//!
//! * **∂L/∂x** — conventional route: correlate the padded upsampled
//!   input's gradient ... i.e. propagate through the explicit upsample:
//!   `dL/dU = full_corr(dL/dy, flip(K))`, then *downsample* (read every
//!   other element).  Unified route: never materialize `dL/dU`; each
//!   input pixel only receives gradients from the output phase its
//!   sub-kernel touched, so `dL/dx = Σ_phases corr_full(dy_phase,
//!   flip(k_rs))` — the same 4× multiplication saving, now in the
//!   backward direction.
//! * **∂L/∂K** — per-tap: `dL/dK[u,v] = Σ_i,j U_pad[i+u, j+v] ⊗
//!   dy[i,j]`; the unified route computes each sub-kernel's gradient
//!   from its phase only and re-interleaves (zero wasted work).
//!
//! Both routes are validated against each other and against central
//! finite differences.

use crate::tensor::{ops, Feature};
use crate::tensor::Kernel;

use super::segregation::segregate;
use super::unified::phase_geometries;

/// Gradient w.r.t. the input, conventional route (materializes the
/// upsampled-gradient buffer — the training-time cost the paper
/// criticizes).
pub fn grad_input_conventional(
    dy: &Feature,
    k: &Kernel,
    n_in: usize,
    padding: usize,
) -> Feature {
    // dL/dU_pad[a, b] = Σ_{u,v} dy[a-u, b-v] · K[u,v]  (full correlation
    // with the flipped kernel).  Implement by zero-padding dy by (n-1)
    // and correlating with the flipped kernel.
    let n_k = k.n;
    let flipped = flip_kernel(k);
    let dy_pad = ops::pad(dy, n_k - 1);
    let du = super::conventional::correlate_valid(&dy_pad, &flipped); // [2N-1+2P]²
    // Strip the padding ring, then downsample (bed-of-nails adjoint).
    let up_side = 2 * n_in - 1;
    let du_core = ops::crop(&du, padding, padding, up_side, up_side);
    ops::extract_phase(&du_core, 0, 0)
}

/// Gradient w.r.t. the input, unified route: per-phase correlation with
/// the flipped sub-kernels, no upsampled buffer.
pub fn grad_input_unified(dy: &Feature, k: &Kernel, n_in: usize, padding: usize) -> Feature {
    let seg = segregate(k);
    let cin = k.cin;
    let cout = k.cout;
    let mut dx = Feature::zeros(n_in, n_in, cin);
    for g in phase_geometries(n_in, k.n, padding) {
        let sub = &seg.subs[g.sub];
        // Phase slice of dy.
        let dyp = extract_output_phase(dy, g.rp, g.sp, g.n_rows, g.n_cols, cout);
        // dL/dslab = full-corr(dyp, flip(sub)) over the slab coordinates,
        // then accumulate the slab back into dx (adjoint of pad+crop).
        let flipped = flip_sub(sub);
        let dyp_pad = ops::pad_asym(
            &dyp,
            sub.rows - 1,
            sub.rows - 1,
            sub.cols - 1,
            sub.cols - 1,
        );
        let dslab = super::conventional::correlate_valid(&dyp_pad, &flipped);
        accumulate_slab_adjoint(&mut dx, &dslab, &g);
    }
    dx
}

/// Gradient w.r.t. the kernel, conventional route.
pub fn grad_kernel_conventional(
    x: &Feature,
    dy: &Feature,
    n_k: usize,
    padding: usize,
) -> Kernel {
    let up = ops::upsample_bed_of_nails(x);
    let upp = ops::pad(&up, padding);
    let cin = x.c;
    let cout = dy.c;
    let mut dk = Kernel::zeros(n_k, cin, cout);
    for u in 0..n_k {
        for v in 0..n_k {
            for oy in 0..dy.h {
                for ox in 0..dy.w {
                    let px = upp.pixel(oy + u, ox + v);
                    let gy = dy.pixel(oy, ox);
                    let base = dk.idx(u, v, 0, 0);
                    for (ci, &xv) in px.iter().enumerate() {
                        if xv == 0.0 {
                            continue;
                        }
                        let row = &mut dk.data[base + ci * cout..base + (ci + 1) * cout];
                        for (d, &g) in row.iter_mut().zip(gy) {
                            *d += xv * g;
                        }
                    }
                }
            }
        }
    }
    dk
}

/// Gradient w.r.t. the kernel, unified route: each sub-kernel's
/// gradient comes from its phase only; re-interleave into the full dK.
pub fn grad_kernel_unified(x: &Feature, dy: &Feature, n_k: usize, padding: usize) -> Kernel {
    let cin = x.c;
    let cout = dy.c;
    let n_in = x.h;
    let mut dk = Kernel::zeros(n_k, cin, cout);
    for g in phase_geometries(n_in, n_k, padding) {
        let (r, s) = (g.sub / 2, g.sub % 2);
        let dyp = extract_output_phase(dy, g.rp, g.sp, g.n_rows, g.n_cols, cout);
        // Slab as in forward.
        let (pt, pb, pl, pr) = g.pads;
        let padded = ops::pad_asym(x, pt, pb, pl, pr);
        let slab = ops::crop(
            &padded,
            g.rows.0,
            g.cols.0,
            g.rows.1 - g.rows.0,
            g.cols.1 - g.cols.0,
        );
        // dSub[u,v] = Σ slab[oy+u, ox+v] ⊗ dyp[oy, ox]; scatter into the
        // full-kernel taps (r + 2u, s + 2v).
        let sub_rows = (n_k - r).div_ceil(2);
        let sub_cols = (n_k - s).div_ceil(2);
        for u in 0..sub_rows {
            for v in 0..sub_cols {
                let base = dk.idx(r + 2 * u, s + 2 * v, 0, 0);
                for oy in 0..dyp.h {
                    for ox in 0..dyp.w {
                        let px = slab.pixel(oy + u, ox + v);
                        let gy = dyp.pixel(oy, ox);
                        for (ci, &xv) in px.iter().enumerate() {
                            let row =
                                &mut dk.data[base + ci * cout..base + (ci + 1) * cout];
                            for (d, &g2) in row.iter_mut().zip(gy) {
                                *d += xv * g2;
                            }
                        }
                    }
                }
            }
        }
    }
    dk
}

// ------------------------------------------------------------- helpers

/// Spatial flip + channel transpose: the backward kernel maps cout→cin,
/// so `f[n-1-u, n-1-v, co, ci] = k[u, v, ci, co]`.
fn flip_kernel(k: &Kernel) -> Kernel {
    let mut f = Kernel::zeros(k.n, k.cout, k.cin);
    for u in 0..k.n {
        for v in 0..k.n {
            for ci in 0..k.cin {
                for co in 0..k.cout {
                    let dst = f.idx(k.n - 1 - u, k.n - 1 - v, co, ci);
                    f.data[dst] = k.get(u, v, ci, co);
                }
            }
        }
    }
    f
}

/// Sub-kernel analogue of [`flip_kernel`].  `pub(crate)` so
/// [`crate::conv::plan`] can freeze the flipped sub-kernels (and their
/// packed GEMM operands) at plan-construction time.
pub(crate) fn flip_sub(s: &crate::tensor::SubKernel) -> crate::tensor::SubKernel {
    let mut f = crate::tensor::SubKernel::zeros(s.rows, s.cols, s.cout, s.cin);
    for u in 0..s.rows {
        for v in 0..s.cols {
            for ci in 0..s.cin {
                for co in 0..s.cout {
                    let dst = f.idx(s.rows - 1 - u, s.cols - 1 - v, co, ci);
                    f.data[dst] = s.get(u, v, ci, co);
                }
            }
        }
    }
    f
}

/// Extract output phase `(rp, sp)` of `dy` as a dense map.
fn extract_output_phase(
    dy: &Feature,
    rp: usize,
    sp: usize,
    n_rows: usize,
    n_cols: usize,
    cout: usize,
) -> Feature {
    let mut out = Feature::zeros(n_rows, n_cols, cout);
    for (py, y) in (rp..dy.h).step_by(2).enumerate().take(n_rows) {
        for (px, x) in (sp..dy.w).step_by(2).enumerate().take(n_cols) {
            let src = dy.idx(y, x, 0);
            let dst = out.idx(py, px, 0);
            out.data[dst..dst + cout].copy_from_slice(&dy.data[src..src + cout]);
        }
    }
    out
}

/// Adjoint of `phase_slab`: accumulate a slab-gradient back into dx,
/// discarding positions that fell in zero padding.
fn accumulate_slab_adjoint(
    dx: &mut Feature,
    dslab: &Feature,
    g: &super::unified::PhaseGeometry,
) {
    let (pt, _, pl, _) = g.pads;
    let row0 = g.rows.0;
    let col0 = g.cols.0;
    let n = dx.h as isize;
    let c = dx.c;
    for sy in 0..dslab.h {
        // Position in the padded frame → raw-input frame.
        let iy = (row0 + sy) as isize - pt as isize;
        if iy < 0 || iy >= n {
            continue;
        }
        for sx in 0..dslab.w {
            let ix = (col0 + sx) as isize - pl as isize;
            if ix < 0 || ix >= n {
                continue;
            }
            let src = dslab.idx(sy, sx, 0);
            let dst = dx.idx(iy as usize, ix as usize, 0);
            for ci in 0..c {
                dx.data[dst + ci] += dslab.data[src + ci];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::conventional;
    use crate::conv::out_size;
    use crate::util::prop::{close, forall_res, Config};
    use crate::util::rng::Rng;

    /// Loss = Σ y ⊙ w for a fixed random weighting w → dL/dy = w.
    fn weighted_loss_grad(shape: (usize, usize, usize), rng: &mut Rng) -> Feature {
        Feature::random(shape.0, shape.1, shape.2, rng)
    }

    fn forward(x: &Feature, k: &Kernel, p: usize) -> Feature {
        conventional::transpose_conv(x, k, p)
    }

    /// Central finite difference of dL/dx[idx].
    fn fd_input(x: &Feature, k: &Kernel, p: usize, w: &Feature, idx: usize) -> f32 {
        let eps = 1e-2f32;
        let mut xp = x.clone();
        xp.data[idx] += eps;
        let mut xm = x.clone();
        xm.data[idx] -= eps;
        let yp = forward(&xp, k, p);
        let ym = forward(&xm, k, p);
        let lp: f32 = yp.data.iter().zip(&w.data).map(|(a, b)| a * b).sum();
        let lm: f32 = ym.data.iter().zip(&w.data).map(|(a, b)| a * b).sum();
        (lp - lm) / (2.0 * eps)
    }

    fn fd_kernel(x: &Feature, k: &Kernel, p: usize, w: &Feature, idx: usize) -> f32 {
        let eps = 1e-2f32;
        let mut kp = k.clone();
        kp.data[idx] += eps;
        let mut km = k.clone();
        km.data[idx] -= eps;
        let yp = forward(x, &kp, p);
        let ym = forward(x, &km, p);
        let lp: f32 = yp.data.iter().zip(&w.data).map(|(a, b)| a * b).sum();
        let lm: f32 = ym.data.iter().zip(&w.data).map(|(a, b)| a * b).sum();
        (lp - lm) / (2.0 * eps)
    }

    #[test]
    fn grad_input_matches_finite_difference() {
        let mut rng = Rng::seeded(80);
        for (n_in, nk, p) in [(4, 3, 1), (4, 4, 2), (3, 5, 2)] {
            let x = Feature::random(n_in, n_in, 2, &mut rng);
            let k = Kernel::random(nk, 2, 2, &mut rng);
            let ho = out_size(n_in, nk, p);
            let w = weighted_loss_grad((ho, ho, 2), &mut rng);
            let dx = grad_input_conventional(&w, &k, n_in, p);
            assert_eq!((dx.h, dx.w, dx.c), (n_in, n_in, 2));
            for idx in [0, dx.data.len() / 2, dx.data.len() - 1] {
                let fd = fd_input(&x, &k, p, &w, idx);
                assert!(
                    (dx.data[idx] - fd).abs() < 2e-2 * (1.0 + fd.abs()),
                    "dx[{idx}]={} fd={fd} (n={n_in} k={nk} p={p})",
                    dx.data[idx]
                );
            }
        }
    }

    #[test]
    fn grad_input_unified_equals_conventional() {
        forall_res(
            Config::default().cases(40),
            "grad_input unified == conventional",
            |rng| {
                let n_in = rng.range(1, 7);
                let nk = rng.range(2, 5);
                let p = rng.range(0, 3);
                if 2 * n_in + 2 * p <= nk {
                    return ((n_in, nk, p), Ok(()));
                }
                let mut r2 = rng.split();
                let k = Kernel::random(nk, 2, 3, &mut r2);
                let ho = out_size(n_in, nk, p);
                let dy = Feature::random(ho, ho, 3, &mut r2);
                let a = grad_input_conventional(&dy, &k, n_in, p);
                let b = grad_input_unified(&dy, &k, n_in, p);
                ((n_in, nk, p), close(&a.data, &b.data, 1e-3))
            },
        );
    }

    #[test]
    fn grad_kernel_matches_finite_difference() {
        let mut rng = Rng::seeded(81);
        let (n_in, nk, p) = (4, 4, 2);
        let x = Feature::random(n_in, n_in, 2, &mut rng);
        let k = Kernel::random(nk, 2, 2, &mut rng);
        let ho = out_size(n_in, nk, p);
        let w = weighted_loss_grad((ho, ho, 2), &mut rng);
        let dk = grad_kernel_conventional(&x, &w, nk, p);
        for idx in [0, dk.data.len() / 3, dk.data.len() - 1] {
            let fd = fd_kernel(&x, &k, p, &w, idx);
            assert!(
                (dk.data[idx] - fd).abs() < 2e-2 * (1.0 + fd.abs()),
                "dk[{idx}]={} fd={fd}",
                dk.data[idx]
            );
        }
    }

    #[test]
    fn grad_kernel_unified_equals_conventional() {
        forall_res(
            Config::default().cases(30),
            "grad_kernel unified == conventional",
            |rng| {
                let n_in = rng.range(1, 6);
                let nk = rng.range(2, 5);
                let p = rng.range(0, 3);
                if 2 * n_in + 2 * p <= nk {
                    return ((n_in, nk, p), Ok(()));
                }
                let mut r2 = rng.split();
                let x = Feature::random(n_in, n_in, 2, &mut r2);
                let ho = out_size(n_in, nk, p);
                let dy = Feature::random(ho, ho, 2, &mut r2);
                let a = grad_kernel_conventional(&x, &dy, nk, p);
                let b = grad_kernel_unified(&x, &dy, nk, p);
                ((n_in, nk, p), close(&a.data, &b.data, 1e-3))
            },
        );
    }

    #[test]
    fn flip_is_involution() {
        let mut rng = Rng::seeded(82);
        let k = Kernel::random(5, 2, 3, &mut rng);
        let ff = flip_kernel(&flip_kernel(&k));
        assert_eq!(ff, k); // flip+transpose twice is the identity
    }
}
