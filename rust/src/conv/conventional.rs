//! Algorithm 1: conventional transpose convolution.
//!
//! Literal implementation of the paper's baseline: bed-of-nails
//! upsampling (`N×N → (2N-1)×(2N-1)`), zero-padding by `P`, then a
//! stride-1 VALID cross-correlation with the full `n×n` kernel —
//! including every multiplication against an inserted zero that the
//! unified algorithm skips.  The correlation primitive here is dense on
//! purpose: the baseline must *pay* for the zeros, exactly as the
//! paper's C++/CUDA baseline does.

use crate::tensor::{ops, Feature};
use crate::util::threadpool;

use super::{simd, TapSet};

/// VALID stride-1 cross-correlation of `x` with `taps`, serial, dense.
///
/// Inner loop is channel-contiguous: for each output pixel and tap, an
/// `acc[co] += px[ci] * tap[ci][co]` rank-1 update over contiguous
/// slices, which LLVM auto-vectorizes.  No data-dependent branches.
pub fn correlate_valid<T: TapSet>(x: &Feature, taps: &T) -> Feature {
    let (kr, kc) = (taps.rows(), taps.cols());
    assert!(x.h >= kr && x.w >= kc, "correlate_valid: input smaller than kernel");
    assert_eq!(x.c, taps.cin(), "correlate_valid: channel mismatch");
    let (ho, wo) = (x.h - kr + 1, x.w - kc + 1);
    let cout = taps.cout();
    let mut out = Feature::zeros(ho, wo, cout);
    correlate_valid_into(x, taps, &mut out.data, wo, 0, ho);
    out
}

/// Correlate output rows `[row_lo, row_hi)` into `out` (a buffer
/// covering exactly those rows, `wo * cout` floats per row).
pub(crate) fn correlate_valid_into<T: TapSet>(
    x: &Feature,
    taps: &T,
    out: &mut [f32],
    wo: usize,
    row_lo: usize,
    row_hi: usize,
) {
    debug_assert_eq!(x.c, taps.cin(), "correlate_valid_into: channel mismatch");
    correlate_rows(&x.data, x.w, taps, out, wo, row_lo, row_hi)
}

/// [`correlate_valid_into`] over a raw row-major HWC slab (`data` of
/// width `w`, channel count `taps.cin()`) — lets the plan/execute path
/// (`conv::plan`) correlate straight out of a scratch arena without
/// wrapping the slab in an owned [`Feature`].  Loop structure and f32
/// accumulation order are identical to the `Feature` path, so the two
/// are bit-identical.
pub(crate) fn correlate_rows<T: TapSet>(
    data: &[f32],
    w: usize,
    taps: &T,
    out: &mut [f32],
    wo: usize,
    row_lo: usize,
    row_hi: usize,
) {
    let (kr, kc) = (taps.rows(), taps.cols());
    let (cin, cout) = (taps.cin(), taps.cout());
    let stride = w * cin;
    if cout == 1 {
        // Scalar-output specialization (the Table 2/3 configuration):
        // keep the accumulator in a register across the whole tap loop.
        for oy in row_lo..row_hi {
            let row_base = (oy - row_lo) * wo;
            for ox in 0..wo {
                let mut acc = 0f32;
                for u in 0..kr {
                    let in_row = &data[(oy + u) * stride..(oy + u + 1) * stride];
                    for v in 0..kc {
                        let tap = taps.tap(u, v);
                        let px = &in_row[(ox + v) * cin..(ox + v + 1) * cin];
                        for (xv, t) in px.iter().zip(tap) {
                            acc += xv * t;
                        }
                    }
                }
                out[row_base + ox] = acc;
            }
        }
        return;
    }
    // General path: tap-outer so each `[Cin, Cout]` tap matrix is
    // streamed once per output row instead of once per pixel (pixel-
    // outer was tried and regressed large-Cout layers ~25% — the tap
    // matrices blow L2; EXPERIMENTS.md §Perf iteration 1).  The inner
    // rank-1 update dispatches to the active SIMD lane's saxpy
    // (mul+add, never FMA), which is bit-identical to the scalar loop
    // per output lane — the `==` contract with the one-shot reference
    // survives (DESIGN.md §SIMD-Dispatch).
    let saxpy = simd::saxpy_kernel();
    for oy in row_lo..row_hi {
        let row_base = (oy - row_lo) * wo * cout;
        for u in 0..kr {
            let in_row = &data[(oy + u) * stride..(oy + u + 1) * stride];
            for v in 0..kc {
                let tap = taps.tap(u, v);
                for ox in 0..wo {
                    let px = &in_row[(ox + v) * cin..(ox + v + 1) * cin];
                    let acc = &mut out[row_base + ox * cout..row_base + (ox + 1) * cout];
                    for (ci, &xv) in px.iter().enumerate() {
                        saxpy(acc, xv, &tap[ci * cout..(ci + 1) * cout]);
                    }
                }
            }
        }
    }
}

/// Algorithm 1, serial: upsample → pad → dense correlate.
pub fn transpose_conv<T: TapSet>(x: &Feature, k: &T, padding: usize) -> Feature {
    let up = ops::upsample_bed_of_nails(x);
    let padded = ops::pad(&up, padding);
    correlate_valid(&padded, k)
}

/// Algorithm 1 with a runtime zero-skip branch — an ablation lane (NOT
/// the paper baseline): shows how much of the unified win a branchy
/// CPU baseline could recover by testing for inserted zeros, at the
/// cost of a data-dependent branch per input element.
pub fn transpose_conv_zeroskip<T: TapSet>(x: &Feature, k: &T, padding: usize) -> Feature {
    let up = ops::upsample_bed_of_nails(x);
    let padded = ops::pad(&up, padding);
    let (kr, kc) = (k.rows(), k.cols());
    let (ho, wo) = (padded.h - kr + 1, padded.w - kc + 1);
    let (cin, cout) = (k.cin(), k.cout());
    let mut out = Feature::zeros(ho, wo, cout);
    for oy in 0..ho {
        for u in 0..kr {
            let in_row = padded.row(oy + u);
            for v in 0..kc {
                let tap = k.tap(u, v);
                for ox in 0..wo {
                    let px = &in_row[(ox + v) * cin..(ox + v + 1) * cin];
                    let base = (oy * wo + ox) * cout;
                    let acc = &mut out.data[base..base + cout];
                    for (ci, &xv) in px.iter().enumerate() {
                        if xv != 0.0 {
                            let trow = &tap[ci * cout..(ci + 1) * cout];
                            for (a, &t) in acc.iter_mut().zip(trow) {
                                *a += xv * t;
                            }
                        }
                    }
                }
            }
        }
    }
    out
}

/// Algorithm 1, parallel lane: output rows distributed over `workers`
/// threads (the "GPU" emulation — the CUDA grid of per-element threads
/// becomes row-chunks per OS thread; see DESIGN.md §2).
pub fn transpose_conv_par<T: TapSet + Sync>(
    x: &Feature,
    k: &T,
    padding: usize,
    workers: usize,
) -> Feature {
    let up = ops::upsample_bed_of_nails(x);
    let padded = ops::pad(&up, padding);
    let (kr, kc) = (k.rows(), k.cols());
    let (ho, wo) = (padded.h - kr + 1, padded.w - kc + 1);
    let cout = k.cout();
    let mut out = Feature::zeros(ho, wo, cout);
    let row_len = wo * cout;
    let padded_ref = &padded;
    threadpool::parallel_chunks_mut(&mut out.data, ho.max(1), workers, |row, chunk| {
        debug_assert_eq!(chunk.len(), row_len);
        correlate_valid_into(padded_ref, k, chunk, wo, row, row + 1);
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Kernel;
    use crate::util::rng::Rng;

    /// Hand-computed 1-channel example: 2×2 input, 2×2 kernel, P=0.
    /// Upsampled = [[1,0,2],[0,0,0],[3,0,4]]; out = 2×2.
    #[test]
    fn tiny_hand_example() {
        let x = Feature::from_vec(2, 2, 1, vec![1.0, 2.0, 3.0, 4.0]);
        let k = Kernel::from_vec(2, 1, 1, vec![10.0, 20.0, 30.0, 40.0]);
        let out = transpose_conv(&x, &k, 0);
        assert_eq!((out.h, out.w, out.c), (2, 2, 1));
        assert_eq!(out.get(0, 0, 0), 10.0); // 1*k[0,0]
        assert_eq!(out.get(0, 1, 0), 40.0); // 2*k[0,1]
        assert_eq!(out.get(1, 0, 0), 3.0 * 30.0);
        assert_eq!(out.get(1, 1, 0), 4.0 * 40.0);
    }

    #[test]
    fn output_shape_with_padding() {
        let mut rng = Rng::seeded(1);
        let x = Feature::random(4, 4, 3, &mut rng);
        let k = Kernel::random(5, 3, 2, &mut rng);
        let out = transpose_conv(&x, &k, 2);
        assert_eq!((out.h, out.w, out.c), (7, 7, 2)); // 2*4+4-5 = 7
    }

    #[test]
    fn zeroskip_matches_dense() {
        let mut rng = Rng::seeded(2);
        let x = Feature::random(5, 5, 2, &mut rng);
        let k = Kernel::random(3, 2, 3, &mut rng);
        let a = transpose_conv(&x, &k, 1);
        let b = transpose_conv_zeroskip(&x, &k, 1);
        assert!(ops::max_abs_diff(&a, &b) < 1e-5);
    }

    #[test]
    fn parallel_matches_serial() {
        let mut rng = Rng::seeded(3);
        let x = Feature::random(6, 6, 3, &mut rng);
        let k = Kernel::random(4, 3, 4, &mut rng);
        let serial = transpose_conv(&x, &k, 2);
        for workers in [1, 2, 4, 8] {
            let par = transpose_conv_par(&x, &k, 2, workers);
            assert!(ops::max_abs_diff(&serial, &par) < 1e-5);
        }
    }

    #[test]
    fn correlate_identity_kernel() {
        // 1×1 kernel with weight 1 is the identity.
        let mut rng = Rng::seeded(4);
        let x = Feature::random(3, 3, 1, &mut rng);
        let k = Kernel::from_vec(1, 1, 1, vec![1.0]);
        let out = correlate_valid(&x, &k);
        assert_eq!(out, x);
    }

    #[test]
    #[should_panic(expected = "channel mismatch")]
    fn channel_mismatch_panics() {
        let x = Feature::zeros(3, 3, 2);
        let k = Kernel::zeros(2, 3, 1);
        correlate_valid(&x, &k);
    }
}
