//! Analytic MAC (multiply-accumulate) counts for every algorithm.
//!
//! Used by the bench harness to report achieved GFLOP/s and by the
//! ablation to confirm the paper's "number of floating-point operation
//! reductions remains the same as [HICSS'23]" claim.

use super::{out_size, ConvTransposeParams};

/// MACs of Algorithm 1 — every tap of the full kernel at every output
/// position, zeros included.
pub fn conventional(p: &ConvTransposeParams) -> u64 {
    let ho = p.out_size() as u64;
    ho * ho * (p.n_k * p.n_k * p.cin * p.cout) as u64
}

/// MACs of Algorithm 2 — only the effective (non-zero-hitting) taps:
/// each output parity phase uses its sub-kernel's taps exactly once per
/// phase element.
pub fn unified(p: &ConvTransposeParams) -> u64 {
    let ho = out_size(p.n_in, p.n_k, p.padding);
    let ceil = p.n_k.div_ceil(2);
    let floor = p.n_k / 2;
    let mut total = 0u64;
    for rp in 0..2usize {
        for sp in 0..2usize {
            let r = (rp + p.padding) % 2;
            let s = (sp + p.padding) % 2;
            let kr = if r == 0 { ceil } else { floor };
            let ks = if s == 0 { ceil } else { floor };
            let n_rows = if ho > rp { (ho - rp).div_ceil(2) } else { 0 };
            let n_cols = if ho > sp { (ho - sp).div_ceil(2) } else { 0 };
            total += (n_rows * n_cols * kr * ks * p.cin * p.cout) as u64;
        }
    }
    total
}

/// MACs of the HICSS'23 grouped formulation: identical to [`unified`]
/// on even outputs, plus the wasted extra row/column of 2×2 blocks on
/// odd outputs.
pub fn grouped(p: &ConvTransposeParams) -> u64 {
    let ho = out_size(p.n_in, p.n_k, p.padding);
    let ho_pad = ho.div_ceil(2) * 2;
    // Padded output: every parity phase has exactly ho_pad/2 extent.
    let ceil = p.n_k.div_ceil(2);
    let floor = p.n_k / 2;
    let half = (ho_pad / 2) as u64;
    let mut total = 0u64;
    for rp in 0..2usize {
        for sp in 0..2usize {
            let r = (rp + p.padding) % 2;
            let s = (sp + p.padding) % 2;
            let kr = if r == 0 { ceil } else { floor } as u64;
            let ks = if s == 0 { ceil } else { floor } as u64;
            total += half * half * kr * ks * (p.cin * p.cout) as u64;
        }
    }
    total
}

/// The paper's ideal-case claim (§3.4): unified should approach 4× fewer
/// MACs than conventional.  Returns the actual ratio.
pub fn reduction_ratio(p: &ConvTransposeParams) -> f64 {
    conventional(p) as f64 / unified(p) as f64
}

/// Wasted MACs of the grouped approach relative to unified (zero when
/// the output feature map is even-sized).
pub fn grouped_waste(p: &ConvTransposeParams) -> u64 {
    grouped(p) - unified(p)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params(n_in: usize, n_k: usize, pad: usize) -> ConvTransposeParams {
        ConvTransposeParams::new(n_in, n_k, pad, 3, 2)
    }

    #[test]
    fn unified_about_quarter_of_conventional() {
        for p in [params(4, 4, 2), params(8, 5, 2), params(16, 3, 1), params(224, 5, 2)] {
            let ratio = reduction_ratio(&p);
            assert!(ratio > 3.0 && ratio <= 4.5, "ratio={ratio} for {p:?}");
        }
    }

    #[test]
    fn grouped_equals_unified_on_even_output() {
        let p = params(4, 4, 2); // ho = 8, even
        assert_eq!(grouped(&p), unified(&p));
        assert_eq!(grouped_waste(&p), 0);
    }

    #[test]
    fn grouped_wastes_on_odd_output() {
        let p = params(4, 5, 2); // ho = 7, odd
        assert!(grouped_waste(&p) > 0);
        // Waste is the extra row+col of 2×2 blocks: padded 8×8 output
        // vs the exact phase extents (4·4 + 4·3 + 3·4 + 3·3 = 49 ≠ 64).
    }

    #[test]
    fn grouped_exact_value_odd_case() {
        // ho=7 → padded 8: each phase 4×4 elements.
        // Subs for 5×5: 3×3, 3×2, 2×3, 2×2 → 9+6+6+4 = 25 taps.
        // grouped = 16 * 25 * cin*cout = 16*25*6 = 2400.
        let p = params(4, 5, 2);
        assert_eq!(grouped(&p), 2400);
        // unified: 4*4*9 + 4*3*6 + 3*4*6 + 3*3*4 = 144+72+72+36 = 324
        // times cin*cout=6 → 1944.
        assert_eq!(unified(&p), 1944);
        assert_eq!(grouped_waste(&p), 456);
    }

    #[test]
    fn conventional_formula() {
        let p = params(4, 5, 2); // ho=7
        assert_eq!(conventional(&p), 49 * 25 * 6);
    }

    #[test]
    fn flop_reduction_matches_hicss_claim() {
        // §4.3: "The number of floating-point operation reductions
        // remains the same as [HICSS'23]" — on even outputs the two
        // segregated variants count identically.
        for p in [params(4, 4, 2), params(32, 4, 2), params(64, 4, 2)] {
            assert_eq!(unified(&p), grouped(&p));
        }
    }
}
