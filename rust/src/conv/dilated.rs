//! Dilated convolution via segregated *inputs* (paper §5 future work).
//!
//! Dilated (atrous) convolution upsamples the **kernel** with
//! bed-of-nails zeros (Yu & Koltun 2015): with rate 2, an `n×n` kernel
//! becomes `(2n-1)×(2n-1)` and most of its taps are zeros.  The paper's
//! §5 observes the same computation-pattern trick applies with the
//! roles swapped: segregate the *input feature map* into its four
//! parity phases and convolve each phase with the original, un-dilated
//! kernel — zero wasted multiplications, no dilated kernel buffer.
//!
//! Both routes are implemented; the naive one is the correctness oracle
//! and the ablation bench quantifies the savings (extending the paper's
//! future-work claim with a measurement).

use crate::tensor::{ops, Feature};
use crate::tensor::Kernel;

use super::conventional::correlate_valid;

/// Output size of a VALID rate-2 dilated conv: `H - 2(n-1)`.
pub fn out_size_dilated(n_in: usize, n_k: usize) -> usize {
    n_in
        .checked_sub(2 * (n_k - 1))
        .expect("input too small for dilated kernel")
}

/// Naive route: bed-of-nails-upsample the kernel to `(2n-1)×(2n-1)`,
/// then dense VALID correlation (pays for all the inserted zeros).
pub fn dilated_conv_naive(x: &Feature, k: &Kernel) -> Feature {
    let nd = 2 * k.n - 1;
    let mut kd = Kernel::zeros(nd, k.cin, k.cout);
    for u in 0..k.n {
        for v in 0..k.n {
            let src = k.tap(u, v);
            let base = kd.idx(2 * u, 2 * v, 0, 0);
            kd.data[base..base + src.len()].copy_from_slice(src);
        }
    }
    correlate_valid(x, &kd)
}

/// Optimized route (§5): segregate the input into parity phases and
/// convolve each with the original kernel.
///
/// For output index `(i, j)`: `out[i,j] = Σ x[i+2u, j+2v]·k[u,v]`, and
/// `i + 2u` has the parity of `i` — so output phase `(r, s)` is exactly
/// the VALID correlation of input phase `(r, s)` with `k`.
pub fn dilated_conv_segregated(x: &Feature, k: &Kernel) -> Feature {
    let ho = out_size_dilated(x.h, k.n);
    let wo = out_size_dilated(x.w, k.n);
    let mut out = Feature::zeros(ho, wo, k.cout);
    for r in 0..2usize {
        if r >= ho {
            continue;
        }
        for s in 0..2usize {
            if s >= wo {
                continue;
            }
            let phase_in = ops::extract_phase(x, r, s);
            let phase_out = correlate_valid(&phase_in, k);
            // Scatter into out[r::2, s::2].
            let n_rows = (ho - r).div_ceil(2);
            let n_cols = (wo - s).div_ceil(2);
            for py in 0..n_rows {
                for px in 0..n_cols {
                    let src = phase_out.idx(py, px, 0);
                    let dst = out.idx(r + 2 * py, s + 2 * px, 0);
                    out.data[dst..dst + k.cout]
                        .copy_from_slice(&phase_out.data[src..src + k.cout]);
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{close, forall_res, Config};
    use crate::util::rng::Rng;

    #[test]
    fn shapes() {
        assert_eq!(out_size_dilated(9, 3), 5);
        assert_eq!(out_size_dilated(7, 2), 5);
    }

    #[test]
    fn segregated_matches_naive() {
        let mut rng = Rng::seeded(50);
        let x = Feature::random(9, 9, 3, &mut rng);
        let k = Kernel::random(3, 3, 2, &mut rng);
        let a = dilated_conv_naive(&x, &k);
        let b = dilated_conv_segregated(&x, &k);
        assert_eq!((a.h, a.w, a.c), (b.h, b.w, b.c));
        assert!(ops::max_abs_diff(&a, &b) < 1e-4);
    }

    #[test]
    fn prop_dilated_equivalence() {
        forall_res(Config::default().cases(30), "dilated seg == naive", |rng| {
            let nk = rng.range(2, 4);
            let n_in = rng.range(2 * (nk - 1) + 1, 12);
            let mut r2 = rng.split();
            let x = Feature::random(n_in, n_in, 2, &mut r2);
            let k = Kernel::random(nk, 2, 2, &mut r2);
            let a = dilated_conv_naive(&x, &k);
            let b = dilated_conv_segregated(&x, &k);
            ((n_in, nk), close(&a.data, &b.data, 1e-3))
        });
    }

    #[test]
    #[should_panic(expected = "input too small")]
    fn too_small_input_panics() {
        out_size_dilated(3, 3);
    }
}
