//! The paper's transpose-convolution algorithms and their extensions.
//!
//! * [`conventional`] — Algorithm 1: bed-of-nails upsample + stride-1
//!   correlation (the baseline every speedup is measured against)
//! * [`segregation`] — the Fig. 4 kernel-splitting mechanism
//! * [`grouped`] — the HICSS'23 prior work: four sub-kernels grouped per
//!   work-item, over-computing on odd output sizes
//! * [`unified`] — **the paper's contribution** (Algorithm 2 / Eqs. 1–4)
//! * [`parallel`] — multi-threaded lanes of all three ("GPU" substitute)
//! * [`im2col`] — GEMM-based transpose conv (§5 discussion baseline)
//! * [`gemm`] — register-blocked, cache-tiled f32 microkernel behind
//!   the planned phase-GEMM formulation and the im2col lanes
//! * [`simd`] — runtime ISA dispatch (AVX2+FMA / AVX-512 / NEON with
//!   scalar fallback) for the GEMM microkernel and the direct inner
//!   loops
//! * [`quant`] — reduced-precision (f16/bf16/int8) operand storage and
//!   widening GEMM kernels behind the `Precision` strategy axis
//! * [`dilated`] — segregated-input dilated convolution (§5 future work)
//! * [`flops`] — analytic MAC counts
//! * [`memory`] — analytic buffer accounting (matches the paper's
//!   savings columns exactly; see DESIGN.md §6)
//! * [`backward`] — training-stage gradients, both routes
//! * [`stride`] — generalized stride-s segregation (extension)
//!
//! All algorithms share the geometry in [`ConvTransposeParams`] and are
//! bit-comparable: given the same input/kernel they produce the same
//! output up to f32 accumulation-order error.

pub mod backward;
pub mod conventional;
pub mod dilated;
pub mod flops;
pub mod gemm;
pub mod grouped;
pub mod im2col;
pub mod memory;
pub mod parallel;
pub mod plan;
pub mod quant;
pub mod segregation;
pub mod simd;
pub mod stride;
pub mod unified;

use crate::tensor::{Kernel, SubKernel};

/// Geometry of one transpose-convolution operation, in the paper's
/// bed-of-nails framing: input `N×N×Cin`, kernel `n×n×Cin×Cout`,
/// padding factor `P` applied to the *upsampled* map.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ConvTransposeParams {
    /// Input spatial size `N` (square).
    pub n_in: usize,
    /// Kernel spatial size `n` (square).
    pub n_k: usize,
    /// Padding factor `P` on the upsampled map.
    pub padding: usize,
    pub cin: usize,
    pub cout: usize,
}

impl ConvTransposeParams {
    pub fn new(n_in: usize, n_k: usize, padding: usize, cin: usize, cout: usize) -> Self {
        ConvTransposeParams {
            n_in,
            n_k,
            padding,
            cin,
            cout,
        }
    }

    /// The standard GAN generator block: `k=4, s=2, p=1` in framework
    /// terms, i.e. paper padding factor `P = k - 1 - p = 2` (exactly
    /// doubles the spatial size).
    ///
    /// Only the *kernel geometry* (`n_k`, `padding`) is meaningful on
    /// the returned value — `n_in`, `cin` and `cout` are zero
    /// placeholders, so size- and cost-model methods
    /// ([`out_size`](Self::out_size), [`odd_output`](Self::odd_output),
    /// the [`flops`]/[`memory`] models) panic or return nonsense until
    /// the I/O geometry is filled in.  Chain [`with_io`](Self::with_io)
    /// to get a fully-specified layer:
    ///
    /// ```
    /// use ukstc::conv::ConvTransposeParams;
    /// let p = ConvTransposeParams::gan_layer().with_io(16, 64, 32);
    /// assert_eq!(p.out_size(), 32); // doubles the spatial size
    /// ```
    pub fn gan_layer() -> Self {
        ConvTransposeParams::new(0, 4, 2, 0, 0)
    }

    /// Fill in the I/O geometry (input spatial size and channel counts)
    /// on a kernel-geometry template such as [`gan_layer`](Self::gan_layer).
    pub fn with_io(mut self, n_in: usize, cin: usize, cout: usize) -> Self {
        self.n_in = n_in;
        self.cin = cin;
        self.cout = cout;
        self
    }

    /// Output spatial size: `2N + 2P - n` (paper §3.3).
    pub fn out_size(&self) -> usize {
        out_size(self.n_in, self.n_k, self.padding)
    }

    /// Upsampled (pre-padding) size: `2N - 1`, saturating to 0 for the
    /// `n_in = 0` placeholder templates ([`gan_layer`](Self::gan_layer)
    /// before [`with_io`](Self::with_io)) — `2·0 - 1` used to underflow
    /// and panic in debug builds.
    pub fn upsampled_size(&self) -> usize {
        (2 * self.n_in).saturating_sub(1)
    }

    /// True if the output feature map has odd spatial dimensions — the
    /// case where the prior grouped approach over-computes.
    pub fn odd_output(&self) -> bool {
        self.out_size() % 2 == 1
    }
}

/// Output spatial size `2N + 2P - n` (callers must ensure it's > 0).
pub fn out_size(n_in: usize, n_k: usize, padding: usize) -> usize {
    (2 * n_in + 2 * padding)
        .checked_sub(n_k)
        .expect("kernel larger than padded upsampled input")
}

/// Uniform view over full kernels and sub-kernels so the correlation
/// helpers work with both.
pub trait TapSet {
    fn rows(&self) -> usize;
    fn cols(&self) -> usize;
    fn cin(&self) -> usize;
    fn cout(&self) -> usize;
    /// `[Cin, Cout]` row-major matrix at spatial tap `(u, v)`.
    fn tap(&self, u: usize, v: usize) -> &[f32];
}

impl TapSet for Kernel {
    fn rows(&self) -> usize {
        self.n
    }
    fn cols(&self) -> usize {
        self.n
    }
    fn cin(&self) -> usize {
        self.cin
    }
    fn cout(&self) -> usize {
        self.cout
    }
    fn tap(&self, u: usize, v: usize) -> &[f32] {
        Kernel::tap(self, u, v)
    }
}

impl TapSet for SubKernel {
    fn rows(&self) -> usize {
        self.rows
    }
    fn cols(&self) -> usize {
        self.cols
    }
    fn cin(&self) -> usize {
        self.cin
    }
    fn cout(&self) -> usize {
        self.cout
    }
    fn tap(&self, u: usize, v: usize) -> &[f32] {
        SubKernel::tap(self, u, v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn out_size_formula() {
        assert_eq!(out_size(4, 5, 2), 7); // Fig. 5 worked example
        assert_eq!(out_size(4, 4, 2), 8); // GAN doubling layer
        assert_eq!(out_size(224, 3, 1), 447);
        assert_eq!(out_size(224, 5, 2), 447);
    }

    #[test]
    fn gan_layer_doubles() {
        let mut p = ConvTransposeParams::gan_layer();
        p.n_in = 16;
        assert_eq!(p.out_size(), 32);
        assert!(!p.odd_output());
    }

    #[test]
    fn gan_layer_with_io_fully_specified() {
        let p = ConvTransposeParams::gan_layer().with_io(16, 64, 32);
        assert_eq!(
            p,
            ConvTransposeParams::new(16, 4, 2, 64, 32),
            "with_io must fill every placeholder field"
        );
        assert_eq!(p.out_size(), 32);
        assert_eq!(p.upsampled_size(), 31);
        assert!(!p.odd_output());
        // The cost models become usable once I/O geometry is set.
        assert!(flops::conventional(&p) > 0);
        assert!(memory::savings_table4(&p) > 0);
    }

    #[test]
    fn gan_layer_placeholders_documented_behavior() {
        // Without with_io the template has zero I/O geometry — the
        // documented footgun this test pins down.
        let p = ConvTransposeParams::gan_layer();
        assert_eq!((p.n_in, p.cin, p.cout), (0, 0, 0));
        assert_eq!((p.n_k, p.padding), (4, 2));
        assert_eq!(flops::conventional(&p), 0);
    }

    #[test]
    fn upsampled_size_saturates_on_placeholder_template() {
        // `2 * 0 - 1` underflowed (debug-build panic) before saturation.
        assert_eq!(ConvTransposeParams::gan_layer().upsampled_size(), 0);
        let p = ConvTransposeParams::gan_layer().with_io(16, 64, 32);
        assert_eq!(p.upsampled_size(), 31);
        assert_eq!(ConvTransposeParams::new(1, 3, 2, 1, 1).upsampled_size(), 1);
    }

    #[test]
    fn odd_output_detection() {
        let p = ConvTransposeParams::new(4, 5, 2, 1, 1);
        assert_eq!(p.out_size(), 7);
        assert!(p.odd_output());
    }

    #[test]
    #[should_panic(expected = "kernel larger")]
    fn oversized_kernel_panics() {
        out_size(1, 5, 0);
    }
}
