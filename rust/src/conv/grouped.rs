//! Prior work (HICSS'23): grouped kernel-segregated transpose conv.
//!
//! The predecessor algorithm the paper improves on: kernel segregation
//! is the same (Fig. 4), but one work-item computes a full **2×2 output
//! block** by applying all four sub-kernels sequentially.  The block
//! grid is `⌈Ho/2⌉ × ⌈Wo/2⌉`, so when the output feature map has odd
//! dimensions the last row/column of blocks computes **extra elements**
//! past the output boundary — wasted multiplications *and* a padded
//! output allocation (the paper's headline criticism, §3.2: "extra
//! memory usage if the output feature map has odd dimensions").
//!
//! We reproduce that over-computation faithfully: the block loop writes
//! into an even-rounded buffer which is cropped at the end, and the
//! extra elements are really computed (input-clipped like the CUDA
//! original), so the measured waste matches the prior system's.

use crate::tensor::{ops, Feature};
use crate::util::threadpool;

use super::segregation::{segregate, Segregated};
use super::out_size;
use crate::tensor::Kernel;

/// Bytes of the even-rounded output allocation the grouped approach
/// makes (vs the exact `ho²`): the paper's "extra elements" overhead.
pub fn extra_output_bytes(ho: usize, cout: usize) -> usize {
    let ho_pad = ho.div_ceil(2) * 2;
    (ho_pad * ho_pad - ho * ho) * cout * std::mem::size_of::<f32>()
}

/// Compute one 2×2 block at block coords `(a, b)` into the padded
/// buffer.  `n` = input size, `p` = padding factor.
#[inline]
fn compute_block(
    x: &Feature,
    seg: &Segregated,
    p: usize,
    a: usize,
    b: usize,
    buf: &mut [f32],
    wo_pad: usize,
) {
    let n = x.h as isize;
    let pi = p as isize;
    let cout = seg.subs[0].cout;
    for rp in 0..2usize {
        for sp in 0..2usize {
            let i = (2 * a + rp) as isize;
            let j = (2 * b + sp) as isize;
            let base_i = (i - pi).div_euclid(2) + ((i - pi).rem_euclid(2) != 0) as isize;
            let base_j = (j - pi).div_euclid(2) + ((j - pi).rem_euclid(2) != 0) as isize;
            let sub = seg.for_output_parity(rp, sp, p);
            let dst = ((i as usize) * wo_pad + j as usize) * cout;
            let acc = &mut buf[dst..dst + cout];
            for u in 0..sub.rows {
                let iy = base_i + u as isize;
                if iy < 0 || iy >= n {
                    continue;
                }
                for v in 0..sub.cols {
                    let ix = base_j + v as isize;
                    if ix < 0 || ix >= n {
                        continue;
                    }
                    let px = x.pixel(iy as usize, ix as usize);
                    let tap = sub.tap(u, v);
                    for (ci, &xv) in px.iter().enumerate() {
                        let trow = &tap[ci * cout..(ci + 1) * cout];
                        for (acc_v, &t) in acc.iter_mut().zip(trow) {
                            *acc_v += xv * t;
                        }
                    }
                }
            }
        }
    }
}

/// Grouped segregated transpose conv from a pre-segregated kernel.
pub fn transpose_conv_seg(x: &Feature, seg: &Segregated, padding: usize) -> Feature {
    assert_eq!(x.h, x.w, "square inputs only (paper setting)");
    let ho = out_size(x.h, seg.n, padding);
    let cout = seg.subs[0].cout;
    let ho_pad = ho.div_ceil(2) * 2; // extra row/col when ho is odd
    let mut buf = vec![0.0f32; ho_pad * ho_pad * cout];
    let blocks = ho_pad / 2;
    for a in 0..blocks {
        for b in 0..blocks {
            compute_block(x, seg, padding, a, b, &mut buf, ho_pad);
        }
    }
    crop_padded(buf, ho_pad, ho, cout)
}

/// Grouped segregated transpose conv (segregates internally).
pub fn transpose_conv(x: &Feature, k: &Kernel, padding: usize) -> Feature {
    transpose_conv_seg(x, &segregate(k), padding)
}

/// Parallel lane: one work-item per 2×2 block (the prior work's CUDA
/// thread mapping), chunked over `workers` threads.
pub fn transpose_conv_par_seg(
    x: &Feature,
    seg: &Segregated,
    padding: usize,
    workers: usize,
) -> Feature {
    assert_eq!(x.h, x.w, "square inputs only (paper setting)");
    let ho = out_size(x.h, seg.n, padding);
    let cout = seg.subs[0].cout;
    let ho_pad = ho.div_ceil(2) * 2;
    let blocks = ho_pad / 2;
    let mut buf = vec![0.0f32; ho_pad * ho_pad * cout];
    // Two block-rows per chunk keeps rows whole (each block writes two
    // output rows, so chunking by block-row pairs keeps writes disjoint).
    let row_floats = ho_pad * cout;
    threadpool::parallel_chunks_mut(&mut buf, blocks, workers, |block_row, chunk| {
        debug_assert_eq!(chunk.len(), 2 * row_floats);
        // Chunk covers output rows [2*block_row, 2*block_row+2); rebase
        // a local view so compute_block can write with global indices.
        let base = 2 * block_row * row_floats;
        for b in 0..blocks {
            compute_block_offset(x, seg, padding, block_row, b, chunk, ho_pad, base);
        }
    });
    crop_padded(buf, ho_pad, ho, cout)
}

/// As [`compute_block`] but writing into a chunk that starts at global
/// flat offset `chunk_base`.
#[inline]
#[allow(clippy::too_many_arguments)]
fn compute_block_offset(
    x: &Feature,
    seg: &Segregated,
    p: usize,
    a: usize,
    b: usize,
    chunk: &mut [f32],
    wo_pad: usize,
    chunk_base: usize,
) {
    let n = x.h as isize;
    let pi = p as isize;
    let cout = seg.subs[0].cout;
    for rp in 0..2usize {
        for sp in 0..2usize {
            let i = (2 * a + rp) as isize;
            let j = (2 * b + sp) as isize;
            let base_i = (i - pi).div_euclid(2) + ((i - pi).rem_euclid(2) != 0) as isize;
            let base_j = (j - pi).div_euclid(2) + ((j - pi).rem_euclid(2) != 0) as isize;
            let sub = seg.for_output_parity(rp, sp, p);
            let dst = ((i as usize) * wo_pad + j as usize) * cout - chunk_base;
            let acc = &mut chunk[dst..dst + cout];
            for u in 0..sub.rows {
                let iy = base_i + u as isize;
                if iy < 0 || iy >= n {
                    continue;
                }
                for v in 0..sub.cols {
                    let ix = base_j + v as isize;
                    if ix < 0 || ix >= n {
                        continue;
                    }
                    let px = x.pixel(iy as usize, ix as usize);
                    let tap = sub.tap(u, v);
                    for (ci, &xv) in px.iter().enumerate() {
                        let trow = &tap[ci * cout..(ci + 1) * cout];
                        for (acc_v, &t) in acc.iter_mut().zip(trow) {
                            *acc_v += xv * t;
                        }
                    }
                }
            }
        }
    }
}

fn crop_padded(buf: Vec<f32>, ho_pad: usize, ho: usize, cout: usize) -> Feature {
    if ho_pad == ho {
        return Feature::from_vec(ho, ho, cout, buf);
    }
    let full = Feature::from_vec(ho_pad, ho_pad, cout, buf);
    ops::crop(&full, 0, 0, ho, ho)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::conventional;
    use crate::util::prop::{close, forall_res, Config};
    use crate::util::rng::Rng;

    #[test]
    fn matches_conventional_even_output() {
        let mut rng = Rng::seeded(20);
        let x = Feature::random(4, 4, 3, &mut rng);
        let k = Kernel::random(4, 3, 2, &mut rng);
        let want = conventional::transpose_conv(&x, &k, 2); // 8×8 even
        let got = transpose_conv(&x, &k, 2);
        assert!(ops::max_abs_diff(&want, &got) < 1e-4);
    }

    #[test]
    fn matches_conventional_odd_output() {
        let mut rng = Rng::seeded(21);
        let x = Feature::random(4, 4, 2, &mut rng);
        let k = Kernel::random(5, 2, 3, &mut rng);
        let want = conventional::transpose_conv(&x, &k, 2); // 7×7 odd
        let got = transpose_conv(&x, &k, 2);
        assert_eq!((got.h, got.w), (7, 7)); // extra elements cropped away
        assert!(ops::max_abs_diff(&want, &got) < 1e-4);
    }

    #[test]
    fn extra_bytes_only_for_odd() {
        assert_eq!(extra_output_bytes(8, 4), 0);
        // 7×7 → padded 8×8: (64-49)*cout*4 bytes
        assert_eq!(extra_output_bytes(7, 4), 15 * 4 * 4);
    }

    #[test]
    fn parallel_matches_serial() {
        let mut rng = Rng::seeded(22);
        let x = Feature::random(6, 6, 2, &mut rng);
        let k = Kernel::random(5, 2, 3, &mut rng);
        let seg = segregate(&k);
        let want = transpose_conv_seg(&x, &seg, 2);
        for workers in [1, 2, 4] {
            let got = transpose_conv_par_seg(&x, &seg, 2, workers);
            assert!(ops::max_abs_diff(&want, &got) < 1e-5);
        }
    }

    #[test]
    fn prop_grouped_equals_conventional() {
        forall_res(
            Config::default().cases(50),
            "grouped (HICSS'23) == conventional",
            |rng| {
                let n_in = rng.range(1, 7);
                let nk = rng.range(2, 5);
                let p = rng.range(0, 3);
                if 2 * n_in + 2 * p <= nk {
                    return ((n_in, nk, p), Ok(()));
                }
                let mut r2 = rng.split();
                let x = Feature::random(n_in, n_in, 2, &mut r2);
                let k = Kernel::random(nk, 2, 2, &mut r2);
                let want = conventional::transpose_conv(&x, &k, p);
                let got = transpose_conv(&x, &k, p);
                ((n_in, nk, p), close(&want.data, &got.data, 1e-3))
            },
        );
    }
}
