//! Kernel segregation mechanism (paper §3.1–§3.2, Fig. 4).
//!
//! Splits the original `n×n` kernel into four sub-kernels by taking
//! every other row/column:
//!
//! * `k00 = K[0::2, 0::2]` — `⌈n/2⌉ × ⌈n/2⌉` (9 elements for 5×5)
//! * `k01 = K[0::2, 1::2]` — `⌈n/2⌉ × ⌊n/2⌋` (6)
//! * `k10 = K[1::2, 0::2]` — `⌊n/2⌋ × ⌈n/2⌉` (6)
//! * `k11 = K[1::2, 1::2]` — `⌊n/2⌋ × ⌊n/2⌋` (4)
//!
//! Sub-kernel `k_rs` contains exactly the kernel taps that land on
//! non-zero (even) positions of the upsampled map when the output index
//! has parity `(r, s)` — so convolving the raw input with `k_rs`
//! reproduces phase `(r, s)` of the output with zero wasted
//! multiplications.
//!
//! §3.4: with padding factor `P`, the sub-kernel serving output parity
//! `(rp, sp)` is `k_{(rp+P)%2, (sp+P)%2}` — for odd `P` the roles swap
//! to `k11, k10, k01, k00`.

use crate::tensor::{Kernel, SubKernel};

/// The four segregated sub-kernels, indexed `[r*2 + s]`.
#[derive(Debug, Clone, PartialEq)]
pub struct Segregated {
    pub subs: [SubKernel; 4],
    /// Original kernel size `n`.
    pub n: usize,
}

/// Segregate `k` into the four sub-kernels (Fig. 4).
pub fn segregate(k: &Kernel) -> Segregated {
    let n = k.n;
    let make = |r: usize, s: usize| -> SubKernel {
        let rows = (n - r).div_ceil(2);
        let cols = (n - s).div_ceil(2);
        let mut sub = SubKernel::zeros(rows, cols, k.cin, k.cout);
        for (su, u) in (r..n).step_by(2).enumerate() {
            for (sv, v) in (s..n).step_by(2).enumerate() {
                let src = k.tap(u, v);
                let base = sub.idx(su, sv, 0, 0);
                sub.data[base..base + src.len()].copy_from_slice(src);
            }
        }
        sub
    };
    Segregated {
        subs: [make(0, 0), make(0, 1), make(1, 0), make(1, 1)],
        n,
    }
}

impl Segregated {
    /// Sub-kernel for output parity `(rp, sp)` under padding factor `P`
    /// (§3.4 role swap folded in).
    pub fn for_output_parity(&self, rp: usize, sp: usize, padding: usize) -> &SubKernel {
        let r = (rp + padding) % 2;
        let s = (sp + padding) % 2;
        &self.subs[r * 2 + s]
    }

    /// Total spatial taps across all four sub-kernels (== n²).
    pub fn total_taps(&self) -> usize {
        self.subs.iter().map(|s| s.taps()).sum()
    }

    /// Bytes of all sub-kernel data (equals the original kernel's bytes:
    /// segregation re-arranges, never duplicates).
    pub fn bytes(&self) -> usize {
        self.subs.iter().map(|s| s.bytes()).sum()
    }

    /// The §5-discussed bookkeeping array: the four (rows, cols) pairs a
    /// device implementation keeps resident (≤ 32 bytes in the paper).
    pub fn size_table(&self) -> [(usize, usize); 4] {
        [
            (self.subs[0].rows, self.subs[0].cols),
            (self.subs[1].rows, self.subs[1].cols),
            (self.subs[2].rows, self.subs[2].cols),
            (self.subs[3].rows, self.subs[3].cols),
        ]
    }
}

/// Reassemble the original kernel from its segregation (inverse of
/// [`segregate`]; used by property tests).
pub fn desegregate(seg: &Segregated, cin: usize, cout: usize) -> Kernel {
    let n = seg.n;
    let mut k = Kernel::zeros(n, cin, cout);
    for r in 0..2 {
        for s in 0..2 {
            let sub = &seg.subs[r * 2 + s];
            for (su, u) in (r..n).step_by(2).enumerate() {
                for (sv, v) in (s..n).step_by(2).enumerate() {
                    let dst = k.idx(u, v, 0, 0);
                    let src = sub.idx(su, sv, 0, 0);
                    let len = cin * cout;
                    let tmp = sub.data[src..src + len].to_vec();
                    k.data[dst..dst + len].copy_from_slice(&tmp);
                }
            }
        }
    }
    k
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{forall, Config};
    use crate::util::rng::Rng;

    #[test]
    fn fig4_sizes_for_5x5() {
        let mut rng = Rng::seeded(1);
        let k = Kernel::random(5, 1, 1, &mut rng);
        let seg = segregate(&k);
        assert_eq!((seg.subs[0].rows, seg.subs[0].cols), (3, 3)); // 9
        assert_eq!((seg.subs[1].rows, seg.subs[1].cols), (3, 2)); // 6
        assert_eq!((seg.subs[2].rows, seg.subs[2].cols), (2, 3)); // 6
        assert_eq!((seg.subs[3].rows, seg.subs[3].cols), (2, 2)); // 4
        assert_eq!(seg.total_taps(), 25);
    }

    #[test]
    fn even_kernel_equal_subs() {
        let mut rng = Rng::seeded(2);
        let k = Kernel::random(4, 1, 1, &mut rng);
        let seg = segregate(&k);
        for sub in &seg.subs {
            assert_eq!((sub.rows, sub.cols), (2, 2));
        }
        assert_eq!(seg.total_taps(), 16);
    }

    #[test]
    fn values_land_in_right_subkernel() {
        // k[u][v] = 10*u + v, single channel → easy to check placement.
        let n = 5;
        let mut k = Kernel::zeros(n, 1, 1);
        for u in 0..n {
            for v in 0..n {
                let i = k.idx(u, v, 0, 0);
                k.data[i] = (10 * u + v) as f32;
            }
        }
        let seg = segregate(&k);
        assert_eq!(seg.subs[0].get(0, 0, 0, 0), 0.0); // k[0][0]
        assert_eq!(seg.subs[0].get(1, 1, 0, 0), 22.0); // k[2][2]
        assert_eq!(seg.subs[1].get(0, 0, 0, 0), 1.0); // k[0][1]
        assert_eq!(seg.subs[2].get(0, 0, 0, 0), 10.0); // k[1][0]
        assert_eq!(seg.subs[3].get(1, 1, 0, 0), 33.0); // k[3][3]
    }

    #[test]
    fn parity_selection_even_padding() {
        let mut rng = Rng::seeded(3);
        let k = Kernel::random(5, 1, 1, &mut rng);
        let seg = segregate(&k);
        // Even P: identity mapping.
        assert_eq!(
            seg.for_output_parity(0, 1, 2) as *const _,
            &seg.subs[1] as *const _
        );
        // Odd P: role swap k00 ↔ k11, k01 ↔ k10 (§3.4).
        assert_eq!(
            seg.for_output_parity(0, 0, 1) as *const _,
            &seg.subs[3] as *const _
        );
        assert_eq!(
            seg.for_output_parity(0, 1, 3) as *const _,
            &seg.subs[2] as *const _
        );
    }

    #[test]
    fn size_table_fits_32_bytes() {
        // §5: the sub-kernel size array is ≤ 32 bytes on device (4 pairs
        // of u32).  Sanity-check our table is exactly 4 pairs.
        let mut rng = Rng::seeded(4);
        let k = Kernel::random(3, 2, 2, &mut rng);
        let table = segregate(&k).size_table();
        assert_eq!(table.len(), 4);
        assert_eq!(std::mem::size_of_val(&[0u32; 8]), 32);
    }

    #[test]
    fn prop_segregate_partitions_and_roundtrips() {
        forall(Config::default().cases(40), "segregate-roundtrip", |rng| {
            let n = rng.range(2, 7);
            let cin = rng.range(1, 3);
            let cout = rng.range(1, 3);
            let mut r2 = rng.split();
            let k = Kernel::random(n, cin, cout, &mut r2);
            let seg = segregate(&k);
            let ok_taps = seg.total_taps() == n * n;
            let ok_bytes = seg.bytes() == k.bytes();
            let back = desegregate(&seg, cin, cout);
            ((n, cin, cout), ok_taps && ok_bytes && back == k)
        });
    }
}
