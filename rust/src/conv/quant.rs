//! Reduced-precision storage and widening GEMM kernels for the packed
//! phase-GEMM engine (DESIGN.md §Reduced-Precision).
//!
//! PR 5 made the batched phase-GEMM path bandwidth-bound, so operand
//! bytes are the throughput lever: this module halves (f16/bf16) or
//! quarters (int8) both sides of every phase GEMM while keeping **all
//! accumulation in f32** (i32 for int8, scaled back to f32 at the
//! epilogue).  Storage formats:
//!
//! * **f16** — IEEE 754 binary16 stored as `u16` bits.  Conversion is
//!   round-to-nearest-even, handles subnormal halves exactly, overflows
//!   to ±Inf, and *preserves NaN* (quieted: a mantissa bit is forced so
//!   a NaN payload never collapses to Inf).  ~3 decimal digits
//!   (ε = 2⁻¹¹), range ±65504.
//! * **bf16** — the top 16 bits of the f32 pattern (sign, full 8-bit
//!   exponent, 7 mantissa bits) with round-to-nearest-even on the
//!   dropped half.  Same range as f32, ε = 2⁻⁸; NaN preserved
//!   (quieted).
//! * **int8** — symmetric absmax: `q = round(v / scale)` clamped to
//!   `[-127, 127]` with `scale = absmax / 127` (scale 1.0 when the
//!   tensor is all-zero).  Weights carry one scale per output channel
//!   per phase (computed at plan time); the im2col patch carries one
//!   scale per phase per call.
//!
//! The widening kernels here are the always-available scalar
//! references; `conv/simd.rs` provides AVX2 lanes (F16C convert-on-load
//! for f16, `i32`-widening multiplies for int8) that are **bit-identical**
//! to these references — both sides use plain mul+add (never FMA) in
//! the same k-ascending order, and the int8 path accumulates exactly in
//! `i32` before one scaled f32 epilogue per output element.
//!
//! Quantized B panels reuse the [`gemm::pack_b_for`] layout at a fixed
//! panel width of [`QNR`] = 8 columns, so one panel geometry serves
//! every ISA (the AVX2 widening kernels consume 8 columns per step).

use super::gemm;

/// Fixed panel width (columns) for quantized B panels — every quantized
/// lane, scalar or SIMD, consumes [`QNR`]-column panels, so the packed
/// layout is ISA-independent (unlike the f32 panels, which follow the
/// active microkernel's tile width).
pub const QNR: usize = 8;

/// Element count of a quantized packed B panel for a `k × n` matrix:
/// the [`gemm::packed_b_floats_for`] figure at panel width [`QNR`].
pub fn packed_qb_elems(k: usize, n: usize) -> usize {
    gemm::packed_b_floats_for(QNR, k, n)
}

// ---------------------------------------------------------------------------
// Precision axis
// ---------------------------------------------------------------------------

/// Storage precision of a phase-GEMM lane's packed operands.
///
/// `F32` is the full-precision engine (the packed panels PR 4 built);
/// the quantized variants swap in the reduced-precision panels and the
/// widening kernels from this module.  Accumulation is f32 (i32 for
/// `Int8`) in every case — precision only changes what is *stored and
/// streamed*, never the accumulator.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum Precision {
    #[default]
    F32,
    F16,
    Bf16,
    Int8,
}

impl Precision {
    /// Every precision, f32 first (reporting order).
    pub const ALL: [Precision; 4] = [
        Precision::F32,
        Precision::F16,
        Precision::Bf16,
        Precision::Int8,
    ];

    /// The reduced-precision lanes only.
    pub const QUANTIZED: [Precision; 3] = [Precision::F16, Precision::Bf16, Precision::Int8];

    /// Canonical lowercase name (used in strategy names, JSON, cache
    /// keys and the CLI).
    pub fn name(self) -> &'static str {
        match self {
            Precision::F32 => "f32",
            Precision::F16 => "f16",
            Precision::Bf16 => "bf16",
            Precision::Int8 => "int8",
        }
    }

    /// Parse a [`name`](Self::name) back; `None` for unknown spellings.
    pub fn parse(s: &str) -> Option<Precision> {
        match s {
            "f32" => Some(Precision::F32),
            "f16" => Some(Precision::F16),
            "bf16" => Some(Precision::Bf16),
            "int8" => Some(Precision::Int8),
            _ => None,
        }
    }

    /// Bytes per stored operand element.
    pub fn operand_bytes(self) -> usize {
        match self {
            Precision::F32 => 4,
            Precision::F16 | Precision::Bf16 => 2,
            Precision::Int8 => 1,
        }
    }

    /// True for the reduced-precision lanes.
    pub fn is_quantized(self) -> bool {
        self != Precision::F32
    }
}

// ---------------------------------------------------------------------------
// f16 / bf16 bit conversions
// ---------------------------------------------------------------------------

/// f32 → IEEE binary16 bits, round-to-nearest-even.
///
/// Overflow (|x| ≥ 65520) → ±Inf; f32 subnormals (and anything below
/// 2⁻²⁵) flush to ±0; values in the half-subnormal range convert to
/// exact subnormal halves; NaN is preserved quieted (sign kept, a high
/// mantissa bit forced so the payload never reads as Inf).
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let man = bits & 0x007f_ffff;
    if exp == 0xff {
        // Inf stays Inf; NaN stays NaN (quieted).
        return if man == 0 { sign | 0x7c00 } else { sign | 0x7e00 };
    }
    let unbiased = exp - 127;
    if unbiased >= 16 {
        return sign | 0x7c00; // overflow → ±Inf
    }
    if unbiased >= -14 {
        // Normal half: top 10 mantissa bits, RNE on the dropped 13.
        let man10 = man >> 13;
        let rest = man & 0x1fff;
        let mut h = (sign as u32) | (((unbiased + 15) as u32) << 10) | man10;
        if rest > 0x1000 || (rest == 0x1000 && (man10 & 1) == 1) {
            h += 1; // a carry ripples into the exponent correctly
        }
        return h as u16;
    }
    if unbiased >= -25 && exp != 0 {
        // Subnormal half: the implicit leading 1 becomes explicit.  In
        // units of 2⁻²⁴ the value is `full × 2^(unbiased+1)` with
        // `full` the 24-bit significand, so shift right by
        // `-(unbiased+1)` ∈ [14, 24] with RNE.
        let full = man | 0x0080_0000;
        let shift = (-(unbiased + 1)) as u32;
        let kept = full >> shift;
        let rest = full & ((1u32 << shift) - 1);
        let halfway = 1u32 << (shift - 1);
        let mut h = kept;
        if rest > halfway || (rest == halfway && (kept & 1) == 1) {
            h += 1; // may carry into the first normal half — still correct
        }
        return sign | h as u16;
    }
    sign // underflow (incl. every f32 subnormal) → ±0
}

/// IEEE binary16 bits → f32 (exact: every half is representable).
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let man = (h & 0x3ff) as u32;
    if exp == 0x1f {
        return f32::from_bits(sign | 0x7f80_0000 | (man << 13));
    }
    if exp == 0 {
        if man == 0 {
            return f32::from_bits(sign); // ±0
        }
        // Subnormal half `man × 2⁻²⁴`: normalize to an f32 normal.
        let p = 31 - man.leading_zeros(); // top set bit, 0..=9
        let man32 = (man << (23 - p)) & 0x007f_ffff;
        return f32::from_bits(sign | ((p + 103) << 23) | man32);
    }
    f32::from_bits(sign | ((exp + 112) << 23) | (man << 13))
}

/// f32 → bfloat16 bits (top 16 bits), round-to-nearest-even.
/// NaN preserved quieted; Inf stays Inf; f32 subnormals become bf16
/// subnormals exactly (same exponent range).
pub fn f32_to_bf16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        // Truncation could zero the payload and read as Inf — force a
        // mantissa bit instead.
        return ((bits >> 16) as u16) | 0x0040;
    }
    let round = 0x7fff + ((bits >> 16) & 1);
    ((bits + round) >> 16) as u16
}

/// bfloat16 bits → f32 (exact by construction).
pub fn bf16_bits_to_f32(h: u16) -> f32 {
    f32::from_bits((h as u32) << 16)
}

// ---------------------------------------------------------------------------
// int8 symmetric absmax
// ---------------------------------------------------------------------------

/// Largest |x| over the slice (0.0 for an empty slice; NaN ignored by
/// `max` semantics only if another element dominates — quantizing NaN
/// data is undefined and clamps to 0).
pub fn absmax(xs: &[f32]) -> f32 {
    xs.iter().fold(0.0f32, |m, &v| m.max(v.abs()))
}

/// Symmetric absmax scale: `absmax / 127`, or 1.0 for an all-zero
/// tensor (everything quantizes to 0 either way, and the epilogue
/// never divides).
pub fn int8_scale(absmax: f32) -> f32 {
    if absmax > 0.0 {
        absmax / 127.0
    } else {
        1.0
    }
}

/// Quantize `src` to int8 under `scale`: `round(v / scale)` clamped to
/// `[-127, 127]` (the symmetric range — -128 is never produced).
pub fn quantize_i8(src: &[f32], scale: f32, dst: &mut [i8]) {
    debug_assert_eq!(src.len(), dst.len());
    let inv = 1.0 / scale;
    for (d, &v) in dst.iter_mut().zip(src) {
        *d = (v * inv).round().clamp(-127.0, 127.0) as i8;
    }
}

/// Quantize `src` into f16 bit patterns.
pub fn quantize_f16(src: &[f32], dst: &mut [u16]) {
    debug_assert_eq!(src.len(), dst.len());
    for (d, &v) in dst.iter_mut().zip(src) {
        *d = f32_to_f16_bits(v);
    }
}

/// Quantize `src` into bf16 bit patterns.
pub fn quantize_bf16(src: &[f32], dst: &mut [u16]) {
    debug_assert_eq!(src.len(), dst.len());
    for (d, &v) in dst.iter_mut().zip(src) {
        *d = f32_to_bf16_bits(v);
    }
}

// ---------------------------------------------------------------------------
// Quantized B-panel packing (layout = gemm::pack_b_for at width QNR)
// ---------------------------------------------------------------------------

/// Pack a row-major `k × n` B matrix into [`QNR`]-column u16 panels
/// under `to_bits` (f16 or bf16 conversion).  Layout contract matches
/// [`gemm::pack_b_for`]: panel `jp` occupies
/// `packed[jp·k·QNR .. (jp+1)·k·QNR]`, row `kk` within it holds QNR
/// consecutive columns, zero-padded past `n`.  Every element of
/// `packed` is written, so a dirty buffer is safe to reuse.
pub fn pack_b_q16(b: &[f32], k: usize, n: usize, to_bits: fn(f32) -> u16, packed: &mut [u16]) {
    assert_eq!(b.len(), k * n, "B must be k x n row-major");
    assert_eq!(packed.len(), packed_qb_elems(k, n), "packed B size");
    let zero = to_bits(0.0);
    let panels = n.div_ceil(QNR);
    for jp in 0..panels {
        let j0 = jp * QNR;
        let jn = QNR.min(n - j0);
        let base = jp * k * QNR;
        for kk in 0..k {
            let dst = &mut packed[base + kk * QNR..base + (kk + 1) * QNR];
            let src = &b[kk * n + j0..kk * n + j0 + jn];
            for (d, &v) in dst[..jn].iter_mut().zip(src) {
                *d = to_bits(v);
            }
            for d in &mut dst[jn..] {
                *d = zero;
            }
        }
    }
}

/// Per-output-channel symmetric scales for a row-major `k × n` B
/// matrix: `scales[j] = absmax(column j) / 127` (1.0 for an all-zero
/// column).
pub fn col_absmax_scales(b: &[f32], k: usize, n: usize) -> Vec<f32> {
    assert_eq!(b.len(), k * n, "B must be k x n row-major");
    let mut scales = vec![0.0f32; n];
    for row in b.chunks_exact(n) {
        for (m, &v) in scales.iter_mut().zip(row) {
            *m = m.max(v.abs());
        }
    }
    for m in &mut scales {
        *m = int8_scale(*m);
    }
    scales
}

/// Pack a row-major `k × n` B matrix into [`QNR`]-column int8 panels,
/// column `j` quantized under `scales[j]`.  Same layout contract as
/// [`pack_b_q16`]; padding columns are 0.
pub fn pack_b_q8(b: &[f32], k: usize, n: usize, scales: &[f32], packed: &mut [i8]) {
    assert_eq!(b.len(), k * n, "B must be k x n row-major");
    assert_eq!(scales.len(), n, "one scale per column");
    assert_eq!(packed.len(), packed_qb_elems(k, n), "packed B size");
    let panels = n.div_ceil(QNR);
    for jp in 0..panels {
        let j0 = jp * QNR;
        let jn = QNR.min(n - j0);
        let base = jp * k * QNR;
        for kk in 0..k {
            let dst = &mut packed[base + kk * QNR..base + (kk + 1) * QNR];
            for (jj, d) in dst.iter_mut().enumerate() {
                *d = if jj < jn {
                    let v = b[kk * n + j0 + jj];
                    (v / scales[j0 + jj]).round().clamp(-127.0, 127.0) as i8
                } else {
                    0
                };
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Scalar widening GEMM references (C += dequant(A) · dequant(B))
// ---------------------------------------------------------------------------

/// `C += A·B` with both operands stored as 16-bit floats (`from_bits`
/// is the f16 or bf16 decoder), B packed by [`pack_b_q16`].  f32
/// accumulation, plain mul+add in k-ascending order — the contract the
/// AVX2 widening lane in `conv/simd.rs` reproduces bit-identically.
pub fn gemm_q16_scalar(
    a: &[u16],
    packed_b: &[u16],
    from_bits: fn(u16) -> f32,
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
) {
    assert_eq!(a.len(), m * k, "A must be m x k");
    assert_eq!(packed_b.len(), packed_qb_elems(k, n), "packed B size");
    assert_eq!(c.len(), m * n, "C must be m x n");
    let panels = n.div_ceil(QNR);
    for jp in 0..panels {
        let j0 = jp * QNR;
        let jn = QNR.min(n - j0);
        let panel = &packed_b[jp * k * QNR..(jp + 1) * k * QNR];
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            let mut acc = [0.0f32; QNR];
            for (kk, &ab) in arow.iter().enumerate() {
                let av = from_bits(ab);
                let brow = &panel[kk * QNR..(kk + 1) * QNR];
                for (s, &bb) in acc.iter_mut().zip(brow) {
                    *s += av * from_bits(bb);
                }
            }
            for (jj, &s) in acc[..jn].iter().enumerate() {
                c[i * n + j0 + jj] += s;
            }
        }
    }
}

/// `C += (a_scale · A) · (B ⊙ b_scales)` with int8 operands, B packed
/// by [`pack_b_q8`].  Accumulation is **exact i32**; each output gets
/// one f32 epilogue `c += (acc as f32) * (a_scale * b_scales[j])` — the
/// identical op the AVX2 lane performs, so scalar and SIMD int8 results
/// are bit-identical.
pub fn gemm_q8_scalar(
    a: &[i8],
    a_scale: f32,
    packed_b: &[i8],
    b_scales: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
) {
    assert_eq!(a.len(), m * k, "A must be m x k");
    assert_eq!(packed_b.len(), packed_qb_elems(k, n), "packed B size");
    assert_eq!(b_scales.len(), n, "one scale per column");
    assert_eq!(c.len(), m * n, "C must be m x n");
    let panels = n.div_ceil(QNR);
    for jp in 0..panels {
        let j0 = jp * QNR;
        let jn = QNR.min(n - j0);
        let panel = &packed_b[jp * k * QNR..(jp + 1) * k * QNR];
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            let mut acc = [0i32; QNR];
            for (kk, &ab) in arow.iter().enumerate() {
                let av = ab as i32;
                let brow = &panel[kk * QNR..(kk + 1) * QNR];
                for (s, &bb) in acc.iter_mut().zip(brow) {
                    *s += av * bb as i32;
                }
            }
            for (jj, &s) in acc[..jn].iter().enumerate() {
                c[i * n + j0 + jj] += (s as f32) * (a_scale * b_scales[j0 + jj]);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn roundtrip_f16(x: f32) -> f32 {
        f16_bits_to_f32(f32_to_f16_bits(x))
    }

    fn roundtrip_bf16(x: f32) -> f32 {
        bf16_bits_to_f32(f32_to_bf16_bits(x))
    }

    #[test]
    fn f16_specials_exact() {
        // ±0 keep their sign bit.
        assert_eq!(f32_to_f16_bits(0.0), 0x0000);
        assert_eq!(f32_to_f16_bits(-0.0), 0x8000);
        assert_eq!(roundtrip_f16(0.0).to_bits(), 0.0f32.to_bits());
        assert_eq!(roundtrip_f16(-0.0).to_bits(), (-0.0f32).to_bits());
        // Inf round-trips; overflow saturates to Inf.
        assert_eq!(f32_to_f16_bits(f32::INFINITY), 0x7c00);
        assert_eq!(f32_to_f16_bits(f32::NEG_INFINITY), 0xfc00);
        assert_eq!(roundtrip_f16(1e30), f32::INFINITY);
        assert_eq!(roundtrip_f16(-1e30), f32::NEG_INFINITY);
        // NaN preserved (quieted) — documented contract.
        assert!(roundtrip_f16(f32::NAN).is_nan());
        // Exact powers of two and small integers are lossless.
        for v in [1.0f32, -2.0, 0.5, 1024.0, 65504.0, 3.25, -0.125] {
            assert_eq!(roundtrip_f16(v), v, "{v} must be f16-exact");
        }
    }

    #[test]
    fn f16_subnormals_exact() {
        // The smallest subnormal half is 2⁻²⁴; all its multiples up to
        // the normal threshold 2⁻¹⁴ are exactly representable.
        let ulp = 2.0f32.powi(-24);
        for mult in [1.0f32, 2.0, 3.0, 511.0, 1023.0] {
            let v = ulp * mult;
            assert_eq!(roundtrip_f16(v), v, "subnormal {mult}·2⁻²⁴");
            assert_eq!(roundtrip_f16(-v), -v);
        }
        // Smallest normal half.
        let min_norm = 2.0f32.powi(-14);
        assert_eq!(roundtrip_f16(min_norm), min_norm);
        assert_eq!(f32_to_f16_bits(min_norm), 0x0400);
        // Below half the smallest subnormal → ±0 (documented flush);
        // f32 subnormals flush too.
        assert_eq!(f32_to_f16_bits(2.0f32.powi(-26)), 0x0000);
        assert_eq!(f32_to_f16_bits(-2.0f32.powi(-26)), 0x8000);
        assert_eq!(f32_to_f16_bits(f32::MIN_POSITIVE / 2.0), 0x0000);
        // Ties round to even: exactly 2⁻²⁵ is halfway between 0 and
        // 2⁻²⁴ → even → 0.
        assert_eq!(f32_to_f16_bits(2.0f32.powi(-25)), 0x0000);
        // Just above the tie rounds up to the smallest subnormal.
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(1.1 * ulp / 2.0 * 2.0)), ulp);
    }

    #[test]
    fn f16_round_to_nearest_even() {
        // 1 + 2⁻¹¹ is exactly halfway between 1.0 and the next half
        // (1 + 2⁻¹⁰) → ties-to-even keeps 1.0.
        let tie = 1.0 + 2.0f32.powi(-11);
        assert_eq!(roundtrip_f16(tie), 1.0);
        // 1 + 3·2⁻¹¹ is halfway between 1+2⁻¹⁰ and 1+2·2⁻¹⁰ → even →
        // 1 + 2·2⁻¹⁰.
        let tie2 = 1.0 + 3.0 * 2.0f32.powi(-11);
        assert_eq!(roundtrip_f16(tie2), 1.0 + 2.0 * 2.0f32.powi(-10));
        // Just above a tie rounds up.
        assert_eq!(roundtrip_f16(tie + 2.0f32.powi(-20)), 1.0 + 2.0f32.powi(-10));
        // Rounding can carry into the exponent: the largest value below
        // 2.0 that rounds up.
        assert_eq!(roundtrip_f16(2.0 - 2.0f32.powi(-12)), 2.0);
    }

    #[test]
    fn f16_relative_error_bound() {
        // |x - rt(x)| ≤ 2⁻¹¹·|x| over the normal range.
        let mut rng = Rng::seeded(901);
        for _ in 0..2000 {
            let x = rng.normal_f32() * 100.0;
            let err = (roundtrip_f16(x) - x).abs();
            assert!(
                err <= 2.0f32.powi(-11) * x.abs() + 1e-30,
                "f16 rel err too large at {x}: {err}"
            );
        }
    }

    #[test]
    fn bf16_specials_and_bound() {
        assert_eq!(f32_to_bf16_bits(0.0), 0x0000);
        assert_eq!(f32_to_bf16_bits(-0.0), 0x8000);
        assert_eq!(roundtrip_bf16(f32::INFINITY), f32::INFINITY);
        assert!(roundtrip_bf16(f32::NAN).is_nan());
        // bf16 keeps the f32 exponent range: huge and tiny magnitudes
        // survive (unlike f16).
        assert!((roundtrip_bf16(1e30) - 1e30).abs() <= 2.0f32.powi(-8) * 1e30);
        let tiny = f32::MIN_POSITIVE; // f32 min normal is bf16-exact
        assert_eq!(roundtrip_bf16(tiny), tiny);
        // Powers of two are exact; RNE on the dropped 16 bits.
        for v in [1.0f32, -4.0, 0.25, 3.0, -1.5] {
            assert_eq!(roundtrip_bf16(v), v);
        }
        let mut rng = Rng::seeded(902);
        for _ in 0..2000 {
            let x = rng.normal_f32() * 100.0;
            let err = (roundtrip_bf16(x) - x).abs();
            assert!(
                err <= 2.0f32.powi(-8) * x.abs() + 1e-30,
                "bf16 rel err too large at {x}: {err}"
            );
        }
        // RNE tie: 1 + 2⁻⁸ is halfway between 1.0 and 1 + 2⁻⁷ → 1.0.
        assert_eq!(roundtrip_bf16(1.0 + 2.0f32.powi(-8)), 1.0);
    }

    #[test]
    fn int8_scale_invariants() {
        // absmax maps to exactly ±127; zero tensor gets scale 1.0.
        let xs = [0.5f32, -2.0, 1.25, 0.0];
        let s = int8_scale(absmax(&xs));
        assert_eq!(s, 2.0 / 127.0);
        let mut q = [0i8; 4];
        quantize_i8(&xs, s, &mut q);
        assert_eq!(q[1], -127);
        // Dequantized absmax is exact: -127 · (2/127) = -2.
        assert_eq!(q[1] as f32 * s, -2.0);
        assert_eq!(int8_scale(absmax(&[0.0, -0.0])), 1.0);
        let mut qz = [7i8; 2];
        quantize_i8(&[0.0, -0.0], 1.0, &mut qz);
        assert_eq!(qz, [0, 0]);
        // Quantization error is at most scale/2 per element.
        let mut rng = Rng::seeded(903);
        let xs: Vec<f32> = (0..512).map(|_| rng.normal_f32()).collect();
        let s = int8_scale(absmax(&xs));
        let mut q = vec![0i8; xs.len()];
        quantize_i8(&xs, s, &mut q);
        for (&v, &qi) in xs.iter().zip(&q) {
            assert!((v - qi as f32 * s).abs() <= s / 2.0 + 1e-7);
            assert!(qi >= -127, "-128 must never be produced");
        }
    }

    #[test]
    fn col_scales_per_column() {
        // 2×3 B: columns have absmax 4, 0, 0.5.
        let b = [4.0f32, 0.0, -0.5, -1.0, 0.0, 0.25];
        let s = col_absmax_scales(&b, 2, 3);
        assert_eq!(s, vec![4.0 / 127.0, 1.0, 0.5 / 127.0]);
    }

    fn gemm_ref(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut c = vec![0.0f32; m * n];
        for i in 0..m {
            for kk in 0..k {
                for j in 0..n {
                    c[i * n + j] += a[i * k + kk] * b[kk * n + j];
                }
            }
        }
        c
    }

    #[test]
    fn q16_gemm_matches_dequantized_reference() {
        // The quantized GEMM must equal the f32 GEMM over the
        // *dequantized* operands within accumulation tolerance — the
        // quantization error itself is bounded separately.
        let mut rng = Rng::seeded(904);
        for (m, k, n) in [(3usize, 7usize, 5usize), (4, 16, 17), (1, 1, 1), (2, 9, 8)] {
            let a: Vec<f32> = (0..m * k).map(|_| rng.normal_f32()).collect();
            let b: Vec<f32> = (0..k * n).map(|_| rng.normal_f32()).collect();
            for (to, from) in [
                (f32_to_f16_bits as fn(f32) -> u16, f16_bits_to_f32 as fn(u16) -> f32),
                (f32_to_bf16_bits, bf16_bits_to_f32),
            ] {
                let mut aq = vec![0u16; a.len()];
                for (d, &v) in aq.iter_mut().zip(&a) {
                    *d = to(v);
                }
                let mut bq = vec![0u16; packed_qb_elems(k, n)];
                pack_b_q16(&b, k, n, to, &mut bq);
                let adq: Vec<f32> = aq.iter().map(|&v| from(v)).collect();
                let bdq: Vec<f32> = b.iter().map(|&v| from(to(v))).collect();
                let want = gemm_ref(&adq, &bdq, m, k, n);
                let mut c = vec![0.0f32; m * n];
                gemm_q16_scalar(&aq, &bq, from, &mut c, m, k, n);
                for (got, want) in c.iter().zip(&want) {
                    assert!((got - want).abs() < 1e-4, "q16 {m}x{k}x{n}");
                }
            }
        }
    }

    #[test]
    fn q8_gemm_matches_dequantized_reference() {
        let mut rng = Rng::seeded(905);
        for (m, k, n) in [(3usize, 7usize, 5usize), (4, 16, 17), (2, 9, 8)] {
            let a: Vec<f32> = (0..m * k).map(|_| rng.normal_f32()).collect();
            let b: Vec<f32> = (0..k * n).map(|_| rng.normal_f32()).collect();
            let a_scale = int8_scale(absmax(&a));
            let mut aq = vec![0i8; a.len()];
            quantize_i8(&a, a_scale, &mut aq);
            let b_scales = col_absmax_scales(&b, k, n);
            let mut bq = vec![0i8; packed_qb_elems(k, n)];
            pack_b_q8(&b, k, n, &b_scales, &mut bq);
            // Dequantized reference.
            let adq: Vec<f32> = aq.iter().map(|&q| q as f32 * a_scale).collect();
            let bdq: Vec<f32> = b
                .iter()
                .enumerate()
                .map(|(idx, &v)| {
                    let s = b_scales[idx % n];
                    (v / s).round().clamp(-127.0, 127.0) * s
                })
                .collect();
            let want = gemm_ref(&adq, &bdq, m, k, n);
            let mut c = vec![0.0f32; m * n];
            gemm_q8_scalar(&aq, a_scale, &bq, &b_scales, &mut c, m, k, n);
            for (got, want) in c.iter().zip(&want) {
                assert!((got - want).abs() < 1e-3, "q8 {m}x{k}x{n}: {got} vs {want}");
            }
        }
    }

    #[test]
    fn packed_q_layout_zero_pads_and_overwrites() {
        // n = 5 → one QNR panel with 3 padding columns, all written
        // even on a dirty buffer.
        let k = 2;
        let n = 5;
        let b: Vec<f32> = (0..k * n).map(|i| i as f32 + 1.0).collect();
        let mut packed = vec![0xffffu16; packed_qb_elems(k, n)];
        pack_b_q16(&b, k, n, f32_to_bf16_bits, &mut packed);
        assert_eq!(packed.len(), QNR * k);
        for kk in 0..k {
            let row = &packed[kk * QNR..(kk + 1) * QNR];
            for j in 0..n {
                assert_eq!(bf16_bits_to_f32(row[j]), b[kk * n + j]);
            }
            for &pad in &row[n..] {
                assert_eq!(bf16_bits_to_f32(pad), 0.0);
            }
        }
        let mut packed8 = vec![-1i8; packed_qb_elems(k, n)];
        let scales = col_absmax_scales(&b, k, n);
        pack_b_q8(&b, k, n, &scales, &mut packed8);
        for kk in 0..k {
            let row = &packed8[kk * QNR..(kk + 1) * QNR];
            for &pad in &row[n..] {
                assert_eq!(pad, 0, "padding columns must be written to 0");
            }
        }
    }

    #[test]
    fn precision_names_roundtrip() {
        for p in Precision::ALL {
            assert_eq!(Precision::parse(p.name()), Some(p));
        }
        assert_eq!(Precision::parse("fp16"), None);
        assert_eq!(Precision::default(), Precision::F32);
        assert_eq!(Precision::F32.operand_bytes(), 4);
        assert_eq!(Precision::F16.operand_bytes(), 2);
        assert_eq!(Precision::Bf16.operand_bytes(), 2);
        assert_eq!(Precision::Int8.operand_bytes(), 1);
        assert!(!Precision::F32.is_quantized());
        assert!(Precision::QUANTIZED.iter().all(|p| p.is_quantized()));
    }
}
