//! Device-lane abstraction over the transpose-conv algorithms.
//!
//! The paper reports every experiment twice — "CPU" (single-thread
//! C++) and "GPU" (CUDA grid).  On this testbed the two lanes are
//! [`Lane::Serial`] and [`Lane::Parallel`] (thread-pool over the same
//! output index space); DESIGN.md §2 argues why the conventional-vs-
//! unified *ratio* survives the substitution.
//!
//! [`Algorithm`] × [`Lane`] is the full measurement matrix used by the
//! bench harness and by the end-to-end examples.

use crate::tensor::Feature;
use crate::tensor::Kernel;

use super::segregation::{segregate, Segregated};
use super::{conventional, grouped, im2col, unified};

/// Which transpose-convolution algorithm to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Algorithm {
    /// Algorithm 1 — bed-of-nails upsample + dense correlation.
    Conventional,
    /// HICSS'23 grouped segregation (prior work).
    Grouped,
    /// **Algorithm 2 — unified segregation (the contribution),**
    /// phase-decomposed hot path.
    Unified,
    /// Algorithm 2, literal per-element formulation (ablation lane).
    UnifiedPerElement,
    /// GEMM-based transpose conv (§5 discussion baseline).
    Im2col,
}

impl Algorithm {
    pub fn name(&self) -> &'static str {
        match self {
            Algorithm::Conventional => "conventional",
            Algorithm::Grouped => "grouped",
            Algorithm::Unified => "unified",
            Algorithm::UnifiedPerElement => "unified-per-element",
            Algorithm::Im2col => "im2col",
        }
    }

    /// All algorithms, for exhaustive test/bench sweeps.
    pub fn all() -> [Algorithm; 5] {
        [
            Algorithm::Conventional,
            Algorithm::Grouped,
            Algorithm::Unified,
            Algorithm::UnifiedPerElement,
            Algorithm::Im2col,
        ]
    }
}

/// Execution lane: the paper's CPU (serial) or GPU (parallel) column.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Lane {
    Serial,
    /// Thread-pool lane with this many workers.
    Parallel(usize),
}

impl Lane {
    pub fn name(&self) -> String {
        match self {
            Lane::Serial => "serial".to_string(),
            Lane::Parallel(w) => format!("parallel({w})"),
        }
    }
}

/// Run `alg` on `lane`.  Segregation (where applicable) is performed
/// inside the call — use [`run_seg`] to amortize it across calls the
/// way a real layer does (weights are segregated once at load time).
pub fn run(alg: Algorithm, lane: Lane, x: &Feature, k: &Kernel, padding: usize) -> Feature {
    match (alg, lane) {
        (Algorithm::Conventional, Lane::Serial) => conventional::transpose_conv(x, k, padding),
        (Algorithm::Conventional, Lane::Parallel(w)) => {
            conventional::transpose_conv_par(x, k, padding, w)
        }
        (Algorithm::Grouped, Lane::Serial) => grouped::transpose_conv(x, k, padding),
        (Algorithm::Grouped, Lane::Parallel(w)) => {
            grouped::transpose_conv_par_seg(x, &segregate(k), padding, w)
        }
        (Algorithm::Unified, Lane::Serial) => unified::transpose_conv(x, k, padding),
        (Algorithm::Unified, Lane::Parallel(w)) => {
            unified::transpose_conv_par(x, k, padding, w)
        }
        (Algorithm::UnifiedPerElement, Lane::Serial) => {
            unified::transpose_conv_per_element(x, k, padding)
        }
        (Algorithm::UnifiedPerElement, Lane::Parallel(w)) => {
            let seg = segregate(k);
            unified_per_element_par(x, &seg, padding, w)
        }
        (Algorithm::Im2col, Lane::Serial) => im2col::transpose_conv(x, k, padding),
        (Algorithm::Im2col, Lane::Parallel(_)) => im2col::transpose_conv(x, k, padding),
    }
}

/// Run from a pre-segregated kernel (weights prepared once at model
/// load — the deployment-realistic path).  Falls back to the full
/// kernel for algorithms that do not use segregation.
pub fn run_seg(
    alg: Algorithm,
    lane: Lane,
    x: &Feature,
    k: &Kernel,
    seg: &Segregated,
    padding: usize,
) -> Feature {
    match (alg, lane) {
        (Algorithm::Grouped, Lane::Serial) => grouped::transpose_conv_seg(x, seg, padding),
        (Algorithm::Grouped, Lane::Parallel(w)) => {
            grouped::transpose_conv_par_seg(x, seg, padding, w)
        }
        (Algorithm::Unified, Lane::Serial) => unified::transpose_conv_seg(x, seg, padding),
        (Algorithm::Unified, Lane::Parallel(w)) => {
            unified::transpose_conv_par_seg(x, seg, padding, w)
        }
        (Algorithm::UnifiedPerElement, Lane::Serial) => {
            unified::transpose_conv_per_element_seg(x, seg, padding)
        }
        (Algorithm::UnifiedPerElement, Lane::Parallel(w)) => {
            unified_per_element_par(x, seg, padding, w)
        }
        _ => run(alg, lane, x, k, padding),
    }
}

/// The paper's *exact* GPU mapping for Algorithm 2: one work-item per
/// output element with runtime sub-kernel selection, distributed over
/// threads by output-row chunks.
pub fn unified_per_element_par(
    x: &Feature,
    seg: &Segregated,
    padding: usize,
    workers: usize,
) -> Feature {
    use crate::util::threadpool::parallel_chunks_mut;
    assert_eq!(x.h, x.w, "square inputs only (paper setting)");
    let ho = super::out_size(x.h, seg.n, padding);
    let cout = seg.subs[0].cout;
    let n = x.h as isize;
    let p = padding as isize;
    let mut out = Feature::zeros(ho, ho, cout);
    parallel_chunks_mut(&mut out.data, ho.max(1), workers, |i, row| {
        let ii = i as isize;
        let base_i = (ii - p).div_euclid(2) + ((ii - p).rem_euclid(2) != 0) as isize;
        for j in 0..ho {
            let jj = j as isize;
            let base_j = (jj - p).div_euclid(2) + ((jj - p).rem_euclid(2) != 0) as isize;
            let sub = seg.for_output_parity(i % 2, j % 2, padding);
            let acc = &mut row[j * cout..(j + 1) * cout];
            for u in 0..sub.rows {
                let iy = base_i + u as isize;
                if iy < 0 || iy >= n {
                    continue;
                }
                for v in 0..sub.cols {
                    let ix = base_j + v as isize;
                    if ix < 0 || ix >= n {
                        continue;
                    }
                    let px = x.pixel(iy as usize, ix as usize);
                    let tap = sub.tap(u, v);
                    for (ci, &xv) in px.iter().enumerate() {
                        let trow = &tap[ci * cout..(ci + 1) * cout];
                        for (a, &t) in acc.iter_mut().zip(trow) {
                            *a += xv * t;
                        }
                    }
                }
            }
        }
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ops;
    use crate::util::rng::Rng;

    #[test]
    fn all_algorithms_agree_all_lanes() {
        let mut rng = Rng::seeded(30);
        for (n_in, nk, p) in [(4, 4, 2), (4, 5, 2), (5, 3, 1), (6, 4, 0)] {
            let x = Feature::random(n_in, n_in, 3, &mut rng);
            let k = Kernel::random(nk, 3, 2, &mut rng);
            let want = run(Algorithm::Conventional, Lane::Serial, &x, &k, p);
            for alg in Algorithm::all() {
                for lane in [Lane::Serial, Lane::Parallel(4)] {
                    let got = run(alg, lane, &x, &k, p);
                    assert!(
                        ops::max_abs_diff(&want, &got) < 1e-3,
                        "{} on {} disagrees (n={n_in} k={nk} p={p})",
                        alg.name(),
                        lane.name()
                    );
                }
            }
        }
    }

    #[test]
    fn run_seg_matches_run() {
        let mut rng = Rng::seeded(31);
        let x = Feature::random(6, 6, 2, &mut rng);
        let k = Kernel::random(4, 2, 3, &mut rng);
        let seg = segregate(&k);
        for alg in Algorithm::all() {
            let a = run(alg, Lane::Serial, &x, &k, 2);
            let b = run_seg(alg, Lane::Serial, &x, &k, &seg, 2);
            assert!(ops::max_abs_diff(&a, &b) < 1e-4, "{}", alg.name());
        }
    }

    #[test]
    fn names_unique() {
        let names: Vec<_> = Algorithm::all().iter().map(|a| a.name()).collect();
        let mut dedup = names.clone();
        dedup.dedup();
        assert_eq!(names.len(), dedup.len());
    }
}
