//! Summary statistics for benchmarking and serving metrics.
//!
//! Two flavors: [`Summary`] computed from a full sample vector (bench
//! harness — exact percentiles), and [`Welford`], a streaming
//! mean/variance accumulator for the coordinator's hot path where we
//! refuse to buffer every observation.

/// Exact summary of a sample set (sorted copy internally).
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub stddev: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

impl Summary {
    /// Compute from raw samples.  Panics on an empty slice.
    pub fn of(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty(), "Summary::of on empty samples");
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            stddev: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: percentile_sorted(&sorted, 50.0),
            p95: percentile_sorted(&sorted, 95.0),
            p99: percentile_sorted(&sorted, 99.0),
        }
    }
}

/// Linear-interpolated percentile of an ascending-sorted slice.
pub fn percentile_sorted(sorted: &[f64], pct: f64) -> f64 {
    assert!(!sorted.is_empty());
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = pct / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] + (sorted[hi] - sorted[lo]) * frac
}

/// Streaming mean/variance (Welford's online algorithm).
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    pub fn new() -> Self {
        Welford {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.min }
    }

    pub fn max(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.max }
    }
}

/// Fixed-boundary latency histogram (log-spaced buckets, µs…s range).
/// Lock-free-friendly: push is O(#buckets) scan, quantile is approximate
/// (upper bucket bound), which is what serving metrics need.
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    /// Bucket upper bounds in seconds, ascending; last is +inf.
    bounds: Vec<f64>,
    counts: Vec<u64>,
    total: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    /// Log-spaced 1µs → 60s, ~20% resolution.
    pub fn new() -> Self {
        let mut bounds = Vec::new();
        let mut b = 1e-6;
        while b < 60.0 {
            bounds.push(b);
            b *= 1.2;
        }
        bounds.push(f64::INFINITY);
        let n = bounds.len();
        LatencyHistogram {
            bounds,
            counts: vec![0; n],
            total: 0,
        }
    }

    pub fn record(&mut self, seconds: f64) {
        let idx = self
            .bounds
            .iter()
            .position(|&b| seconds <= b)
            .unwrap_or(self.bounds.len() - 1);
        self.counts[idx] += 1;
        self.total += 1;
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    /// Approximate quantile (upper bound of the containing bucket).
    pub fn quantile(&self, q: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let target = (q * self.total as f64).ceil() as u64;
        let mut cum = 0;
        for (i, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum >= target {
                return self.bounds[i];
            }
        }
        *self.bounds.last().unwrap()
    }

    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.p50 - 3.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let sorted = [0.0, 10.0];
        assert!((percentile_sorted(&sorted, 50.0) - 5.0).abs() < 1e-12);
        assert_eq!(percentile_sorted(&sorted, 0.0), 0.0);
        assert_eq!(percentile_sorted(&sorted, 100.0), 10.0);
    }

    #[test]
    #[should_panic]
    fn summary_empty_panics() {
        Summary::of(&[]);
    }

    #[test]
    fn welford_matches_exact() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - 5.0).abs() < 1e-12);
        assert!((w.variance() - 4.0).abs() < 1e-12);
        assert_eq!(w.min(), 2.0);
        assert_eq!(w.max(), 9.0);
        assert_eq!(w.count(), 8);
    }

    #[test]
    fn histogram_quantiles_monotone() {
        let mut h = LatencyHistogram::new();
        for i in 1..=1000 {
            h.record(i as f64 * 1e-4); // 0.1ms .. 100ms
        }
        let p50 = h.quantile(0.5);
        let p95 = h.quantile(0.95);
        let p99 = h.quantile(0.99);
        assert!(p50 <= p95 && p95 <= p99);
        assert!(p50 > 0.03 && p50 < 0.08, "p50={p50}");
    }

    #[test]
    fn histogram_merge() {
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        a.record(0.001);
        b.record(0.1);
        a.merge(&b);
        assert_eq!(a.count(), 2);
    }

    #[test]
    fn histogram_empty_quantiles_are_zero() {
        let h = LatencyHistogram::new();
        assert_eq!(h.count(), 0);
        for q in [0.0, 0.5, 0.95, 0.99, 1.0] {
            assert_eq!(h.quantile(q), 0.0, "q={q}");
        }
    }

    #[test]
    fn histogram_single_sample_every_quantile_brackets_it() {
        let mut h = LatencyHistogram::new();
        h.record(0.00042);
        assert_eq!(h.count(), 1);
        // Every quantile lands on the one occupied bucket's upper
        // bound: at least the sample, within one 1.2× bucket of it.
        for q in [0.01, 0.5, 0.99, 1.0] {
            let v = h.quantile(q);
            assert!(v >= 0.00042 && v <= 0.00042 * 1.2, "q={q} v={v}");
        }
    }

    #[test]
    fn histogram_top_bucket_saturates_to_infinity() {
        let mut h = LatencyHistogram::new();
        h.record(1e9); // way past the 60s top finite bound
        h.record(f64::INFINITY);
        assert_eq!(h.count(), 2);
        assert_eq!(h.quantile(0.99), f64::INFINITY);
        // Sub-second samples still dominate the low quantiles.
        for _ in 0..98 {
            h.record(0.001);
        }
        assert!(h.quantile(0.5) < 0.002);
        assert_eq!(h.quantile(1.0), f64::INFINITY);
    }

    #[test]
    fn histogram_merge_equals_recording_everything_once() {
        use crate::util::prop::{forall, Config};
        // Buckets are fixed at construction, so merge must be *exactly*
        // record-concatenation: same counts, same quantiles.
        forall(Config::default().cases(50), "hist-merge-roundtrip", |rng| {
            let na = rng.below(40);
            let nb = rng.below(40);
            let mut a = LatencyHistogram::new();
            let mut b = LatencyHistogram::new();
            let mut all = LatencyHistogram::new();
            let mut sample = |rng: &mut crate::util::rng::Rng| {
                // Spread across the full range, µs to beyond-top-bucket.
                10f64.powf(rng.uniform() * 9.0 - 7.0)
            };
            for _ in 0..na {
                let s = sample(rng);
                a.record(s);
                all.record(s);
            }
            for _ in 0..nb {
                let s = sample(rng);
                b.record(s);
                all.record(s);
            }
            a.merge(&b);
            let mut ok = a.count() == all.count();
            for q in [0.25, 0.5, 0.9, 0.95, 0.99] {
                ok &= a.quantile(q) == all.quantile(q);
            }
            ((na, nb), ok)
        });
    }
}
