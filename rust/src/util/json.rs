//! Minimal JSON substrate (replaces `serde_json` on the offline image).
//!
//! Supports the full JSON grammar needed by the artifact manifest,
//! golden vectors, and coordinator config files: objects, arrays,
//! strings (with escapes), numbers, booleans, null.  Recursive-descent
//! parser; serializer with stable (sorted) key order via `BTreeMap`.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset context.
#[derive(Debug)]
pub struct JsonError {
    pub offset: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ------------------------------------------------------- accessors

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"][2]`-style access: `value.path(&["a", "b"])`.
    pub fn path(&self, keys: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in keys {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    /// Convenience: numeric array → `Vec<f32>`.
    pub fn as_f32_vec(&self) -> Option<Vec<f32>> {
        self.as_arr()?
            .iter()
            .map(|v| v.as_f64().map(|n| n as f32))
            .collect()
    }

    /// Convenience: numeric array → `Vec<usize>`.
    pub fn as_usize_vec(&self) -> Option<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    // ------------------------------------------------------ serializer

    /// Serialize to a compact string.
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string_compact())
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document.
pub fn parse(input: &str) -> Result<Json, JsonError> {
    let mut p = Parser {
        bytes: input.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing content"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            offset: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, val: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(val)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(b'-' | b'0'..=b'9') => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(a)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b't') => s.push('\t'),
                    Some(b'r') => s.push('\r'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let d = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (d as char)
                                    .to_digit(16)
                                    .ok_or_else(|| self.err("bad hex digit"))?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(b) if b < 0x80 => s.push(b as char),
                Some(b) => {
                    // Re-decode multi-byte UTF-8 starting at pos-1.
                    let start = self.pos - 1;
                    let len = match b {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let end = (start + len).min(self.bytes.len());
                    let chunk = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| self.err("invalid utf8"))?;
                    s.push_str(chunk);
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')) {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

/// Read and parse a JSON file.
pub fn parse_file(path: &std::path::Path) -> anyhow::Result<Json> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
    parse(&text).map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("false").unwrap(), Json::Bool(false));
        assert_eq!(parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.path(&["c"]).unwrap().as_str(), Some("x"));
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn parses_escapes() {
        let v = parse(r#""a\nb\t\"c\" A""#).unwrap();
        assert_eq!(v.as_str(), Some("a\nb\t\"c\" A"));
    }

    #[test]
    fn parses_unicode_passthrough() {
        let v = parse("\"héllo ⊛\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo ⊛"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("tru").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,true,null,"s"],"obj":{"k":"v"}}"#;
        let v = parse(src).unwrap();
        let re = parse(&v.to_string_compact()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn f32_vec_helper() {
        let v = parse("[1, 2.5, -3]").unwrap();
        assert_eq!(v.as_f32_vec().unwrap(), vec![1.0, 2.5, -3.0]);
        assert_eq!(parse("[1, \"x\"]").unwrap().as_f32_vec(), None);
    }

    #[test]
    fn usize_vec_helper() {
        let v = parse("[4, 4, 8, 4]").unwrap();
        assert_eq!(v.as_usize_vec().unwrap(), vec![4, 4, 8, 4]);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
    }

    #[test]
    fn serializer_integers_clean() {
        assert_eq!(Json::Num(42.0).to_string_compact(), "42");
        assert_eq!(Json::Num(2.5).to_string_compact(), "2.5");
    }
}
