//! Thread-pool substrate (replaces `rayon` on the offline image).
//!
//! Three facilities:
//!
//! * [`parallel_for`] — scoped data-parallel loop over an index range,
//!   built on `std::thread::scope`.  This is the paper's "GPU lane": the
//!   CUDA grid of per-output-element threads maps to chunks of the
//!   output index space executed by OS threads (see DESIGN.md §2 for why
//!   the conventional-vs-unified *ratio* survives this substitution).
//! * [`ThreadPool`] — a persistent pool with a submission queue, used by
//!   the coordinator's worker lanes where jobs are `'static`.
//! * [`parallel_drain`] / [`ThreadPool::run_scoped`] — *scoped* work on
//!   the persistent [`shared_pool`]: borrowed jobs drain through warm
//!   pool threads instead of freshly-spawned ones, so per-call cost is
//!   queue traffic rather than OS thread startup.  This is what the
//!   planned conv lanes (`conv::plan::ConvTransposePlan::run_par`) ride
//!   on — and why the autotuner's measured worker counts mean what they
//!   say on small layers (DESIGN.md §Autotuning).

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::thread;

use once_cell::sync::Lazy;

/// Number of worker threads to use by default (leaves one core for the
/// coordinator / OS, min 1).
pub fn default_parallelism() -> usize {
    thread::available_parallelism()
        .map(|n| n.get().saturating_sub(1).max(1))
        .unwrap_or(4)
}

/// Scoped parallel loop: calls `body(i)` for every `i in 0..n`, using
/// `workers` OS threads with dynamic chunk stealing (atomic cursor).
///
/// `body` only needs to borrow — no `'static` bound — which is what the
/// convolution kernels want (they write disjoint slices of one output).
pub fn parallel_for<F>(n: usize, workers: usize, chunk: usize, body: F)
where
    F: Fn(usize) + Sync,
{
    if n == 0 {
        return;
    }
    let workers = workers.max(1).min(n);
    if workers == 1 {
        for i in 0..n {
            body(i);
        }
        return;
    }
    let chunk = chunk.max(1);
    let cursor = AtomicUsize::new(0);
    let body = &body;
    let cursor = &cursor;
    thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(move || loop {
                let start = cursor.fetch_add(chunk, Ordering::Relaxed);
                if start >= n {
                    break;
                }
                let end = (start + chunk).min(n);
                for i in start..end {
                    body(i);
                }
            });
        }
    });
}

/// Scoped parallel loop over mutable, disjoint row-chunks of a slice:
/// splits `data` into `n_chunks` nearly equal pieces and calls
/// `body(chunk_index, chunk)` in parallel.  Useful when the output
/// decomposes by rows.
pub fn parallel_chunks_mut<T, F>(data: &mut [T], n_chunks: usize, workers: usize, body: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    let n = data.len();
    if n == 0 {
        return;
    }
    let n_chunks = n_chunks.max(1).min(n);
    let base = n / n_chunks;
    let rem = n % n_chunks;
    let mut pieces = Vec::with_capacity(n_chunks);
    let mut rest = data;
    for i in 0..n_chunks {
        let len = base + usize::from(i < rem);
        let (head, tail) = rest.split_at_mut(len);
        pieces.push((i, head));
        rest = tail;
    }
    let body = &body;
    let jobs = Mutex::new(pieces);
    let jobs = &jobs;
    let workers = workers.max(1).min(n_chunks);
    thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(move || loop {
                let job = jobs.lock().unwrap().pop();
                match job {
                    Some((i, piece)) => body(i, piece),
                    None => break,
                }
            });
        }
    });
}

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Message {
    Run(Job),
    Shutdown,
}

/// Persistent thread pool with a shared submission queue.
///
/// Jobs are `'static` closures; [`ThreadPool::wait_idle`] blocks until
/// every submitted job has finished (used by coordinator shutdown and
/// tests).  Dropping the pool joins all workers.
pub struct ThreadPool {
    tx: mpsc::Sender<Message>,
    handles: Vec<thread::JoinHandle<()>>,
    inflight: Arc<(Mutex<usize>, Condvar)>,
}

impl ThreadPool {
    /// Spawn a pool with `workers` threads (≥1).
    pub fn new(workers: usize) -> Self {
        let workers = workers.max(1);
        let (tx, rx) = mpsc::channel::<Message>();
        let rx = Arc::new(Mutex::new(rx));
        let inflight = Arc::new((Mutex::new(0usize), Condvar::new()));
        let mut handles = Vec::with_capacity(workers);
        for w in 0..workers {
            let rx = Arc::clone(&rx);
            let inflight = Arc::clone(&inflight);
            handles.push(
                thread::Builder::new()
                    .name(format!("ukstc-pool-{w}"))
                    .spawn(move || loop {
                        let msg = { rx.lock().unwrap().recv() };
                        match msg {
                            Ok(Message::Run(job)) => {
                                job();
                                let (lock, cv) = &*inflight;
                                let mut n = lock.lock().unwrap();
                                *n -= 1;
                                if *n == 0 {
                                    cv.notify_all();
                                }
                            }
                            Ok(Message::Shutdown) | Err(_) => break,
                        }
                    })
                    .expect("spawn pool worker"),
            );
        }
        ThreadPool {
            tx,
            handles,
            inflight,
        }
    }

    /// Number of worker threads.
    pub fn workers(&self) -> usize {
        self.handles.len()
    }

    /// Submit a job.  Never blocks.
    pub fn submit(&self, job: impl FnOnce() + Send + 'static) {
        {
            let (lock, _) = &*self.inflight;
            *lock.lock().unwrap() += 1;
        }
        self.tx
            .send(Message::Run(Box::new(job)))
            .expect("pool closed");
    }

    /// Block until all submitted jobs have completed.
    pub fn wait_idle(&self) {
        let (lock, cv) = &*self.inflight;
        let mut n = lock.lock().unwrap();
        while *n > 0 {
            n = cv.wait(n).unwrap();
        }
    }

    /// Run `body(item)` for every item of `jobs` using up to `workers`
    /// threads **including the calling thread**: `workers - 1` pool
    /// helpers are enlisted and the caller always drains alongside
    /// them, so the items complete even when every pool thread is busy
    /// with other scopes.  Blocks until all items are processed *and*
    /// every enlisted helper has released its borrows, which is what
    /// lets `body` and the items borrow from the caller's stack with no
    /// `'static` bound.  Panics in `body` are re-raised here after the
    /// scope has fully quiesced.
    ///
    /// Invariant: `body` must not itself call `run_scoped` on the same
    /// pool — a helper blocked inside a nested scope could starve the
    /// queue.  The conv-kernel callers satisfy this trivially (their
    /// bodies are leaf compute loops).
    pub fn run_scoped<'env, T, F>(&self, jobs: Vec<T>, workers: usize, body: F)
    where
        T: Send + 'env,
        F: Fn(T) + Send + Sync + 'env,
    {
        if jobs.is_empty() {
            return;
        }
        let n_helpers = workers
            .max(1)
            .saturating_sub(1)
            .min(jobs.len().saturating_sub(1))
            .min(self.workers());
        let state = Arc::new(ScopeState {
            queue: Mutex::new(jobs),
            body,
        });
        // 'static completion latch: each helper signals it only AFTER
        // dropping its clone of `state`, so once the latch reaches
        // `n_helpers` no pool thread holds any borrow of this frame.
        let latch = Arc::new((Mutex::new(0usize), Condvar::new()));
        // First helper panic payload, re-raised verbatim by the caller
        // so the original message/location survive the pool hop.
        let helper_panic: Arc<Mutex<Option<Box<dyn std::any::Any + Send>>>> =
            Arc::new(Mutex::new(None));
        for _ in 0..n_helpers {
            let state = Arc::clone(&state);
            let latch = Arc::clone(&latch);
            let helper_panic = Arc::clone(&helper_panic);
            let job: Box<dyn FnOnce() + Send + 'env> = Box::new(move || {
                if let Err(payload) = catch_unwind(AssertUnwindSafe(|| drain_scope(&state))) {
                    helper_panic.lock().unwrap().get_or_insert(payload);
                }
                drop(state);
                let (done, cv) = &*latch;
                *done.lock().unwrap() += 1;
                cv.notify_all();
            });
            // SAFETY: the closure touches caller-borrowed data only
            // through `state`, which it drops before signalling the
            // ('static) latch; the wait below does not return until all
            // `n_helpers` signals arrive, so no borrow escapes this
            // call.  Box<dyn FnOnce> differs only in lifetime — same
            // layout.
            let job: Box<dyn FnOnce() + Send + 'static> = unsafe {
                std::mem::transmute::<
                    Box<dyn FnOnce() + Send + 'env>,
                    Box<dyn FnOnce() + Send + 'static>,
                >(job)
            };
            self.submit(job);
        }
        // The caller is worker zero: drain alongside the helpers.
        let caller = catch_unwind(AssertUnwindSafe(|| drain_scope(&state)));
        let (done, cv) = &*latch;
        let mut n = done.lock().unwrap();
        while *n < n_helpers {
            n = cv.wait(n).unwrap();
        }
        drop(n);
        if let Err(e) = caller {
            resume_unwind(e);
        }
        if let Some(payload) = helper_panic.lock().unwrap().take() {
            resume_unwind(payload);
        }
    }
}

/// Shared queue + body of one [`ThreadPool::run_scoped`] call.
struct ScopeState<T, F> {
    queue: Mutex<Vec<T>>,
    body: F,
}

fn drain_scope<T, F: Fn(T)>(state: &ScopeState<T, F>) {
    loop {
        let item = state.queue.lock().unwrap().pop();
        match item {
            Some(t) => (state.body)(t),
            None => break,
        }
    }
}

/// Process-wide persistent pool for scoped data-parallel kernel work,
/// sized by [`default_parallelism`] and spawned on first use.  Used
/// exclusively through [`parallel_drain`]; the coordinator keeps its
/// own [`ThreadPool`] instances, so leaf kernel work and `'static`
/// serving jobs never contend for the same queue.
static SHARED_POOL: Lazy<ThreadPool> = Lazy::new(|| ThreadPool::new(default_parallelism()));

/// The process-wide kernel pool (spawned on first use, sized by
/// [`default_parallelism`]).
pub fn shared_pool() -> &'static ThreadPool {
    &SHARED_POOL
}

/// [`ThreadPool::run_scoped`] on the [`shared_pool`]: borrowed jobs on
/// persistent threads.  `workers` counts the calling thread, so the
/// effective parallelism equals the tuned/benched worker number.
pub fn parallel_drain<T, F>(jobs: Vec<T>, workers: usize, body: F)
where
    T: Send,
    F: Fn(T) + Send + Sync,
{
    shared_pool().run_scoped(jobs, workers, body);
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        for _ in &self.handles {
            let _ = self.tx.send(Message::Shutdown);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn parallel_for_covers_range() {
        let hits: Vec<AtomicUsize> = (0..1000).map(|_| AtomicUsize::new(0)).collect();
        parallel_for(1000, 4, 16, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn parallel_for_empty_and_single() {
        parallel_for(0, 4, 8, |_| panic!("must not run"));
        let count = AtomicUsize::new(0);
        parallel_for(1, 4, 8, |_| {
            count.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(count.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn parallel_chunks_mut_disjoint() {
        let mut data = vec![0usize; 103];
        parallel_chunks_mut(&mut data, 7, 4, |ci, chunk| {
            for v in chunk {
                *v = ci + 1;
            }
        });
        assert!(data.iter().all(|&v| v >= 1 && v <= 7));
        // Every chunk index appears.
        for ci in 1..=7 {
            assert!(data.contains(&ci));
        }
    }

    #[test]
    fn pool_runs_jobs() {
        let pool = ThreadPool::new(4);
        let sum = Arc::new(AtomicU64::new(0));
        for i in 0..100u64 {
            let sum = Arc::clone(&sum);
            pool.submit(move || {
                sum.fetch_add(i, Ordering::Relaxed);
            });
        }
        pool.wait_idle();
        assert_eq!(sum.load(Ordering::Relaxed), 4950);
    }

    #[test]
    fn pool_wait_idle_no_jobs() {
        let pool = ThreadPool::new(2);
        pool.wait_idle(); // must not hang
    }

    #[test]
    fn pool_drop_joins() {
        let pool = ThreadPool::new(2);
        let flag = Arc::new(AtomicUsize::new(0));
        let f = Arc::clone(&flag);
        pool.submit(move || {
            f.fetch_add(1, Ordering::Relaxed);
        });
        drop(pool);
        assert_eq!(flag.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn run_scoped_covers_all_items_borrowed() {
        // Items and body borrow the stack — the whole point of the API.
        let hits: Vec<AtomicUsize> = (0..257).map(|_| AtomicUsize::new(0)).collect();
        let jobs: Vec<usize> = (0..hits.len()).collect();
        shared_pool().run_scoped(jobs, 4, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn run_scoped_empty_and_caller_only() {
        shared_pool().run_scoped(Vec::<usize>::new(), 4, |_| panic!("must not run"));
        // workers = 1 → no helpers enlisted; the caller drains alone.
        let count = AtomicUsize::new(0);
        shared_pool().run_scoped(vec![1, 2, 3], 1, |_| {
            count.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(count.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn run_scoped_disjoint_mut_slices() {
        // The conv-plan shape: jobs hand out &mut rows of one buffer.
        let mut data = vec![0u32; 64];
        let jobs: Vec<(usize, &mut [u32])> = data.chunks_mut(8).enumerate().collect();
        parallel_drain(jobs, 3, |(i, chunk)| {
            for v in chunk {
                *v = i as u32 + 1;
            }
        });
        for (i, piece) in data.chunks(8).enumerate() {
            assert!(piece.iter().all(|&v| v == i as u32 + 1));
        }
    }

    #[test]
    fn run_scoped_concurrent_scopes() {
        // Several threads scope through the one shared pool at once.
        let total = Arc::new(AtomicU64::new(0));
        thread::scope(|s| {
            for _ in 0..3 {
                let total = Arc::clone(&total);
                s.spawn(move || {
                    let jobs: Vec<u64> = (0..100).collect();
                    parallel_drain(jobs, 4, |i| {
                        total.fetch_add(i, Ordering::Relaxed);
                    });
                });
            }
        });
        assert_eq!(total.load(Ordering::Relaxed), 3 * 4950);
    }

    #[test]
    #[should_panic]
    fn run_scoped_propagates_body_panic() {
        parallel_drain(vec![0usize, 1, 2, 3], 2, |i| {
            if i == 2 {
                panic!("boom");
            }
        });
    }
}
