//! Deterministic PRNG substrate (replaces `rand`).
//!
//! xoshiro256++ seeded through SplitMix64 — the standard pairing: the
//! xoshiro state must not be all-zero and benefits from a well-mixed
//! seed, which SplitMix64 provides from any u64.  Adds the samplers the
//! library needs: uniform floats, normals (Box–Muller), Poisson and
//! exponential inter-arrival draws for the workload generator.

/// xoshiro256++ PRNG.  Deterministic, seedable, `Send`.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal from Box–Muller.
    spare_normal: Option<f64>,
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Construct from a 64-bit seed (any value, including 0, is fine).
    pub fn seeded(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
            spare_normal: None,
        }
    }

    /// Split off an independent stream (for per-thread RNGs).
    pub fn split(&mut self) -> Rng {
        Rng::seeded(self.next_u64())
    }

    /// Next raw 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53-bit resolution.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[lo, hi)`.
    #[inline]
    pub fn uniform_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform() as f32
    }

    /// Uniform integer in `[0, n)` (Lemire-style rejection-free enough
    /// for non-cryptographic use).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.uniform() * n as f64) as usize % n
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo + 1)
    }

    /// Standard normal via Box–Muller (with spare caching).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        // Avoid log(0).
        let u1 = loop {
            let u = self.uniform();
            if u > 1e-300 {
                break u;
            }
        };
        let u2 = self.uniform();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = std::f64::consts::TAU * u2;
        self.spare_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Standard normal as f32.
    #[inline]
    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Exponential inter-arrival sample with the given rate (events/s).
    pub fn exponential(&mut self, rate: f64) -> f64 {
        debug_assert!(rate > 0.0);
        let u = loop {
            let u = self.uniform();
            if u > 1e-300 {
                break u;
            }
        };
        -u.ln() / rate
    }

    /// Fill a slice with standard normals.
    pub fn fill_normal(&mut self, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = self.normal_f32();
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::seeded(1);
        let mut b = Rng::seeded(1);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::seeded(1);
        let mut b = Rng::seeded(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::seeded(3);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_near_half() {
        let mut r = Rng::seeded(4);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.uniform()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seeded(5);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::seeded(6);
        for _ in 0..10_000 {
            assert!(r.below(7) < 7);
        }
        // All residues reachable.
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[r.below(7)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::seeded(7);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.exponential(4.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.25).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seeded(8);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn split_streams_independent() {
        let mut a = Rng::seeded(9);
        let mut b = a.split();
        let av: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let bv: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(av, bv);
    }
}
