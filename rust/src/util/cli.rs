//! Command-line parsing substrate (replaces `clap` on the offline image).
//!
//! Grammar: `ukstc <subcommand> [--flag] [--key value] [positional...]`.
//! Flags may use `--key=value` or `--key value`.  Unknown flags are
//! errors; every flag must be declared so `--help` output stays honest.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Declared option for help text + validation.
#[derive(Debug, Clone)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    /// `true` → boolean flag; `false` → takes a value.
    pub is_flag: bool,
    pub default: Option<&'static str>,
}

/// Parsed argument bag for one subcommand.
#[derive(Debug, Clone, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> anyhow::Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects an integer, got '{v}'")),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> anyhow::Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects a number, got '{v}'")),
        }
    }

    pub fn has_flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }
}

/// A subcommand with declared options.
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    pub opts: Vec<OptSpec>,
}

impl Command {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Command {
            name,
            about,
            opts: Vec::new(),
        }
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec {
            name,
            help,
            is_flag: true,
            default: None,
        });
        self
    }

    pub fn opt(
        mut self,
        name: &'static str,
        help: &'static str,
        default: Option<&'static str>,
    ) -> Self {
        self.opts.push(OptSpec {
            name,
            help,
            is_flag: false,
            default,
        });
        self
    }

    /// Parse raw args (after the subcommand name).
    pub fn parse(&self, raw: &[String]) -> anyhow::Result<Args> {
        let mut args = Args::default();
        for spec in &self.opts {
            if let Some(d) = spec.default {
                args.values.insert(spec.name.to_string(), d.to_string());
            }
        }
        let mut it = raw.iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(body) = tok.strip_prefix("--") {
                let (key, inline_val) = match body.split_once('=') {
                    Some((k, v)) => (k, Some(v.to_string())),
                    None => (body, None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == key)
                    .ok_or_else(|| anyhow::anyhow!("unknown option --{key}\n{}", self.help()))?;
                if spec.is_flag {
                    if inline_val.is_some() {
                        anyhow::bail!("flag --{key} takes no value");
                    }
                    args.flags.push(key.to_string());
                } else {
                    let val = match inline_val {
                        Some(v) => v,
                        None => it
                            .next()
                            .ok_or_else(|| anyhow::anyhow!("--{key} expects a value"))?
                            .clone(),
                    };
                    args.values.insert(key.to_string(), val);
                }
            } else {
                args.positional.push(tok.clone());
            }
        }
        Ok(args)
    }

    /// Render help text for this subcommand.
    pub fn help(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "{} — {}", self.name, self.about);
        for o in &self.opts {
            let kind = if o.is_flag { "" } else { " <value>" };
            let dft = o
                .default
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            let _ = writeln!(s, "  --{}{kind}\t{}{dft}", o.name, o.help);
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cmd() -> Command {
        Command::new("bench", "run benchmarks")
            .opt("iters", "iterations", Some("10"))
            .opt("model", "gan model", None)
            .flag("verbose", "chatty output")
    }

    fn raw(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_key_value_both_styles() {
        let a = cmd().parse(&raw(&["--iters", "5", "--model=ebgan"])).unwrap();
        assert_eq!(a.get_usize("iters", 0).unwrap(), 5);
        assert_eq!(a.get("model"), Some("ebgan"));
    }

    #[test]
    fn defaults_apply() {
        let a = cmd().parse(&raw(&[])).unwrap();
        assert_eq!(a.get_usize("iters", 0).unwrap(), 10);
        assert_eq!(a.get("model"), None);
    }

    #[test]
    fn flags_and_positionals() {
        let a = cmd().parse(&raw(&["--verbose", "table2", "table4"])).unwrap();
        assert!(a.has_flag("verbose"));
        assert_eq!(a.positional, vec!["table2", "table4"]);
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(cmd().parse(&raw(&["--nope"])).is_err());
    }

    #[test]
    fn flag_with_value_rejected() {
        assert!(cmd().parse(&raw(&["--verbose=yes"])).is_err());
    }

    #[test]
    fn missing_value_rejected() {
        assert!(cmd().parse(&raw(&["--model"])).is_err());
    }

    #[test]
    fn bad_int_rejected() {
        let a = cmd().parse(&raw(&["--iters", "abc"])).unwrap();
        assert!(a.get_usize("iters", 0).is_err());
    }

    #[test]
    fn help_mentions_options() {
        let h = cmd().help();
        assert!(h.contains("--iters"));
        assert!(h.contains("--verbose"));
    }
}
