//! Minimal leveled logger backend for the `log` facade.
//!
//! Stderr sink with RFC-ish timestamps relative to process start; level
//! from `UKSTC_LOG` (error|warn|info|debug|trace, default info).

use std::sync::Once;
use std::time::Instant;

use log::{Level, LevelFilter, Metadata, Record};
use once_cell::sync::Lazy;

static START: Lazy<Instant> = Lazy::new(Instant::now);

struct StderrLogger;

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= log::max_level()
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = START.elapsed().as_secs_f64();
        let lvl = match record.level() {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[{t:10.4}s {lvl} {}] {}", record.target(), record.args());
    }

    fn flush(&self) {}
}

static INIT: Once = Once::new();
static LOGGER: StderrLogger = StderrLogger;

/// Install the logger (idempotent).  Level from `UKSTC_LOG` env var.
pub fn init() {
    INIT.call_once(|| {
        Lazy::force(&START);
        let level = match std::env::var("UKSTC_LOG").as_deref() {
            Ok("error") => LevelFilter::Error,
            Ok("warn") => LevelFilter::Warn,
            Ok("debug") => LevelFilter::Debug,
            Ok("trace") => LevelFilter::Trace,
            _ => LevelFilter::Info,
        };
        let _ = log::set_logger(&LOGGER);
        log::set_max_level(level);
    });
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_idempotent() {
        super::init();
        super::init();
        log::info!("logger smoke test");
    }
}
