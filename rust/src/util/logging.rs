//! Minimal leveled logger backend for the `log` facade.
//!
//! Stderr sink with RFC-ish timestamps relative to process start.
//! `UKSTC_LOG` follows the familiar `env_logger` grammar, reduced to
//! what an offline binary needs: a default level plus per-target
//! overrides, e.g.
//!
//! ```text
//! UKSTC_LOG=info                       # default level only
//! UKSTC_LOG=debug                      # everything at debug
//! UKSTC_LOG=info,ukstc::tune=debug     # tuner chatty, rest at info
//! UKSTC_LOG=warn,ukstc::coordinator=trace,ukstc::conv=debug
//! ```
//!
//! An override applies to the named target and everything below it at a
//! module boundary: `ukstc::tune` matches `ukstc::tune` and
//! `ukstc::tune::measure`, but not `ukstc::tuner2`.  The most specific
//! (longest) matching override wins.  Unknown level words fall back to
//! `info` rather than erroring — a typo in an env var should never kill
//! the process.

use std::sync::Once;
use std::time::Instant;

use log::{Level, LevelFilter, Metadata, Record};
use once_cell::sync::Lazy;

static START: Lazy<Instant> = Lazy::new(Instant::now);

/// Parsed `UKSTC_LOG` directive set: a default level plus per-target
/// overrides, longest target first so the first match is the winner.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Spec {
    default: LevelFilter,
    /// `(target, level)`, sorted by descending target length.
    overrides: Vec<(String, LevelFilter)>,
}

fn parse_level(word: &str) -> Option<LevelFilter> {
    match word {
        "off" => Some(LevelFilter::Off),
        "error" => Some(LevelFilter::Error),
        "warn" => Some(LevelFilter::Warn),
        "info" => Some(LevelFilter::Info),
        "debug" => Some(LevelFilter::Debug),
        "trace" => Some(LevelFilter::Trace),
        _ => None,
    }
}

impl Spec {
    /// Parse a `UKSTC_LOG` value.  Comma-separated directives; a bare
    /// level sets the default, `target=level` adds an override.
    /// Malformed pieces are ignored (the default stays `info`).
    pub fn parse(s: &str) -> Spec {
        let mut default = LevelFilter::Info;
        let mut overrides: Vec<(String, LevelFilter)> = Vec::new();
        for piece in s.split(',') {
            let piece = piece.trim();
            if piece.is_empty() {
                continue;
            }
            match piece.split_once('=') {
                None => {
                    if let Some(lvl) = parse_level(piece) {
                        default = lvl;
                    }
                }
                Some((target, word)) => {
                    if let Some(lvl) = parse_level(word.trim()) {
                        let target = target.trim();
                        if !target.is_empty() {
                            overrides.push((target.to_string(), lvl));
                        }
                    }
                }
            }
        }
        // Longest target first: the most specific override wins.
        overrides.sort_by(|a, b| b.0.len().cmp(&a.0.len()).then_with(|| a.0.cmp(&b.0)));
        Spec { default, overrides }
    }

    /// The effective level for one log target.
    pub fn level_for(&self, target: &str) -> LevelFilter {
        for (t, lvl) in &self.overrides {
            // Module-boundary prefix match: `ukstc::tune` covers
            // `ukstc::tune::measure` but not `ukstc::tuner2`.
            if target == t || (target.starts_with(t) && target[t.len()..].starts_with("::")) {
                return *lvl;
            }
        }
        self.default
    }

    /// The loosest level any directive allows — what
    /// `log::set_max_level` gets, so the facade's early-out stays
    /// correct while per-target filtering happens in [`log::Log::enabled`].
    pub fn max(&self) -> LevelFilter {
        self.overrides
            .iter()
            .map(|(_, l)| *l)
            .chain(std::iter::once(self.default))
            .max()
            .unwrap_or(LevelFilter::Info)
    }
}

struct StderrLogger {
    spec: Spec,
}

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= self.spec.level_for(metadata.target())
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = START.elapsed().as_secs_f64();
        let lvl = match record.level() {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[{t:10.4}s {lvl} {}] {}", record.target(), record.args());
    }

    fn flush(&self) {}
}

static INIT: Once = Once::new();

/// Install the logger (idempotent).  Directives from `UKSTC_LOG`.
pub fn init() {
    INIT.call_once(|| {
        Lazy::force(&START);
        let spec = Spec::parse(&std::env::var("UKSTC_LOG").unwrap_or_default());
        log::set_max_level(spec.max());
        // Leaked once per process: `log::set_logger` wants 'static.
        let logger: &'static StderrLogger = Box::leak(Box::new(StderrLogger { spec }));
        let _ = log::set_logger(logger);
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_idempotent() {
        super::init();
        super::init();
        log::info!("logger smoke test");
    }

    #[test]
    fn bare_level_sets_default() {
        let s = Spec::parse("debug");
        assert_eq!(s.level_for("ukstc::conv"), LevelFilter::Debug);
        assert_eq!(s.max(), LevelFilter::Debug);
    }

    #[test]
    fn empty_and_garbage_fall_back_to_info() {
        for raw in ["", "verbose", "=debug", "ukstc::tune=chatty"] {
            let s = Spec::parse(raw);
            assert_eq!(s.level_for("anything"), LevelFilter::Info, "raw={raw:?}");
        }
    }

    #[test]
    fn per_target_override_beats_default() {
        let s = Spec::parse("info,ukstc::tune=debug");
        assert_eq!(s.level_for("ukstc::tune"), LevelFilter::Debug);
        assert_eq!(s.level_for("ukstc::tune::measure"), LevelFilter::Debug);
        assert_eq!(s.level_for("ukstc::conv"), LevelFilter::Info);
        // Module-boundary match only: no accidental prefix capture.
        assert_eq!(s.level_for("ukstc::tuner2"), LevelFilter::Info);
        assert_eq!(s.max(), LevelFilter::Debug);
    }

    #[test]
    fn most_specific_override_wins() {
        let s = Spec::parse("warn,ukstc=info,ukstc::tune=trace");
        assert_eq!(s.level_for("ukstc::tune::space"), LevelFilter::Trace);
        assert_eq!(s.level_for("ukstc::conv"), LevelFilter::Info);
        assert_eq!(s.level_for("other_crate"), LevelFilter::Warn);
        assert_eq!(s.max(), LevelFilter::Trace);
    }

    #[test]
    fn off_silences_a_target() {
        let s = Spec::parse("debug,ukstc::coordinator=off");
        assert_eq!(s.level_for("ukstc::coordinator::worker"), LevelFilter::Off);
        assert_eq!(s.max(), LevelFilter::Debug);
    }

    #[test]
    fn whitespace_tolerated() {
        let s = Spec::parse(" info , ukstc::tune = debug ");
        assert_eq!(s.level_for("ukstc::tune"), LevelFilter::Debug);
        assert_eq!(s.level_for("ukstc::conv"), LevelFilter::Info);
    }
}
