//! Wall-clock measurement helpers for the bench harness.

use std::time::Instant;

use super::stats::Summary;

/// Time a single invocation, returning (seconds, result).
pub fn time_once<T>(f: impl FnOnce() -> T) -> (f64, T) {
    let t0 = Instant::now();
    let out = f();
    (t0.elapsed().as_secs_f64(), out)
}

/// Repeated measurement: `warmup` unrecorded runs, then `iters` recorded
/// ones.  A `black_box`-style sink prevents the optimizer from deleting
/// the computation (results must flow through `consume`).
pub fn measure<T>(warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> Measurement {
    for _ in 0..warmup {
        consume(f());
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        consume(f());
        samples.push(t0.elapsed().as_secs_f64());
    }
    Measurement { samples }
}

/// Adaptive measurement: run until `min_time_s` of recorded samples or
/// `max_iters`, whichever first (at least 3 iterations).
pub fn measure_for<T>(
    warmup: usize,
    min_time_s: f64,
    max_iters: usize,
    mut f: impl FnMut() -> T,
) -> Measurement {
    for _ in 0..warmup {
        consume(f());
    }
    let mut samples = Vec::new();
    let started = Instant::now();
    while (samples.len() < 3)
        || (started.elapsed().as_secs_f64() < min_time_s && samples.len() < max_iters)
    {
        let t0 = Instant::now();
        consume(f());
        samples.push(t0.elapsed().as_secs_f64());
    }
    Measurement { samples }
}

/// Collected timing samples (seconds per iteration).
#[derive(Debug, Clone)]
pub struct Measurement {
    pub samples: Vec<f64>,
}

impl Measurement {
    pub fn summary(&self) -> Summary {
        Summary::of(&self.samples)
    }

    /// Best (minimum) sample — the conventional proxy for "true" cost of
    /// a deterministic computation under scheduler noise.
    pub fn best(&self) -> f64 {
        self.samples
            .iter()
            .copied()
            .fold(f64::INFINITY, f64::min)
    }

    /// Median sample — robust central tendency used in the tables.
    pub fn median(&self) -> f64 {
        self.summary().p50
    }

    pub fn total(&self) -> f64 {
        self.samples.iter().sum()
    }
}

/// Optimizer sink, equivalent in spirit to `std::hint::black_box`.
#[inline]
pub fn consume<T>(value: T) {
    let _ = std::hint::black_box(value);
}

/// Pretty-print a duration in adaptive units.
pub fn fmt_duration(seconds: f64) -> String {
    if seconds >= 1.0 {
        format!("{seconds:.3} s")
    } else if seconds >= 1e-3 {
        format!("{:.3} ms", seconds * 1e3)
    } else if seconds >= 1e-6 {
        format!("{:.3} µs", seconds * 1e6)
    } else {
        format!("{:.1} ns", seconds * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_collects_samples() {
        let m = measure(1, 5, || (0..1000).sum::<u64>());
        assert_eq!(m.samples.len(), 5);
        assert!(m.best() >= 0.0);
        assert!(m.median() >= m.best());
    }

    #[test]
    fn measure_for_respects_min_iters() {
        let m = measure_for(0, 0.0, 100, || 1 + 1);
        assert!(m.samples.len() >= 3);
    }

    #[test]
    fn fmt_units() {
        assert!(fmt_duration(2.0).ends_with(" s"));
        assert!(fmt_duration(2e-3).ends_with(" ms"));
        assert!(fmt_duration(2e-6).ends_with(" µs"));
        assert!(fmt_duration(2e-9).ends_with(" ns"));
    }

    #[test]
    fn time_once_returns_result() {
        let (dt, v) = time_once(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(dt >= 0.0);
    }
}
