//! Property-testing substrate (replaces `proptest` on the offline image).
//!
//! Deterministic, seed-reported random case generation with size-ramped
//! inputs and greedy failing-case minimization for the common "bag of
//! small integers" configuration shape the conv invariants use.
//!
//! ```no_run
//! use ukstc::util::prop::{forall, Config};
//! forall(Config::default().cases(64), "add-commutes", |rng| {
//!     let (a, b) = (rng.below(100) as u64, rng.below(100) as u64);
//!     ((a, b), a + b == b + a)
//! });
//! ```

use super::rng::Rng;
use std::fmt::Debug;

/// Run configuration.
#[derive(Debug, Clone)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            cases: 100,
            seed: 0xC0FFEE,
        }
    }
}

impl Config {
    pub fn cases(mut self, n: usize) -> Self {
        self.cases = n;
        self
    }

    pub fn seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }
}

/// Run `prop` over `cfg.cases` generated cases.  `prop` receives a fresh
/// per-case RNG and returns `(case_description, holds)`.  On failure the
/// case description, its index and the reproduction seed are reported in
/// the panic message.
pub fn forall<D: Debug>(cfg: Config, name: &str, mut prop: impl FnMut(&mut Rng) -> (D, bool)) {
    let mut meta = Rng::seeded(cfg.seed);
    for case_idx in 0..cfg.cases {
        let case_seed = meta.next_u64();
        let mut rng = Rng::seeded(case_seed);
        let (desc, ok) = prop(&mut rng);
        if !ok {
            panic!(
                "property '{name}' failed at case {case_idx}\n  case: {desc:?}\n  \
                 reproduce with seed {case_seed:#x}"
            );
        }
    }
}

/// Like [`forall`] but the property returns `Result<(), String>` so the
/// failure message can carry numeric diagnostics (max abs error, etc.).
pub fn forall_res<D: Debug>(
    cfg: Config,
    name: &str,
    mut prop: impl FnMut(&mut Rng) -> (D, Result<(), String>),
) {
    let mut meta = Rng::seeded(cfg.seed);
    for case_idx in 0..cfg.cases {
        let case_seed = meta.next_u64();
        let mut rng = Rng::seeded(case_seed);
        let (desc, res) = prop(&mut rng);
        if let Err(msg) = res {
            panic!(
                "property '{name}' failed at case {case_idx}\n  case: {desc:?}\n  \
                 error: {msg}\n  reproduce with seed {case_seed:#x}"
            );
        }
    }
}

/// Approximate float comparison helper for property bodies.
pub fn close(a: &[f32], b: &[f32], atol: f32) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch {} vs {}", a.len(), b.len()));
    }
    let mut max_err = 0f32;
    let mut max_idx = 0usize;
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        let err = (x - y).abs();
        if err > max_err {
            max_err = err;
            max_idx = i;
        }
    }
    if max_err > atol {
        Err(format!(
            "max abs err {max_err:.3e} at index {max_idx} (a={}, b={})",
            a[max_idx], b[max_idx]
        ))
    } else {
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall(Config::default().cases(50), "u64-add-commutes", |rng| {
            let a = rng.below(1000) as u64;
            let b = rng.below(1000) as u64;
            ((a, b), a + b == b + a)
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-false' failed")]
    fn failing_property_panics_with_seed() {
        forall(Config::default().cases(5), "always-false", |rng| {
            (rng.below(10), false)
        });
    }

    #[test]
    fn forall_res_reports_message() {
        let result = std::panic::catch_unwind(|| {
            forall_res(Config::default().cases(3), "bad", |_rng| {
                ((), Err("numeric blowup".to_string()))
            });
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        assert!(msg.contains("numeric blowup"));
        assert!(msg.contains("reproduce with seed"));
    }

    #[test]
    fn close_detects_mismatch() {
        assert!(close(&[1.0, 2.0], &[1.0, 2.0], 1e-6).is_ok());
        assert!(close(&[1.0, 2.0], &[1.0, 2.1], 1e-6).is_err());
        assert!(close(&[1.0], &[1.0, 2.0], 1e-6).is_err());
    }

    #[test]
    fn deterministic_across_runs() {
        let mut first = Vec::new();
        forall(Config::default().cases(10).seed(42), "capture", |rng| {
            first.push(rng.below(1_000_000));
            ((), true)
        });
        let mut second = Vec::new();
        forall(Config::default().cases(10).seed(42), "capture", |rng| {
            second.push(rng.below(1_000_000));
            ((), true)
        });
        assert_eq!(first, second);
    }
}
