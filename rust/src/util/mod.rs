//! Offline-image substrates.
//!
//! The build environment resolves only the crates vendored for the xla
//! bridge (no serde / clap / rand / rayon / criterion / proptest), so the
//! facilities a production crate would pull from the ecosystem are built
//! here from scratch:
//!
//! * [`rng`] — SplitMix64 seeding + xoshiro256++ PRNG with normal/uniform
//!   samplers (replaces `rand`)
//! * [`json`] — recursive-descent JSON parser + serializer (replaces
//!   `serde_json`; parses the artifact manifest and golden vectors)
//! * [`stats`] — streaming summary statistics and percentile estimation
//! * [`timing`] — wall-clock measurement helpers for the bench harness
//! * [`threadpool`] — persistent worker pool + scoped `parallel_for`
//!   (replaces `rayon`; also serves as the paper's "GPU lane", see
//!   DESIGN.md §2)
//! * [`cli`] — subcommand/flag parser (replaces `clap`)
//! * [`prop`] — property-test harness with seeded case generation and
//!   failing-case reporting (replaces `proptest`)
//! * [`logging`] — minimal leveled logger backend for the `log` crate

pub mod cli;
pub mod json;
pub mod logging;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod threadpool;
pub mod timing;
