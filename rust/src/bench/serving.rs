//! Serving A/B bench: coordinator throughput/latency with the unified
//! kernel vs the conventional baseline as the backend compute.
//!
//! This is the end-to-end claim check: the kernel-level ~4× FLOP
//! reduction must translate into service-level throughput/latency wins
//! when everything above it (router, batcher, workers) is identical.
//! Since the batched refactor (DESIGN.md §Batched-Execution) the
//! matrix also A/Bs the **fused batched** forward — one
//! `forward_batch` per dynamic batch, packed GEMM operands streamed
//! once per batch — against the historic per-latent loop, the
//! throughput column the ISSUE-5 acceptance asks for.

use std::sync::Arc;
use std::time::Duration;

use crate::conv::parallel::{Algorithm, Lane};
use crate::coordinator::backend::RustBackend;
use crate::coordinator::batcher::BatchPolicy;
use crate::coordinator::metrics::Snapshot;
use crate::coordinator::Coordinator;
use crate::models::GanModel;
use crate::util::rng::Rng;
use crate::workload::generator::burst;

/// Serving scenario knobs.
#[derive(Debug, Clone)]
pub struct ServingConfig {
    pub model: GanModel,
    pub requests: usize,
    pub workers_per_model: usize,
    pub lane_workers: usize,
    /// Threads the backend fans one batch's latents across (per-worker
    /// scratch arenas; 1 = in-line).
    pub batch_workers: usize,
    pub max_batch: usize,
    pub max_delay: Duration,
    pub queue_capacity: usize,
    /// Tuning-cache path: when set, every backend is autotuned for
    /// `max_batch` through it (`RustBackend::with_autotune_batch`), so
    /// `ukstc tune --batch N` verdicts drive the serving runs.
    pub tune_cache: Option<std::path::PathBuf>,
}

impl Default for ServingConfig {
    fn default() -> Self {
        ServingConfig {
            model: GanModel::GpGan,
            requests: 24,
            workers_per_model: 2,
            lane_workers: 2,
            batch_workers: 1,
            max_batch: 8,
            max_delay: Duration::from_millis(3),
            queue_capacity: 512,
            tune_cache: None,
        }
    }
}

/// Result of one serving run.
#[derive(Debug, Clone)]
pub struct ServingResult {
    pub algorithm: Algorithm,
    /// Whether the backend executed through the AOT plans.
    pub planned: bool,
    /// Whether batches ran through the fused batched forward (vs the
    /// per-latent loop).
    pub fused: bool,
    pub wall_s: f64,
    pub snapshot: Snapshot,
}

/// Run a closed-loop burst through a coordinator whose backend uses
/// `alg` (planned, fused-batch execution) for every transpose conv.
pub fn run_once(cfg: &ServingConfig, alg: Algorithm) -> anyhow::Result<ServingResult> {
    run_once_mode(cfg, alg, true, true)
}

/// [`run_once`] with the planned path switchable — the
/// planned-vs-unplanned serving ablation lane.
pub fn run_once_with(
    cfg: &ServingConfig,
    alg: Algorithm,
    planned: bool,
) -> anyhow::Result<ServingResult> {
    run_once_mode(cfg, alg, planned, true)
}

/// [`run_once`] with both the planned path and the fused batched lane
/// switchable.  Only the unified algorithm has a planned path or a
/// fused batched forward; for every other algorithm the result is
/// recorded as unplanned/unfused regardless of the flags, and
/// `batch_workers > 1` also routes around the fused lane.
pub fn run_once_mode(
    cfg: &ServingConfig,
    alg: Algorithm,
    planned: bool,
    fused: bool,
) -> anyhow::Result<ServingResult> {
    let planned = planned && alg == Algorithm::Unified;
    let lane = if cfg.lane_workers <= 1 {
        Lane::Serial
    } else {
        Lane::Parallel(cfg.lane_workers)
    };
    let mut backend = RustBackend::new(cfg.model, alg, lane, 77, cfg.max_batch)
        .with_batch_workers(cfg.batch_workers);
    if !planned {
        backend = backend.with_unplanned();
    }
    if !fused {
        backend = backend.with_per_latent();
    }
    if let Some(path) = &cfg.tune_cache {
        backend = backend.with_autotune_batch(Some(path.as_path()), cfg.max_batch);
    }
    let fused = backend.is_fused_batch();
    let backend = Arc::new(backend);
    let coord = Coordinator::builder()
        .queue_capacity(cfg.queue_capacity)
        .workers_per_model(cfg.workers_per_model)
        .batch_policy(BatchPolicy {
            max_batch: cfg.max_batch,
            max_delay: cfg.max_delay,
        })
        .register(backend)
        .start()?;

    let mut rng = Rng::seeded(4242);
    let reqs = burst(cfg.model.name(), 100, cfg.requests, &mut rng);
    let t0 = std::time::Instant::now();
    let rxs: Vec<_> = reqs
        .into_iter()
        .map(|r| coord.submit_blocking(r).expect("submit"))
        .collect();
    for rx in rxs {
        rx.recv().expect("response");
    }
    let wall_s = t0.elapsed().as_secs_f64();
    let snapshot = coord.metrics(cfg.model.name()).unwrap();
    Ok(ServingResult {
        algorithm: alg,
        planned,
        fused,
        wall_s,
        snapshot,
    })
}

/// A/B the unified kernel against the conventional baseline.
pub fn run_ab(cfg: &ServingConfig) -> anyhow::Result<(ServingResult, ServingResult)> {
    let unified = run_once(cfg, Algorithm::Unified)?;
    let conventional = run_once(cfg, Algorithm::Conventional)?;
    Ok((unified, conventional))
}

/// The full serving matrix: unified planned fused-batch, unified
/// planned per-latent, unified unplanned, and the conventional
/// baseline — same coordinator, same trace.
pub fn run_matrix(cfg: &ServingConfig) -> anyhow::Result<Vec<ServingResult>> {
    Ok(vec![
        run_once_mode(cfg, Algorithm::Unified, true, true)?,
        run_once_mode(cfg, Algorithm::Unified, true, false)?,
        run_once_mode(cfg, Algorithm::Unified, false, false)?,
        run_once_mode(cfg, Algorithm::Conventional, true, false)?,
    ])
}

/// Print serving results side by side, with planned and fused columns.
pub fn print_results(results: &[ServingResult]) {
    use super::report;
    let rows: Vec<Vec<String>> = results
        .iter()
        .map(|r| {
            vec![
                r.algorithm.name().to_string(),
                if r.planned { "yes" } else { "no" }.to_string(),
                if r.fused { "fused" } else { "per-latent" }.to_string(),
                format!("{:.3}", r.wall_s),
                format!("{:.2}", r.snapshot.completed as f64 / r.wall_s),
                format!("{:.1}", r.snapshot.total_p50_s * 1e3),
                format!("{:.1}", r.snapshot.total_p95_s * 1e3),
                format!(
                    "{:.2}/{:.0}/{:.0}",
                    r.snapshot.mean_batch_size, r.snapshot.batch_p50, r.snapshot.batch_p95
                ),
            ]
        })
        .collect();
    report::print_table(
        "Serving A/B — coordinator end-to-end",
        &[
            "backend kernel",
            "planned",
            "batch lane",
            "wall (s)",
            "thpt (img/s)",
            "p50 (ms)",
            "p95 (ms)",
            "batch mean/p50/p95",
        ],
        &rows,
    );
    let find = |alg: Algorithm, planned: bool, fused: bool| {
        results
            .iter()
            .find(|r| r.algorithm == alg && r.planned == planned && r.fused == fused)
    };
    let fused_batch = find(Algorithm::Unified, true, true);
    let per_latent = find(Algorithm::Unified, true, false);
    let unified_planned = fused_batch.or(per_latent);
    if let (Some(u), Some(c)) = (unified_planned, find(Algorithm::Conventional, false, false)) {
        println!(
            "\nend-to-end speedup (unified vs conventional): {:.3}×",
            c.wall_s / u.wall_s
        );
    }
    if let (Some(p), Some(n)) = (unified_planned, find(Algorithm::Unified, false, false)) {
        println!(
            "end-to-end speedup (planned vs unplanned unified): {:.3}×",
            n.wall_s / p.wall_s
        );
    }
    if let (Some(f), Some(l)) = (fused_batch, per_latent) {
        println!(
            "end-to-end speedup (fused batch vs per-latent): {:.3}×",
            l.wall_s / f.wall_s
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serving_planned_and_batch_parallel_complete() {
        let cfg = ServingConfig {
            requests: 4,
            workers_per_model: 1,
            lane_workers: 1,
            batch_workers: 2,
            ..Default::default()
        };
        let planned = run_once_with(&cfg, Algorithm::Unified, true).unwrap();
        let unplanned = run_once_with(&cfg, Algorithm::Unified, false).unwrap();
        assert!(planned.planned && !unplanned.planned);
        // batch_workers 2 routes around the fused lane.
        assert!(!planned.fused && !unplanned.fused);
        assert_eq!(planned.snapshot.completed, 4);
        assert_eq!(unplanned.snapshot.completed, 4);
    }

    #[test]
    fn serving_matrix_exercises_fused_batched_lane() {
        let cfg = ServingConfig {
            requests: 6,
            workers_per_model: 1,
            lane_workers: 1,
            ..Default::default()
        };
        let results = run_matrix(&cfg).unwrap();
        assert_eq!(results.len(), 4);
        let fused: Vec<_> = results.iter().filter(|r| r.fused).collect();
        assert_eq!(fused.len(), 1, "exactly one fused-batch row");
        assert!(fused[0].planned && fused[0].algorithm == Algorithm::Unified);
        for r in &results {
            assert_eq!(r.snapshot.completed, 6);
        }
        // The fused run recorded a batch-size distribution.
        assert!(fused[0].snapshot.batches >= 1);
        assert!(fused[0].snapshot.batch_p95 >= 1.0);
        print_results(&results);
    }

    #[test]
    fn serving_ab_unified_wins() {
        let cfg = ServingConfig {
            requests: 6,
            workers_per_model: 1,
            lane_workers: 1,
            ..Default::default()
        };
        let (u, c) = run_ab(&cfg).unwrap();
        assert_eq!(u.snapshot.completed, 6);
        assert_eq!(c.snapshot.completed, 6);
        // The unified backend must serve the burst faster.
        assert!(
            u.wall_s < c.wall_s,
            "unified {:.3}s vs conventional {:.3}s",
            u.wall_s,
            c.wall_s
        );
    }
}
