//! Serving A/B bench: coordinator throughput/latency with the unified
//! kernel vs the conventional baseline as the backend compute.
//!
//! This is the end-to-end claim check: the kernel-level ~4× FLOP
//! reduction must translate into service-level throughput/latency wins
//! when everything above it (router, batcher, workers) is identical.

use std::sync::Arc;
use std::time::Duration;

use crate::conv::parallel::{Algorithm, Lane};
use crate::coordinator::backend::RustBackend;
use crate::coordinator::batcher::BatchPolicy;
use crate::coordinator::metrics::Snapshot;
use crate::coordinator::Coordinator;
use crate::models::GanModel;
use crate::util::rng::Rng;
use crate::workload::generator::burst;

/// Serving scenario knobs.
#[derive(Debug, Clone, Copy)]
pub struct ServingConfig {
    pub model: GanModel,
    pub requests: usize,
    pub workers_per_model: usize,
    pub lane_workers: usize,
    /// Threads the backend fans one batch's latents across (per-worker
    /// scratch arenas; 1 = in-line).
    pub batch_workers: usize,
    pub max_batch: usize,
    pub max_delay: Duration,
    pub queue_capacity: usize,
}

impl Default for ServingConfig {
    fn default() -> Self {
        ServingConfig {
            model: GanModel::GpGan,
            requests: 24,
            workers_per_model: 2,
            lane_workers: 2,
            batch_workers: 1,
            max_batch: 8,
            max_delay: Duration::from_millis(3),
            queue_capacity: 512,
        }
    }
}

/// Result of one serving run.
#[derive(Debug, Clone)]
pub struct ServingResult {
    pub algorithm: Algorithm,
    /// Whether the backend executed through the AOT plans.
    pub planned: bool,
    pub wall_s: f64,
    pub snapshot: Snapshot,
}

/// Run a closed-loop burst through a coordinator whose backend uses
/// `alg` (planned execution) for every transpose conv.
pub fn run_once(cfg: &ServingConfig, alg: Algorithm) -> anyhow::Result<ServingResult> {
    run_once_with(cfg, alg, true)
}

/// [`run_once`] with the planned path switchable — the
/// planned-vs-unplanned serving ablation lane.  Only the unified
/// algorithm has a planned path; for every other algorithm the result
/// is recorded as unplanned regardless of the flag.
pub fn run_once_with(
    cfg: &ServingConfig,
    alg: Algorithm,
    planned: bool,
) -> anyhow::Result<ServingResult> {
    let planned = planned && alg == Algorithm::Unified;
    let lane = if cfg.lane_workers <= 1 {
        Lane::Serial
    } else {
        Lane::Parallel(cfg.lane_workers)
    };
    let mut backend = RustBackend::new(cfg.model, alg, lane, 77, cfg.max_batch)
        .with_batch_workers(cfg.batch_workers);
    if !planned {
        backend = backend.with_unplanned();
    }
    let backend = Arc::new(backend);
    let coord = Coordinator::builder()
        .queue_capacity(cfg.queue_capacity)
        .workers_per_model(cfg.workers_per_model)
        .batch_policy(BatchPolicy {
            max_batch: cfg.max_batch,
            max_delay: cfg.max_delay,
        })
        .register(backend)
        .start()?;

    let mut rng = Rng::seeded(4242);
    let reqs = burst(cfg.model.name(), 100, cfg.requests, &mut rng);
    let t0 = std::time::Instant::now();
    let rxs: Vec<_> = reqs
        .into_iter()
        .map(|r| coord.submit_blocking(r).expect("submit"))
        .collect();
    for rx in rxs {
        rx.recv().expect("response");
    }
    let wall_s = t0.elapsed().as_secs_f64();
    let snapshot = coord.metrics(cfg.model.name()).unwrap();
    Ok(ServingResult {
        algorithm: alg,
        planned,
        wall_s,
        snapshot,
    })
}

/// A/B the unified kernel against the conventional baseline.
pub fn run_ab(cfg: &ServingConfig) -> anyhow::Result<(ServingResult, ServingResult)> {
    let unified = run_once(cfg, Algorithm::Unified)?;
    let conventional = run_once(cfg, Algorithm::Conventional)?;
    Ok((unified, conventional))
}

/// The full serving matrix: unified planned, unified unplanned, and
/// the conventional baseline — same coordinator, same trace.
pub fn run_matrix(cfg: &ServingConfig) -> anyhow::Result<Vec<ServingResult>> {
    Ok(vec![
        run_once_with(cfg, Algorithm::Unified, true)?,
        run_once_with(cfg, Algorithm::Unified, false)?,
        run_once_with(cfg, Algorithm::Conventional, true)?,
    ])
}

/// Print serving results side by side, with a planned column.
pub fn print_results(results: &[ServingResult]) {
    use super::report;
    let rows: Vec<Vec<String>> = results
        .iter()
        .map(|r| {
            vec![
                r.algorithm.name().to_string(),
                if r.planned { "yes" } else { "no" }.to_string(),
                format!("{:.3}", r.wall_s),
                format!("{:.2}", r.snapshot.completed as f64 / r.wall_s),
                format!("{:.1}", r.snapshot.total_p50_s * 1e3),
                format!("{:.1}", r.snapshot.total_p95_s * 1e3),
                format!("{:.2}", r.snapshot.mean_batch_size),
            ]
        })
        .collect();
    report::print_table(
        "Serving A/B — coordinator end-to-end",
        &[
            "backend kernel",
            "planned",
            "wall (s)",
            "thpt (img/s)",
            "p50 (ms)",
            "p95 (ms)",
            "mean batch",
        ],
        &rows,
    );
    let find = |alg: Algorithm, planned: bool| {
        results
            .iter()
            .find(|r| r.algorithm == alg && r.planned == planned)
    };
    let unified_planned = find(Algorithm::Unified, true);
    if let (Some(u), Some(c)) = (unified_planned, find(Algorithm::Conventional, false)) {
        println!(
            "\nend-to-end speedup (unified vs conventional): {:.3}×",
            c.wall_s / u.wall_s
        );
    }
    if let (Some(p), Some(n)) = (unified_planned, find(Algorithm::Unified, false)) {
        println!(
            "end-to-end speedup (planned vs unplanned unified): {:.3}×",
            n.wall_s / p.wall_s
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serving_planned_and_batch_parallel_complete() {
        let cfg = ServingConfig {
            requests: 4,
            workers_per_model: 1,
            lane_workers: 1,
            batch_workers: 2,
            ..Default::default()
        };
        let planned = run_once_with(&cfg, Algorithm::Unified, true).unwrap();
        let unplanned = run_once_with(&cfg, Algorithm::Unified, false).unwrap();
        assert!(planned.planned && !unplanned.planned);
        assert_eq!(planned.snapshot.completed, 4);
        assert_eq!(unplanned.snapshot.completed, 4);
    }

    #[test]
    fn serving_ab_unified_wins() {
        let cfg = ServingConfig {
            requests: 6,
            workers_per_model: 1,
            lane_workers: 1,
            ..Default::default()
        };
        let (u, c) = run_ab(&cfg).unwrap();
        assert_eq!(u.snapshot.completed, 6);
        assert_eq!(c.snapshot.completed, 6);
        // The unified backend must serve the burst faster.
        assert!(
            u.wall_s < c.wall_s,
            "unified {:.3}s vs conventional {:.3}s",
            u.wall_s,
            c.wall_s
        );
    }
}
