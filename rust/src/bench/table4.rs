//! Table 4 regeneration: the GAN ablation — per-layer conventional vs
//! proposed times (serial + parallel lanes), totals, speedups,
//! achieved GFLOP/s (analytic MACs from `conv::flops` over measured
//! time), and the exact memory-savings bytes.
//!
//! Protocol (paper §4.3): forward propagation of the transpose-conv
//! layers only, one input sample, per layer.

use crate::conv::parallel::{run_seg, Algorithm, Lane};
use crate::conv::plan::{ConvTransposePlan, Scratch};
use crate::conv::segregation::segregate;
use crate::conv::{flops, memory};
use crate::models::zoo::{GanModel, LayerSpec};
use crate::tensor::{Feature, Kernel};
use crate::tune::{MeasureBudget, Tuner, WallClockMeasurer};
use crate::util::rng::Rng;
use crate::util::timing;

use super::{report, BenchConfig};

/// One measured GAN layer row.
#[derive(Debug, Clone)]
pub struct LayerRow {
    pub layer_index: usize,
    pub spec: LayerSpec,
    pub conv_par: f64,
    pub conv_ser: f64,
    pub prop_par: f64,
    pub prop_ser: f64,
    /// Proposed kernel through the AOT plan + warm scratch arena
    /// (serial lane) — the planned-vs-unplanned ablation column.
    pub prop_planned_ser: f64,
    /// Proposed kernel under the autotuner's per-layer winner
    /// (DESIGN.md §Autotuning) — hand-picked vs autotuned side by side.
    pub prop_tuned: f64,
    /// Display name of the winning strategy for this layer.
    pub tuned_strategy: String,
    pub mem_savings_bytes: usize,
    pub flops_conv: u64,
    pub flops_prop: u64,
}

/// A full model's measurement.
#[derive(Debug, Clone)]
pub struct ModelResult {
    pub model: GanModel,
    pub rows: Vec<LayerRow>,
}

impl ModelResult {
    pub fn total_conv_par(&self) -> f64 {
        self.rows.iter().map(|r| r.conv_par).sum()
    }
    pub fn total_conv_ser(&self) -> f64 {
        self.rows.iter().map(|r| r.conv_ser).sum()
    }
    pub fn total_prop_par(&self) -> f64 {
        self.rows.iter().map(|r| r.prop_par).sum()
    }
    pub fn total_prop_ser(&self) -> f64 {
        self.rows.iter().map(|r| r.prop_ser).sum()
    }
    pub fn total_prop_planned_ser(&self) -> f64 {
        self.rows.iter().map(|r| r.prop_planned_ser).sum()
    }
    pub fn total_prop_tuned(&self) -> f64 {
        self.rows.iter().map(|r| r.prop_tuned).sum()
    }
    /// Planned-vs-unplanned ratio on the proposed serial path.
    pub fn planned_speedup_ser(&self) -> f64 {
        self.total_prop_ser() / self.total_prop_planned_ser()
    }
    /// Autotuned-vs-hand-picked-serial ratio on the planned path.
    pub fn tuned_speedup(&self) -> f64 {
        self.total_prop_planned_ser() / self.total_prop_tuned()
    }
    pub fn speedup_par(&self) -> f64 {
        self.total_conv_par() / self.total_prop_par()
    }
    pub fn speedup_ser(&self) -> f64 {
        self.total_conv_ser() / self.total_prop_ser()
    }
    pub fn total_savings(&self) -> usize {
        self.rows.iter().map(|r| r.mem_savings_bytes).sum()
    }
}

/// Measure one model's layer stack.
pub fn measure_model(model: GanModel, cfg: &BenchConfig) -> ModelResult {
    let mut rng = Rng::seeded(0x6A_4A_4E ^ model.name().len() as u64);
    let rows = model
        .layers()
        .iter()
        .enumerate()
        .map(|(i, &spec)| {
            log::info!("table4: {} layer {} ({}→{})", model.name(), i + 2, spec.n_in, spec.n_out());
            let x = Feature::random(spec.n_in, spec.n_in, spec.cin, &mut rng);
            let kernel = Kernel::random(spec.ksize, spec.cin, spec.cout, &mut rng);
            let seg = segregate(&kernel);
            let lane_time = |alg: Algorithm, lane: Lane| {
                timing::measure(cfg.warmup, cfg.iters, || {
                    timing::consume(run_seg(alg, lane, &x, &kernel, &seg, spec.padding))
                })
                .median()
            };
            let par = Lane::Parallel(cfg.workers);
            let params = spec.params();
            // Planned lane: plan + arena + output built once, reused
            // every iteration (the steady-state serving shape).
            let plan = ConvTransposePlan::from_seg(params, seg.clone());
            let mut scratch = Scratch::for_plan(&plan);
            let mut out = plan.new_output();
            let prop_planned_ser = timing::measure(cfg.warmup, cfg.iters, || {
                plan.run(&x, &mut scratch, &mut out);
            })
            .median();
            // Tuned lane: search the strategy space under the bench's
            // iteration budget, then time the winner with the same
            // protocol as every other column.
            let tuner = Tuner::new(cfg.workers.max(2)).with_budget(MeasureBudget {
                warmup: cfg.warmup,
                min_time_s: 0.0,
                max_iters: cfg.iters.max(1),
            });
            let tuned = tuner.tune_layer(&plan, &mut WallClockMeasurer::new(tuner.budget));
            let prop_tuned = timing::measure(cfg.warmup, cfg.iters, || {
                plan.run_with(&tuned.strategy, &x, &mut scratch, &mut out);
            })
            .median();
            LayerRow {
                layer_index: i + 2, // Table 4 numbers layers from 2
                spec,
                conv_par: lane_time(Algorithm::Conventional, par),
                conv_ser: lane_time(Algorithm::Conventional, Lane::Serial),
                prop_par: lane_time(Algorithm::Unified, par),
                prop_ser: lane_time(Algorithm::Unified, Lane::Serial),
                prop_planned_ser,
                prop_tuned,
                tuned_strategy: tuned.strategy.name(),
                mem_savings_bytes: memory::savings_table4(&params),
                flops_conv: flops::conventional(&params),
                flops_prop: flops::unified(&params),
            }
        })
        .collect();
    ModelResult { model, rows }
}

/// Paper reference totals for the summary line (Table 4).
pub fn paper_reference(model: GanModel) -> (f64, f64, usize) {
    // (GPU speedup, CPU speedup, memory savings bytes)
    match model {
        GanModel::DcGan => (3.0601, 4.211, 4_787_712),
        GanModel::ArtGan => (2.67, 4.06184, 1_871_872),
        GanModel::GpGan => (2.703, 4.0166, 2_393_856),
        GanModel::EbGan => (3.277, 4.583, 35_534_592),
    }
}

/// Print one model's block in the paper's Table 4 shape.
pub fn print_model(result: &ModelResult) {
    let rows: Vec<Vec<String>> = result
        .rows
        .iter()
        .map(|r| {
            vec![
                r.layer_index.to_string(),
                format!("{0}×{0}×{1}", r.spec.n_in, r.spec.cin),
                format!(
                    "{0}×{0}×{1}×{2}",
                    r.spec.ksize, r.spec.cin, r.spec.cout
                ),
                report::secs(r.conv_par),
                report::secs(r.prop_par),
                report::secs(r.conv_ser),
                report::secs(r.prop_ser),
                report::secs(r.prop_planned_ser),
                report::secs(r.prop_tuned),
                r.tuned_strategy.clone(),
                // Achieved GFLOP/s (analytic MACs / measured time) so
                // the table reports speed in hardware terms too.
                report::gflops_cell(r.flops_conv, r.conv_ser),
                report::gflops_cell(r.flops_prop, r.prop_tuned),
                r.mem_savings_bytes.to_string(),
                format!("{:.2}", r.flops_conv as f64 / r.flops_prop as f64),
            ]
        })
        .collect();
    report::print_table(
        &format!("Table 4 — {} transpose-conv layers", result.model.name()),
        &[
            "#",
            "Input size",
            "Kernel size",
            "Conv (par)",
            "Prop (par)",
            "Conv (serial)",
            "Prop (serial)",
            "Prop (planned)",
            "Prop (tuned)",
            "Tuned strategy",
            "Conv GF/s (ser)",
            "Prop GF/s (tuned)",
            "Mem savings (B)",
            "FLOP ratio",
        ],
        &rows,
    );
    let (paper_gpu, paper_cpu, paper_mem) = paper_reference(result.model);
    println!(
        "total: speedup par {:.3}× / serial {:.3}×, planned-vs-unplanned {:.3}×, \
         tuned-vs-planned {:.3}×, memory saved {} B",
        result.speedup_par(),
        result.speedup_ser(),
        result.planned_speedup_ser(),
        result.tuned_speedup(),
        result.total_savings()
    );
    println!(
        "paper: speedup GPU {paper_gpu}× / CPU {paper_cpu}×, memory saved {paper_mem} B{}",
        if result.total_savings() == paper_mem {
            "  [memory matches EXACTLY]"
        } else {
            "  [memory differs — see EXPERIMENTS.md notes]"
        }
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Full Table-4 protocol on the smallest model at minimal iters —
    /// validates the measurement plumbing, not performance.
    #[test]
    fn gpgan_measurement_sane() {
        let cfg = BenchConfig {
            scale: 1.0,
            warmup: 0,
            iters: 1,
            workers: 2,
        };
        let res = measure_model(GanModel::GpGan, &cfg);
        assert_eq!(res.rows.len(), 4);
        assert!(res.total_conv_ser() > 0.0);
        assert!(res.total_prop_ser() > 0.0);
        assert!(res.total_prop_planned_ser() > 0.0);
        assert!(res.total_prop_tuned() > 0.0);
        assert!(res.rows.iter().all(|r| !r.tuned_strategy.is_empty()));
        // The unified path must beat conventional on the serial lane
        // even in a single noisy iteration (≈4× FLOP reduction).
        assert!(
            res.speedup_ser() > 1.2,
            "serial speedup only {:.2}×",
            res.speedup_ser()
        );
        assert_eq!(res.total_savings(), 2_393_856); // exact paper match
    }

    #[test]
    fn flop_ratio_close_to_four() {
        let cfg = BenchConfig {
            scale: 1.0,
            warmup: 0,
            iters: 1,
            workers: 2,
        };
        let res = measure_model(GanModel::GpGan, &cfg);
        for r in &res.rows {
            let ratio = r.flops_conv as f64 / r.flops_prop as f64;
            assert!(ratio > 3.5 && ratio < 4.5, "ratio {ratio}");
        }
    }
}
