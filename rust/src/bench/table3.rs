//! Table 3 regeneration: MSCOCO 2017 (10% subset) and PASCAL VOC 2012
//! (classification + segmentation) — same protocol as Table 2, larger
//! sample counts, no memory column in the paper (it equals Table 2's
//! since the image geometry is identical; we print it anyway).

use crate::workload::datasets::{DatasetGroup, TABLE3_GROUPS};

use super::table2::{self, Row};
use super::BenchConfig;

/// Run the Table 3 sweep.
pub fn run_sweep(cfg: &BenchConfig, image_size: usize) -> Vec<Row> {
    table2::run_sweep(&TABLE3_GROUPS, cfg, image_size)
}

/// Run over a custom group list (used by the dataset_sweep example).
pub fn run_sweep_groups(
    groups: &[DatasetGroup],
    cfg: &BenchConfig,
    image_size: usize,
) -> Vec<Row> {
    table2::run_sweep(groups, cfg, image_size)
}

/// Print in the paper's Table 3 shape.
pub fn print_rows(rows: &[Row]) {
    table2::print_rows(
        "Table 3 — MSCOCO 2017 + PASCAL VOC 2012 (conventional vs proposed)",
        rows,
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_covers_all_groups_and_kernels() {
        let cfg = BenchConfig {
            scale: 0.0001,
            warmup: 0,
            iters: 1,
            workers: 2,
        };
        let rows = run_sweep(&cfg, 8);
        assert_eq!(rows.len(), TABLE3_GROUPS.len() * table2::KERNEL_SWEEP.len());
        // Groups appear in order with full kernel sweeps each.
        assert_eq!(rows[0].group, "(10% subset)");
        assert_eq!(rows[3].group, "Classification");
        assert_eq!(rows[6].group, "Segmentation");
    }
}
