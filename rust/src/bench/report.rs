//! Markdown table rendering for the bench harness, plus the shared
//! latency vocabulary ([`Latency`]: mean/best/p50/p95) that the bench
//! tables and the autotuner's decisions both report in.

use std::fmt::Write as _;

use crate::util::stats::Summary;
use crate::util::timing::fmt_duration;

/// Render a markdown table with right-padded columns.
pub fn render_table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let ncols = headers.len();
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        assert_eq!(row.len(), ncols, "row arity mismatch");
        for (w, cell) in widths.iter_mut().zip(row) {
            *w = (*w).max(cell.len());
        }
    }
    let mut out = String::new();
    let write_row = |out: &mut String, cells: &[String]| {
        let _ = write!(out, "|");
        for (w, cell) in widths.iter().zip(cells) {
            let _ = write!(out, " {cell:<w$} |");
        }
        let _ = writeln!(out);
    };
    write_row(
        &mut out,
        &headers.iter().map(|h| h.to_string()).collect::<Vec<_>>(),
    );
    let _ = write!(out, "|");
    for w in &widths {
        let _ = write!(out, "{:-<1$}|", "", w + 2);
    }
    let _ = writeln!(out);
    for row in rows {
        write_row(&mut out, row);
    }
    out
}

/// Print a titled table to stdout.
pub fn print_table(title: &str, headers: &[&str], rows: &[Vec<String>]) {
    println!("\n## {title}\n");
    print!("{}", render_table(headers, rows));
}

/// Format seconds with 4 significant decimals (the paper's unit).
pub fn secs(t: f64) -> String {
    format!("{t:.4}")
}

/// Latency summary of one measurement's samples: mean and best next
/// to p50/p95, so tuning decisions and bench tables speak one
/// vocabulary.  A thin projection of [`Summary`] — one stats
/// implementation, one percentile convention.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Latency {
    pub mean: f64,
    pub best: f64,
    pub p50: f64,
    pub p95: f64,
}

impl Latency {
    /// Column headers matching [`cells`](Self::cells).
    pub const HEADERS: [&'static str; 4] = ["mean", "best", "p50", "p95"];

    /// Compute from raw samples (seconds).  Panics on an empty slice.
    pub fn of(samples: &[f64]) -> Latency {
        let s = Summary::of(samples);
        Latency {
            mean: s.mean,
            best: s.min,
            p50: s.p50,
            p95: s.p95,
        }
    }

    /// Formatted table cells (adaptive units), ordered as
    /// [`HEADERS`](Self::HEADERS).
    pub fn cells(&self) -> Vec<String> {
        [self.mean, self.best, self.p50, self.p95]
            .iter()
            .map(|&t| fmt_duration(t))
            .collect()
    }
}

/// Format a speedup ratio like the paper ("2.03×").
pub fn speedup(r: f64) -> String {
    format!("{r:.3}×")
}

/// Achieved GFLOP/s for `macs` multiply-accumulates (2 FLOPs each)
/// executed in `seconds` — the hardware-terms throughput column
/// (`conv::flops` supplies the analytic MAC counts).
pub fn gflops(macs: u64, seconds: f64) -> f64 {
    if seconds <= 0.0 {
        return 0.0;
    }
    2.0 * macs as f64 / seconds / 1e9
}

/// Table cell for [`gflops`], 2 decimals.
pub fn gflops_cell(macs: u64, seconds: f64) -> String {
    format!("{:.2}", gflops(macs, seconds))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let t = render_table(
            &["a", "long-header"],
            &[
                vec!["1".into(), "2".into()],
                vec!["333".into(), "4".into()],
            ],
        );
        let lines: Vec<&str> = t.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].contains("long-header"));
        // All lines same width.
        assert_eq!(lines[0].len(), lines[2].len());
        assert!(lines[1].starts_with("|--"));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        render_table(&["a", "b"], &[vec!["1".into()]]);
    }

    #[test]
    fn formatting() {
        assert_eq!(secs(1.23456), "1.2346");
        assert_eq!(speedup(2.034), "2.034×");
    }

    #[test]
    fn gflops_formula() {
        // 1e9 MACs in 2 s = 2e9 FLOPs / 2 s = 1 GFLOP/s.
        assert!((gflops(1_000_000_000, 2.0) - 1.0).abs() < 1e-12);
        assert_eq!(gflops_cell(1_000_000_000, 2.0), "1.00");
        // Degenerate timings never divide by zero.
        assert_eq!(gflops(42, 0.0), 0.0);
    }

    #[test]
    fn latency_summary() {
        let l = Latency::of(&[3.0, 1.0, 2.0, 4.0, 5.0]);
        assert!((l.mean - 3.0).abs() < 1e-12);
        assert_eq!(l.best, 1.0);
        assert!((l.p50 - 3.0).abs() < 1e-12);
        assert!((l.p95 - 4.8).abs() < 1e-12);
        assert_eq!(l.cells().len(), Latency::HEADERS.len());
        assert!(l.cells()[0].ends_with(" s"));
    }

    #[test]
    #[should_panic(expected = "empty")]
    fn latency_empty_panics() {
        Latency::of(&[]);
    }
}
