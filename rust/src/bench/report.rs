//! Markdown table rendering for the bench harness.

use std::fmt::Write as _;

/// Render a markdown table with right-padded columns.
pub fn render_table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let ncols = headers.len();
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        assert_eq!(row.len(), ncols, "row arity mismatch");
        for (w, cell) in widths.iter_mut().zip(row) {
            *w = (*w).max(cell.len());
        }
    }
    let mut out = String::new();
    let write_row = |out: &mut String, cells: &[String]| {
        let _ = write!(out, "|");
        for (w, cell) in widths.iter().zip(cells) {
            let _ = write!(out, " {cell:<w$} |");
        }
        let _ = writeln!(out);
    };
    write_row(
        &mut out,
        &headers.iter().map(|h| h.to_string()).collect::<Vec<_>>(),
    );
    let _ = write!(out, "|");
    for w in &widths {
        let _ = write!(out, "{:-<1$}|", "", w + 2);
    }
    let _ = writeln!(out);
    for row in rows {
        write_row(&mut out, row);
    }
    out
}

/// Print a titled table to stdout.
pub fn print_table(title: &str, headers: &[&str], rows: &[Vec<String>]) {
    println!("\n## {title}\n");
    print!("{}", render_table(headers, rows));
}

/// Format seconds with 4 significant decimals (the paper's unit).
pub fn secs(t: f64) -> String {
    format!("{t:.4}")
}

/// Format a speedup ratio like the paper ("2.03×").
pub fn speedup(r: f64) -> String {
    format!("{r:.3}×")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let t = render_table(
            &["a", "long-header"],
            &[
                vec!["1".into(), "2".into()],
                vec!["333".into(), "4".into()],
            ],
        );
        let lines: Vec<&str> = t.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].contains("long-header"));
        // All lines same width.
        assert_eq!(lines[0].len(), lines[2].len());
        assert!(lines[1].starts_with("|--"));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        render_table(&["a", "b"], &[vec!["1".into()]]);
    }

    #[test]
    fn formatting() {
        assert_eq!(secs(1.23456), "1.2346");
        assert_eq!(speedup(2.034), "2.034×");
    }
}
