//! Table 2 regeneration: Flower dataset, conventional vs proposed,
//! serial ("CPU") + parallel ("GPU") lanes, kernels 5/4/3, plus the
//! memory-savings column.
//!
//! Protocol (paper §4.1–4.2): every image converted to 224×224×3, one
//! transpose convolution per image per configuration, total seconds per
//! group reported.  We time a `scale` subset per group and extrapolate
//! to the Table 1 sample counts (exact, since cost is per-image
//! constant); speedups are scale-invariant.

use crate::conv::parallel::{run_seg, Algorithm, Lane};
use crate::conv::segregation::segregate;
use crate::conv::{memory, ConvTransposeParams};
use crate::tensor::Kernel;
use crate::util::rng::Rng;
use crate::util::timing;
use crate::workload::datasets::{DatasetGroup, IMAGE_CHANNELS};

use super::{report, BenchConfig};

/// Kernel sizes in the paper's sweep, with their conventional padding
/// factors (chosen so the proposed path halves them: P = n - 2 keeps
/// the GAN convention k=4→P=2; the paper uses "same-family" padding).
pub const KERNEL_SWEEP: [(usize, usize); 3] = [(5, 2), (4, 2), (3, 1)];

/// One measured row of Table 2/3.
#[derive(Debug, Clone)]
pub struct Row {
    pub group: String,
    pub kernel: usize,
    /// Extrapolated dataset-total seconds.
    pub conv_par: f64,
    pub conv_ser: f64,
    pub prop_par: f64,
    pub prop_ser: f64,
    pub mem_savings_mb: f64,
}

impl Row {
    pub fn speedup_par(&self) -> f64 {
        self.conv_par / self.prop_par
    }

    pub fn speedup_ser(&self) -> f64 {
        self.conv_ser / self.prop_ser
    }
}

/// Time one (group, kernel) cell: returns extrapolated dataset totals.
pub fn measure_group(
    group: &DatasetGroup,
    n_k: usize,
    padding: usize,
    cfg: &BenchConfig,
    image_size: usize,
) -> Row {
    let count = cfg.sample_count(group.samples);
    let mut rng = Rng::seeded(0x7AB1E2 ^ n_k as u64);
    // The paper applies one n×n×3 filter bank per image (single output
    // map): cout = 1.
    let kernel = Kernel::random(n_k, IMAGE_CHANNELS, 1, &mut rng);
    let seg = segregate(&kernel);
    let images: Vec<_> = (0..count).map(|i| group.sample(i, image_size)).collect();

    let time_lane = |alg: Algorithm, lane: Lane| -> f64 {
        let m = timing::measure(cfg.warmup, cfg.iters, || {
            for img in &images {
                timing::consume(run_seg(alg, lane, img, &kernel, &seg, padding));
            }
        });
        // Median run / images-timed × full dataset size.
        m.median() / count as f64 * group.samples as f64
    };

    let par = Lane::Parallel(cfg.workers);
    let params = ConvTransposeParams::new(image_size, n_k, padding, IMAGE_CHANNELS, 1);
    Row {
        group: group.group.to_string(),
        kernel: n_k,
        conv_par: time_lane(Algorithm::Conventional, par),
        conv_ser: time_lane(Algorithm::Conventional, Lane::Serial),
        prop_par: time_lane(Algorithm::Unified, par),
        prop_ser: time_lane(Algorithm::Unified, Lane::Serial),
        mem_savings_mb: memory::to_decimal_mb(memory::savings_table2(&params)),
    }
}

/// Run the full Table 2 sweep over `groups`.
pub fn run_sweep(groups: &[DatasetGroup], cfg: &BenchConfig, image_size: usize) -> Vec<Row> {
    let mut rows = Vec::new();
    for group in groups {
        for &(n_k, padding) in &KERNEL_SWEEP {
            log::info!("table2: {} kernel {n_k}×{n_k}", group.group);
            rows.push(measure_group(group, n_k, padding, cfg, image_size));
        }
    }
    rows
}

/// Print rows in the paper's Table 2 format plus the summary claim line.
pub fn print_rows(title: &str, rows: &[Row]) {
    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.group.clone(),
                format!("{0}×{0}×3", r.kernel),
                report::secs(r.conv_par),
                report::secs(r.conv_ser),
                report::secs(r.prop_par),
                report::secs(r.prop_ser),
                report::speedup(r.speedup_par()),
                report::speedup(r.speedup_ser()),
                format!("{:.4}", r.mem_savings_mb),
            ]
        })
        .collect();
    report::print_table(
        title,
        &[
            "Data group",
            "Kernel",
            "Conv (par)",
            "Conv (serial)",
            "Prop (par)",
            "Prop (serial)",
            "Speedup (par)",
            "Speedup (serial)",
            "Mem savings (MB)",
        ],
        &table,
    );
    let par: Vec<f64> = rows.iter().map(Row::speedup_par).collect();
    let ser: Vec<f64> = rows.iter().map(Row::speedup_ser).collect();
    println!(
        "\naverage speedup: parallel {:.3}× (geomean {:.3}×), serial {:.3}× (geomean {:.3}×)",
        super::mean(&par),
        super::geomean(&par),
        super::mean(&ser),
        super::geomean(&ser),
    );
    println!(
        "paper reference: 2.03× GPU / 3.89× CPU average on its RTX 2070 + Xeon testbed"
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::datasets::{FLOWER_GROUPS, IMAGE_SIZE};

    /// Miniature end-to-end sweep: 16×16 images, 1-sample scale.
    #[test]
    fn mini_sweep_produces_sane_rows() {
        let cfg = BenchConfig {
            scale: 0.002,
            warmup: 0,
            iters: 1,
            workers: 2,
        };
        let rows = run_sweep(&FLOWER_GROUPS[..1], &cfg, 16);
        assert_eq!(rows.len(), KERNEL_SWEEP.len());
        for r in &rows {
            assert!(r.conv_ser > 0.0 && r.prop_ser > 0.0);
            assert!(r.speedup_ser() > 0.5, "serial speedup {}", r.speedup_ser());
            assert!(r.mem_savings_mb > 0.0);
        }
    }

    #[test]
    fn memory_column_matches_paper_at_full_size() {
        let cfg = BenchConfig {
            scale: 0.001,
            warmup: 0,
            iters: 1,
            workers: 2,
        };
        // Only check the analytic column; use a single tiny timing run
        // at full 224 image size but 1 sample.
        let row = measure_group(&FLOWER_GROUPS[0], 5, 2, &cfg, IMAGE_SIZE);
        assert!((row.mem_savings_mb - 1.8279).abs() < 1e-9);
    }

    #[test]
    fn print_rows_smoke() {
        let rows = vec![Row {
            group: "Daisy".into(),
            kernel: 5,
            conv_par: 2.0,
            conv_ser: 8.0,
            prop_par: 1.0,
            prop_ser: 2.0,
            mem_savings_mb: 1.8279,
        }];
        print_rows("smoke", &rows); // must not panic
        assert!((rows[0].speedup_ser() - 4.0).abs() < 1e-12);
    }
}
